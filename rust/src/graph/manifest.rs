//! Loader for `artifacts/manifest.json` produced by `python/compile/aot.py`.
//!
//! The manifest describes each AOT-compiled model: layer graph (mirroring
//! [`super::zoo`]), per-layer raw-weight blobs, and for every available
//! kernel variant the HLO-text artifact paths for its *execute* computation
//! and (if the variant needs one) its *weight-transform* computation.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use super::layer::Layer;
use super::model::ModelGraph;
use super::op::OpKind;
use crate::util::json::Json;

/// Artifact paths for one kernel variant of one layer.
#[derive(Debug, Clone)]
pub struct VariantArtifacts {
    /// Variant name ("direct", "im2col", "winograd", …).
    pub variant: String,
    /// HLO text implementing the layer forward with this variant's layout.
    pub exec_hlo: PathBuf,
    /// HLO text implementing raw→transformed weight conversion (None for
    /// variants that execute on raw weights).
    pub transform_hlo: Option<PathBuf>,
    /// Expected transformed-weight element count (f32), for cache sizing.
    pub transformed_elems: u64,
    /// Dims of the weight argument the exec computation expects.
    pub w_dims: Vec<i64>,
}

/// Per-layer artifact set.
#[derive(Debug, Clone, Default)]
pub struct LayerArtifacts {
    /// Path to the raw weight blob (empty for weightless layers).
    pub raw_weights: Option<PathBuf>,
    /// Raw weight element count (f32).
    pub raw_elems: u64,
    /// Bias element count at the tail of the raw blob (0 = no bias).
    pub bias_elems: u64,
    /// Dims of the layer's input activation (empty for the graph input).
    pub in_dims: Vec<i64>,
    /// Dims of the layer's output activation.
    pub out_dims: Vec<i64>,
    pub variants: Vec<VariantArtifacts>,
}

/// A fully parsed manifest: the graph plus artifact locations.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub model: ModelGraph,
    /// Indexed by layer id.
    pub artifacts: Vec<LayerArtifacts>,
    /// Directory the manifest was loaded from (paths are relative to it).
    pub root: PathBuf,
    /// Reference input blob for end-to-end numeric verification.
    pub fixture_input: Option<PathBuf>,
    /// Expected model output for the fixture input (produced by jax).
    pub fixture_output: Option<PathBuf>,
}

impl Manifest {
    /// Load and validate `<root>/manifest.json`.
    pub fn load(root: &Path) -> Result<Manifest> {
        let path = root.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        Manifest::parse(&text, root)
    }

    /// Parse manifest text. `root` is recorded for path resolution.
    pub fn parse(text: &str, root: &Path) -> Result<Manifest> {
        let doc = Json::parse(text).context("manifest.json is not valid JSON")?;
        let name = doc
            .get("model")
            .as_str()
            .context("manifest: missing 'model'")?
            .to_string();
        let layers_json = doc
            .get("layers")
            .as_arr()
            .context("manifest: missing 'layers' array")?;

        let mut layers = Vec::new();
        let mut artifacts = Vec::new();
        for (index, lj) in layers_json.iter().enumerate() {
            let (layer, arts) = parse_layer(index, lj)?;
            layers.push(layer);
            artifacts.push(arts);
        }
        let model = ModelGraph::new(&name, layers)
            .map_err(|e| anyhow::anyhow!("manifest graph invalid: {e}"))?;
        // Cross-check: every weighted layer must have raw weights and at
        // least one variant.
        for id in model.weighted_layers() {
            let a = &artifacts[id];
            if a.raw_weights.is_none() {
                bail!("manifest: layer {id} carries weights but has no raw blob");
            }
            if a.variants.is_empty() {
                bail!("manifest: layer {id} has no kernel variants");
            }
        }
        Ok(Manifest {
            model,
            artifacts,
            root: root.to_path_buf(),
            fixture_input: doc.get("fixture").get("input").as_str().map(PathBuf::from),
            fixture_output: doc.get("fixture").get("output").as_str().map(PathBuf::from),
        })
    }

    /// Resolve a manifest-relative path.
    pub fn resolve(&self, p: &Path) -> PathBuf {
        self.root.join(p)
    }

    /// All distinct variant names present.
    pub fn variant_names(&self) -> Vec<String> {
        let mut set = BTreeMap::new();
        for a in &self.artifacts {
            for v in &a.variants {
                set.insert(v.variant.clone(), ());
            }
        }
        set.into_keys().collect()
    }
}

fn parse_layer(index: usize, lj: &Json) -> Result<(Layer, LayerArtifacts)> {
    let ctx = || format!("manifest layer index {index}");
    let id = lj.get("id").as_usize().with_context(ctx)?;
    let name = lj.get("name").as_str().with_context(ctx)?.to_string();
    let op_name = lj.get("op").as_str().with_context(ctx)?;
    let get_u32 = |k: &str| -> Result<u32> {
        lj.get(k)
            .as_u64()
            .map(|v| v as u32)
            .with_context(|| format!("{} field {k}", ctx()))
    };
    let op = match op_name {
        "input" => OpKind::Input,
        "conv" => OpKind::Conv {
            kernel: get_u32("kernel")?,
            stride: get_u32("stride")?,
            groups: get_u32("groups")?,
        },
        "fc" => OpKind::Fc,
        "pool" => OpKind::Pool {
            kernel: get_u32("kernel")?,
            stride: get_u32("stride")?,
            global: lj.get("global").as_bool().unwrap_or(false),
        },
        "eltwise" => OpKind::Eltwise,
        "concat" => OpKind::Concat,
        "shuffle" => OpKind::ChannelShuffle,
        "act" => OpKind::Activation,
        "softmax" => OpKind::Softmax,
        "reshape" => OpKind::Reshape,
        "split" => OpKind::Split,
        "upsample" => OpKind::Upsample,
        other => bail!("{}: unknown op '{other}'", ctx()),
    };
    let deps = lj
        .get("deps")
        .as_arr()
        .unwrap_or(&[])
        .iter()
        .map(|d| d.as_usize().with_context(|| format!("{} deps", ctx())))
        .collect::<Result<Vec<_>>>()?;
    let layer = Layer {
        id,
        name,
        op,
        in_ch: get_u32("in_ch")?,
        out_ch: get_u32("out_ch")?,
        in_hw: get_u32("in_hw")?,
        out_hw: get_u32("out_hw")?,
        deps,
    };

    let dims = |key: &str| -> Vec<i64> {
        lj.get(key)
            .as_arr()
            .unwrap_or(&[])
            .iter()
            .filter_map(|d| d.as_f64().map(|v| v as i64))
            .collect()
    };
    let mut arts = LayerArtifacts {
        in_dims: dims("in_dims"),
        out_dims: dims("out_dims"),
        bias_elems: lj.get("bias_elems").as_u64().unwrap_or(0),
        ..LayerArtifacts::default()
    };
    if let Some(w) = lj.get("weights").as_str() {
        arts.raw_weights = Some(PathBuf::from(w));
        arts.raw_elems = lj.get("raw_elems").as_u64().unwrap_or(0);
    }
    if let Some(vmap) = lj.get("variants").as_obj() {
        for (vname, vj) in vmap {
            let exec = vj
                .get("exec")
                .as_str()
                .with_context(|| format!("{} variant {vname}: missing exec", ctx()))?;
            arts.variants.push(VariantArtifacts {
                variant: vname.clone(),
                exec_hlo: PathBuf::from(exec),
                transform_hlo: vj.get("transform").as_str().map(PathBuf::from),
                transformed_elems: vj.get("transformed_elems").as_u64().unwrap_or(0),
                w_dims: vj
                    .get("w_dims")
                    .as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .filter_map(|d| d.as_f64().map(|v| v as i64))
                    .collect(),
            });
        }
    }
    Ok((layer, arts))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "model": "unit",
      "layers": [
        {"id":0,"name":"input","op":"input","in_ch":3,"out_ch":3,"in_hw":8,"out_hw":8,"deps":[]},
        {"id":1,"name":"conv1","op":"conv","kernel":3,"stride":1,"groups":1,
         "in_ch":3,"out_ch":8,"in_hw":8,"out_hw":8,"deps":[0],
         "weights":"weights/L01.raw.bin","raw_elems":224,
         "variants":{
           "direct":{"exec":"layers/L01.direct.hlo.txt"},
           "im2col":{"exec":"layers/L01.im2col.hlo.txt",
                     "transform":"layers/L01.im2col.trans.hlo.txt",
                     "transformed_elems":216}}}
      ]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        assert_eq!(m.model.name, "unit");
        assert_eq!(m.model.len(), 2);
        assert_eq!(m.artifacts[1].variants.len(), 2);
        assert_eq!(m.variant_names(), vec!["direct".to_string(), "im2col".to_string()]);
        let im2col = &m.artifacts[1].variants[1];
        assert!(im2col.transform_hlo.is_some());
        assert_eq!(im2col.transformed_elems, 216);
        assert_eq!(
            m.resolve(&m.artifacts[1].raw_weights.clone().unwrap()),
            PathBuf::from("/tmp/a/weights/L01.raw.bin")
        );
    }

    #[test]
    fn rejects_weighted_layer_without_blob() {
        let bad = SAMPLE.replace(r#""weights":"weights/L01.raw.bin","#, "");
        assert!(Manifest::parse(&bad, Path::new("/tmp")).is_err());
    }

    #[test]
    fn rejects_unknown_op() {
        let bad = SAMPLE.replace(r#""op":"conv""#, r#""op":"lstm""#);
        assert!(Manifest::parse(&bad, Path::new("/tmp")).is_err());
    }
}
