//! The model graph container: validation, topological order, aggregates.

use std::collections::VecDeque;

use super::layer::{Layer, LayerId};
use crate::{Bytes, Flops};

/// A validated DAG of layers in topological id order.
#[derive(Debug, Clone)]
pub struct ModelGraph {
    pub name: String,
    layers: Vec<Layer>,
    /// Early-exit heads, in ascending layer-id order. Empty for the
    /// classic single-exit models — every existing consumer sees exactly
    /// the graph it always did.
    exits: Vec<ExitPoint>,
}

/// One early-exit head of a multi-exit model: the layer producing the
/// exit's prediction, the confidence threshold the runtime would gate on,
/// and the *calibrated* probability that a request actually leaves the
/// network here (measured offline on a validation set, as in the
/// early-exit literature). Layers after the exit only execute for the
/// `1 - probability` of requests that survive past it.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ExitPoint {
    /// The layer (typically a softmax head) whose output this exit reads.
    pub layer: LayerId,
    /// Confidence threshold in `(0, 1]` the exit gates on at runtime.
    pub threshold: f64,
    /// Calibrated probability in `[0, 1]` that a request exits here,
    /// conditioned on having reached this exit.
    pub probability: f64,
}

/// Error produced by [`ModelGraph::new`] validation.
#[derive(Debug, Clone, PartialEq)]
pub enum GraphError {
    /// Layer ids must equal their vector index.
    BadId { index: usize, id: LayerId },
    /// A dependency points at a not-yet-defined (or self) layer, so the
    /// given order is not topological.
    ForwardDep { layer: LayerId, dep: LayerId },
    /// Duplicate dependency entry.
    DupDep { layer: LayerId, dep: LayerId },
    /// Graph has no layers.
    Empty,
    /// An exit point references a layer the graph does not have.
    BadExit { layer: LayerId },
    /// An exit probability or threshold is outside `[0, 1]` / not finite.
    BadExitProbability { layer: LayerId, probability: f64 },
}

impl std::fmt::Display for GraphError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            GraphError::BadId { index, id } => write!(f, "layer at index {index} has id {id}"),
            GraphError::ForwardDep { layer, dep } => {
                write!(f, "layer {layer} depends on non-earlier layer {dep}")
            }
            GraphError::DupDep { layer, dep } => {
                write!(f, "layer {layer} lists dependency {dep} twice")
            }
            GraphError::Empty => write!(f, "graph has no layers"),
            GraphError::BadExit { layer } => {
                write!(f, "exit point references unknown layer {layer}")
            }
            GraphError::BadExitProbability { layer, probability } => {
                write!(f, "exit at layer {layer} has invalid probability {probability}")
            }
        }
    }
}

impl std::error::Error for GraphError {}

impl ModelGraph {
    /// Build and validate a graph. Layers must already be in topological
    /// order with `layer.id == index` (the builder guarantees this).
    pub fn new(name: &str, layers: Vec<Layer>) -> Result<ModelGraph, GraphError> {
        if layers.is_empty() {
            return Err(GraphError::Empty);
        }
        for (index, l) in layers.iter().enumerate() {
            if l.id != index {
                return Err(GraphError::BadId { index, id: l.id });
            }
            let mut seen = Vec::new();
            for &d in &l.deps {
                if d >= l.id {
                    return Err(GraphError::ForwardDep { layer: l.id, dep: d });
                }
                if seen.contains(&d) {
                    return Err(GraphError::DupDep { layer: l.id, dep: d });
                }
                seen.push(d);
            }
        }
        Ok(ModelGraph { name: name.to_string(), layers, exits: Vec::new() })
    }

    /// Attach validated early-exit points (multi-exit models). Exits are
    /// stored sorted by layer id; each must reference an existing layer
    /// and carry a probability and threshold in `[0, 1]`.
    pub fn with_exits(mut self, exits: Vec<ExitPoint>) -> Result<ModelGraph, GraphError> {
        for e in &exits {
            if e.layer >= self.layers.len() {
                return Err(GraphError::BadExit { layer: e.layer });
            }
            if !(0.0..=1.0).contains(&e.probability) || !e.probability.is_finite() {
                return Err(GraphError::BadExitProbability {
                    layer: e.layer,
                    probability: e.probability,
                });
            }
            if !(0.0..=1.0).contains(&e.threshold) || !e.threshold.is_finite() {
                return Err(GraphError::BadExitProbability {
                    layer: e.layer,
                    probability: e.threshold,
                });
            }
        }
        let mut exits = exits;
        exits.sort_by_key(|e| e.layer);
        self.exits = exits;
        Ok(self)
    }

    /// Early-exit points in ascending layer-id order (empty for
    /// single-exit models).
    pub fn exits(&self) -> &[ExitPoint] {
        &self.exits
    }

    /// Whether this is a multi-exit model.
    pub fn has_exits(&self) -> bool {
        !self.exits.is_empty()
    }

    /// Per-layer survival probabilities: `weights[l]` is the probability
    /// that a request still executes layer `l`, i.e. `Π (1 - p_e)` over
    /// all exits whose head layer precedes `l` in program order. All
    /// `1.0` for single-exit graphs — multiplying prices by these weights
    /// is then bit-preserving (IEEE `x * 1.0 == x`), which is what makes
    /// the expected-makespan scheduler provably exact in the
    /// no-early-exit limit.
    pub fn survival_weights(&self) -> Vec<f64> {
        let mut w = vec![1.0; self.layers.len()];
        if self.exits.is_empty() {
            return w;
        }
        let mut survive = 1.0;
        let mut next_exit = 0usize;
        for l in 0..self.layers.len() {
            while next_exit < self.exits.len() && self.exits[next_exit].layer < l {
                survive *= 1.0 - self.exits[next_exit].probability;
                next_exit += 1;
            }
            w[l] = survive;
        }
        w
    }

    pub fn layers(&self) -> &[Layer] {
        &self.layers
    }

    pub fn layer(&self, id: LayerId) -> &Layer {
        &self.layers[id]
    }

    pub fn len(&self) -> usize {
        self.layers.len()
    }

    pub fn is_empty(&self) -> bool {
        self.layers.is_empty()
    }

    /// Total parameter count.
    pub fn params(&self) -> u64 {
        self.layers.iter().map(Layer::params).sum()
    }

    /// Total raw weight bytes on disk.
    pub fn weight_bytes(&self) -> Bytes {
        self.layers.iter().map(Layer::weight_bytes).sum()
    }

    /// Total forward FLOPs.
    pub fn flops(&self) -> Flops {
        self.layers.iter().map(Layer::flops).sum()
    }

    /// Ids of layers that carry weights (those with read/transform
    /// operations in the cold-inference pipeline).
    pub fn weighted_layers(&self) -> Vec<LayerId> {
        self.layers
            .iter()
            .filter(|l| l.op.has_weights())
            .map(|l| l.id)
            .collect()
    }

    /// Successor adjacency (inverse of `deps`).
    pub fn successors(&self) -> Vec<Vec<LayerId>> {
        let mut succ = vec![Vec::new(); self.layers.len()];
        for l in &self.layers {
            for &d in &l.deps {
                succ[d].push(l.id);
            }
        }
        succ
    }

    /// Length (in layers) of the longest dependency chain — the graph's
    /// critical-path depth, used by the pipeline-efficiency analysis.
    pub fn depth(&self) -> usize {
        let mut depth = vec![1usize; self.layers.len()];
        for l in &self.layers {
            for &d in &l.deps {
                depth[l.id] = depth[l.id].max(depth[d] + 1);
            }
        }
        depth.into_iter().max().unwrap_or(0)
    }

    /// BFS layer ordering from the inputs (equals id order for valid graphs;
    /// used as a sanity check in tests).
    pub fn bfs_order(&self) -> Vec<LayerId> {
        let succ = self.successors();
        let mut indeg: Vec<usize> = self.layers.iter().map(|l| l.deps.len()).collect();
        let mut q: VecDeque<LayerId> = self
            .layers
            .iter()
            .filter(|l| l.deps.is_empty())
            .map(|l| l.id)
            .collect();
        let mut order = Vec::with_capacity(self.layers.len());
        while let Some(id) = q.pop_front() {
            order.push(id);
            for &s in &succ[id] {
                indeg[s] -= 1;
                if indeg[s] == 0 {
                    q.push_back(s);
                }
            }
        }
        order
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::op::OpKind;

    fn mk(id: usize, deps: Vec<usize>) -> Layer {
        Layer {
            id,
            name: format!("l{id}"),
            op: OpKind::Activation,
            in_ch: 8,
            out_ch: 8,
            in_hw: 8,
            out_hw: 8,
            deps,
        }
    }

    #[test]
    fn valid_graph_builds() {
        let g = ModelGraph::new("t", vec![mk(0, vec![]), mk(1, vec![0]), mk(2, vec![0, 1])])
            .unwrap();
        assert_eq!(g.len(), 3);
        assert_eq!(g.depth(), 3);
        assert_eq!(g.bfs_order().len(), 3);
    }

    #[test]
    fn rejects_forward_and_self_deps() {
        assert_eq!(
            ModelGraph::new("t", vec![mk(0, vec![0])]).unwrap_err(),
            GraphError::ForwardDep { layer: 0, dep: 0 }
        );
        assert_eq!(
            ModelGraph::new("t", vec![mk(0, vec![]), mk(1, vec![2]), mk(2, vec![])])
                .unwrap_err(),
            GraphError::ForwardDep { layer: 1, dep: 2 }
        );
    }

    #[test]
    fn rejects_bad_ids_and_dups() {
        assert_eq!(
            ModelGraph::new("t", vec![mk(1, vec![])]).unwrap_err(),
            GraphError::BadId { index: 0, id: 1 }
        );
        assert_eq!(
            ModelGraph::new("t", vec![mk(0, vec![]), mk(1, vec![0, 0])]).unwrap_err(),
            GraphError::DupDep { layer: 1, dep: 0 }
        );
        assert_eq!(ModelGraph::new("t", vec![]).unwrap_err(), GraphError::Empty);
    }

    #[test]
    fn exits_validate_and_sort() {
        let g = ModelGraph::new("t", vec![mk(0, vec![]), mk(1, vec![0]), mk(2, vec![1])])
            .unwrap();
        let g = g
            .with_exits(vec![
                ExitPoint { layer: 2, threshold: 0.9, probability: 0.3 },
                ExitPoint { layer: 1, threshold: 0.8, probability: 0.5 },
            ])
            .unwrap();
        assert!(g.has_exits());
        assert_eq!(g.exits()[0].layer, 1, "exits sorted by layer id");
        assert_eq!(g.exits()[1].layer, 2);
    }

    #[test]
    fn exits_reject_bad_layer_and_probability() {
        let g = ModelGraph::new("t", vec![mk(0, vec![])]).unwrap();
        assert_eq!(
            g.clone()
                .with_exits(vec![ExitPoint { layer: 9, threshold: 0.9, probability: 0.5 }])
                .unwrap_err(),
            GraphError::BadExit { layer: 9 }
        );
        assert!(matches!(
            g.with_exits(vec![ExitPoint { layer: 0, threshold: 0.9, probability: 1.5 }])
                .unwrap_err(),
            GraphError::BadExitProbability { layer: 0, .. }
        ));
    }

    #[test]
    fn survival_weights_compound_past_exits() {
        let layers: Vec<Layer> =
            (0..5).map(|i| mk(i, if i == 0 { vec![] } else { vec![i - 1] })).collect();
        let g = ModelGraph::new("t", layers)
            .unwrap()
            .with_exits(vec![
                ExitPoint { layer: 1, threshold: 0.9, probability: 0.5 },
                ExitPoint { layer: 3, threshold: 0.9, probability: 0.5 },
            ])
            .unwrap();
        let w = g.survival_weights();
        assert_eq!(w, vec![1.0, 1.0, 0.5, 0.5, 0.25]);
    }

    #[test]
    fn no_exits_means_all_ones() {
        let g = ModelGraph::new("t", vec![mk(0, vec![]), mk(1, vec![0])]).unwrap();
        assert!(!g.has_exits());
        assert!(g.survival_weights().iter().all(|&w| w == 1.0));
    }

    #[test]
    fn successors_inverse_of_deps() {
        let g = ModelGraph::new("t", vec![mk(0, vec![]), mk(1, vec![0]), mk(2, vec![0])])
            .unwrap();
        assert_eq!(g.successors()[0], vec![1, 2]);
        assert!(g.successors()[1].is_empty());
    }
}
