//! The model graph container: validation, topological order, aggregates.

use std::collections::VecDeque;

use super::layer::{Layer, LayerId};
use crate::{Bytes, Flops};

/// A validated DAG of layers in topological id order.
#[derive(Debug, Clone)]
pub struct ModelGraph {
    pub name: String,
    layers: Vec<Layer>,
}

/// Error produced by [`ModelGraph::new`] validation.
#[derive(Debug, Clone, PartialEq)]
pub enum GraphError {
    /// Layer ids must equal their vector index.
    BadId { index: usize, id: LayerId },
    /// A dependency points at a not-yet-defined (or self) layer, so the
    /// given order is not topological.
    ForwardDep { layer: LayerId, dep: LayerId },
    /// Duplicate dependency entry.
    DupDep { layer: LayerId, dep: LayerId },
    /// Graph has no layers.
    Empty,
}

impl std::fmt::Display for GraphError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            GraphError::BadId { index, id } => write!(f, "layer at index {index} has id {id}"),
            GraphError::ForwardDep { layer, dep } => {
                write!(f, "layer {layer} depends on non-earlier layer {dep}")
            }
            GraphError::DupDep { layer, dep } => {
                write!(f, "layer {layer} lists dependency {dep} twice")
            }
            GraphError::Empty => write!(f, "graph has no layers"),
        }
    }
}

impl std::error::Error for GraphError {}

impl ModelGraph {
    /// Build and validate a graph. Layers must already be in topological
    /// order with `layer.id == index` (the builder guarantees this).
    pub fn new(name: &str, layers: Vec<Layer>) -> Result<ModelGraph, GraphError> {
        if layers.is_empty() {
            return Err(GraphError::Empty);
        }
        for (index, l) in layers.iter().enumerate() {
            if l.id != index {
                return Err(GraphError::BadId { index, id: l.id });
            }
            let mut seen = Vec::new();
            for &d in &l.deps {
                if d >= l.id {
                    return Err(GraphError::ForwardDep { layer: l.id, dep: d });
                }
                if seen.contains(&d) {
                    return Err(GraphError::DupDep { layer: l.id, dep: d });
                }
                seen.push(d);
            }
        }
        Ok(ModelGraph { name: name.to_string(), layers })
    }

    pub fn layers(&self) -> &[Layer] {
        &self.layers
    }

    pub fn layer(&self, id: LayerId) -> &Layer {
        &self.layers[id]
    }

    pub fn len(&self) -> usize {
        self.layers.len()
    }

    pub fn is_empty(&self) -> bool {
        self.layers.is_empty()
    }

    /// Total parameter count.
    pub fn params(&self) -> u64 {
        self.layers.iter().map(Layer::params).sum()
    }

    /// Total raw weight bytes on disk.
    pub fn weight_bytes(&self) -> Bytes {
        self.layers.iter().map(Layer::weight_bytes).sum()
    }

    /// Total forward FLOPs.
    pub fn flops(&self) -> Flops {
        self.layers.iter().map(Layer::flops).sum()
    }

    /// Ids of layers that carry weights (those with read/transform
    /// operations in the cold-inference pipeline).
    pub fn weighted_layers(&self) -> Vec<LayerId> {
        self.layers
            .iter()
            .filter(|l| l.op.has_weights())
            .map(|l| l.id)
            .collect()
    }

    /// Successor adjacency (inverse of `deps`).
    pub fn successors(&self) -> Vec<Vec<LayerId>> {
        let mut succ = vec![Vec::new(); self.layers.len()];
        for l in &self.layers {
            for &d in &l.deps {
                succ[d].push(l.id);
            }
        }
        succ
    }

    /// Length (in layers) of the longest dependency chain — the graph's
    /// critical-path depth, used by the pipeline-efficiency analysis.
    pub fn depth(&self) -> usize {
        let mut depth = vec![1usize; self.layers.len()];
        for l in &self.layers {
            for &d in &l.deps {
                depth[l.id] = depth[l.id].max(depth[d] + 1);
            }
        }
        depth.into_iter().max().unwrap_or(0)
    }

    /// BFS layer ordering from the inputs (equals id order for valid graphs;
    /// used as a sanity check in tests).
    pub fn bfs_order(&self) -> Vec<LayerId> {
        let succ = self.successors();
        let mut indeg: Vec<usize> = self.layers.iter().map(|l| l.deps.len()).collect();
        let mut q: VecDeque<LayerId> = self
            .layers
            .iter()
            .filter(|l| l.deps.is_empty())
            .map(|l| l.id)
            .collect();
        let mut order = Vec::with_capacity(self.layers.len());
        while let Some(id) = q.pop_front() {
            order.push(id);
            for &s in &succ[id] {
                indeg[s] -= 1;
                if indeg[s] == 0 {
                    q.push_back(s);
                }
            }
        }
        order
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::op::OpKind;

    fn mk(id: usize, deps: Vec<usize>) -> Layer {
        Layer {
            id,
            name: format!("l{id}"),
            op: OpKind::Activation,
            in_ch: 8,
            out_ch: 8,
            in_hw: 8,
            out_hw: 8,
            deps,
        }
    }

    #[test]
    fn valid_graph_builds() {
        let g = ModelGraph::new("t", vec![mk(0, vec![]), mk(1, vec![0]), mk(2, vec![0, 1])])
            .unwrap();
        assert_eq!(g.len(), 3);
        assert_eq!(g.depth(), 3);
        assert_eq!(g.bfs_order().len(), 3);
    }

    #[test]
    fn rejects_forward_and_self_deps() {
        assert_eq!(
            ModelGraph::new("t", vec![mk(0, vec![0])]).unwrap_err(),
            GraphError::ForwardDep { layer: 0, dep: 0 }
        );
        assert_eq!(
            ModelGraph::new("t", vec![mk(0, vec![]), mk(1, vec![2]), mk(2, vec![])])
                .unwrap_err(),
            GraphError::ForwardDep { layer: 1, dep: 2 }
        );
    }

    #[test]
    fn rejects_bad_ids_and_dups() {
        assert_eq!(
            ModelGraph::new("t", vec![mk(1, vec![])]).unwrap_err(),
            GraphError::BadId { index: 0, id: 1 }
        );
        assert_eq!(
            ModelGraph::new("t", vec![mk(0, vec![]), mk(1, vec![0, 0])]).unwrap_err(),
            GraphError::DupDep { layer: 1, dep: 0 }
        );
        assert_eq!(ModelGraph::new("t", vec![]).unwrap_err(), GraphError::Empty);
    }

    #[test]
    fn successors_inverse_of_deps() {
        let g = ModelGraph::new("t", vec![mk(0, vec![]), mk(1, vec![0]), mk(2, vec![0])])
            .unwrap();
        assert_eq!(g.successors()[0], vec![1, 2]);
        assert!(g.successors()[1].is_empty());
    }
}
