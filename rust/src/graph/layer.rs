//! Per-layer record: shapes, parameters, FLOPs, dependencies.

use super::op::OpKind;
use crate::{Bytes, Flops};

/// Index of a layer within its [`super::model::ModelGraph`].
pub type LayerId = usize;

/// One layer of a model graph.
#[derive(Debug, Clone)]
pub struct Layer {
    pub id: LayerId,
    pub name: String,
    pub op: OpKind,
    /// Input channels (for multi-input ops: channels after combination).
    pub in_ch: u32,
    pub out_ch: u32,
    /// Input spatial size (square tensors; the paper's models are all
    /// 224×224-input CNNs).
    pub in_hw: u32,
    pub out_hw: u32,
    /// Direct predecessors in the dataflow graph.
    pub deps: Vec<LayerId>,
}

impl Layer {
    /// Number of weight parameters (bias folded in; BN folded).
    pub fn params(&self) -> u64 {
        match self.op {
            OpKind::Conv { kernel, groups, .. } => {
                let k = kernel as u64;
                let cin = self.in_ch as u64;
                let cout = self.out_ch as u64;
                let g = groups.max(1) as u64;
                // weights + bias
                cout * (cin / g) * k * k + cout
            }
            OpKind::Fc => (self.in_ch as u64) * (self.out_ch as u64) + self.out_ch as u64,
            _ => 0,
        }
    }

    /// Raw (pre-transformation) weight bytes on disk, f32 storage.
    pub fn weight_bytes(&self) -> Bytes {
        self.params() * 4
    }

    /// Multiply-accumulate count ×2 = FLOPs of the forward pass.
    pub fn flops(&self) -> Flops {
        let spatial = (self.out_hw as u64) * (self.out_hw as u64);
        match self.op {
            OpKind::Conv { kernel, groups, .. } => {
                let k = kernel as u64;
                let cin = self.in_ch as u64;
                let cout = self.out_ch as u64;
                let g = groups.max(1) as u64;
                2 * spatial * cout * (cin / g) * k * k
            }
            OpKind::Fc => 2 * (self.in_ch as u64) * (self.out_ch as u64),
            OpKind::Pool { kernel, .. } => {
                spatial * (self.out_ch as u64) * (kernel as u64) * (kernel as u64)
            }
            OpKind::Eltwise | OpKind::Activation | OpKind::ChannelShuffle => {
                spatial * self.out_ch as u64
            }
            OpKind::Softmax => 3 * self.out_ch as u64,
            OpKind::Concat | OpKind::Reshape | OpKind::Split | OpKind::Upsample => {
                spatial * self.out_ch as u64
            }
            OpKind::Input => 0,
        }
    }

    /// Activation (output feature map) bytes, f32.
    pub fn activation_bytes(&self) -> Bytes {
        (self.out_hw as u64) * (self.out_hw as u64) * (self.out_ch as u64) * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn conv(in_ch: u32, out_ch: u32, hw: u32, k: u32, s: u32, groups: u32) -> Layer {
        Layer {
            id: 0,
            name: "t".into(),
            op: OpKind::Conv { kernel: k, stride: s, groups },
            in_ch,
            out_ch,
            in_hw: hw,
            out_hw: hw / s,
            deps: vec![],
        }
    }

    #[test]
    fn conv_params_match_hand_count() {
        // 3x3 conv 64->192: 64*192*9 + 192 bias = 110,784
        let l = conv(64, 192, 56, 3, 1, 1);
        assert_eq!(l.params(), 64 * 192 * 9 + 192);
        assert_eq!(l.weight_bytes(), (64 * 192 * 9 + 192) * 4);
    }

    #[test]
    fn depthwise_params() {
        // dw 3x3 over 32 channels: 32*1*9 + 32
        let l = conv(32, 32, 112, 3, 1, 32);
        assert_eq!(l.params(), 32 * 9 + 32);
    }

    #[test]
    fn conv_flops_match_hand_count() {
        let l = conv(64, 192, 56, 3, 1, 1);
        assert_eq!(l.flops(), 2 * 56 * 56 * 192 * 64 * 9);
    }

    #[test]
    fn fc_params_and_flops() {
        let l = Layer {
            id: 0,
            name: "fc".into(),
            op: OpKind::Fc,
            in_ch: 2048,
            out_ch: 1000,
            in_hw: 1,
            out_hw: 1,
            deps: vec![],
        };
        assert_eq!(l.params(), 2048 * 1000 + 1000);
        assert_eq!(l.flops(), 2 * 2048 * 1000);
    }

    #[test]
    fn weightless_ops_have_zero_params() {
        let l = Layer {
            id: 0,
            name: "pool".into(),
            op: OpKind::Pool { kernel: 2, stride: 2, global: false },
            in_ch: 64,
            out_ch: 64,
            in_hw: 56,
            out_hw: 28,
            deps: vec![],
        };
        assert_eq!(l.params(), 0);
        assert!(l.flops() > 0);
    }
}
