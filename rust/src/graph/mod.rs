//! Model-graph IR, including the conditional-execution (early-exit) model.
//!
//! A DNN is a DAG of [`Layer`]s, each carrying its operator type, tensor
//! shapes, parameter count, and FLOPs. The scheduler (§3.2) needs exactly
//! this level of detail: per-layer weight bytes (reading cost), the layout
//! transformation implied by the selected kernel (transformation cost), the
//! FLOPs (execution cost), and the dependency structure (pipelining
//! constraints).
//!
//! # Conditional execution: multi-exit graphs
//!
//! A graph may additionally carry [`ExitPoint`]s — early-exit heads in the
//! BranchyNet style, each with a confidence threshold and a *calibrated*
//! probability that a request leaves the network there. Execution past an
//! exit is then **conditional**: layer `l` only runs for the fraction of
//! requests that survived every earlier exit, which
//! [`ModelGraph::survival_weights`] exposes as a per-layer probability
//! (`Π (1 - p_e)` over exits preceding `l`). The `exits` subsystem turns
//! these weights into expected-makespan schedules and local-vs-offload
//! serving decisions; graphs without exits report all-ones weights and are
//! bit-identical to the historical single-exit path everywhere.
//!
//! * [`op`] — operator taxonomy.
//! * [`layer`] — the per-layer record.
//! * [`model`] — the graph container with validation + topological order,
//!   plus exit-point validation and survival weights.
//! * [`builder`] — fluent construction helper used by the zoo (including
//!   [`builder::GraphBuilder::exit_branch`] for attaching exit heads).
//! * [`zoo`] — the paper's 12 evaluation models (Table 4), the small
//!   real-mode models matching the python artifacts, and the
//!   [`zoo::BRANCHY_MODELS`] multi-exit variants.
//! * [`manifest`] — loader for `artifacts/manifest.json` (real mode).

pub mod op;
pub mod layer;
pub mod model;
pub mod builder;
pub mod zoo;
pub mod manifest;

pub use layer::{Layer, LayerId};
pub use model::{ExitPoint, ModelGraph};
pub use op::OpKind;
