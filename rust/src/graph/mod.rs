//! Model-graph IR.
//!
//! A DNN is a DAG of [`Layer`]s, each carrying its operator type, tensor
//! shapes, parameter count, and FLOPs. The scheduler (§3.2) needs exactly
//! this level of detail: per-layer weight bytes (reading cost), the layout
//! transformation implied by the selected kernel (transformation cost), the
//! FLOPs (execution cost), and the dependency structure (pipelining
//! constraints).
//!
//! * [`op`] — operator taxonomy.
//! * [`layer`] — the per-layer record.
//! * [`model`] — the graph container with validation + topological order.
//! * [`builder`] — fluent construction helper used by the zoo.
//! * [`zoo`] — the paper's 12 evaluation models (Table 4) plus the small
//!   real-mode models matching the python artifacts.
//! * [`manifest`] — loader for `artifacts/manifest.json` (real mode).

pub mod op;
pub mod layer;
pub mod model;
pub mod builder;
pub mod zoo;
pub mod manifest;

pub use layer::{Layer, LayerId};
pub use model::ModelGraph;
pub use op::OpKind;
