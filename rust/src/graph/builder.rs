//! Fluent graph construction used by the model zoo.
//!
//! Tracks the "current" tensor (channels + spatial size) so chains of layers
//! read like the architecture tables in the original papers. Branches are
//! expressed by saving a [`Tap`] and resuming from it.

use super::layer::{Layer, LayerId};
use super::model::{ExitPoint, GraphError, ModelGraph};
use super::op::OpKind;

/// A resumable point in the graph: a layer output with known shape.
#[derive(Debug, Clone, Copy)]
pub struct Tap {
    pub id: LayerId,
    pub ch: u32,
    pub hw: u32,
}

/// Builder accumulating layers in topological order.
pub struct GraphBuilder {
    name: String,
    layers: Vec<Layer>,
    cur: Option<Tap>,
    exits: Vec<ExitPoint>,
}

impl GraphBuilder {
    pub fn new(name: &str) -> GraphBuilder {
        GraphBuilder {
            name: name.to_string(),
            layers: Vec::new(),
            cur: None,
            exits: Vec::new(),
        }
    }

    /// Current tap (panics if no layers yet).
    pub fn tap(&self) -> Tap {
        self.cur.expect("builder has no current tensor")
    }

    /// Resume building from a saved tap.
    pub fn resume(&mut self, tap: Tap) -> &mut Self {
        self.cur = Some(tap);
        self
    }

    fn push(&mut self, name: String, op: OpKind, deps: Vec<LayerId>, in_ch: u32, out_ch: u32, in_hw: u32, out_hw: u32) -> Tap {
        let id = self.layers.len();
        self.layers.push(Layer { id, name, op, in_ch, out_ch, in_hw, out_hw, deps });
        let tap = Tap { id, ch: out_ch, hw: out_hw };
        self.cur = Some(tap);
        tap
    }

    /// Declare the graph input.
    pub fn input(&mut self, ch: u32, hw: u32) -> Tap {
        assert!(self.layers.is_empty(), "input must be the first layer");
        self.push("input".into(), OpKind::Input, vec![], ch, ch, hw, hw)
    }

    /// Standard convolution from the current tensor.
    pub fn conv(&mut self, name: &str, out_ch: u32, kernel: u32, stride: u32) -> Tap {
        self.grouped_conv(name, out_ch, kernel, stride, 1)
    }

    /// Grouped convolution.
    pub fn grouped_conv(&mut self, name: &str, out_ch: u32, kernel: u32, stride: u32, groups: u32) -> Tap {
        let t = self.tap();
        let out_hw = conv_out(t.hw, kernel, stride);
        self.push(
            name.into(),
            OpKind::Conv { kernel, stride, groups },
            vec![t.id],
            t.ch,
            out_ch,
            t.hw,
            out_hw,
        )
    }

    /// Depthwise convolution (groups == channels).
    pub fn dwconv(&mut self, name: &str, kernel: u32, stride: u32) -> Tap {
        let ch = self.tap().ch;
        self.grouped_conv(name, ch, kernel, stride, ch)
    }

    /// Pointwise (1×1) convolution.
    pub fn pwconv(&mut self, name: &str, out_ch: u32) -> Tap {
        self.conv(name, out_ch, 1, 1)
    }

    /// Pooling.
    pub fn pool(&mut self, name: &str, kernel: u32, stride: u32) -> Tap {
        let t = self.tap();
        let out_hw = conv_out(t.hw, kernel, stride);
        self.push(
            name.into(),
            OpKind::Pool { kernel, stride, global: false },
            vec![t.id],
            t.ch,
            t.ch,
            t.hw,
            out_hw,
        )
    }

    /// Global average pool down to 1×1.
    pub fn global_pool(&mut self, name: &str) -> Tap {
        let t = self.tap();
        self.push(
            name.into(),
            OpKind::Pool { kernel: t.hw, stride: t.hw, global: true },
            vec![t.id],
            t.ch,
            t.ch,
            t.hw,
            1,
        )
    }

    /// Fully connected layer (input flattened).
    pub fn fc(&mut self, name: &str, out: u32) -> Tap {
        let t = self.tap();
        let in_features = t.ch * t.hw * t.hw;
        self.push(name.into(), OpKind::Fc, vec![t.id], in_features, out, 1, 1)
    }

    /// Residual add of the current tensor with another tap.
    pub fn add(&mut self, name: &str, other: Tap) -> Tap {
        let t = self.tap();
        assert_eq!(t.hw, other.hw, "eltwise shape mismatch in {name}");
        assert_eq!(t.ch, other.ch, "eltwise channel mismatch in {name}");
        self.push(
            name.into(),
            OpKind::Eltwise,
            vec![t.id, other.id],
            t.ch,
            t.ch,
            t.hw,
            t.hw,
        )
    }

    /// Channel concat of multiple taps (all same spatial size).
    pub fn concat(&mut self, name: &str, taps: &[Tap]) -> Tap {
        assert!(!taps.is_empty());
        let hw = taps[0].hw;
        assert!(taps.iter().all(|t| t.hw == hw), "concat spatial mismatch in {name}");
        let ch: u32 = taps.iter().map(|t| t.ch).sum();
        self.push(
            name.into(),
            OpKind::Concat,
            taps.iter().map(|t| t.id).collect(),
            ch,
            ch,
            hw,
            hw,
        )
    }

    /// Channel shuffle (ShuffleNet).
    pub fn shuffle(&mut self, name: &str) -> Tap {
        let t = self.tap();
        self.push(name.into(), OpKind::ChannelShuffle, vec![t.id], t.ch, t.ch, t.hw, t.hw)
    }

    /// Channel split: returns the two halves as taps (modelled as one Split
    /// layer; both halves resume from it with half the channels).
    pub fn split(&mut self, name: &str) -> (Tap, Tap) {
        let t = self.tap();
        assert!(t.ch % 2 == 0, "split needs even channels in {name}");
        let tap = self.push(name.into(), OpKind::Split, vec![t.id], t.ch, t.ch, t.hw, t.hw);
        let half = Tap { id: tap.id, ch: t.ch / 2, hw: t.hw };
        (half, half)
    }

    /// Nearest-neighbour upsample ×2 (YOLO neck).
    pub fn upsample(&mut self, name: &str) -> Tap {
        let t = self.tap();
        self.push(name.into(), OpKind::Upsample, vec![t.id], t.ch, t.ch, t.hw, t.hw * 2)
    }

    /// Softmax head.
    pub fn softmax(&mut self, name: &str) -> Tap {
        let t = self.tap();
        self.push(name.into(), OpKind::Softmax, vec![t.id], t.ch, t.ch, t.hw, t.hw)
    }

    /// Attach an early-exit head at the current tensor: a global pool →
    /// `classes`-way FC → softmax branch whose softmax is recorded as an
    /// [`ExitPoint`] with the given confidence `threshold` and calibrated
    /// exit `probability`. The builder then resumes the backbone from the
    /// pre-branch tap, so subsequent layers depend on the branch point,
    /// not the exit head — exactly the branchy-network topology
    /// (BranchyNet-style) the early-exit literature schedules.
    pub fn exit_branch(
        &mut self,
        name: &str,
        classes: u32,
        threshold: f64,
        probability: f64,
    ) -> Tap {
        let backbone = self.tap();
        self.global_pool(&format!("{name}_gap"));
        self.fc(&format!("{name}_fc"), classes);
        let head = self.softmax(&format!("{name}_softmax"));
        self.exits.push(ExitPoint { layer: head.id, threshold, probability });
        self.cur = Some(backbone);
        head
    }

    /// Finalize into a validated graph (with any recorded exit points
    /// attached — single-exit graphs take the exact historical path).
    pub fn build(self) -> Result<ModelGraph, GraphError> {
        let g = ModelGraph::new(&self.name, self.layers)?;
        if self.exits.is_empty() {
            return Ok(g);
        }
        g.with_exits(self.exits)
    }
}

/// Output spatial size of a conv/pool with SAME-ish padding, floor division
/// (matches how the paper's model zoo shapes march: 224→112→56→28→14→7).
pub fn conv_out(hw: u32, _kernel: u32, stride: u32) -> u32 {
    (hw + stride - 1) / stride
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chains_track_shapes() {
        let mut b = GraphBuilder::new("t");
        b.input(3, 224);
        let t = b.conv("c1", 32, 3, 2);
        assert_eq!(t.hw, 112);
        assert_eq!(t.ch, 32);
        b.dwconv("dw", 3, 1);
        let t = b.pwconv("pw", 64);
        assert_eq!(t.ch, 64);
        b.global_pool("gap");
        let t = b.fc("fc", 1000);
        assert_eq!(t.ch, 1000);
        let g = b.build().unwrap();
        assert_eq!(g.len(), 6);
        assert_eq!(g.bfs_order().len(), 6);
    }

    #[test]
    fn branches_and_merge() {
        let mut b = GraphBuilder::new("t");
        b.input(3, 32);
        let stem = b.conv("stem", 16, 3, 1);
        let left = b.conv("left", 16, 3, 1);
        b.resume(stem);
        let right = b.conv("right", 16, 1, 1);
        b.resume(left);
        b.add("merge", right);
        let g = b.build().unwrap();
        assert_eq!(g.layer(4).deps, vec![2, 3]);
    }

    #[test]
    #[should_panic(expected = "eltwise channel mismatch")]
    fn add_rejects_mismatched_channels() {
        let mut b = GraphBuilder::new("t");
        b.input(3, 32);
        let a = b.conv("a", 16, 3, 1);
        b.conv("b", 32, 3, 1);
        b.add("bad", a);
    }

    #[test]
    fn exit_branch_records_exit_and_resumes_backbone() {
        let mut b = GraphBuilder::new("t");
        b.input(3, 32);
        let stem = b.conv("stem", 16, 3, 1);
        b.exit_branch("exit1", 10, 0.9, 0.4);
        let next = b.conv("c2", 32, 3, 2);
        let g = b.build().unwrap();
        // Branch head: gap(2), fc(3), softmax(4); backbone resumes at the
        // stem — the post-branch conv depends on the stem, not the head.
        assert_eq!(g.layer(next.id).deps, vec![stem.id]);
        assert_eq!(g.exits().len(), 1);
        assert_eq!(g.exits()[0].layer, 4);
        assert_eq!(g.exits()[0].probability, 0.4);
        // Layers strictly after the exit head carry the survival weight.
        let w = g.survival_weights();
        assert_eq!(w[stem.id], 1.0);
        assert_eq!(w[4], 1.0, "the exit head itself always executes");
        assert!((w[next.id] - 0.6).abs() < 1e-12);
    }

    #[test]
    fn concat_sums_channels() {
        let mut b = GraphBuilder::new("t");
        b.input(3, 32);
        let s = b.conv("s", 8, 1, 1);
        let x = b.conv("x", 16, 1, 1);
        b.resume(s);
        let y = b.conv("y", 24, 3, 1);
        let t = b.concat("cat", &[x, y]);
        assert_eq!(t.ch, 40);
    }
}
