//! Operator taxonomy.
//!
//! The kinds cover everything appearing in the paper's 12 models (Table 4).
//! Batch norm and bias are treated as folded into the preceding conv/fc
//! (standard inference-time folding, which is also what ncnn's optimizer
//! does before the kernels the paper studies ever run).

/// Operator kind with its static hyper-parameters.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum OpKind {
    /// Graph input placeholder (no cost).
    Input,
    /// 2-D convolution. `groups == in_ch` is depthwise.
    Conv { kernel: u32, stride: u32, groups: u32 },
    /// Fully connected / inner product.
    Fc,
    /// Pooling (max or average; cost-equivalent here).
    Pool { kernel: u32, stride: u32, global: bool },
    /// Element-wise binary op (residual add, multiply).
    Eltwise,
    /// Channel concatenation.
    Concat,
    /// ShuffleNet channel shuffle.
    ChannelShuffle,
    /// Stand-alone activation (ReLU/HSwish/SiLU — cost-equivalent).
    Activation,
    /// Softmax head.
    Softmax,
    /// Tensor reshape / flatten (no math, negligible cost).
    Reshape,
    /// Channel split (ShuffleNetV2).
    Split,
    /// Upsample / interp (YOLO necks).
    Upsample,
}

impl OpKind {
    /// Whether this operator carries weights that must be read from disk.
    pub fn has_weights(&self) -> bool {
        matches!(self, OpKind::Conv { .. } | OpKind::Fc)
    }

    /// Whether this is a convolution (the operator family with the rich
    /// kernel-variant space of Fig. 5).
    pub fn is_conv(&self) -> bool {
        matches!(self, OpKind::Conv { .. })
    }

    /// Whether this is a depthwise convolution given the input channels.
    pub fn is_depthwise(&self, in_ch: u32) -> bool {
        matches!(self, OpKind::Conv { groups, .. } if *groups == in_ch && in_ch > 1)
    }

    /// Short name used in manifests, plans, and reports.
    pub fn name(&self) -> &'static str {
        match self {
            OpKind::Input => "input",
            OpKind::Conv { .. } => "conv",
            OpKind::Fc => "fc",
            OpKind::Pool { .. } => "pool",
            OpKind::Eltwise => "eltwise",
            OpKind::Concat => "concat",
            OpKind::ChannelShuffle => "shuffle",
            OpKind::Activation => "act",
            OpKind::Softmax => "softmax",
            OpKind::Reshape => "reshape",
            OpKind::Split => "split",
            OpKind::Upsample => "upsample",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn weight_carrying_ops() {
        assert!(OpKind::Conv { kernel: 3, stride: 1, groups: 1 }.has_weights());
        assert!(OpKind::Fc.has_weights());
        assert!(!OpKind::Pool { kernel: 2, stride: 2, global: false }.has_weights());
        assert!(!OpKind::Eltwise.has_weights());
    }

    #[test]
    fn depthwise_detection() {
        let dw = OpKind::Conv { kernel: 3, stride: 1, groups: 32 };
        assert!(dw.is_depthwise(32));
        assert!(!dw.is_depthwise(64));
        let std = OpKind::Conv { kernel: 3, stride: 1, groups: 1 };
        assert!(!std.is_depthwise(1));
    }
}
