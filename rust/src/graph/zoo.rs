//! The paper's evaluation models (Table 4) plus small real-mode models.
//!
//! Layer configurations follow the original architecture papers; batch norm
//! is folded. Parameter counts land within a few tens of percent of the
//! originals (exact padding/cropping details differ), which is all the cold
//! -inference cost model needs: per-layer weight bytes, FLOPs, and the
//! dependency structure.
//!
//! `tiny_net` / `micro_mobilenet` mirror the models that
//! `python/compile/model.py` AOT-lowers for the real PJRT execution path,
//! layer for layer — `tests/real_mode.rs` asserts the manifest agrees.

use super::builder::{GraphBuilder, Tap};
use super::model::ModelGraph;

/// Names of the 12 paper models, in Table 4 order.
pub const PAPER_MODELS: [&str; 12] = [
    "alexnet",
    "googlenet",
    "mobilenet",
    "mobilenetv2",
    "resnet18",
    "shufflenet",
    "efficientnetb0",
    "resnet50",
    "squeezenet",
    "shufflenetv2",
    "mobilenetv2-yolov3",
    "mobilenet-yolo",
];

/// Build a model by name (paper models + `tinynet`, `micro-mobilenet`,
/// `crnn-lite`).
pub fn by_name(name: &str) -> Option<ModelGraph> {
    let g = match name {
        "alexnet" => alexnet(),
        "googlenet" => googlenet(),
        "mobilenet" => mobilenet_v1(),
        "mobilenetv2" => mobilenet_v2(),
        "resnet18" => resnet18(),
        "shufflenet" => shufflenet_v1(),
        "efficientnetb0" => efficientnet_b0(),
        "resnet50" => resnet50(),
        "squeezenet" => squeezenet(),
        "shufflenetv2" => shufflenet_v2(),
        "mobilenetv2-yolov3" => mobilenetv2_yolov3(),
        "mobilenet-yolo" => mobilenet_yolo(),
        "crnn-lite" => crnn_lite(),
        "tinynet" => tiny_net(),
        "micro-mobilenet" => micro_mobilenet(),
        "branchy-resnet18" => branchy_resnet18(),
        "branchy-mobilenet" => branchy_mobilenet(),
        "branchy-tinynet" => branchy_tinynet(),
        _ => return None,
    };
    Some(g)
}

/// The multi-exit models (BranchyNet-style variants of zoo backbones).
pub const BRANCHY_MODELS: [&str; 3] =
    ["branchy-resnet18", "branchy-mobilenet", "branchy-tinynet"];

/// All paper models, built.
pub fn paper_models() -> Vec<ModelGraph> {
    PAPER_MODELS.iter().map(|n| by_name(n).unwrap()).collect()
}

pub fn alexnet() -> ModelGraph {
    let mut b = GraphBuilder::new("alexnet");
    b.input(3, 224);
    b.conv("conv1", 96, 11, 4);
    b.pool("pool1", 3, 2);
    b.grouped_conv("conv2", 256, 5, 1, 2);
    b.pool("pool2", 3, 2);
    b.conv("conv3", 384, 3, 1);
    b.grouped_conv("conv4", 384, 3, 1, 2);
    b.grouped_conv("conv5", 256, 3, 1, 2);
    b.pool("pool5", 3, 2);
    // Original flattens 6x6; our SAME-padding shape math gives 7x7, so we
    // GAP to a 2x2 grid worth of features via an fc on the pooled map.
    b.pool("pool6", 2, 1); // keeps 7x7 -> models the crop
    b.fc("fc6", 4096);
    b.fc("fc7", 4096);
    b.fc("fc8", 1000);
    b.softmax("prob");
    b.build().unwrap()
}

fn inception(b: &mut GraphBuilder, name: &str, stem: Tap, c1: u32, c3r: u32, c3: u32, c5r: u32, c5: u32, pp: u32) -> Tap {
    b.resume(stem);
    let b1 = b.pwconv(&format!("{name}/1x1"), c1);
    b.resume(stem);
    b.pwconv(&format!("{name}/3x3_reduce"), c3r);
    let b3 = b.conv(&format!("{name}/3x3"), c3, 3, 1);
    b.resume(stem);
    b.pwconv(&format!("{name}/5x5_reduce"), c5r);
    let b5 = b.conv(&format!("{name}/5x5"), c5, 5, 1);
    b.resume(stem);
    b.pool(&format!("{name}/pool"), 3, 1);
    let bp = b.pwconv(&format!("{name}/pool_proj"), pp);
    b.concat(&format!("{name}/concat"), &[b1, b3, b5, bp])
}

pub fn googlenet() -> ModelGraph {
    let mut b = GraphBuilder::new("googlenet");
    b.input(3, 224);
    b.conv("conv1", 64, 7, 2);
    b.pool("pool1", 3, 2);
    b.pwconv("conv2_reduce", 64);
    b.conv("conv2", 192, 3, 1);
    let mut t = b.pool("pool2", 3, 2);
    t = inception(&mut b, "3a", t, 64, 96, 128, 16, 32, 32);
    inception(&mut b, "3b", t, 128, 128, 192, 32, 96, 64);
    t = b.pool("pool3", 3, 2);
    t = inception(&mut b, "4a", t, 192, 96, 208, 16, 48, 64);
    t = inception(&mut b, "4b", t, 160, 112, 224, 24, 64, 64);
    t = inception(&mut b, "4c", t, 128, 128, 256, 24, 64, 64);
    t = inception(&mut b, "4d", t, 112, 144, 288, 32, 64, 64);
    inception(&mut b, "4e", t, 256, 160, 320, 32, 128, 128);
    t = b.pool("pool4", 3, 2);
    t = inception(&mut b, "5a", t, 256, 160, 320, 32, 128, 128);
    inception(&mut b, "5b", t, 384, 192, 384, 48, 128, 128);
    b.global_pool("gap");
    b.fc("fc", 1000);
    b.softmax("prob");
    b.build().unwrap()
}

fn dw_separable(b: &mut GraphBuilder, name: &str, out_ch: u32, stride: u32) -> Tap {
    b.dwconv(&format!("{name}/dw"), 3, stride);
    b.pwconv(&format!("{name}/pw"), out_ch)
}

pub fn mobilenet_v1() -> ModelGraph {
    let mut b = GraphBuilder::new("mobilenet");
    b.input(3, 224);
    b.conv("conv1", 32, 3, 2);
    dw_separable(&mut b, "ds2", 64, 1);
    dw_separable(&mut b, "ds3", 128, 2);
    dw_separable(&mut b, "ds4", 128, 1);
    dw_separable(&mut b, "ds5", 256, 2);
    dw_separable(&mut b, "ds6", 256, 1);
    dw_separable(&mut b, "ds7", 512, 2);
    for i in 8..13 {
        dw_separable(&mut b, &format!("ds{i}"), 512, 1);
    }
    dw_separable(&mut b, "ds13", 1024, 2);
    dw_separable(&mut b, "ds14", 1024, 1);
    b.global_pool("gap");
    b.fc("fc", 1000);
    b.softmax("prob");
    b.build().unwrap()
}

fn inverted_residual(b: &mut GraphBuilder, name: &str, in_tap: Tap, out_ch: u32, stride: u32, expand: u32) -> Tap {
    b.resume(in_tap);
    let hidden = in_tap.ch * expand;
    if expand != 1 {
        b.pwconv(&format!("{name}/expand"), hidden);
    }
    b.dwconv(&format!("{name}/dw"), 3, stride);
    let out = b.pwconv(&format!("{name}/project"), out_ch);
    if stride == 1 && in_tap.ch == out_ch {
        b.add(&format!("{name}/add"), in_tap)
    } else {
        out
    }
}

pub fn mobilenet_v2() -> ModelGraph {
    let mut b = GraphBuilder::new("mobilenetv2");
    b.input(3, 224);
    let mut t = b.conv("conv1", 32, 3, 2);
    // (expand, out_ch, repeats, stride)
    let cfg: [(u32, u32, u32, u32); 7] = [
        (1, 16, 1, 1),
        (6, 24, 2, 2),
        (6, 32, 3, 2),
        (6, 64, 4, 2),
        (6, 96, 3, 1),
        (6, 160, 3, 2),
        (6, 320, 1, 1),
    ];
    let mut block = 0;
    for (e, c, n, s) in cfg {
        for i in 0..n {
            let stride = if i == 0 { s } else { 1 };
            t = inverted_residual(&mut b, &format!("ir{block}"), t, c, stride, e);
            block += 1;
        }
    }
    b.pwconv("conv_last", 1280);
    b.global_pool("gap");
    b.fc("fc", 1000);
    b.softmax("prob");
    b.build().unwrap()
}

fn basic_block(b: &mut GraphBuilder, name: &str, in_tap: Tap, out_ch: u32, stride: u32) -> Tap {
    b.resume(in_tap);
    b.conv(&format!("{name}/conv1"), out_ch, 3, stride);
    let main = b.conv(&format!("{name}/conv2"), out_ch, 3, 1);
    let shortcut = if stride != 1 || in_tap.ch != out_ch {
        b.resume(in_tap);
        b.conv(&format!("{name}/down"), out_ch, 1, stride)
    } else {
        in_tap
    };
    b.resume(main);
    b.add(&format!("{name}/add"), shortcut)
}

pub fn resnet18() -> ModelGraph {
    let mut b = GraphBuilder::new("resnet18");
    b.input(3, 224);
    b.conv("conv1", 64, 7, 2);
    let mut t = b.pool("pool1", 3, 2);
    for (stage, (ch, s)) in [(64u32, 1u32), (128, 2), (256, 2), (512, 2)].iter().enumerate() {
        for i in 0..2 {
            let stride = if i == 0 { *s } else { 1 };
            t = basic_block(&mut b, &format!("res{}_{i}", stage + 2), t, *ch, stride);
        }
    }
    b.global_pool("gap");
    b.fc("fc", 1000);
    b.softmax("prob");
    b.build().unwrap()
}

fn bottleneck(b: &mut GraphBuilder, name: &str, in_tap: Tap, mid_ch: u32, stride: u32) -> Tap {
    let out_ch = mid_ch * 4;
    b.resume(in_tap);
    b.pwconv(&format!("{name}/conv1"), mid_ch);
    b.conv(&format!("{name}/conv2"), mid_ch, 3, stride);
    let main = b.pwconv(&format!("{name}/conv3"), out_ch);
    let shortcut = if stride != 1 || in_tap.ch != out_ch {
        b.resume(in_tap);
        b.conv(&format!("{name}/down"), out_ch, 1, stride)
    } else {
        in_tap
    };
    b.resume(main);
    b.add(&format!("{name}/add"), shortcut)
}

pub fn resnet50() -> ModelGraph {
    let mut b = GraphBuilder::new("resnet50");
    b.input(3, 224);
    b.conv("conv1", 64, 7, 2);
    let mut t = b.pool("pool1", 3, 2);
    for (stage, (mid, reps, s)) in
        [(64u32, 3u32, 1u32), (128, 4, 2), (256, 6, 2), (512, 3, 2)].iter().enumerate()
    {
        for i in 0..*reps {
            let stride = if i == 0 { *s } else { 1 };
            t = bottleneck(&mut b, &format!("res{}_{i}", stage + 2), t, *mid, stride);
        }
    }
    b.global_pool("gap");
    b.fc("fc", 1000);
    b.softmax("prob");
    b.build().unwrap()
}

fn shuffle_unit_v1(b: &mut GraphBuilder, name: &str, in_tap: Tap, out_ch: u32, stride: u32, groups: u32) -> Tap {
    let mid = out_ch / 4;
    b.resume(in_tap);
    b.grouped_conv(&format!("{name}/gconv1"), mid, 1, 1, groups);
    b.shuffle(&format!("{name}/shuffle"));
    b.dwconv(&format!("{name}/dw"), 3, stride);
    let branch_out = if stride == 2 { out_ch - in_tap.ch } else { out_ch };
    let main = b.grouped_conv(&format!("{name}/gconv2"), branch_out, 1, 1, groups);
    if stride == 2 {
        b.resume(in_tap);
        let avg = b.pool(&format!("{name}/avgpool"), 3, 2);
        b.concat(&format!("{name}/concat"), &[main, avg])
    } else {
        b.resume(main);
        b.add(&format!("{name}/add"), in_tap)
    }
}

pub fn shufflenet_v1() -> ModelGraph {
    // ShuffleNet v1, groups = 3, ~1.5x width to land near the paper's 3.6M.
    let mut b = GraphBuilder::new("shufflenet");
    b.input(3, 224);
    b.conv("conv1", 24, 3, 2);
    let mut t = b.pool("pool1", 3, 2);
    let stage_ch = [360u32, 720, 1440];
    for (s, &ch) in stage_ch.iter().enumerate() {
        let reps = [3, 7, 3][s];
        t = shuffle_unit_v1(&mut b, &format!("st{}u0", s + 2), t, ch, 2, 3);
        for i in 0..reps {
            t = shuffle_unit_v1(&mut b, &format!("st{}u{}", s + 2, i + 1), t, ch, 1, 3);
        }
    }
    b.global_pool("gap");
    b.fc("fc", 1000);
    b.softmax("prob");
    b.build().unwrap()
}

fn shuffle_unit_v2(b: &mut GraphBuilder, name: &str, in_tap: Tap, out_ch: u32, stride: u32) -> Tap {
    if stride == 1 {
        b.resume(in_tap);
        let (left, right) = b.split(&format!("{name}/split"));
        let half = out_ch / 2;
        b.resume(right);
        b.pwconv(&format!("{name}/pw1"), half);
        b.dwconv(&format!("{name}/dw"), 3, 1);
        let r = b.pwconv(&format!("{name}/pw2"), half);
        let cat = b.concat(&format!("{name}/concat"), &[left, r]);
        b.shuffle(&format!("{name}/shuffle"));
        let _ = cat;
    } else {
        let half = out_ch / 2;
        b.resume(in_tap);
        b.dwconv(&format!("{name}/ldw"), 3, 2);
        let l = b.pwconv(&format!("{name}/lpw"), half);
        b.resume(in_tap);
        b.pwconv(&format!("{name}/pw1"), half);
        b.dwconv(&format!("{name}/dw"), 3, 2);
        let r = b.pwconv(&format!("{name}/pw2"), half);
        b.concat(&format!("{name}/concat"), &[l, r]);
        b.shuffle(&format!("{name}/shuffle"));
    }
    b.tap()
}

pub fn shufflenet_v2() -> ModelGraph {
    // ShuffleNet v2 1.5x (the paper reports 3.4M params).
    let mut b = GraphBuilder::new("shufflenetv2");
    b.input(3, 224);
    b.conv("conv1", 24, 3, 2);
    let mut t = b.pool("pool1", 3, 2);
    let stage_ch = [176u32, 352, 704];
    for (s, &ch) in stage_ch.iter().enumerate() {
        let reps = [3, 7, 3][s];
        t = shuffle_unit_v2(&mut b, &format!("st{}u0", s + 2), t, ch, 2);
        for i in 0..reps {
            t = shuffle_unit_v2(&mut b, &format!("st{}u{}", s + 2, i + 1), t, ch, 1);
        }
    }
    b.pwconv("conv5", 1024);
    b.global_pool("gap");
    b.fc("fc", 1000);
    b.softmax("prob");
    b.build().unwrap()
}

fn fire(b: &mut GraphBuilder, name: &str, squeeze: u32, expand: u32) -> Tap {
    b.pwconv(&format!("{name}/squeeze"), squeeze);
    let s = b.tap();
    let e1 = b.pwconv(&format!("{name}/expand1x1"), expand);
    b.resume(s);
    let e3 = b.conv(&format!("{name}/expand3x3"), expand, 3, 1);
    b.concat(&format!("{name}/concat"), &[e1, e3])
}

pub fn squeezenet() -> ModelGraph {
    let mut b = GraphBuilder::new("squeezenet");
    b.input(3, 224);
    b.conv("conv1", 96, 7, 2);
    b.pool("pool1", 3, 2);
    fire(&mut b, "fire2", 16, 64);
    fire(&mut b, "fire3", 16, 64);
    fire(&mut b, "fire4", 32, 128);
    b.pool("pool4", 3, 2);
    fire(&mut b, "fire5", 32, 128);
    fire(&mut b, "fire6", 48, 192);
    fire(&mut b, "fire7", 48, 192);
    fire(&mut b, "fire8", 64, 256);
    b.pool("pool8", 3, 2);
    fire(&mut b, "fire9", 64, 256);
    b.pwconv("conv10", 1000);
    b.global_pool("gap");
    b.softmax("prob");
    b.build().unwrap()
}

fn mbconv(b: &mut GraphBuilder, name: &str, in_tap: Tap, out_ch: u32, kernel: u32, stride: u32, expand: u32) -> Tap {
    b.resume(in_tap);
    let hidden = in_tap.ch * expand;
    if expand != 1 {
        b.pwconv(&format!("{name}/expand"), hidden);
    }
    b.dwconv(&format!("{name}/dw"), kernel, stride);
    // Squeeze-excite: modelled as two 1x1 convs on the pooled map.
    let body = b.tap();
    b.global_pool(&format!("{name}/se_pool"));
    b.pwconv(&format!("{name}/se_reduce"), (in_tap.ch / 4).max(1));
    let se = b.pwconv(&format!("{name}/se_expand"), hidden);
    b.resume(body);
    // SE scale is an eltwise with broadcast; model as eltwise over body.
    let _ = se;
    let scaled = {
        let t = b.tap();
        t
    };
    b.resume(scaled);
    let out = b.pwconv(&format!("{name}/project"), out_ch);
    if stride == 1 && in_tap.ch == out_ch {
        b.add(&format!("{name}/add"), in_tap)
    } else {
        out
    }
}

pub fn efficientnet_b0() -> ModelGraph {
    let mut b = GraphBuilder::new("efficientnetb0");
    b.input(3, 224);
    let mut t = b.conv("stem", 32, 3, 2);
    // (expand, out, reps, stride, kernel)
    let cfg: [(u32, u32, u32, u32, u32); 7] = [
        (1, 16, 1, 1, 3),
        (6, 24, 2, 2, 3),
        (6, 40, 2, 2, 5),
        (6, 80, 3, 2, 3),
        (6, 112, 3, 1, 5),
        (6, 192, 4, 2, 5),
        (6, 320, 1, 1, 3),
    ];
    let mut blk = 0;
    for (e, c, n, s, k) in cfg {
        for i in 0..n {
            let stride = if i == 0 { s } else { 1 };
            t = mbconv(&mut b, &format!("mb{blk}"), t, c, k, stride, e);
            blk += 1;
        }
    }
    b.pwconv("head", 1280);
    b.global_pool("gap");
    b.fc("fc", 1000);
    b.softmax("prob");
    b.build().unwrap()
}

fn yolo_head(b: &mut GraphBuilder, name: &str, in_tap: Tap, mid: u32, anchors_out: u32) -> Tap {
    b.resume(in_tap);
    b.conv(&format!("{name}/conv"), mid, 3, 1);
    b.pwconv(&format!("{name}/out"), anchors_out)
}

pub fn mobilenetv2_yolov3() -> ModelGraph {
    // MobileNetV2 backbone (trimmed head) + two YOLOv3-lite detection heads.
    let mut b = GraphBuilder::new("mobilenetv2-yolov3");
    b.input(3, 224);
    let mut t = b.conv("conv1", 32, 3, 2);
    let cfg: [(u32, u32, u32, u32); 7] = [
        (1, 16, 1, 1),
        (6, 24, 2, 2),
        (6, 32, 3, 2),
        (6, 64, 4, 2),
        (6, 96, 3, 1),
        (6, 160, 3, 2),
        (6, 320, 1, 1),
    ];
    let mut block = 0;
    let mut mid_tap = None;
    for (e, c, n, s) in cfg {
        for i in 0..n {
            let stride = if i == 0 { s } else { 1 };
            t = inverted_residual(&mut b, &format!("ir{block}"), t, c, stride, e);
            block += 1;
        }
        if c == 96 {
            mid_tap = Some(t); // 14x14 feature map for the second head
        }
    }
    let deep = b.pwconv("neck_deep", 256);
    let h1 = yolo_head(&mut b, "head26", deep, 256, 75);
    b.resume(deep);
    b.pwconv("neck_up", 128);
    b.upsample("up");
    let up = b.tap();
    b.resume(mid_tap.unwrap());
    let lateral = b.pwconv("lateral", 128);
    b.resume(up);
    let cat = b.concat("neck_cat", &[up, lateral]);
    let _ = cat;
    // Two detection heads are both graph sinks (7x7 and 14x14 scales).
    let cat_tap = b.tap();
    let _h2 = yolo_head(&mut b, "head13", cat_tap, 128, 75);
    let _h1 = h1;
    b.build().unwrap()
}

pub fn mobilenet_yolo() -> ModelGraph {
    // MobileNetV1 backbone + YOLOv2-style single head (MobileNet-YOLO).
    let mut b = GraphBuilder::new("mobilenet-yolo");
    b.input(3, 224);
    b.conv("conv1", 32, 3, 2);
    dw_separable(&mut b, "ds2", 64, 1);
    dw_separable(&mut b, "ds3", 128, 2);
    dw_separable(&mut b, "ds4", 128, 1);
    dw_separable(&mut b, "ds5", 256, 2);
    dw_separable(&mut b, "ds6", 256, 1);
    dw_separable(&mut b, "ds7", 512, 2);
    for i in 8..13 {
        dw_separable(&mut b, &format!("ds{i}"), 512, 1);
    }
    dw_separable(&mut b, "ds13", 1024, 2);
    dw_separable(&mut b, "ds14", 1024, 1);
    // One dense 3x3 extra (the YOLOv2-style head conv) + separable block.
    b.conv("extra1", 768, 3, 1);
    dw_separable(&mut b, "extra2", 1024, 1);
    b.pwconv("detect", 125);
    b.build().unwrap()
}

pub fn crnn_lite() -> ModelGraph {
    // CRNN-lite OCR backbone: small conv stack + sequence FC decoder (the
    // recurrent layers are modelled as per-timestep FCs, matching the
    // dominant cost structure).
    let mut b = GraphBuilder::new("crnn-lite");
    b.input(1, 32);
    b.conv("conv1", 32, 3, 1);
    b.pool("pool1", 2, 2);
    b.conv("conv2", 64, 3, 1);
    b.pool("pool2", 2, 2);
    b.conv("conv3", 128, 3, 1);
    b.conv("conv4", 128, 3, 1);
    b.pool("pool3", 2, 2);
    b.conv("conv5", 256, 3, 1);
    b.conv("conv6", 256, 3, 1);
    b.pool("pool4", 2, 2);
    b.conv("conv7", 512, 2, 1);
    b.fc("rnn1", 512);
    b.fc("rnn2", 512);
    b.fc("ctc", 5990);
    b.softmax("prob");
    b.build().unwrap()
}

/// Six-conv CNN matching `python/compile/model.py::tiny_net` — the model the
/// real PJRT path loads. Keep in sync with the python definition; the
/// manifest test cross-checks.
pub fn tiny_net() -> ModelGraph {
    let mut b = GraphBuilder::new("tinynet");
    b.input(3, 32);
    b.conv("conv1", 16, 3, 1);
    b.conv("conv2", 16, 3, 1);
    b.conv("conv3", 32, 3, 2);
    b.conv("conv4", 32, 3, 1);
    b.conv("conv5", 64, 3, 2);
    b.conv("conv6", 64, 3, 1);
    b.global_pool("gap");
    b.fc("fc", 10);
    b.softmax("prob");
    b.build().unwrap()
}

/// BranchyNet-style ResNet-18: two early-exit heads after the first and
/// second residual stages. Calibrated exit probabilities follow the
/// early-exit literature's "most requests leave early" regime — over half
/// of the traffic never executes the (weight-heavy) 256/512-channel tail,
/// which is exactly the structure the expected-makespan scheduler exploits.
pub fn branchy_resnet18() -> ModelGraph {
    let mut b = GraphBuilder::new("branchy-resnet18");
    b.input(3, 224);
    b.conv("conv1", 64, 7, 2);
    let mut t = b.pool("pool1", 3, 2);
    for (stage, (ch, s)) in [(64u32, 1u32), (128, 2), (256, 2), (512, 2)].iter().enumerate() {
        for i in 0..2 {
            let stride = if i == 0 { *s } else { 1 };
            t = basic_block(&mut b, &format!("res{}_{i}", stage + 2), t, *ch, stride);
        }
        if stage == 0 {
            b.exit_branch("exit1", 1000, 0.85, 0.55);
        } else if stage == 1 {
            b.exit_branch("exit2", 1000, 0.80, 0.50);
        }
    }
    b.global_pool("gap");
    b.fc("fc", 1000);
    b.softmax("prob");
    b.build().unwrap()
}

/// BranchyNet-style MobileNetV1 with two early exits (after ds5 and ds7).
pub fn branchy_mobilenet() -> ModelGraph {
    let mut b = GraphBuilder::new("branchy-mobilenet");
    b.input(3, 224);
    b.conv("conv1", 32, 3, 2);
    dw_separable(&mut b, "ds2", 64, 1);
    dw_separable(&mut b, "ds3", 128, 2);
    dw_separable(&mut b, "ds4", 128, 1);
    dw_separable(&mut b, "ds5", 256, 2);
    b.exit_branch("exit1", 1000, 0.85, 0.50);
    dw_separable(&mut b, "ds6", 256, 1);
    dw_separable(&mut b, "ds7", 512, 2);
    b.exit_branch("exit2", 1000, 0.80, 0.45);
    for i in 8..13 {
        dw_separable(&mut b, &format!("ds{i}"), 512, 1);
    }
    dw_separable(&mut b, "ds13", 1024, 2);
    dw_separable(&mut b, "ds14", 1024, 1);
    b.global_pool("gap");
    b.fc("fc", 1000);
    b.softmax("prob");
    b.build().unwrap()
}

/// One-exit variant of [`tiny_net`] — small enough for serving and chaos
/// tests that want a multi-exit model without real planning cost.
pub fn branchy_tinynet() -> ModelGraph {
    let mut b = GraphBuilder::new("branchy-tinynet");
    b.input(3, 32);
    b.conv("conv1", 16, 3, 1);
    b.conv("conv2", 16, 3, 1);
    b.conv("conv3", 32, 3, 2);
    b.exit_branch("exit1", 10, 0.9, 0.6);
    b.conv("conv4", 32, 3, 1);
    b.conv("conv5", 64, 3, 2);
    b.conv("conv6", 64, 3, 1);
    b.global_pool("gap");
    b.fc("fc", 10);
    b.softmax("prob");
    b.build().unwrap()
}

/// The `i`-th model of the [`synthetic`] family: a small CNN whose
/// depth, width, input size, and conv flavor are drawn from an `Rng`
/// seeded by `(seed, i)` alone — model `i` is the same graph whether it
/// was built alone or as part of any batch.
pub fn synthetic_model(seed: u64, i: usize) -> ModelGraph {
    let mut rng = crate::util::rng::Rng::new(
        seed ^ (i as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15),
    );
    let mut b = GraphBuilder::new(&format!("syn-{i:04}"));
    let hw = [16u32, 24, 32][rng.index(3)];
    b.input(3, hw);
    let mut ch = [8u32, 12, 16][rng.index(3)];
    b.conv("stem", ch, 3, 1);
    // 1–4 body stages, each widening and sometimes striding down.
    let stages = rng.range(1, 5);
    for s in 0..stages {
        ch = (ch * 2).min(128);
        let stride = if rng.chance(0.5) { 2 } else { 1 };
        if rng.chance(0.5) {
            b.conv(&format!("conv{s}"), ch, 3, stride);
        } else {
            b.dwconv(&format!("dw{s}"), 3, stride);
            b.pwconv(&format!("pw{s}"), ch);
        }
        if rng.chance(0.3) {
            b.pool(&format!("pool{s}"), 2, 2);
        }
    }
    b.global_pool("gap");
    b.fc("fc", [10u32, 100][rng.index(2)]);
    b.softmax("prob");
    b.build().unwrap()
}

/// A deterministic family of `n` distinct small synthetic CNNs
/// (`syn-0000` … `syn-{n-1:04}`), for fleet-scale experiments where a
/// thousand models must plan and serve quickly (`benches/serve_1000.rs`,
/// `repro serve --models N`). Tiny on purpose — a few conv layers each —
/// so the *population* is the workload, not any one model's planning
/// cost. Reproducible model-for-model: `synthetic(seed, n)` is a prefix
/// of `synthetic(seed, n + m)`.
pub fn synthetic(seed: u64, n: usize) -> Vec<ModelGraph> {
    (0..n).map(|i| synthetic_model(seed, i)).collect()
}

/// Small depthwise-separable CNN matching
/// `python/compile/model.py::micro_mobilenet`.
pub fn micro_mobilenet() -> ModelGraph {
    let mut b = GraphBuilder::new("micro-mobilenet");
    b.input(3, 32);
    b.conv("conv1", 16, 3, 2);
    dw_separable(&mut b, "ds2", 32, 1);
    dw_separable(&mut b, "ds3", 64, 2);
    dw_separable(&mut b, "ds4", 64, 1);
    dw_separable(&mut b, "ds5", 128, 2);
    b.global_pool("gap");
    b.fc("fc", 10);
    b.softmax("prob");
    b.build().unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Paper Table 4 parameter counts (millions). Our rebuilt architectures
    /// must land in the same ballpark (±40%: padding/variant details differ,
    /// which is irrelevant to the cold-start cost structure).
    #[test]
    fn parameter_counts_near_table4() {
        let expect: [(&str, f64); 11] = [
            ("alexnet", 61.3),
            ("googlenet", 7.1),
            ("mobilenet", 4.4),
            ("mobilenetv2", 3.7),
            ("resnet18", 12.7),
            ("shufflenet", 3.6),
            ("efficientnetb0", 5.4),
            ("resnet50", 25.7),
            ("squeezenet", 1.4),
            ("shufflenetv2", 3.4),
            ("mobilenet-yolo", 11.9),
        ];
        for (name, want_m) in expect {
            let g = by_name(name).unwrap();
            let got_m = g.params() as f64 / 1e6;
            let ratio = got_m / want_m;
            assert!(
                (0.6..=1.6).contains(&ratio),
                "{name}: params {got_m:.2}M vs paper {want_m}M (ratio {ratio:.2})"
            );
        }
    }

    #[test]
    fn all_models_build_and_validate() {
        for name in PAPER_MODELS {
            let g = by_name(name).unwrap();
            assert!(g.len() > 5, "{name} suspiciously small");
            assert_eq!(g.bfs_order().len(), g.len(), "{name} not fully reachable");
            assert!(g.flops() > 0);
            assert!(g.weight_bytes() > 0);
        }
        for name in ["crnn-lite", "tinynet", "micro-mobilenet"] {
            assert!(by_name(name).is_some());
        }
        for name in BRANCHY_MODELS {
            let g = by_name(name).unwrap();
            assert!(g.has_exits(), "{name} must carry exit points");
            assert_eq!(g.bfs_order().len(), g.len(), "{name} not fully reachable");
            assert!(
                g.survival_weights().last().copied().unwrap() < 1.0,
                "{name} tail must be conditional"
            );
        }
        assert!(by_name("nonexistent").is_none());
    }

    #[test]
    fn branchy_backbones_match_their_single_exit_twins() {
        // The branchy variants add exit heads but keep the backbone: every
        // backbone layer name of resnet18 appears in branchy-resnet18.
        let plain = resnet18();
        let branchy = branchy_resnet18();
        for l in plain.layers() {
            assert!(
                branchy.layers().iter().any(|bl| bl.name == l.name),
                "backbone layer {} missing from branchy variant",
                l.name
            );
        }
        assert!(branchy.len() > plain.len());
        assert_eq!(branchy.exits().len(), 2);
    }

    #[test]
    fn synthetic_family_is_deterministic_and_distinct() {
        let a = synthetic(0xFEED, 40);
        let b = synthetic(0xFEED, 40);
        assert_eq!(a.len(), 40);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.name, y.name);
            assert_eq!(x.weight_bytes(), y.weight_bytes());
            assert_eq!(x.flops(), y.flops());
            assert_eq!(x.len(), y.len());
        }
        assert_eq!(a[0].name, "syn-0000");
        assert_eq!(a[39].name, "syn-0039");
        // A prefix of a longer family, model for model.
        let longer = synthetic(0xFEED, 60);
        assert_eq!(longer[17].weight_bytes(), a[17].weight_bytes());
        // Structurally diverse: not every model has the same footprint.
        let mut sizes: Vec<u64> = a.iter().map(|g| g.weight_bytes()).collect();
        sizes.sort();
        sizes.dedup();
        assert!(sizes.len() > 10, "only {} distinct footprints", sizes.len());
        // Each model is valid and small.
        for g in &a {
            assert_eq!(g.bfs_order().len(), g.len(), "{} not reachable", g.name);
            assert!(g.weight_bytes() > 0);
            assert!(g.len() <= 16, "{} too deep ({})", g.name, g.len());
        }
        // A different seed yields a different family.
        let c = synthetic(0xBEEF, 40);
        assert!(
            a.iter().zip(&c).any(|(x, y)| x.weight_bytes() != y.weight_bytes()),
            "seed must matter"
        );
    }

    #[test]
    fn resnet50_structure() {
        let g = resnet50();
        // 3+4+6+3 bottlenecks, 3 convs each + downsamples (4) + stem = 53 convs
        let convs = g
            .layers()
            .iter()
            .filter(|l| l.op.is_conv())
            .count();
        assert_eq!(convs, 53);
        // ~25.6M params
        let m = g.params() as f64 / 1e6;
        assert!((20.0..30.0).contains(&m), "resnet50 params {m}M");
    }

    #[test]
    fn mobilenet_dw_layers_detected() {
        let g = mobilenet_v1();
        let dw = g
            .layers()
            .iter()
            .filter(|l| l.op.is_depthwise(l.in_ch))
            .count();
        assert_eq!(dw, 13);
    }

    #[test]
    fn flops_sane_scale() {
        // ResNet-50 ~ 7.7 GFLOPs (2*3.86 GMACs) at 224x224
        let g = resnet50();
        let gf = g.flops() as f64 / 1e9;
        assert!((5.0..12.0).contains(&gf), "resnet50 {gf} GFLOPs");
        // MobileNetV1 ~ 1.1 GFLOPs
        let g = mobilenet_v1();
        let gf = g.flops() as f64 / 1e9;
        assert!((0.7..1.8).contains(&gf), "mobilenet {gf} GFLOPs");
    }
}
