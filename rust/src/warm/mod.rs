//! Kernel switching for continuous inference (§3.5).
//!
//! The kernels NNV12 selects for cold inference (`K_cold`) are not always
//! the warm-fastest ones (`K_warm`). In continuous-inference mode NNV12
//! prepares the missing `K_warm − K_cold` kernels on little cores during
//! the idle time of the cold inference, switching each layer to its warm
//! kernel as soon as it is prepared. If idle time runs out, the remaining
//! preparations pipeline into the 2nd inference (which is therefore
//! slightly slower than steady-state — the paper measures 8%), and from
//! the 3rd inference the engine runs at full warm speed.
//!
//! This module is the *primitive*; callers get the ladder through the
//! facade ([`crate::engine::Engine::load`] →
//! [`crate::engine::Session::ladder`]), whose backends call
//! [`continuous_from`] with the cached plan. [`continuous_from`] is a
//! pure function of its inputs and [`ContinuousReport`] is plain `Send +
//! Sync` data, so the facade can compute ladders lazily from any serving
//! thread (each session memoizes its report in a `OnceLock`).

use crate::cost::CostModel;
use crate::device::{CoreClass, DeviceProfile};
use crate::graph::ModelGraph;
use crate::kernels::Registry;
use crate::sched::plan::UnitId;
use crate::Ms;

/// Latency sequence of a continuous-inference session.
#[derive(Debug, Clone)]
pub struct ContinuousReport {
    /// Latency of inference #1 (cold), #2, #3, … (ms).
    pub latencies: Vec<Ms>,
    /// Steady-state warm latency.
    pub warm_ms: Ms,
    /// Layers whose kernel had to be switched after cold inference.
    pub switched_layers: usize,
}

/// The continuous-inference model over an already-scheduled cold plan —
/// the facade's path ([`crate::engine::Session::ladder`] via
/// [`crate::engine::ExecBackend::warm_ladder`]), which draws `s` from the
/// fingerprint-keyed [`crate::sched::cache::PlanCache`] instead of
/// re-planning per model. The scheduler config is already baked into `s`.
pub fn continuous_from(
    dev: &DeviceProfile,
    graph: &ModelGraph,
    registry: &Registry,
    n_inferences: usize,
    s: &crate::sched::heuristic::Scheduled,
) -> ContinuousReport {
    let cm = CostModel::new(dev);
    let (exec_class, exec_threads) = cm.exec_class();
    let cold_ms = s.schedule.makespan;

    // Which layers need switching, and what the switch costs to prepare.
    let mut switch_prep: Vec<(usize, Ms, Ms, Ms)> = Vec::new(); // (layer, prep, cold_exec, warm_exec)
    for l in graph.layers() {
        if !l.op.has_weights() {
            continue;
        }
        let warm_k = cm.warm_best_kernel(l, registry);
        let cold_choice = s.plan.choices[l.id].as_ref().unwrap();
        let cold_exec = cm.exec_ms(&cold_choice.kernel, l, exec_class, exec_threads);
        let warm_exec = cm.exec_ms(&warm_k, l, exec_class, exec_threads);
        if warm_k.family == cold_choice.kernel.family {
            continue;
        }
        // Preparing the warm kernel on a little core: read raw + transform.
        let prep = cm.read_ms(l.weight_bytes(), CoreClass::Little, 1)
            + cm.transform_ms(&warm_k, l, CoreClass::Little, 1);
        switch_prep.push((l.id, prep, cold_exec, warm_exec));
    }

    // Idle little-core time during the cold inference.
    let n_little = s
        .schedule
        .busy
        .iter()
        .filter(|(u, _)| matches!(u, UnitId::Little(_)))
        .count()
        .max(1);
    let little_busy: Ms = s
        .schedule
        .busy
        .iter()
        .filter(|(u, _)| matches!(u, UnitId::Little(_)))
        .map(|(_, b)| *b)
        .sum();
    let mut idle = (n_little as f64) * cold_ms - little_busy;

    // Greedily prepare switches (cheapest first) in the idle window; what
    // does not fit spills into the 2nd inference.
    switch_prep.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
    let warm_ms = cm.warm_ms(graph, registry);
    let mut unswitched_exec_penalty: Ms = 0.0;
    let mut spill_prep: Ms = 0.0;
    for (_, prep, cold_exec, warm_exec) in &switch_prep {
        if idle >= *prep {
            idle -= prep;
        } else {
            spill_prep += prep;
            unswitched_exec_penalty += (cold_exec - warm_exec).max(0.0);
        }
    }

    // 2nd inference: unswitched layers still run their (slower) cold
    // kernels; the spilled preparations pipeline across little cores
    // concurrently, so they don't add to the critical path beyond what the
    // exec penalty already captures (same argument as cold pipelining).
    let second = warm_ms + unswitched_exec_penalty.min(spill_prep / n_little as f64 + unswitched_exec_penalty);
    let mut latencies = vec![cold_ms];
    if n_inferences > 1 {
        latencies.push(second.max(warm_ms));
    }
    for _ in 2..n_inferences {
        latencies.push(warm_ms);
    }
    ContinuousReport {
        latencies,
        warm_ms,
        switched_layers: switch_prep.len(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::profiles;
    use crate::graph::zoo;
    use crate::sched::heuristic::{schedule, SchedulerConfig};

    /// Plan from scratch, then model `n` consecutive inferences — what the
    /// removed `continuous` shim did; callers outside tests go through the
    /// facade (`Engine::load` → `Session::ladder`).
    fn plan_and_run(
        dev: &DeviceProfile,
        g: &ModelGraph,
        registry: &Registry,
        cfg: &SchedulerConfig,
        n: usize,
    ) -> ContinuousReport {
        let s = schedule(dev, g, registry, cfg);
        continuous_from(dev, g, registry, n, &s)
    }

    #[test]
    fn fig14_shape() {
        // Cold >> 2nd ≈ warm; 3rd == warm exactly.
        let dev = profiles::meizu_16t();
        for model in ["googlenet", "resnet50"] {
            let g = zoo::by_name(model).unwrap();
            let r = plan_and_run(&dev, &g, &Registry::full(), &SchedulerConfig::kcp(), 4);
            assert_eq!(r.latencies.len(), 4);
            let cold = r.latencies[0];
            let second = r.latencies[1];
            let third = r.latencies[2];
            assert!(cold > second, "{model}: cold {cold} vs 2nd {second}");
            assert_eq!(third, r.warm_ms);
            assert_eq!(r.latencies[3], r.warm_ms);
            // Paper: 2nd within ~8% of steady state; allow 25%.
            assert!(
                second <= r.warm_ms * 1.25,
                "{model}: 2nd {second} vs warm {}",
                r.warm_ms
            );
        }
    }

    #[test]
    fn no_switching_needed_when_cold_picks_warm_kernels() {
        // With the cache enabled, NNV12 often keeps the warm-fastest
        // (winograd) kernels via cached weights — those layers need no
        // switch. Just assert the count is consistent.
        let dev = profiles::meizu_16t();
        let g = zoo::resnet50();
        let r = plan_and_run(&dev, &g, &Registry::full(), &SchedulerConfig::kcp(), 3);
        assert!(r.switched_layers <= g.weighted_layers().len());
    }
}
