//! Real weight-transformation math, used on the real (PJRT) execution path.
//!
//! These are the Rust-side counterparts of the transformations whose *cost*
//! the scheduler reasons about: they turn a raw conv weight blob
//! `(C_out, C_in, K, K)` into the layout a kernel family executes on, and
//! they are what gets cached to disk by the post-transformed-weights cache
//! (§3.1.2). The same transforms exist in Python
//! (`python/compile/kernels/*.py`) for the AOT'd HLO path; golden tests
//! ensure the two implementations agree
//! (`python/tests/test_transforms_golden.py` writes goldens consumed by
//! `tests/transform_golden.rs`).

use crate::graph::Layer;

/// im2col/SGEMM layout: `(C_out, C_in·K·K)` row-major — a flat GEMM matrix.
/// For our dense row-major input this is a pure reshape (copy), which is
/// exactly why its transformation cost is low (Table 2: 2.2 ms vs
/// winograd's 38.2 ms).
pub fn im2col_weights(raw: &[f32], c_out: usize, c_in: usize, k: usize) -> Vec<f32> {
    assert_eq!(raw.len(), c_out * c_in * k * k, "raw weight size mismatch");
    raw.to_vec()
}

/// pack4 layout: channels grouped in blocks of 4 for SIMD-friendly access:
/// `(C_out/4, C_in, K·K, 4)`. Channel counts must be divisible by 4
/// (the Fig. 5 tree only offers pack4 kernels in that case).
pub fn pack4_weights(raw: &[f32], c_out: usize, c_in: usize, k: usize) -> Vec<f32> {
    assert_eq!(raw.len(), c_out * c_in * k * k);
    assert!(c_out % 4 == 0, "pack4 requires C_out % 4 == 0");
    let kk = k * k;
    let mut out = vec![0.0f32; raw.len()];
    let mut idx = 0;
    for ob in 0..c_out / 4 {
        for ci in 0..c_in {
            for t in 0..kk {
                for lane in 0..4 {
                    let co = ob * 4 + lane;
                    out[idx] = raw[(co * c_in + ci) * kk + t];
                    idx += 1;
                }
            }
        }
    }
    out
}

/// Winograd F(2×2, 3×3) weight transform: each 3×3 tap `g` becomes the 4×4
/// tile `G·g·Gᵀ`. Output layout `(C_out, C_in, 4, 4)` — a 16/9 ≈ 1.78×
/// expansion (the paper's ncnn kernel uses F(4,3) with 8×8 tiles / 64/9 ≈
/// 7.1×; we use F(2,3) on the real path for numerical robustness, the cost
/// model keeps the paper's F(4,3) expansion factors).
pub fn winograd23_weights(raw: &[f32], c_out: usize, c_in: usize) -> Vec<f32> {
    assert_eq!(raw.len(), c_out * c_in * 9, "winograd needs 3x3 weights");
    // G is 4x3.
    const G: [[f32; 3]; 4] = [
        [1.0, 0.0, 0.0],
        [0.5, 0.5, 0.5],
        [0.5, -0.5, 0.5],
        [0.0, 0.0, 1.0],
    ];
    let mut out = vec![0.0f32; c_out * c_in * 16];
    for oc in 0..c_out {
        for ic in 0..c_in {
            let g = &raw[(oc * c_in + ic) * 9..(oc * c_in + ic) * 9 + 9];
            // tmp = G (4x3) · g (3x3) → 4x3
            let mut tmp = [[0.0f32; 3]; 4];
            for (i, row) in G.iter().enumerate() {
                for j in 0..3 {
                    tmp[i][j] = (0..3).map(|m| row[m] * g[m * 3 + j]).sum();
                }
            }
            // u = tmp (4x3) · Gᵀ (3x4) → 4x4
            let base = (oc * c_in + ic) * 16;
            for i in 0..4 {
                for (jj, grow) in G.iter().enumerate() {
                    out[base + i * 4 + jj] =
                        (0..3).map(|m| tmp[i][m] * grow[m]).sum();
                }
            }
        }
    }
    out
}

/// Dispatch a transformation by kernel-family name for a layer; returns
/// `None` for families that execute on raw weights.
pub fn transform_by_name(name: &str, raw: &[f32], layer: &Layer) -> Option<Vec<f32>> {
    let c_out = layer.out_ch as usize;
    let groups = match layer.op {
        crate::graph::OpKind::Conv { groups, .. } => groups.max(1) as usize,
        _ => 1,
    };
    let c_in = (layer.in_ch as usize) / groups;
    let k = match layer.op {
        crate::graph::OpKind::Conv { kernel, .. } => kernel as usize,
        crate::graph::OpKind::Fc => 1,
        _ => return None,
    };
    // Bias (c_out trailing floats) passes through untransformed.
    let wlen = c_out * c_in * k * k;
    assert!(raw.len() >= wlen, "raw blob too small: {} < {}", raw.len(), wlen);
    let (w, bias) = raw.split_at(wlen);
    let mut t = match name {
        "im2col" | "sgemm" | "fc-sgemm" => im2col_weights(w, c_out, c_in, k),
        "pack4" | "sgemm-pack4" => pack4_weights(w, c_out, c_in, k),
        "winograd" | "winograd-pack4" if k == 3 => winograd23_weights(w, c_out, c_in),
        _ => return None,
    };
    t.extend_from_slice(bias);
    Some(t)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn im2col_is_identity_copy() {
        let raw: Vec<f32> = (0..2 * 3 * 9).map(|i| i as f32).collect();
        assert_eq!(im2col_weights(&raw, 2, 3, 3), raw);
    }

    #[test]
    fn pack4_permutation_roundtrips() {
        let c_out = 8;
        let c_in = 2;
        let k = 3;
        let raw: Vec<f32> = (0..c_out * c_in * k * k).map(|i| i as f32).collect();
        let packed = pack4_weights(&raw, c_out, c_in, k);
        // Same multiset of values.
        let mut a = raw.clone();
        let mut b = packed.clone();
        a.sort_by(|x, y| x.partial_cmp(y).unwrap());
        b.sort_by(|x, y| x.partial_cmp(y).unwrap());
        assert_eq!(a, b);
        // Spot-check the layout: out[0..4] are taps (co=0..4, ci=0, t=0).
        for lane in 0..4 {
            assert_eq!(packed[lane], raw[lane * c_in * k * k]);
        }
    }

    #[test]
    fn winograd_identity_kernel() {
        // g = delta at center ⇒ G·g·Gᵀ is the outer product of G's middle
        // column with itself.
        let mut g = vec![0.0f32; 9];
        g[4] = 1.0; // center tap
        let u = winograd23_weights(&g, 1, 1);
        let col = [0.0f32, 0.5, -0.5, 0.0];
        for i in 0..4 {
            for j in 0..4 {
                assert!(
                    (u[i * 4 + j] - col[i] * col[j]).abs() < 1e-6,
                    "u[{i}][{j}]"
                );
            }
        }
    }

    #[test]
    fn winograd_preserves_filter_sum_at_tile_11() {
        // B(1,1) evaluation point: u[1][1] = sum(g)/ ... For F(2,3),
        // u[1][1] = (Σ rows averaged) — verify against a direct compute.
        let g: Vec<f32> = (1..=9).map(|x| x as f32).collect();
        let u = winograd23_weights(&g, 1, 1);
        // direct: G row1 = [.5 .5 .5]; u11 = r1·g·r1ᵀ = 0.25 * Σg = 11.25
        let expect = 0.25 * g.iter().sum::<f32>();
        assert!((u[5] - expect).abs() < 1e-5, "{} vs {}", u[5], expect);
    }

    #[test]
    fn expansion_factor_is_16_over_9() {
        let raw = vec![1.0f32; 4 * 4 * 9];
        let u = winograd23_weights(&raw, 4, 4);
        assert_eq!(u.len() * 9, raw.len() * 16);
    }

    #[test]
    fn dispatch_handles_bias_and_unknown() {
        let layer = Layer {
            id: 0,
            name: "c".into(),
            op: crate::graph::OpKind::Conv { kernel: 3, stride: 1, groups: 1 },
            in_ch: 2,
            out_ch: 4,
            in_hw: 8,
            out_hw: 8,
            deps: vec![],
        };
        let raw: Vec<f32> = (0..(4 * 2 * 9 + 4)).map(|i| i as f32).collect();
        let t = transform_by_name("winograd", &raw, &layer).unwrap();
        assert_eq!(t.len(), 4 * 2 * 16 + 4);
        // bias preserved at the tail
        assert_eq!(&t[t.len() - 4..], &raw[raw.len() - 4..]);
        assert!(transform_by_name("direct", &raw, &layer).is_none());
    }
}
