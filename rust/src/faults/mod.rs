//! Deterministic fault injection for the serving stack.
//!
//! Edge deployments fail in mundane ways — a flaky flash read, a torn
//! write on power loss, a transient backend error, a crashed executor —
//! and the cold path is where those failures concentrate, because that is
//! where the bytes move. This module provides a seeded, replayable fault
//! source that threads into the [`crate::store::ArtifactStore`] and the
//! engine's execution backends so `tests/chaos_serving.rs` can replay the
//! serving workload under randomized fault schedules and assert the
//! survival invariants (no escaped panic, conserved request accounting,
//! healed corruption).
//!
//! # Determinism
//!
//! A [`FaultPlan`] decides faults as a pure function of `(seed, site,
//! call index, rule index)`: each instrumented site keeps an atomic call
//! counter, and probabilistic triggers hash those four values rather than
//! consulting a global RNG. Two plans built with the same seed and rules
//! inject the identical fault sequence, so a single-threaded chaos replay
//! is bit-reproducible. Under multi-threaded replay *which request*
//! observes call index `n` depends on interleaving, but the multiset of
//! injected faults per site does not — which is exactly what the
//! conservation invariants need.
//!
//! # Zero-cost default
//!
//! Instrumented sites hold an `Option`/`OnceLock` of an `Arc<FaultPlan>`
//! that is `None` unless a test or `repro serve --faults SEED` armed it;
//! the production path pays one pointer check per site and nothing else,
//! and with no plan armed behavior is bit-identical to an uninstrumented
//! build (asserted by the chaos suite's no-fault parity test).
//!
//! # Simulated process death
//!
//! [`FaultKind::Crash`] simulates the process dying at an instrumented
//! site — battery pull, OOM kill, app upgrade mid-write — *without*
//! killing the test process: the site first leaves exactly the on-disk
//! state a real death would (e.g. a fully written temp file that never
//! renamed), then unwinds with a typed [`CrashToken`] payload that only
//! [`with_crash_boundary`] catches. Everything between the site and the
//! boundary is abandoned mid-flight, like a real crash; in particular no
//! cleanup code between them may repair the on-disk state (the store's
//! write-intent journaling is designed so none does). [`CrashPlan`]
//! enumerates deterministic crash points (site × call index) so
//! `tests/crash_recovery.rs` can loop seed × crash-point and assert a
//! reopened store always recovers.

use std::sync::atomic::{AtomicUsize, Ordering};

/// An instrumented code site a fault can be injected at.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FaultSite {
    /// [`crate::store::ArtifactStore`] artifact reads (`get`/`get_scoped`).
    StoreRead,
    /// [`crate::store::ArtifactStore`] artifact writes (`put`/`put_scoped`).
    StoreWrite,
    /// One [`crate::engine::ExecBackend::run`] cold execution attempt.
    ExecRun,
    /// One attempt to ship a multi-exit model's conditional tail to the
    /// simulated offload remote ([`crate::serving::Router`] with an
    /// [`crate::exits::OffloadPolicy`] armed).
    OffloadSend,
    /// One file unlinked by the store's LRU size-cap evictor — drawn
    /// *after* the unlink but before any byte accounting is updated, the
    /// window a mid-sweep death leaves half-applied.
    StoreEvict,
}

impl FaultSite {
    pub const ALL: [FaultSite; 5] = [
        FaultSite::StoreRead,
        FaultSite::StoreWrite,
        FaultSite::ExecRun,
        FaultSite::OffloadSend,
        FaultSite::StoreEvict,
    ];

    fn idx(self) -> usize {
        match self {
            FaultSite::StoreRead => 0,
            FaultSite::StoreWrite => 1,
            FaultSite::ExecRun => 2,
            FaultSite::OffloadSend => 3,
            FaultSite::StoreEvict => 4,
        }
    }
}

/// What goes wrong when a rule fires.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FaultKind {
    /// A transient I/O error: a read reports failure without touching the
    /// bytes on disk (the store must treat it as a miss, not corruption);
    /// a write returns an `io::Error` after a half-written temp file has
    /// already landed, leaving an orphan for boot-time recovery to sweep.
    IoError,
    /// Bit rot: one payload byte of the on-disk artifact is flipped in
    /// place before the read validates it (the store must reject + heal).
    CorruptBytes,
    /// A torn write: the header claims the full payload but only half of
    /// it lands — the next reader must reject + heal.
    TornWrite,
    /// A transient execution failure: the backend returns `Err` for this
    /// attempt (retryable).
    ExecFail,
    /// The executor panics mid-run (the router must contain it; the real
    /// backend's executor thread dies and must respawn).
    ExecPanic,
    /// The offload link drops the tail shipment: the router must fall
    /// back to the degraded path (never hang, never double-count).
    OffloadDrop,
    /// Simulated process death at the site: leave exactly the on-disk
    /// state a real death would, then unwind with a [`CrashToken`] to the
    /// nearest [`with_crash_boundary`] (see the module docs).
    Crash,
}

impl FaultKind {
    pub const ALL: [FaultKind; 7] = [
        FaultKind::IoError,
        FaultKind::CorruptBytes,
        FaultKind::TornWrite,
        FaultKind::ExecFail,
        FaultKind::ExecPanic,
        FaultKind::OffloadDrop,
        FaultKind::Crash,
    ];

    fn idx(self) -> usize {
        match self {
            FaultKind::IoError => 0,
            FaultKind::CorruptBytes => 1,
            FaultKind::TornWrite => 2,
            FaultKind::ExecFail => 3,
            FaultKind::ExecPanic => 4,
            FaultKind::OffloadDrop => 5,
            FaultKind::Crash => 6,
        }
    }
}

/// When a rule fires, in terms of the site's call counter (0-based).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Trigger {
    /// Exactly at call `n` of the rule's site.
    At(usize),
    /// Every `period`-th call starting at `offset` (`period == 0` never
    /// fires).
    Every { period: usize, offset: usize },
    /// Independently at each call with probability `p`, decided by a hash
    /// of `(seed, site, call index, rule index)` — deterministic per
    /// seed, no shared RNG state.
    Prob(f64),
}

/// One injection rule: at `site`, inject `kind` whenever `trigger` says.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultRule {
    pub site: FaultSite,
    pub kind: FaultKind,
    pub trigger: Trigger,
}

/// A seeded fault schedule plus its bookkeeping: per-site call counters
/// (the clock every trigger reads) and per-kind injected counters (what
/// the chaos assertions reconcile against the router's failure taxonomy).
/// Cheap to share as an `Arc` across a store handle and a backend; all
/// state is atomic.
#[derive(Debug, Default)]
pub struct FaultPlan {
    seed: u64,
    rules: Vec<FaultRule>,
    calls: [AtomicUsize; 5],
    injected: [AtomicUsize; 7],
}

impl FaultPlan {
    /// An empty plan (injects nothing) with the given seed; add rules
    /// with [`FaultPlan::with_rule`].
    pub fn new(seed: u64) -> FaultPlan {
        FaultPlan { seed, ..Default::default() }
    }

    /// Builder-style: append one rule. Rules are consulted in insertion
    /// order; the first rule that fires on a call wins.
    pub fn with_rule(mut self, site: FaultSite, kind: FaultKind, trigger: Trigger) -> FaultPlan {
        self.rules.push(FaultRule { site, kind, trigger });
        self
    }

    /// The standard randomized chaos mix used by the chaos test suite and
    /// `repro serve --faults SEED`: a moderate rate of every fault kind
    /// at its natural site. Frequent enough that a few hundred requests
    /// exercise every path, rare enough that most requests still succeed.
    pub fn chaos(seed: u64) -> FaultPlan {
        FaultPlan::new(seed).with_chaos_rules()
    }

    /// Append the standard chaos mix (see [`FaultPlan::chaos`]) to this
    /// plan. Because the first matching rule wins, a plan that needs a
    /// deterministic rule to take priority over the probabilistic mix —
    /// e.g. a [`CrashPlan`]'s `Trigger::At` crash — installs that rule
    /// first and layers the chaos on top with this combinator.
    pub fn with_chaos_rules(self) -> FaultPlan {
        self.with_rule(FaultSite::StoreRead, FaultKind::IoError, Trigger::Prob(0.10))
            .with_rule(FaultSite::StoreRead, FaultKind::CorruptBytes, Trigger::Prob(0.08))
            .with_rule(FaultSite::StoreWrite, FaultKind::TornWrite, Trigger::Prob(0.08))
            .with_rule(FaultSite::StoreWrite, FaultKind::IoError, Trigger::Prob(0.05))
            .with_rule(FaultSite::ExecRun, FaultKind::ExecFail, Trigger::Prob(0.12))
            .with_rule(FaultSite::ExecRun, FaultKind::ExecPanic, Trigger::Prob(0.03))
            .with_rule(FaultSite::OffloadSend, FaultKind::OffloadDrop, Trigger::Prob(0.10))
    }

    /// The seed this plan hashes probabilistic triggers with.
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// One tick of `site`'s clock: advance the call counter and decide
    /// whether (and which) fault to inject at this call. Instrumented
    /// sites call this exactly once per operation. `None` = run clean.
    pub fn draw(&self, site: FaultSite) -> Option<FaultKind> {
        self.draw_at(site).1
    }

    /// Like [`FaultPlan::draw`], but also returns the 0-based call index
    /// this draw consumed — the coordinate a [`CrashToken`] reports so a
    /// crash point can be replayed exactly.
    pub fn draw_at(&self, site: FaultSite) -> (usize, Option<FaultKind>) {
        let n = self.calls[site.idx()].fetch_add(1, Ordering::Relaxed);
        for (ri, rule) in self.rules.iter().enumerate() {
            if rule.site != site {
                continue;
            }
            let fire = match rule.trigger {
                Trigger::At(k) => n == k,
                Trigger::Every { period, offset } => {
                    period > 0 && n >= offset && (n - offset) % period == 0
                }
                Trigger::Prob(p) => unit_f64(mix64(
                    self.seed
                        ^ (site.idx() as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
                        ^ (n as u64).wrapping_mul(0xBF58_476D_1CE4_E5B9)
                        ^ (ri as u64).wrapping_mul(0x94D0_49BB_1331_11EB),
                )) < p,
            };
            if fire {
                self.injected[rule.kind.idx()].fetch_add(1, Ordering::Relaxed);
                return (n, Some(rule.kind));
            }
        }
        (n, None)
    }

    /// Convenience for execution backends: draw at [`FaultSite::ExecRun`]
    /// and enact the result — `Err` for a transient failure, `panic!` for
    /// an injected executor crash (the caller's containment is the thing
    /// under test), a [`crash_now`] unwind for simulated process death,
    /// `Ok(())` for a clean run or a kind that does not apply to
    /// execution.
    pub fn exec_check(&self) -> Result<(), String> {
        match self.draw_at(FaultSite::ExecRun) {
            (_, Some(FaultKind::ExecFail)) => Err("injected transient exec failure".to_string()),
            (_, Some(FaultKind::ExecPanic)) => panic!("injected executor panic"),
            (n, Some(FaultKind::Crash)) => crash_now(FaultSite::ExecRun, n),
            _ => Ok(()),
        }
    }

    /// How many faults of `kind` this plan has injected so far.
    pub fn injected(&self, kind: FaultKind) -> usize {
        self.injected[kind.idx()].load(Ordering::Relaxed)
    }

    /// Total faults injected across all kinds.
    pub fn injected_total(&self) -> usize {
        FaultKind::ALL.iter().map(|k| self.injected(*k)).sum()
    }

    /// How many calls `site` has seen (each call = one `draw`).
    pub fn calls(&self, site: FaultSite) -> usize {
        self.calls[site.idx()].load(Ordering::Relaxed)
    }
}

/// SplitMix64 finalizer: a full-avalanche 64-bit mix, the deterministic
/// hash behind [`Trigger::Prob`] decisions and the router's seeded retry
/// jitter.
pub fn mix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Map a 64-bit hash to a uniform f64 in `[0, 1)`.
pub fn unit_f64(h: u64) -> f64 {
    (h >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
}

/// The typed panic payload of a simulated process death: which
/// instrumented site crashed, at which 0-based call index of that site's
/// clock. Only [`with_crash_boundary`] catches it; any other
/// `catch_unwind` in the stack must re-raise it (see
/// [`crate::serving::Router`]'s executor containment), because swallowing
/// it would let "dead" code keep running.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CrashToken {
    pub site: FaultSite,
    pub call: usize,
}

/// Simulate the process dying right here: unwind with a [`CrashToken`]
/// payload to the nearest [`with_crash_boundary`]. The caller must have
/// already left the on-disk state exactly as a real death would — nothing
/// between this call and the boundary runs except `Drop` impls, and those
/// must not repair disk state.
pub fn crash_now(site: FaultSite, call: usize) -> ! {
    std::panic::panic_any(CrashToken { site, call })
}

/// Run `f` under a simulated-crash boundary: a [`crash_now`] unwind
/// inside `f` is caught and returned as `Err(token)`, leaving whatever
/// on-disk state the crash site abandoned for the caller to recover from
/// (typically by reopening the store). Any other panic is re-raised
/// unchanged — this boundary is for simulated deaths only, not a general
/// panic guard.
pub fn with_crash_boundary<T>(f: impl FnOnce() -> T) -> Result<T, CrashToken> {
    match std::panic::catch_unwind(std::panic::AssertUnwindSafe(f)) {
        Ok(v) => Ok(v),
        Err(payload) => match payload.downcast::<CrashToken>() {
            Ok(token) => Err(*token),
            Err(other) => std::panic::resume_unwind(other),
        },
    }
}

/// Install a process-wide panic hook that stays silent for [`CrashToken`]
/// unwinds (they are scheduled, not bugs) and defers to the previous hook
/// for everything else. Idempotent; call once at the top of a crash test.
pub fn quiet_crash_panics() {
    use std::sync::Once;
    static QUIET: Once = Once::new();
    QUIET.call_once(|| {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            if info.payload().downcast_ref::<CrashToken>().is_none() {
                prev(info);
            }
        }));
    });
}

/// One deterministic crash point: die at call `call` of `site`. Arm it
/// with [`CrashPlan::arm`] to get a [`FaultPlan`] that injects exactly
/// that one crash (layer chaos on top with
/// [`FaultPlan::with_chaos_rules`] if the run should also see ordinary
/// faults), and enumerate a sweep of points with [`CrashPlan::sweep`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CrashPlan {
    pub site: FaultSite,
    pub call: usize,
}

impl CrashPlan {
    /// A `FaultPlan` whose only rule is this crash. The crash rule is
    /// installed first, so appending further (probabilistic) rules can
    /// never preempt it — first matching rule wins.
    pub fn arm(self, seed: u64) -> FaultPlan {
        FaultPlan::new(seed).with_rule(self.site, FaultKind::Crash, Trigger::At(self.call))
    }

    /// Every crash point in `sites × [0, per_site)`: the cartesian sweep
    /// `tests/crash_recovery.rs` loops over. Points whose call index is
    /// never reached in a given run simply never fire — the test counts
    /// observed crashes, not scheduled ones.
    pub fn sweep(sites: &[FaultSite], per_site: usize) -> Vec<CrashPlan> {
        let mut points = Vec::with_capacity(sites.len() * per_site);
        for &site in sites {
            for call in 0..per_site {
                points.push(CrashPlan { site, call });
            }
        }
        points
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_plan_never_fires() {
        let p = FaultPlan::new(7);
        for _ in 0..100 {
            assert_eq!(p.draw(FaultSite::StoreRead), None);
            assert!(p.exec_check().is_ok());
        }
        assert_eq!(p.injected_total(), 0);
        assert_eq!(p.calls(FaultSite::StoreRead), 100);
        assert_eq!(p.calls(FaultSite::ExecRun), 100);
        assert_eq!(p.calls(FaultSite::StoreWrite), 0);
    }

    #[test]
    fn at_and_every_triggers_fire_by_call_count() {
        let p = FaultPlan::new(1)
            .with_rule(FaultSite::StoreRead, FaultKind::IoError, Trigger::At(2))
            .with_rule(
                FaultSite::StoreWrite,
                FaultKind::TornWrite,
                Trigger::Every { period: 3, offset: 1 },
            );
        let reads: Vec<Option<FaultKind>> =
            (0..5).map(|_| p.draw(FaultSite::StoreRead)).collect();
        assert_eq!(
            reads,
            vec![None, None, Some(FaultKind::IoError), None, None]
        );
        let writes: Vec<bool> = (0..8)
            .map(|_| p.draw(FaultSite::StoreWrite) == Some(FaultKind::TornWrite))
            .collect();
        assert_eq!(
            writes,
            vec![false, true, false, false, true, false, false, true]
        );
        assert_eq!(p.injected(FaultKind::IoError), 1);
        assert_eq!(p.injected(FaultKind::TornWrite), 3);
    }

    #[test]
    fn prob_triggers_are_deterministic_per_seed_and_roughly_calibrated() {
        let draws = |seed: u64| -> Vec<Option<FaultKind>> {
            let p = FaultPlan::new(seed)
                .with_rule(FaultSite::ExecRun, FaultKind::ExecFail, Trigger::Prob(0.25));
            (0..2000).map(|_| p.draw(FaultSite::ExecRun)).collect()
        };
        let a = draws(0xC0FFEE);
        let b = draws(0xC0FFEE);
        assert_eq!(a, b, "same seed must replay the same fault sequence");
        let hits = a.iter().filter(|d| d.is_some()).count();
        assert!(
            (300..700).contains(&hits),
            "p=0.25 over 2000 draws gave {hits} hits"
        );
        let c = draws(0xBEEF);
        assert_ne!(a, c, "different seeds must differ");
    }

    #[test]
    fn first_matching_rule_wins() {
        let p = FaultPlan::new(3)
            .with_rule(FaultSite::ExecRun, FaultKind::ExecFail, Trigger::Every { period: 1, offset: 0 })
            .with_rule(FaultSite::ExecRun, FaultKind::ExecPanic, Trigger::Every { period: 1, offset: 0 });
        for _ in 0..10 {
            assert_eq!(p.draw(FaultSite::ExecRun), Some(FaultKind::ExecFail));
        }
        assert_eq!(p.injected(FaultKind::ExecPanic), 0);
    }

    #[test]
    fn exec_check_panics_on_injected_panic() {
        let p = FaultPlan::new(4).with_rule(
            FaultSite::ExecRun,
            FaultKind::ExecPanic,
            Trigger::At(0),
        );
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| p.exec_check()));
        assert!(r.is_err(), "injected panic must unwind");
        assert!(p.exec_check().is_ok(), "only call 0 was scheduled");
        assert_eq!(p.injected(FaultKind::ExecPanic), 1);
    }

    #[test]
    fn chaos_mix_touches_every_site() {
        let p = FaultPlan::chaos(0x5EED);
        for _ in 0..400 {
            let _ = p.draw(FaultSite::StoreRead);
            let _ = p.draw(FaultSite::StoreWrite);
            let _ = p.draw(FaultSite::OffloadSend);
            let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| p.exec_check()));
        }
        assert!(p.injected(FaultKind::IoError) > 0);
        assert!(p.injected(FaultKind::CorruptBytes) > 0);
        assert!(p.injected(FaultKind::TornWrite) > 0);
        assert!(p.injected(FaultKind::ExecFail) > 0);
        assert!(p.injected(FaultKind::ExecPanic) > 0);
        assert!(p.injected(FaultKind::OffloadDrop) > 0);
    }

    #[test]
    fn draw_at_reports_the_consumed_call_index() {
        let p = FaultPlan::new(9)
            .with_rule(FaultSite::StoreWrite, FaultKind::Crash, Trigger::At(2));
        assert_eq!(p.draw_at(FaultSite::StoreWrite), (0, None));
        assert_eq!(p.draw_at(FaultSite::StoreWrite), (1, None));
        assert_eq!(
            p.draw_at(FaultSite::StoreWrite),
            (2, Some(FaultKind::Crash))
        );
        assert_eq!(p.draw_at(FaultSite::StoreWrite), (3, None));
        assert_eq!(p.injected(FaultKind::Crash), 1);
    }

    #[test]
    fn crash_boundary_catches_only_crash_tokens() {
        quiet_crash_panics();
        let caught = with_crash_boundary(|| -> u32 { crash_now(FaultSite::StoreWrite, 5) });
        assert_eq!(
            caught,
            Err(CrashToken { site: FaultSite::StoreWrite, call: 5 })
        );
        assert_eq!(with_crash_boundary(|| 42), Ok(42));
        let other = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _ = with_crash_boundary(|| panic!("a real bug"));
        }));
        assert!(
            other.is_err(),
            "non-crash panics must pass through the boundary"
        );
    }

    #[test]
    fn armed_crash_plan_fires_exactly_once_even_under_chaos_overlay() {
        quiet_crash_panics();
        let point = CrashPlan { site: FaultSite::ExecRun, call: 3 };
        let p = point.arm(0x5EED).with_chaos_rules();
        let r = with_crash_boundary(|| {
            for _ in 0..100 {
                // Contain the chaos overlay's ordinary ExecPanic
                // injections; only the scheduled CrashToken escapes to
                // the boundary.
                match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| p.exec_check()))
                {
                    Err(payload) if payload.downcast_ref::<CrashToken>().is_some() => {
                        std::panic::resume_unwind(payload)
                    }
                    _ => {}
                }
            }
        });
        assert_eq!(r, Err(CrashToken { site: FaultSite::ExecRun, call: 3 }));
        assert_eq!(p.injected(FaultKind::Crash), 1);
    }

    #[test]
    fn sweep_enumerates_the_cartesian_grid() {
        let pts = CrashPlan::sweep(&[FaultSite::StoreRead, FaultSite::StoreEvict], 3);
        assert_eq!(pts.len(), 6);
        assert_eq!(pts[0], CrashPlan { site: FaultSite::StoreRead, call: 0 });
        assert_eq!(pts[5], CrashPlan { site: FaultSite::StoreEvict, call: 2 });
    }
}
