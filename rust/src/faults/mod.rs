//! Deterministic fault injection for the serving stack.
//!
//! Edge deployments fail in mundane ways — a flaky flash read, a torn
//! write on power loss, a transient backend error, a crashed executor —
//! and the cold path is where those failures concentrate, because that is
//! where the bytes move. This module provides a seeded, replayable fault
//! source that threads into the [`crate::store::ArtifactStore`] and the
//! engine's execution backends so `tests/chaos_serving.rs` can replay the
//! serving workload under randomized fault schedules and assert the
//! survival invariants (no escaped panic, conserved request accounting,
//! healed corruption).
//!
//! # Determinism
//!
//! A [`FaultPlan`] decides faults as a pure function of `(seed, site,
//! call index, rule index)`: each instrumented site keeps an atomic call
//! counter, and probabilistic triggers hash those four values rather than
//! consulting a global RNG. Two plans built with the same seed and rules
//! inject the identical fault sequence, so a single-threaded chaos replay
//! is bit-reproducible. Under multi-threaded replay *which request*
//! observes call index `n` depends on interleaving, but the multiset of
//! injected faults per site does not — which is exactly what the
//! conservation invariants need.
//!
//! # Zero-cost default
//!
//! Instrumented sites hold an `Option`/`OnceLock` of an `Arc<FaultPlan>`
//! that is `None` unless a test or `repro serve --faults SEED` armed it;
//! the production path pays one pointer check per site and nothing else,
//! and with no plan armed behavior is bit-identical to an uninstrumented
//! build (asserted by the chaos suite's no-fault parity test).

use std::sync::atomic::{AtomicUsize, Ordering};

/// An instrumented code site a fault can be injected at.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FaultSite {
    /// [`crate::store::ArtifactStore`] artifact reads (`get`/`get_scoped`).
    StoreRead,
    /// [`crate::store::ArtifactStore`] artifact writes (`put`/`put_scoped`).
    StoreWrite,
    /// One [`crate::engine::ExecBackend::run`] cold execution attempt.
    ExecRun,
    /// One attempt to ship a multi-exit model's conditional tail to the
    /// simulated offload remote ([`crate::serving::Router`] with an
    /// [`crate::exits::OffloadPolicy`] armed).
    OffloadSend,
}

impl FaultSite {
    pub const ALL: [FaultSite; 4] = [
        FaultSite::StoreRead,
        FaultSite::StoreWrite,
        FaultSite::ExecRun,
        FaultSite::OffloadSend,
    ];

    fn idx(self) -> usize {
        match self {
            FaultSite::StoreRead => 0,
            FaultSite::StoreWrite => 1,
            FaultSite::ExecRun => 2,
            FaultSite::OffloadSend => 3,
        }
    }
}

/// What goes wrong when a rule fires.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FaultKind {
    /// A transient I/O error: a read reports failure without touching the
    /// bytes on disk (the store must treat it as a miss, not corruption);
    /// a write returns an `io::Error` before anything lands.
    IoError,
    /// Bit rot: one payload byte of the on-disk artifact is flipped in
    /// place before the read validates it (the store must reject + heal).
    CorruptBytes,
    /// A torn write: the header claims the full payload but only half of
    /// it lands — the next reader must reject + heal.
    TornWrite,
    /// A transient execution failure: the backend returns `Err` for this
    /// attempt (retryable).
    ExecFail,
    /// The executor panics mid-run (the router must contain it; the real
    /// backend's executor thread dies and must respawn).
    ExecPanic,
    /// The offload link drops the tail shipment: the router must fall
    /// back to the degraded path (never hang, never double-count).
    OffloadDrop,
}

impl FaultKind {
    pub const ALL: [FaultKind; 6] = [
        FaultKind::IoError,
        FaultKind::CorruptBytes,
        FaultKind::TornWrite,
        FaultKind::ExecFail,
        FaultKind::ExecPanic,
        FaultKind::OffloadDrop,
    ];

    fn idx(self) -> usize {
        match self {
            FaultKind::IoError => 0,
            FaultKind::CorruptBytes => 1,
            FaultKind::TornWrite => 2,
            FaultKind::ExecFail => 3,
            FaultKind::ExecPanic => 4,
            FaultKind::OffloadDrop => 5,
        }
    }
}

/// When a rule fires, in terms of the site's call counter (0-based).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Trigger {
    /// Exactly at call `n` of the rule's site.
    At(usize),
    /// Every `period`-th call starting at `offset` (`period == 0` never
    /// fires).
    Every { period: usize, offset: usize },
    /// Independently at each call with probability `p`, decided by a hash
    /// of `(seed, site, call index, rule index)` — deterministic per
    /// seed, no shared RNG state.
    Prob(f64),
}

/// One injection rule: at `site`, inject `kind` whenever `trigger` says.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultRule {
    pub site: FaultSite,
    pub kind: FaultKind,
    pub trigger: Trigger,
}

/// A seeded fault schedule plus its bookkeeping: per-site call counters
/// (the clock every trigger reads) and per-kind injected counters (what
/// the chaos assertions reconcile against the router's failure taxonomy).
/// Cheap to share as an `Arc` across a store handle and a backend; all
/// state is atomic.
#[derive(Debug, Default)]
pub struct FaultPlan {
    seed: u64,
    rules: Vec<FaultRule>,
    calls: [AtomicUsize; 4],
    injected: [AtomicUsize; 6],
}

impl FaultPlan {
    /// An empty plan (injects nothing) with the given seed; add rules
    /// with [`FaultPlan::with_rule`].
    pub fn new(seed: u64) -> FaultPlan {
        FaultPlan { seed, ..Default::default() }
    }

    /// Builder-style: append one rule. Rules are consulted in insertion
    /// order; the first rule that fires on a call wins.
    pub fn with_rule(mut self, site: FaultSite, kind: FaultKind, trigger: Trigger) -> FaultPlan {
        self.rules.push(FaultRule { site, kind, trigger });
        self
    }

    /// The standard randomized chaos mix used by the chaos test suite and
    /// `repro serve --faults SEED`: a moderate rate of every fault kind
    /// at its natural site. Frequent enough that a few hundred requests
    /// exercise every path, rare enough that most requests still succeed.
    pub fn chaos(seed: u64) -> FaultPlan {
        FaultPlan::new(seed)
            .with_rule(FaultSite::StoreRead, FaultKind::IoError, Trigger::Prob(0.10))
            .with_rule(FaultSite::StoreRead, FaultKind::CorruptBytes, Trigger::Prob(0.08))
            .with_rule(FaultSite::StoreWrite, FaultKind::TornWrite, Trigger::Prob(0.08))
            .with_rule(FaultSite::StoreWrite, FaultKind::IoError, Trigger::Prob(0.05))
            .with_rule(FaultSite::ExecRun, FaultKind::ExecFail, Trigger::Prob(0.12))
            .with_rule(FaultSite::ExecRun, FaultKind::ExecPanic, Trigger::Prob(0.03))
            .with_rule(FaultSite::OffloadSend, FaultKind::OffloadDrop, Trigger::Prob(0.10))
    }

    /// The seed this plan hashes probabilistic triggers with.
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// One tick of `site`'s clock: advance the call counter and decide
    /// whether (and which) fault to inject at this call. Instrumented
    /// sites call this exactly once per operation. `None` = run clean.
    pub fn draw(&self, site: FaultSite) -> Option<FaultKind> {
        let n = self.calls[site.idx()].fetch_add(1, Ordering::Relaxed);
        for (ri, rule) in self.rules.iter().enumerate() {
            if rule.site != site {
                continue;
            }
            let fire = match rule.trigger {
                Trigger::At(k) => n == k,
                Trigger::Every { period, offset } => {
                    period > 0 && n >= offset && (n - offset) % period == 0
                }
                Trigger::Prob(p) => unit_f64(mix64(
                    self.seed
                        ^ (site.idx() as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
                        ^ (n as u64).wrapping_mul(0xBF58_476D_1CE4_E5B9)
                        ^ (ri as u64).wrapping_mul(0x94D0_49BB_1331_11EB),
                )) < p,
            };
            if fire {
                self.injected[rule.kind.idx()].fetch_add(1, Ordering::Relaxed);
                return Some(rule.kind);
            }
        }
        None
    }

    /// Convenience for execution backends: draw at [`FaultSite::ExecRun`]
    /// and enact the result — `Err` for a transient failure, `panic!` for
    /// an injected executor crash (the caller's containment is the thing
    /// under test), `Ok(())` for a clean run or a kind that does not
    /// apply to execution.
    pub fn exec_check(&self) -> Result<(), String> {
        match self.draw(FaultSite::ExecRun) {
            Some(FaultKind::ExecFail) => Err("injected transient exec failure".to_string()),
            Some(FaultKind::ExecPanic) => panic!("injected executor panic"),
            _ => Ok(()),
        }
    }

    /// How many faults of `kind` this plan has injected so far.
    pub fn injected(&self, kind: FaultKind) -> usize {
        self.injected[kind.idx()].load(Ordering::Relaxed)
    }

    /// Total faults injected across all kinds.
    pub fn injected_total(&self) -> usize {
        FaultKind::ALL.iter().map(|k| self.injected(*k)).sum()
    }

    /// How many calls `site` has seen (each call = one `draw`).
    pub fn calls(&self, site: FaultSite) -> usize {
        self.calls[site.idx()].load(Ordering::Relaxed)
    }
}

/// SplitMix64 finalizer: a full-avalanche 64-bit mix, the deterministic
/// hash behind [`Trigger::Prob`] decisions and the router's seeded retry
/// jitter.
pub fn mix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Map a 64-bit hash to a uniform f64 in `[0, 1)`.
pub fn unit_f64(h: u64) -> f64 {
    (h >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_plan_never_fires() {
        let p = FaultPlan::new(7);
        for _ in 0..100 {
            assert_eq!(p.draw(FaultSite::StoreRead), None);
            assert!(p.exec_check().is_ok());
        }
        assert_eq!(p.injected_total(), 0);
        assert_eq!(p.calls(FaultSite::StoreRead), 100);
        assert_eq!(p.calls(FaultSite::ExecRun), 100);
        assert_eq!(p.calls(FaultSite::StoreWrite), 0);
    }

    #[test]
    fn at_and_every_triggers_fire_by_call_count() {
        let p = FaultPlan::new(1)
            .with_rule(FaultSite::StoreRead, FaultKind::IoError, Trigger::At(2))
            .with_rule(
                FaultSite::StoreWrite,
                FaultKind::TornWrite,
                Trigger::Every { period: 3, offset: 1 },
            );
        let reads: Vec<Option<FaultKind>> =
            (0..5).map(|_| p.draw(FaultSite::StoreRead)).collect();
        assert_eq!(
            reads,
            vec![None, None, Some(FaultKind::IoError), None, None]
        );
        let writes: Vec<bool> = (0..8)
            .map(|_| p.draw(FaultSite::StoreWrite) == Some(FaultKind::TornWrite))
            .collect();
        assert_eq!(
            writes,
            vec![false, true, false, false, true, false, false, true]
        );
        assert_eq!(p.injected(FaultKind::IoError), 1);
        assert_eq!(p.injected(FaultKind::TornWrite), 3);
    }

    #[test]
    fn prob_triggers_are_deterministic_per_seed_and_roughly_calibrated() {
        let draws = |seed: u64| -> Vec<Option<FaultKind>> {
            let p = FaultPlan::new(seed)
                .with_rule(FaultSite::ExecRun, FaultKind::ExecFail, Trigger::Prob(0.25));
            (0..2000).map(|_| p.draw(FaultSite::ExecRun)).collect()
        };
        let a = draws(0xC0FFEE);
        let b = draws(0xC0FFEE);
        assert_eq!(a, b, "same seed must replay the same fault sequence");
        let hits = a.iter().filter(|d| d.is_some()).count();
        assert!(
            (300..700).contains(&hits),
            "p=0.25 over 2000 draws gave {hits} hits"
        );
        let c = draws(0xBEEF);
        assert_ne!(a, c, "different seeds must differ");
    }

    #[test]
    fn first_matching_rule_wins() {
        let p = FaultPlan::new(3)
            .with_rule(FaultSite::ExecRun, FaultKind::ExecFail, Trigger::Every { period: 1, offset: 0 })
            .with_rule(FaultSite::ExecRun, FaultKind::ExecPanic, Trigger::Every { period: 1, offset: 0 });
        for _ in 0..10 {
            assert_eq!(p.draw(FaultSite::ExecRun), Some(FaultKind::ExecFail));
        }
        assert_eq!(p.injected(FaultKind::ExecPanic), 0);
    }

    #[test]
    fn exec_check_panics_on_injected_panic() {
        let p = FaultPlan::new(4).with_rule(
            FaultSite::ExecRun,
            FaultKind::ExecPanic,
            Trigger::At(0),
        );
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| p.exec_check()));
        assert!(r.is_err(), "injected panic must unwind");
        assert!(p.exec_check().is_ok(), "only call 0 was scheduled");
        assert_eq!(p.injected(FaultKind::ExecPanic), 1);
    }

    #[test]
    fn chaos_mix_touches_every_site() {
        let p = FaultPlan::chaos(0x5EED);
        for _ in 0..400 {
            let _ = p.draw(FaultSite::StoreRead);
            let _ = p.draw(FaultSite::StoreWrite);
            let _ = p.draw(FaultSite::OffloadSend);
            let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| p.exec_check()));
        }
        assert!(p.injected(FaultKind::IoError) > 0);
        assert!(p.injected(FaultKind::CorruptBytes) > 0);
        assert!(p.injected(FaultKind::TornWrite) > 0);
        assert!(p.injected(FaultKind::ExecFail) > 0);
        assert!(p.injected(FaultKind::ExecPanic) > 0);
        assert!(p.injected(FaultKind::OffloadDrop) > 0);
    }
}
