//! Raw f32 blob I/O + an optionally throttled reader.
//!
//! The throttle emulates edge-device storage bandwidth on the development
//! host (our NVMe is far faster than a phone's flash), preserving the
//! read-raw vs read-cached trade-off of Table 2 on the *real* execution
//! path. Throttling sleeps to pace actual reads; it never fakes data.

use std::fs::File;
use std::io::{Read, Write};
use std::path::Path;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

/// Write an f32 slice as little-endian bytes.
pub fn write_f32(path: &Path, data: &[f32]) -> Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut f = File::create(path).with_context(|| format!("creating {}", path.display()))?;
    // Safety: f32 -> bytes reinterpretation for plain-old-data.
    let bytes =
        unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
    f.write_all(bytes)?;
    Ok(())
}

/// Read a whole file of little-endian f32s.
pub fn read_f32(path: &Path) -> Result<Vec<f32>> {
    let mut f = File::open(path).with_context(|| format!("opening {}", path.display()))?;
    let mut buf = Vec::new();
    f.read_to_end(&mut buf)?;
    anyhow::ensure!(buf.len() % 4 == 0, "{}: length not a multiple of 4", path.display());
    let mut out = Vec::with_capacity(buf.len() / 4);
    for chunk in buf.chunks_exact(4) {
        out.push(f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]));
    }
    Ok(out)
}

/// Reader pacing reads to a target bandwidth (MB/s). `None` = unthrottled.
#[derive(Debug, Clone, Copy)]
pub struct ThrottledReader {
    pub mbps: Option<f64>,
    /// Read chunk size; pacing granularity.
    pub chunk: usize,
}

impl Default for ThrottledReader {
    fn default() -> ThrottledReader {
        ThrottledReader { mbps: None, chunk: 1 << 20 }
    }
}

impl ThrottledReader {
    pub fn throttled(mbps: f64) -> ThrottledReader {
        ThrottledReader { mbps: Some(mbps), chunk: 256 << 10 }
    }

    /// Read a file fully, pacing to the configured bandwidth.
    pub fn read(&self, path: &Path) -> Result<Vec<u8>> {
        let mut f =
            File::open(path).with_context(|| format!("opening {}", path.display()))?;
        let len = f.metadata()?.len() as usize;
        let mut buf = vec![0u8; len];
        let t0 = Instant::now();
        let mut off = 0usize;
        while off < len {
            let end = (off + self.chunk).min(len);
            f.read_exact(&mut buf[off..end])?;
            off = end;
            if let Some(mbps) = self.mbps {
                // Sleep until the pace front catches up.
                let target_s = off as f64 / (mbps * 1e6);
                let elapsed = t0.elapsed().as_secs_f64();
                if target_s > elapsed {
                    std::thread::sleep(Duration::from_secs_f64(target_s - elapsed));
                }
            }
        }
        Ok(buf)
    }

    /// Read little-endian f32s with pacing.
    pub fn read_f32(&self, path: &Path) -> Result<Vec<f32>> {
        let buf = self.read(path)?;
        anyhow::ensure!(buf.len() % 4 == 0, "{}: bad length", path.display());
        Ok(buf
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir() -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!(
            "nnv12-store-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn f32_roundtrip() {
        let p = tmpdir().join("w.bin");
        let data: Vec<f32> = (0..1000).map(|i| i as f32 * 0.5 - 7.0).collect();
        write_f32(&p, &data).unwrap();
        assert_eq!(read_f32(&p).unwrap(), data);
    }

    #[test]
    fn throttled_read_paces() {
        let p = tmpdir().join("big.bin");
        let data = vec![1.0f32; 1 << 18]; // 1 MiB
        write_f32(&p, &data).unwrap();
        let r = ThrottledReader::throttled(50.0); // 50 MB/s ⇒ ≥ 20 ms
        let t0 = Instant::now();
        let out = r.read_f32(&p).unwrap();
        let elapsed = t0.elapsed().as_secs_f64();
        assert_eq!(out.len(), data.len());
        assert!(elapsed >= 0.015, "read too fast: {elapsed}s");
        // Unthrottled should be much faster (min of 3 tries to absorb
        // scheduler noise when the test host is loaded).
        let fast = ThrottledReader::default();
        let best = (0..3)
            .map(|_| {
                let t0 = Instant::now();
                fast.read_f32(&p).unwrap();
                t0.elapsed().as_secs_f64()
            })
            .fold(f64::INFINITY, f64::min);
        assert!(best < elapsed, "unthrottled {best}s vs throttled {elapsed}s");
    }

    #[test]
    fn missing_file_is_error() {
        assert!(read_f32(Path::new("/nonexistent/nope.bin")).is_err());
    }
}
