//! Weight storage: raw blobs + the post-transformed-weights cache.
//!
//! The decision stage (Fig. 4) writes transformed weights next to the raw
//! model; the runtime then reads whichever the plan asks for. Cache
//! entries live in the [`crate::store::ArtifactStore`]'s `weights`
//! namespace, content-addressed by (model, layer, kernel variant, raw
//! blob length + checksum) — so a model update addresses fresh entries
//! and stale ones age out through the store's LRU eviction instead of
//! being silently served (versioned invalidation).

pub mod store;
pub mod cache;

pub use cache::TransformCache;
pub use store::{read_f32, write_f32, ThrottledReader};
