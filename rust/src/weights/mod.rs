//! Weight storage: raw blobs + the post-transformed-weights disk cache.
//!
//! The decision stage (Fig. 4) writes transformed weights next to the raw
//! model; the runtime then reads whichever the plan asks for. Cache entries
//! are keyed by (layer, kernel variant) and carry a header with the source
//! blob's length + checksum, so stale caches are detected after a model
//! update (versioned invalidation).

pub mod store;
pub mod cache;

pub use cache::TransformCache;
pub use store::{read_f32, write_f32, ThrottledReader};
