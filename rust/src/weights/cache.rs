//! The post-transformed-weights cache (§3.1.2), a typed view over the
//! content-addressed [`crate::store::ArtifactStore`].
//!
//! Entries live in the store's [`Namespace::Weights`] namespace. The key
//! is content-addressed over (model, layer, kernel variant, raw blob
//! length, raw blob checksum), so a re-downloaded or updated model simply
//! addresses *different* entries instead of silently executing on wrong
//! weights (zero-accuracy-loss principle, §3); the stale entries stop
//! being referenced and age out through the store's LRU eviction. The
//! store's header + checksum validation additionally rejects truncated or
//! corrupt blobs on read.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::store::{ArtifactStore, Namespace};

/// FNV-1a over the bit pattern of an f32 slice.
pub fn checksum(data: &[f32]) -> u32 {
    let mut h: u32 = 0x811C_9DC5;
    for x in data {
        for b in x.to_le_bytes() {
            h ^= b as u32;
            h = h.wrapping_mul(0x0100_0193);
        }
    }
    h
}

/// Per-model view over a weights store.
#[derive(Debug, Clone)]
pub struct TransformCache {
    store: Arc<ArtifactStore>,
    model: String,
}

impl TransformCache {
    /// A cache rooted at a private store directory (created lazily on the
    /// first write).
    pub fn new(dir: &Path, model: &str) -> TransformCache {
        TransformCache::over(Arc::new(ArtifactStore::at(dir)), model)
    }

    /// A cache over a shared artifact store — the engine facade's path,
    /// where weights share the store (and its size cap) with plans.
    pub fn over(store: Arc<ArtifactStore>, model: &str) -> TransformCache {
        TransformCache { store, model: model.to_string() }
    }

    /// The backing store (hit/miss/eviction counters live there).
    pub fn store(&self) -> &Arc<ArtifactStore> {
        &self.store
    }

    /// Content-addressed key of one entry: everything the transformed
    /// blob is a function of.
    fn key(&self, layer: usize, variant: &str, raw: &[f32]) -> u64 {
        ArtifactStore::key_of(&[
            &self.model,
            &format!("L{layer:03}"),
            variant,
            &raw.len().to_string(),
            &format!("{:08x}", checksum(raw)),
        ])
    }

    /// Store transformed weights, addressed by the raw source blob and
    /// scoped under this model's name (so the model's entries can be
    /// sized and cleared as a group).
    pub fn put(&self, layer: usize, variant: &str, raw: &[f32], transformed: &[f32]) -> Result<()> {
        let mut payload = Vec::with_capacity(transformed.len() * 4);
        for x in transformed {
            payload.extend_from_slice(&x.to_le_bytes());
        }
        self.store
            .put_scoped(
                Namespace::Weights,
                &self.model,
                self.key(layer, variant, raw),
                &payload,
            )
            .with_context(|| {
                format!(
                    "writing weights cache entry {}/L{layer:03}.{variant} under {}",
                    self.model,
                    self.store.dir().display()
                )
            })
    }

    /// Fetch transformed weights if present *and* still valid for `raw`
    /// (a changed source blob addresses a different key, so stale entries
    /// can never be returned).
    pub fn get(&self, layer: usize, variant: &str, raw: &[f32]) -> Result<Option<Vec<f32>>> {
        let Some(payload) =
            self.store
                .get_scoped(Namespace::Weights, &self.model, self.key(layer, variant, raw))
        else {
            return Ok(None);
        };
        if payload.len() % 4 != 0 {
            // Cannot happen for our own writes (checksum-validated), but a
            // foreign writer could store a non-f32 payload under this key.
            return Ok(None);
        }
        Ok(Some(
            payload
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect(),
        ))
    }

    /// Whether an entry for this exact (layer, variant, raw source)
    /// exists (without reading or validating the payload).
    pub fn contains(&self, layer: usize, variant: &str, raw: &[f32]) -> bool {
        self.store
            .contains_scoped(Namespace::Weights, &self.model, self.key(layer, variant, raw))
    }

    /// Total bytes of *this model's* weight artifacts (Table 4's "Storage
    /// Overhead" column).
    pub fn bytes_used(&self) -> u64 {
        self.store.bytes_in_scope(Namespace::Weights, &self.model)
    }

    /// Drop this model's weight entries (other models sharing the store
    /// are untouched). Also removes the pre-artifact-store layout
    /// (`<dir>/<model>/L*.cache.bin`) if a directory from an older cache
    /// is still sitting there, so upgraded stores don't leak stale blobs.
    pub fn clear(&self) -> Result<()> {
        self.store.clear_scope(Namespace::Weights, &self.model);
        let legacy = self.store.dir().join(&self.model);
        if legacy.is_dir() {
            std::fs::remove_dir_all(&legacy)
                .with_context(|| format!("removing legacy cache dir {}", legacy.display()))?;
        }
        Ok(())
    }
}

/// Kept for callers that want a throwaway cache directory in tests.
pub fn temp_cache_dir(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!(
        "nnv12-weights-{tag}-{}-{:?}",
        std::process::id(),
        std::thread::current().id()
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cache() -> TransformCache {
        TransformCache::new(&temp_cache_dir("unit"), "unit")
    }

    #[test]
    fn put_get_roundtrip() {
        let c = cache();
        c.clear().unwrap();
        let raw: Vec<f32> = (0..100).map(|i| i as f32).collect();
        let transformed: Vec<f32> = raw.iter().map(|x| x * 2.0).collect();
        c.put(3, "winograd", &raw, &transformed).unwrap();
        assert!(c.contains(3, "winograd", &raw));
        assert_eq!(c.get(3, "winograd", &raw).unwrap().unwrap(), transformed);
        assert!(c.get(3, "sgemm", &raw).unwrap().is_none());
        assert!(c.bytes_used() > transformed.len() as u64 * 4);
    }

    #[test]
    fn stale_entry_rejected_after_model_update() {
        let c = cache();
        c.clear().unwrap();
        let raw: Vec<f32> = (0..50).map(|i| i as f32).collect();
        c.put(0, "pack4", &raw, &raw).unwrap();
        // "Update the model": same length, different values.
        let raw2: Vec<f32> = raw.iter().map(|x| x + 1.0).collect();
        assert!(c.get(0, "pack4", &raw2).unwrap().is_none());
        // Different length too.
        assert!(c.get(0, "pack4", &raw[..10]).unwrap().is_none());
        // Original still valid.
        assert!(c.get(0, "pack4", &raw).unwrap().is_some());
    }

    #[test]
    fn checksum_sensitive_to_changes() {
        let a: Vec<f32> = vec![1.0, 2.0, 3.0];
        let mut b = a.clone();
        b[1] = 2.0000002;
        assert_ne!(checksum(&a), checksum(&b));
        assert_eq!(checksum(&a), checksum(&a.clone()));
    }

    #[test]
    fn shared_store_serves_fresh_view() {
        let dir = temp_cache_dir("shared");
        let _ = std::fs::remove_dir_all(&dir);
        let raw: Vec<f32> = (0..64).map(|i| i as f32 * 0.5).collect();
        let tr: Vec<f32> = raw.iter().map(|x| x + 7.0).collect();
        TransformCache::new(&dir, "m").put(5, "im2col", &raw, &tr).unwrap();
        // A fresh view (≈ a fresh process) over the same directory hits.
        let c2 = TransformCache::new(&dir, "m");
        assert_eq!(c2.get(5, "im2col", &raw).unwrap().unwrap(), tr);
        assert_eq!(c2.store().stats().hits, 1);
        // A different model name addresses different entries.
        assert!(TransformCache::new(&dir, "other").get(5, "im2col", &raw).unwrap().is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn clear_and_bytes_used_are_per_model() {
        let dir = temp_cache_dir("per-model");
        let _ = std::fs::remove_dir_all(&dir);
        let raw: Vec<f32> = (0..32).map(|i| i as f32).collect();
        let a = TransformCache::new(&dir, "model-a");
        let b = TransformCache::new(&dir, "model-b");
        a.put(0, "winograd", &raw, &raw).unwrap();
        b.put(0, "winograd", &raw, &raw).unwrap();
        assert!(a.bytes_used() > 0);
        assert_eq!(a.bytes_used(), b.bytes_used());
        // Clearing model A must not touch model B's entries.
        a.clear().unwrap();
        assert_eq!(a.bytes_used(), 0);
        assert!(a.get(0, "winograd", &raw).unwrap().is_none());
        assert!(b.get(0, "winograd", &raw).unwrap().is_some());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
