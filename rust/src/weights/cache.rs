//! The post-transformed-weights disk cache (§3.1.2).
//!
//! Entries live under `<dir>/<model>/L<layer>.<variant>.cache.bin` with a
//! 16-byte header: magic, header version, source length (f32 count), and an
//! FNV-1a checksum of the source blob — so a re-downloaded or updated model
//! invalidates stale entries instead of silently executing on wrong
//! weights (zero-accuracy-loss principle, §3).

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use super::store::{read_f32, write_f32};

const MAGIC: u32 = 0x4E4E_5631; // "NNV1"
const VERSION: u32 = 1;

/// FNV-1a over the bit pattern of an f32 slice.
pub fn checksum(data: &[f32]) -> u32 {
    let mut h: u32 = 0x811C_9DC5;
    for x in data {
        for b in x.to_le_bytes() {
            h ^= b as u32;
            h = h.wrapping_mul(0x0100_0193);
        }
    }
    h
}

/// Disk cache rooted at a directory.
#[derive(Debug, Clone)]
pub struct TransformCache {
    dir: PathBuf,
    model: String,
}

impl TransformCache {
    pub fn new(dir: &Path, model: &str) -> TransformCache {
        TransformCache { dir: dir.to_path_buf(), model: model.to_string() }
    }

    fn path(&self, layer: usize, variant: &str) -> PathBuf {
        self.dir
            .join(&self.model)
            .join(format!("L{layer:03}.{variant}.cache.bin"))
    }

    /// Store transformed weights, stamped against the raw source blob.
    pub fn put(&self, layer: usize, variant: &str, raw: &[f32], transformed: &[f32]) -> Result<()> {
        let p = self.path(layer, variant);
        let mut blob = Vec::with_capacity(transformed.len() + 4);
        blob.push(f32::from_bits(MAGIC));
        blob.push(f32::from_bits(VERSION));
        blob.push(f32::from_bits(raw.len() as u32));
        blob.push(f32::from_bits(checksum(raw)));
        blob.extend_from_slice(transformed);
        write_f32(&p, &blob).with_context(|| format!("writing cache {}", p.display()))
    }

    /// Fetch transformed weights if present *and* still valid for `raw`.
    pub fn get(&self, layer: usize, variant: &str, raw: &[f32]) -> Result<Option<Vec<f32>>> {
        let p = self.path(layer, variant);
        if !p.exists() {
            return Ok(None);
        }
        let blob = read_f32(&p)?;
        if blob.len() < 4 {
            bail!("cache {} truncated", p.display());
        }
        let magic = blob[0].to_bits();
        let version = blob[1].to_bits();
        let src_len = blob[2].to_bits() as usize;
        let src_sum = blob[3].to_bits();
        if magic != MAGIC || version != VERSION {
            return Ok(None); // foreign or old-format file: ignore
        }
        if src_len != raw.len() || src_sum != checksum(raw) {
            return Ok(None); // stale: model changed underneath
        }
        Ok(Some(blob[4..].to_vec()))
    }

    /// Whether a valid-looking entry exists (without verifying the source).
    pub fn contains(&self, layer: usize, variant: &str) -> bool {
        self.path(layer, variant).exists()
    }

    /// Total bytes used by this model's cache entries (Table 4's "Storage
    /// Overhead" column).
    pub fn bytes_used(&self) -> u64 {
        let dir = self.dir.join(&self.model);
        std::fs::read_dir(&dir)
            .map(|rd| {
                rd.flatten()
                    .filter_map(|e| e.metadata().ok())
                    .map(|m| m.len())
                    .sum()
            })
            .unwrap_or(0)
    }

    /// Drop all entries for this model.
    pub fn clear(&self) -> Result<()> {
        let dir = self.dir.join(&self.model);
        if dir.exists() {
            std::fs::remove_dir_all(&dir)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cache() -> TransformCache {
        let d = std::env::temp_dir().join(format!(
            "nnv12-cache-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        TransformCache::new(&d, "unit")
    }

    #[test]
    fn put_get_roundtrip() {
        let c = cache();
        c.clear().unwrap();
        let raw: Vec<f32> = (0..100).map(|i| i as f32).collect();
        let transformed: Vec<f32> = raw.iter().map(|x| x * 2.0).collect();
        c.put(3, "winograd", &raw, &transformed).unwrap();
        assert!(c.contains(3, "winograd"));
        assert_eq!(c.get(3, "winograd", &raw).unwrap().unwrap(), transformed);
        assert!(c.get(3, "sgemm", &raw).unwrap().is_none());
        assert!(c.bytes_used() > transformed.len() as u64 * 4);
    }

    #[test]
    fn stale_entry_rejected_after_model_update() {
        let c = cache();
        c.clear().unwrap();
        let raw: Vec<f32> = (0..50).map(|i| i as f32).collect();
        c.put(0, "pack4", &raw, &raw).unwrap();
        // "Update the model": same length, different values.
        let raw2: Vec<f32> = raw.iter().map(|x| x + 1.0).collect();
        assert!(c.get(0, "pack4", &raw2).unwrap().is_none());
        // Different length too.
        assert!(c.get(0, "pack4", &raw[..10]).unwrap().is_none());
        // Original still valid.
        assert!(c.get(0, "pack4", &raw).unwrap().is_some());
    }

    #[test]
    fn checksum_sensitive_to_changes() {
        let a: Vec<f32> = vec![1.0, 2.0, 3.0];
        let mut b = a.clone();
        b[1] = 2.0000002;
        assert_ne!(checksum(&a), checksum(&b));
        assert_eq!(checksum(&a), checksum(&a.clone()));
    }
}
