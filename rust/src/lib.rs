//! # NNV12 — boosting DNN cold inference on edge devices
//!
//! Reproduction of the MobiSys'23 NNV12 system as a three-layer
//! Rust + JAX + Pallas stack. Cold inference — reading weights from disk,
//! transforming them into a kernel's execution-ready layout, and executing
//! the model — is optimized through three knobs (§3.1 of the paper):
//!
//! 1. **Kernel selection** — every operator has many kernel implementations
//!    (ncnn ships 28 for convolution alone, Fig. 5); the fastest kernel for
//!    *warm* inference is often not the fastest end-to-end in *cold*
//!    inference because of its weight-transformation cost
//!    ([`kernels`]).
//! 2. **Post-transformed-weights caching** — the transformation can be
//!    bypassed by caching transformed weights on disk, trading disk I/O for
//!    memory-bound transformation work ([`weights`]).
//! 3. **Pipelined inference** — per-layer read/transform/execute operations
//!    are pipelined across the asymmetric cores of an edge SoC
//!    ([`sched`], [`sim`], [`pipeline`]).
//!
//! The crate is organized bottom-up:
//!
//! * [`util`] — in-tree substrates for the offline build environment
//!   (JSON, CLI, statistics, PRNG, property testing, bench harness).
//! * [`graph`] — model-graph IR plus builders for the paper's 12 models.
//! * [`kernels`] — kernel registry, the Fig. 5 selection tree, per-family
//!   cost functions.
//! * [`device`] — edge-device profiles (Meizu 16T, Pixel 5, Redmi 9,
//!   Meizu 18 Pro, Jetson TX2, Jetson Nano).
//! * [`cost`] — the per-operation latency model `T(op, core, threads)`.
//! * [`sched`] — the §3.2 scheduling problem and the §3.3 heuristic
//!   scheduler (Algorithm 1), plus an exact brute-force oracle.
//! * [`baselines`] — ncnn / TFLite / AsyMo / TensorFlow-GPU engine models.
//! * [`sim`] — discrete-event simulator of the device executing a plan,
//!   with bandwidth contention, background load, and workload stealing.
//! * [`transform`] — real weight-transformation math (im2col packing,
//!   Winograd F(2,3), pack4) used on the real execution path.
//! * [`weights`] — raw weight store and the post-transform disk cache.
//! * [`runtime`] — PJRT client wrapper: loads AOT HLO-text artifacts
//!   produced by `python/compile/aot.py` and executes them.
//! * [`pipeline`] — real-thread pipelined executor over the runtime.
//! * [`serving`] — multi-tenant serving front: request router and LRU model
//!   residency manager (cold inferences are induced by eviction).
//! * [`warm`] — §3.5 kernel switching for subsequent warm inference.
//! * [`metrics`] — timing, summaries, and the energy model.
//! * [`report`] — regenerates every table and figure of the paper's
//!   evaluation.

pub mod util;
pub mod graph;
pub mod kernels;
pub mod device;
pub mod cost;
pub mod sched;
pub mod baselines;
pub mod sim;
pub mod transform;
pub mod weights;
pub mod runtime;
pub mod pipeline;
pub mod serving;
pub mod warm;
pub mod metrics;
pub mod report;

/// Milliseconds, the time unit used throughout the cost model and simulator.
pub type Ms = f64;

/// Bytes.
pub type Bytes = u64;

/// Floating-point operations.
pub type Flops = u64;
