//! # NNV12 — boosting DNN cold inference on edge devices
//!
//! Reproduction of the MobiSys'23 NNV12 system. Cold inference — reading
//! weights from disk, transforming them into a kernel's execution-ready
//! layout, and executing the model — is optimized through three knobs
//! (§3.1 of the paper): cold-aware **kernel selection**,
//! **post-transformed-weights caching**, and **pipelined** preparation
//! across the asymmetric cores of an edge SoC.
//!
//! ## Entry point: [`engine::Engine`] and [`engine::Session`]
//!
//! The whole lifecycle — plan kernels, read/transform or cache weights,
//! execute cold, switch kernels toward warm speed — hangs off one facade:
//!
//! ```
//! use nnv12::device::profiles;
//! use nnv12::engine::{Engine, Phase};
//! use nnv12::graph::zoo;
//!
//! // An engine owns the shared substrate: device, kernel registry,
//! // scheduler config, plan cache, and an execution backend.
//! let engine = Engine::builder()
//!     .device(profiles::meizu_16t())
//!     .memory_budget(64 << 20)
//!     .build();
//!
//! // Loading a model plans it (cached; optionally disk-persistent via
//! // `.artifact_store(dir)`) and computes its §3.5 warm-up ladder.
//! let session = engine.load(zoo::tiny_net());
//!
//! // Sessions expose the explicit cold → warming → warm state machine.
//! let report = session.infer();
//! assert_eq!(report.phase, Phase::Cold);
//! assert!(session.infer().latency_ms <= report.latency_ms);
//! ```
//!
//! Execution is pluggable ([`engine::ExecBackend`]): the default
//! [`engine::SimBackend`] runs plans on the contention-aware device
//! simulator; [`engine::BaselineBackend`] charges a vanilla engine's
//! latencies for comparison arms; `engine::RealBackend` (behind the
//! default-on `real-runtime` cargo feature, the only thing that pulls in
//! the `xla` crate) executes AOT HLO artifacts through PJRT. Everything
//! above compiles and runs under `--no-default-features`.
//!
//! ## Concurrent serving and the failure model
//!
//! The engine substrate is thread-safe (`Engine`/`Session` are
//! `Send + Sync`; backends are `Send + Sync` by trait bound), and the
//! serving [`serving::Router`] is a sharded concurrent front over it —
//! [`serving::Router::request`] takes `&self`, so one router serves
//! requests from any number of threads:
//!
//! ```
//! use nnv12::device::profiles;
//! use nnv12::graph::zoo;
//! use nnv12::serving::{Router, RouterConfig};
//!
//! let router = Router::new(
//!     &profiles::meizu_16t(),
//!     vec![zoo::tiny_net(), zoo::micro_mobilenet()],
//!     // `RouterConfig { tenants: K, .. }` would also partition the
//!     // fleet and the memory budget across K tenants, each with its own
//!     // LRU residency lane (one tenant's eviction storm cannot
//!     // cold-start another's models) and a per-tenant row in
//!     // `summary().per_tenant`.
//!     RouterConfig::default(),
//! );
//! std::thread::scope(|s| {
//!     for _ in 0..2 {
//!         let router = &router;
//!         s.spawn(move || {
//!             router.request("tinynet").unwrap();
//!             router.request("micro-mobilenet").unwrap();
//!         });
//!     }
//! });
//! assert_eq!(router.stats_cold() + router.stats_warm(), 4);
//!
//! // On the happy path the failure taxonomy stays all-zero, and the
//! // conservation invariant always holds:
//! //   cold + warm + degraded + offloaded + shed + failed == issued.
//! let s = router.summary();
//! assert_eq!(s.degraded + s.offloaded + s.shed + s.failed, 0);
//! assert!(s.conserves());
//! ```
//!
//! The router **survives** the failure modes that concentrate on the cold
//! path (ISSUE 6, extended by ISSUE 8). Every request resolves to exactly
//! one of six outcomes — the conservation invariant above is asserted by
//! the chaos suite under injected faults:
//!
//! * **Cold / Warm** — the normal lifecycle: plan + execute on a miss,
//!   then walk the §3.5 warm-up ladder.
//! * **Offloaded** — the deadline is tighter than the cold estimate but
//!   the model has early exits and [`serving::RouterConfig::offload`]
//!   priced serving the head locally and the conditional tail on a
//!   remote inside the deadline (see [`exits::OffloadPolicy`]).
//! * **Degraded** — the request is served from the baseline-engine plan
//!   (no plan search, no residency charge) because either (a) its
//!   deadline is tighter than the §3.5 ladder's cold estimate and
//!   offload was off or infeasible, or (b) the model's circuit breaker
//!   is open after repeated backend failures.
//! * **Shed** — the per-shard admission budget of in-flight cold starts
//!   is exhausted (and the bounded waiting room, if
//!   [`serving::RouterConfig::queue_depth`] enables one, is full); the
//!   router refuses explicitly instead of queueing unboundedly.
//! * **Failed** — a cold execution kept failing after bounded
//!   exponential-backoff retries (deterministic, seeded jitter; charged
//!   to modeled latency, never slept).
//!
//! Transient failures trip a per-model circuit breaker
//! (closed → open → half-open probe), and [`faults::FaultPlan`] injects
//! deterministic store/backend faults for `tests/chaos_serving.rs`.
//! `repro serve --threads N --deadline-ms D --admission K --faults SEED`
//! drives the same path from the CLI, and
//! `benches/serving_throughput.rs` ratchets it in CI (4-thread
//! throughput must beat 1-thread in the same run, with shed == 0 and
//! degraded == 0 on the fault-free trace).
//!
//! Two serving extensions ride on top of that taxonomy (ISSUE 8): an
//! optional bounded per-shard **queue** (`queue_depth`) that lets a
//! request wait for an in-flight cold start instead of shedding
//! immediately (counted by `queued`, which is a waiting-room gauge, not a
//! terminal outcome), and an **offload** path for multi-exit models —
//! see the next section — which adds `offloaded` as a sixth conserved
//! outcome.
//!
//! ## Early-exit workloads: multi-exit graphs, expected makespans, offload
//!
//! Models with BranchyNet-style early exits ([`graph::ExitPoint`]) make
//! execution past an exit *conditional*: layer `l` only runs for the
//! requests that survived every earlier exit. The [`exits`] subsystem
//! exploits that end to end. [`exits::schedule_expected`] searches cold
//! plans under survival-weighted prices (the same exact incremental
//! machinery as [`sched::schedule`]; bit-identical to it when every exit
//! probability is zero), [`exits::compare_expected_vs_blind`] scores the
//! probability-blind plan under the same expected-makespan metric (the
//! `exits` report and bench ratchet the gap), and
//! [`exits::OffloadPolicy`] prices serving the conditional tail on a
//! simulated remote (RTT + bandwidth + remote speedup), which the router
//! uses when a local cold start would miss a request's deadline:
//!
//! ```
//! use nnv12::device::profiles;
//! use nnv12::exits::{compare_expected_vs_blind, OffloadPolicy};
//! use nnv12::graph::zoo;
//! use nnv12::kernels::Registry;
//! use nnv12::sched::SchedulerConfig;
//!
//! // A multi-exit model: a resnet18 backbone with two calibrated exits.
//! let g = zoo::branchy_resnet18();
//! assert!(g.has_exits());
//! assert!(g.survival_weights().last().unwrap() < &1.0);
//!
//! // Expected-makespan plan vs the probability-blind plan, both scored
//! // under the survival-weighted metric. The expected plan never loses.
//! let cmp = compare_expected_vs_blind(
//!     &profiles::meizu_16t(), &g, &Registry::full(), &SchedulerConfig::kcp());
//! assert!(cmp.expected_ms <= cmp.blind_ms);
//!
//! // The tail-offload estimate is deterministic arithmetic over the
//! // first exit: local head + survival-weighted (link + remote tail).
//! let est = nnv12::exits::offload_estimate(&g, &OffloadPolicy::default(), 800.0).unwrap();
//! assert!(est.expected_ms > est.head_ms);
//! ```
//!
//! ## Fleet planning: plans travel between devices
//!
//! A fleet of devices running the same zoo repeats nearly the same plan
//! search everywhere. The [`fleet`] subsystem shares that work:
//! every searched plan is *published* into the artifact store's
//! fleet namespace (scoped by model fingerprint, keyed by a canonical
//! [`fleet::DeviceFingerprint`]), and a device that misses looks up the
//! **nearest-profile donor** (a scale-invariant distance over
//! within-device cost ratios) and runs a *seeded* search — the donor's
//! choices re-priced exactly on the target, kept only if they beat the
//! target's own greedy baseline, then one short descent pass — instead
//! of a cold one. A seed that re-prices worse is rejected and the search
//! falls back to the full cold descent, so transfer can only save search
//! time, never cost plan quality. [`fleet::FleetPlanner`] plans a whole
//! zoo × device grid this way (nearest-profile device tour, models in
//! parallel) and emits a coverage report (hit-rate, descent passes
//! saved, per-cell transfer-vs-cold quality ratio); `repro fleet` prints
//! it, and `Engine::builder().fleet_transfer(true)` wires the same
//! lookup into session cold starts.
//!
//! ## Layers underneath
//!
//! * [`util`] — in-tree substrates for the offline build environment
//!   (JSON, CLI, statistics, PRNG, property testing, bench harness,
//!   scoped parallel map).
//! * [`graph`] — model-graph IR plus builders for the paper's 12 models.
//! * [`kernels`] — kernel registry, the Fig. 5 selection tree, per-family
//!   cost functions.
//! * [`device`] — edge-device profiles (Meizu 16T, Pixel 5, Redmi 9,
//!   Meizu 18 Pro, Jetson TX2, Jetson Nano).
//! * [`cost`] — the per-operation latency model `T(op, core, threads)`.
//! * [`sched`] — the §3.2 scheduling problem, the §3.3 heuristic
//!   scheduler (Algorithm 1) with its incremental plan-search engine, and
//!   the fingerprint-keyed plan + calibrated-plan caches.
//! * [`exits`] — early-exit workloads: survival-weighted
//!   (expected-makespan) plan search over the same incremental engine,
//!   and the deterministic local-vs-offload latency model.
//! * [`store`] — the content-addressed artifact store: one persistence
//!   layer (typed namespaces, version+checksum headers, atomic writes,
//!   LRU size cap) for plans, calibrated plans, transformed weights, and
//!   fleet plans.
//! * [`fleet`] — cross-device plan transfer: device fingerprints
//!   (identity + similarity), nearest-profile seeding over the store's
//!   fleet namespace, and the zoo × fleet coverage planner/report.
//! * [`faults`] — deterministic fault injection: seeded
//!   trigger-by-call-count rules (I/O error, corrupt bytes, torn write,
//!   transient exec failure, executor panic) threaded into the store and
//!   the backends behind a zero-cost default.
//! * [`baselines`] — ncnn / TFLite / AsyMo / TensorFlow-GPU engine models.
//! * [`sim`] — discrete-event simulator of the device executing a plan,
//!   with bandwidth contention, background load, and workload stealing.
//! * [`transform`] — real weight-transformation math (im2col packing,
//!   Winograd F(2,3), pack4) used on the real execution path.
//! * [`weights`] — raw weight I/O and the post-transform cache (a typed
//!   view over the artifact store).
//! * [`runtime`] (`real-runtime`) — PJRT client wrapper: loads AOT
//!   HLO-text artifacts produced by `python/compile/aot.py`.
//! * [`pipeline`] (`real-runtime`) — real-thread pipelined executor over
//!   the runtime.
//! * [`engine`] — **the facade**: `Engine`/`Session` lifecycle over
//!   pluggable backends and the persistent artifact store; fully
//!   thread-safe (O(1) intrusive-LRU residency with optional per-tenant
//!   quota lanes, `Send + Sync` backends).
//! * [`serving`] — multi-tenant serving front over the engine: sharded
//!   concurrent request router (`request()` is `&self`) with
//!   deadline-aware degradation, bounded admission, retries, a
//!   per-model circuit breaker, per-shard latency recorders, and
//!   per-tenant budget partitioning + outcome attribution; open-loop
//!   Poisson workload generator (cold inferences are induced by
//!   eviction).
//! * [`warm`] — §3.5 kernel switching for subsequent warm inference (the
//!   primitive behind session warm-up ladders).
//! * [`metrics`] — timing, summaries, and the energy model.
//! * [`report`] — regenerates every table and figure of the paper's
//!   evaluation through the facade.

pub mod util;
pub mod graph;
pub mod kernels;
pub mod device;
pub mod cost;
pub mod sched;
pub mod exits;
pub mod store;
pub mod fleet;
pub mod faults;
pub mod baselines;
pub mod sim;
pub mod transform;
pub mod weights;
#[cfg(feature = "real-runtime")]
pub mod runtime;
#[cfg(feature = "real-runtime")]
pub mod pipeline;
pub mod engine;
pub mod serving;
pub mod warm;
pub mod metrics;
pub mod report;

/// Milliseconds, the time unit used throughout the cost model and simulator.
pub type Ms = f64;

/// Bytes.
pub type Bytes = u64;

/// Floating-point operations.
pub type Flops = u64;
