//! Real-mode pipelined cold inference over the PJRT runtime.
//!
//! This is the paper's runtime stage (Fig. 4, right) executed for real on
//! the host: worker threads ("little cores") read weight blobs from disk
//! (optionally throttled to edge-storage bandwidth) and transform them into
//! the chosen kernel's layout (or read the post-transformed cache), while
//! the executor thread (the "gang") compiles + runs each layer's AOT HLO
//! artifact via PJRT as soon as its weights and input activation are ready.
//!
//! Python never runs here: artifacts were AOT-compiled by `make artifacts`.
//!
//! The sequential mode (`pipelined = false`) emulates a vanilla engine —
//! read everything, transform everything, then execute — and is the real-
//! mode baseline the examples compare against.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use anyhow::{anyhow, bail, Context, Result};

use crate::graph::manifest::Manifest;
use crate::metrics::Timer;
use crate::runtime::Runtime;
use crate::store::ArtifactStore;
use crate::transform::transform_by_name;
use crate::weights::{ThrottledReader, TransformCache};

/// Kernel-variant preference for real-mode planning.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum VariantPref {
    /// NNV12-style: fastest-exec variant, cached when caching is on.
    Auto,
    /// Force a specific family (ablations + tests).
    Direct,
    Im2col,
    Winograd,
}

/// Options for a real cold run.
#[derive(Debug, Clone)]
pub struct RealRunOpts {
    /// Throttle disk reads to this bandwidth (None = host speed).
    pub disk_mbps: Option<f64>,
    /// Number of preparation worker threads ("little cores").
    pub workers: usize,
    /// Read/write the post-transformed-weights cache.
    pub use_cache: bool,
    /// Shared content-addressed [`ArtifactStore`] backing the weights
    /// cache — the engine facade's path ([`crate::engine::RealBackend`]
    /// fills this from its engine), which puts real-mode transformed
    /// weights under the same size cap, integrity checks, and counters
    /// as plans. When `None`, `cache_dir` is used as a private fallback.
    pub store: Option<Arc<ArtifactStore>>,
    /// Deprecated fallback: private store directory used only when
    /// `store` is `None` (standalone CLI/example runs). The default is
    /// scoped per user (`$TMPDIR/nnv12-cache-<user>`), so concurrent
    /// users on one machine no longer contend over a single shared path
    /// whose files the second user cannot replace. Prefer `store`.
    pub cache_dir: PathBuf,
    /// Overlap preparation with execution (the "P" knob). Off = vanilla
    /// sequential engine.
    pub pipelined: bool,
    pub variant: VariantPref,
}

impl Default for RealRunOpts {
    fn default() -> RealRunOpts {
        RealRunOpts {
            disk_mbps: None,
            workers: 2,
            use_cache: false,
            store: None,
            cache_dir: default_cache_dir(),
            pipelined: true,
            variant: VariantPref::Auto,
        }
    }
}

/// Per-user fallback weights-cache directory. The historical default was
/// the shared `$TMPDIR/nnv12-cache`, which collided across users (the
/// first user's files are unwritable to the second) and across concurrent
/// processes' accounting; scoping by user keeps the benign cross-process
/// reuse (atomic content-addressed writes make it safe) while removing
/// the cross-user hazard.
fn default_cache_dir() -> PathBuf {
    // USER/LOGNAME (unix), USERNAME (windows), then the home directory's
    // basename (covers stripped-env daemons that export only HOME) — the
    // constant tail is a last resort, not the common path.
    let user = ["USER", "LOGNAME", "USERNAME"]
        .iter()
        .find_map(|k| std::env::var(k).ok())
        .filter(|u| !u.is_empty())
        .or_else(|| {
            std::env::var("HOME").ok().and_then(|h| {
                PathBuf::from(h)
                    .file_name()
                    .map(|n| n.to_string_lossy().into_owned())
            })
        })
        .unwrap_or_else(|| "shared".to_string());
    std::env::temp_dir().join(format!("nnv12-cache-{user}"))
}

/// Open the transform cache `opts` asks for: the shared artifact store
/// when one is wired (the engine path), else the private `cache_dir`
/// fallback; `None` when caching is off.
fn open_cache(opts: &RealRunOpts, model: &str) -> Option<TransformCache> {
    if !opts.use_cache {
        return None;
    }
    Some(match &opts.store {
        Some(store) => TransformCache::over(store.clone(), model),
        None => TransformCache::new(&opts.cache_dir, model),
    })
}

/// Phase timing breakdown of a real run (sums of op durations; phases
/// overlap in pipelined mode, so they can exceed `wall_ms`).
#[derive(Debug, Clone, Default)]
pub struct ColdRun {
    pub wall_ms: f64,
    pub read_ms: f64,
    pub transform_ms: f64,
    pub compile_ms: f64,
    pub exec_ms: f64,
    /// Cache hits among prepared layers.
    pub cache_hits: usize,
    /// Final activation of the model.
    pub output: Vec<f32>,
}

/// Pick the variant for a layer given the preference and what the manifest
/// offers. Returns (variant name, needs transform).
fn pick_variant(m: &Manifest, layer: usize, pref: VariantPref, cache_on: bool) -> Result<String> {
    let avail: Vec<&str> = m.artifacts[layer]
        .variants
        .iter()
        .map(|v| v.variant.as_str())
        .collect();
    if avail.is_empty() {
        bail!("layer {layer} has no variants");
    }
    let want = match pref {
        VariantPref::Direct => "direct",
        VariantPref::Im2col => "im2col",
        VariantPref::Winograd => "winograd",
        VariantPref::Auto => {
            // Cold-aware: winograd executes fastest but its transform is
            // expensive — pick it only when the cache can absorb the cost;
            // otherwise im2col (cheap transform, good exec); else direct.
            if cache_on && avail.contains(&"winograd") {
                "winograd"
            } else if avail.contains(&"im2col") {
                "im2col"
            } else {
                avail[0]
            }
        }
    };
    if avail.contains(&want) {
        Ok(want.to_string())
    } else {
        Ok(avail[0].to_string())
    }
}

struct PrepSlots {
    /// layer -> (weights in exec layout, bias)
    ready: Mutex<HashMap<usize, Arc<(Vec<f32>, Vec<f32>)>>>,
    cv: Condvar,
}

/// Prepare one layer's weights: read (raw or cached), transform if needed.
/// Returns (weights, bias, read_ms, transform_ms, cache_hit).
fn prepare_layer(
    m: &Manifest,
    layer: usize,
    variant: &str,
    reader: &ThrottledReader,
    cache: Option<&TransformCache>,
) -> Result<(Vec<f32>, Vec<f32>, f64, f64, bool)> {
    let arts = &m.artifacts[layer];
    let raw_path = m.resolve(
        arts.raw_weights
            .as_ref()
            .ok_or_else(|| anyhow!("layer {layer} has no weights"))?,
    );
    let t_read = Timer::start();
    let raw = reader
        .read_f32(&raw_path)
        .with_context(|| format!("reading weights for layer {layer}"))?;
    let mut read_ms = t_read.elapsed_ms();

    let graph_layer = m.model.layer(layer);
    let needs_transform = matches!(variant, "im2col" | "winograd");
    let bias_elems = arts.bias_elems as usize;

    if !needs_transform {
        let (w, b) = raw.split_at(raw.len() - bias_elems);
        return Ok((w.to_vec(), b.to_vec(), read_ms, 0.0, false));
    }

    // Cache fast path: read the post-transformed blob instead.
    if let Some(cache) = cache {
        let t = Timer::start();
        if let Some(tr) = cache.get(layer, variant, &raw)? {
            read_ms += t.elapsed_ms(); // cache verification + read
            let (w, b) = tr.split_at(tr.len() - bias_elems);
            return Ok((w.to_vec(), b.to_vec(), read_ms, 0.0, true));
        }
    }

    let t_tr = Timer::start();
    let transformed = transform_by_name(variant, &raw, graph_layer)
        .ok_or_else(|| anyhow!("no rust transform for variant {variant}"))?;
    let transform_ms = t_tr.elapsed_ms();
    if let Some(cache) = cache {
        cache.put(layer, variant, &raw, &transformed)?;
    }
    let (w, b) = transformed.split_at(transformed.len() - bias_elems);
    Ok((w.to_vec(), b.to_vec(), read_ms, transform_ms, false))
}

/// A warm session: prepared weights resident in memory. Subsequent
/// inferences skip reading and transformation entirely (warm inference).
pub struct Session {
    variant_of: HashMap<usize, String>,
    weights: HashMap<usize, Arc<(Vec<f32>, Vec<f32>)>>,
}

impl Session {
    /// Warm inference: execute only (weights already resident).
    /// Returns (output, exec wall ms).
    pub fn run_warm(
        &self,
        manifest: &Manifest,
        runtime: &Runtime,
        input: &[f32],
    ) -> Result<(Vec<f32>, f64)> {
        let slots = PrepSlots {
            ready: Mutex::new(self.weights.clone()),
            cv: Condvar::new(),
        };
        let t = Timer::start();
        let run = execute_layers(manifest, runtime, input, &self.variant_of, &slots, false)?;
        Ok((run.output, t.elapsed_ms()))
    }

    /// Bytes of resident prepared weights.
    pub fn resident_bytes(&self) -> u64 {
        self.weights
            .values()
            .map(|wb| ((wb.0.len() + wb.1.len()) * 4) as u64)
            .sum()
    }
}

/// Run one real cold inference and keep the prepared weights as a warm
/// [`Session`] (what a resident model looks like to the serving layer).
pub fn run_cold_session(
    manifest: &Manifest,
    runtime: &Runtime,
    input: &[f32],
    opts: &RealRunOpts,
) -> Result<(ColdRun, Session)> {
    let run = run_cold(manifest, runtime, input, opts)?;
    // Re-derive the variant decisions and re-load prepared weights from
    // the (now hot) OS page cache + transform cache: cheap, and keeps
    // `run_cold` allocation-free of session plumbing.
    let weighted = manifest.model.weighted_layers();
    let mut variant_of = HashMap::new();
    let mut weights = HashMap::new();
    let reader = ThrottledReader::default();
    let cache = open_cache(opts, &manifest.model.name);
    for &l in &weighted {
        let variant = pick_variant(manifest, l, opts.variant, opts.use_cache)?;
        let (w, b, _, _, _) = prepare_layer(manifest, l, &variant, &reader, cache.as_ref())?;
        variant_of.insert(l, variant);
        weights.insert(l, Arc::new((w, b)));
    }
    Ok((run, Session { variant_of, weights }))
}

/// Run one real cold inference. `input` must match the manifest's input
/// layer dims (flat f32, NCHW).
pub fn run_cold(
    manifest: &Manifest,
    runtime: &Runtime,
    input: &[f32],
    opts: &RealRunOpts,
) -> Result<ColdRun> {
    let t_wall = Timer::start();
    let reader = match opts.disk_mbps {
        Some(mbps) => ThrottledReader::throttled(mbps),
        None => ThrottledReader::default(),
    };
    let cache = open_cache(opts, &manifest.model.name);

    // Per-layer variant decision.
    let weighted = manifest.model.weighted_layers();
    let mut variant_of: HashMap<usize, String> = HashMap::new();
    for &l in &weighted {
        variant_of.insert(l, pick_variant(manifest, l, opts.variant, opts.use_cache)?);
    }

    let slots = Arc::new(PrepSlots { ready: Mutex::new(HashMap::new()), cv: Condvar::new() });
    let read_ns = Arc::new(AtomicU64::new(0));
    let transform_ns = Arc::new(AtomicU64::new(0));
    let cache_hits = Arc::new(AtomicU64::new(0));

    let prep_one = |layer: usize| -> Result<()> {
        let variant = &variant_of[&layer];
        let (w, b, r_ms, t_ms, hit) =
            prepare_layer(manifest, layer, variant, &reader, cache.as_ref())?;
        read_ns.fetch_add((r_ms * 1e6) as u64, Ordering::Relaxed);
        transform_ns.fetch_add((t_ms * 1e6) as u64, Ordering::Relaxed);
        if hit {
            cache_hits.fetch_add(1, Ordering::Relaxed);
        }
        let mut g = slots.ready.lock().unwrap();
        g.insert(layer, Arc::new((w, b)));
        slots.cv.notify_all();
        Ok(())
    };

    let mut run = ColdRun::default();

    if opts.pipelined && opts.workers > 0 {
        // Round-robin layers over workers; scoped threads so we can borrow.
        std::thread::scope(|scope| -> Result<()> {
            let mut handles = Vec::new();
            for w in 0..opts.workers {
                let my_layers: Vec<usize> = weighted
                    .iter()
                    .copied()
                    .enumerate()
                    .filter(|(i, _)| i % opts.workers == w)
                    .map(|(_, l)| l)
                    .collect();
                let prep = &prep_one;
                handles.push(scope.spawn(move || -> Result<()> {
                    for l in my_layers {
                        prep(l)?;
                    }
                    Ok(())
                }));
            }
            // Gang: execute layers in order as weights become ready.
            run = execute_layers(manifest, runtime, input, &variant_of, &slots, true)?;
            for h in handles {
                h.join().map_err(|_| anyhow!("prep worker panicked"))??;
            }
            Ok(())
        })?;
    } else {
        // Sequential baseline: prepare everything, then execute.
        for &l in &weighted {
            prep_one(l)?;
        }
        run = execute_layers(manifest, runtime, input, &variant_of, &slots, false)?;
    }

    run.read_ms = read_ns.load(Ordering::Relaxed) as f64 / 1e6;
    run.transform_ms = transform_ns.load(Ordering::Relaxed) as f64 / 1e6;
    run.cache_hits = cache_hits.load(Ordering::Relaxed) as usize;
    run.wall_ms = t_wall.elapsed_ms();
    Ok(run)
}

/// The gang loop: topological execution of every layer's HLO artifact.
fn execute_layers(
    manifest: &Manifest,
    runtime: &Runtime,
    input: &[f32],
    variant_of: &HashMap<usize, String>,
    slots: &PrepSlots,
    pipelined: bool,
) -> Result<ColdRun> {
    let mut run = ColdRun::default();
    let g = &manifest.model;
    let mut acts: HashMap<usize, Arc<Vec<f32>>> = HashMap::new();
    acts.insert(0, Arc::new(input.to_vec()));

    for layer in g.layers().iter().skip(1) {
        let arts = &manifest.artifacts[layer.id];
        // Locate the exec artifact for the chosen variant (weightless
        // layers have a single variant named "builtin").
        let variant = variant_of
            .get(&layer.id)
            .map(String::as_str)
            .unwrap_or("builtin");
        let va = arts
            .variants
            .iter()
            .find(|v| v.variant == variant)
            .or_else(|| arts.variants.first())
            .ok_or_else(|| anyhow!("layer {} has no exec artifact", layer.id))?;
        // "Pipeline creation": compile (cached across runs in-process).
        let pre = runtime.is_cached(&manifest.resolve(&va.exec_hlo));
        let exe = runtime.load(&manifest.resolve(&va.exec_hlo))?;
        if !pre {
            run.compile_ms += exe.compile_ms;
        }

        // Wait for this layer's weights if it has any.
        let weights = if g.layer(layer.id).op.has_weights() {
            let mut guard = slots.ready.lock().unwrap();
            while !guard.contains_key(&layer.id) {
                if !pipelined {
                    bail!("layer {} weights missing in sequential mode", layer.id);
                }
                guard = slots.cv.wait(guard).unwrap();
            }
            Some(guard[&layer.id].clone())
        } else {
            None
        };

        // Assemble inputs: activation(s) then weights then bias.
        let dep = *layer.deps.first().unwrap_or(&0);
        let x = acts
            .get(&dep)
            .ok_or_else(|| anyhow!("missing activation of layer {dep}"))?
            .clone();
        let in_dims = &arts.in_dims;
        let t_exec = Timer::start();
        let out = match &weights {
            Some(wb) => {
                let (w, b) = (&wb.0, &wb.1);
                let b_dims = [b.len() as i64];
                exe.run_f32(&[
                    (x.as_slice(), in_dims.as_slice()),
                    (w.as_slice(), va.w_dims.as_slice()),
                    (b.as_slice(), b_dims.as_slice()),
                ])?
            }
            None => exe.run_f32(&[(x.as_slice(), in_dims.as_slice())])?,
        };
        run.exec_ms += t_exec.elapsed_ms();
        acts.insert(layer.id, Arc::new(out));
    }

    let last = g.len() - 1;
    run.output = acts
        .remove(&last)
        .map(|a| a.as_ref().clone())
        .unwrap_or_default();
    Ok(run)
}

// Real-mode integration tests live in `tests/real_mode.rs` (they need the
// artifacts produced by `make artifacts`).
