//! Fleet planning: cross-device plan transfer with nearest-profile
//! seeding, and a fleet-wide coverage report.
//!
//! A fleet of edge devices running the same model zoo repeats nearly the
//! same plan search on every device: the §3.3 combination search is
//! deterministic and its result depends only on the device's *cost
//! shape* (compute vs IO balance, big:little ratios), which varies far
//! less across a device family than across families. This module turns
//! that redundancy into wall-clock savings with three pieces:
//!
//! * [`DeviceFingerprint`] — a canonical capture of every profile field
//!   the cost model reads, with a stable identity key (FNV-1a over a
//!   canonical byte layout) and a *scale-invariant* distance metric over
//!   within-device ratios. Identity keys the fleet store; distance picks
//!   donors. The fleet namespace keys on the *measured* variant
//!   ([`DeviceFingerprint::measured`]: rate features derived from
//!   deterministic cost-model micro-probes, so the key reflects what the
//!   planner will actually price, not what the spec sheet claims);
//!   legacy static-keyed artifacts are migrated by a one-time
//!   revalidate-and-heal pass ([`PlanTransfer::heal_scope`]) the first
//!   time a transfer handle plans in a scope.
//! * [`PlanTransfer`] — publish every searched plan into the store's
//!   fleet namespace (scoped by model fingerprint, keyed by device
//!   fingerprint); on a later miss, fetch the nearest-profile donor plan
//!   and run a **seeded search**: re-price the donor's kernel choices on
//!   the target with exact per-layer price-table patches, keep the seed
//!   only if its confirmed makespan is no worse than the target's own
//!   greedy baseline, then run one short descent pass over only the
//!   transferred layers. A transfer is *rejected* — falling back to the
//!   full cold search — when the seed doesn't map structurally (layer
//!   count mismatch) or re-prices worse than the baseline. Either way
//!   the final plan is confirmed on the target and never worse than the
//!   cold search's starting point; transfer changes how fast a plan is
//!   *found*, never how bad a plan is allowed to *be*.
//! * [`FleetPlanner`] — plan a zoo across every profile in a
//!   nearest-profile device tour (families adjacent, so each family pays
//!   one cold search), models in parallel per device, auditing every
//!   cell against a same-run cold search and keeping the better plan.
//!   The [`FleetReport`] states the transfer hit-rate, descent passes
//!   saved, and per-cell transfer-vs-cold quality ratios.

mod fingerprint;
mod planner;
mod transfer;

pub use fingerprint::DeviceFingerprint;
pub use planner::{FleetCell, FleetPlanner, FleetReport};
pub use transfer::{Donor, HealReport, PlanTransfer, TransferResult};
