//! Fleet-wide planning: every model in a zoo × every device profile,
//! with cross-device plan transfer doing the heavy lifting.
//!
//! The planner walks the devices in a *nearest-profile tour* (greedy
//! nearest-neighbor chain over [`DeviceFingerprint::distance`]), so that
//! by the time a device plans, the fleet store already holds a plan from
//! the most similar device that came before it — families share seeds:
//! the first phone pays the cold search, the phones after it seed from
//! it; the first Jetson pays once for the GPU family. Within one device,
//! models plan in parallel ([`par_map`]) — they live in disjoint store
//! scopes and the transfer counters are atomic.
//!
//! Every cell also runs the *same-run cold search* and keeps whichever
//! plan is better. That makes the planner an audit tool, not just a
//! batch runner: the [`FleetReport`] can state, per cell, what transfer
//! saved (descent passes, search quality ratio) against ground truth
//! computed in the same process — and the kept plan is never worse than
//! the cold search's, by construction.

use std::sync::Arc;

use crate::device::DeviceProfile;
use crate::fleet::transfer::PlanTransfer;
use crate::fleet::DeviceFingerprint;
use crate::graph::ModelGraph;
use crate::kernels::Registry;
use crate::sched::heuristic::{schedule_seeded, SchedulerConfig};
use crate::store::ArtifactStore;
use crate::util::json::Json;
use crate::util::parallel::par_map;
use crate::util::table::{fmt_ms, Table};

/// One (device, model) cell of the fleet plan.
#[derive(Debug, Clone)]
pub struct FleetCell {
    pub device: String,
    pub model: String,
    /// The donor device the transfer drew from; `None` on a store miss.
    pub donor: Option<String>,
    /// Fingerprint distance to the donor.
    pub distance: Option<f64>,
    /// Whether the transferred seed was accepted (hit). `false` covers
    /// both rejection (donor found, re-priced worse than baseline) and
    /// miss (no donor).
    pub seeded: bool,
    /// The transferred seed's re-priced makespan on this device.
    pub seed_ms: Option<f64>,
    /// This device's own greedy baseline — the bar the seed had to clear.
    pub baseline_ms: f64,
    /// Makespan of the plan the transfer path settled on.
    pub transfer_ms: f64,
    /// Makespan of the same-run cold search (ground truth).
    pub cold_ms: f64,
    /// Makespan of the plan the fleet keeps: `min(transfer, cold)`.
    pub kept_ms: f64,
    /// Confirm-accepted descent passes on the transfer path.
    pub passes_transfer: usize,
    /// Confirm-accepted descent passes in the cold search.
    pub passes_cold: usize,
}

/// Aggregated outcome of one [`FleetPlanner::plan_fleet`] run.
#[derive(Debug, Clone)]
pub struct FleetReport {
    pub cells: Vec<FleetCell>,
    /// Transfers accepted (donor seed beat or matched the baseline).
    pub hits: usize,
    /// Donors found but rejected at the accept gate.
    pub rejected: usize,
    /// Cells with no donor in the store.
    pub misses: usize,
}

impl FleetReport {
    /// Fraction of cells whose search was seeded by a transferred plan.
    pub fn hit_rate(&self) -> f64 {
        if self.cells.is_empty() {
            0.0
        } else {
            self.hits as f64 / self.cells.len() as f64
        }
    }

    /// Descent passes the seeded searches avoided, against the same-run
    /// cold searches of the same cells.
    pub fn passes_saved(&self) -> usize {
        self.cells
            .iter()
            .filter(|c| c.seeded)
            .map(|c| c.passes_cold.saturating_sub(c.passes_transfer))
            .sum()
    }

    /// Worst per-cell `transfer_ms / cold_ms` ratio — 1.0 or below
    /// everywhere means transfer never cost plan quality. (The *kept*
    /// plan is `min` of the two, so kept/cold is ≤ 1.0 by construction;
    /// this ratio audits the transfer path itself.)
    pub fn worst_quality_ratio(&self) -> f64 {
        self.cells
            .iter()
            .filter(|c| c.cold_ms > 0.0)
            .map(|c| c.transfer_ms / c.cold_ms)
            .fold(0.0, f64::max)
    }

    /// The per-cell coverage table.
    pub fn table(&self) -> Table {
        let mut t = Table::new(
            "Fleet plan coverage (transfer vs same-run cold search)",
            &[
                "device", "model", "donor", "dist", "seeded", "seed",
                "baseline", "transfer", "cold", "kept",
            ],
        );
        for c in &self.cells {
            t.row(vec![
                c.device.clone(),
                c.model.clone(),
                c.donor.clone().unwrap_or_else(|| "-".into()),
                c.distance.map_or("-".into(), |d| format!("{d:.2}")),
                if c.seeded { "hit".into() } else if c.donor.is_some() { "reject".into() } else { "miss".into() },
                c.seed_ms.map_or("-".into(), fmt_ms),
                fmt_ms(c.baseline_ms),
                fmt_ms(c.transfer_ms),
                fmt_ms(c.cold_ms),
                fmt_ms(c.kept_ms),
            ]);
        }
        t
    }

    /// The one-line aggregates: hit rate, passes saved, worst ratio.
    pub fn summary(&self) -> String {
        format!(
            "cells {} | transfer hits {} ({:.0}%), rejected {}, misses {} | descent passes saved {} | worst transfer/cold ratio {:.3}",
            self.cells.len(),
            self.hits,
            100.0 * self.hit_rate(),
            self.rejected,
            self.misses,
            self.passes_saved(),
            self.worst_quality_ratio(),
        )
    }

    /// Machine-readable form for `--report DIR`.
    pub fn to_json(&self) -> Json {
        let cells = self
            .cells
            .iter()
            .map(|c| {
                Json::obj(vec![
                    ("device", Json::from(c.device.as_str())),
                    ("model", Json::from(c.model.as_str())),
                    (
                        "donor",
                        c.donor.as_deref().map_or(Json::Null, Json::from),
                    ),
                    ("distance", c.distance.map_or(Json::Null, Json::from)),
                    ("seeded", Json::from(c.seeded)),
                    ("seed_ms", c.seed_ms.map_or(Json::Null, Json::from)),
                    ("baseline_ms", Json::from(c.baseline_ms)),
                    ("transfer_ms", Json::from(c.transfer_ms)),
                    ("cold_ms", Json::from(c.cold_ms)),
                    ("kept_ms", Json::from(c.kept_ms)),
                    ("passes_transfer", Json::from(c.passes_transfer)),
                    ("passes_cold", Json::from(c.passes_cold)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("cells", Json::Arr(cells)),
            ("hits", Json::from(self.hits)),
            ("rejected", Json::from(self.rejected)),
            ("misses", Json::from(self.misses)),
            ("hit_rate", Json::from(self.hit_rate())),
            ("passes_saved", Json::from(self.passes_saved())),
            ("worst_quality_ratio", Json::from(self.worst_quality_ratio())),
        ])
    }
}

/// Plans a model zoo across a device fleet through the transfer path.
pub struct FleetPlanner {
    transfer: PlanTransfer,
    registry: Registry,
    cfg: SchedulerConfig,
    registry_tag: String,
}

impl FleetPlanner {
    /// A planner over `store` with the full kernel registry and the given
    /// scheduler config.
    pub fn new(store: Arc<ArtifactStore>, cfg: SchedulerConfig) -> FleetPlanner {
        FleetPlanner {
            transfer: PlanTransfer::new(store),
            registry: Registry::full(),
            cfg,
            registry_tag: "full".to_string(),
        }
    }

    /// The transfer handle (counters, store).
    pub fn transfer(&self) -> &PlanTransfer {
        &self.transfer
    }

    /// Order devices as a greedy nearest-neighbor chain: start from the
    /// first device as given, then repeatedly append the unvisited device
    /// closest (by fingerprint distance, ties by name) to the last one
    /// appended. Profile families end up adjacent, so each device after
    /// the first of its family finds a close donor already published.
    pub fn device_tour(devices: Vec<DeviceProfile>) -> Vec<DeviceProfile> {
        if devices.len() < 3 {
            return devices;
        }
        // Measured fingerprints: the tour's adjacency metric must be the
        // same one nearest_donor ranks candidates with.
        let fps: Vec<DeviceFingerprint> =
            devices.iter().map(DeviceFingerprint::measured).collect();
        let mut remaining: Vec<usize> = (1..devices.len()).collect();
        let mut order = vec![0usize];
        while !remaining.is_empty() {
            let last = &fps[*order.last().unwrap()];
            let (pos, _) = remaining
                .iter()
                .enumerate()
                .min_by(|(_, &a), (_, &b)| {
                    let da = last.distance(&fps[a]);
                    let db = last.distance(&fps[b]);
                    da.partial_cmp(&db)
                        .unwrap()
                        .then_with(|| fps[a].name.cmp(&fps[b].name))
                })
                .unwrap();
            order.push(remaining.remove(pos));
        }
        let mut devices: Vec<Option<DeviceProfile>> =
            devices.into_iter().map(Some).collect();
        order.into_iter().map(|i| devices[i].take().unwrap()).collect()
    }

    /// Plan every model on every device. Devices run sequentially in tour
    /// order (so publishes from earlier devices seed later ones); models
    /// run in parallel within a device. Each cell runs both the transfer
    /// path and a cold search, keeps the better plan (re-publishing the
    /// cold one if it wins), and reports both.
    pub fn plan_fleet(
        &self,
        models: &[ModelGraph],
        devices: Vec<DeviceProfile>,
    ) -> FleetReport {
        let tour = FleetPlanner::device_tour(devices);
        let mut cells = Vec::with_capacity(tour.len() * models.len());
        for dev in &tour {
            let per_model = par_map(models, |_, graph| {
                let r = self.transfer.plan(dev, graph, &self.registry, &self.cfg, &self.registry_tag);
                // Ground truth in the same process: an empty seed never
                // maps, so this is exactly the cold search (and reports
                // its descent pass count). No transfer counters move.
                let cold = schedule_seeded(dev, graph, &self.registry, &self.cfg, &[]);
                let transfer_ms = r.outcome.scheduled.schedule.makespan;
                let cold_ms = cold.scheduled.schedule.makespan;
                if cold_ms < transfer_ms {
                    // Cold search found a strictly better plan: the fleet
                    // keeps (and republishes) that one.
                    self.transfer.publish(dev, graph, &self.cfg, &self.registry_tag, &cold.scheduled);
                }
                FleetCell {
                    device: dev.name.to_string(),
                    model: graph.name.clone(),
                    donor: r.donor.as_ref().map(|d| d.device.clone()),
                    distance: r.donor.as_ref().map(|d| d.distance),
                    seeded: r.outcome.seeded,
                    seed_ms: r.outcome.seed_ms,
                    baseline_ms: r.outcome.baseline_ms,
                    transfer_ms,
                    cold_ms,
                    kept_ms: transfer_ms.min(cold_ms),
                    passes_transfer: r.outcome.passes,
                    passes_cold: cold.passes,
                }
            });
            cells.extend(per_model);
        }
        let hits = cells.iter().filter(|c| c.seeded).count();
        let rejected = cells.iter().filter(|c| c.donor.is_some() && !c.seeded).count();
        let misses = cells.iter().filter(|c| c.donor.is_none()).count();
        FleetReport { cells, hits, rejected, misses }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::profiles;
    use crate::graph::zoo;
    use std::path::PathBuf;

    fn temp_store(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!(
            "nnv12-fleetplan-{tag}-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ))
    }

    #[test]
    fn tour_keeps_families_adjacent() {
        let tour = FleetPlanner::device_tour(profiles::all_devices());
        let names: Vec<&str> = tour.iter().map(|d| d.name).collect();
        assert_eq!(names.len(), 6);
        // The two GPU boards must be adjacent: every phone is at least
        // the GPU-mismatch penalty away from either Jetson, while the
        // Jetsons are closer to each other than to any phone.
        let tx2 = names.iter().position(|n| *n == "jetson-tx2").unwrap();
        let nano = names.iter().position(|n| *n == "jetson-nano").unwrap();
        assert_eq!(tx2.abs_diff(nano), 1, "tour {names:?}");
    }

    #[test]
    fn second_run_is_fully_seeded_and_kept_never_worse_than_cold() {
        let dir = temp_store("rerun");
        let _ = std::fs::remove_dir_all(&dir);
        let models = [zoo::tiny_net(), zoo::squeezenet()];
        let devices = || {
            vec![
                profiles::meizu_16t(),
                profiles::pixel_5(),
                profiles::jetson_nano(),
            ]
        };
        let store = || Arc::new(ArtifactStore::open(&dir).unwrap());

        let first = FleetPlanner::new(store(), SchedulerConfig::kcp())
            .plan_fleet(&models, devices());
        assert_eq!(first.cells.len(), 6);
        // The very first cell of the tour has nothing to draw from.
        assert!(first.misses >= 1);
        for c in &first.cells {
            assert!(c.kept_ms <= c.cold_ms, "{}/{}", c.device, c.model);
            assert!(
                c.transfer_ms <= c.baseline_ms + 1e-9,
                "{}/{}: transfer path must never lose to its own baseline",
                c.device,
                c.model
            );
            assert_eq!(c.seeded, c.donor.is_some() && c.seed_ms.is_some_and(|s| s <= c.baseline_ms));
        }

        // A second planner over the same store finds every cell's own
        // published plan at distance 0 — all cells must be hits, and the
        // report must agree with the transfer counters.
        let planner = FleetPlanner::new(store(), SchedulerConfig::kcp());
        let second = planner.plan_fleet(&models, devices());
        assert_eq!(second.hits, second.cells.len(), "{}", second.summary());
        assert_eq!(second.misses, 0);
        assert_eq!(planner.transfer().hits(), second.hits);
        assert!(second.hit_rate() == 1.0);
        for c in &second.cells {
            assert_eq!(c.distance, Some(0.0), "{}/{}: own plan is the nearest donor", c.device, c.model);
            assert!(c.kept_ms <= c.cold_ms);
        }
        // Rendering never panics and covers every cell.
        assert_eq!(second.table().rows().len(), 6);
        assert!(second.to_json().to_pretty().contains("hit_rate"));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
