//! Canonical device fingerprints: identity + similarity for
//! [`DeviceProfile`]s.
//!
//! The fleet needs two different notions of "device":
//!
//! * **Identity** — an exact, stable key for "this plan was searched on
//!   this device". [`DeviceFingerprint::key`] hashes every profile field
//!   the cost model reads (FNV-1a over a canonical byte layout, so the
//!   key survives processes and builds — unlike `DefaultHasher`-based
//!   fingerprints it is stable by construction).
//! * **Similarity** — "how alike will two devices' plans be?".
//!   [`DeviceFingerprint::distance`] compares *scale-free* features:
//!   within-device ratios (big:little compute, disk and memory rates per
//!   GFLOP, the Fig. 6 little-core slowdowns) rather than absolute
//!   rates, so a device that is a uniformly-scaled clone of another —
//!   same silicon, different clock — is at distance ~0 and is the ideal
//!   plan donor, while a device with a different *shape* (GPU vs CPU,
//!   inverted compute:IO balance) is far away even at equal raw speed.
//!   Kernel choices depend on the shape of the trade-off, not its
//!   absolute scale, which is exactly what transfer cares about.

use crate::cost::CostModel;
use crate::device::{CoreClass, DeviceProfile};
use crate::graph::{Layer, OpKind};
use crate::kernels::Registry;
use crate::store::fnv1a;
use crate::util::json::Json;

/// Additive distance charged when exactly one of two devices executes on
/// a GPU: their plans schedule different op sets (driver init, pipeline
/// creation), so they are structurally poor donors for each other no
/// matter how close the CPU features look.
const GPU_MISMATCH_PENALTY: f64 = 4.0;

/// Additive distance per feature that is positive on one device and zero
/// on the other (e.g. `big_gflops` on jetson-nano, which has no big CPU
/// cores): the log-ratio is undefined there, and "has the resource" vs
/// "doesn't" is a shape difference worth a fixed charge.
const ZERO_FEATURE_PENALTY: f64 = 2.0;

/// Canonical capture of every [`DeviceProfile`] field the scheduler's
/// cost model reads, in a form that hashes stably ([`key`]), serializes
/// ([`to_json`]/[`from_json`]), and compares scale-invariantly
/// ([`distance`]).
///
/// [`key`]: DeviceFingerprint::key
/// [`to_json`]: DeviceFingerprint::to_json
/// [`from_json`]: DeviceFingerprint::from_json
/// [`distance`]: DeviceFingerprint::distance
#[derive(Debug, Clone, PartialEq)]
pub struct DeviceFingerprint {
    pub name: String,
    pub n_big: usize,
    pub n_little: usize,
    pub big_gflops: f64,
    pub little_gflops: f64,
    pub disk_mbps: f64,
    pub mem_eff_gbps: f64,
    pub read_little_slowdown: f64,
    pub transform_little_slowdown: f64,
    /// GPU throughput when the device executes on a GPU; `None` for
    /// CPU-only devices. Presence participates in both identity and
    /// distance (see [`GPU_MISMATCH_PENALTY`]).
    pub gpu_gflops: Option<f64>,
}

impl DeviceFingerprint {
    /// Capture a device profile.
    pub fn of(dev: &DeviceProfile) -> DeviceFingerprint {
        DeviceFingerprint {
            name: dev.name.to_string(),
            n_big: dev.n_big,
            n_little: dev.n_little,
            big_gflops: dev.big_gflops,
            little_gflops: dev.little_gflops,
            disk_mbps: dev.disk_mbps,
            mem_eff_gbps: dev.mem_eff_gbps,
            read_little_slowdown: dev.read_little_slowdown,
            transform_little_slowdown: dev.transform_little_slowdown,
            gpu_gflops: dev.gpu.as_ref().map(|g| g.gflops),
        }
    }

    /// Capture a device by *measuring* the cost model instead of copying
    /// the profile's claims: each rate feature is derived from a
    /// deterministic micro-probe (a canonical conv executed, transformed,
    /// and read through [`CostModel`] on one core of each class), so the
    /// fingerprint reflects what the planner will actually be charged —
    /// including per-op overheads, utilization, and kernel-family speed
    /// factors the raw profile fields ignore. Two profiles that claim
    /// different numbers but cost identically land at distance 0; a
    /// profile field the cost model never reads cannot perturb the key.
    ///
    /// The probes are pure arithmetic over the profile (nothing is timed),
    /// so `measured` is a deterministic function of the device: every
    /// process of a fleet derives bit-identical keys, which is what lets
    /// a republished plan be its own distance-0 donor across processes.
    /// Any probe that degenerates (a class the device lacks, a kernel set
    /// with no transform) falls back per-feature to the static capture
    /// [`DeviceFingerprint::of`].
    pub fn measured(dev: &DeviceProfile) -> DeviceFingerprint {
        let stat = DeviceFingerprint::of(dev);
        let cm = CostModel::new(dev);
        let probe = probe_layer();
        let cands = Registry::full().candidates(&probe);
        // Deterministic kernel picks: the registry's candidate order is
        // static. Exec probes want any kernel; the memory probe needs one
        // that actually moves transformed bytes.
        let exec_kernel = cands.first();
        let tf_kernel = cands.iter().find(|k| k.family.needs_transform());

        let or_static = |measured: f64, fallback: f64| {
            if measured.is_finite() && measured > 0.0 { measured } else { fallback }
        };
        let flops = probe.flops() as f64;
        // Effective GFLOP/s of one core of `class` on the probe conv
        // (overheads and utilization included — that is the point).
        let exec_rate = |class: CoreClass| -> f64 {
            exec_kernel.map_or(0.0, |k| flops / cm.exec_ms(k, &probe, class, 1) / 1e6)
        };
        // Effective streaming rate of the transform stage on `class`.
        let tf_ms = |class: CoreClass| -> f64 {
            tf_kernel.map_or(0.0, |k| cm.transform_ms(k, &probe, class, 1))
        };
        let tf_big = tf_ms(CoreClass::Big);
        let tf_little = tf_ms(CoreClass::Little);
        let mem_gbps = tf_kernel.map_or(0.0, |k| {
            let moved = k.transformed_bytes(&probe) as f64 * k.family.transform_work();
            moved / 1e9 / (tf_big / 1e3)
        });
        let read_big = cm.read_ms(PROBE_READ_BYTES, CoreClass::Big, 1);
        let read_little = cm.read_ms(PROBE_READ_BYTES, CoreClass::Little, 1);

        DeviceFingerprint {
            name: stat.name,
            n_big: stat.n_big,
            n_little: stat.n_little,
            big_gflops: or_static(exec_rate(CoreClass::Big), stat.big_gflops),
            little_gflops: or_static(exec_rate(CoreClass::Little), stat.little_gflops),
            disk_mbps: or_static(
                (PROBE_READ_BYTES as f64 / 1e6) / (read_big / 1e3),
                stat.disk_mbps,
            ),
            mem_eff_gbps: or_static(mem_gbps, stat.mem_eff_gbps),
            read_little_slowdown: or_static(
                read_little / read_big,
                stat.read_little_slowdown,
            ),
            transform_little_slowdown: or_static(
                tf_little / tf_big,
                stat.transform_little_slowdown,
            ),
            gpu_gflops: dev.gpu.as_ref().map(|g| {
                or_static(exec_rate(CoreClass::Gpu), g.gflops)
            }),
        }
    }

    /// Stable identity key: FNV-1a over a canonical byte layout of every
    /// field (floats by bit pattern, so equal keys mean bit-equal
    /// profiles). This is the fleet store's artifact key — one slot per
    /// device per model scope.
    pub fn key(&self) -> u64 {
        let mut bytes = Vec::with_capacity(self.name.len() + 80);
        bytes.extend_from_slice(self.name.as_bytes());
        bytes.push(0x1f); // separator: name can't bleed into the numbers
        for v in [self.n_big as u64, self.n_little as u64] {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        for v in [
            self.big_gflops,
            self.little_gflops,
            self.disk_mbps,
            self.mem_eff_gbps,
            self.read_little_slowdown,
            self.transform_little_slowdown,
        ] {
            bytes.extend_from_slice(&v.to_bits().to_le_bytes());
        }
        match self.gpu_gflops {
            Some(g) => {
                bytes.push(1);
                bytes.extend_from_slice(&g.to_bits().to_le_bytes());
            }
            None => bytes.push(0),
        }
        fnv1a(&bytes)
    }

    /// Scale-invariant dissimilarity: the sum of |ln(a/b)| over the
    /// derived shape features of both devices, plus fixed penalties for
    /// presence mismatches (GPU vs CPU execution, a resource one side
    /// lacks entirely). Properties, by construction:
    ///
    /// * `d(a, a) == 0` and `d(a, b) == d(b, a)`;
    /// * multiplying *all* of one device's rates (`*_gflops`, `disk_mbps`,
    ///   `mem_eff_gbps`) by one constant leaves its distances unchanged —
    ///   the features are within-device ratios;
    /// * always finite, even against profiles with zero-valued fields
    ///   (jetson-nano's absent big cores): zero-vs-zero contributes 0,
    ///   zero-vs-positive a fixed [`ZERO_FEATURE_PENALTY`].
    ///
    /// The name deliberately does not participate: two identically-shaped
    /// profiles under different names are perfect donors for each other.
    pub fn distance(&self, other: &DeviceFingerprint) -> f64 {
        let mut d = 0.0;
        for (a, b) in [
            // Compute shape: how lopsided is big vs little, GPU vs CPU.
            (self.big_over_little(), other.big_over_little()),
            (self.gpu_over_little(), other.gpu_over_little()),
            // IO/memory shape: bytes moved per unit of little-core compute
            // — the §3.1 read/transform-vs-exec trade-off that decides
            // which kernels win cold.
            (self.disk_per_gflop(), other.disk_per_gflop()),
            (self.mem_per_gflop(), other.mem_per_gflop()),
            // The Fig. 6 little-core slowdowns are already ratios.
            (self.read_little_slowdown, other.read_little_slowdown),
            (self.transform_little_slowdown, other.transform_little_slowdown),
        ] {
            d += log_ratio(a, b);
        }
        // Core counts shape the pipelining (bundle round-robin width);
        // +1 keeps the log finite for zero-core classes.
        d += log_ratio((1 + self.n_big) as f64, (1 + other.n_big) as f64);
        d += log_ratio((1 + self.n_little) as f64, (1 + other.n_little) as f64);
        if self.gpu_gflops.is_some() != other.gpu_gflops.is_some() {
            d += GPU_MISMATCH_PENALTY;
        }
        d
    }

    fn big_over_little(&self) -> f64 {
        safe_ratio(self.big_gflops, self.little_gflops)
    }

    fn gpu_over_little(&self) -> f64 {
        safe_ratio(self.gpu_gflops.unwrap_or(0.0), self.little_gflops)
    }

    fn disk_per_gflop(&self) -> f64 {
        safe_ratio(self.disk_mbps, self.little_gflops)
    }

    fn mem_per_gflop(&self) -> f64 {
        safe_ratio(self.mem_eff_gbps, self.little_gflops)
    }

    /// Serialize for artifact payloads. The float round trip through
    /// [`Json`] is exact (shortest-roundtrip formatting), so
    /// `from_json(to_json()).key() == key()` bit-for-bit — the calibrated
    /// cache's view check depends on this.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::from(self.name.as_str())),
            ("n_big", Json::from(self.n_big)),
            ("n_little", Json::from(self.n_little)),
            ("big_gflops", Json::from(self.big_gflops)),
            ("little_gflops", Json::from(self.little_gflops)),
            ("disk_mbps", Json::from(self.disk_mbps)),
            ("mem_eff_gbps", Json::from(self.mem_eff_gbps)),
            ("read_little_slowdown", Json::from(self.read_little_slowdown)),
            ("transform_little_slowdown", Json::from(self.transform_little_slowdown)),
            (
                "gpu_gflops",
                self.gpu_gflops.map_or(Json::Null, Json::from),
            ),
        ])
    }

    /// Parse a fingerprint document; `None` for anything else — including
    /// the pre-fingerprint `{n_big, n_little}` device views old
    /// calibrated artifacts carry, which is how those heal.
    pub fn from_json(j: &Json) -> Option<DeviceFingerprint> {
        Some(DeviceFingerprint {
            name: j.get("name").as_str()?.to_string(),
            n_big: j.get("n_big").as_usize()?,
            n_little: j.get("n_little").as_usize()?,
            big_gflops: j.get("big_gflops").as_f64()?,
            little_gflops: j.get("little_gflops").as_f64()?,
            disk_mbps: j.get("disk_mbps").as_f64()?,
            mem_eff_gbps: j.get("mem_eff_gbps").as_f64()?,
            read_little_slowdown: j.get("read_little_slowdown").as_f64()?,
            transform_little_slowdown: j.get("transform_little_slowdown").as_f64()?,
            gpu_gflops: match j.get("gpu_gflops") {
                Json::Null => None,
                v => Some(v.as_f64()?),
            },
        })
    }
}

/// Bytes moved by [`DeviceFingerprint::measured`]'s disk probe — big
/// enough that the 4 KiB I/O floor is invisible.
const PROBE_READ_BYTES: u64 = 8 << 20;

/// The canonical probe workload for [`DeviceFingerprint::measured`]: a
/// mid-size k3 conv with a feature map large enough for full SIMD
/// utilization — representative of the layers whose kernel choices the
/// transferred plans actually carry.
fn probe_layer() -> Layer {
    Layer {
        id: 0,
        name: "fingerprint-probe".into(),
        op: OpKind::Conv { kernel: 3, stride: 1, groups: 1 },
        in_ch: 64,
        out_ch: 64,
        in_hw: 32,
        out_hw: 32,
        deps: vec![],
    }
}

/// `a / b` with non-finite and divide-by-zero cases collapsed to 0.0, so
/// every feature is a finite non-negative number and [`log_ratio`]'s
/// zero-handling covers all degenerate profiles.
fn safe_ratio(a: f64, b: f64) -> f64 {
    if a > 0.0 && b > 0.0 {
        let r = a / b;
        if r.is_finite() {
            r
        } else {
            0.0
        }
    } else {
        0.0
    }
}

/// |ln(a/b)| for positive pairs; 0 when both sides lack the feature; a
/// fixed [`ZERO_FEATURE_PENALTY`] when only one does.
fn log_ratio(a: f64, b: f64) -> f64 {
    if a > 0.0 && b > 0.0 {
        (a / b).ln().abs()
    } else if a <= 0.0 && b <= 0.0 {
        0.0
    } else {
        ZERO_FEATURE_PENALTY
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::profiles;

    fn all() -> Vec<DeviceFingerprint> {
        profiles::ALL_DEVICES
            .iter()
            .map(|n| DeviceFingerprint::of(&profiles::by_name(n).unwrap()))
            .collect()
    }

    #[test]
    fn identity_zero_symmetric_finite() {
        let fps = all();
        for a in &fps {
            assert_eq!(a.distance(a), 0.0, "{}: self-distance", a.name);
            for b in &fps {
                let d = a.distance(b);
                assert!(d.is_finite() && d >= 0.0, "{} vs {}: {d}", a.name, b.name);
                assert_eq!(d.to_bits(), b.distance(a).to_bits(), "symmetry");
                if a.name != b.name {
                    assert!(d > 0.0, "{} vs {} indistinguishable", a.name, b.name);
                }
            }
        }
    }

    #[test]
    fn distance_is_scale_invariant() {
        // A uniformly overclocked clone — every rate ×1.7 — is the same
        // *shape* of device: distance to the original stays 0, and its
        // distances to everything else match the original's exactly.
        let mut dev = profiles::pixel_5();
        let base = DeviceFingerprint::of(&dev);
        dev.big_gflops *= 1.7;
        dev.little_gflops *= 1.7;
        dev.disk_mbps *= 1.7;
        dev.mem_eff_gbps *= 1.7;
        let scaled = DeviceFingerprint::of(&dev);
        assert!(scaled.distance(&base) < 1e-12, "{}", scaled.distance(&base));
        for other in all() {
            let d0 = base.distance(&other);
            let d1 = scaled.distance(&other);
            assert!((d0 - d1).abs() < 1e-9, "{}: {d0} vs {d1}", other.name);
        }
        // But identity is exact: the clone is still a different device.
        assert_ne!(scaled.key(), base.key());
    }

    #[test]
    fn gpu_mismatch_dominates_over_cpu_similarity() {
        // Any CPU-only phone is at least the GPU penalty away from any
        // GPU device — transfer should prefer the other Jetson.
        let fps = all();
        for a in &fps {
            for b in &fps {
                if a.gpu_gflops.is_some() != b.gpu_gflops.is_some() {
                    assert!(
                        a.distance(b) >= GPU_MISMATCH_PENALTY,
                        "{} vs {}",
                        a.name,
                        b.name
                    );
                }
            }
        }
    }

    #[test]
    fn zero_fields_never_poison_the_metric() {
        // jetson-nano has no big CPU cores (n_big = 0, big_gflops = 0):
        // every distance involving it must still be finite and symmetric.
        let nano = DeviceFingerprint::of(&profiles::jetson_nano());
        assert_eq!(nano.distance(&nano), 0.0);
        for other in all() {
            let d = nano.distance(&other);
            assert!(d.is_finite(), "nano vs {}: {d}", other.name);
        }
    }

    #[test]
    fn json_roundtrip_preserves_identity() {
        for fp in all() {
            let back = DeviceFingerprint::from_json(&Json::parse(
                &fp.to_json().to_pretty(),
            )
            .unwrap())
            .unwrap();
            assert_eq!(back, fp);
            assert_eq!(back.key(), fp.key(), "{}: key must survive JSON", fp.name);
        }
        // The old ad-hoc device view is NOT a fingerprint.
        let old = Json::obj(vec![("n_big", Json::from(4usize)), ("n_little", Json::from(4usize))]);
        assert!(DeviceFingerprint::from_json(&old).is_none());
    }

    #[test]
    fn measured_is_deterministic_and_self_consistent() {
        // The probes are pure arithmetic, so two captures of the same
        // device — as in two fleet processes — agree bit-for-bit, and the
        // metric still sees them as identical devices.
        for name in profiles::ALL_DEVICES {
            let dev = profiles::by_name(name).unwrap();
            let a = DeviceFingerprint::measured(&dev);
            let b = DeviceFingerprint::measured(&dev);
            assert_eq!(a, b, "{name}");
            assert_eq!(a.key(), b.key(), "{name}: keys must replay");
            assert_eq!(a.distance(&b), 0.0, "{name}");
        }
    }

    #[test]
    fn measured_reflects_costs_not_claims() {
        // Effective rates include per-op overhead and kernel speed
        // factors, so a measured capture never collides with the static
        // one — the keyspaces are disjoint in practice, which is what the
        // transfer layer's legacy-artifact migration detects.
        let dev = profiles::meizu_16t();
        let m = DeviceFingerprint::measured(&dev);
        let s = DeviceFingerprint::of(&dev);
        assert_ne!(m.key(), s.key());
        // Overheads only ever slow the probe down relative to the
        // profile's peak rate claim.
        assert!(m.big_gflops < s.big_gflops, "{} vs {}", m.big_gflops, s.big_gflops);
        assert!(m.big_gflops > 0.0);
    }

    #[test]
    fn measured_survives_degenerate_devices() {
        // jetson-nano has no big cores: the big-class probes degenerate
        // and must fall back per-feature to the static capture instead of
        // poisoning the fingerprint with infinities.
        let nano = DeviceFingerprint::measured(&profiles::jetson_nano());
        let stat = DeviceFingerprint::of(&profiles::jetson_nano());
        assert_eq!(nano.big_gflops, stat.big_gflops, "fallback preserves zero");
        assert!(nano.little_gflops > 0.0 && nano.little_gflops.is_finite());
        assert!(nano.distance(&nano) == 0.0);
        for other in all() {
            assert!(nano.distance(&other).is_finite());
        }
        // GPU presence survives measurement.
        assert!(nano.gpu_gflops.is_some());
    }

    #[test]
    fn keys_are_distinct_across_the_fleet() {
        let fps = all();
        for (i, a) in fps.iter().enumerate() {
            for b in &fps[i + 1..] {
                assert_ne!(a.key(), b.key(), "{} vs {}", a.name, b.name);
            }
        }
    }
}
