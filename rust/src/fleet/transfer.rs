//! The cross-device plan-transfer path over the artifact store.
//!
//! Every planned (model, device) cell *publishes* its plan into the
//! store's [`Namespace::FleetPlan`] namespace — scoped by the
//! device-independent model fingerprint, keyed by the device's
//! [`DeviceFingerprint`] identity — so any later planner can enumerate
//! "every device's plan for this model" with one scope scan and no
//! manifest. A planner that misses its own plan looks up the
//! *nearest-profile* donor by fingerprint distance and runs the seeded
//! search ([`schedule_seeded`]) instead of a cold one: re-price the
//! donor's kernel choices on the target (exact 3-entry table patches),
//! keep them only if they beat the target's own greedy baseline, then a
//! single short descent pass over the transferred layers. A rejected
//! seed falls back to the full cold search, so transfer can change how
//! fast a plan is *found*, never how good the found plan is allowed to
//! be.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use crate::device::DeviceProfile;
use crate::fleet::DeviceFingerprint;
use crate::graph::ModelGraph;
use crate::kernels::Registry;
use crate::sched::cache::model_fingerprint;
use crate::sched::heuristic::{schedule_seeded, Scheduled, SchedulerConfig, TransferOutcome};
use crate::sched::plan::Plan;
use crate::store::{ArtifactStore, Namespace};
use crate::util::json::Json;

/// The donor a transfer drew from: which device's plan seeded the search,
/// and how far its profile was from the target's.
#[derive(Debug, Clone)]
pub struct Donor {
    pub device: String,
    pub distance: f64,
}

/// One [`PlanTransfer::plan`] result: the search outcome (seeded or cold)
/// plus where the seed came from, if anywhere.
#[derive(Debug, Clone)]
pub struct TransferResult {
    pub outcome: TransferOutcome,
    /// `None` when the fleet store held no usable plan for this model
    /// (first device of a family pays the cold search for everyone).
    pub donor: Option<Donor>,
}

/// Fleet-plan publish + nearest-profile lookup + seeded search, as one
/// shared handle (`Arc`-cheap, all counters atomic).
pub struct PlanTransfer {
    store: Arc<ArtifactStore>,
    /// Seeds accepted: the donor's choices revalidated no worse than the
    /// target's greedy baseline and seeded the search.
    hits: AtomicUsize,
    /// Seeds found but rejected at the accept gate (re-priced worse than
    /// the baseline): the search fell back to a full cold descent.
    rejected: AtomicUsize,
    /// Lookups that found no donor at all (empty scope).
    misses: AtomicUsize,
}

impl PlanTransfer {
    pub fn new(store: Arc<ArtifactStore>) -> PlanTransfer {
        PlanTransfer {
            store,
            hits: AtomicUsize::new(0),
            rejected: AtomicUsize::new(0),
            misses: AtomicUsize::new(0),
        }
    }

    /// The backing store.
    pub fn store(&self) -> &Arc<ArtifactStore> {
        &self.store
    }

    /// The fleet-plan scope of one planning problem: model name (for
    /// humans reading the store directory) + the device-independent
    /// fingerprint (for correctness — two configs or registries never
    /// share donors).
    fn scope(graph: &ModelGraph, cfg: &SchedulerConfig, registry_tag: &str) -> String {
        format!("{}-{:016x}", graph.name, model_fingerprint(graph, cfg, registry_tag))
    }

    /// Publish a device's plan for a model into the fleet namespace
    /// (best-effort, like every cache write-back: an unwritable store
    /// costs future devices a cold search, never correctness).
    pub fn publish(
        &self,
        dev: &DeviceProfile,
        graph: &ModelGraph,
        cfg: &SchedulerConfig,
        registry_tag: &str,
        scheduled: &Scheduled,
    ) {
        let fp = DeviceFingerprint::of(dev);
        let key = fp.key();
        let doc = Json::obj(vec![
            ("fingerprint", Json::from(format!("{key:016x}"))),
            ("device", fp.to_json()),
            ("model", Json::from(graph.name.as_str())),
            ("makespan_ms", Json::from(scheduled.schedule.makespan)),
            ("plan", scheduled.plan.to_json(graph)),
        ]);
        let scope = PlanTransfer::scope(graph, cfg, registry_tag);
        let _ = self
            .store
            .put_scoped(Namespace::FleetPlan, &scope, key, doc.to_pretty().as_bytes());
    }

    /// The nearest-profile donor plan for `dev`, if the fleet store holds
    /// any usable plan for this model. Candidates that fail validation
    /// (store header, fingerprint/key agreement, kernel resolution
    /// against `registry`) are skipped, not trusted. Ties on distance
    /// break by fingerprint key, so enumeration order never changes the
    /// answer. Note the target's *own* published plan (distance 0) is a
    /// legitimate donor: a second process re-planning the same device
    /// seeds from it and confirms bit-exactly.
    pub fn nearest_donor(
        &self,
        dev: &DeviceProfile,
        graph: &ModelGraph,
        registry: &Registry,
        cfg: &SchedulerConfig,
        registry_tag: &str,
    ) -> Option<(Donor, Plan)> {
        let fp = DeviceFingerprint::of(dev);
        let scope = PlanTransfer::scope(graph, cfg, registry_tag);
        let mut best: Option<(f64, u64, DeviceFingerprint, Plan)> = None;
        for key in self.store.keys_in_scope(Namespace::FleetPlan, &scope) {
            let Some(payload) = self.store.get_scoped(Namespace::FleetPlan, &scope, key) else {
                continue;
            };
            let Ok(text) = String::from_utf8(payload) else { continue };
            let Ok(doc) = Json::parse(&text) else { continue };
            if doc.get("fingerprint").as_str() != Some(format!("{key:016x}").as_str()) {
                continue;
            }
            let Some(dfp) = DeviceFingerprint::from_json(doc.get("device")) else {
                continue;
            };
            if dfp.key() != key {
                continue;
            }
            let Ok(plan) = Plan::from_json(doc.get("plan"), graph, registry) else {
                continue;
            };
            let d = fp.distance(&dfp);
            let closer = match &best {
                None => true,
                Some((bd, bk, _, _)) => d < *bd || (d == *bd && key < *bk),
            };
            if closer {
                best = Some((d, key, dfp, plan));
            }
        }
        best.map(|(d, _, dfp, plan)| (Donor { device: dfp.name, distance: d }, plan))
    }

    /// Plan (model, device) through the transfer path: nearest-donor
    /// lookup → seeded search (or cold search when no donor exists or the
    /// seed is rejected) → publish the result for the next device. The
    /// returned plan is always a confirmed plan for *this* device — at
    /// least as good as its greedy baseline, by [`schedule_seeded`]'s
    /// accept gate.
    pub fn plan(
        &self,
        dev: &DeviceProfile,
        graph: &ModelGraph,
        registry: &Registry,
        cfg: &SchedulerConfig,
        registry_tag: &str,
    ) -> TransferResult {
        let donor = self.nearest_donor(dev, graph, registry, cfg, registry_tag);
        let (outcome, donor) = match donor {
            Some((donor, plan)) => {
                let outcome = schedule_seeded(dev, graph, registry, cfg, &plan.choices);
                if outcome.seeded {
                    self.hits.fetch_add(1, Ordering::Relaxed);
                } else {
                    self.rejected.fetch_add(1, Ordering::Relaxed);
                }
                (outcome, Some(donor))
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                // An empty seed never maps (layer-count mismatch), so this
                // is exactly the cold search, with baseline/pass metrics.
                (schedule_seeded(dev, graph, registry, cfg, &[]), None)
            }
        };
        self.publish(dev, graph, cfg, registry_tag, &outcome.scheduled);
        TransferResult { outcome, donor }
    }

    /// Transfers accepted (seed beat or matched the greedy baseline).
    pub fn hits(&self) -> usize {
        self.hits.load(Ordering::Relaxed)
    }

    /// Seeds found but rejected at the accept gate.
    pub fn rejected(&self) -> usize {
        self.rejected.load(Ordering::Relaxed)
    }

    /// Lookups with no donor available.
    pub fn misses(&self) -> usize {
        self.misses.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::profiles;
    use crate::graph::zoo;
    use std::path::PathBuf;

    fn temp_store(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!(
            "nnv12-fleet-{tag}-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ))
    }

    #[test]
    fn first_plan_misses_then_self_transfer_hits() {
        let dir = temp_store("self");
        let _ = std::fs::remove_dir_all(&dir);
        let dev = profiles::meizu_16t();
        let g = zoo::squeezenet();
        let reg = Registry::full();
        let cfg = SchedulerConfig::kcp();

        let t = PlanTransfer::new(Arc::new(ArtifactStore::open(&dir).unwrap()));
        let first = t.plan(&dev, &g, &reg, &cfg, "full");
        assert!(first.donor.is_none(), "empty store has no donor");
        assert!(!first.outcome.seeded);
        assert_eq!((t.hits(), t.misses()), (0, 1));

        // A second process over the same store: its own published plan is
        // the distance-0 donor and must be accepted (seed == stored plan
        // revalidates to exactly its stored makespan ≤ baseline).
        let t2 = PlanTransfer::new(Arc::new(ArtifactStore::open(&dir).unwrap()));
        let second = t2.plan(&dev, &g, &reg, &cfg, "full");
        let donor = second.donor.expect("published plan must be found");
        assert_eq!(donor.device, dev.name);
        assert_eq!(donor.distance, 0.0);
        assert!(second.outcome.seeded, "distance-0 seed must be accepted");
        assert_eq!((t2.hits(), t2.misses()), (1, 0));
        assert_eq!(
            second.outcome.scheduled.schedule.makespan.to_bits(),
            first.outcome.scheduled.schedule.makespan.to_bits(),
            "self-transfer reproduces the stored plan's quality exactly"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn donor_selection_prefers_nearer_profiles() {
        let dir = temp_store("nearest");
        let _ = std::fs::remove_dir_all(&dir);
        let g = zoo::squeezenet();
        let reg = Registry::full();
        let cfg = SchedulerConfig::kcp();
        let t = PlanTransfer::new(Arc::new(ArtifactStore::open(&dir).unwrap()));

        // Publish plans for one CPU phone and one GPU board.
        for dev in [profiles::meizu_16t(), profiles::jetson_tx2()] {
            let r = t.plan(&dev, &g, &reg, &cfg, "full");
            assert!(
                r.outcome.scheduled.schedule.makespan.is_finite(),
                "{}",
                dev.name
            );
        }
        // A CPU phone must draw from the CPU donor, a GPU board from the
        // GPU donor — the GPU-mismatch penalty dominates the metric.
        let (donor, _) = t
            .nearest_donor(&profiles::pixel_5(), &g, &reg, &cfg, "full")
            .expect("donors exist");
        assert_eq!(donor.device, "meizu16t");
        let (donor, _) = t
            .nearest_donor(&profiles::jetson_nano(), &g, &reg, &cfg, "full")
            .expect("donors exist");
        assert_eq!(donor.device, "jetson-tx2");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn scopes_isolate_models_and_configs() {
        let dir = temp_store("scopes");
        let _ = std::fs::remove_dir_all(&dir);
        let dev = profiles::meizu_16t();
        let reg = Registry::full();
        let t = PlanTransfer::new(Arc::new(ArtifactStore::open(&dir).unwrap()));
        t.plan(&dev, &zoo::squeezenet(), &reg, &SchedulerConfig::kcp(), "full");
        // Different model: no donor.
        assert!(t
            .nearest_donor(&dev, &zoo::tiny_net(), &reg, &SchedulerConfig::kcp(), "full")
            .is_none());
        // Same model, different config: no donor either (a no-pipeline
        // plan must never seed a pipelined search's store scope).
        assert!(t
            .nearest_donor(&dev, &zoo::squeezenet(), &reg, &SchedulerConfig::kc(), "full")
            .is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
