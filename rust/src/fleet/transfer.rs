//! The cross-device plan-transfer path over the artifact store.
//!
//! Every planned (model, device) cell *publishes* its plan into the
//! store's [`Namespace::FleetPlan`] namespace — scoped by the
//! device-independent model fingerprint, keyed by the device's
//! [`DeviceFingerprint`] identity — so any later planner can enumerate
//! "every device's plan for this model" with one scope scan and no
//! manifest. A planner that misses its own plan looks up the
//! *nearest-profile* donor by fingerprint distance and runs the seeded
//! search ([`schedule_seeded`]) instead of a cold one: re-price the
//! donor's kernel choices on the target (exact 3-entry table patches),
//! keep them only if they beat the target's own greedy baseline, then a
//! single short descent pass over the transferred layers. A rejected
//! seed falls back to the full cold search, so transfer can change how
//! fast a plan is *found*, never how good the found plan is allowed to
//! be.
//!
//! Devices are identified by their **measured** fingerprint
//! ([`DeviceFingerprint::measured`]): deterministic cost-model
//! micro-probes, so the key captures what the planner is charged rather
//! than what the profile claims. Fleet artifacts published by older
//! versions under the static capture ([`DeviceFingerprint::of`]) are
//! migrated by a **one-time revalidate-and-heal** pass over each scope
//! on first touch ([`PlanTransfer::heal_scope`]): corrupt or
//! unresolvable entries are removed (the next publish repairs them),
//! and legacy static-keyed entries of known device profiles are
//! re-keyed in place — so a fleet store survives the fingerprint
//! upgrade without losing a single usable plan.

use std::collections::HashSet;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::device::DeviceProfile;
use crate::fleet::DeviceFingerprint;
use crate::graph::ModelGraph;
use crate::kernels::Registry;
use crate::sched::cache::model_fingerprint;
use crate::sched::heuristic::{schedule_seeded, Scheduled, SchedulerConfig, TransferOutcome};
use crate::sched::plan::Plan;
use crate::store::{ArtifactStore, Namespace};
use crate::util::json::Json;

/// The donor a transfer drew from: which device's plan seeded the search,
/// and how far its profile was from the target's.
#[derive(Debug, Clone)]
pub struct Donor {
    pub device: String,
    pub distance: f64,
}

/// One [`PlanTransfer::plan`] result: the search outcome (seeded or cold)
/// plus where the seed came from, if anywhere.
#[derive(Debug, Clone)]
pub struct TransferResult {
    pub outcome: TransferOutcome,
    /// `None` when the fleet store held no usable plan for this model
    /// (first device of a family pays the cold search for everyone).
    pub donor: Option<Donor>,
}

/// What one [`PlanTransfer::heal_scope`] pass did to a fleet-plan scope.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct HealReport {
    /// Valid artifacts left untouched.
    pub kept: usize,
    /// Legacy static-fingerprint artifacts re-keyed to the measured
    /// fingerprint of their (known) device profile.
    pub migrated: usize,
    /// Corrupt, unparseable, or unresolvable artifacts removed.
    pub removed: usize,
}

/// Fleet-plan publish + nearest-profile lookup + seeded search, as one
/// shared handle (`Arc`-cheap, all counters atomic).
pub struct PlanTransfer {
    store: Arc<ArtifactStore>,
    /// Seeds accepted: the donor's choices revalidated no worse than the
    /// target's greedy baseline and seeded the search.
    hits: AtomicUsize,
    /// Seeds found but rejected at the accept gate (re-priced worse than
    /// the baseline): the search fell back to a full cold descent.
    rejected: AtomicUsize,
    /// Lookups that found no donor at all (empty scope).
    misses: AtomicUsize,
    /// Legacy artifacts re-keyed by heal passes (see [`HealReport`]).
    healed_migrated: AtomicUsize,
    /// Broken artifacts removed by heal passes.
    healed_removed: AtomicUsize,
    /// Scopes already revalidated by this handle — the heal is one-time
    /// per scope, not per lookup.
    healed_scopes: Mutex<HashSet<String>>,
}

impl PlanTransfer {
    pub fn new(store: Arc<ArtifactStore>) -> PlanTransfer {
        PlanTransfer {
            store,
            hits: AtomicUsize::new(0),
            rejected: AtomicUsize::new(0),
            misses: AtomicUsize::new(0),
            healed_migrated: AtomicUsize::new(0),
            healed_removed: AtomicUsize::new(0),
            healed_scopes: Mutex::new(HashSet::new()),
        }
    }

    /// The backing store.
    pub fn store(&self) -> &Arc<ArtifactStore> {
        &self.store
    }

    /// The fleet-plan scope of one planning problem: model name (for
    /// humans reading the store directory) + the device-independent
    /// fingerprint (for correctness — two configs or registries never
    /// share donors).
    fn scope(graph: &ModelGraph, cfg: &SchedulerConfig, registry_tag: &str) -> String {
        format!("{}-{:016x}", graph.name, model_fingerprint(graph, cfg, registry_tag))
    }

    /// The canonical fleet-plan artifact document.
    fn doc(fp: &DeviceFingerprint, model: &str, makespan_ms: Json, plan: Json) -> Json {
        Json::obj(vec![
            ("fingerprint", Json::from(format!("{:016x}", fp.key()))),
            ("device", fp.to_json()),
            ("model", Json::from(model)),
            ("makespan_ms", makespan_ms),
            ("plan", plan),
        ])
    }

    /// Publish a device's plan for a model into the fleet namespace
    /// (best-effort, like every cache write-back: an unwritable store
    /// costs future devices a cold search, never correctness).
    pub fn publish(
        &self,
        dev: &DeviceProfile,
        graph: &ModelGraph,
        cfg: &SchedulerConfig,
        registry_tag: &str,
        scheduled: &Scheduled,
    ) {
        let fp = DeviceFingerprint::measured(dev);
        let doc = PlanTransfer::doc(
            &fp,
            &graph.name,
            Json::from(scheduled.schedule.makespan),
            scheduled.plan.to_json(graph),
        );
        let scope = PlanTransfer::scope(graph, cfg, registry_tag);
        let _ = self
            .store
            .put_scoped(Namespace::FleetPlan, &scope, fp.key(), doc.to_pretty().as_bytes());
    }

    /// One-time revalidate-and-heal of a fleet-plan scope: every artifact
    /// is re-read and re-validated; entries that fail (corrupt payloads,
    /// fingerprint/key disagreement, plans that no longer resolve against
    /// `registry`) are **removed** — the next publish repairs them — and
    /// valid entries still keyed by the legacy *static* fingerprint of a
    /// known device profile are **re-keyed** to that device's measured
    /// fingerprint, payload intact. Lookups already skip invalid
    /// candidates, so healing never changes which donor wins; it keeps
    /// the scope scan from paying for dead entries forever and lets
    /// pre-upgrade plans keep seeding at distance 0.
    pub fn heal_scope(
        &self,
        graph: &ModelGraph,
        registry: &Registry,
        cfg: &SchedulerConfig,
        registry_tag: &str,
    ) -> HealReport {
        let scope = PlanTransfer::scope(graph, cfg, registry_tag);
        let mut report = HealReport::default();
        for key in self.store.keys_in_scope(Namespace::FleetPlan, &scope) {
            let parsed = self
                .store
                .get_scoped(Namespace::FleetPlan, &scope, key)
                .and_then(|p| String::from_utf8(p).ok())
                .and_then(|t| Json::parse(&t).ok());
            let valid = parsed.as_ref().is_some_and(|doc| {
                doc.get("fingerprint").as_str() == Some(format!("{key:016x}").as_str())
                    && DeviceFingerprint::from_json(doc.get("device"))
                        .is_some_and(|dfp| dfp.key() == key)
                    && Plan::from_json(doc.get("plan"), graph, registry).is_ok()
            });
            if !valid {
                self.store.remove_scoped(Namespace::FleetPlan, &scope, key);
                report.removed += 1;
                continue;
            }
            let doc = parsed.expect("validated above");
            // Legacy entry: keyed by the static capture of a profile this
            // build knows. Re-key it to the measured fingerprint.
            let legacy = DeviceFingerprint::from_json(doc.get("device"))
                .and_then(|dfp| crate::device::profiles::by_name(&dfp.name))
                .filter(|dev| DeviceFingerprint::of(dev).key() == key)
                .map(|dev| DeviceFingerprint::measured(&dev))
                .filter(|mfp| mfp.key() != key);
            let Some(mfp) = legacy else {
                report.kept += 1;
                continue;
            };
            let healed = PlanTransfer::doc(
                &mfp,
                doc.get("model").as_str().unwrap_or(&graph.name),
                doc.get("makespan_ms").clone(),
                doc.get("plan").clone(),
            );
            match self.store.put_scoped(
                Namespace::FleetPlan,
                &scope,
                mfp.key(),
                healed.to_pretty().as_bytes(),
            ) {
                Ok(()) => {
                    self.store.remove_scoped(Namespace::FleetPlan, &scope, key);
                    report.migrated += 1;
                }
                // Unwritable store: leave the legacy entry — it is still
                // a valid (if farther) donor.
                Err(_) => report.kept += 1,
            }
        }
        self.healed_migrated.fetch_add(report.migrated, Ordering::Relaxed);
        self.healed_removed.fetch_add(report.removed, Ordering::Relaxed);
        report
    }

    /// Run [`PlanTransfer::heal_scope`] exactly once per scope per handle.
    fn heal_scope_once(
        &self,
        graph: &ModelGraph,
        registry: &Registry,
        cfg: &SchedulerConfig,
        registry_tag: &str,
    ) {
        let scope = PlanTransfer::scope(graph, cfg, registry_tag);
        if self.healed_scopes.lock().expect("heal set poisoned").insert(scope) {
            self.heal_scope(graph, registry, cfg, registry_tag);
        }
    }

    /// The nearest-profile donor plan for `dev`, if the fleet store holds
    /// any usable plan for this model. Candidates that fail validation
    /// (store header, fingerprint/key agreement, kernel resolution
    /// against `registry`) are skipped, not trusted. Ties on distance
    /// break by fingerprint key, so enumeration order never changes the
    /// answer. Note the target's *own* published plan (distance 0) is a
    /// legitimate donor: a second process re-planning the same device
    /// derives the same measured fingerprint, seeds from it, and confirms
    /// bit-exactly.
    pub fn nearest_donor(
        &self,
        dev: &DeviceProfile,
        graph: &ModelGraph,
        registry: &Registry,
        cfg: &SchedulerConfig,
        registry_tag: &str,
    ) -> Option<(Donor, Plan)> {
        let fp = DeviceFingerprint::measured(dev);
        let scope = PlanTransfer::scope(graph, cfg, registry_tag);
        let mut best: Option<(f64, u64, DeviceFingerprint, Plan)> = None;
        for key in self.store.keys_in_scope(Namespace::FleetPlan, &scope) {
            let Some(payload) = self.store.get_scoped(Namespace::FleetPlan, &scope, key) else {
                continue;
            };
            let Ok(text) = String::from_utf8(payload) else { continue };
            let Ok(doc) = Json::parse(&text) else { continue };
            if doc.get("fingerprint").as_str() != Some(format!("{key:016x}").as_str()) {
                continue;
            }
            let Some(dfp) = DeviceFingerprint::from_json(doc.get("device")) else {
                continue;
            };
            if dfp.key() != key {
                continue;
            }
            let Ok(plan) = Plan::from_json(doc.get("plan"), graph, registry) else {
                continue;
            };
            let d = fp.distance(&dfp);
            let closer = match &best {
                None => true,
                Some((bd, bk, _, _)) => d < *bd || (d == *bd && key < *bk),
            };
            if closer {
                best = Some((d, key, dfp, plan));
            }
        }
        best.map(|(d, _, dfp, plan)| (Donor { device: dfp.name, distance: d }, plan))
    }

    /// Plan (model, device) through the transfer path: nearest-donor
    /// lookup → seeded search (or cold search when no donor exists or the
    /// seed is rejected) → publish the result for the next device. The
    /// returned plan is always a confirmed plan for *this* device — at
    /// least as good as its greedy baseline, by [`schedule_seeded`]'s
    /// accept gate.
    pub fn plan(
        &self,
        dev: &DeviceProfile,
        graph: &ModelGraph,
        registry: &Registry,
        cfg: &SchedulerConfig,
        registry_tag: &str,
    ) -> TransferResult {
        self.heal_scope_once(graph, registry, cfg, registry_tag);
        let donor = self.nearest_donor(dev, graph, registry, cfg, registry_tag);
        let (outcome, donor) = match donor {
            Some((donor, plan)) => {
                let outcome = schedule_seeded(dev, graph, registry, cfg, &plan.choices);
                if outcome.seeded {
                    self.hits.fetch_add(1, Ordering::Relaxed);
                } else {
                    self.rejected.fetch_add(1, Ordering::Relaxed);
                }
                (outcome, Some(donor))
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                // An empty seed never maps (layer-count mismatch), so this
                // is exactly the cold search, with baseline/pass metrics.
                (schedule_seeded(dev, graph, registry, cfg, &[]), None)
            }
        };
        self.publish(dev, graph, cfg, registry_tag, &outcome.scheduled);
        TransferResult { outcome, donor }
    }

    /// Transfers accepted (seed beat or matched the greedy baseline).
    pub fn hits(&self) -> usize {
        self.hits.load(Ordering::Relaxed)
    }

    /// Seeds found but rejected at the accept gate.
    pub fn rejected(&self) -> usize {
        self.rejected.load(Ordering::Relaxed)
    }

    /// Lookups with no donor available.
    pub fn misses(&self) -> usize {
        self.misses.load(Ordering::Relaxed)
    }

    /// Legacy artifacts re-keyed across every heal pass of this handle.
    pub fn healed_migrated(&self) -> usize {
        self.healed_migrated.load(Ordering::Relaxed)
    }

    /// Broken artifacts removed across every heal pass of this handle.
    pub fn healed_removed(&self) -> usize {
        self.healed_removed.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::profiles;
    use crate::graph::zoo;
    use std::path::PathBuf;

    fn temp_store(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!(
            "nnv12-fleet-{tag}-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ))
    }

    #[test]
    fn first_plan_misses_then_self_transfer_hits() {
        let dir = temp_store("self");
        let _ = std::fs::remove_dir_all(&dir);
        let dev = profiles::meizu_16t();
        let g = zoo::squeezenet();
        let reg = Registry::full();
        let cfg = SchedulerConfig::kcp();

        let t = PlanTransfer::new(Arc::new(ArtifactStore::open(&dir).unwrap()));
        let first = t.plan(&dev, &g, &reg, &cfg, "full");
        assert!(first.donor.is_none(), "empty store has no donor");
        assert!(!first.outcome.seeded);
        assert_eq!((t.hits(), t.misses()), (0, 1));

        // A second process over the same store: its own published plan is
        // the distance-0 donor and must be accepted (seed == stored plan
        // revalidates to exactly its stored makespan ≤ baseline).
        let t2 = PlanTransfer::new(Arc::new(ArtifactStore::open(&dir).unwrap()));
        let second = t2.plan(&dev, &g, &reg, &cfg, "full");
        let donor = second.donor.expect("published plan must be found");
        assert_eq!(donor.device, dev.name);
        assert_eq!(donor.distance, 0.0);
        assert!(second.outcome.seeded, "distance-0 seed must be accepted");
        assert_eq!((t2.hits(), t2.misses()), (1, 0));
        assert_eq!(
            second.outcome.scheduled.schedule.makespan.to_bits(),
            first.outcome.scheduled.schedule.makespan.to_bits(),
            "self-transfer reproduces the stored plan's quality exactly"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn donor_selection_prefers_nearer_profiles() {
        let dir = temp_store("nearest");
        let _ = std::fs::remove_dir_all(&dir);
        let g = zoo::squeezenet();
        let reg = Registry::full();
        let cfg = SchedulerConfig::kcp();
        let t = PlanTransfer::new(Arc::new(ArtifactStore::open(&dir).unwrap()));

        // Publish plans for one CPU phone and one GPU board.
        for dev in [profiles::meizu_16t(), profiles::jetson_tx2()] {
            let r = t.plan(&dev, &g, &reg, &cfg, "full");
            assert!(
                r.outcome.scheduled.schedule.makespan.is_finite(),
                "{}",
                dev.name
            );
        }
        // A CPU phone must draw from the CPU donor, a GPU board from the
        // GPU donor — the GPU-mismatch penalty dominates the metric.
        let (donor, _) = t
            .nearest_donor(&profiles::pixel_5(), &g, &reg, &cfg, "full")
            .expect("donors exist");
        assert_eq!(donor.device, "meizu16t");
        let (donor, _) = t
            .nearest_donor(&profiles::jetson_nano(), &g, &reg, &cfg, "full")
            .expect("donors exist");
        assert_eq!(donor.device, "jetson-tx2");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn heal_migrates_legacy_static_keys_and_removes_corruption() {
        let dir = temp_store("heal");
        let _ = std::fs::remove_dir_all(&dir);
        let dev = profiles::meizu_16t();
        let g = zoo::squeezenet();
        let reg = Registry::full();
        let cfg = SchedulerConfig::kcp();
        let store = Arc::new(ArtifactStore::open(&dir).unwrap());
        let t = PlanTransfer::new(store.clone());
        let scope = PlanTransfer::scope(&g, &cfg, "full");

        // Author a pre-upgrade artifact by hand: a real plan, published
        // under the *static* fingerprint key exactly as older versions
        // did — plus one artifact that was never valid JSON.
        let searched = schedule_seeded(&dev, &g, &reg, &cfg, &[]).scheduled;
        let legacy = DeviceFingerprint::of(&dev);
        let doc = PlanTransfer::doc(
            &legacy,
            &g.name,
            Json::from(searched.schedule.makespan),
            searched.plan.to_json(&g),
        );
        store
            .put_scoped(Namespace::FleetPlan, &scope, legacy.key(), doc.to_pretty().as_bytes())
            .unwrap();
        store
            .put_scoped(Namespace::FleetPlan, &scope, 0xDEAD, b"not a fleet plan")
            .unwrap();

        let r = t.heal_scope(&g, &reg, &cfg, "full");
        assert_eq!(r, HealReport { kept: 0, migrated: 1, removed: 1 }, "{r:?}");
        assert_eq!((t.healed_migrated(), t.healed_removed()), (1, 1));
        let measured = DeviceFingerprint::measured(&dev);
        assert_eq!(
            store.keys_in_scope(Namespace::FleetPlan, &scope),
            vec![measured.key()],
            "only the re-keyed artifact survives"
        );

        // The migrated plan is this device's distance-0 donor, payload
        // intact.
        let (donor, plan) = t
            .nearest_donor(&dev, &g, &reg, &cfg, "full")
            .expect("migrated plan must be found");
        assert_eq!(donor.device, dev.name);
        assert_eq!(donor.distance, 0.0);
        assert_eq!(
            plan.to_json(&g).to_pretty(),
            searched.plan.to_json(&g).to_pretty(),
            "healing must not alter the plan payload"
        );

        // Healing is idempotent: a second pass finds a clean scope.
        let again = t.heal_scope(&g, &reg, &cfg, "full");
        assert_eq!(again, HealReport { kept: 1, migrated: 0, removed: 0 }, "{again:?}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn plan_heals_its_scope_once_before_looking_up() {
        let dir = temp_store("heal-once");
        let _ = std::fs::remove_dir_all(&dir);
        let dev = profiles::meizu_16t();
        let g = zoo::tiny_net();
        let reg = Registry::full();
        let cfg = SchedulerConfig::kcp();
        let store = Arc::new(ArtifactStore::open(&dir).unwrap());
        let scope = PlanTransfer::scope(&g, &cfg, "full");
        store
            .put_scoped(Namespace::FleetPlan, &scope, 0xBAD, b"torn")
            .unwrap();

        let t = PlanTransfer::new(store.clone());
        let first = t.plan(&dev, &g, &reg, &cfg, "full");
        assert!(first.donor.is_none(), "the broken entry must not become a donor");
        assert_eq!(t.healed_removed(), 1, "plan() heals on first touch");
        // Re-planning the same scope does not re-scan: the one-time set
        // swallows the second pass (the counter stays put even though the
        // scope now holds this device's published plan).
        t.plan(&dev, &g, &reg, &cfg, "full");
        assert_eq!(t.healed_removed(), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn scopes_isolate_models_and_configs() {
        let dir = temp_store("scopes");
        let _ = std::fs::remove_dir_all(&dir);
        let dev = profiles::meizu_16t();
        let reg = Registry::full();
        let t = PlanTransfer::new(Arc::new(ArtifactStore::open(&dir).unwrap()));
        t.plan(&dev, &zoo::squeezenet(), &reg, &SchedulerConfig::kcp(), "full");
        // Different model: no donor.
        assert!(t
            .nearest_donor(&dev, &zoo::tiny_net(), &reg, &SchedulerConfig::kcp(), "full")
            .is_none());
        // Same model, different config: no donor either (a no-pipeline
        // plan must never seed a pipelined search's store scope).
        assert!(t
            .nearest_donor(&dev, &zoo::squeezenet(), &reg, &SchedulerConfig::kc(), "full")
            .is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
