//! `repro` — the NNV12 coordinator CLI, built on the [`nnv12::engine`]
//! facade.
//!
//! Subcommands:
//!   plan      — generate + print a kernel scheduling plan for a model
//!   simulate  — run a plan through the device simulator (Gantt + stats)
//!   report    — regenerate a paper table/figure (or `all`)
//!   kernels   — list kernel candidates for a conv configuration
//!   serve     — run the multi-tenant serving workload (simulated device)
//!   fleet     — plan a model zoo across the device fleet with
//!               cross-device plan transfer; print the coverage report
//!   cold      — real-mode cold inference over PJRT artifacts
//!               (needs the `real-runtime` feature, on by default)
//!   devices   — list device profiles
//!
//! Examples:
//!   repro plan --model resnet50 --device meizu16t --store plans/
//!   repro report fig8
//!   repro cold --artifacts artifacts/tinynet --workers 2 --cache
//!   repro serve --device meizu16t --requests 200 --budget-mb 48 --threads 4 --execute
//!   repro serve --models 1000 --tenants 4 --requests 5000 --budget-mb 16
//!   repro fleet --models squeezenet,mobilenetv2 --store plans/ --report out/

use anyhow::{anyhow, bail, Result};

use nnv12::device::profiles;
use nnv12::engine::{Engine, SimBackend};
use nnv12::fleet::FleetPlanner;
use nnv12::graph::zoo;
use nnv12::kernels::Registry;
use nnv12::report;
use nnv12::sched::heuristic::SchedulerConfig;
use nnv12::serving::{generate, Router, RouterConfig, WorkloadSpec};
use nnv12::sim::{trace, SimConfig};
use nnv12::util::cli::Args;

fn main() {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let args = match Args::parse(&raw, &["cache", "no-pipeline", "sequential", "verbose", "execute", "offload"]) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    if let Err(e) = run(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(args: &Args) -> Result<()> {
    match args.cmd.as_str() {
        "plan" => cmd_plan(args),
        "simulate" => cmd_simulate(args),
        "report" => cmd_report(args),
        "kernels" => cmd_kernels(args),
        "serve" => cmd_serve(args),
        "fleet" => cmd_fleet(args),
        "cold" => cmd_cold(args),
        "store" => cmd_store(args),
        "devices" => cmd_devices(),
        "" | "help" => {
            print_help();
            Ok(())
        }
        other => bail!("unknown subcommand '{other}' (try 'repro help')"),
    }
}

fn print_help() {
    println!(
        "repro — NNV12 cold-inference engine (MobiSys'23 reproduction)\n\
         \n\
         subcommands:\n\
           plan      --model M --device D [--no-pipeline] [--store DIR [--store-cap-mb N]]  print a scheduling plan\n\
           simulate  --model M --device D [--bg-little U]   simulate with contention\n\
           report    <fig2|table1|table2|fig6|fig8|fig9|fig10|fig11|fig12|fig13|fig14|table4|table5|fleet|exits|all>\n\
           kernels   --k K --s S --in C --out C             list conv kernel candidates\n\
           serve     --device D --requests N --budget-mb B [--threads T] [--execute]\n\
                     [--deadline-ms D] [--admission N] [--queue N] [--offload] [--faults SEED]\n\
                     [--models N] [--tenants K]\n\
                     multi-tenant serving sim (--offload adds a multi-exit model + remote tail\n\
                     offload; --models N swaps in the synthetic N-model fleet; --tenants K\n\
                     partitions budget + models across K tenants and prints per-tenant outcomes)\n\
           fleet     [--models A,B,..] [--devices D,E,.. | all] [--no-pipeline]\n\
                     [--store DIR] [--report DIR]   zoo x fleet planning with cross-device transfer\n\
           cold      --artifacts DIR [--cache | --store DIR] [--workers N] [--mbps X] [--sequential]\n\
           store     gc --dir DIR [--days N]                drop artifacts untouched for N days\n\
           store     fsck --dir DIR                         audit artifacts; exit 1 on corruption\n\
           devices                                          list device profiles"
    );
}

fn device_of(args: &Args) -> Result<nnv12::device::DeviceProfile> {
    let name = args.get_or("device", "meizu16t");
    profiles::by_name(name).ok_or_else(|| anyhow!("unknown device '{name}'"))
}

fn model_of(args: &Args) -> Result<nnv12::graph::ModelGraph> {
    let name = args.get_or("model", "resnet50");
    zoo::by_name(name).ok_or_else(|| anyhow!("unknown model '{name}'"))
}

/// Engine for one CLI invocation; `--store DIR` persists artifacts
/// (plans, calibrated plans, transformed weights) across invocations
/// through the content-addressed store, so a second `repro plan` of the
/// same problem skips the search. `--store-cap-mb N` bounds the store,
/// evicting least-recently-used artifacts past the cap.
fn engine_of(args: &Args, cfg: SchedulerConfig) -> Result<Engine> {
    let mut b = Engine::builder().device(device_of(args)?).sched(cfg);
    if let Some(dir) = args.get("store") {
        b = b.artifact_store(dir);
        let cap_mb = args.get_usize("store-cap-mb", 0).map_err(|e| anyhow!(e))?;
        if cap_mb > 0 {
            b = b.store_cap_bytes((cap_mb as u64) << 20);
        }
    }
    b.try_build()
        .map_err(|e| anyhow!("cannot open artifact store: {e}"))
}

fn cmd_plan(args: &Args) -> Result<()> {
    let cfg = SchedulerConfig {
        pipeline: !args.has("no-pipeline"),
        ..SchedulerConfig::default()
    };
    let engine = engine_of(args, cfg)?;
    let t = nnv12::metrics::Timer::start();
    let session = engine.load(model_of(args)?);
    let s = session.scheduled();
    println!(
        "model={} device={} layers={} plan generated in {:.1} ms{}",
        session.name(),
        engine.device().name,
        session.graph().len(),
        t.elapsed_ms(),
        if engine.plan_cache().disk_hits() > 0 { " (plan-store hit)" } else { "" }
    );
    println!(
        "estimated cold latency: {:.2} ms (cache storage {}, warm {:.2} ms)",
        s.schedule.makespan,
        nnv12::util::table::fmt_bytes(session.plan().cache_bytes(session.graph())),
        session.warm_ms()
    );
    if let Some(stats) = engine.store_stats() {
        println!(
            "artifact store: {} hits, {} misses, {} evictions, {} rejected, {} used",
            stats.hits,
            stats.misses,
            stats.evictions,
            stats.rejected,
            nnv12::util::table::fmt_bytes(stats.bytes_used)
        );
    }
    if args.has("verbose") {
        println!("{}", session.plan().to_json(session.graph()).to_pretty());
    }
    println!("{}", trace::gantt(&s.set, &s.schedule.timings, 100));
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let bg_u = args.get_f64("bg-little", 0.0).map_err(|e| anyhow!(e))?;
    let mut sim_cfg = SimConfig::nnv12();
    if bg_u > 0.0 {
        sim_cfg.background = vec![
            nnv12::sim::BgLoad { unit: nnv12::sched::plan::UnitId::Little(0), utilization: bg_u },
            nnv12::sim::BgLoad { unit: nnv12::sched::plan::UnitId::Little(1), utilization: bg_u },
        ];
    }
    let engine = Engine::builder()
        .device(device_of(args)?)
        .backend(SimBackend::with(sim_cfg))
        .build();
    let session = engine.load(model_of(args)?);
    let r = session
        .run_cold()
        .map_err(|e| anyhow!("simulation failed: {e}"))?;
    println!(
        "simulated cold latency: {:.2} ms (steals={}, energy={:.0} mJ)",
        r.latency_ms, r.steals, r.energy_mj
    );
    println!("{}", trace::gantt(&session.scheduled().set, &r.timings, 100));
    Ok(())
}

fn cmd_report(args: &Args) -> Result<()> {
    let which = args
        .positional
        .first()
        .map(String::as_str)
        .unwrap_or("all");
    if which == "all" {
        for name in report::ALL_REPORTS {
            println!("{}", report::by_name(name).unwrap().render());
        }
        return Ok(());
    }
    let t = report::by_name(which)
        .ok_or_else(|| anyhow!("unknown report '{which}' (see 'repro help')"))?;
    println!("{}", t.render());
    Ok(())
}

fn cmd_kernels(args: &Args) -> Result<()> {
    let k = args.get_usize("k", 3).map_err(|e| anyhow!(e))? as u32;
    let s = args.get_usize("s", 1).map_err(|e| anyhow!(e))? as u32;
    let cin = args.get_usize("in", 64).map_err(|e| anyhow!(e))? as u32;
    let cout = args.get_usize("out", 64).map_err(|e| anyhow!(e))? as u32;
    let layer = nnv12::graph::Layer {
        id: 0,
        name: "query".into(),
        op: nnv12::graph::OpKind::Conv { kernel: k, stride: s, groups: 1 },
        in_ch: cin,
        out_ch: cout,
        in_hw: 56,
        out_hw: 56 / s.max(1),
        deps: vec![],
    };
    println!("usable kernels for conv k{k}s{s} {cin}->{cout}:");
    for kern in Registry::full().candidates(&layer) {
        println!(
            "  {:<24} family={:<16} exec_speed={:.2} expand={:.1} needs_transform={}",
            kern.name,
            kern.family.name(),
            kern.family.exec_speed(),
            kern.family.expand(),
            kern.family.needs_transform()
        );
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let dev = device_of(args)?;
    let n = args.get_usize("requests", 200).map_err(|e| anyhow!(e))?;
    let budget_mb = args.get_usize("budget-mb", 48).map_err(|e| anyhow!(e))? as u64;
    let threads = args.get_usize("threads", 1).map_err(|e| anyhow!(e))?.max(1);
    // Robustness knobs (ISSUE 6): `--deadline-ms D` stamps a latency
    // budget on every request (0 = none) so cold starts that cannot meet
    // it serve degraded; `--admission N` bounds in-flight cold starts per
    // shard (0 = unbounded), shedding the rest; `--faults SEED` injects
    // the deterministic chaos fault mix into the backend — the same
    // schedule `tests/chaos_serving.rs` replays, reproducible from the
    // command line.
    let deadline = args.get_f64("deadline-ms", 0.0).map_err(|e| anyhow!(e))?;
    if deadline < 0.0 || !deadline.is_finite() {
        bail!("--deadline-ms expects a non-negative number");
    }
    let admission = args.get_usize("admission", 0).map_err(|e| anyhow!(e))?;
    // ISSUE 8 knobs: `--queue N` lets up to N requests per shard wait for
    // an in-flight cold start instead of shedding (needs --admission);
    // `--offload` adds a multi-exit model to the fleet and arms the
    // remote-tail offload policy, so deadline-missing requests on it serve
    // `offloaded` instead of degrading.
    let queue = args.get_usize("queue", 0).map_err(|e| anyhow!(e))?;
    let offload = args.has("offload");
    // ISSUE 9 knobs: `--models N` serves the deterministic synthetic
    // fleet `syn-0000..` instead of the fixed 4-model zoo (the
    // thousand-model regime the O(1) residency/metrics paths exist for);
    // `--tenants K` partitions the fleet round-robin across K tenants,
    // each with an equal share of the budget as its own residency lane,
    // and prints the per-tenant outcome table.
    let n_models = args.get_usize("models", 0).map_err(|e| anyhow!(e))?;
    let tenants = args.get_usize("tenants", 0).map_err(|e| anyhow!(e))?;
    let faults = match args.get("faults") {
        Some(seed) => {
            let seed: u64 = seed
                .parse()
                .map_err(|_| anyhow!("--faults expects an integer seed"))?;
            Some(std::sync::Arc::new(nnv12::faults::FaultPlan::chaos(seed)))
        }
        None => None,
    };
    let mut models: Vec<nnv12::graph::ModelGraph> = if n_models > 0 {
        zoo::synthetic(0xFEED, n_models)
    } else {
        ["squeezenet", "shufflenetv2", "mobilenetv2", "googlenet"]
            .iter()
            .map(|m| zoo::by_name(m).unwrap())
            .collect()
    };
    if offload {
        models.push(zoo::branchy_mobilenet());
    }
    // Construction order, not sorted: the workload's Zipf popularity and
    // tenant stamps follow this order, matching the router's round-robin
    // model → tenant ownership.
    let names: Vec<String> = models.iter().map(|g| g.name.clone()).collect();
    // The serving front is itself a thin layer over Engine/Session — it
    // adds the sharded request surface, the failure policy, and the
    // per-model accounting used here. `--threads N` replays the trace
    // across N serving threads (the router's request path is `&self` and
    // thread-safe); `--execute` runs each cold request through the
    // contention-aware simulator instead of charging the planner's
    // estimate.
    let router = Router::new(
        &dev,
        models,
        RouterConfig {
            memory_budget: budget_mb << 20,
            execute_cold: args.has("execute"),
            admission: (admission > 0).then_some(admission),
            queue_depth: (queue > 0).then_some(queue),
            offload: offload.then(nnv12::exits::OffloadPolicy::default),
            faults,
            tenants,
            ..Default::default()
        },
    );
    let reqs = generate(
        &names,
        &WorkloadSpec {
            n_requests: n,
            deadline_ms: (deadline > 0.0).then_some(deadline),
            tenants,
            ..Default::default()
        },
    );
    let t = nnv12::metrics::Timer::start();
    let served = router.replay(&reqs, threads);
    let wall_ms = t.elapsed_ms();
    let s = router.summary();
    println!(
        "served {} requests on {} thread(s) in {:.1} ms ({:.0} req/s): {} cold, {} warm, \
         {} degraded, {} offloaded, {} shed, {} failed (budget {} MB on {})",
        served,
        threads,
        wall_ms,
        served as f64 / (wall_ms / 1e3).max(1e-9),
        s.cold,
        s.warm,
        s.degraded,
        s.offloaded,
        s.shed,
        s.failed,
        budget_mb,
        dev.name
    );
    assert!(s.conserves(), "request accounting must conserve: {s:?}");
    if tenants > 0 {
        // Every model is tenant-owned and every request tenant-stamped,
        // so the per-tenant columns must sum exactly to the globals.
        let (tc, tw, ts): (usize, usize, usize) = s.per_tenant.iter().fold(
            (0, 0, 0),
            |(c, w, sh), t| (c + t.cold, w + t.warm, sh + t.shed),
        );
        assert_eq!(
            (tc, tw, ts),
            (s.cold, s.warm, s.shed),
            "per-tenant attribution must conserve: {:?}",
            s.per_tenant
        );
        println!("  per-tenant (quota {} MB each):", budget_mb / tenants as u64);
        println!("    {:<12} {:>6} {:>6} {:>6} {:>10}", "tenant", "cold", "warm", "shed", "resident");
        for t in &s.per_tenant {
            let used = router
                .engine()
                .tenant_mem_used(&t.tenant)
                .unwrap_or(0);
            println!(
                "    {:<12} {:>6} {:>6} {:>6} {:>10}",
                t.tenant,
                t.cold,
                t.warm,
                t.shed,
                nnv12::util::table::fmt_bytes(used)
            );
        }
    }
    if s.queued > 0 {
        println!("  queue: {} request(s) waited for a cold slot instead of shedding", s.queued);
    }
    if s.degraded + s.failed + s.exec_failures + s.breaker_opens > 0 {
        println!(
            "  faults: {} exec failure(s) ({} panic(s)), {} retried; degraded = {} deadline + \
             {} breaker + {} offload-drop; breaker opened {}x, probed {}x",
            s.exec_failures,
            s.exec_panics,
            s.retries,
            s.degraded_deadline,
            s.degraded_breaker,
            s.degraded_offload,
            s.breaker_opens,
            s.breaker_probes
        );
    }
    for label in ["cold", "warm", "degraded", "offloaded"] {
        let s = router.latency_summary(label);
        if s.n > 0 {
            println!(
                "  {label:<8} n={:<4} mean={:.1} ms p50={:.1} p90={:.1} p99={:.1}",
                s.n, s.mean, s.p50, s.p90, s.p99
            );
        }
    }
    Ok(())
}

/// Zoo × fleet planning through cross-device plan transfer. With
/// `--store DIR` the fleet-plan namespace persists, so a second
/// invocation (or any engine built with `.fleet_transfer(true)` over the
/// same store) seeds every search from the published plans — the
/// `fleet-transfer-hits:` line is machine-parseable for exactly that
/// check. Without a store the transfer still operates within the run
/// (later devices of the tour seed from earlier ones) in a temp
/// directory that is removed afterwards.
fn cmd_fleet(args: &Args) -> Result<()> {
    let models: Vec<nnv12::graph::ModelGraph> = args
        .get_or("models", "squeezenet,shufflenetv2,mobilenetv2")
        .split(',')
        .map(str::trim)
        .filter(|m| !m.is_empty())
        .map(|m| zoo::by_name(m).ok_or_else(|| anyhow!("unknown model '{m}'")))
        .collect::<Result<_>>()?;
    if models.is_empty() {
        bail!("--models expects a comma-separated list of zoo models");
    }
    let devices: Vec<nnv12::device::DeviceProfile> = match args.get_or("devices", "all") {
        "all" => profiles::all_devices(),
        list => list
            .split(',')
            .map(str::trim)
            .filter(|d| !d.is_empty())
            .map(|d| profiles::by_name(d).ok_or_else(|| anyhow!("unknown device '{d}'")))
            .collect::<Result<_>>()?,
    };
    if devices.is_empty() {
        bail!("--devices expects 'all' or a comma-separated list of devices");
    }
    let (store_dir, temp) = match args.get("store") {
        Some(dir) => (std::path::PathBuf::from(dir), false),
        None => (
            std::env::temp_dir().join(format!("nnv12-fleet-cli-{}", std::process::id())),
            true,
        ),
    };
    let store = nnv12::store::ArtifactStore::open(&store_dir)
        .map_err(|e| anyhow!("cannot open artifact store at {}: {e}", store_dir.display()))?;
    let cfg = SchedulerConfig {
        pipeline: !args.has("no-pipeline"),
        ..SchedulerConfig::default()
    };
    let planner = FleetPlanner::new(std::sync::Arc::new(store), cfg);
    let t = nnv12::metrics::Timer::start();
    let fleet_report = planner.plan_fleet(&models, devices);
    let wall_ms = t.elapsed_ms();
    println!("{}", fleet_report.table().render());
    println!("{}", fleet_report.summary());
    println!(
        "planned {} cell(s) in {:.1} ms (store: {})",
        fleet_report.cells.len(),
        wall_ms,
        if temp { "temporary".to_string() } else { store_dir.display().to_string() }
    );
    println!("fleet-transfer-hits: {}", fleet_report.hits);
    if let Some(dir) = args.get("report") {
        std::fs::create_dir_all(dir)
            .map_err(|e| anyhow!("cannot create report dir {dir}: {e}"))?;
        let path = std::path::Path::new(dir).join("fleet_report.json");
        std::fs::write(&path, fleet_report.to_json().to_pretty())
            .map_err(|e| anyhow!("cannot write {}: {e}", path.display()))?;
        println!("wrote {}", path.display());
    }
    if temp {
        let _ = std::fs::remove_dir_all(&store_dir);
    }
    Ok(())
}

/// Store maintenance. `repro store gc --dir DIR [--days N]` removes
/// artifacts not touched in N days (default 30) — the age-based sweep for
/// unaddressed artifacts that capped stores handle via LRU eviction. The
/// newest artifact of each namespace is always kept.
fn cmd_store(args: &Args) -> Result<()> {
    let action = args.positional.first().map(String::as_str).unwrap_or("");
    match action {
        "gc" => {
            let dir = args
                .get("dir")
                .or_else(|| args.get("store"))
                .ok_or_else(|| anyhow!("store gc: --dir DIR (or --store DIR) is required"))?;
            let days = args.get_f64("days", 30.0).map_err(|e| anyhow!(e))?;
            // Upper bound keeps days*86400 comfortably inside Duration's
            // u64-seconds range (from_secs_f64 panics past it).
            if !days.is_finite() || !(0.0..=3_650_000.0).contains(&days) {
                bail!("--days expects a number of days between 0 and 3650000");
            }
            let store = nnv12::store::ArtifactStore::open(dir)
                .map_err(|e| anyhow!("cannot open artifact store at {dir}: {e}"))?;
            let r = store.gc(std::time::Duration::from_secs_f64(days * 86_400.0));
            println!(
                "store gc ({dir}, older than {days} day(s)): removed {} artifact(s) \
                 ({} freed), kept {} — newest per namespace always kept; {} now in use",
                r.removed,
                nnv12::util::table::fmt_bytes(r.bytes_freed),
                r.kept,
                nnv12::util::table::fmt_bytes(store.bytes_used())
            );
            Ok(())
        }
        "fsck" => {
            let dir = args
                .get("dir")
                .or_else(|| args.get("store"))
                .ok_or_else(|| anyhow!("store fsck: --dir DIR (or --store DIR) is required"))?;
            if !std::path::Path::new(dir).is_dir() {
                bail!("store fsck: {dir} is not a directory");
            }
            // `at` (not `open`) so the audit sees the directory exactly as
            // the last process left it — torn intent groups and orphan
            // temp files included — instead of the post-recovery view.
            let store = nnv12::store::ArtifactStore::at(dir);
            let r = store.fsck();
            println!(
                "store fsck ({dir}): {} scanned, {} valid, {} corrupt, {} foreign, \
                 {} registry-stale, {} legacy-v1, {} orphan temp(s), {} torn intent group(s)",
                r.scanned, r.valid, r.corrupt, r.foreign, r.stale, r.legacy, r.orphans, r.intents
            );
            if r.corrupt > 0 {
                bail!("store fsck: {} corrupt artifact(s) in {dir}", r.corrupt);
            }
            Ok(())
        }
        other => bail!("unknown store action '{other}' (expected 'gc' or 'fsck')"),
    }
}

#[cfg(feature = "real-runtime")]
fn cmd_cold(args: &Args) -> Result<()> {
    use nnv12::graph::manifest::Manifest;
    use nnv12::pipeline::{run_cold, RealRunOpts, VariantPref};
    use nnv12::runtime::Runtime;

    let dir = std::path::PathBuf::from(args.get_or("artifacts", "artifacts/tinynet"));
    let manifest = Manifest::load(&dir)?;
    let runtime = Runtime::cpu()?;
    let opts = RealRunOpts {
        disk_mbps: args
            .get("mbps")
            .map(|v| v.parse())
            .transpose()
            .map_err(|_| anyhow!("--mbps expects a number"))?,
        workers: args.get_usize("workers", 2).map_err(|e| anyhow!(e))?,
        // Passing a store only makes sense to cache transformed weights
        // through it, so `--store DIR` implies `--cache`.
        use_cache: args.has("cache") || args.get("store").is_some(),
        pipelined: !args.has("sequential"),
        variant: match args.get_or("variant", "auto") {
            "auto" => VariantPref::Auto,
            "direct" => VariantPref::Direct,
            "im2col" => VariantPref::Im2col,
            "winograd" => VariantPref::Winograd,
            v => bail!("unknown variant '{v}'"),
        },
        // `--store DIR` routes the weights cache through the shared
        // content-addressed store (same cap/counters as plans) instead of
        // the deprecated private cache_dir fallback.
        store: match args.get("store") {
            Some(dir) => Some(std::sync::Arc::new(
                nnv12::store::ArtifactStore::open(dir)
                    .map_err(|e| anyhow!("cannot open artifact store at {dir}: {e}"))?,
            )),
            None => None,
        },
        ..Default::default()
    };
    let in_dims = &manifest.artifacts[1].in_dims;
    let n_in: i64 = in_dims.iter().product();
    let input = vec![0.5f32; n_in as usize];
    let r = run_cold(&manifest, &runtime, &input, &opts)?;
    println!(
        "cold inference of {}: wall {:.1} ms (read {:.1} + transform {:.1} + compile {:.1} + exec {:.1}; cache hits {})",
        manifest.model.name, r.wall_ms, r.read_ms, r.transform_ms, r.compile_ms, r.exec_ms, r.cache_hits
    );
    println!("output[0..4] = {:?}", &r.output[..r.output.len().min(4)]);
    Ok(())
}

#[cfg(not(feature = "real-runtime"))]
fn cmd_cold(_args: &Args) -> Result<()> {
    bail!(
        "the 'cold' subcommand needs real PJRT execution; rebuild with the \
         default 'real-runtime' feature enabled"
    )
}

fn cmd_devices() -> Result<()> {
    for name in profiles::ALL_DEVICES {
        let d = profiles::by_name(name).unwrap();
        println!(
            "{:<12} {} big + {} little, big {:.0} GF/s, disk {:.0} MB/s, mem {:.1} GB/s, gpu: {}",
            d.name,
            d.n_big,
            d.n_little,
            d.big_gflops,
            d.disk_mbps,
            d.mem_eff_gbps,
            d.gpu
                .as_ref()
                .map(|g| format!("{:.0} GF/s", g.gflops))
                .unwrap_or_else(|| "-".into())
        );
    }
    Ok(())
}
