//! Baseline DNN engines (§4.1): vanilla ncnn, MNN, TFLite (CPU),
//! TensorFlow + ncnn-Vulkan (GPU), and AsyMo re-implemented on ncnn.
//!
//! All baselines share the same structure — *sequential* cold inference
//! (read → transform → execute, per the Fig. 1 pipeline) with warm-optimal
//! hard-coded kernels, no post-transformed-weight cache, and no shader
//! cache — and differ in per-engine efficiency factors calibrated against
//! the paper's measurements (Table 1 breakdown, Fig. 2 cold/warm gaps,
//! AsyMo's 1.03–1.28× improvement over ncnn).

use crate::cost::CostModel;
use crate::device::{CoreClass, DeviceProfile};
use crate::graph::ModelGraph;
use crate::kernels::Registry;
use crate::Ms;

/// Cold-inference latency breakdown (Table 1's rows).
#[derive(Debug, Clone, Default)]
pub struct Breakdown {
    pub read_ms: Ms,
    pub alloc_ms: Ms,
    pub gpu_prep_ms: Ms,
    pub transform_ms: Ms,
    pub exec_ms: Ms,
}

impl Breakdown {
    pub fn total(&self) -> Ms {
        self.read_ms + self.alloc_ms + self.gpu_prep_ms + self.transform_ms + self.exec_ms
    }
}

/// A baseline engine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Engine {
    /// Tencent ncnn (the engine NNV12 is built on).
    Ncnn,
    /// Alibaba MNN.
    Mnn,
    /// TFLite on CPU / TensorFlow on GPU (the paper swaps TFLite for TF on
    /// the Jetsons since TFLite lacks a Vulkan/CUDA backend).
    Tensorflow,
    /// AsyMo re-implemented atop ncnn: asymmetry-aware *execution*
    /// scheduling only — preparations remain sequential, which is why it
    /// barely helps cold inference (§4.2).
    Asymo,
}

impl Engine {
    pub fn name(&self, gpu: bool) -> &'static str {
        match self {
            Engine::Ncnn => "ncnn",
            Engine::Mnn => "MNN",
            Engine::Tensorflow => {
                if gpu {
                    "TF"
                } else {
                    "TFLite"
                }
            }
            Engine::Asymo => "AsyMo",
        }
    }

    /// Multiplier on weight-transformation time (engine-specific copy and
    /// preparation overheads on top of the raw layout math).
    fn transform_factor(&self) -> f64 {
        match self {
            Engine::Ncnn => 1.0,
            Engine::Mnn => 0.85,
            Engine::Tensorflow => 1.9,
            Engine::Asymo => 1.0,
        }
    }

    /// Multiplier on execution time (codegen quality difference).
    fn exec_factor(&self) -> f64 {
        match self {
            Engine::Ncnn => 1.0,
            Engine::Mnn => 1.05,
            Engine::Tensorflow => 1.25,
            Engine::Asymo => 1.0 / 1.22, // AsyMo's asymmetric exec speedup
        }
    }

    /// First-execution penalty on GPU (allocator growth, staging buffers,
    /// descriptor pools — all avoided by NNV12's pre-planned arena).
    /// Calibrated so TF's TX2 ResNet-50 cold exec ≈ Table 1's 803 ms vs
    /// 137 ms warm.
    fn gpu_cold_exec_penalty(&self) -> f64 {
        match self {
            Engine::Ncnn => 2.5,
            Engine::Mnn => 3.0,
            Engine::Tensorflow => 6.0,
            Engine::Asymo => 2.5,
        }
    }

    /// TensorFlow rebuilds its graph/runtime state at session start.
    fn fixed_startup_ms(&self, gpu: bool) -> f64 {
        match self {
            Engine::Tensorflow if gpu => 350.0,
            Engine::Tensorflow => 30.0,
            _ => 0.0,
        }
    }
}

/// Cold-inference breakdown for a baseline engine on a device.
pub fn cold_breakdown(engine: Engine, dev: &DeviceProfile, graph: &ModelGraph) -> Breakdown {
    let cm = CostModel::new(dev);
    let reg = Registry::full();
    let gpu = dev.executes_on_gpu();

    // Sequential read of every weight blob from the main core.
    let read_ms: Ms = graph
        .layers()
        .iter()
        .map(|l| cm.read_ms(l.weight_bytes(), CoreClass::Big, 1))
        .sum();

    let alloc_ms = cm.alloc_ms(graph);

    // GPU preparation: driver init + per-kernel pipeline creation with
    // shader compilation (no baseline caches shaders).
    let gpu_prep_ms = if gpu {
        let kernels = graph.layers().iter().filter(|l| l.op.has_weights()).count();
        cm.gpu_driver_init_ms() + kernels as f64 * cm.pipeline_create_ms(false)
    } else {
        0.0
    } + engine.fixed_startup_ms(gpu);

    // Transformation of every layer's weights into the warm-default
    // kernel's layout, single-threaded on a big core (vanilla engines
    // multithread this poorly — Fig. 9 discussion).
    let transform_ms: Ms = graph
        .layers()
        .iter()
        .map(|l| {
            let k = cm.warm_best_kernel(l, &reg);
            cm.transform_ms(&k, l, CoreClass::Big, 1)
        })
        .sum::<f64>()
        * engine.transform_factor();

    // Execution with warm-default kernels on the engine's best core
    // config; on GPU the first execution pays the cold penalty.
    let mut exec_ms = cm.warm_ms(graph, &reg) * engine.exec_factor();
    if gpu {
        exec_ms *= engine.gpu_cold_exec_penalty();
        exec_ms += cm.upload_ms(graph.weight_bytes());
    }

    Breakdown { read_ms, alloc_ms, gpu_prep_ms, transform_ms, exec_ms }
}

/// Cold latency (Table 5 / Figs. 8+10 numbers).
pub fn cold_ms(engine: Engine, dev: &DeviceProfile, graph: &ModelGraph) -> Ms {
    cold_breakdown(engine, dev, graph).total()
}

/// Warm latency for a baseline engine.
pub fn warm_ms(engine: Engine, dev: &DeviceProfile, graph: &ModelGraph) -> Ms {
    CostModel::new(dev).warm_ms(graph, &Registry::full()) * engine.exec_factor()
}

/// Fig. 9 support: baseline cold latency when the engine is configured to
/// use `n_big + n_little` CPU cores. Mixed big+little multithreading
/// suffers from stragglers (the paper's motivation for AsyMo): little
/// cores contribute a fraction of their throughput and add sync overhead.
pub fn cold_ms_with_cores(
    engine: Engine,
    dev: &DeviceProfile,
    graph: &ModelGraph,
    n_big: usize,
    n_little: usize,
) -> Ms {
    let cm = CostModel::new(dev);
    let reg = Registry::full();
    let b = cold_breakdown(engine, dev, graph);

    // Recompute execution with the mixed-core capacity model.
    let straggler = match engine {
        Engine::Asymo => 0.9, // cost-model-based partitioning
        _ => 0.35,            // naive equal split ⇒ little cores straggle
    };
    let nb = n_big.min(dev.n_big) as f64;
    let nl = n_little.min(dev.n_little) as f64;
    let sync_eff = 0.97f64.powf((nb + nl - 1.0).max(0.0));
    let capacity = (nb * dev.big_gflops + straggler * nl * dev.little_gflops) * sync_eff;
    let base_capacity = dev.big_gflops * (dev.n_big as f64).powf(dev.mt_exec_exp);
    let exec_scale = base_capacity / capacity.max(1e-9);

    let warm = cm.warm_ms(graph, &reg) * engine.exec_factor();
    Breakdown {
        exec_ms: warm * exec_scale,
        ..b
    }
    .total()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::profiles;
    use crate::graph::zoo;

    #[test]
    fn table1_pixel5_resnet50_shape() {
        let dev = profiles::pixel_5();
        let g = zoo::resnet50();
        let b = cold_breakdown(Engine::Ncnn, &dev, &g);
        // Paper: read 36.5, alloc 1.3, transform 1135, exec 190, total 1363.
        assert!((10.0..90.0).contains(&b.read_ms), "read {}", b.read_ms);
        assert!(b.alloc_ms < 20.0, "alloc {}", b.alloc_ms);
        assert_eq!(b.gpu_prep_ms, 0.0);
        assert!(
            (500.0..2300.0).contains(&b.transform_ms),
            "transform {}",
            b.transform_ms
        );
        assert!((60.0..400.0).contains(&b.exec_ms), "exec {}", b.exec_ms);
        let total = b.total();
        assert!((700.0..2800.0).contains(&total), "total {total}");
        // Structure: transform dominates.
        assert!(b.transform_ms > 0.5 * total);
    }

    #[test]
    fn table1_tx2_resnet50_shape() {
        let dev = profiles::jetson_tx2();
        let g = zoo::resnet50();
        let b = cold_breakdown(Engine::Tensorflow, &dev, &g);
        // Paper: read 43, prep 3004, transform 1617, exec 803, total 5467.
        assert!((1800.0..4800.0).contains(&b.gpu_prep_ms), "prep {}", b.gpu_prep_ms);
        assert!((700.0..3200.0).contains(&b.transform_ms), "transform {}", b.transform_ms);
        assert!((250.0..1600.0).contains(&b.exec_ms), "exec {}", b.exec_ms);
        let total = b.total();
        assert!((3000.0..9000.0).contains(&total), "total {total}");
        let warm = warm_ms(Engine::Tensorflow, &dev, &g);
        assert!(
            (10.0..45.0).contains(&(total / warm)),
            "cold/warm {} (paper ~40x, Fig. 2 85-443x across engines)",
            total / warm
        );
    }

    #[test]
    fn fig2_cold_warm_gaps() {
        // CPU gap 1.5–12.7×; GPU gap 85.5–443.5×.
        let cpu = profiles::pixel_5();
        let gpu = profiles::jetson_tx2();
        for model in ["mobilenet", "mobilenetv2", "resnet50"] {
            let g = zoo::by_name(model).unwrap();
            for e in [Engine::Ncnn, Engine::Mnn, Engine::Tensorflow] {
                let gap_cpu = cold_ms(e, &cpu, &g) / warm_ms(e, &cpu, &g);
                assert!(
                    (1.25..30.0).contains(&gap_cpu),
                    "{model}/{e:?} cpu gap {gap_cpu}"
                );
                let gap_gpu = cold_ms(e, &gpu, &g) / warm_ms(e, &gpu, &g);
                assert!(
                    gap_gpu > 8.0,
                    "{model}/{e:?} gpu gap {gap_gpu} should be >> cpu"
                );
                assert!(gap_gpu > gap_cpu, "{model}/{e:?}");
            }
        }
    }

    #[test]
    fn asymo_slightly_beats_ncnn() {
        // Paper: AsyMo achieves only 1.03–1.28× over ncnn (prep dominates).
        let dev = profiles::meizu_16t();
        for model in ["googlenet", "resnet50", "mobilenet"] {
            let g = zoo::by_name(model).unwrap();
            let ncnn = cold_ms(Engine::Ncnn, &dev, &g);
            let asymo = cold_ms(Engine::Asymo, &dev, &g);
            let speedup = ncnn / asymo;
            assert!(
                (1.0..1.4).contains(&speedup),
                "{model}: asymo speedup {speedup}"
            );
        }
    }

    #[test]
    fn tflite_slower_than_ncnn_on_cpu() {
        let dev = profiles::meizu_16t();
        let g = zoo::resnet50();
        assert!(cold_ms(Engine::Tensorflow, &dev, &g) > cold_ms(Engine::Ncnn, &dev, &g));
    }

    #[test]
    fn fig9_best_core_count_is_all_big() {
        // ncnn: 4 big cores beats 2 big and beats 4+4 mixed (stragglers).
        let dev = profiles::meizu_16t();
        let g = zoo::googlenet();
        let c2 = cold_ms_with_cores(Engine::Ncnn, &dev, &g, 2, 0);
        let c4 = cold_ms_with_cores(Engine::Ncnn, &dev, &g, 4, 0);
        let c44 = cold_ms_with_cores(Engine::Ncnn, &dev, &g, 4, 4);
        assert!(c4 < c2, "4 cores {c4} vs 2 cores {c2}");
        assert!(c4 < c44, "4 big {c4} should beat 4+4 mixed {c44}");
        // AsyMo benefits from the little cores.
        let a4 = cold_ms_with_cores(Engine::Asymo, &dev, &g, 4, 0);
        let a44 = cold_ms_with_cores(Engine::Asymo, &dev, &g, 4, 4);
        assert!(a44 < a4, "asymo 4+4 {a44} vs 4 {a4}");
    }
}
