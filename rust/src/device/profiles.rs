//! The six paper devices (§4.1 "Hardware"), calibrated to the paper's own
//! measurements. CPU inference on the four phones, GPU inference on the two
//! Jetson boards (the paper found phone GPUs unprofitable for cold
//! inference because of GPU preparation time — Table 1).

use super::profile::{DeviceProfile, GpuProfile};

/// Names accepted by [`by_name`].
pub const ALL_DEVICES: [&str; 6] = [
    "meizu16t",
    "pixel5",
    "redmi9",
    "meizu18pro",
    "jetson-tx2",
    "jetson-nano",
];

/// Look up a device profile by CLI name.
pub fn by_name(name: &str) -> Option<DeviceProfile> {
    Some(match name {
        "meizu16t" => meizu_16t(),
        "pixel5" => pixel_5(),
        "redmi9" => redmi_9(),
        "meizu18pro" => meizu_18_pro(),
        "jetson-tx2" => jetson_tx2(),
        "jetson-nano" => jetson_nano(),
        _ => return None,
    })
}

/// Every profile of the fleet, CPU phones then GPU boards — the device
/// set `FleetPlanner` and `repro fleet` plan across by default.
pub fn all_devices() -> Vec<DeviceProfile> {
    ALL_DEVICES.iter().map(|n| by_name(n).unwrap()).collect()
}

/// The four CPU (phone) devices.
pub fn cpu_devices() -> Vec<DeviceProfile> {
    vec![meizu_16t(), pixel_5(), redmi_9(), meizu_18_pro()]
}

/// The two GPU (Jetson) devices.
pub fn gpu_devices() -> Vec<DeviceProfile> {
    vec![jetson_tx2(), jetson_nano()]
}

fn phone_defaults() -> DeviceProfile {
    DeviceProfile {
        name: "phone",
        n_big: 4,
        n_little: 4,
        big_gflops: 20.0,
        little_gflops: 3.3,
        disk_mbps: 2400.0,
        mem_eff_gbps: 2.4,
        read_little_slowdown: 2.0,       // Fig. 6
        transform_little_slowdown: 3.8,  // Fig. 6
        mt_exec_exp: 0.93,               // near-linear execution scaling
        mt_read_exp: 0.10,               // disk-bound: almost flat
        mt_transform_exp: 0.25,          // memory-bound: poor scaling
        big_power_w: 2.2,
        little_power_w: 0.45,
        idle_power_w: 0.35,
        gpu: None,
    }
}

/// Meizu 16T — Snapdragon 855 (1×A76@2.84 + 3×A76@2.42 + 4×A55).
/// Primary CPU evaluation device of the paper.
pub fn meizu_16t() -> DeviceProfile {
    DeviceProfile {
        name: "meizu16t",
        big_gflops: 24.0,
        little_gflops: 4.0,
        disk_mbps: 2800.0,
        mem_eff_gbps: 3.0,
        ..phone_defaults()
    }
}

/// Google Pixel 5 — Snapdragon 765G (2×A76 + 6×A55). Calibrated so the
/// ncnn-default ResNet-50 cold breakdown lands near Table 1
/// (read 36.5 ms, transform 1,135 ms, exec 190 ms, warm 186 ms).
pub fn pixel_5() -> DeviceProfile {
    DeviceProfile {
        name: "pixel5",
        n_big: 2,
        n_little: 6,
        big_gflops: 21.0,
        little_gflops: 3.5,
        disk_mbps: 2700.0,
        mem_eff_gbps: 1.55,
        ..phone_defaults()
    }
}

/// Redmi 9 — MediaTek Helio G80 (2×A75 + 6×A55), the weakest phone.
pub fn redmi_9() -> DeviceProfile {
    DeviceProfile {
        name: "redmi9",
        n_big: 2,
        n_little: 6,
        big_gflops: 13.0,
        little_gflops: 2.6,
        disk_mbps: 950.0,
        mem_eff_gbps: 1.1,
        ..phone_defaults()
    }
}

/// Meizu 18 Pro — Snapdragon 888 (1×X1 + 3×A78 + 4×A55), the strongest.
pub fn meizu_18_pro() -> DeviceProfile {
    DeviceProfile {
        name: "meizu18pro",
        big_gflops: 31.0,
        little_gflops: 4.6,
        disk_mbps: 3300.0,
        mem_eff_gbps: 3.6,
        ..phone_defaults()
    }
}

/// Jetson TX2 — 256-core Pascal GPU + (2×Denver2 + 4×A57) CPU. Calibrated
/// so the TensorFlow/ncnn-style GPU cold breakdown lands near Table 1
/// (GPU prep 3,004 ms, transform 1,617 ms, exec 803 ms, warm 137 ms).
pub fn jetson_tx2() -> DeviceProfile {
    DeviceProfile {
        name: "jetson-tx2",
        n_big: 2,
        n_little: 4,
        big_gflops: 11.0,
        little_gflops: 5.5,
        disk_mbps: 2300.0,
        mem_eff_gbps: 2.1,
        read_little_slowdown: 1.6,
        transform_little_slowdown: 2.0,
        big_power_w: 3.0,
        little_power_w: 1.2,
        idle_power_w: 1.5,
        gpu: Some(GpuProfile {
            // Table 1's 3,004 ms "GPU preparation" is dominated by
            // per-kernel shader compilation + pipeline-state creation
            // (53 ms x ~54 kernels); context init itself is modest.
            gflops: 420.0,
            driver_init_ms: 120.0,
            pipeline_create_ms: 5.0,
            shader_compile_ms: 48.0,
            upload_gbps: 4.0,
            power_w: 9.0,
        }),
        ..phone_defaults()
    }
}

/// Jetson Nano — 128-core Maxwell GPU + 4×A57 CPU, the weakest GPU board.
pub fn jetson_nano() -> DeviceProfile {
    DeviceProfile {
        name: "jetson-nano",
        n_big: 0,
        n_little: 4,
        big_gflops: 0.0,
        little_gflops: 4.6,
        disk_mbps: 180.0, // SD-card storage
        mem_eff_gbps: 1.3,
        read_little_slowdown: 1.3,
        transform_little_slowdown: 1.5,
        big_power_w: 2.0,
        little_power_w: 0.9,
        idle_power_w: 1.2,
        gpu: Some(GpuProfile {
            gflops: 190.0,
            driver_init_ms: 200.0,
            pipeline_create_ms: 8.0,
            shader_compile_ms: 75.0,
            upload_gbps: 2.5,
            power_w: 6.0,
        }),
        ..phone_defaults()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_devices_resolve() {
        for name in ALL_DEVICES {
            let d = by_name(name).unwrap();
            assert_eq!(d.name, name);
            assert!(d.n_cpu() > 0);
        }
        assert!(by_name("iphone").is_none());
    }

    #[test]
    fn all_devices_matches_the_name_list() {
        let names: Vec<&str> = all_devices().iter().map(|d| d.name).collect();
        assert_eq!(names, ALL_DEVICES);
    }

    #[test]
    fn jetsons_have_gpus_phones_dont() {
        for d in cpu_devices() {
            assert!(d.gpu.is_none(), "{}", d.name);
        }
        for d in gpu_devices() {
            assert!(d.gpu.is_some(), "{}", d.name);
        }
    }

    #[test]
    fn relative_device_strength() {
        // Meizu 18 Pro is the fastest phone; Redmi 9 the slowest.
        assert!(meizu_18_pro().big_gflops > meizu_16t().big_gflops);
        assert!(redmi_9().big_gflops < pixel_5().big_gflops);
        // TX2's GPU is stronger than Nano's; Nano's disk (SD card) is slow.
        assert!(jetson_tx2().gpu.as_ref().unwrap().gflops > jetson_nano().gpu.as_ref().unwrap().gflops);
        assert!(jetson_nano().disk_mbps < 300.0);
    }
}
