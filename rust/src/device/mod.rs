//! Edge-device models.
//!
//! The paper's testbed (two Android phones for CPU experiments, two more in
//! Table 5, and two Jetson boards for GPU experiments) is represented as
//! [`profile::DeviceProfile`]s: core topology, per-core-class effective
//! compute/memory rates, disk bandwidth, GPU preparation costs, and power.
//! The numbers are calibrated against the paper's own measurements
//! (Table 1 breakdown, Table 2 per-kernel costs, Fig. 6 asymmetry ratios).

pub mod profile;
pub mod profiles;

pub use profile::{CoreClass, CoreId, DeviceProfile, GpuProfile};
