//! Device profile: the hardware facts the scheduler and simulator consume.

/// Class of an execution unit.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CoreClass {
    /// LITTLE CPU core (e.g. Cortex-A55).
    Little,
    /// big CPU core (e.g. Cortex-A76/X1, or Jetson's CPU treated as
    /// "little" relative to its GPU).
    Big,
    /// GPU treated as one wide execution unit (§3.4: "treating the GPU as
    /// the big core and CPU as little cores").
    Gpu,
}

impl CoreClass {
    pub fn name(&self) -> &'static str {
        match self {
            CoreClass::Little => "little",
            CoreClass::Big => "big",
            CoreClass::Gpu => "gpu",
        }
    }
}

/// Identifier of a concrete core: class + index within the class.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct CoreId {
    pub class_rank: u8,
    pub index: u8,
}

/// GPU-specific cold-start parameters (§3.4, Table 1's "GPU preparation").
#[derive(Debug, Clone)]
pub struct GpuProfile {
    /// Effective GEMM throughput, GFLOP/s.
    pub gflops: f64,
    /// One-shot driver/context initialization, ms.
    pub driver_init_ms: f64,
    /// Per-kernel Vulkan pipeline creation (state objects), ms. Paid per
    /// executed kernel even with cached shaders.
    pub pipeline_create_ms: f64,
    /// Per-kernel shader (SPIR-V) compilation, ms. Bypassed entirely by the
    /// shader cache (§3.4 "Caching compute shaders").
    pub shader_compile_ms: f64,
    /// Host→device weight upload bandwidth, GB/s.
    pub upload_gbps: f64,
    /// Board power while the GPU is busy, W.
    pub power_w: f64,
}

/// An edge device.
#[derive(Debug, Clone)]
pub struct DeviceProfile {
    pub name: &'static str,
    pub n_big: usize,
    pub n_little: usize,
    /// Effective single-core SGEMM throughput, GFLOP/s.
    pub big_gflops: f64,
    pub little_gflops: f64,
    /// Sequential-read disk bandwidth seen from a big core, MB/s.
    /// (Fig. 6: reads issued from a little core run ~2× slower.)
    pub disk_mbps: f64,
    /// Effective streaming memory bandwidth from one big core, GB/s
    /// (drives weight transformation; Fig. 6: little cores see ~1/3.8).
    pub mem_eff_gbps: f64,
    /// big:little slowdown factors per operation type (Fig. 6).
    pub read_little_slowdown: f64,
    pub transform_little_slowdown: f64,
    /// Multithread efficiency exponents per stage: speedup(n) = n^e.
    /// Fig. 6: execution ~linear (e≈0.93), read/transform ~flat.
    pub mt_exec_exp: f64,
    pub mt_read_exp: f64,
    pub mt_transform_exp: f64,
    /// Per-core active power, W.
    pub big_power_w: f64,
    pub little_power_w: f64,
    pub idle_power_w: f64,
    /// GPU, if this device runs inference on one.
    pub gpu: Option<GpuProfile>,
}

impl DeviceProfile {
    /// Total CPU cores.
    pub fn n_cpu(&self) -> usize {
        self.n_big + self.n_little
    }

    /// Enumerate all schedulable cores: big cores first (class_rank 0),
    /// then little (1), then the GPU as a single unit (2).
    pub fn cores(&self) -> Vec<(CoreId, CoreClass)> {
        let mut out = Vec::new();
        for i in 0..self.n_big {
            out.push((CoreId { class_rank: 0, index: i as u8 }, CoreClass::Big));
        }
        for i in 0..self.n_little {
            out.push((CoreId { class_rank: 1, index: i as u8 }, CoreClass::Little));
        }
        if self.gpu.is_some() {
            out.push((CoreId { class_rank: 2, index: 0 }, CoreClass::Gpu));
        }
        out
    }

    /// Whether inference executes on the GPU for this device (the paper
    /// uses GPU on the Jetsons, CPU on the phones).
    pub fn executes_on_gpu(&self) -> bool {
        self.gpu.is_some()
    }

    /// Number of little-core (preparation) units the scheduler plans
    /// for: on GPU devices every CPU core plays the little role (§3.4).
    /// Single source of truth — the seed rebuild, the incremental
    /// confirm, and the pricer must all agree on this count or the
    /// search's bit-exact-confirm invariant silently breaks.
    pub fn prep_units(&self) -> usize {
        if self.executes_on_gpu() {
            self.n_cpu()
        } else {
            self.n_little
        }
    }

    /// GFLOP/s of one core of the given class.
    pub fn core_gflops(&self, class: CoreClass) -> f64 {
        match class {
            CoreClass::Big => self.big_gflops,
            CoreClass::Little => self.little_gflops,
            CoreClass::Gpu => self.gpu.as_ref().map(|g| g.gflops).unwrap_or(0.0),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::profiles;

    #[test]
    fn cores_enumeration() {
        let d = profiles::meizu_16t();
        let cores = d.cores();
        assert_eq!(cores.len(), d.n_cpu());
        assert_eq!(cores.iter().filter(|(_, c)| *c == CoreClass::Big).count(), d.n_big);

        let tx2 = profiles::jetson_tx2();
        assert!(tx2.executes_on_gpu());
        assert!(tx2.cores().iter().any(|(_, c)| *c == CoreClass::Gpu));
    }

    #[test]
    fn class_speed_ordering() {
        let d = profiles::meizu_16t();
        assert!(d.core_gflops(CoreClass::Big) > d.core_gflops(CoreClass::Little));
        // Fig. 6: exec big/little ratio ≈ 6
        let ratio = d.big_gflops / d.little_gflops;
        assert!((4.0..8.0).contains(&ratio), "ratio {ratio}");
    }
}
