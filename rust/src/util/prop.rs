//! Minimal property-based testing harness (proptest is unavailable offline).
//!
//! Usage:
//! ```ignore
//! prop::check(1234, 200, |rng| {
//!     let n = rng.range(1, 20) as usize;
//!     // ... build a random case, return Err(msg) on violation
//!     Ok(())
//! });
//! ```
//!
//! On failure the harness reports the seed of the failing case so it can be
//! replayed deterministically with [`replay`]. Shrinking is delegated to the
//! caller (cases are generated from sizes drawn small-to-large, so the first
//! failure is usually near-minimal).

use super::rng::Rng;

/// Run `cases` random checks. `f` receives a fresh deterministic RNG per
/// case. Panics with the failing case's seed + message on violation.
pub fn check<F>(seed: u64, cases: usize, mut f: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    for case in 0..cases {
        let case_seed = seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(case_seed);
        if let Err(msg) = f(&mut rng) {
            panic!(
                "property failed at case {case} (replay seed {case_seed:#x}): {msg}"
            );
        }
    }
}

/// Re-run a single failing case by its reported seed.
pub fn replay<F>(case_seed: u64, mut f: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    let mut rng = Rng::new(case_seed);
    if let Err(msg) = f(&mut rng) {
        panic!("replayed property failure (seed {case_seed:#x}): {msg}");
    }
}

/// Assert two floats are within relative tolerance.
pub fn close(a: f64, b: f64, rel: f64) -> Result<(), String> {
    let denom = a.abs().max(b.abs()).max(1e-12);
    if (a - b).abs() / denom <= rel {
        Ok(())
    } else {
        Err(format!("{a} !~ {b} (rel tol {rel})"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check(1, 50, |rng| {
            count += 1;
            let x = rng.f64();
            if (0.0..1.0).contains(&x) {
                Ok(())
            } else {
                Err(format!("{x} out of range"))
            }
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        check(2, 50, |rng| {
            if rng.f64() < 0.9 {
                Ok(())
            } else {
                Err("too big".into())
            }
        });
    }

    #[test]
    fn close_tolerance() {
        assert!(close(1.0, 1.0005, 1e-3).is_ok());
        assert!(close(1.0, 1.1, 1e-3).is_err());
        assert!(close(0.0, 0.0, 1e-9).is_ok());
    }
}
