//! Deterministic PRNG: SplitMix64 seeding + xoshiro256** core.
//!
//! Used by the workload generators, the background-load injector, and the
//! in-tree property-testing harness. Deterministic seeding keeps every
//! experiment reproducible (the paper repeats each experiment 100× and
//! reports averages; we do the same with fixed seed streams).

/// xoshiro256** generator (Blackman & Vigna), seeded via SplitMix64.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Rng {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[lo, hi)`. Panics if `lo >= hi`.
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo < hi, "rng.range: empty range {lo}..{hi}");
        lo + self.next_u64() % (hi - lo)
    }

    /// Uniform usize in `[0, n)`.
    pub fn index(&mut self, n: usize) -> usize {
        self.range(0, n as u64) as usize
    }

    /// Uniform float in `[lo, hi)`.
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Bernoulli trial.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Exponentially distributed value with the given mean (for Poisson
    /// request arrival processes in the serving layer).
    pub fn exponential(&mut self, mean: f64) -> f64 {
        let u = 1.0 - self.f64(); // (0, 1]
        -mean * u.ln()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick a reference to a random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.index(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(8);
        assert_ne!(Rng::new(7).next_u64(), c.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn range_bounds() {
        let mut r = Rng::new(2);
        for _ in 0..10_000 {
            let x = r.range(5, 9);
            assert!((5..9).contains(&x));
        }
    }

    #[test]
    fn mean_of_uniform_close_to_half() {
        let mut r = Rng::new(3);
        let n = 100_000;
        let sum: f64 = (0..n).map(|_| r.f64()).sum();
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(4);
        let n = 100_000;
        let sum: f64 = (0..n).map(|_| r.exponential(4.0)).sum();
        let mean = sum / n as f64;
        assert!((mean - 4.0).abs() < 0.1, "mean={mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
