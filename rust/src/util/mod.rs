//! In-tree substrates for the offline build environment.
//!
//! The build image has no network access and the vendored crate set does not
//! include `serde`/`serde_json`, `clap`, `criterion`, `proptest`, or a PRNG,
//! so this module provides small, tested replacements:
//!
//! * [`json`] — a JSON value model, parser, and pretty-printer (used for the
//!   artifact manifest, plan serialization, and report output).
//! * [`cli`] — a flag/subcommand parser for the `repro` binary.
//! * [`rng`] — a SplitMix64/xoshiro256** PRNG for workload generation and
//!   property tests.
//! * [`prop`] — a tiny property-based testing harness (shrinking included).
//! * [`stats`] — summary statistics (mean/percentiles/stddev) for metrics.
//! * [`bench`] — a warmup+measure micro-bench harness driving the
//!   `cargo bench` targets (criterion replacement).
//! * [`table`] — fixed-width text tables for paper-style reports.
//! * [`parallel`] — scoped data-parallel map over `std::thread` (rayon
//!   replacement; used by the scheduler's outer combination search).

pub mod json;
pub mod cli;
pub mod rng;
pub mod prop;
pub mod stats;
pub mod bench;
pub mod table;
pub mod parallel;
