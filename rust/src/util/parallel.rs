//! Scoped data-parallel map (rayon is not in the offline vendored crate
//! set — see `Cargo.toml`), built on `std::thread::scope`.
//!
//! Work is distributed by an atomic cursor (self-balancing: threads pull
//! the next index when free, so uneven per-item cost — e.g. per-layer
//! kernel-trial batches of different candidate counts — doesn't stall the
//! pool). Results arrive over a channel tagged with their index, so the
//! output order always matches the input order. A panic in the closure
//! propagates out of the scope, preserving ordinary test behaviour.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;

/// Maximum worker threads; override with `NNV12_THREADS` (0 or 1 forces
/// sequential execution — useful for profiling and determinism triage,
/// though `par_map` output is deterministic either way).
fn max_threads() -> usize {
    if let Ok(v) = std::env::var("NNV12_THREADS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
}

/// Map `f` over `items` in parallel, preserving order. `f` receives
/// `(index, &item)`. Falls back to a plain sequential map for short inputs
/// or single-core environments.
pub fn par_map<T, R, F>(items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let n = items.len();
    let threads = max_threads().min(n);
    if threads <= 1 || n <= 1 {
        return items.iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }
    let next = AtomicUsize::new(0);
    let (tx, rx) = mpsc::channel::<(usize, R)>();
    std::thread::scope(|s| {
        for _ in 0..threads {
            let tx = tx.clone();
            let next = &next;
            let f = &f;
            s.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                if tx.send((i, f(i, &items[i]))).is_err() {
                    break;
                }
            });
        }
    });
    drop(tx);
    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    for (i, r) in rx {
        out[i] = Some(r);
    }
    out.into_iter()
        .map(|o| o.expect("par_map worker dropped a result"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order_and_covers_all() {
        let items: Vec<usize> = (0..257).collect();
        let out = par_map(&items, |i, &x| {
            assert_eq!(i, x);
            x * 2
        });
        assert_eq!(out, items.iter().map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn empty_and_singleton() {
        let none: Vec<u32> = Vec::new();
        assert!(par_map(&none, |_, &x| x).is_empty());
        assert_eq!(par_map(&[7u32], |_, &x| x + 1), vec![8]);
    }

    #[test]
    fn matches_sequential_reference() {
        let items: Vec<u64> = (0..100).map(|i| i * 37 % 91).collect();
        let seq: Vec<u64> = items.iter().enumerate().map(|(i, &x)| x + i as u64).collect();
        let par = par_map(&items, |i, &x| x + i as u64);
        assert_eq!(seq, par);
    }

    #[test]
    #[should_panic]
    fn worker_panic_propagates() {
        let items: Vec<usize> = (0..64).collect();
        let _ = par_map(&items, |_, &x| {
            assert!(x < 10, "boom");
            x
        });
    }
}
