//! Fixed-width text tables for paper-style console reports.

/// A simple text table: header row + data rows, columns auto-sized.
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row; short rows are padded with empty cells.
    pub fn row(&mut self, cells: Vec<String>) {
        let mut cells = cells;
        while cells.len() < self.header.len() {
            cells.push(String::new());
        }
        self.rows.push(cells);
    }

    /// Convenience for rows of string slices.
    pub fn row_strs(&mut self, cells: &[&str]) {
        self.row(cells.iter().map(|s| s.to_string()).collect());
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn rows(&self) -> &[Vec<String>] {
        &self.rows
    }

    /// Render with box-drawing separators; first column left-aligned, the
    /// rest right-aligned (numeric convention).
    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate().take(ncols) {
                widths[i] = widths[i].max(c.chars().count());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("## {}\n", self.title));
        }
        let sep: String = widths
            .iter()
            .map(|w| "-".repeat(w + 2))
            .collect::<Vec<_>>()
            .join("+");
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::new();
            for (i, w) in widths.iter().enumerate() {
                let cell = cells.get(i).map(String::as_str).unwrap_or("");
                let pad = w - cell.chars().count();
                if i == 0 {
                    line.push_str(&format!(" {}{} ", cell, " ".repeat(pad)));
                } else {
                    line.push_str(&format!(" {}{} ", " ".repeat(pad), cell));
                }
                if i + 1 < widths.len() {
                    line.push('|');
                }
            }
            line
        };
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }
}

/// Format milliseconds compactly: "0.70", "38.2", "1,135", "22,963".
pub fn fmt_ms(ms: f64) -> String {
    if ms < 10.0 {
        format!("{ms:.2}")
    } else if ms < 100.0 {
        format!("{ms:.1}")
    } else {
        group_thousands(ms.round() as i64)
    }
}

/// Format a speedup factor: "3.7x".
pub fn fmt_x(x: f64) -> String {
    if x >= 100.0 {
        format!("{x:.0}x")
    } else {
        format!("{x:.1}x")
    }
}

/// Format bytes human-readably.
pub fn fmt_bytes(b: u64) -> String {
    const KB: f64 = 1024.0;
    let b = b as f64;
    if b >= KB * KB * KB {
        format!("{:.1}GB", b / (KB * KB * KB))
    } else if b >= KB * KB {
        format!("{:.1}MB", b / (KB * KB))
    } else if b >= KB {
        format!("{:.1}KB", b / KB)
    } else {
        format!("{b:.0}B")
    }
}

fn group_thousands(mut n: i64) -> String {
    let neg = n < 0;
    n = n.abs();
    let digits = n.to_string();
    let mut out = String::new();
    for (i, c) in digits.chars().enumerate() {
        if i > 0 && (digits.len() - i) % 3 == 0 {
            out.push(',');
        }
        out.push(c);
    }
    if neg {
        format!("-{out}")
    } else {
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["model", "ms"]);
        t.row_strs(&["resnet50", "1363.2"]);
        t.row_strs(&["mobilenet", "85.0"]);
        let r = t.render();
        assert!(r.contains("## demo"));
        assert!(r.contains("resnet50"));
        let lines: Vec<&str> = r.lines().collect();
        // header + sep + 2 rows + title
        assert_eq!(lines.len(), 5);
        // all body lines same width
        assert_eq!(lines[1].len(), lines[2].len() + lines[2].len() - lines[3].len());
    }

    #[test]
    fn fmt_helpers() {
        assert_eq!(fmt_ms(0.7), "0.70");
        assert_eq!(fmt_ms(38.23), "38.2");
        assert_eq!(fmt_ms(1135.28), "1,135");
        assert_eq!(fmt_ms(22962.6), "22,963");
        assert_eq!(fmt_x(3.71), "3.7x");
        assert_eq!(fmt_x(401.5), "402x");
        assert_eq!(fmt_bytes(12), "12B");
        assert_eq!(fmt_bytes(9408), "9.2KB");
        assert_eq!(fmt_bytes(172 * 1024 * 1024), "172.0MB");
    }

    #[test]
    fn pads_short_rows() {
        let mut t = Table::new("", &["a", "b", "c"]);
        t.row(vec!["x".into()]);
        assert_eq!(t.rows()[0].len(), 3);
    }
}
