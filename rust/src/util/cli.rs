//! Tiny CLI parser (clap is unavailable offline).
//!
//! Supports `repro <subcommand> [--flag value] [--switch] [positional…]`.

use std::collections::BTreeMap;

/// Parsed command line: subcommand, named flags, positionals.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub cmd: String,
    flags: BTreeMap<String, String>,
    switches: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse raw args (excluding argv[0]). `bool_flags` lists switches that
    /// take no value; everything else starting with `--` consumes the next
    /// token as its value.
    pub fn parse(raw: &[String], bool_flags: &[&str]) -> Result<Args, String> {
        let mut out = Args::default();
        let mut it = raw.iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with("--") {
                out.cmd = it.next().unwrap().clone();
            }
        }
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if bool_flags.contains(&name) {
                    out.switches.push(name.to_string());
                } else if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else {
                    let val = it
                        .next()
                        .ok_or_else(|| format!("flag --{name} expects a value"))?;
                    out.flags.insert(name.to_string(), val.clone());
                }
            } else {
                out.positional.push(tok.clone());
            }
        }
        Ok(out)
    }

    /// Get a string flag.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(String::as_str)
    }

    /// Get a string flag with a default.
    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    /// Parse a numeric flag.
    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("flag --{name}: expected a number, got '{v}'")),
        }
    }

    /// Parse an integer flag.
    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("flag --{name}: expected an integer, got '{v}'")),
        }
    }

    /// Whether a boolean switch was given.
    pub fn has(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_flags_positionals() {
        let a = Args::parse(
            &v(&["report", "--device", "meizu16t", "--verbose", "fig8", "--reps=3"]),
            &["verbose"],
        )
        .unwrap();
        assert_eq!(a.cmd, "report");
        assert_eq!(a.get("device"), Some("meizu16t"));
        assert_eq!(a.get("reps"), Some("3"));
        assert!(a.has("verbose"));
        assert_eq!(a.positional, v(&["fig8"]));
    }

    #[test]
    fn numeric_flags() {
        let a = Args::parse(&v(&["x", "--rate", "2.5"]), &[]).unwrap();
        assert_eq!(a.get_f64("rate", 0.0).unwrap(), 2.5);
        assert_eq!(a.get_f64("missing", 7.0).unwrap(), 7.0);
        assert!(Args::parse(&v(&["x", "--n", "abc"]), &[])
            .unwrap()
            .get_usize("n", 0)
            .is_err());
    }

    #[test]
    fn missing_value_is_error() {
        assert!(Args::parse(&v(&["x", "--flag"]), &[]).is_err());
    }
}
