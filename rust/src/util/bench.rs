//! Micro-benchmark harness driving the `cargo bench` targets
//! (criterion is unavailable offline).
//!
//! Each bench target is a plain binary (`harness = false`) that builds a
//! [`Bench`], registers cases, and calls [`Bench::run`]. The harness does a
//! warmup phase, then measures wall time over enough iterations to exceed a
//! minimum measurement window, and prints mean ± stddev plus throughput.

use std::time::Instant;

use super::json::Json;
use super::stats::Summary;

/// One benchmark result.
#[derive(Debug, Clone)]
pub struct CaseResult {
    pub name: String,
    pub iters: usize,
    pub per_iter_ms: Summary,
    /// For throughput cases ([`Bench::case_throughput`]): how many
    /// logical items (e.g. serving requests) one iteration processes.
    /// The JSON dump derives `items_per_sec` from it.
    pub items_per_iter: Option<usize>,
}

/// Bench harness configuration + registered results.
pub struct Bench {
    suite: String,
    warmup_iters: usize,
    samples: usize,
    min_sample_ms: f64,
    results: Vec<CaseResult>,
    filter: Option<String>,
}

impl Bench {
    /// Create a suite. Honors a `NNV12_BENCH_FAST=1` env var (used by CI and
    /// the final capture run) to cut warmup/sample counts.
    pub fn new(suite: &str) -> Bench {
        let fast = std::env::var("NNV12_BENCH_FAST").ok().as_deref() == Some("1");
        // `cargo bench -- <filter>` passes the filter as an arg.
        let filter = std::env::args().skip(1).find(|a| !a.starts_with('-'));
        Bench {
            suite: suite.to_string(),
            warmup_iters: if fast { 1 } else { 3 },
            samples: if fast { 3 } else { 10 },
            min_sample_ms: if fast { 1.0 } else { 20.0 },
            results: Vec::new(),
            filter,
        }
    }

    /// Override sampling (for long end-to-end cases).
    pub fn with_samples(mut self, samples: usize) -> Bench {
        self.samples = samples;
        self
    }

    /// Measure `f`, which performs one logical iteration per call.
    pub fn case<F: FnMut()>(&mut self, name: &str, f: F) {
        self.measure(name, None, f);
    }

    /// [`Bench::case`] for throughput suites: one iteration of `f`
    /// processes `items` logical items (e.g. requests of a serving
    /// trace). The result additionally reports items/second — printed
    /// here and emitted as `items_per_iter` / `items_per_sec` in the
    /// JSON dump, so requests/sec is a first-class tracked quantity.
    pub fn case_throughput<F: FnMut()>(&mut self, name: &str, items: usize, f: F) {
        if self.measure(name, Some(items), f) {
            if let Some(r) = self.results.last() {
                println!(
                    "{:<48} {:>12.0} items/s",
                    format!("{}/{}", self.suite, r.name),
                    items_per_sec(items, r.per_iter_ms.mean),
                );
            }
        }
    }

    /// Register an externally measured quantity (already in ms, or any
    /// unit the consumer agrees on — e.g. a latency percentile computed
    /// by a workload replay) as a single-sample case, so it lands in the
    /// same JSON dump the CI ratchet reads. Not filtered: a derived
    /// metric belongs to whatever run produced it.
    pub fn case_value(&mut self, name: &str, value_ms: f64) {
        println!(
            "{:<48} {:>12}",
            format!("{}/{}", self.suite, name),
            format!("{value_ms:.4} ms"),
        );
        self.results.push(CaseResult {
            name: name.to_string(),
            iters: 1,
            per_iter_ms: Summary::of(&[value_ms]),
            items_per_iter: None,
        });
    }

    /// Shared measurement core; returns whether the case ran (false when
    /// filtered out).
    fn measure<F: FnMut()>(&mut self, name: &str, items_per_iter: Option<usize>, mut f: F) -> bool {
        if let Some(filter) = &self.filter {
            if !name.contains(filter.as_str()) && !self.suite.contains(filter.as_str()) {
                return false;
            }
        }
        for _ in 0..self.warmup_iters {
            f();
        }
        // Determine how many iterations fill the minimum sample window.
        let t0 = Instant::now();
        f();
        let probe_ms = t0.elapsed().as_secs_f64() * 1e3;
        let iters_per_sample = if probe_ms >= self.min_sample_ms {
            1
        } else {
            ((self.min_sample_ms / probe_ms.max(1e-6)).ceil() as usize).min(100_000)
        };
        let mut per_iter = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t = Instant::now();
            for _ in 0..iters_per_sample {
                f();
            }
            per_iter.push(t.elapsed().as_secs_f64() * 1e3 / iters_per_sample as f64);
        }
        let summary = Summary::of(&per_iter);
        println!(
            "{:<48} {:>12} {:>12} {:>8}",
            format!("{}/{}", self.suite, name),
            format!("{:.4} ms", summary.mean),
            format!("± {:.4}", summary.std),
            format!("x{}", iters_per_sample * self.samples),
        );
        self.results.push(CaseResult {
            name: name.to_string(),
            iters: iters_per_sample * self.samples,
            per_iter_ms: summary,
            items_per_iter,
        });
        true
    }

    /// Print the suite footer; returns results for further reporting.
    pub fn finish(self) -> Vec<CaseResult> {
        println!(
            "suite {}: {} case(s) measured",
            self.suite,
            self.results.len()
        );
        self.results
    }

    /// [`Bench::finish`] plus a machine-readable dump (e.g.
    /// `BENCH_sched.json`) so the perf trajectory is trackable across PRs
    /// by CI and by the EXPERIMENTS notes. Writing is best-effort: an
    /// unwritable path warns instead of failing the bench run.
    pub fn finish_to(self, path: &str) -> Vec<CaseResult> {
        let suite = self.suite.clone();
        let results = self.finish();
        let json = results_json(&suite, &results);
        match std::fs::write(path, json.to_pretty()) {
            Ok(()) => println!("wrote {path}"),
            Err(e) => eprintln!("warning: could not write {path}: {e}"),
        }
        results
    }
}

/// Items/second of a throughput case — the one formula behind both the
/// console print and the JSON `items_per_sec` field the CI ratchet
/// consumes, so they can never drift apart.
fn items_per_sec(items: usize, mean_ms: f64) -> f64 {
    items as f64 / (mean_ms / 1e3).max(1e-12)
}

/// JSON shape: `{"suite": .., "cases": [{"name", "iters", "mean_ms",
/// "std_ms", "min_ms", "p50_ms", "max_ms"}, ..]}`. Throughput cases
/// ([`Bench::case_throughput`]) additionally carry `items_per_iter` and
/// the derived `items_per_sec` (requests/sec for the serving suite).
pub fn results_json(suite: &str, results: &[CaseResult]) -> Json {
    Json::obj(vec![
        ("suite", Json::from(suite)),
        (
            "cases",
            Json::Arr(
                results
                    .iter()
                    .map(|r| {
                        let mut fields = vec![
                            ("name", Json::from(r.name.as_str())),
                            ("iters", Json::from(r.iters)),
                            ("mean_ms", Json::from(r.per_iter_ms.mean)),
                            ("std_ms", Json::from(r.per_iter_ms.std)),
                            ("min_ms", Json::from(r.per_iter_ms.min)),
                            ("p50_ms", Json::from(r.per_iter_ms.p50)),
                            ("max_ms", Json::from(r.per_iter_ms.max)),
                        ];
                        if let Some(items) = r.items_per_iter {
                            fields.push(("items_per_iter", Json::from(items)));
                            fields.push((
                                "items_per_sec",
                                Json::from(items_per_sec(items, r.per_iter_ms.mean)),
                            ));
                        }
                        Json::obj(fields)
                    })
                    .collect(),
            ),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_a_trivial_case() {
        std::env::set_var("NNV12_BENCH_FAST", "1");
        let mut b = Bench::new("unit");
        let mut acc = 0u64;
        b.case("noop", || {
            acc = acc.wrapping_add(1);
        });
        let rs = b.finish();
        assert_eq!(rs.len(), 1);
        assert!(rs[0].per_iter_ms.mean >= 0.0);
        assert!(acc > 0);
    }

    #[test]
    fn throughput_case_reports_items_per_sec() {
        std::env::set_var("NNV12_BENCH_FAST", "1");
        let mut b = Bench::new("unit-tp");
        b.case_throughput("noop", 128, || {});
        let rs = b.finish();
        assert_eq!(rs[0].items_per_iter, Some(128));
        let json = results_json("unit-tp", &rs);
        let case = &json.get("cases").as_arr().unwrap()[0];
        assert_eq!(case.get("items_per_iter").as_usize(), Some(128));
        assert!(case.get("items_per_sec").as_f64().unwrap() > 0.0);
    }

    #[test]
    fn value_case_lands_in_results_verbatim() {
        std::env::set_var("NNV12_BENCH_FAST", "1");
        let mut b = Bench::new("unit-val");
        b.case_value("p99", 12.5);
        let rs = b.finish();
        assert_eq!(rs.len(), 1);
        assert_eq!(rs[0].per_iter_ms.mean, 12.5);
        assert_eq!(rs[0].iters, 1);
    }

    #[test]
    fn json_dump_round_trips() {
        std::env::set_var("NNV12_BENCH_FAST", "1");
        let mut b = Bench::new("unit-json");
        b.case("noop", || {});
        let path = std::env::temp_dir().join("nnv12_bench_unit.json");
        let rs = b.finish_to(path.to_str().unwrap());
        let parsed = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(parsed.get("suite").as_str(), Some("unit-json"));
        let cases = parsed.get("cases").as_arr().unwrap();
        assert_eq!(cases.len(), rs.len());
        assert_eq!(cases[0].get("name").as_str(), Some("noop"));
        assert!(cases[0].get("mean_ms").as_f64().unwrap() >= 0.0);
        let _ = std::fs::remove_file(&path);
    }
}
