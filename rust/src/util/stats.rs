//! Summary statistics for latency/throughput series.

/// Summary of a sample of f64 observations.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    /// Compute a summary; returns an all-zero summary for an empty sample.
    pub fn of(xs: &[f64]) -> Summary {
        if xs.is_empty() {
            return Summary { n: 0, mean: 0.0, std: 0.0, min: 0.0, p50: 0.0, p90: 0.0, p99: 0.0, max: 0.0 };
        }
        let mut sorted: Vec<f64> = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = sorted.len();
        let mean = sorted.iter().sum::<f64>() / n as f64;
        let var = sorted.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            p50: percentile(&sorted, 0.50),
            p90: percentile(&sorted, 0.90),
            p99: percentile(&sorted, 0.99),
            max: sorted[n - 1],
        }
    }
}

/// Linear-interpolated percentile of a pre-sorted slice. `q` in `[0, 1]`.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=1.0).contains(&q));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Geometric mean (used for the paper's average-speedup rows in Table 5).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let log_sum: f64 = xs.iter().map(|x| x.ln()).sum();
    (log_sum / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.p50 - 3.0).abs() < 1e-12);
    }

    #[test]
    fn summary_empty() {
        let s = Summary::of(&[]);
        assert_eq!(s.n, 0);
        assert_eq!(s.mean, 0.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [10.0, 20.0, 30.0, 40.0];
        assert!((percentile(&xs, 0.0) - 10.0).abs() < 1e-12);
        assert!((percentile(&xs, 1.0) - 40.0).abs() < 1e-12);
        assert!((percentile(&xs, 0.5) - 25.0).abs() < 1e-12);
    }

    #[test]
    fn geomean_matches_hand_computation() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 8.0]) - 4.0).abs() < 1e-12);
        assert_eq!(geomean(&[]), 0.0);
    }
}
