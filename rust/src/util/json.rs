//! Minimal JSON: value model, recursive-descent parser, writer.
//!
//! Serde is unavailable in the offline image; this module covers exactly
//! what the project needs: the artifact `manifest.json` emitted by
//! `python/compile/aot.py`, scheduling-plan serialization, and machine-
//! readable report output. It is a strict parser (no comments, no trailing
//! commas) with precise error positions.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use a `BTreeMap` so output is deterministic.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset and message.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub offset: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    /// Parse a complete JSON document; trailing non-whitespace is an error.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters after document"));
        }
        Ok(v)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 && n <= u64::MAX as f64 {
                Some(n as u64)
            } else {
                None
            }
        })
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field access; `Json::Null` for missing keys or non-objects.
    pub fn get(&self, key: &str) -> &Json {
        const NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Builder helper: object from pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Serialize compactly (no whitespace).
    pub fn to_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s.push('\n');
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_str(out, s),
            Json::Arr(a) => {
                if a.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push(']');
            }
            Json::Obj(o) => {
                if o.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_str(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push('}');
            }
        }
    }
}

impl From<f64> for Json {
    fn from(n: f64) -> Json {
        Json::Num(n)
    }
}
impl From<u64> for Json {
    fn from(n: u64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Json {
        Json::Num(n as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, n: f64) {
    if n.is_finite() && n.fract() == 0.0 && n.abs() < 1e15 {
        out.push_str(&format!("{}", n as i64));
    } else if n.is_finite() {
        out.push_str(&format!("{n}"));
    } else {
        // JSON has no Inf/NaN; emit null like most tolerant writers.
        out.push_str("null");
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { offset: self.i, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("invalid literal, expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}' in object")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut arr = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(arr));
        }
        loop {
            self.skip_ws();
            arr.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(arr));
                }
                _ => return Err(self.err("expected ',' or ']' in array")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{0008}'),
                        Some(b'f') => s.push('\u{000C}'),
                        Some(b'u') => {
                            let cp = self.unicode_escape()?;
                            s.push(cp);
                            continue;
                        }
                        _ => return Err(self.err("invalid escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar from the underlying str.
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    if (c as u32) < 0x20 {
                        return Err(self.err("control character in string"));
                    }
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn unicode_escape(&mut self) -> Result<char, JsonError> {
        // self.i points at 'u'
        self.i += 1;
        let hi = self.hex4()?;
        if (0xD800..0xDC00).contains(&hi) {
            // surrogate pair
            if self.b[self.i..].starts_with(b"\\u") {
                self.i += 2;
                let lo = self.hex4()?;
                if !(0xDC00..0xE000).contains(&lo) {
                    return Err(self.err("invalid low surrogate"));
                }
                let cp = 0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00);
                return char::from_u32(cp).ok_or_else(|| self.err("invalid code point"));
            }
            return Err(self.err("unpaired surrogate"));
        }
        char::from_u32(hi).ok_or_else(|| self.err("invalid code point"))
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        if self.i + 4 > self.b.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let s = std::str::from_utf8(&self.b[self.i..self.i + 4])
            .map_err(|_| self.err("invalid \\u escape"))?;
        let v = u32::from_str_radix(s, 16).map_err(|_| self.err("invalid \\u escape"))?;
        self.i += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse(r#""hi""#).unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("c").as_str(), Some("x\ny"));
        assert_eq!(v.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a").as_arr().unwrap()[2].get("b"), &Json::Null);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(
            Json::parse(r#""é😀""#).unwrap(),
            Json::Str("é😀".into())
        );
        assert!(Json::parse(r#""\ud800""#).is_err());
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"m":[{"id":0,"name":"conv1","bytes":9408}],"v":1.5}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(Json::parse(&v.to_compact()).unwrap(), v);
        assert_eq!(Json::parse(&v.to_pretty()).unwrap(), v);
    }

    #[test]
    fn accessors() {
        let v = Json::parse(r#"{"n": 7, "s": "x", "b": true}"#).unwrap();
        assert_eq!(v.get("n").as_u64(), Some(7));
        assert_eq!(v.get("n").as_usize(), Some(7));
        assert_eq!(v.get("s").as_str(), Some("x"));
        assert_eq!(v.get("b").as_bool(), Some(true));
        assert_eq!(v.get("missing"), &Json::Null);
        assert_eq!(v.get("n").as_str(), None);
    }

    #[test]
    fn escaped_output_reparses() {
        let v = Json::Str("quote\" slash\\ nl\n tab\t ctrl\u{0001}".into());
        assert_eq!(Json::parse(&v.to_compact()).unwrap(), v);
    }
}
