//! Metrics: wall-clock timers, latency recorders, and the energy model.

use std::collections::HashMap;
use std::time::Instant;

use crate::device::DeviceProfile;
use crate::util::stats::Summary;
use crate::Ms;

/// A simple scope timer returning elapsed milliseconds.
pub struct Timer {
    t0: Instant,
}

impl Timer {
    pub fn start() -> Timer {
        Timer { t0: Instant::now() }
    }

    pub fn elapsed_ms(&self) -> Ms {
        self.t0.elapsed().as_secs_f64() * 1e3
    }
}

/// Accumulates latency observations per label (request classes, phases).
///
/// Lookups are O(1): a `HashMap` index maps each label to its slot in the
/// insertion-ordered `series` vec, so recording stays flat as the label
/// population grows (a thousand-model serving fleet carries several
/// thousand per-model series). Composite per-scope labels
/// (`"{scope}:{label}"`) go through [`Recorder::record_scoped`], which is
/// allocation-free once a pair has been seen — the steady-state hot path.
#[derive(Debug, Default)]
pub struct Recorder {
    series: Vec<(String, Vec<f64>)>,
    index: HashMap<String, usize>,
    /// scope -> label -> index into `series`, so the composite key never
    /// needs to be materialized to find an existing series.
    scoped: HashMap<String, HashMap<String, usize>>,
}

impl Recorder {
    pub fn new() -> Recorder {
        Recorder::default()
    }

    pub fn record(&mut self, label: &str, value_ms: f64) {
        match self.index.get(label) {
            Some(&i) => self.series[i].1.push(value_ms),
            None => {
                self.index.insert(label.to_string(), self.series.len());
                self.series.push((label.to_string(), vec![value_ms]));
            }
        }
    }

    /// Record under the composite label `"{scope}:{label}"`, equivalent to
    /// `record(&format!("{scope}:{label}"), v)` but without formatting the
    /// key when the pair has been seen before. Only the first observation
    /// of a (scope, label) pair allocates.
    pub fn record_scoped(&mut self, scope: &str, label: &str, value_ms: f64) {
        if let Some(&i) = self.scoped.get(scope).and_then(|m| m.get(label)) {
            self.series[i].1.push(value_ms);
            return;
        }
        let key = format!("{scope}:{label}");
        let i = match self.index.get(&key) {
            Some(&i) => {
                self.series[i].1.push(value_ms);
                i
            }
            None => {
                let i = self.series.len();
                self.index.insert(key.clone(), i);
                self.series.push((key, vec![value_ms]));
                i
            }
        };
        self.scoped
            .entry(scope.to_string())
            .or_default()
            .insert(label.to_string(), i);
    }

    /// Labels in first-observation order.
    pub fn labels(&self) -> Vec<&str> {
        self.series.iter().map(|(l, _)| l.as_str()).collect()
    }

    pub fn values(&self, label: &str) -> &[f64] {
        self.index
            .get(label)
            .map(|&i| self.series[i].1.as_slice())
            .unwrap_or(&[])
    }

    pub fn summary(&self, label: &str) -> Summary {
        Summary::of(self.values(label))
    }
}

/// Energy model used outside the simulator (e.g. baseline engines, Fig. 12):
/// active core-seconds × class power + idle floor over the duration.
pub fn energy_mj(
    dev: &DeviceProfile,
    big_busy_ms: Ms,
    little_busy_ms: Ms,
    gpu_busy_ms: Ms,
    duration_ms: Ms,
) -> f64 {
    let gpu_w = dev.gpu.as_ref().map(|g| g.power_w).unwrap_or(0.0);
    dev.big_power_w * big_busy_ms
        + dev.little_power_w * little_busy_ms
        + gpu_w * gpu_busy_ms
        + dev.idle_power_w * duration_ms
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::profiles;

    #[test]
    fn timer_measures_something() {
        let t = Timer::start();
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert!(t.elapsed_ms() >= 4.0);
    }

    #[test]
    fn recorder_accumulates_and_summarizes() {
        let mut r = Recorder::new();
        r.record("cold", 10.0);
        r.record("cold", 20.0);
        r.record("warm", 5.0);
        assert_eq!(r.labels(), vec!["cold", "warm"]);
        assert_eq!(r.summary("cold").n, 2);
        assert!((r.summary("cold").mean - 15.0).abs() < 1e-12);
        assert_eq!(r.values("missing").len(), 0);
    }

    #[test]
    fn scoped_records_match_formatted_labels() {
        let mut r = Recorder::new();
        r.record_scoped("squeezenet", "cold", 10.0);
        r.record_scoped("squeezenet", "cold", 12.0);
        r.record_scoped("squeezenet", "warm", 1.0);
        r.record_scoped("alexnet", "cold", 30.0);
        assert_eq!(r.values("squeezenet:cold"), &[10.0, 12.0]);
        assert_eq!(r.values("squeezenet:warm"), &[1.0]);
        assert_eq!(r.values("alexnet:cold"), &[30.0]);
        // A plain record under the composite key lands in the same series.
        r.record("squeezenet:cold", 14.0);
        r.record_scoped("squeezenet", "cold", 16.0);
        assert_eq!(r.values("squeezenet:cold"), &[10.0, 12.0, 14.0, 16.0]);
        assert_eq!(
            r.labels(),
            vec!["squeezenet:cold", "squeezenet:warm", "alexnet:cold"]
        );
    }

    #[test]
    fn energy_monotone_in_busy_time() {
        let dev = profiles::meizu_16t();
        let a = energy_mj(&dev, 100.0, 50.0, 0.0, 200.0);
        let b = energy_mj(&dev, 200.0, 50.0, 0.0, 200.0);
        assert!(b > a);
    }
}
