//! Metrics: wall-clock timers, latency recorders, and the energy model.

use std::time::Instant;

use crate::device::DeviceProfile;
use crate::util::stats::Summary;
use crate::Ms;

/// A simple scope timer returning elapsed milliseconds.
pub struct Timer {
    t0: Instant,
}

impl Timer {
    pub fn start() -> Timer {
        Timer { t0: Instant::now() }
    }

    pub fn elapsed_ms(&self) -> Ms {
        self.t0.elapsed().as_secs_f64() * 1e3
    }
}

/// Accumulates latency observations per label (request classes, phases).
#[derive(Debug, Default)]
pub struct Recorder {
    series: Vec<(String, Vec<f64>)>,
}

impl Recorder {
    pub fn new() -> Recorder {
        Recorder::default()
    }

    pub fn record(&mut self, label: &str, value_ms: f64) {
        match self.series.iter_mut().find(|(l, _)| l == label) {
            Some((_, v)) => v.push(value_ms),
            None => self.series.push((label.to_string(), vec![value_ms])),
        }
    }

    pub fn labels(&self) -> Vec<&str> {
        self.series.iter().map(|(l, _)| l.as_str()).collect()
    }

    pub fn values(&self, label: &str) -> &[f64] {
        self.series
            .iter()
            .find(|(l, _)| l == label)
            .map(|(_, v)| v.as_slice())
            .unwrap_or(&[])
    }

    pub fn summary(&self, label: &str) -> Summary {
        Summary::of(self.values(label))
    }
}

/// Energy model used outside the simulator (e.g. baseline engines, Fig. 12):
/// active core-seconds × class power + idle floor over the duration.
pub fn energy_mj(
    dev: &DeviceProfile,
    big_busy_ms: Ms,
    little_busy_ms: Ms,
    gpu_busy_ms: Ms,
    duration_ms: Ms,
) -> f64 {
    let gpu_w = dev.gpu.as_ref().map(|g| g.power_w).unwrap_or(0.0);
    dev.big_power_w * big_busy_ms
        + dev.little_power_w * little_busy_ms
        + gpu_w * gpu_busy_ms
        + dev.idle_power_w * duration_ms
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::profiles;

    #[test]
    fn timer_measures_something() {
        let t = Timer::start();
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert!(t.elapsed_ms() >= 4.0);
    }

    #[test]
    fn recorder_accumulates_and_summarizes() {
        let mut r = Recorder::new();
        r.record("cold", 10.0);
        r.record("cold", 20.0);
        r.record("warm", 5.0);
        assert_eq!(r.labels(), vec!["cold", "warm"]);
        assert_eq!(r.summary("cold").n, 2);
        assert!((r.summary("cold").mean - 15.0).abs() < 1e-12);
        assert_eq!(r.values("missing").len(), 0);
    }

    #[test]
    fn energy_monotone_in_busy_time() {
        let dev = profiles::meizu_16t();
        let a = energy_mj(&dev, 100.0, 50.0, 0.0, 200.0);
        let b = energy_mj(&dev, 200.0, 50.0, 0.0, 200.0);
        assert!(b > a);
    }
}
