//! The evaluation harness: one generator per table and figure of the
//! paper's evaluation section (§2 + §4). Each function returns a
//! [`Table`] whose rows mirror what the paper plots, with paper reference
//! values included where the paper states them, so EXPERIMENTS.md can
//! record paper-vs-measured side by side.

use std::sync::{Arc, OnceLock};

use crate::baselines::{cold_breakdown, cold_ms, cold_ms_with_cores, warm_ms, Engine};
use crate::cost::CostModel;
use crate::device::{profiles, CoreClass, DeviceProfile};
use crate::engine::{Engine as Nnv12Engine, SimBackend};
use crate::graph::zoo;
use crate::kernels::{Kernel, KernelFamily, Registry};
use crate::metrics::{energy_mj, Timer};
use crate::sched::cache::{CalibratedPlanCache, PlanCache};
use crate::sched::heuristic::SchedulerConfig;
use crate::sched::plan::UnitId;
use crate::sim::{BgLoad, SimConfig};
use crate::util::stats::geomean;
use crate::util::table::{fmt_bytes, fmt_ms, fmt_x, Table};

/// Process-wide calibrated-plan cache shared by every report engine: the
/// figure/table grids revisit the same (device, model) cells across
/// reports (fig8 and table5 both price resnet50 on every phone, fig9
/// sweeps core configs, `report all` runs them back-to-back), and
/// calibration is deterministic in the fingerprint, so each distinct cell
/// is calibrated exactly once per process.
fn calibrated_cache() -> Arc<CalibratedPlanCache> {
    static CACHE: OnceLock<Arc<CalibratedPlanCache>> = OnceLock::new();
    CACHE
        .get_or_init(|| Arc::new(CalibratedPlanCache::new()))
        .clone()
}

/// NNV12's end-to-end cold latency on a device (calibrated scheduler plan
/// executed by the contention-aware simulator with workload stealing on).
pub fn nnv12_cold_ms(dev: &DeviceProfile, model: &str) -> f64 {
    let g = zoo::by_name(model).expect("unknown model");
    let engine = Nnv12Engine::builder()
        .device(dev.clone())
        .calibrated(true)
        .calibrated_cache(calibrated_cache())
        .build();
    engine
        .load(g)
        .run_cold()
        .expect("sim backend is infallible")
        .latency_ms
}

/// Fig. 2 — cold vs warm inference gap on vanilla engines.
pub fn fig2() -> Table {
    let mut t = Table::new(
        "Fig. 2 — cold/warm gap on vanilla DL libraries (paper: 1.5-12.7x CPU, 85.5-443.5x GPU)",
        &["model", "engine", "device", "cold ms", "warm ms", "gap"],
    );
    let pixel5 = profiles::pixel_5();
    let tx2 = profiles::jetson_tx2();
    for model in ["mobilenet", "mobilenetv2", "resnet50"] {
        let g = zoo::by_name(model).unwrap();
        for engine in [Engine::Tensorflow, Engine::Ncnn, Engine::Mnn] {
            for dev in [&pixel5, &tx2] {
                let cold = cold_ms(engine, dev, &g);
                let warm = warm_ms(engine, dev, &g);
                t.row(vec![
                    model.into(),
                    engine.name(dev.executes_on_gpu()).into(),
                    dev.name.into(),
                    fmt_ms(cold),
                    fmt_ms(warm),
                    fmt_x(cold / warm),
                ]);
            }
        }
    }
    t
}

/// Table 1 — ResNet-50 cold-inference breakdown.
pub fn table1() -> Table {
    let mut t = Table::new(
        "Table 1 — ResNet-50 cold breakdown (paper: Pixel5 36.5/1.3/-/1135/190; TX2 43.0/0.7/3004/1617/803)",
        &["stage", "Pixel 5 CPU (ms)", "Jetson TX2 GPU (ms)"],
    );
    let cpu = cold_breakdown(Engine::Ncnn, &profiles::pixel_5(), &zoo::resnet50());
    let gpu = cold_breakdown(Engine::Tensorflow, &profiles::jetson_tx2(), &zoo::resnet50());
    let rows: [(&str, f64, f64); 6] = [
        ("Weights reading", cpu.read_ms, gpu.read_ms),
        ("Memory allocation", cpu.alloc_ms, gpu.alloc_ms),
        ("GPU preparation", cpu.gpu_prep_ms, gpu.gpu_prep_ms),
        ("Weights transformation", cpu.transform_ms, gpu.transform_ms),
        ("Model execution", cpu.exec_ms, gpu.exec_ms),
        ("Total cold inference", cpu.total(), gpu.total()),
    ];
    for (name, a, b) in rows {
        t.row(vec![name.into(), fmt_ms(a), fmt_ms(b)]);
    }
    let warm_cpu = warm_ms(Engine::Ncnn, &profiles::pixel_5(), &zoo::resnet50());
    let warm_gpu = warm_ms(Engine::Tensorflow, &profiles::jetson_tx2(), &zoo::resnet50());
    t.row(vec!["Warm inference".into(), fmt_ms(warm_cpu), fmt_ms(warm_gpu)]);
    t
}

/// Table 2 — per-kernel conv costs (k3 s1, 64→192 channels, Meizu 16T).
pub fn table2() -> Table {
    let mut t = Table::new(
        "Table 2 — kernel alternatives for conv k3 s1 64->192 (read/transform on little, exec on 4 big)",
        &["kernel", "read raw", "transform", "read cache", "exec"],
    );
    let dev = profiles::meizu_16t();
    let cm = CostModel::new(&dev);
    let layer = crate::graph::Layer {
        id: 0,
        name: "conv".into(),
        op: crate::graph::OpKind::Conv { kernel: 3, stride: 1, groups: 1 },
        in_ch: 64,
        out_ch: 192,
        in_hw: 32,
        out_hw: 32,
        deps: vec![],
    };
    let kernels: [(&str, KernelFamily); 6] = [
        ("3x3s1-winograd-pack4", KernelFamily::WinogradPack4),
        ("sgemm-pack4", KernelFamily::SgemmPack4),
        ("pack4", KernelFamily::Pack4),
        ("3x3s1-winograd", KernelFamily::Winograd),
        ("3x3s1", KernelFamily::Direct),
        ("general", KernelFamily::General),
    ];
    for (name, fam) in kernels {
        let k = Kernel::new(name, fam);
        let read_raw = cm.read_ms(layer.weight_bytes(), CoreClass::Little, 1);
        let transform = cm.transform_ms(&k, &layer, CoreClass::Little, 1);
        let read_cache = cm.read_ms(k.transformed_bytes(&layer), CoreClass::Little, 1);
        let exec = cm.exec_ms(&k, &layer, CoreClass::Big, 4);
        t.row(vec![
            name.into(),
            fmt_ms(read_raw),
            fmt_ms(transform),
            fmt_ms(read_cache),
            fmt_ms(exec),
        ]);
    }
    t
}

/// Fig. 6 — per-stage times on different core types/counts (ResNet-50
/// totals on Meizu 16T).
pub fn fig6() -> Table {
    let mut t = Table::new(
        "Fig. 6 — stage time by core config, ResNet-50 on Meizu 16T (paper ratios: exec 6x, read 2x, transform 3.8x)",
        &["config", "read (ms)", "transform (ms)", "exec (ms)"],
    );
    let dev = profiles::meizu_16t();
    let cm = CostModel::new(&dev);
    let g = zoo::resnet50();
    let reg = Registry::full();
    let configs: [(&str, CoreClass, usize); 4] = [
        ("1 little", CoreClass::Little, 1),
        ("1 big", CoreClass::Big, 1),
        ("2 big", CoreClass::Big, 2),
        ("4 big", CoreClass::Big, 4),
    ];
    for (name, class, threads) in configs {
        let read: f64 = g
            .layers()
            .iter()
            .map(|l| cm.read_ms(l.weight_bytes(), class, threads))
            .sum();
        let transform: f64 = g
            .layers()
            .iter()
            .map(|l| cm.transform_ms(&cm.warm_best_kernel(l, &reg), l, class, threads))
            .sum();
        let exec: f64 = g
            .layers()
            .iter()
            .map(|l| cm.exec_ms(&cm.warm_best_kernel(l, &reg), l, class, threads))
            .sum();
        t.row(vec![name.into(), fmt_ms(read), fmt_ms(transform), fmt_ms(exec)]);
    }
    t
}

/// Figs. 8/10 shared body: cold latency of all engines for all models on
/// the given devices.
fn engine_grid(title: &str, devices: &[DeviceProfile], models: &[&str]) -> Table {
    let mut header = vec!["model", "device"];
    let gpu = devices[0].executes_on_gpu();
    let engines: Vec<Engine> = if gpu {
        vec![Engine::Tensorflow, Engine::Ncnn]
    } else {
        vec![Engine::Tensorflow, Engine::Ncnn, Engine::Asymo]
    };
    let mut names: Vec<String> = engines.iter().map(|e| e.name(gpu).to_string()).collect();
    names.push("NNV12".into());
    names.push("warm".into());
    let name_refs: Vec<&str> = names.iter().map(String::as_str).collect();
    header.extend(name_refs);
    let mut t = Table::new(title, &header);
    for model in models {
        let g = zoo::by_name(model).unwrap();
        for dev in devices {
            let mut row = vec![model.to_string(), dev.name.to_string()];
            for e in &engines {
                row.push(fmt_ms(cold_ms(*e, dev, &g)));
            }
            row.push(fmt_ms(nnv12_cold_ms(dev, model)));
            row.push(fmt_ms(CostModel::new(dev).warm_ms(&g, &Registry::full())));
            t.row(row);
        }
    }
    t
}

/// Fig. 8 — CPU cold latency: 12 models × 4 phones × 4 engines.
pub fn fig8() -> Table {
    engine_grid(
        "Fig. 8 — cold inference latency on edge CPUs (ms)",
        &profiles::cpu_devices(),
        &zoo::PAPER_MODELS,
    )
}

/// Fig. 10 — GPU cold latency: 12 models × 2 Jetsons × 3 engines.
pub fn fig10() -> Table {
    engine_grid(
        "Fig. 10 — cold inference latency on edge GPUs (ms)",
        &profiles::gpu_devices(),
        &zoo::PAPER_MODELS,
    )
}

/// Fig. 9 — impact of CPU core count (Meizu 16T).
pub fn fig9() -> Table {
    let mut t = Table::new(
        "Fig. 9 — cold latency vs core config on Meizu 16T ('X+Y' = X big + Y little)",
        &["model", "config", "TFLite", "ncnn", "AsyMo", "NNV12"],
    );
    let dev = profiles::meizu_16t();
    let configs: [(&str, usize, usize); 5] =
        [("1+0", 1, 0), ("2+0", 2, 0), ("4+0", 4, 0), ("4+2", 4, 2), ("4+4", 4, 4)];
    for model in ["googlenet", "resnet50"] {
        let g = zoo::by_name(model).unwrap();
        for (name, nb, nl) in configs {
            let mut sub = dev.clone();
            sub.n_big = nb;
            sub.n_little = nl;
            let engine = Nnv12Engine::builder()
                .device(sub)
                .calibrated(true)
                .calibrated_cache(calibrated_cache())
                .build();
            let nnv12 = engine
                .load(g.clone())
                .run_cold()
                .expect("sim backend")
                .latency_ms;
            t.row(vec![
                model.into(),
                name.into(),
                fmt_ms(cold_ms_with_cores(Engine::Tensorflow, &dev, &g, nb, nl)),
                fmt_ms(cold_ms_with_cores(Engine::Ncnn, &dev, &g, nb, nl)),
                fmt_ms(cold_ms_with_cores(Engine::Asymo, &dev, &g, nb, nl)),
                fmt_ms(nnv12),
            ]);
        }
    }
    t
}

/// Fig. 11 — adapting to background loads (GoogLeNet, Meizu 16T).
pub fn fig11() -> Table {
    let mut t = Table::new(
        "Fig. 11 — dynamic background load, GoogLeNet on Meizu 16T ('WS' = workload stealing)",
        &["background", "ncnn", "NNV12 w/o WS", "NNV12 + WS"],
    );
    let dev = profiles::meizu_16t();
    let g = zoo::googlenet();
    // One plan, many runtime conditions: engines per (stealing,
    // background) arm share the plan through one cache.
    let cache = Arc::new(PlanCache::new());
    let run = |stealing: bool, background: Vec<BgLoad>| -> f64 {
        let engine = Nnv12Engine::builder()
            .device(dev.clone())
            .plan_cache(cache.clone())
            .backend(SimBackend::with(SimConfig { stealing, contention: true, background }))
            .build();
        engine.load(g.clone()).run_cold().expect("sim backend").latency_ms
    };
    let cases: [(&str, Vec<BgLoad>); 4] = [
        ("none", vec![]),
        (
            "2 little @25%",
            vec![
                BgLoad { unit: UnitId::Little(0), utilization: 0.25 },
                BgLoad { unit: UnitId::Little(1), utilization: 0.25 },
            ],
        ),
        (
            "2 little @50%",
            vec![
                BgLoad { unit: UnitId::Little(0), utilization: 0.5 },
                BgLoad { unit: UnitId::Little(1), utilization: 0.5 },
            ],
        ),
        ("big gang @50%", vec![BgLoad { unit: UnitId::Gang, utilization: 0.5 }]),
    ];
    for (name, bg) in cases {
        // ncnn runs on big cores only ⇒ unaffected by little-core load.
        let ncnn_base = cold_ms(Engine::Ncnn, &dev, &g);
        let ncnn = if bg.iter().any(|b| b.unit == UnitId::Gang) {
            ncnn_base / (1.0 - 0.5 / dev.n_big as f64) // 1 of 4 big cores half-busy
        } else {
            ncnn_base
        };
        let no_ws = run(false, bg.clone());
        let ws = run(true, bg);
        t.row(vec![name.into(), fmt_ms(ncnn), fmt_ms(no_ws), fmt_ms(ws)]);
    }
    t
}

/// Fig. 12 — energy consumption of cold inference.
pub fn fig12() -> Table {
    let mut t = Table::new(
        "Fig. 12 — cold-inference energy on Meizu 16T (paper: NNV12 is 0.2-0.6x of ncnn)",
        &["model", "ncnn (mJ)", "NNV12 (mJ)", "ratio"],
    );
    let dev = profiles::meizu_16t();
    let engine = Nnv12Engine::builder().device(dev.clone()).build();
    for model in ["googlenet", "mobilenetv2", "resnet50", "squeezenet"] {
        let g = zoo::by_name(model).unwrap();
        // ncnn: sequential on big cores — busy the whole cold latency.
        let b = cold_breakdown(Engine::Ncnn, &dev, &g);
        let ncnn_mj = energy_mj(
            &dev,
            (b.read_ms + b.transform_ms) + b.exec_ms * dev.n_big as f64,
            0.0,
            0.0,
            b.total(),
        );
        let sim = engine.load(g).run_cold().expect("sim backend");
        t.row(vec![
            model.into(),
            format!("{:.0}", ncnn_mj),
            format!("{:.0}", sim.energy_mj),
            format!("{:.2}", sim.energy_mj / ncnn_mj),
        ]);
    }
    t
}

/// Fig. 13 — ablation: K / K+C / K+C+P.
pub fn fig13() -> Table {
    let mut t = Table::new(
        "Fig. 13 — ablation (paper, ResNet-50 TX2: 8272 -> K 2300 -> +C 555 -> +P 240 ms)",
        &["model", "device", "baseline", "K", "K+C", "K+C+P"],
    );
    let cases = [
        ("resnet50", profiles::jetson_tx2()),
        ("resnet50", profiles::meizu_16t()),
        ("googlenet", profiles::meizu_16t()),
        ("mobilenetv2", profiles::meizu_16t()),
    ];
    for (model, dev) in cases {
        let g = zoo::by_name(model).unwrap();
        let run = |cfg: &SchedulerConfig| {
            // Workload stealing is part of the "P" knob: without pipelining
            // the engine is single-queue sequential, so nothing steals.
            let sim_cfg = SimConfig {
                stealing: cfg.pipeline,
                contention: true,
                background: vec![],
            };
            let engine = Nnv12Engine::builder()
                .device(dev.clone())
                .sched(cfg.clone())
                .backend(SimBackend::with(sim_cfg))
                .build();
            engine.load(g.clone()).run_cold().expect("sim backend").latency_ms
        };
        let baseline = run(&SchedulerConfig {
            kernel_selection: false,
            weight_cache: false,
            shader_cache: false,
            pipeline: false,
            ..SchedulerConfig::default()
        });
        t.row(vec![
            model.into(),
            dev.name.into(),
            fmt_ms(baseline),
            fmt_ms(run(&SchedulerConfig::k_only())),
            fmt_ms(run(&SchedulerConfig::kc())),
            fmt_ms(run(&SchedulerConfig::kcp())),
        ]);
    }
    t
}

/// Fig. 14 — continuous inference: cold + subsequent warm latencies.
pub fn fig14() -> Table {
    let mut t = Table::new(
        "Fig. 14 — continuous inference on Meizu 16T (paper: 2nd inference ~8% over ncnn warm, equal from 3rd)",
        &["model", "engine", "1st (cold)", "2nd", "3rd", "4th"],
    );
    let dev = profiles::meizu_16t();
    let engine = Nnv12Engine::builder().device(dev.clone()).warmup_depth(4).build();
    for model in ["googlenet", "resnet50"] {
        let g = zoo::by_name(model).unwrap();
        let session = engine.load(g);
        let ladder = session.ladder();
        t.row(vec![
            model.into(),
            "NNV12".into(),
            fmt_ms(ladder[0]),
            fmt_ms(ladder[1]),
            fmt_ms(ladder[2]),
            fmt_ms(ladder[3]),
        ]);
        let ncnn_cold = cold_ms(Engine::Ncnn, &dev, &g);
        let ncnn_warm = warm_ms(Engine::Ncnn, &dev, &g);
        t.row(vec![
            model.into(),
            "ncnn".into(),
            fmt_ms(ncnn_cold),
            fmt_ms(ncnn_warm),
            fmt_ms(ncnn_warm),
            fmt_ms(ncnn_warm),
        ]);
    }
    t
}

/// Table 4 — models, plan-generation time, storage overhead.
pub fn table4() -> Table {
    let mut t = Table::new(
        "Table 4 — models, offline plan generation time, cache storage overhead",
        &["model", "params", "size", "FLOPs", "cache storage", "plangen meizu16t", "plangen tx2"],
    );
    let meizu = Nnv12Engine::builder().device(profiles::meizu_16t()).build();
    let tx2 = Nnv12Engine::builder().device(profiles::jetson_tx2()).build();
    let mut models: Vec<&str> = zoo::PAPER_MODELS.to_vec();
    models.push("crnn-lite");
    for model in models {
        let g = zoo::by_name(model).unwrap();
        let t0 = Timer::start();
        let s1 = meizu.plan_fresh(&g);
        let meizu_ms = t0.elapsed_ms();
        let t1 = Timer::start();
        let _s2 = tx2.plan_fresh(&g);
        let tx2_ms = t1.elapsed_ms();
        t.row(vec![
            model.into(),
            format!("{:.1}M", g.params() as f64 / 1e6),
            fmt_bytes(g.weight_bytes()),
            format!("{:.1}G", g.flops() as f64 / 1e9),
            fmt_bytes(s1.plan.cache_bytes(&g)),
            fmt_ms(meizu_ms),
            fmt_ms(tx2_ms),
        ]);
    }
    t
}

/// Table 5 — speedup summary over baselines per device.
pub fn table5() -> Table {
    let mut t = Table::new(
        "Table 5 — NNV12 speedup over baselines (min-max, geomean) — paper: Meizu16T 3.7x vs ncnn, TX2 29.6x, Nano 28.5x",
        &["device", "vs ncnn", "vs TFLite/TF"],
    );
    let mut devices = profiles::cpu_devices();
    devices.extend(profiles::gpu_devices());
    for dev in devices {
        let mut ncnn_speedups = Vec::new();
        let mut tf_speedups = Vec::new();
        for model in zoo::PAPER_MODELS {
            let g = zoo::by_name(model).unwrap();
            let ours = nnv12_cold_ms(&dev, model);
            ncnn_speedups.push(cold_ms(Engine::Ncnn, &dev, &g) / ours);
            tf_speedups.push(cold_ms(Engine::Tensorflow, &dev, &g) / ours);
        }
        let fmt_range = |v: &[f64]| {
            let min = v.iter().cloned().fold(f64::INFINITY, f64::min);
            let max = v.iter().cloned().fold(0.0f64, f64::max);
            format!("{} - {} ({})", fmt_x(min), fmt_x(max), fmt_x(geomean(v)))
        };
        t.row(vec![
            dev.name.into(),
            fmt_range(&ncnn_speedups),
            fmt_range(&tf_speedups),
        ]);
    }
    t
}

/// Fleet coverage (not a paper figure): the zoo's small models planned
/// across every device profile through cross-device plan transfer, in a
/// throwaway store — which cells seeded from which donors, and what the
/// transfer path cost against a same-run cold search (never anything, by
/// construction: the kept plan is the better of the two). The full
/// version with persistence and model selection is `repro fleet`.
pub fn fleet_coverage() -> Table {
    let dir = std::env::temp_dir().join(format!(
        "nnv12-report-fleet-{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    let store = Arc::new(
        crate::store::ArtifactStore::open(&dir).expect("temp store must open"),
    );
    let planner = crate::fleet::FleetPlanner::new(store, SchedulerConfig::kcp());
    let report = planner.plan_fleet(
        &[zoo::tiny_net(), zoo::micro_mobilenet()],
        profiles::all_devices(),
    );
    let _ = std::fs::remove_dir_all(&dir);
    report.table()
}

/// Early exits (not a paper figure): the expected-makespan grid for every
/// multi-exit zoo model. Per model, the probability-blind plan and the
/// survival-weighted plan are both scored under the expected-makespan
/// metric (the expected plan never loses — `benches/exits_expected.rs`
/// ratchets the gap in CI), then the first-exit tail offload is priced
/// against three simulated remotes; the verdict says which side a
/// deadline-missing request would take. The CLI entry is `repro report
/// exits`.
pub fn exits() -> Table {
    use crate::exits::{compare_expected_vs_blind, offload_estimate, OffloadPolicy};

    let mut t = Table::new(
        "Early exits — expected-vs-blind plans and tail offload (Meizu 16T, model units)",
        &[
            "model", "exits", "tail survives", "blind exp-ms", "expected exp-ms",
            "gain", "remote", "local cold", "offload est", "verdict",
        ],
    );
    let dev = profiles::meizu_16t();
    let reg = Registry::full();
    let cfg = SchedulerConfig::kcp();
    // RTT ms / bandwidth Mbps / remote speedup / remote cold ms.
    let remotes: [(&str, OffloadPolicy); 3] = [
        ("lan", OffloadPolicy {
            rtt_ms: 5.0,
            bandwidth_mbps: 1000.0,
            remote_speedup: 10.0,
            remote_cold_ms: 2.0,
        }),
        ("wan", OffloadPolicy::default()),
        ("far", OffloadPolicy {
            rtt_ms: 80.0,
            bandwidth_mbps: 20.0,
            remote_speedup: 2.0,
            remote_cold_ms: 50.0,
        }),
    ];
    for model in zoo::BRANCHY_MODELS {
        let g = zoo::by_name(model).unwrap();
        let cmp = compare_expected_vs_blind(&dev, &g, &reg, &cfg);
        let survive = *g.survival_weights().last().unwrap();
        let local_cold = cmp.blind.schedule.makespan;
        for (remote, policy) in &remotes {
            let (est_ms, verdict) = match offload_estimate(&g, policy, local_cold) {
                Some(est) if est.expected_ms < local_cold => {
                    (fmt_ms(est.expected_ms), "offload")
                }
                Some(est) => (fmt_ms(est.expected_ms), "local"),
                None => ("-".into(), "local"),
            };
            t.row(vec![
                model.to_string(),
                g.exits().len().to_string(),
                format!("{:.0}%", survive * 100.0),
                fmt_ms(cmp.blind_ms),
                fmt_ms(cmp.expected_ms),
                fmt_x(cmp.blind_ms / cmp.expected_ms.max(1e-12)),
                remote.to_string(),
                fmt_ms(local_cold),
                est_ms,
                verdict.into(),
            ]);
        }
    }
    t
}

/// All reports keyed by CLI name.
pub fn by_name(name: &str) -> Option<Table> {
    Some(match name {
        "fleet" => fleet_coverage(),
        "exits" => exits(),
        "fig2" => fig2(),
        "table1" => table1(),
        "table2" => table2(),
        "fig6" => fig6(),
        "fig8" => fig8(),
        "fig9" => fig9(),
        "fig10" => fig10(),
        "fig11" => fig11(),
        "fig12" => fig12(),
        "fig13" => fig13(),
        "fig14" => fig14(),
        "table4" => table4(),
        "table5" => table5(),
        _ => return None,
    })
}

/// Report ids in paper order.
pub const ALL_REPORTS: [&str; 13] = [
    "fig2", "table1", "table2", "fig6", "fig8", "fig9", "fig10", "fig11",
    "fig12", "fig13", "fig14", "table4", "table5",
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_reports_have_rows() {
        for name in ["table1", "table2", "fig6"] {
            let t = by_name(name).unwrap();
            assert!(!t.is_empty(), "{name} empty");
            let rendered = t.render();
            assert!(rendered.contains("##"));
        }
        assert!(by_name("fig99").is_none());
    }

    #[test]
    fn exits_report_covers_every_branchy_model_and_remote() {
        let t = exits();
        assert_eq!(t.rows().len(), zoo::BRANCHY_MODELS.len() * 3);
        for row in t.rows() {
            assert!(row[9] == "offload" || row[9] == "local", "{row:?}");
        }
    }

    #[test]
    fn fig13_rows_monotone() {
        let t = fig13();
        for row in t.rows() {
            let parse = |s: &str| s.replace(',', "").parse::<f64>().unwrap();
            let base = parse(&row[2]);
            let k = parse(&row[3]);
            let kc = parse(&row[4]);
            let kcp = parse(&row[5]);
            assert!(base >= k && k >= kc && kc >= kcp, "{row:?}");
        }
    }
}
