//! The NNV12 kernel scheduler (§3.3, Algorithm 1) plus the outer
//! kernel-combination search.
//!
//! Heuristics encoded here, from the paper:
//! 1. execution operations always occupy all big cores (the gang) and run
//!    sequentially in model order — cold inference's lower bound is warm
//!    inference;
//! 2. each layer's read+transform(+GPU pipeline creation) form a
//!    *preparation bundle* placed on one little core, without
//!    multithreading (I/O- and memory-bound stages don't scale — Fig. 6);
//! 3. the big-core loop migrates leading preparation bundles onto the gang
//!    while the gang would otherwise start later than the most-loaded
//!    little core;
//! 4. the little-core loop rebalances bundles between the most- and
//!    least-loaded little cores.
//!
//! The outer layer searches kernel combinations over the Pareto-filtered
//! candidates (see [`super::filter`]); with 1–2 survivors per layer,
//! greedy seeding + coordinate descent converges in a few passes.
//!
//! §Perf — the search runs incrementally, end to end. Canonical op sets
//! ([`OpSet::build`]) make every kernel swap *structurally exact*: the
//! set materializes read/transform/exec ops for every weighted layer
//! (bypassed transforms price as 0), so the op-set structure never
//! depends on the kernel choices and [`swap_prices`] is always a plain
//! 3-entry price delta — no fold, no approximation. Each pass freezes
//! the incumbent plan and screens every per-layer kernel swap with
//! [`IncrementalEval::retime`] (prefix replay + suffix re-schedule)
//! against the flat candidate price table built once by the Pareto
//! filter — no per-trial `OpSet` rebuild, cost-model call, or
//! choice-vector clone. Independent layer trials are evaluated in
//! parallel ([`crate::util::parallel::par_map`]); accepted swaps mutate
//! `pick` in place and rebase the evaluator's table. The pass-end
//! confirm is incremental too ([`confirm_from_table`]): because the
//! rebased table is bit-identical to a freshly priced one, the confirm
//! re-runs only the Algorithm-1 queue assembly (bundle promotion +
//! little-core balancing, O(layers × little cores)) plus one full
//! evaluation — never an `OpSet`/`Pricer`/`PriceTable` reconstruction —
//! and its table carries into the next pass. The confirm remains the
//! only accept gate: the returned plan's makespan is always a full
//! evaluation of a fully re-assembled plan, never a delta estimate.
//! [`inner_schedule`] (the from-scratch rebuild) is retained as the
//! oracle `tests/canonical_confirm.rs` proves the confirm bit-exact
//! against.

use std::sync::Arc;

use crate::device::DeviceProfile;
use crate::graph::ModelGraph;
use crate::kernels::Registry;
use crate::sched::filter::{candidates, Candidate};
use crate::sched::makespan::{evaluate_with, IncrementalEval, PriceDelta, Schedule};
use crate::sched::op::{OpSet, OpStage};
use crate::sched::plan::{KernelChoice, Plan};
use crate::sched::price::{PriceTable, Pricer};
use crate::util::parallel::par_map;
use crate::Ms;

/// Scheduler configuration; the three ablation knobs of Fig. 13 ("K":
/// kernel selection, "C": post-transformed-weight + shader caching, "P":
/// pipelined execution) can be toggled independently.
#[derive(Debug, Clone)]
pub struct SchedulerConfig {
    /// Balance tolerance ε of Algorithm 1, ms.
    pub epsilon_ms: f64,
    /// Max coordinate-descent passes of the outer combination search.
    pub max_outer_passes: usize,
    /// Knob "K": cold-aware kernel selection (off ⇒ warm-default kernels).
    pub kernel_selection: bool,
    /// Knob "C": allow reading cached post-transformed weights.
    pub weight_cache: bool,
    /// Knob "C" (GPU): shader cache.
    pub shader_cache: bool,
    /// Knob "P": pipeline preparations across little cores (off ⇒ strictly
    /// sequential single-queue cold inference, like vanilla engines).
    pub pipeline: bool,
}

impl Default for SchedulerConfig {
    fn default() -> SchedulerConfig {
        SchedulerConfig {
            epsilon_ms: 0.5,
            max_outer_passes: 3,
            kernel_selection: true,
            weight_cache: true,
            shader_cache: true,
            pipeline: true,
        }
    }
}

impl SchedulerConfig {
    /// Fig. 13 arm "K": selection only.
    pub fn k_only() -> SchedulerConfig {
        SchedulerConfig {
            weight_cache: false,
            shader_cache: false,
            pipeline: false,
            ..SchedulerConfig::default()
        }
    }

    /// Fig. 13 arm "K+C": selection + caching.
    pub fn kc() -> SchedulerConfig {
        SchedulerConfig { pipeline: false, ..SchedulerConfig::default() }
    }

    /// Fig. 13 arm "K+C+P": the full system.
    pub fn kcp() -> SchedulerConfig {
        SchedulerConfig::default()
    }
}

/// Scheduler output.
#[derive(Debug, Clone)]
pub struct Scheduled {
    pub plan: Plan,
    pub schedule: Schedule,
    /// The op set the plan refers to (needed to interpret queue entries).
    /// Canonical op sets are structurally identical across a whole search
    /// — and across every confirm and plan-cache entry of that search —
    /// so the set is shared by `Arc` rather than cloned per result:
    /// cloning a `Scheduled` (cache hits, confirm results) is
    /// allocation-free on the set. `&scheduled.set` still derefs to
    /// `&OpSet` everywhere it is consumed.
    pub set: Arc<OpSet>,
}

/// Number of little-core (preparation) units the scheduler plans for on
/// `dev` — a thin alias of [`DeviceProfile::prep_units`], the single
/// source shared with [`Pricer::n_little_units`].
pub fn prep_units(dev: &DeviceProfile) -> usize {
    dev.prep_units()
}

/// Per-layer candidate sets (Algorithm 1, line 1: Pareto filter) — the
/// shared front half of [`schedule`] and [`schedule_seeded`]. Weightless
/// layers get an empty set; with kernel selection off, candidates come
/// from the warm-default registry.
pub(crate) fn build_candidates(
    dev: &DeviceProfile,
    graph: &ModelGraph,
    registry: &Registry,
    cfg: &SchedulerConfig,
) -> Vec<Vec<Candidate>> {
    graph
        .layers()
        .iter()
        .map(|l| {
            if !l.op.has_weights() {
                return Vec::new();
            }
            let cs = if cfg.kernel_selection {
                candidates(dev, l, registry, cfg.weight_cache)
            } else {
                candidates(dev, l, &Registry::warm_default(), cfg.weight_cache)
            };
            // The filter can return an empty set only if every candidate
            // was cache-only, which cannot happen (uncached always exists).
            assert!(!cs.is_empty(), "layer {} lost all candidates", l.id);
            cs
        })
        .collect()
}

/// Per-layer greedy pick (the cold search's seed). Preparation runs on
/// ~n_little cores in parallel with execution, so a bundle "costs"
/// roughly prep/n_little against the gang's exec time.
pub(crate) fn greedy_pick(
    cands: &[Vec<Candidate>],
    cfg: &SchedulerConfig,
    n_prep_units: usize,
) -> Vec<usize> {
    let n_little = n_prep_units.max(1);
    cands
        .iter()
        .map(|cs| {
            if cs.is_empty() {
                return 0;
            }
            let score = |c: &Candidate| {
                if cfg.pipeline {
                    c.exec_ms + c.prep_ms / n_little as f64
                } else {
                    c.exec_ms + c.prep_ms
                }
            };
            (0..cs.len())
                .min_by(|&a, &b| score(&cs[a]).partial_cmp(&score(&cs[b])).unwrap())
                .unwrap()
        })
        .collect()
}

/// The only place choice vectors are materialized: when (re)building a
/// plan. Trials never clone kernel choices — they operate on `pick` and
/// the candidates' flat price table.
pub(crate) fn choices_of(cands: &[Vec<Candidate>], pick: &[usize]) -> Vec<Option<KernelChoice>> {
    cands
        .iter()
        .zip(pick)
        .map(|(cs, &p)| cs.get(p).map(|c| c.choice.clone()))
        .collect()
}

/// The incremental coordinate descent over kernel combinations — the
/// shared back half of [`schedule`] (full pass budget, every layer
/// searchable) and [`schedule_seeded`] (short budget, only the layers the
/// transferred seed changed). `best`/`pick` are the incumbent (already
/// evaluated) and are updated in place on every confirmed improvement;
/// `seed_table` must be exact for `pick`. Returns the number of
/// confirm-accepted passes.
#[allow(clippy::too_many_arguments)]
pub(crate) fn descend(
    cands: &[Vec<Candidate>],
    pick: &mut Vec<usize>,
    best: &mut Scheduled,
    seed_table: PriceTable,
    cfg: &SchedulerConfig,
    n_prep_units: usize,
    max_passes: usize,
    searchable: &[usize],
) -> usize {
    // The price table is priced exactly once (at the seed rebuild) and
    // then carried between passes: accepted swaps rebase it through
    // the delta evaluator, which keeps it bit-identical to a freshly
    // priced table for the current `pick` (per-op prices depend only
    // on the op's own layer's choice, and candidate prices match the
    // Pricer bit-for-bit — asserted by
    // `candidate_prices_match_pricer_exactly`).
    let mut accepted = 0usize;
    let mut table = Some(seed_table);
    for _pass in 0..max_passes {
        // Freeze the incumbent plan; build the delta evaluator over it.
        let carried = table.take().expect("price table carried between passes");
        let Ok(mut inc) = IncrementalEval::new(&best.set, &best.plan, carried) else {
            break;
        };

        // Proposal phase (parallel, read-only): per layer, the best
        // alternative candidate under delta re-evaluation of the
        // frozen incumbent. Layers are independent here, so trials
        // fan out across cores.
        let base_ms = inc.makespan();
        let proposals: Vec<Option<(usize, usize, f64)>> = {
            let (inc, set, pick, cands) = (&inc, &best.set, &*pick, cands);
            par_map(searchable, move |_, &layer| {
                let cs = &cands[layer];
                let cur = pick[layer];
                let mut best_alt: Option<(usize, f64)> = None;
                for alt in 0..cs.len() {
                    if alt == cur {
                        continue;
                    }
                    // Swapping one layer's kernel changes the makespan
                    // by at most the total |Δcost| of its ops; skip
                    // trials that cannot move the needle (§Perf).
                    let delta = (cs[alt].prep_ms - cs[cur].prep_ms).abs()
                        + (cs[alt].exec_ms - cs[cur].exec_ms).abs();
                    if delta < 0.02 {
                        continue;
                    }
                    let dirty = swap_prices(set, layer, &cs[alt]);
                    let Ok(ms) = inc.retime(set, &dirty) else { continue };
                    if ms + 1e-9 < base_ms && best_alt.map_or(true, |(_, b)| ms < b) {
                        best_alt = Some((alt, ms));
                    }
                }
                best_alt.map(|(alt, ms)| (layer, alt, ms))
            })
        };

        // Apply phase (sequential, most promising first): re-screen
        // each proposal against the working baseline, which shifts as
        // earlier swaps land; accepted swaps mutate `pick` in place
        // and rebase the evaluator's price table.
        let mut props: Vec<(usize, usize, f64)> =
            proposals.into_iter().flatten().collect();
        props.sort_by(|a, b| a.2.partial_cmp(&b.2).unwrap());
        let before_pick = pick.clone();
        let mut applied = false;
        for (layer, alt, _) in props {
            let dirty = swap_prices(&best.set, layer, &cands[layer][alt]);
            let Ok(ms) = inc.retime(&best.set, &dirty) else { continue };
            if ms + 1e-9 < inc.makespan() && inc.rebase(&best.set, &dirty).is_ok() {
                pick[layer] = alt;
                applied = true;
            }
        }
        if !applied {
            break;
        }

        // Confirm (incremental): re-run only the Algorithm-1 queue
        // assembly under the new kernel mix (bundle balancing may
        // shift) against the evaluator's rebased table — canonical op
        // sets guarantee the set structure and table are already
        // exact for `pick`, so no OpSet/Pricer/PriceTable rebuild.
        // Accept only a real improvement of the fully evaluated
        // makespan; otherwise the frozen-plan gains didn't survive
        // the re-assembly — converged.
        let trial = confirm_from_table(
            &best.set,
            choices_of(cands, pick),
            inc.table(),
            cfg,
            n_prep_units,
        );
        if trial.schedule.makespan + 1e-9 < best.schedule.makespan {
            table = Some(inc.into_table());
            *best = trial;
            accepted += 1;
        } else {
            *pick = before_pick;
            break;
        }
    }
    accepted
}

/// Run the NNV12 scheduler for a model on a device.
pub fn schedule(
    dev: &DeviceProfile,
    graph: &ModelGraph,
    registry: &Registry,
    cfg: &SchedulerConfig,
) -> Scheduled {
    let cands = build_candidates(dev, graph, registry, cfg);
    let n_prep_units = prep_units(dev);
    let mut pick = greedy_pick(&cands, cfg, n_prep_units);

    // --- Outer loop: incremental coordinate descent over combinations ---
    let (mut best, seed_table) = rebuild_with_table(dev, graph, &choices_of(&cands, &pick), cfg);
    if cfg.kernel_selection {
        let searchable: Vec<usize> =
            (0..cands.len()).filter(|&l| cands[l].len() >= 2).collect();
        descend(
            &cands,
            &mut pick,
            &mut best,
            seed_table,
            cfg,
            n_prep_units,
            cfg.max_outer_passes,
            &searchable,
        );
    }
    best
}

/// Outcome of one cross-device seeded search ([`schedule_seeded`]).
#[derive(Debug, Clone)]
pub struct TransferOutcome {
    /// The plan this search settled on (seeded short descent when the
    /// transfer was accepted, full cold search when it was rejected).
    /// Always at least as good as the greedy baseline — both branches
    /// only ever accept confirmed improvements over their start point.
    pub scheduled: Scheduled,
    /// Whether the transferred seed was accepted (its re-priced makespan
    /// on the target was no worse than the greedy baseline). Invariant:
    /// `seeded == seed_ms.is_some_and(|s| s <= baseline_ms)`.
    pub seeded: bool,
    /// The transferred seed's fully evaluated makespan on the *target*
    /// device (None when the seed didn't map structurally — wrong layer
    /// count — and was rejected without pricing).
    pub seed_ms: Option<Ms>,
    /// The greedy seed's makespan — the cold search's starting point and
    /// the bar the transferred seed had to clear.
    pub baseline_ms: Ms,
    /// Confirm-accepted descent passes this search ran (the fleet report
    /// aggregates cold-vs-seeded pass counts into "passes saved").
    pub passes: usize,
}

/// Cross-device plan transfer (ROADMAP item 3): run the scheduler with a
/// donor device's kernel choices as the starting point instead of a cold
/// search.
///
/// The donor's per-layer choices are mapped onto the target's
/// Pareto-filtered candidate sets (a choice the target's registry/filter
/// does not offer falls back to the greedy pick for that layer; a seed
/// with the wrong layer count is rejected outright). The mapped seed is
/// then *re-priced on the target* without a second cost-model run: the
/// greedy rebuild's price table is patched at the disagreeing layers only
/// — canonical op sets make a kernel swap an exact 3-entry delta
/// ([`swap_prices`]), so the patched table is bit-identical to a freshly
/// priced one and the seed's evaluation through [`confirm_from_table`] is
/// bit-exact against the [`inner_schedule`] full-rebuild oracle
/// (property-tested in `tests/fleet_transfer.rs`).
///
/// Accept/reject gate: the transferred seed is accepted only when its
/// re-priced makespan is **no worse than the greedy baseline** — transfer
/// must never start the descent from a worse point than a cold search
/// would. Accepted seeds get a *short* descent (at most one pass,
/// restricted to the layers the seed actually changed: the transfer's
/// entire payoff is skipping the full pass budget); rejected seeds fall
/// back to the full cold search, bit-identical to [`schedule`].
pub fn schedule_seeded(
    dev: &DeviceProfile,
    graph: &ModelGraph,
    registry: &Registry,
    cfg: &SchedulerConfig,
    seed_choices: &[Option<KernelChoice>],
) -> TransferOutcome {
    let cands = build_candidates(dev, graph, registry, cfg);
    let n_prep_units = prep_units(dev);
    let mut pick = greedy_pick(&cands, cfg, n_prep_units);
    let (greedy, greedy_table) =
        rebuild_with_table(dev, graph, &choices_of(&cands, &pick), cfg);
    let baseline_ms = greedy.schedule.makespan;

    let cold = |mut pick: Vec<usize>, mut best: Scheduled, table: PriceTable, seed_ms| {
        let passes = if cfg.kernel_selection {
            let searchable: Vec<usize> =
                (0..cands.len()).filter(|&l| cands[l].len() >= 2).collect();
            descend(
                &cands,
                &mut pick,
                &mut best,
                table,
                cfg,
                n_prep_units,
                cfg.max_outer_passes,
                &searchable,
            )
        } else {
            0
        };
        TransferOutcome { scheduled: best, seeded: false, seed_ms, baseline_ms, passes }
    };

    // Map the donor's choices onto the target's candidate sets.
    if seed_choices.len() != cands.len() {
        // Structural mismatch (seed is for a different architecture):
        // nothing to transfer — full cold search.
        return cold(pick, greedy, greedy_table, None);
    }
    let mut seed_pick = pick.clone();
    let mut disagree: Vec<usize> = Vec::new();
    for (layer, seed) in seed_choices.iter().enumerate() {
        let Some(seed) = seed else { continue };
        let Some(alt) = cands[layer].iter().position(|c| c.choice == *seed) else {
            // Target doesn't offer this kernel/cache variant: keep greedy.
            continue;
        };
        if alt != seed_pick[layer] {
            seed_pick[layer] = alt;
            disagree.push(layer);
        }
    }

    // Re-price the transferred seed on the target: patch the greedy table
    // at the disagreeing layers (exact 3-entry deltas), then one full
    // evaluation through the incremental confirm. No OpSet/Pricer
    // rebuild — the canonical set is choice-independent.
    let mut seed_table = greedy_table.clone();
    for &layer in &disagree {
        for (op, gang, little) in
            swap_prices(&greedy.set, layer, &cands[layer][seed_pick[layer]])
        {
            seed_table.set_op(op, gang, little);
        }
    }
    let seed_eval = confirm_from_table(
        &greedy.set,
        choices_of(&cands, &seed_pick),
        &seed_table,
        cfg,
        n_prep_units,
    );
    let seed_ms = seed_eval.schedule.makespan;
    if seed_ms > baseline_ms {
        // The seed revalidated worse than the greedy baseline: transferring
        // it would start the descent from a worse point than a cold search.
        return cold(pick, greedy, greedy_table, Some(seed_ms));
    }

    // Accepted: short descent (≤ 1 pass) over only the transferred layers.
    pick = seed_pick;
    let mut best = seed_eval;
    let passes = if cfg.kernel_selection {
        let searchable: Vec<usize> =
            disagree.iter().copied().filter(|&l| cands[l].len() >= 2).collect();
        descend(
            &cands,
            &mut pick,
            &mut best,
            seed_table,
            cfg,
            n_prep_units,
            cfg.max_outer_passes.min(1),
            &searchable,
        )
    } else {
        0
    };
    TransferOutcome {
        scheduled: best,
        seeded: true,
        seed_ms: Some(seed_ms),
        baseline_ms,
        passes,
    }
}

/// Price deltas for re-evaluating `layer` as if it used `cand` — the dirty
/// set handed to [`IncrementalEval::retime`]. Canonical op sets
/// materialize read/transform/exec ops for every weighted layer (a
/// bypassing candidate's transform prices as 0), so the swap is
/// *structurally exact*: exactly these three table entries change. The
/// historical read+transform fold — used when the incumbent set lacked a
/// transform op, and wrong whenever read and transform were not
/// contention-adjacent — is gone.
pub fn swap_prices(set: &OpSet, layer: usize, cand: &Candidate) -> Vec<PriceDelta> {
    let r = set.read_of[layer].expect("swap_prices: layer has no read op");
    let w = set.transform_of[layer]
        .expect("swap_prices: canonical op sets always carry a transform op");
    let e = set.exec_of[layer].expect("swap_prices: layer has no exec op");
    vec![
        (r, cand.read_g, cand.read_l),
        (w, cand.tf_g, cand.tf_l),
        (e, cand.exec_g, cand.exec_l),
    ]
}

/// §3.3 "NNV12 keeps calibrating the per-operation performance through
/// re-profiling for better scheduling planning": the static planner prices
/// operations without I/O interference, but concurrent little-core reads
/// share the device's disk bandwidth, so using *every* little core for
/// preparations can be slower than using a few. This wrapper evaluates a
/// small family of prep-parallelism degrees under the contention-aware
/// simulator and keeps the best plan. Returns the plan plus the (possibly
/// reduced) device view it was planned against.
pub fn schedule_calibrated(
    dev: &DeviceProfile,
    graph: &ModelGraph,
    registry: &Registry,
    cfg: &SchedulerConfig,
) -> (Scheduled, DeviceProfile) {
    let full = prep_units(dev);
    if full == 0 {
        // No preparation cores to tune: sequential-ish plan on the gang.
        let s = schedule(dev, graph, registry, cfg);
        return (s, dev.clone());
    }
    let mut degrees: Vec<usize> = vec![full, (full + 1) / 2, 2, 1];
    degrees.retain(|&n| n >= 1 && n <= full);
    degrees.dedup();
    let mut best: Option<(Scheduled, DeviceProfile, f64)> = None;
    for n in degrees {
        let mut d = dev.clone();
        if dev.executes_on_gpu() {
            // Prep cores on GPU devices are all CPU cores; shrink both
            // pools proportionally via n_little (price module uses n_cpu).
            let cut = full - n;
            let cut_little = cut.min(d.n_little);
            d.n_little -= cut_little;
            d.n_big -= (cut - cut_little).min(d.n_big);
        } else {
            d.n_little = n;
        }
        let s = schedule(&d, graph, registry, cfg);
        let pricer = Pricer::new(&d, graph, &s.plan.choices, cfg.shader_cache);
        let sim = crate::sim::simulate(
            &d,
            &s.set,
            &s.plan,
            &pricer,
            &crate::sim::SimConfig { stealing: cfg.pipeline, contention: true, background: vec![] },
        );
        match &best {
            Some((_, _, m)) if *m <= sim.makespan => {}
            _ => best = Some((s, d, sim.makespan)),
        }
    }
    let (s, d, _) = best.unwrap();
    (s, d)
}

/// Inner layer of Algorithm 1: schedule one kernel combination from
/// scratch — canonical op set, pricer, flat price table, queue assembly,
/// evaluation. The production search runs this exactly once (to seed);
/// pass-end confirms go through [`confirm_from_table`], which skips
/// everything but the assembly. Kept `pub` as the full-rebuild oracle the
/// property tests (`tests/canonical_confirm.rs`) compare the incremental
/// confirm against — both paths share the private `assemble_plan` core,
/// so agreement is bit-exact by construction *given* an exact table, and
/// the tests pin the table-exactness half.
pub fn inner_schedule(
    dev: &DeviceProfile,
    graph: &ModelGraph,
    choices: &[Option<KernelChoice>],
    cfg: &SchedulerConfig,
) -> Scheduled {
    rebuild_with_table(dev, graph, choices, cfg).0
}

/// [`inner_schedule`] that also returns the freshly priced table, so the
/// outer search seeds its pass-carried table without pricing twice.
pub(crate) fn rebuild_with_table(
    dev: &DeviceProfile,
    graph: &ModelGraph,
    choices: &[Option<KernelChoice>],
    cfg: &SchedulerConfig,
) -> (Scheduled, PriceTable) {
    let set = Arc::new(OpSet::build(graph, choices, dev.executes_on_gpu()));
    let pricer = Pricer::new(dev, graph, choices, cfg.shader_cache);
    // Flat price table: the cost model runs once per op here; everything
    // below (bundle sizing, balancing, evaluation) is array lookups.
    let table = PriceTable::build(&set, &pricer);
    let scheduled = assemble_plan(&set, choices.to_vec(), &table, cfg, pricer.n_little_units());
    (scheduled, table)
}

/// The incremental pass-end confirm of the outer search: re-run only the
/// Algorithm-1 queue assembly (bundle promotion + little-core balancing)
/// and one full evaluation, against an op set and price table that are
/// already exact for `choices`. Canonical op sets make this sound: a
/// kernel swap never changes the op-set structure, and the delta
/// evaluator's rebased table is bit-identical to the table a full rebuild
/// would derive — so this skips the `OpSet`/`Pricer`/`PriceTable`
/// reconstruction (every cost-model call) of [`inner_schedule`] and is
/// bit-exact against it (property-tested in
/// `tests/canonical_confirm.rs`).
pub fn confirm_from_table(
    set: &Arc<OpSet>,
    choices: Vec<Option<KernelChoice>>,
    table: &PriceTable,
    cfg: &SchedulerConfig,
    n_little: usize,
) -> Scheduled {
    assemble_plan(set, choices, table, cfg, n_little)
}

/// Algorithm-1 queue assembly + evaluation over a prebuilt price table —
/// the shared core of [`inner_schedule`] and [`confirm_from_table`]. No
/// cost-model work happens here: bundle costs come from `table`, the
/// big-core promotion loop is O(layers × little cores) via precomputed
/// round-robin suffix loads (the historical per-iteration re-summation
/// was the search's last O(layers²) step), and the little-core balancing
/// loop carries per-queue load accumulators across moves, so it is
/// O(moves × n_little) instead of re-summing every queue per iteration.
fn assemble_plan(
    set: &Arc<OpSet>,
    choices: Vec<Option<KernelChoice>>,
    table: &PriceTable,
    cfg: &SchedulerConfig,
    n_little: usize,
) -> Scheduled {
    let gpu = set.driver_init.is_some();

    if !cfg.pipeline || n_little == 0 {
        // Sequential cold inference: every op on the gang in id order
        // (reads, transforms, pipelines, execs interleaved per layer).
        let plan = Plan {
            choices,
            gang: (0..set.len()).collect(),
            little: vec![Vec::new(); n_little],
            estimated_ms: 0.0,
        };
        let schedule = evaluate_with(set, &plan, table).expect("sequential plan valid");
        let estimated = schedule.makespan;
        return Scheduled {
            plan: Plan { estimated_ms: estimated, ..plan },
            schedule,
            set: set.clone(),
        };
    }

    // Preparation bundles: per weighted layer, [read, transform] and on
    // GPU also the pipeline-creation op.
    let bundle_ops = |layer: usize| -> Vec<usize> {
        let mut v = set.prep_bundle(layer);
        if let Some(p) = set.pipeline_of[layer] {
            v.push(p);
        }
        v
    };
    // Perf: bundle costs are reused many times by the loops below — price
    // each bundle exactly once from the table.
    let n_layers = set.read_of.len();
    let mut b_gang_v = vec![0.0f64; n_layers];
    let mut b_little_v = vec![0.0f64; n_layers];
    for layer in 0..n_layers {
        for op in bundle_ops(layer) {
            b_gang_v[layer] += table.gang[op];
            b_little_v[layer] += table.little[op];
        }
    }
    let bundle_ms =
        |layer: usize, on_gang: bool| -> Ms { if on_gang { b_gang_v[layer] } else { b_little_v[layer] } };

    let prep_layers = set.prep_layers();
    // Weightless GPU layers still need their pipeline op scheduled; bundle
    // them with preparations on little cores.
    let mut extra_pipeline_layers: Vec<usize> = Vec::new();
    if gpu {
        for (layer, p) in set.pipeline_of.iter().enumerate() {
            if p.is_some() && set.read_of[layer].is_none() {
                extra_pipeline_layers.push(layer);
            }
        }
    }

    // Gang queue: driver init, first bundle (fast boot), then all execs.
    let mut gang: Vec<usize> = Vec::new();
    if let Some(di) = set.driver_init {
        gang.push(di);
    }
    // `s` = number of leading prep bundles promoted to the gang (Alg. 1
    // starts with the first layer's r_1, w_1 on the big cores).
    let mut s = 1.min(prep_layers.len());
    // Exec ops in id order.
    let execs: Vec<usize> = set
        .ops
        .iter()
        .filter(|o| o.stage == OpStage::Exec)
        .map(|o| o.id)
        .collect();

    // Gang exec time (fixed part) + promoted bundles (variable part).
    let exec_total: Ms = execs.iter().map(|&e| table.gang[e]).sum::<f64>()
        + set.driver_init.map(|di| table.gang[di]).unwrap_or(0.0);

    // --- Big-core loop (Alg. 1 lines 6–11) ---
    // Balance T_Q0 against the round-robin little-core load; promote the
    // next bundle while the littles remain the bottleneck.
    //
    // §Perf: every candidate `s` needs the most-loaded little core after
    // round-robining bundles `s..`. Dropping the leading bundle shifts
    // each remaining bundle's core by one, so the suffix loads obey a
    // rotation recurrence — suffix(s)[0] = b(l_s) + suffix(s+1)[n−1],
    // suffix(s)[c] = suffix(s+1)[c−1] — and all of them precompute
    // back-to-front in O(layers × n_little) pure additions, instead of
    // the O(layers) re-summation per promotion step that made the
    // assembly O(layers²).
    let s0 = s;
    let n_suffix = prep_layers.len() - s0;
    let mut extra_loads = vec![0.0f64; n_little];
    for (idx, &l) in extra_pipeline_layers.iter().enumerate() {
        extra_loads[idx % n_little] += bundle_ms(l, false);
    }
    let mut suffix: Vec<Vec<f64>> = vec![vec![0.0f64; n_little]; n_suffix + 1];
    for i in (0..n_suffix).rev() {
        let b = bundle_ms(prep_layers[s0 + i], false);
        let prev = suffix[i + 1].clone();
        let row = &mut suffix[i];
        row[0] = b + prev[n_little - 1];
        row[1..n_little].copy_from_slice(&prev[..n_little - 1]);
    }
    let mut promoted_ms: Ms = prep_layers[..s].iter().map(|&l| bundle_ms(l, true)).sum();
    loop {
        let t_q0: Ms = exec_total + promoted_ms;
        // Estimated little-core max load with bundles s.. round-robined.
        let loads = &suffix[s - s0];
        let t_max = (0..n_little)
            .map(|c| loads[c] + extra_loads[c])
            .fold(0.0, f64::max);
        if t_max <= t_q0 + cfg.epsilon_ms || s >= prep_layers.len() {
            break;
        }
        // Alg. 1 line 9: promote only if the move still leaves the gang
        // ahead (big time added + little time removed < gap).
        let next = prep_layers[s];
        if bundle_ms(next, true) + bundle_ms(next, false) < t_max - t_q0 {
            promoted_ms += bundle_ms(next, true);
            s += 1;
        } else {
            break;
        }
    }

    for &l in &prep_layers[..s] {
        gang.extend(bundle_ops(l));
    }
    gang.extend(execs.iter().copied());

    // --- Little-core init (Alg. 1 line 12): round-robin remaining bundles.
    let mut little_layers: Vec<Vec<usize>> = vec![Vec::new(); n_little];
    for (idx, &l) in prep_layers[s..].iter().enumerate() {
        little_layers[idx % n_little].push(l);
    }
    for (idx, &l) in extra_pipeline_layers.iter().enumerate() {
        little_layers[idx % n_little].push(l);
    }

    // --- Little-core balancing loop (Alg. 1 lines 13–20) ---
    // §Perf: per-queue loads are summed once up front and then carried
    // across moves as accumulators (`loads[j_max] -= b; loads[j_min] +=
    // b`), so each iteration is an O(n_little) max/min scan plus the
    // move itself — O(moves) total bundle-cost work, instead of
    // re-summing every queue (O(prep layers)) per iteration. Both the
    // full rebuild and the incremental confirm share this code, so the
    // confirm's bit-exactness oracle is unaffected.
    let load_of = |layers: &[usize]| -> Ms {
        layers.iter().map(|&l| bundle_ms(l, false)).sum()
    };
    let mut loads: Vec<Ms> = little_layers.iter().map(|q| load_of(q)).collect();
    for _ in 0..4 * n_little.max(1) {
        let (j_max, &t_max) = loads
            .iter()
            .enumerate()
            .max_by(|(_, a), (_, b)| a.partial_cmp(b).unwrap())
            .unwrap();
        let (j_min, &t_min) = loads
            .iter()
            .enumerate()
            .min_by(|(_, a), (_, b)| a.partial_cmp(b).unwrap())
            .unwrap();
        if t_max - t_min <= cfg.epsilon_ms || j_max == j_min {
            break;
        }
        // Largest bundle that fits in half the gap (Alg. 1 line 18).
        let mut moved = false;
        let mut order: Vec<usize> = little_layers[j_max].clone();
        order.sort_by(|&a, &b| {
            bundle_ms(b, false).partial_cmp(&bundle_ms(a, false)).unwrap()
        });
        for l in order {
            let b = bundle_ms(l, false);
            if b < (t_max - t_min) / 2.0 {
                little_layers[j_max].retain(|&x| x != l);
                little_layers[j_min].push(l);
                loads[j_max] -= b;
                loads[j_min] += b;
                moved = true;
                break;
            }
        }
        if !moved {
            break;
        }
    }

    // Within each little core, run bundles in layer order so early layers'
    // preparations finish before the gang needs them.
    let little: Vec<Vec<usize>> = little_layers
        .into_iter()
        .map(|mut layers| {
            layers.sort_unstable();
            layers.into_iter().flat_map(|l| bundle_ops(l)).collect()
        })
        .collect();

    let plan = Plan {
        choices,
        gang,
        little,
        estimated_ms: 0.0,
    };
    let schedule = evaluate_with(set, &plan, table).expect("heuristic plan valid");
    let estimated = schedule.makespan;
    Scheduled {
        plan: Plan { estimated_ms: estimated, ..plan },
        schedule,
        set: set.clone(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::profiles;
    use crate::graph::zoo;

    fn run(dev: &DeviceProfile, model: &str, cfg: &SchedulerConfig) -> f64 {
        let g = zoo::by_name(model).unwrap();
        let s = schedule(dev, &g, &Registry::full(), cfg);
        s.plan.validate(&s.set).unwrap();
        s.schedule.makespan
    }

    #[test]
    fn ablation_order_k_kc_kcp() {
        // Fig. 13: each knob must improve cold latency:
        // sequential-warm-default ≥ K ≥ K+C ≥ K+C+P.
        let dev = profiles::meizu_16t();
        for model in ["googlenet", "resnet50", "mobilenetv2"] {
            let none = run(
                &dev,
                model,
                &SchedulerConfig {
                    kernel_selection: false,
                    weight_cache: false,
                    shader_cache: false,
                    pipeline: false,
                    ..SchedulerConfig::default()
                },
            );
            let k = run(&dev, model, &SchedulerConfig::k_only());
            let kc = run(&dev, model, &SchedulerConfig::kc());
            let kcp = run(&dev, model, &SchedulerConfig::kcp());
            assert!(k <= none * 1.001, "{model}: K {k} > none {none}");
            assert!(kc <= k * 1.001, "{model}: KC {kc} > K {k}");
            assert!(kcp <= kc * 1.001, "{model}: KCP {kcp} > KC {kc}");
            // And the full system is a substantial win.
            assert!(kcp < none * 0.7, "{model}: KCP {kcp} vs none {none}");
        }
    }

    #[test]
    fn cold_close_to_warm_bound() {
        // Paper: NNV12 is only 1.72× slower than warm at average; assert
        // cold/warm < 4 on the primary device across models.
        let dev = profiles::meizu_16t();
        let cm = crate::cost::CostModel::new(&dev);
        for model in ["mobilenet", "shufflenetv2", "resnet50", "googlenet"] {
            let g = zoo::by_name(model).unwrap();
            let warm = cm.warm_ms(&g, &Registry::full());
            let cold = run(&dev, model, &SchedulerConfig::kcp());
            let ratio = cold / warm;
            assert!(
                (1.0..4.0).contains(&ratio),
                "{model}: cold {cold:.1} / warm {warm:.1} = {ratio:.2}"
            );
        }
    }

    #[test]
    fn gpu_scheduling_works() {
        let dev = profiles::jetson_tx2();
        let g = zoo::resnet50();
        let s = schedule(&dev, &g, &Registry::full(), &SchedulerConfig::kcp());
        s.plan.validate(&s.set).unwrap();
        assert!(s.schedule.makespan.is_finite());
        // Without the shader cache it must be slower.
        let no_cache = schedule(
            &dev,
            &g,
            &Registry::full(),
            &SchedulerConfig { shader_cache: false, ..SchedulerConfig::kcp() },
        );
        assert!(no_cache.schedule.makespan > s.schedule.makespan);
    }

    #[test]
    fn winning_choices_carried_into_plan() {
        // Regression: the outer search must return the plan built from the
        // winning kernel combination, not just its makespan. Re-evaluating
        // the returned (set, plan, choices) triple from scratch must
        // reproduce the reported makespan exactly.
        let dev = profiles::meizu_16t();
        for model in ["resnet50", "googlenet"] {
            let g = zoo::by_name(model).unwrap();
            let s = schedule(&dev, &g, &Registry::full(), &SchedulerConfig::kcp());
            let pricer = Pricer::new(&dev, &g, &s.plan.choices, true);
            let again = crate::sched::makespan::evaluate(&s.set, &s.plan, &pricer).unwrap();
            assert_eq!(
                again.makespan.to_bits(),
                s.schedule.makespan.to_bits(),
                "{model}: plan choices disagree with reported makespan"
            );
            assert_eq!(s.plan.estimated_ms.to_bits(), s.schedule.makespan.to_bits());
        }
    }

    #[test]
    fn search_never_worse_than_greedy_seed() {
        // The incremental descent only accepts confirmed full-rebuild
        // improvements, so it can never return a worse plan than a search
        // with zero passes (= the greedy seed).
        let dev = profiles::meizu_16t();
        for model in ["resnet50", "mobilenetv2", "squeezenet"] {
            let g = zoo::by_name(model).unwrap();
            let seed_only = schedule(
                &dev,
                &g,
                &Registry::full(),
                &SchedulerConfig { max_outer_passes: 0, ..SchedulerConfig::kcp() },
            );
            let searched = schedule(&dev, &g, &Registry::full(), &SchedulerConfig::kcp());
            assert!(
                searched.schedule.makespan <= seed_only.schedule.makespan + 1e-9,
                "{model}: search {} worse than seed {}",
                searched.schedule.makespan,
                seed_only.schedule.makespan
            );
        }
    }

    #[test]
    fn plans_valid_across_zoo_and_devices() {
        for dev in [profiles::meizu_16t(), profiles::pixel_5(), profiles::jetson_nano()] {
            for model in ["tinynet", "squeezenet", "mobilenetv2", "crnn-lite"] {
                let g = zoo::by_name(model).unwrap();
                let s = schedule(&dev, &g, &Registry::full(), &SchedulerConfig::kcp());
                s.plan.validate(&s.set).unwrap();
                assert!(
                    s.schedule.makespan.is_finite() && s.schedule.makespan > 0.0,
                    "{} on {}",
                    model,
                    dev.name
                );
            }
        }
    }
}
