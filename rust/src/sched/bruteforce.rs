//! Exact scheduling oracle for tiny instances.
//!
//! The §3.2 problem is NP-hard; for graphs with a handful of layers we can
//! enumerate (kernel combination × op-to-unit assignment) exhaustively and
//! verify the heuristic lands within a small factor of optimal. Test-only
//! scale: it explodes beyond ~4 weighted layers.

use crate::device::DeviceProfile;
use crate::graph::ModelGraph;
use crate::kernels::Registry;
use crate::sched::filter::candidates;
use crate::sched::makespan::evaluate_with;
use crate::sched::op::{OpSet, OpStage};
use crate::sched::plan::{KernelChoice, Plan};
use crate::sched::price::{PriceTable, Pricer};
use crate::Ms;

/// Exhaustively find the best makespan. `n_little` caps the little cores
/// considered (keeps the search tractable).
pub fn best_makespan(
    dev: &DeviceProfile,
    graph: &ModelGraph,
    registry: &Registry,
    n_little: usize,
) -> Ms {
    let cand_sets: Vec<Vec<KernelChoice>> = graph
        .layers()
        .iter()
        .map(|l| {
            if !l.op.has_weights() {
                return vec![];
            }
            candidates(dev, l, registry, true)
                .into_iter()
                .map(|c| c.choice)
                .collect()
        })
        .collect();

    let mut best = f64::INFINITY;
    let mut combo_idx: Vec<usize> = vec![0; graph.len()];
    loop {
        let choices: Vec<Option<KernelChoice>> = cand_sets
            .iter()
            .zip(&combo_idx)
            .map(|(cs, &i)| cs.get(i).cloned())
            .collect();
        best = best.min(best_assignment(dev, graph, &choices, n_little));

        // Advance the mixed-radix counter over kernel combinations.
        let mut carry = true;
        for (i, cs) in cand_sets.iter().enumerate() {
            if !carry || cs.len() <= 1 {
                continue;
            }
            combo_idx[i] += 1;
            if combo_idx[i] < cs.len() {
                carry = false;
            } else {
                combo_idx[i] = 0;
            }
        }
        if carry {
            break;
        }
    }
    best
}

/// Best makespan over all prep-bundle→unit assignments for fixed choices.
/// Execs stay on the gang (assumption 1 of §3.3 — also holds for the
/// optimum whenever the gang is the fastest unit, which our devices
/// guarantee). Bundles may go on the gang (before execs) or any little
/// core; within a unit they run in layer order.
fn best_assignment(
    dev: &DeviceProfile,
    graph: &ModelGraph,
    choices: &[Option<KernelChoice>],
    n_little: usize,
) -> Ms {
    let gpu = dev.executes_on_gpu();
    let set = OpSet::build(graph, choices, gpu);
    let pricer = Pricer::new(dev, graph, choices, true);
    // (set, choices) are fixed across the n_units^bundles enumerated
    // plans: price once, evaluate by table lookup.
    let table = PriceTable::build(&set, &pricer);
    let prep_layers = set.prep_layers();
    let n_units = n_little + 1; // 0 = gang
    let mut best = f64::INFINITY;

    let execs: Vec<usize> = set
        .ops
        .iter()
        .filter(|o| o.stage == OpStage::Exec)
        .map(|o| o.id)
        .collect();

    let mut assign = vec![0usize; prep_layers.len()];
    loop {
        // Build queues from the assignment.
        let mut gang: Vec<usize> = Vec::new();
        if let Some(di) = set.driver_init {
            gang.push(di);
        }
        let mut little: Vec<Vec<usize>> = vec![Vec::new(); n_little];
        for (b, &layer) in prep_layers.iter().enumerate() {
            let mut ops = set.prep_bundle(layer);
            if let Some(p) = set.pipeline_of[layer] {
                ops.push(p);
            }
            if assign[b] == 0 {
                gang.extend(ops);
            } else {
                little[assign[b] - 1].extend(ops);
            }
        }
        // Pipeline ops of weightless layers ride on the gang.
        for (layer, p) in set.pipeline_of.iter().enumerate() {
            if let Some(p) = p {
                if set.read_of[layer].is_none() {
                    gang.push(*p);
                }
            }
        }
        gang.extend(execs.iter().copied());
        let plan = Plan {
            choices: choices.to_vec(),
            gang,
            little,
            estimated_ms: 0.0,
        };
        if let Ok(s) = evaluate_with(&set, &plan, &table) {
            best = best.min(s.makespan);
        }

        // Advance assignment counter (base n_units).
        let mut carry = true;
        for a in assign.iter_mut() {
            if !carry {
                break;
            }
            *a += 1;
            if *a < n_units {
                carry = false;
            } else {
                *a = 0;
            }
        }
        if carry || prep_layers.is_empty() {
            break;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::profiles;
    use crate::graph::builder::GraphBuilder;
    use crate::sched::heuristic::{schedule, SchedulerConfig};

    fn tiny_chain(n_convs: u32) -> ModelGraph {
        let mut b = GraphBuilder::new("chain");
        b.input(4, 16);
        for i in 0..n_convs {
            b.conv(&format!("c{i}"), 8 + 4 * i, 3, 1);
        }
        b.build().unwrap()
    }

    #[test]
    fn heuristic_within_factor_of_optimal() {
        let mut dev = profiles::meizu_16t();
        dev.n_little = 2; // keep the brute force tractable
        let reg = Registry::full();
        for n in [2u32, 3] {
            let g = tiny_chain(n);
            let opt = best_makespan(&dev, &g, &reg, 2);
            let h = schedule(&dev, &g, &reg, &SchedulerConfig::kcp());
            let ratio = h.schedule.makespan / opt;
            assert!(
                ratio < 1.35,
                "chain{n}: heuristic {:.3} vs optimal {:.3} (x{:.2})",
                h.schedule.makespan,
                opt,
                ratio
            );
            assert!(ratio >= 1.0 - 1e-9, "heuristic beat 'optimal'?!");
        }
    }

    #[test]
    fn bruteforce_explores_kernel_combinations() {
        // With kernel selection restricted to warm defaults, the optimum
        // must be no better than with the full registry.
        let mut dev = profiles::meizu_16t();
        dev.n_little = 2;
        let g = tiny_chain(2);
        let full = best_makespan(&dev, &g, &Registry::full(), 2);
        let warm = best_makespan(&dev, &g, &Registry::warm_default(), 2);
        assert!(full <= warm + 1e-9, "full {full} vs warm-only {warm}");
    }
}
