//! Operation costing on scheduling units.
//!
//! Bridges the [`CostModel`] (per-stage, per-core-class rates) to the
//! scheduler's view (gang vs little-core units, kernel choices, cache
//! decisions). On CPU devices the gang is all big cores: exec ops use every
//! big core (multithreaded), while read/transform ops placed on the gang
//! use a single big core (the others are not useful for I/O — Fig. 6). On
//! GPU devices the gang is the GPU; read/transform land on the board's CPU
//! cores, which all play the "little" role (§3.4).

use crate::cost::CostModel;
use crate::device::{CoreClass, DeviceProfile};
use crate::graph::ModelGraph;
use crate::sched::op::{OpId, OpSet, OpStage, Operation};
use crate::sched::plan::{KernelChoice, UnitId};
use crate::Ms;

/// Flat per-op × per-unit-class price table.
///
/// Every scheduling unit is either the gang or a little core, and op cost
/// depends only on that *class* (all little cores are identical — see
/// [`Pricer::price`], which matches `Little(_)` without inspecting the
/// index). The table therefore needs exactly two lanes. Building it runs
/// the full [`CostModel`] once per op; afterwards the evaluator, the
/// heuristic's bundle sizing, and the simulator are pure array lookups and
/// never re-derive a cost.
///
/// Invariants:
/// * `gang[i]`/`little[i]` equal `pricer.price(&set.ops[i], Gang)` /
///   `price(.., Little(0))` for the `(set, pricer)` the table was built
///   from (asserted by `table_matches_pricer` below);
/// * entries are finite and ≥ 0;
/// * [`PriceTable::set_op`] is the only mutation, used by the outer search
///   to swap one layer's kernel prices in place.
#[derive(Debug, Clone)]
pub struct PriceTable {
    pub gang: Vec<Ms>,
    pub little: Vec<Ms>,
}

impl PriceTable {
    /// Price every op of `set` on both unit classes.
    pub fn build(set: &OpSet, pricer: &Pricer) -> PriceTable {
        let mut gang = Vec::with_capacity(set.len());
        let mut little = Vec::with_capacity(set.len());
        for op in &set.ops {
            gang.push(pricer.price(op, UnitId::Gang));
            little.push(pricer.price(op, UnitId::Little(0)));
        }
        PriceTable { gang, little }
    }

    #[inline]
    pub fn get(&self, op: OpId, unit: UnitId) -> Ms {
        match unit {
            UnitId::Gang => self.gang[op],
            UnitId::Little(_) => self.little[op],
        }
    }

    /// Lookup by flat unit index (0 = gang, 1.. = little cores), the
    /// layout [`crate::sched::plan::Plan::queues`] flattens to.
    #[inline]
    pub fn by_unit_idx(&self, op: OpId, unit_idx: usize) -> Ms {
        if unit_idx == 0 {
            self.gang[op]
        } else {
            self.little[op]
        }
    }

    /// Swap one op's prices (both classes) in place.
    #[inline]
    pub fn set_op(&mut self, op: OpId, gang: Ms, little: Ms) {
        self.gang[op] = gang;
        self.little[op] = little;
    }
}

/// Prices operations for one (device, model, choices) triple.
pub struct Pricer<'a> {
    pub cm: CostModel<'a>,
    pub graph: &'a ModelGraph,
    pub choices: &'a [Option<KernelChoice>],
    /// Whether the shader cache covers this model (GPU; §3.4).
    pub shader_cache: bool,
}

impl<'a> Pricer<'a> {
    pub fn new(
        dev: &'a DeviceProfile,
        graph: &'a ModelGraph,
        choices: &'a [Option<KernelChoice>],
        shader_cache: bool,
    ) -> Pricer<'a> {
        Pricer { cm: CostModel::new(dev), graph, choices, shader_cache }
    }

    fn dev(&self) -> &DeviceProfile {
        self.cm.dev
    }

    /// Number of little-core units available for preparations — delegates
    /// to [`DeviceProfile::prep_units`], the single source also used by
    /// the scheduler's seed rebuild and incremental confirm (they must
    /// agree, or confirm-vs-oracle bit-exactness silently breaks).
    pub fn n_little_units(&self) -> usize {
        self.dev().prep_units()
    }

    /// Bytes the read op must fetch: raw weights, or the (larger)
    /// post-transformed cache when the choice bypasses transformation.
    pub fn read_bytes(&self, layer: usize) -> u64 {
        let l = self.graph.layer(layer);
        match &self.choices[layer] {
            Some(c) if c.cache => c.kernel.transformed_bytes(l),
            _ => l.weight_bytes(),
        }
    }

    /// Price `op` on `unit`.
    pub fn price(&self, op: &Operation, unit: UnitId) -> Ms {
        let l = self.graph.layer(op.layer);
        let choice = self.choices[op.layer].as_ref();
        match op.stage {
            OpStage::DriverInit => self.cm.gpu_driver_init_ms(),
            OpStage::Read => {
                let class = self.unit_class_io(unit);
                self.cm.read_ms(self.read_bytes(op.layer), class, 1)
            }
            OpStage::Transform => {
                // Canonical op sets materialize a transform op for every
                // weighted layer; a bypassed one (cached weights, or a
                // transform-free family) prices as 0. This is what lets a
                // kernel swap be a pure 3-entry price delta — the op-set
                // structure never changes with the choice.
                let class = self.unit_class_io(unit);
                match choice {
                    Some(c) if c.kernel.family.needs_transform() && !c.cache => {
                        self.cm.transform_ms(&c.kernel, l, class, 1)
                    }
                    _ => 0.0,
                }
            }
            OpStage::Pipeline => self.cm.pipeline_create_ms(self.shader_cache),
            OpStage::Exec => {
                let (class, threads) = match unit {
                    UnitId::Gang => self.cm.exec_class(),
                    // Execution on a little core: single-threaded (the
                    // heuristic never does this, but workload stealing and
                    // the brute-force oracle may).
                    UnitId::Little(_) => (CoreClass::Little, 1),
                };
                match choice {
                    Some(c) => self.cm.exec_ms(&c.kernel, l, class, threads),
                    None => {
                        // Weightless builtin.
                        let k = crate::kernels::Kernel::new(
                            "builtin",
                            crate::kernels::KernelFamily::Builtin,
                        );
                        self.cm.exec_ms(&k, l, class, threads)
                    }
                }
            }
        }
    }

    /// Core class used by I/O-ish ops (read/transform) on a unit.
    fn unit_class_io(&self, unit: UnitId) -> CoreClass {
        match unit {
            // On GPU devices, preparations on the gang actually run on the
            // strongest CPU core (the GPU does not read/transform — §3.4).
            UnitId::Gang if self.dev().executes_on_gpu() => CoreClass::Big,
            UnitId::Gang => CoreClass::Big,
            UnitId::Little(_) => CoreClass::Little,
        }
    }

    /// Preparation cost (read + transform) of a layer on a little core —
    /// the `t^l` values of Algorithm 1.
    pub fn prep_ms_little(&self, layer: usize) -> Ms {
        self.prep_ms(layer, UnitId::Little(0))
    }

    /// Preparation cost on the gang (big core) — the `t^b` values.
    pub fn prep_ms_gang(&self, layer: usize) -> Ms {
        self.prep_ms(layer, UnitId::Gang)
    }

    fn prep_ms(&self, layer: usize, unit: UnitId) -> Ms {
        let l = self.graph.layer(layer);
        if !l.op.has_weights() {
            return 0.0;
        }
        let class = self.unit_class_io(unit);
        let read = self.cm.read_ms(self.read_bytes(layer), class, 1);
        let transform = match &self.choices[layer] {
            Some(c) if c.kernel.family.needs_transform() && !c.cache => {
                self.cm.transform_ms(&c.kernel, l, class, 1)
            }
            _ => 0.0,
        };
        read + transform
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::profiles;
    use crate::graph::zoo;
    use crate::kernels::{KernelFamily, Registry};
    use crate::sched::op::OpSet;
    use crate::sched::plan::default_choices;

    #[test]
    fn cached_choice_reads_more_but_skips_transform() {
        let dev = profiles::meizu_16t();
        let g = zoo::resnet50();
        let reg = Registry::full();
        let mut choices = default_choices(&g, &reg);
        // find a winograd layer
        let wl = choices
            .iter()
            .position(|c| {
                matches!(c, Some(c) if c.kernel.family == KernelFamily::WinogradPack4)
            })
            .expect("resnet50 has a winograd default layer");
        let p_raw = Pricer::new(&dev, &g, &choices, false);
        let raw_prep = p_raw.prep_ms_little(wl);
        let raw_read = p_raw.read_bytes(wl);

        choices[wl].as_mut().unwrap().cache = true;
        let p_cached = Pricer::new(&dev, &g, &choices, false);
        let cached_prep = p_cached.prep_ms_little(wl);
        assert!(p_cached.read_bytes(wl) > raw_read);
        // Table 2: cache read (5.23) ≪ raw read + transform (0.70+38.23).
        assert!(cached_prep < raw_prep, "{cached_prep} vs {raw_prep}");
    }

    #[test]
    fn gang_prep_faster_than_little() {
        let dev = profiles::meizu_16t();
        let g = zoo::resnet50();
        let choices = default_choices(&g, &Registry::full());
        let p = Pricer::new(&dev, &g, &choices, false);
        for layer in g.weighted_layers().into_iter().take(5) {
            assert!(p.prep_ms_gang(layer) < p.prep_ms_little(layer));
        }
    }

    #[test]
    fn prices_every_op_kind() {
        let dev = profiles::jetson_tx2();
        let g = zoo::tiny_net();
        let choices = default_choices(&g, &Registry::full());
        let set = OpSet::build(&g, &choices, true);
        let p = Pricer::new(&dev, &g, &choices, false);
        for op in &set.ops {
            let ms = p.price(op, UnitId::Gang);
            assert!(ms.is_finite() && ms >= 0.0, "{op:?} => {ms}");
        }
        // Shader cache shrinks pipeline ops.
        let pc = Pricer::new(&dev, &g, &choices, true);
        let pipe = set
            .ops
            .iter()
            .find(|o| o.stage == OpStage::Pipeline)
            .unwrap();
        assert!(pc.price(pipe, UnitId::Gang) < p.price(pipe, UnitId::Gang));
    }

    #[test]
    fn table_matches_pricer() {
        for (dev, gpu) in [(profiles::meizu_16t(), false), (profiles::jetson_tx2(), true)] {
            let g = zoo::resnet50();
            let choices = default_choices(&g, &Registry::full());
            let set = OpSet::build(&g, &choices, gpu);
            let p = Pricer::new(&dev, &g, &choices, true);
            let t = PriceTable::build(&set, &p);
            for op in &set.ops {
                assert_eq!(t.get(op.id, UnitId::Gang), p.price(op, UnitId::Gang));
                assert_eq!(t.get(op.id, UnitId::Little(2)), p.price(op, UnitId::Little(2)));
                assert_eq!(t.by_unit_idx(op.id, 0), t.get(op.id, UnitId::Gang));
                assert_eq!(t.by_unit_idx(op.id, 3), t.get(op.id, UnitId::Little(2)));
                assert!(t.gang[op.id].is_finite() && t.gang[op.id] >= 0.0);
                assert!(t.little[op.id].is_finite() && t.little[op.id] >= 0.0);
            }
        }
    }

    #[test]
    fn gpu_little_units_are_all_cpu_cores() {
        let dev = profiles::jetson_tx2();
        let g = zoo::tiny_net();
        let choices = default_choices(&g, &Registry::full());
        let p = Pricer::new(&dev, &g, &choices, false);
        assert_eq!(p.n_little_units(), dev.n_cpu());
        let phone = profiles::meizu_16t();
        let p2 = Pricer::new(&phone, &g, &choices, false);
        assert_eq!(p2.n_little_units(), phone.n_little);
    }
}
