//! Fingerprint-keyed plan cache.
//!
//! Plan generation is deterministic in (device, model, scheduler config,
//! registry), so a serving front that cold-starts the same model on the
//! same device repeatedly — the [`crate::serving`] router re-planning per
//! registered model, ablation sweeps re-planning per arm — can skip the
//! search entirely after the first request. The key is a structural
//! fingerprint, not an object identity: two independently built
//! `ModelGraph`s of the same architecture hash alike.
//!
//! Thread-safe (`Mutex` around the map; planning happens outside the
//! lock, so concurrent misses on *different* keys plan in parallel, and a
//! racing duplicate insert is resolved first-wins).

use std::collections::hash_map::DefaultHasher;
use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::device::DeviceProfile;
use crate::graph::ModelGraph;
use crate::kernels::Registry;
use crate::sched::heuristic::{schedule, Scheduled, SchedulerConfig};

/// Structural fingerprint of one planning problem. `registry_tag`
/// distinguishes kernel registries (e.g. `"full"` vs `"warm-default"`),
/// which are not otherwise hashable.
pub fn fingerprint(
    dev: &DeviceProfile,
    graph: &ModelGraph,
    cfg: &SchedulerConfig,
    registry_tag: &str,
) -> u64 {
    let mut h = DefaultHasher::new();
    // Device: every field the cost model reads.
    dev.name.hash(&mut h);
    dev.n_big.hash(&mut h);
    dev.n_little.hash(&mut h);
    dev.big_gflops.to_bits().hash(&mut h);
    dev.little_gflops.to_bits().hash(&mut h);
    dev.disk_mbps.to_bits().hash(&mut h);
    dev.mem_eff_gbps.to_bits().hash(&mut h);
    dev.read_little_slowdown.to_bits().hash(&mut h);
    dev.transform_little_slowdown.to_bits().hash(&mut h);
    dev.gpu.is_some().hash(&mut h);
    if let Some(g) = &dev.gpu {
        g.gflops.to_bits().hash(&mut h);
        g.driver_init_ms.to_bits().hash(&mut h);
        g.pipeline_create_ms.to_bits().hash(&mut h);
        g.shader_compile_ms.to_bits().hash(&mut h);
    }
    // Model: name + full layer structure.
    graph.name.hash(&mut h);
    graph.len().hash(&mut h);
    for l in graph.layers() {
        format!("{:?}", l.op).hash(&mut h);
        l.in_ch.hash(&mut h);
        l.out_ch.hash(&mut h);
        l.in_hw.hash(&mut h);
        l.out_hw.hash(&mut h);
        l.deps.hash(&mut h);
    }
    // Config knobs.
    cfg.epsilon_ms.to_bits().hash(&mut h);
    cfg.max_outer_passes.hash(&mut h);
    cfg.kernel_selection.hash(&mut h);
    cfg.weight_cache.hash(&mut h);
    cfg.shader_cache.hash(&mut h);
    cfg.pipeline.hash(&mut h);
    registry_tag.hash(&mut h);
    h.finish()
}

/// The cache. Cheap to share (`Arc<PlanCache>`) across routers/threads.
#[derive(Default)]
pub struct PlanCache {
    map: Mutex<HashMap<u64, Arc<Scheduled>>>,
    hits: AtomicUsize,
    misses: AtomicUsize,
}

impl PlanCache {
    pub fn new() -> PlanCache {
        PlanCache::default()
    }

    /// Return the cached plan for this problem, or run the scheduler and
    /// cache the result. `registry_tag` must uniquely name `registry`'s
    /// contents (callers with `Registry::full()` pass `"full"`).
    pub fn get_or_plan(
        &self,
        dev: &DeviceProfile,
        graph: &ModelGraph,
        registry: &Registry,
        cfg: &SchedulerConfig,
        registry_tag: &str,
    ) -> Arc<Scheduled> {
        let key = fingerprint(dev, graph, cfg, registry_tag);
        if let Some(s) = self.map.lock().unwrap().get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return s.clone();
        }
        // Plan outside the lock: misses on different keys run concurrently.
        let planned = Arc::new(schedule(dev, graph, registry, cfg));
        self.misses.fetch_add(1, Ordering::Relaxed);
        self.map
            .lock()
            .unwrap()
            .entry(key)
            .or_insert(planned)
            .clone()
    }

    pub fn hits(&self) -> usize {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> usize {
        self.misses.load(Ordering::Relaxed)
    }

    pub fn len(&self) -> usize {
        self.map.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drop all cached plans (e.g. after a device-profile recalibration).
    pub fn clear(&self) {
        self.map.lock().unwrap().clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::profiles;
    use crate::graph::zoo;

    #[test]
    fn hit_returns_identical_plan() {
        let cache = PlanCache::new();
        let dev = profiles::meizu_16t();
        let g = zoo::squeezenet();
        let reg = Registry::full();
        let cfg = SchedulerConfig::kcp();
        let a = cache.get_or_plan(&dev, &g, &reg, &cfg, "full");
        let b = cache.get_or_plan(&dev, &g, &reg, &cfg, "full");
        assert!(Arc::ptr_eq(&a, &b), "second request must be a cache hit");
        assert_eq!(cache.hits(), 1);
        assert_eq!(cache.misses(), 1);
        // An independently built graph of the same architecture also hits.
        let g2 = zoo::squeezenet();
        let c = cache.get_or_plan(&dev, &g2, &reg, &cfg, "full");
        assert!(Arc::ptr_eq(&a, &c));
    }

    #[test]
    fn distinct_problems_get_distinct_entries() {
        let cache = PlanCache::new();
        let reg = Registry::full();
        let cfg = SchedulerConfig::kcp();
        let g = zoo::tiny_net();
        cache.get_or_plan(&profiles::meizu_16t(), &g, &reg, &cfg, "full");
        cache.get_or_plan(&profiles::pixel_5(), &g, &reg, &cfg, "full");
        cache.get_or_plan(
            &profiles::meizu_16t(),
            &g,
            &reg,
            &SchedulerConfig::k_only(),
            "full",
        );
        cache.get_or_plan(&profiles::meizu_16t(), &zoo::micro_mobilenet(), &reg, &cfg, "full");
        assert_eq!(cache.len(), 4);
        assert_eq!(cache.hits(), 0);
    }

    #[test]
    fn cached_plan_equals_direct_schedule() {
        let cache = PlanCache::new();
        let dev = profiles::meizu_16t();
        let g = zoo::mobilenet_v1();
        let reg = Registry::full();
        let cfg = SchedulerConfig::kcp();
        let cached = cache.get_or_plan(&dev, &g, &reg, &cfg, "full");
        let direct = schedule(&dev, &g, &reg, &cfg);
        assert_eq!(
            cached.schedule.makespan.to_bits(),
            direct.schedule.makespan.to_bits()
        );
    }
}
