//! Fingerprint-keyed plan cache with an optional disk-persistent store.
//!
//! Plan generation is deterministic in (device, model, scheduler config,
//! registry), so a serving front that cold-starts the same model on the
//! same device repeatedly — the [`crate::engine::Engine`] planning per
//! loaded model, ablation sweeps re-planning per arm — can skip the
//! search entirely after the first request. The key is a structural
//! fingerprint, not an object identity: two independently built
//! `ModelGraph`s of the same architecture hash alike.
//!
//! A cache opened with [`PlanCache::persistent`] additionally mirrors
//! every planned entry to a directory of `plan-<fingerprint>.json` files
//! ([`crate::sched::plan::Plan::to_json`] payloads). A *fresh process*
//! pointing at the same directory then reloads plans instead of
//! re-planning — the paper's offline decision stage (Fig. 4) as an actual
//! on-disk artifact. Loads are fully validated (model identity, kernel
//! names against the registry, queue coverage); any mismatch is treated
//! as a miss and the file is rewritten, so stale or corrupt artifacts can
//! never poison a plan.
//!
//! Thread-safe (`Mutex` around the map; planning happens outside the
//! lock, so concurrent misses on *different* keys plan in parallel, and a
//! racing duplicate insert is resolved first-wins). Disk writes go
//! through a temp file + rename, so concurrent processes sharing a store
//! directory only ever observe complete documents.

use std::collections::hash_map::DefaultHasher;
use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::device::DeviceProfile;
use crate::graph::ModelGraph;
use crate::kernels::Registry;
use crate::sched::heuristic::{schedule, Scheduled, SchedulerConfig};
use crate::sched::makespan::evaluate;
use crate::sched::op::OpSet;
use crate::sched::plan::Plan;
use crate::sched::price::Pricer;
use crate::util::json::Json;

/// Structural fingerprint of one planning problem. `registry_tag`
/// distinguishes kernel registries (e.g. `"full"` vs `"warm-default"`),
/// which are not otherwise hashable.
pub fn fingerprint(
    dev: &DeviceProfile,
    graph: &ModelGraph,
    cfg: &SchedulerConfig,
    registry_tag: &str,
) -> u64 {
    let mut h = DefaultHasher::new();
    // Device: every field the cost model reads.
    dev.name.hash(&mut h);
    dev.n_big.hash(&mut h);
    dev.n_little.hash(&mut h);
    dev.big_gflops.to_bits().hash(&mut h);
    dev.little_gflops.to_bits().hash(&mut h);
    dev.disk_mbps.to_bits().hash(&mut h);
    dev.mem_eff_gbps.to_bits().hash(&mut h);
    dev.read_little_slowdown.to_bits().hash(&mut h);
    dev.transform_little_slowdown.to_bits().hash(&mut h);
    dev.gpu.is_some().hash(&mut h);
    if let Some(g) = &dev.gpu {
        g.gflops.to_bits().hash(&mut h);
        g.driver_init_ms.to_bits().hash(&mut h);
        g.pipeline_create_ms.to_bits().hash(&mut h);
        g.shader_compile_ms.to_bits().hash(&mut h);
    }
    // Model: name + full layer structure.
    graph.name.hash(&mut h);
    graph.len().hash(&mut h);
    for l in graph.layers() {
        format!("{:?}", l.op).hash(&mut h);
        l.in_ch.hash(&mut h);
        l.out_ch.hash(&mut h);
        l.in_hw.hash(&mut h);
        l.out_hw.hash(&mut h);
        l.deps.hash(&mut h);
    }
    // Config knobs.
    cfg.epsilon_ms.to_bits().hash(&mut h);
    cfg.max_outer_passes.hash(&mut h);
    cfg.kernel_selection.hash(&mut h);
    cfg.weight_cache.hash(&mut h);
    cfg.shader_cache.hash(&mut h);
    cfg.pipeline.hash(&mut h);
    registry_tag.hash(&mut h);
    h.finish()
}

/// The disk side of a persistent cache: a directory of per-fingerprint
/// plan JSON files.
struct DiskStore {
    dir: PathBuf,
    hits: AtomicUsize,
}

impl DiskStore {
    fn path_of(&self, key: u64) -> PathBuf {
        self.dir.join(format!("plan-{key:016x}.json"))
    }

    /// Reconstruct a [`Scheduled`] from the stored plan. The op set is
    /// rebuilt from the resolved choices and the schedule re-evaluated
    /// under the same deterministic pricing the planner used, so the
    /// result is bit-identical to what planning would have produced.
    fn load(
        &self,
        key: u64,
        dev: &DeviceProfile,
        graph: &ModelGraph,
        registry: &Registry,
        cfg: &SchedulerConfig,
    ) -> Option<Scheduled> {
        let text = std::fs::read_to_string(self.path_of(key)).ok()?;
        let doc = Json::parse(&text).ok()?;
        if doc.get("fingerprint").as_str() != Some(format!("{key:016x}").as_str()) {
            return None;
        }
        let plan = Plan::from_json(doc.get("plan"), graph, registry).ok()?;
        let set = OpSet::build(graph, &plan.choices, dev.executes_on_gpu());
        let pricer = Pricer::new(dev, graph, &plan.choices, cfg.shader_cache);
        let schedule = evaluate(&set, &plan, &pricer).ok()?;
        // The planner guarantees `estimated_ms == makespan` bit-for-bit;
        // a mismatch means the artifact is stale (older cost model) or
        // hand-edited — treat it as a miss and replan rather than serve a
        // plan that disagrees with its own evaluation.
        if schedule.makespan.to_bits() != plan.estimated_ms.to_bits() {
            return None;
        }
        Some(Scheduled { plan, schedule, set })
    }

    /// Best-effort write (temp file + rename): an unwritable store degrades
    /// to in-memory caching rather than failing planning. The temp name is
    /// process- *and* writer-unique so concurrent misses on the same key
    /// (e.g. parallel engines sharing one persistent cache) never
    /// interleave writes into one file — whichever complete document wins
    /// the rename is kept.
    fn save(&self, key: u64, s: &Scheduled, graph: &ModelGraph) {
        static NEXT_TMP: AtomicUsize = AtomicUsize::new(0);
        let doc = Json::obj(vec![
            ("fingerprint", Json::from(format!("{key:016x}"))),
            ("plan", s.plan.to_json(graph)),
        ]);
        let path = self.path_of(key);
        let tmp = path.with_extension(format!(
            "tmp.{}.{}",
            std::process::id(),
            NEXT_TMP.fetch_add(1, Ordering::Relaxed)
        ));
        match std::fs::write(&tmp, doc.to_pretty()) {
            Ok(()) if std::fs::rename(&tmp, &path).is_ok() => {}
            // Failed write or rename: don't leave orphaned temp files
            // accumulating in a long-lived store directory.
            _ => {
                let _ = std::fs::remove_file(&tmp);
            }
        }
    }
}

/// The cache. Cheap to share (`Arc<PlanCache>`) across engines/threads.
#[derive(Default)]
pub struct PlanCache {
    map: Mutex<HashMap<u64, Arc<Scheduled>>>,
    hits: AtomicUsize,
    misses: AtomicUsize,
    disk: Option<DiskStore>,
}

impl PlanCache {
    pub fn new() -> PlanCache {
        PlanCache::default()
    }

    /// An in-memory cache mirrored to `dir` (created if absent): plans
    /// survive the process, so a fresh engine pointing at the same store
    /// directory skips planning entirely (observable via
    /// [`PlanCache::disk_hits`]).
    pub fn persistent(dir: impl Into<PathBuf>) -> std::io::Result<PlanCache> {
        let dir = dir.into();
        std::fs::create_dir_all(&dir)?;
        Ok(PlanCache {
            disk: Some(DiskStore { dir, hits: AtomicUsize::new(0) }),
            ..PlanCache::default()
        })
    }

    /// The backing directory of a persistent cache.
    pub fn store_dir(&self) -> Option<&Path> {
        self.disk.as_ref().map(|d| d.dir.as_path())
    }

    /// Return the cached plan for this problem, or run the scheduler and
    /// cache the result. `registry_tag` must uniquely name `registry`'s
    /// contents (callers with `Registry::full()` pass `"full"`).
    pub fn get_or_plan(
        &self,
        dev: &DeviceProfile,
        graph: &ModelGraph,
        registry: &Registry,
        cfg: &SchedulerConfig,
        registry_tag: &str,
    ) -> Arc<Scheduled> {
        let key = fingerprint(dev, graph, cfg, registry_tag);
        if let Some(s) = self.map.lock().unwrap().get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return s.clone();
        }
        // Disk, then plan — both outside the lock, so misses on different
        // keys load/plan concurrently.
        if let Some(disk) = &self.disk {
            if let Some(s) = disk.load(key, dev, graph, registry, cfg) {
                disk.hits.fetch_add(1, Ordering::Relaxed);
                return self
                    .map
                    .lock()
                    .unwrap()
                    .entry(key)
                    .or_insert(Arc::new(s))
                    .clone();
            }
        }
        let planned = Arc::new(schedule(dev, graph, registry, cfg));
        self.misses.fetch_add(1, Ordering::Relaxed);
        if let Some(disk) = &self.disk {
            disk.save(key, &planned, graph);
        }
        self.map
            .lock()
            .unwrap()
            .entry(key)
            .or_insert(planned)
            .clone()
    }

    pub fn hits(&self) -> usize {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> usize {
        self.misses.load(Ordering::Relaxed)
    }

    /// Plans served from the disk store instead of being re-planned
    /// (always 0 for a purely in-memory cache).
    pub fn disk_hits(&self) -> usize {
        self.disk
            .as_ref()
            .map_or(0, |d| d.hits.load(Ordering::Relaxed))
    }

    pub fn len(&self) -> usize {
        self.map.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drop all in-memory cached plans (e.g. after a device-profile
    /// recalibration). Disk artifacts are left in place; they are
    /// re-validated on the next load.
    pub fn clear(&self) {
        self.map.lock().unwrap().clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::profiles;
    use crate::graph::zoo;

    #[test]
    fn hit_returns_identical_plan() {
        let cache = PlanCache::new();
        let dev = profiles::meizu_16t();
        let g = zoo::squeezenet();
        let reg = Registry::full();
        let cfg = SchedulerConfig::kcp();
        let a = cache.get_or_plan(&dev, &g, &reg, &cfg, "full");
        let b = cache.get_or_plan(&dev, &g, &reg, &cfg, "full");
        assert!(Arc::ptr_eq(&a, &b), "second request must be a cache hit");
        assert_eq!(cache.hits(), 1);
        assert_eq!(cache.misses(), 1);
        // An independently built graph of the same architecture also hits.
        let g2 = zoo::squeezenet();
        let c = cache.get_or_plan(&dev, &g2, &reg, &cfg, "full");
        assert!(Arc::ptr_eq(&a, &c));
    }

    #[test]
    fn distinct_problems_get_distinct_entries() {
        let cache = PlanCache::new();
        let reg = Registry::full();
        let cfg = SchedulerConfig::kcp();
        let g = zoo::tiny_net();
        cache.get_or_plan(&profiles::meizu_16t(), &g, &reg, &cfg, "full");
        cache.get_or_plan(&profiles::pixel_5(), &g, &reg, &cfg, "full");
        cache.get_or_plan(
            &profiles::meizu_16t(),
            &g,
            &reg,
            &SchedulerConfig::k_only(),
            "full",
        );
        cache.get_or_plan(&profiles::meizu_16t(), &zoo::micro_mobilenet(), &reg, &cfg, "full");
        assert_eq!(cache.len(), 4);
        assert_eq!(cache.hits(), 0);
    }

    fn temp_store(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("nnv12-store-{tag}-{}", std::process::id()))
    }

    #[test]
    fn persistent_cache_reloads_across_instances() {
        let dir = temp_store("reload");
        let _ = std::fs::remove_dir_all(&dir);
        let dev = profiles::meizu_16t();
        let g = zoo::squeezenet();
        let reg = Registry::full();
        let cfg = SchedulerConfig::kcp();

        let a = PlanCache::persistent(&dir).unwrap();
        let planned = a.get_or_plan(&dev, &g, &reg, &cfg, "full");
        assert_eq!((a.misses(), a.disk_hits()), (1, 0));

        // A fresh cache (≈ a fresh process) loads from disk, not the planner.
        let b = PlanCache::persistent(&dir).unwrap();
        let loaded = b.get_or_plan(&dev, &g, &reg, &cfg, "full");
        assert_eq!((b.misses(), b.disk_hits()), (0, 1), "disk must satisfy the miss");
        assert_eq!(
            loaded.schedule.makespan.to_bits(),
            planned.schedule.makespan.to_bits()
        );
        assert_eq!(
            loaded.plan.to_json(&g).to_compact(),
            planned.plan.to_json(&g).to_compact(),
            "reloaded plan must be bit-identical"
        );
        // Second request in the same instance is a plain memory hit.
        let again = b.get_or_plan(&dev, &g, &reg, &cfg, "full");
        assert!(Arc::ptr_eq(&loaded, &again));
        assert_eq!(b.hits(), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_store_entry_degrades_to_replanning() {
        let dir = temp_store("corrupt");
        let _ = std::fs::remove_dir_all(&dir);
        let dev = profiles::meizu_16t();
        let g = zoo::tiny_net();
        let reg = Registry::full();
        let cfg = SchedulerConfig::kcp();
        let a = PlanCache::persistent(&dir).unwrap();
        let planned = a.get_or_plan(&dev, &g, &reg, &cfg, "full");
        // Truncate every stored artifact.
        for entry in std::fs::read_dir(&dir).unwrap() {
            std::fs::write(entry.unwrap().path(), "{ not json").unwrap();
        }
        let b = PlanCache::persistent(&dir).unwrap();
        let replanned = b.get_or_plan(&dev, &g, &reg, &cfg, "full");
        assert_eq!((b.misses(), b.disk_hits()), (1, 0));
        assert_eq!(
            replanned.schedule.makespan.to_bits(),
            planned.schedule.makespan.to_bits()
        );
        // The rewrite healed the store.
        let c = PlanCache::persistent(&dir).unwrap();
        c.get_or_plan(&dev, &g, &reg, &cfg, "full");
        assert_eq!(c.disk_hits(), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn cached_plan_equals_direct_schedule() {
        let cache = PlanCache::new();
        let dev = profiles::meizu_16t();
        let g = zoo::mobilenet_v1();
        let reg = Registry::full();
        let cfg = SchedulerConfig::kcp();
        let cached = cache.get_or_plan(&dev, &g, &reg, &cfg, "full");
        let direct = schedule(&dev, &g, &reg, &cfg);
        assert_eq!(
            cached.schedule.makespan.to_bits(),
            direct.schedule.makespan.to_bits()
        );
    }
}
