//! Fingerprint-keyed plan caches, optionally persisted through the
//! content-addressed [`crate::store::ArtifactStore`].
//!
//! Plan generation is deterministic in (device, model, scheduler config,
//! registry), so a serving front that cold-starts the same model on the
//! same device repeatedly — the [`crate::engine::Engine`] planning per
//! loaded model, ablation sweeps re-planning per arm — can skip the
//! search entirely after the first request. The key is a structural
//! fingerprint, not an object identity: two independently built
//! `ModelGraph`s of the same architecture hash alike.
//!
//! Two caches live here, both thin typed views over the artifact store:
//!
//! * [`PlanCache`] — plain plans, [`Namespace::Plan`]. The payload is the
//!   [`crate::sched::plan::Plan::to_json`] document.
//! * [`CalibratedPlanCache`] — `(plan, device-view)` pairs produced by
//!   [`schedule_calibrated`] (§3.3 re-profiling), [`Namespace::CalibratedPlan`].
//!   The payload adds the calibrated core counts, so a fresh process
//!   reconstructs the exact device view the plan was tuned for.
//!
//! Disk loads are fully revalidated (store header + checksum by the
//! store; model identity, kernel names against the registry, and a
//! bit-exact re-evaluation of the makespan here), so stale or corrupt
//! artifacts can never poison a plan — any mismatch is a miss and the
//! entry is rewritten.
//!
//! **Canonical op sets vs pre-canonical artifacts.** Canonical op sets
//! changed the *payload* of plans whose kernels bypass transformation
//! (queues now include the zero-cost transform ops). The fingerprint is
//! deliberately unchanged — it hashes the planning *problem*, never the
//! answer's shape — so a pre-canonical artifact sits under the same key,
//! fails structural revalidation exactly once (its queues no longer
//! cover the canonical op set), and is replanned and rewritten in place:
//! one cold recompute per stale artifact, no key migration, and the next
//! process hits the healed entry (`pre_canonical_artifact_recomputes_once`
//! below).
//!
//! Both caches are thread-safe (`Mutex` around the map; planning happens
//! outside the lock, so concurrent misses on *different* keys plan in
//! parallel, and a racing duplicate insert is resolved first-wins).

use std::collections::hash_map::DefaultHasher;
use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::device::DeviceProfile;
use crate::fleet::DeviceFingerprint;
use crate::graph::ModelGraph;
use crate::kernels::Registry;
use crate::sched::heuristic::{
    schedule, schedule_calibrated, Scheduled, SchedulerConfig,
};
use crate::sched::makespan::evaluate;
use crate::sched::op::OpSet;
use crate::sched::plan::Plan;
use crate::sched::price::Pricer;
use crate::store::{ArtifactStore, Namespace};
use crate::util::json::Json;

/// Structural fingerprint of one planning problem. `registry_tag`
/// distinguishes kernel registries (e.g. `"full"` vs `"warm-default"`),
/// which are not otherwise hashable.
pub fn fingerprint(
    dev: &DeviceProfile,
    graph: &ModelGraph,
    cfg: &SchedulerConfig,
    registry_tag: &str,
) -> u64 {
    let mut h = DefaultHasher::new();
    // Device: every field the cost model reads.
    dev.name.hash(&mut h);
    dev.n_big.hash(&mut h);
    dev.n_little.hash(&mut h);
    dev.big_gflops.to_bits().hash(&mut h);
    dev.little_gflops.to_bits().hash(&mut h);
    dev.disk_mbps.to_bits().hash(&mut h);
    dev.mem_eff_gbps.to_bits().hash(&mut h);
    dev.read_little_slowdown.to_bits().hash(&mut h);
    dev.transform_little_slowdown.to_bits().hash(&mut h);
    dev.gpu.is_some().hash(&mut h);
    if let Some(g) = &dev.gpu {
        g.gflops.to_bits().hash(&mut h);
        g.driver_init_ms.to_bits().hash(&mut h);
        g.pipeline_create_ms.to_bits().hash(&mut h);
        g.shader_compile_ms.to_bits().hash(&mut h);
    }
    hash_model_and_cfg(&mut h, graph, cfg, registry_tag);
    h.finish()
}

/// The device-*independent* half of [`fingerprint`]: model architecture,
/// scheduler config, registry tag. This is the fleet store's scope key —
/// every device's plan for one (model, config, registry) problem lands in
/// one enumerable scope, which is what makes the nearest-profile lookup
/// of [`crate::fleet::PlanTransfer`] possible.
pub fn model_fingerprint(graph: &ModelGraph, cfg: &SchedulerConfig, registry_tag: &str) -> u64 {
    let mut h = DefaultHasher::new();
    hash_model_and_cfg(&mut h, graph, cfg, registry_tag);
    h.finish()
}

fn hash_model_and_cfg(
    h: &mut DefaultHasher,
    graph: &ModelGraph,
    cfg: &SchedulerConfig,
    registry_tag: &str,
) {
    // Model: name + full layer structure.
    graph.name.hash(h);
    graph.len().hash(h);
    for l in graph.layers() {
        format!("{:?}", l.op).hash(h);
        l.in_ch.hash(h);
        l.out_ch.hash(h);
        l.in_hw.hash(h);
        l.out_hw.hash(h);
        l.deps.hash(h);
    }
    // Config knobs.
    cfg.epsilon_ms.to_bits().hash(h);
    cfg.max_outer_passes.hash(h);
    cfg.kernel_selection.hash(h);
    cfg.weight_cache.hash(h);
    cfg.shader_cache.hash(h);
    cfg.pipeline.hash(h);
    registry_tag.hash(h);
}

/// Fingerprint of one *calibrated* planning problem. Calibration is a
/// deterministic function of the same inputs (it re-profiles
/// prep-parallelism degrees under the contention-aware simulator), so the
/// key is the base fingerprint under a distinct salt — kept separate from
/// plain plans because the *answer* differs (it includes a device view).
pub fn calibrated_fingerprint(
    dev: &DeviceProfile,
    graph: &ModelGraph,
    cfg: &SchedulerConfig,
    registry_tag: &str,
) -> u64 {
    let mut h = DefaultHasher::new();
    fingerprint(dev, graph, cfg, registry_tag).hash(&mut h);
    "calibrated-v1".hash(&mut h);
    h.finish()
}

/// Reconstruct a [`Scheduled`] from a stored plan document: rebuild the
/// op set from the resolved choices and re-evaluate under the same
/// deterministic pricing the planner used, so the result is bit-identical
/// to what planning would have produced. `None` on any structural
/// mismatch (wrong model, unknown kernels, stale cost model). Shared with
/// [`crate::fleet`], which revalidates transferred plans the same way.
pub(crate) fn revalidate(
    plan_json: &Json,
    dev: &DeviceProfile,
    graph: &ModelGraph,
    registry: &Registry,
    cfg: &SchedulerConfig,
) -> Option<Scheduled> {
    let plan = Plan::from_json(plan_json, graph, registry).ok()?;
    let set = Arc::new(OpSet::build(graph, &plan.choices, dev.executes_on_gpu()));
    let pricer = Pricer::new(dev, graph, &plan.choices, cfg.shader_cache);
    let schedule = evaluate(&set, &plan, &pricer).ok()?;
    // The planner guarantees `estimated_ms == makespan` bit-for-bit; a
    // mismatch means the artifact is stale (older cost model) or
    // hand-edited — treat it as a miss and replan rather than serve a
    // plan that disagrees with its own evaluation.
    if schedule.makespan.to_bits() != plan.estimated_ms.to_bits() {
        return None;
    }
    Some(Scheduled { plan, schedule, set })
}

/// The disk side of a persistent plan cache: one namespace of the shared
/// artifact store, plus this view's own hit counter (the store's counters
/// aggregate all namespaces).
struct StoreView {
    store: Arc<ArtifactStore>,
    ns: Namespace,
    hits: AtomicUsize,
}

impl StoreView {
    fn load_doc(&self, key: u64) -> Option<Json> {
        let payload = self.store.get(self.ns, key)?;
        let text = String::from_utf8(payload).ok()?;
        let doc = Json::parse(&text).ok()?;
        if doc.get("fingerprint").as_str() != Some(format!("{key:016x}").as_str()) {
            return None;
        }
        Some(doc)
    }

    /// Best-effort write: an unwritable store degrades to in-memory
    /// caching rather than failing planning.
    fn save_doc(&self, key: u64, doc: &Json) {
        let _ = self.store.put(self.ns, key, doc.to_pretty().as_bytes());
    }
}

/// The plan cache. Cheap to share (`Arc<PlanCache>`) across
/// engines/threads.
#[derive(Default)]
pub struct PlanCache {
    map: Mutex<HashMap<u64, Arc<Scheduled>>>,
    hits: AtomicUsize,
    misses: AtomicUsize,
    disk: Option<StoreView>,
}

impl PlanCache {
    pub fn new() -> PlanCache {
        PlanCache::default()
    }

    /// An in-memory cache mirrored to an [`ArtifactStore`] at `dir`
    /// (created if absent): plans survive the process, so a fresh engine
    /// pointing at the same store directory skips planning entirely
    /// (observable via [`PlanCache::disk_hits`]).
    pub fn persistent(dir: impl Into<PathBuf>) -> std::io::Result<PlanCache> {
        Ok(PlanCache::with_store(Arc::new(ArtifactStore::open(dir)?)))
    }

    /// An in-memory cache mirrored to a shared artifact store — the
    /// engine facade's path, where plans, calibrated plans, and weights
    /// share one store (and one size cap).
    pub fn with_store(store: Arc<ArtifactStore>) -> PlanCache {
        PlanCache {
            disk: Some(StoreView { store, ns: Namespace::Plan, hits: AtomicUsize::new(0) }),
            ..PlanCache::default()
        }
    }

    /// The backing directory of a persistent cache.
    pub fn store_dir(&self) -> Option<&Path> {
        self.disk.as_ref().map(|d| d.store.dir())
    }

    /// The backing artifact store of a persistent cache.
    pub fn artifact_store(&self) -> Option<&Arc<ArtifactStore>> {
        self.disk.as_ref().map(|d| &d.store)
    }

    /// Return the cached plan for this problem, or run the scheduler and
    /// cache the result. `registry_tag` must uniquely name `registry`'s
    /// contents (callers with `Registry::full()` pass `"full"`).
    pub fn get_or_plan(
        &self,
        dev: &DeviceProfile,
        graph: &ModelGraph,
        registry: &Registry,
        cfg: &SchedulerConfig,
        registry_tag: &str,
    ) -> Arc<Scheduled> {
        self.get_or_plan_with(dev, graph, registry, cfg, registry_tag, || {
            schedule(dev, graph, registry, cfg)
        })
    }

    /// [`PlanCache::get_or_plan`] with a caller-supplied planner for the
    /// full-miss case (memory *and* disk missed). This is the fleet-
    /// transfer hook: the engine substitutes a nearest-profile seeded
    /// search ([`crate::fleet::PlanTransfer`]) for the cold search, while
    /// hit bookkeeping, disk revalidation, and the artifact write-back
    /// stay identical. `plan_fn` must be deterministic for the
    /// fingerprint's inputs — its result is persisted under the same key
    /// a cold search would use (sound because an accepted transfer is
    /// still a confirmed plan for exactly this (device, model, config)
    /// problem, never the donor's plan verbatim).
    pub fn get_or_plan_with(
        &self,
        dev: &DeviceProfile,
        graph: &ModelGraph,
        registry: &Registry,
        cfg: &SchedulerConfig,
        registry_tag: &str,
        plan_fn: impl FnOnce() -> Scheduled,
    ) -> Arc<Scheduled> {
        let key = fingerprint(dev, graph, cfg, registry_tag);
        if let Some(s) = self.map.lock().unwrap().get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return s.clone();
        }
        // Disk, then plan — both outside the lock, so misses on different
        // keys load/plan concurrently.
        if let Some(disk) = &self.disk {
            let loaded = disk
                .load_doc(key)
                .and_then(|doc| revalidate(doc.get("plan"), dev, graph, registry, cfg));
            if let Some(s) = loaded {
                disk.hits.fetch_add(1, Ordering::Relaxed);
                return self
                    .map
                    .lock()
                    .unwrap()
                    .entry(key)
                    .or_insert(Arc::new(s))
                    .clone();
            }
        }
        // A full miss can write more than one artifact: the fleet-transfer
        // `plan_fn` publishes this device's fleet seed (on this thread)
        // before the plan doc itself is saved below. Group both under one
        // write intent so a crash between the puts can never leave a
        // half-published cold start — boot-time recovery discards the
        // whole group and the next request replans.
        let intent = self
            .disk
            .as_ref()
            .map(|d| d.store.begin_intent(&format!("plan {key:016x}")));
        let planned = Arc::new(plan_fn());
        self.misses.fetch_add(1, Ordering::Relaxed);
        if let Some(disk) = &self.disk {
            let doc = Json::obj(vec![
                ("fingerprint", Json::from(format!("{key:016x}"))),
                ("plan", planned.plan.to_json(graph)),
            ]);
            disk.save_doc(key, &doc);
        }
        if let Some(intent) = intent {
            intent.commit();
        }
        self.map
            .lock()
            .unwrap()
            .entry(key)
            .or_insert(planned)
            .clone()
    }

    pub fn hits(&self) -> usize {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> usize {
        self.misses.load(Ordering::Relaxed)
    }

    /// Plans served from the disk store instead of being re-planned
    /// (always 0 for a purely in-memory cache).
    pub fn disk_hits(&self) -> usize {
        self.disk
            .as_ref()
            .map_or(0, |d| d.hits.load(Ordering::Relaxed))
    }

    pub fn len(&self) -> usize {
        self.map.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drop all in-memory cached plans (e.g. after a device-profile
    /// recalibration). Disk artifacts are left in place; they are
    /// re-validated on the next load.
    pub fn clear(&self) {
        self.map.lock().unwrap().clear();
    }
}

/// Cache of calibrated `(plan, device-view)` pairs. Calibration
/// ([`schedule_calibrated`]) re-plans under several prep-parallelism
/// degrees and simulates each — by far the most expensive way to plan —
/// yet its output is deterministic in the same fingerprint inputs as a
/// plain plan, so the fig8/fig10 grids and repeated calibrated engines
/// hit this cache (and its store namespace) instead of re-planning per
/// load.
#[derive(Default)]
pub struct CalibratedPlanCache {
    #[allow(clippy::type_complexity)]
    map: Mutex<HashMap<u64, (Arc<Scheduled>, DeviceProfile)>>,
    hits: AtomicUsize,
    misses: AtomicUsize,
    disk: Option<StoreView>,
}

impl CalibratedPlanCache {
    pub fn new() -> CalibratedPlanCache {
        CalibratedPlanCache::default()
    }

    /// A calibrated-plan cache persisted through `store`'s
    /// [`Namespace::CalibratedPlan`] namespace (in-memory only when
    /// `None`).
    pub fn with_store(store: Option<Arc<ArtifactStore>>) -> CalibratedPlanCache {
        CalibratedPlanCache {
            disk: store.map(|store| StoreView {
                store,
                ns: Namespace::CalibratedPlan,
                hits: AtomicUsize::new(0),
            }),
            ..CalibratedPlanCache::default()
        }
    }

    /// Return the cached calibrated plan + device view for this problem,
    /// or run calibration and cache the result.
    pub fn get_or_plan(
        &self,
        dev: &DeviceProfile,
        graph: &ModelGraph,
        registry: &Registry,
        cfg: &SchedulerConfig,
        registry_tag: &str,
    ) -> (Arc<Scheduled>, DeviceProfile) {
        let key = calibrated_fingerprint(dev, graph, cfg, registry_tag);
        if let Some(entry) = self.map.lock().unwrap().get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return entry.clone();
        }
        if let Some(disk) = &self.disk {
            if let Some(entry) = disk
                .load_doc(key)
                .and_then(|doc| load_calibrated(&doc, dev, graph, registry, cfg))
            {
                disk.hits.fetch_add(1, Ordering::Relaxed);
                return self
                    .map
                    .lock()
                    .unwrap()
                    .entry(key)
                    .or_insert(entry)
                    .clone();
            }
        }
        // Single-artifact group today, but grouped anyway: calibration is
        // the slowest plan write, so the crash window around its put is
        // the one most worth covering uniformly with the plan path.
        let intent = self
            .disk
            .as_ref()
            .map(|d| d.store.begin_intent(&format!("calibrated {key:016x}")));
        let (s, view) = schedule_calibrated(dev, graph, registry, cfg);
        self.misses.fetch_add(1, Ordering::Relaxed);
        let entry = (Arc::new(s), view);
        if let Some(disk) = &self.disk {
            // The device view is stored as a full canonical fingerprint
            // (not an ad-hoc core-count pair), so calibrated artifacts and
            // fleet artifacts agree on what "device identity" means; old
            // `{n_big, n_little}`-shaped docs fail the fingerprint parse
            // once and heal, like pre-canonical plans did.
            let doc = Json::obj(vec![
                ("fingerprint", Json::from(format!("{key:016x}"))),
                ("device_view", DeviceFingerprint::of(&entry.1).to_json()),
                ("plan", entry.0.plan.to_json(graph)),
            ]);
            disk.save_doc(key, &doc);
        }
        if let Some(intent) = intent {
            intent.commit();
        }
        self.map
            .lock()
            .unwrap()
            .entry(key)
            .or_insert(entry)
            .clone()
    }

    pub fn hits(&self) -> usize {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> usize {
        self.misses.load(Ordering::Relaxed)
    }

    /// Calibrated plans served from the store instead of being re-planned.
    pub fn disk_hits(&self) -> usize {
        self.disk
            .as_ref()
            .map_or(0, |d| d.hits.load(Ordering::Relaxed))
    }
}

/// Reconstruct a calibrated entry: parse the stored device fingerprint,
/// rebuild the device view from its core counts (calibration only ever
/// shrinks the prep pools of the base device), then revalidate the plan
/// against that view. Any implausible view — more cores than the base
/// device, no cores at all — rejects the artifact, and so does a stored
/// fingerprint that is not bit-identically the fingerprint of the
/// reconstructed view (a doc written against a *different* base device
/// landing under a colliding key). Docs from before the fingerprint
/// migration (`{n_big, n_little}` views) fail the parse, recompute once,
/// and are rewritten in place under the same key — the pre-canonical
/// healing pattern (`pre_fingerprint_calibrated_artifact_heals` below).
fn load_calibrated(
    doc: &Json,
    dev: &DeviceProfile,
    graph: &ModelGraph,
    registry: &Registry,
    cfg: &SchedulerConfig,
) -> Option<(Arc<Scheduled>, DeviceProfile)> {
    let fp = DeviceFingerprint::from_json(doc.get("device_view"))?;
    if fp.n_big > dev.n_big || fp.n_little > dev.n_little || fp.n_big + fp.n_little == 0 {
        return None;
    }
    let mut view = dev.clone();
    view.n_big = fp.n_big;
    view.n_little = fp.n_little;
    if DeviceFingerprint::of(&view).key() != fp.key() {
        return None;
    }
    let s = revalidate(doc.get("plan"), &view, graph, registry, cfg)?;
    Some((Arc::new(s), view))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::profiles;
    use crate::graph::zoo;

    #[test]
    fn hit_returns_identical_plan() {
        let cache = PlanCache::new();
        let dev = profiles::meizu_16t();
        let g = zoo::squeezenet();
        let reg = Registry::full();
        let cfg = SchedulerConfig::kcp();
        let a = cache.get_or_plan(&dev, &g, &reg, &cfg, "full");
        let b = cache.get_or_plan(&dev, &g, &reg, &cfg, "full");
        assert!(Arc::ptr_eq(&a, &b), "second request must be a cache hit");
        assert_eq!(cache.hits(), 1);
        assert_eq!(cache.misses(), 1);
        // An independently built graph of the same architecture also hits.
        let g2 = zoo::squeezenet();
        let c = cache.get_or_plan(&dev, &g2, &reg, &cfg, "full");
        assert!(Arc::ptr_eq(&a, &c));
    }

    #[test]
    fn distinct_problems_get_distinct_entries() {
        let cache = PlanCache::new();
        let reg = Registry::full();
        let cfg = SchedulerConfig::kcp();
        let g = zoo::tiny_net();
        cache.get_or_plan(&profiles::meizu_16t(), &g, &reg, &cfg, "full");
        cache.get_or_plan(&profiles::pixel_5(), &g, &reg, &cfg, "full");
        cache.get_or_plan(
            &profiles::meizu_16t(),
            &g,
            &reg,
            &SchedulerConfig::k_only(),
            "full",
        );
        cache.get_or_plan(&profiles::meizu_16t(), &zoo::micro_mobilenet(), &reg, &cfg, "full");
        assert_eq!(cache.len(), 4);
        assert_eq!(cache.hits(), 0);
    }

    fn temp_store(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("nnv12-store-{tag}-{}", std::process::id()))
    }

    #[test]
    fn persistent_cache_reloads_across_instances() {
        let dir = temp_store("reload");
        let _ = std::fs::remove_dir_all(&dir);
        let dev = profiles::meizu_16t();
        let g = zoo::squeezenet();
        let reg = Registry::full();
        let cfg = SchedulerConfig::kcp();

        let a = PlanCache::persistent(&dir).unwrap();
        let planned = a.get_or_plan(&dev, &g, &reg, &cfg, "full");
        assert_eq!((a.misses(), a.disk_hits()), (1, 0));

        // A fresh cache (≈ a fresh process) loads from disk, not the planner.
        let b = PlanCache::persistent(&dir).unwrap();
        let loaded = b.get_or_plan(&dev, &g, &reg, &cfg, "full");
        assert_eq!((b.misses(), b.disk_hits()), (0, 1), "disk must satisfy the miss");
        assert_eq!(
            loaded.schedule.makespan.to_bits(),
            planned.schedule.makespan.to_bits()
        );
        assert_eq!(
            loaded.plan.to_json(&g).to_compact(),
            planned.plan.to_json(&g).to_compact(),
            "reloaded plan must be bit-identical"
        );
        // Second request in the same instance is a plain memory hit.
        let again = b.get_or_plan(&dev, &g, &reg, &cfg, "full");
        assert!(Arc::ptr_eq(&loaded, &again));
        assert_eq!(b.hits(), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_store_entry_degrades_to_replanning() {
        let dir = temp_store("corrupt");
        let _ = std::fs::remove_dir_all(&dir);
        let dev = profiles::meizu_16t();
        let g = zoo::tiny_net();
        let reg = Registry::full();
        let cfg = SchedulerConfig::kcp();
        let a = PlanCache::persistent(&dir).unwrap();
        let planned = a.get_or_plan(&dev, &g, &reg, &cfg, "full");
        // Truncate every stored artifact.
        for entry in std::fs::read_dir(&dir).unwrap() {
            std::fs::write(entry.unwrap().path(), "{ not json").unwrap();
        }
        let b = PlanCache::persistent(&dir).unwrap();
        let replanned = b.get_or_plan(&dev, &g, &reg, &cfg, "full");
        assert_eq!((b.misses(), b.disk_hits()), (1, 0));
        assert_eq!(
            replanned.schedule.makespan.to_bits(),
            planned.schedule.makespan.to_bits()
        );
        // The rewrite healed the store.
        let c = PlanCache::persistent(&dir).unwrap();
        c.get_or_plan(&dev, &g, &reg, &cfg, "full");
        assert_eq!(c.disk_hits(), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn cached_plan_equals_direct_schedule() {
        let cache = PlanCache::new();
        let dev = profiles::meizu_16t();
        let g = zoo::mobilenet_v1();
        let reg = Registry::full();
        let cfg = SchedulerConfig::kcp();
        let cached = cache.get_or_plan(&dev, &g, &reg, &cfg, "full");
        let direct = schedule(&dev, &g, &reg, &cfg);
        assert_eq!(
            cached.schedule.makespan.to_bits(),
            direct.schedule.makespan.to_bits()
        );
    }

    #[test]
    fn calibrated_cache_hits_in_memory_and_on_disk() {
        let dir = temp_store("calibrated");
        let _ = std::fs::remove_dir_all(&dir);
        let dev = profiles::meizu_16t();
        let g = zoo::squeezenet();
        let reg = Registry::full();
        let cfg = SchedulerConfig::kcp();

        let store = Arc::new(ArtifactStore::open(&dir).unwrap());
        let a = CalibratedPlanCache::with_store(Some(store));
        let (s1, v1) = a.get_or_plan(&dev, &g, &reg, &cfg, "full");
        assert_eq!((a.misses(), a.hits()), (1, 0));
        let (s2, v2) = a.get_or_plan(&dev, &g, &reg, &cfg, "full");
        assert_eq!((a.misses(), a.hits()), (1, 1));
        assert!(Arc::ptr_eq(&s1, &s2));
        assert_eq!(v1.n_little, v2.n_little);

        // Fresh cache over the same store: served from disk, not replanned.
        let store2 = Arc::new(ArtifactStore::open(&dir).unwrap());
        let b = CalibratedPlanCache::with_store(Some(store2));
        let (s3, v3) = b.get_or_plan(&dev, &g, &reg, &cfg, "full");
        assert_eq!((b.misses(), b.disk_hits()), (0, 1));
        assert_eq!(
            s3.schedule.makespan.to_bits(),
            s1.schedule.makespan.to_bits(),
            "reloaded calibrated plan must be bit-identical"
        );
        assert_eq!((v3.n_big, v3.n_little), (v1.n_big, v1.n_little));
        // The calibrated result matches direct calibration exactly.
        let (direct, view) = schedule_calibrated(&dev, &g, &reg, &cfg);
        assert_eq!(s3.schedule.makespan.to_bits(), direct.schedule.makespan.to_bits());
        assert_eq!(v3.n_little, view.n_little);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn pre_canonical_artifact_recomputes_once() {
        // Fabricate the artifact a PRE-canonical build would have stored:
        // its op set materialized no transform op for cache-bypassing
        // kernels, so its queues cannot cover today's canonical op set.
        // The cache must treat it as a miss (structural revalidation),
        // replan once under the SAME key, and heal the store.
        let dir = temp_store("pre-canonical");
        let _ = std::fs::remove_dir_all(&dir);
        let dev = profiles::meizu_16t();
        let g = zoo::tiny_net();
        let reg = Registry::full();
        let cfg = SchedulerConfig::kcp();
        let key = fingerprint(&dev, &g, &cfg, "full");

        let mut choices = crate::sched::plan::default_choices(&g, &reg);
        for c in choices.iter_mut().flatten() {
            if c.kernel.family.needs_transform() {
                c.cache = true;
            }
        }
        let minimal = OpSet::build_minimal(&g, &choices, false);
        assert!(minimal.len() < OpSet::build(&g, &choices, false).len());
        let stale = Plan {
            choices,
            gang: (0..minimal.len()).collect(),
            little: vec![Vec::new(); dev.n_little],
            estimated_ms: 1.0,
        };
        let store = ArtifactStore::open(&dir).unwrap();
        let doc = Json::obj(vec![
            ("fingerprint", Json::from(format!("{key:016x}"))),
            ("plan", stale.to_json(&g)),
        ]);
        store.put(Namespace::Plan, key, doc.to_pretty().as_bytes()).unwrap();

        let a = PlanCache::persistent(&dir).unwrap();
        let s = a.get_or_plan(&dev, &g, &reg, &cfg, "full");
        assert_eq!(
            (a.misses(), a.disk_hits()),
            (1, 0),
            "pre-canonical artifact must be a structural miss"
        );
        s.plan.validate(&s.set).unwrap();

        // The rewrite healed the entry: a fresh process loads from disk.
        let b = PlanCache::persistent(&dir).unwrap();
        let loaded = b.get_or_plan(&dev, &g, &reg, &cfg, "full");
        assert_eq!((b.misses(), b.disk_hits()), (0, 1));
        assert_eq!(
            loaded.schedule.makespan.to_bits(),
            s.schedule.makespan.to_bits()
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn pre_fingerprint_calibrated_artifact_heals() {
        // Fabricate the artifact a pre-fingerprint build stored: its
        // device_view is the ad-hoc `{n_big, n_little}` pair, not a
        // canonical DeviceFingerprint. The cache must treat it as a
        // structural miss exactly once, recompute under the SAME key, and
        // rewrite the healed (fingerprint-shaped) doc for the next
        // process — the pre-canonical-plan healing pattern.
        let dir = temp_store("pre-fingerprint");
        let _ = std::fs::remove_dir_all(&dir);
        let dev = profiles::meizu_16t();
        let g = zoo::tiny_net();
        let reg = Registry::full();
        let cfg = SchedulerConfig::kcp();
        let key = calibrated_fingerprint(&dev, &g, &cfg, "full");

        // A perfectly good calibrated plan wearing the old view shape.
        let (s, view) = schedule_calibrated(&dev, &g, &reg, &cfg);
        let store = ArtifactStore::open(&dir).unwrap();
        let doc = Json::obj(vec![
            ("fingerprint", Json::from(format!("{key:016x}"))),
            (
                "device_view",
                Json::obj(vec![
                    ("n_big", Json::from(view.n_big)),
                    ("n_little", Json::from(view.n_little)),
                ]),
            ),
            ("plan", s.plan.to_json(&g)),
        ]);
        store.put(Namespace::CalibratedPlan, key, doc.to_pretty().as_bytes()).unwrap();

        let store_a = Arc::new(ArtifactStore::open(&dir).unwrap());
        let a = CalibratedPlanCache::with_store(Some(store_a));
        let (healed, healed_view) = a.get_or_plan(&dev, &g, &reg, &cfg, "full");
        assert_eq!(
            (a.misses(), a.disk_hits()),
            (1, 0),
            "old-shape device view must be a structural miss"
        );
        assert_eq!(
            healed.schedule.makespan.to_bits(),
            s.schedule.makespan.to_bits(),
            "recompute is deterministic: same plan, new doc shape"
        );
        assert_eq!((healed_view.n_big, healed_view.n_little), (view.n_big, view.n_little));

        // The rewrite healed the entry: a fresh process loads from disk.
        let store_b = Arc::new(ArtifactStore::open(&dir).unwrap());
        let b = CalibratedPlanCache::with_store(Some(store_b));
        let (loaded, _) = b.get_or_plan(&dev, &g, &reg, &cfg, "full");
        assert_eq!((b.misses(), b.disk_hits()), (0, 1), "healed doc must hit");
        assert_eq!(loaded.schedule.makespan.to_bits(), s.schedule.makespan.to_bits());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn calibrated_and_plain_namespaces_do_not_collide() {
        let dev = profiles::meizu_16t();
        let g = zoo::tiny_net();
        let cfg = SchedulerConfig::kcp();
        assert_ne!(
            fingerprint(&dev, &g, &cfg, "full"),
            calibrated_fingerprint(&dev, &g, &cfg, "full")
        );
    }
}
