//! List-schedule evaluator: compute start/finish times of every operation
//! given per-unit queues, respecting dependencies and queue order.
//!
//! This is the scheduler's *internal* objective evaluator (fast, no
//! contention modelling). The discrete-event simulator ([`crate::sim`])
//! re-executes plans with disk/memory-bandwidth interference, background
//! load, and workload stealing; the two agree exactly when contention is
//! absent (asserted by `tests/sim_vs_makespan.rs`).
//!
//! Three entry points, fastest first:
//!
//! * [`IncrementalEval`] — the plan-search hot path. Records the dispatch
//!   order of a baseline evaluation; [`IncrementalEval::retime`] then
//!   re-evaluates a kernel swap by replaying the unchanged schedule prefix
//!   (every dispatch before the first re-priced op) from the recording and
//!   list-scheduling only the affected suffix.
//! * [`evaluate_with`] — one evaluation against a prebuilt
//!   [`PriceTable`]; a binary-heap ready-queue dispatches ops in
//!   O(ops·log units + deps) instead of the reference evaluator's
//!   O(ops·units·deps) rescan.
//! * [`evaluate`] — convenience wrapper that builds the price table from a
//!   [`Pricer`] first.
//!
//! All three produce bit-identical timings: the heap changes how the next
//! dispatch is *found*, never how its start time is computed, and
//! `tests/incremental_eval.rs` asserts exact agreement against
//! [`evaluate_reference`] (the original O(units) linear-scan evaluator,
//! kept as the executable specification).

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::sched::op::{OpId, OpSet};
use crate::sched::plan::{Plan, UnitId};
use crate::sched::price::{PriceTable, Pricer};
use crate::Ms;

/// Timing of one scheduled operation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OpTiming {
    pub start: Ms,
    pub finish: Ms,
    pub unit: UnitId,
}

/// Full evaluation result.
#[derive(Debug, Clone)]
pub struct Schedule {
    /// Per-op timings (indexed by OpId).
    pub timings: Vec<OpTiming>,
    /// Finish time of the final exec op — the paper's objective `E_{e_N}`.
    pub makespan: Ms,
    /// Per-unit busy time (for utilization / energy accounting).
    pub busy: Vec<(UnitId, Ms)>,
}

/// Evaluate a plan, deriving op prices from `pricer`. Returns `Err` if the
/// plan deadlocks (queue order inconsistent with dependencies) or is
/// invalid.
pub fn evaluate(set: &OpSet, plan: &Plan, pricer: &Pricer) -> Result<Schedule, String> {
    let table = PriceTable::build(set, pricer);
    evaluate_with(set, plan, &table)
}

/// Evaluate a plan against a prebuilt price table (the hot-path form: no
/// cost-model work at all).
pub fn evaluate_with(set: &OpSet, plan: &Plan, table: &PriceTable) -> Result<Schedule, String> {
    plan.validate(set)?;
    let flat = Flat::of(set, plan);
    let (schedule, _order) = run(set, &flat, |op, u| table.by_unit_idx(op, u), None)?;
    Ok(schedule)
}

// ---------------------------------------------------------------------------
// Flattened plan view + the heap-based list-schedule core.
// ---------------------------------------------------------------------------

/// Flattened, reusable view of a plan's queues (unit 0 = gang).
#[derive(Debug, Clone)]
struct Flat {
    units: Vec<UnitId>,
    queues: Vec<Vec<OpId>>,
    /// Per op: index into `units`/`queues` of the unit that runs it.
    unit_of: Vec<usize>,
}

impl Flat {
    /// Build from a validated plan (every op appears exactly once).
    fn of(set: &OpSet, plan: &Plan) -> Flat {
        let mut units = Vec::with_capacity(1 + plan.little.len());
        let mut queues = Vec::with_capacity(1 + plan.little.len());
        let mut unit_of = vec![usize::MAX; set.len()];
        for (u, (id, q)) in plan.queues().into_iter().enumerate() {
            for &op in q {
                unit_of[op] = u;
            }
            units.push(id);
            queues.push(q.clone());
        }
        Flat { units, queues, unit_of }
    }
}

/// Heap entry: the head of one unit's queue, ready to start. Ordered so the
/// max-heap pops the smallest start time, ties broken by unit order — the
/// same deterministic rule as [`evaluate_reference`]'s linear scan.
#[derive(PartialEq)]
struct Ready {
    start: Ms,
    unit: usize,
}

impl Eq for Ready {}

impl Ord for Ready {
    fn cmp(&self, other: &Ready) -> Ordering {
        other
            .start
            .total_cmp(&self.start)
            .then_with(|| other.unit.cmp(&self.unit))
    }
}

impl PartialOrd for Ready {
    fn partial_cmp(&self, other: &Ready) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// The list-schedule core. Dispatches every queued op; with `prefix`, the
/// given ops are replayed from recorded timings (they must form a prefix of
/// a previous run's dispatch order under identical prices) and only the
/// remainder is scheduled. Returns the schedule plus the dispatch order.
///
/// Invariant the heap relies on: a unit holds at most one `Ready` entry —
/// its current queue head — pushed exactly once, when the head op's last
/// dependency finishes or when it becomes head with dependencies already
/// met. `unit_free` of an idle unit and the dependency finish-max of a
/// ready op cannot change afterwards, so entries are never stale.
fn run<F: Fn(OpId, usize) -> Ms>(
    set: &OpSet,
    flat: &Flat,
    price: F,
    prefix: Option<(&[OpId], &[OpTiming])>,
) -> Result<(Schedule, Vec<OpId>), String> {
    let n_units = flat.queues.len();
    let n_ops = set.len();
    let mut cursor = vec![0usize; n_units];
    let mut unit_free: Vec<Ms> = vec![0.0; n_units];
    let mut busy: Vec<Ms> = vec![0.0; n_units];
    let mut timings = vec![OpTiming { start: 0.0, finish: 0.0, unit: UnitId::Gang }; n_ops];
    let mut finished = vec![false; n_ops];
    let mut ready_at: Vec<Ms> = vec![0.0; n_ops];
    let mut pending: Vec<u32> = set.ops.iter().map(|o| o.deps.len() as u32).collect();
    let mut order: Vec<OpId> = Vec::with_capacity(n_ops);
    let mut remaining: usize = flat.queues.iter().map(Vec::len).sum();

    // --- Replay the unchanged prefix from the recording. ---
    if let Some((pre, base)) = prefix {
        for &op in pre {
            let t = base[op];
            let u = flat.unit_of[op];
            finished[op] = true;
            timings[op] = t;
            unit_free[u] = t.finish;
            busy[u] += t.finish - t.start;
            cursor[u] += 1;
            remaining -= 1;
            order.push(op);
            for &d in &set.dependents[op] {
                pending[d] -= 1;
                if ready_at[d] < t.finish {
                    ready_at[d] = t.finish;
                }
            }
        }
    }

    // --- Seed: every unit whose head op is ready. ---
    let mut heap: BinaryHeap<Ready> = BinaryHeap::with_capacity(n_units);
    for u in 0..n_units {
        if let Some(&h) = flat.queues[u].get(cursor[u]) {
            if pending[h] == 0 {
                heap.push(Ready { start: ready_at[h].max(unit_free[u]), unit: u });
            }
        }
    }

    // --- Dispatch loop. ---
    while remaining > 0 {
        let Some(Ready { start, unit: u }) = heap.pop() else {
            return Err(format!(
                "plan deadlocks with {remaining} ops unscheduled (queue order \
                 contradicts dependencies)"
            ));
        };
        let op = flat.queues[u][cursor[u]];
        let dur = price(op, u);
        let end = start + dur;
        finished[op] = true;
        timings[op] = OpTiming { start, finish: end, unit: flat.units[u] };
        unit_free[u] = end;
        busy[u] += dur;
        cursor[u] += 1;
        remaining -= 1;
        order.push(op);

        // Notify dependents; a dependent that is now ready *and* at its
        // queue's head becomes dispatchable.
        for &d in &set.dependents[op] {
            pending[d] -= 1;
            if ready_at[d] < end {
                ready_at[d] = end;
            }
            if pending[d] == 0 {
                let v = flat.unit_of[d];
                if v != usize::MAX && v != u && flat.queues[v].get(cursor[v]) == Some(&d) {
                    heap.push(Ready { start: ready_at[d].max(unit_free[v]), unit: v });
                }
            }
        }
        // This unit's new head (covers zero-dep ops and dependents on the
        // same unit, whose cursor just advanced).
        if let Some(&h) = flat.queues[u].get(cursor[u]) {
            if pending[h] == 0 {
                heap.push(Ready { start: ready_at[h].max(unit_free[u]), unit: u });
            }
        }
    }

    let final_exec = set.final_exec();
    let makespan = if finished[final_exec] { timings[final_exec].finish } else { 0.0 };
    let schedule = Schedule {
        timings,
        makespan,
        busy: flat.units.iter().zip(&busy).map(|(&id, &b)| (id, b)).collect(),
    };
    Ok((schedule, order))
}

// ---------------------------------------------------------------------------
// Incremental (delta) evaluation.
// ---------------------------------------------------------------------------

/// Price overrides for a trial kernel swap: `(op, gang_ms, little_ms)`.
pub type PriceDelta = (OpId, Ms, Ms);

/// Delta re-evaluator for the outer kernel-combination search.
///
/// Construction evaluates the plan once and records the dispatch order.
/// [`IncrementalEval::retime`] answers "what would the makespan be if these
/// ops had these prices?" by replaying the recorded prefix up to the first
/// re-priced op (O(1) amortized per replayed op — no ready-set decisions
/// are re-made) and list-scheduling only the suffix. Agreement with a
/// from-scratch [`evaluate_with`] under the mutated table is bit-exact
/// (property-tested in `tests/incremental_eval.rs`): the replayed state
/// (unit cursors, unit free times, dependency finish maxima) is exactly
/// the state a full run reaches at the same point.
pub struct IncrementalEval {
    flat: Flat,
    table: PriceTable,
    baseline: Schedule,
    /// Dispatch order of the baseline run.
    order: Vec<OpId>,
    /// Per op: its position in `order`.
    pos: Vec<usize>,
}

impl IncrementalEval {
    /// Validate + evaluate the plan under `table`, recording the baseline.
    pub fn new(set: &OpSet, plan: &Plan, table: PriceTable) -> Result<IncrementalEval, String> {
        plan.validate(set)?;
        let flat = Flat::of(set, plan);
        let (baseline, order) = run(set, &flat, |op, u| table.by_unit_idx(op, u), None)?;
        let mut pos = vec![0usize; set.len()];
        for (i, &op) in order.iter().enumerate() {
            pos[op] = i;
        }
        Ok(IncrementalEval { flat, table, baseline, order, pos })
    }

    /// Baseline makespan.
    pub fn makespan(&self) -> Ms {
        self.baseline.makespan
    }

    /// Baseline schedule.
    pub fn schedule(&self) -> &Schedule {
        &self.baseline
    }

    /// Baseline price table.
    pub fn table(&self) -> &PriceTable {
        &self.table
    }

    /// Consume the evaluator, returning its (possibly rebased) price
    /// table. After the outer search's apply phase this is bit-identical
    /// to a freshly priced table for the accepted kernel choices (per-op
    /// prices depend only on the op's own layer's choice, and candidate
    /// prices match the `Pricer` bit-for-bit), so the search carries it
    /// into the next pass — and into the incremental pass-end confirm —
    /// instead of re-running the cost model.
    pub fn into_table(self) -> PriceTable {
        self.table
    }

    /// Makespan with the prices of the `dirty` ops replaced, every other op
    /// priced as in the baseline table. The baseline is not modified.
    pub fn retime(&self, set: &OpSet, dirty: &[PriceDelta]) -> Result<Ms, String> {
        if dirty.is_empty() {
            return Ok(self.baseline.makespan);
        }
        let cut = dirty.iter().map(|&(op, _, _)| self.pos[op]).min().unwrap();
        let price = |op: OpId, u: usize| -> Ms {
            for &(d, g, l) in dirty {
                if d == op {
                    return if u == 0 { g } else { l };
                }
            }
            self.table.by_unit_idx(op, u)
        };
        let (schedule, _) = run(
            set,
            &self.flat,
            price,
            Some((&self.order[..cut], &self.baseline.timings[..])),
        )?;
        Ok(schedule.makespan)
    }

    /// Accept a swap: apply `dirty` to the owned table and re-record the
    /// baseline (full run — keeps `busy` exact and the recording replayable
    /// for the next [`IncrementalEval::retime`]).
    pub fn rebase(&mut self, set: &OpSet, dirty: &[PriceDelta]) -> Result<(), String> {
        for &(op, g, l) in dirty {
            self.table.set_op(op, g, l);
        }
        let (baseline, order) =
            run(set, &self.flat, |op, u| self.table.by_unit_idx(op, u), None)?;
        self.baseline = baseline;
        for (i, &op) in order.iter().enumerate() {
            self.pos[op] = i;
        }
        self.order = order;
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Reference evaluator + critical path.
// ---------------------------------------------------------------------------

/// The original O(units·deps)-per-dispatch linear-scan evaluator, kept as
/// the executable specification of list-schedule semantics. Production code
/// uses [`evaluate_with`]; `tests/incremental_eval.rs` asserts the two are
/// bit-identical.
pub fn evaluate_reference(set: &OpSet, plan: &Plan, pricer: &Pricer) -> Result<Schedule, String> {
    plan.validate(set)?;
    let queues: Vec<(UnitId, &Vec<usize>)> = plan.queues();
    let n_units = queues.len();
    let mut cursor = vec![0usize; n_units]; // next index in each queue
    let mut unit_free: Vec<Ms> = vec![0.0; n_units];
    let mut finish: Vec<Option<Ms>> = vec![None; set.len()];
    let mut timings = vec![
        OpTiming { start: 0.0, finish: 0.0, unit: UnitId::Gang };
        set.len()
    ];
    let mut busy: Vec<Ms> = vec![0.0; n_units];
    let mut remaining: usize = queues.iter().map(|(_, q)| q.len()).sum();

    while remaining > 0 {
        // Among units whose next op is ready, start the one that can start
        // earliest (deterministic tie-break by unit order).
        let mut best: Option<(usize, Ms)> = None;
        for (u, (_, q)) in queues.iter().enumerate() {
            if cursor[u] >= q.len() {
                continue;
            }
            let op = &set.ops[q[cursor[u]]];
            let deps_done: Option<Ms> = {
                let mut t: Ms = 0.0;
                let mut all = true;
                for &d in &op.deps {
                    match finish[d] {
                        Some(f) => t = t.max(f),
                        None => {
                            all = false;
                            break;
                        }
                    }
                }
                if all {
                    Some(t)
                } else {
                    None
                }
            };
            if let Some(ready_at) = deps_done {
                let start = ready_at.max(unit_free[u]);
                match best {
                    Some((_, s)) if s <= start => {}
                    _ => best = Some((u, start)),
                }
            }
        }
        let Some((u, start)) = best else {
            return Err(format!(
                "plan deadlocks with {remaining} ops unscheduled (queue order \
                 contradicts dependencies)"
            ));
        };
        let (unit, q) = &queues[u];
        let op_id = q[cursor[u]];
        let dur = pricer.price(&set.ops[op_id], *unit);
        let end = start + dur;
        finish[op_id] = Some(end);
        timings[op_id] = OpTiming { start, finish: end, unit: *unit };
        unit_free[u] = end;
        busy[u] += dur;
        cursor[u] += 1;
        remaining -= 1;
    }

    let final_exec = set.final_exec();
    let makespan = finish[final_exec].unwrap_or(0.0);
    Ok(Schedule {
        timings,
        makespan,
        busy: queues
            .iter()
            .enumerate()
            .map(|(u, (id, _))| (*id, busy[u]))
            .collect(),
    })
}

/// Lower bound on the makespan: the dependency-graph critical path with
/// every op priced at its fastest unit. Used by tests and the §Perf
/// pipeline-efficiency metric.
pub fn critical_path_ms(set: &OpSet, pricer: &Pricer) -> Ms {
    let mut dist = vec![0.0f64; set.len()];
    for op in &set.ops {
        let dur_gang = pricer.price(op, UnitId::Gang);
        let dur_little = pricer.price(op, UnitId::Little(0));
        let dur = dur_gang.min(dur_little);
        let pred: Ms = op
            .deps
            .iter()
            .map(|&d| dist[d])
            .fold(0.0, f64::max);
        dist[op.id] = pred + dur;
    }
    dist[set.final_exec()]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::profiles;
    use crate::graph::zoo;
    use crate::kernels::Registry;
    use crate::sched::op::OpSet;
    use crate::sched::plan::default_choices;

    fn sequential_plan(set: &OpSet, choices: Vec<Option<crate::sched::plan::KernelChoice>>, n_little: usize) -> Plan {
        Plan {
            choices,
            gang: (0..set.len()).collect(),
            little: vec![vec![]; n_little],
            estimated_ms: 0.0,
        }
    }

    #[test]
    fn sequential_makespan_equals_sum() {
        let dev = profiles::meizu_16t();
        let g = zoo::tiny_net();
        let choices = default_choices(&g, &Registry::full());
        let set = OpSet::build(&g, &choices, false);
        let pricer = Pricer::new(&dev, &g, &choices, false);
        let plan = sequential_plan(&set, choices.clone(), dev.n_little);
        let s = evaluate(&set, &plan, &pricer).unwrap();
        let sum: f64 = set
            .ops
            .iter()
            .map(|o| pricer.price(o, UnitId::Gang))
            .sum();
        assert!((s.makespan - sum).abs() < 1e-9, "{} vs {}", s.makespan, sum);
        // Gang busy the whole time; littles idle.
        assert!((s.busy[0].1 - sum).abs() < 1e-9);
        for (_, b) in &s.busy[1..] {
            assert_eq!(*b, 0.0);
        }
    }

    #[test]
    fn pipelined_beats_sequential() {
        let dev = profiles::meizu_16t();
        let g = zoo::mobilenet_v1();
        let choices = default_choices(&g, &Registry::full());
        let set = OpSet::build(&g, &choices, false);
        let pricer = Pricer::new(&dev, &g, &choices, false);
        let seq = evaluate(&set, &sequential_plan(&set, choices.clone(), dev.n_little), &pricer)
            .unwrap();
        // Round-robin prep bundles across little cores, execs on gang.
        let mut gang = Vec::new();
        let mut little: Vec<Vec<usize>> = vec![vec![]; dev.n_little];
        let mut rr = 0usize;
        for l in g.layers() {
            let bundle = set.prep_bundle(l.id);
            if !bundle.is_empty() {
                little[rr % dev.n_little].extend(bundle);
                rr += 1;
            }
            if let Some(e) = set.exec_of[l.id] {
                gang.push(e);
            }
        }
        let plan = Plan { choices: choices.clone(), gang, little, estimated_ms: 0.0 };
        let pipe = evaluate(&set, &plan, &pricer).unwrap();
        assert!(
            pipe.makespan < seq.makespan,
            "pipe {} vs seq {}",
            pipe.makespan,
            seq.makespan
        );
    }

    #[test]
    fn deadlock_detected() {
        let dev = profiles::meizu_16t();
        let g = zoo::tiny_net();
        let choices = default_choices(&g, &Registry::full());
        let set = OpSet::build(&g, &choices, false);
        let pricer = Pricer::new(&dev, &g, &choices, false);
        // Reverse the gang queue: exec ops before their reads on the same
        // unit ⇒ the first queued op depends on a later one ⇒ deadlock.
        let plan = Plan {
            choices: choices.clone(),
            gang: (0..set.len()).rev().collect(),
            little: vec![vec![]; dev.n_little],
            estimated_ms: 0.0,
        };
        assert!(evaluate(&set, &plan, &pricer).is_err());
        assert!(evaluate_reference(&set, &plan, &pricer).is_err());
        let table = PriceTable::build(&set, &pricer);
        assert!(IncrementalEval::new(&set, &plan, table).is_err());
    }

    #[test]
    fn heap_evaluator_matches_reference_exactly() {
        let dev = profiles::meizu_16t();
        for name in ["tinynet", "mobilenet", "resnet18", "googlenet"] {
            let g = zoo::by_name(name).unwrap();
            let choices = default_choices(&g, &Registry::full());
            let set = OpSet::build(&g, &choices, false);
            let pricer = Pricer::new(&dev, &g, &choices, false);
            // Pipelined plan (round-robin bundles) exercises cross-unit deps.
            let mut gang = Vec::new();
            let mut little: Vec<Vec<usize>> = vec![vec![]; dev.n_little];
            let mut rr = 0usize;
            for l in g.layers() {
                let bundle = set.prep_bundle(l.id);
                if !bundle.is_empty() {
                    little[rr % dev.n_little].extend(bundle);
                    rr += 1;
                }
                if let Some(e) = set.exec_of[l.id] {
                    gang.push(e);
                }
            }
            let plan = Plan { choices: choices.clone(), gang, little, estimated_ms: 0.0 };
            let fast = evaluate(&set, &plan, &pricer).unwrap();
            let slow = evaluate_reference(&set, &plan, &pricer).unwrap();
            assert_eq!(fast.makespan.to_bits(), slow.makespan.to_bits(), "{name}");
            for (a, b) in fast.timings.iter().zip(&slow.timings) {
                assert_eq!(a.start.to_bits(), b.start.to_bits(), "{name}");
                assert_eq!(a.finish.to_bits(), b.finish.to_bits(), "{name}");
                assert_eq!(a.unit, b.unit, "{name}");
            }
            for ((ua, ba), (ub, bb)) in fast.busy.iter().zip(&slow.busy) {
                assert_eq!(ua, ub);
                assert_eq!(ba.to_bits(), bb.to_bits(), "{name}");
            }
        }
    }

    #[test]
    fn retime_identity_returns_baseline() {
        let dev = profiles::meizu_16t();
        let g = zoo::mobilenet_v1();
        let choices = default_choices(&g, &Registry::full());
        let set = OpSet::build(&g, &choices, false);
        let pricer = Pricer::new(&dev, &g, &choices, false);
        let plan = sequential_plan(&set, choices.clone(), dev.n_little);
        let table = PriceTable::build(&set, &pricer);
        let inc = IncrementalEval::new(&set, &plan, table.clone()).unwrap();
        assert_eq!(inc.retime(&set, &[]).unwrap().to_bits(), inc.makespan().to_bits());
        // Re-pricing an op with its existing prices is also an identity.
        let op = set.final_exec();
        let same = inc
            .retime(&set, &[(op, table.gang[op], table.little[op])])
            .unwrap();
        assert_eq!(same.to_bits(), inc.makespan().to_bits());
    }

    #[test]
    fn rebase_tracks_mutated_table() {
        let dev = profiles::meizu_16t();
        let g = zoo::mobilenet_v1();
        let choices = default_choices(&g, &Registry::full());
        let set = OpSet::build(&g, &choices, false);
        let pricer = Pricer::new(&dev, &g, &choices, false);
        let plan = sequential_plan(&set, choices.clone(), dev.n_little);
        let mut table = PriceTable::build(&set, &pricer);
        let mut inc = IncrementalEval::new(&set, &plan, table.clone()).unwrap();
        let op = set.final_exec();
        let dirty = [(op, table.gang[op] * 2.0, table.little[op] * 2.0)];
        let predicted = inc.retime(&set, &dirty).unwrap();
        inc.rebase(&set, &dirty).unwrap();
        assert_eq!(inc.makespan().to_bits(), predicted.to_bits());
        table.set_op(op, dirty[0].1, dirty[0].2);
        let full = evaluate_with(&set, &plan, &table).unwrap();
        assert_eq!(full.makespan.to_bits(), inc.makespan().to_bits());
    }

    #[test]
    fn makespan_never_below_critical_path() {
        let dev = profiles::meizu_16t();
        for name in ["tinynet", "mobilenet", "resnet18"] {
            let g = zoo::by_name(name).unwrap();
            let choices = default_choices(&g, &Registry::full());
            let set = OpSet::build(&g, &choices, false);
            let pricer = Pricer::new(&dev, &g, &choices, false);
            let plan = sequential_plan(&set, choices.clone(), dev.n_little);
            let s = evaluate(&set, &plan, &pricer).unwrap();
            let cp = critical_path_ms(&set, &pricer);
            assert!(
                s.makespan >= cp - 1e-9,
                "{name}: makespan {} < critical path {cp}",
                s.makespan
            );
        }
    }
}
