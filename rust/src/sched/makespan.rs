//! List-schedule evaluator: compute start/finish times of every operation
//! given per-unit queues, respecting dependencies and queue order.
//!
//! This is the scheduler's *internal* objective evaluator (fast, no
//! contention modelling). The discrete-event simulator ([`crate::sim`])
//! re-executes plans with disk/memory-bandwidth interference, background
//! load, and workload stealing; the two agree exactly when contention is
//! absent (asserted by `tests/sim_vs_makespan.rs`).

use crate::sched::op::OpSet;
use crate::sched::plan::{Plan, UnitId};
use crate::sched::price::Pricer;
use crate::Ms;

/// Timing of one scheduled operation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OpTiming {
    pub start: Ms,
    pub finish: Ms,
    pub unit: UnitId,
}

/// Full evaluation result.
#[derive(Debug, Clone)]
pub struct Schedule {
    /// Per-op timings (indexed by OpId).
    pub timings: Vec<OpTiming>,
    /// Finish time of the final exec op — the paper's objective `E_{e_N}`.
    pub makespan: Ms,
    /// Per-unit busy time (for utilization / energy accounting).
    pub busy: Vec<(UnitId, Ms)>,
}

/// Evaluate a plan. Returns `Err` if the plan deadlocks (queue order
/// inconsistent with dependencies) or is invalid.
pub fn evaluate(set: &OpSet, plan: &Plan, pricer: &Pricer) -> Result<Schedule, String> {
    plan.validate(set)?;
    let queues: Vec<(UnitId, &Vec<usize>)> = plan.queues();
    let n_units = queues.len();
    let mut cursor = vec![0usize; n_units]; // next index in each queue
    let mut unit_free: Vec<Ms> = vec![0.0; n_units];
    let mut finish: Vec<Option<Ms>> = vec![None; set.len()];
    let mut timings = vec![
        OpTiming { start: 0.0, finish: 0.0, unit: UnitId::Gang };
        set.len()
    ];
    let mut busy: Vec<Ms> = vec![0.0; n_units];
    let mut remaining: usize = queues.iter().map(|(_, q)| q.len()).sum();

    while remaining > 0 {
        // Among units whose next op is ready, start the one that can start
        // earliest (deterministic tie-break by unit order).
        let mut best: Option<(usize, Ms)> = None;
        for (u, (_, q)) in queues.iter().enumerate() {
            if cursor[u] >= q.len() {
                continue;
            }
            let op = &set.ops[q[cursor[u]]];
            let deps_done: Option<Ms> = {
                let mut t: Ms = 0.0;
                let mut all = true;
                for &d in &op.deps {
                    match finish[d] {
                        Some(f) => t = t.max(f),
                        None => {
                            all = false;
                            break;
                        }
                    }
                }
                if all {
                    Some(t)
                } else {
                    None
                }
            };
            if let Some(ready_at) = deps_done {
                let start = ready_at.max(unit_free[u]);
                match best {
                    Some((_, s)) if s <= start => {}
                    _ => best = Some((u, start)),
                }
            }
        }
        let Some((u, start)) = best else {
            return Err(format!(
                "plan deadlocks with {remaining} ops unscheduled (queue order \
                 contradicts dependencies)"
            ));
        };
        let (unit, q) = &queues[u];
        let op_id = q[cursor[u]];
        let dur = pricer.price(&set.ops[op_id], *unit);
        let end = start + dur;
        finish[op_id] = Some(end);
        timings[op_id] = OpTiming { start, finish: end, unit: *unit };
        unit_free[u] = end;
        busy[u] += dur;
        cursor[u] += 1;
        remaining -= 1;
    }

    let final_exec = set.final_exec();
    let makespan = finish[final_exec].unwrap_or(0.0);
    Ok(Schedule {
        timings,
        makespan,
        busy: queues
            .iter()
            .enumerate()
            .map(|(u, (id, _))| (*id, busy[u]))
            .collect(),
    })
}

/// Lower bound on the makespan: the dependency-graph critical path with
/// every op priced at its fastest unit. Used by tests and the §Perf
/// pipeline-efficiency metric.
pub fn critical_path_ms(set: &OpSet, pricer: &Pricer) -> Ms {
    let mut dist = vec![0.0f64; set.len()];
    for op in &set.ops {
        let dur_gang = pricer.price(op, UnitId::Gang);
        let dur_little = pricer.price(op, UnitId::Little(0));
        let dur = dur_gang.min(dur_little);
        let pred: Ms = op
            .deps
            .iter()
            .map(|&d| dist[d])
            .fold(0.0, f64::max);
        dist[op.id] = pred + dur;
    }
    dist[set.final_exec()]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::profiles;
    use crate::graph::zoo;
    use crate::kernels::Registry;
    use crate::sched::op::OpSet;
    use crate::sched::plan::default_choices;

    fn sequential_plan(set: &OpSet, choices: Vec<Option<crate::sched::plan::KernelChoice>>, n_little: usize) -> Plan {
        Plan {
            choices,
            gang: (0..set.len()).collect(),
            little: vec![vec![]; n_little],
            estimated_ms: 0.0,
        }
    }

    #[test]
    fn sequential_makespan_equals_sum() {
        let dev = profiles::meizu_16t();
        let g = zoo::tiny_net();
        let choices = default_choices(&g, &Registry::full());
        let set = OpSet::build(&g, &choices, false);
        let pricer = Pricer::new(&dev, &g, &choices, false);
        let plan = sequential_plan(&set, choices.clone(), dev.n_little);
        let s = evaluate(&set, &plan, &pricer).unwrap();
        let sum: f64 = set
            .ops
            .iter()
            .map(|o| pricer.price(o, UnitId::Gang))
            .sum();
        assert!((s.makespan - sum).abs() < 1e-9, "{} vs {}", s.makespan, sum);
        // Gang busy the whole time; littles idle.
        assert!((s.busy[0].1 - sum).abs() < 1e-9);
        for (_, b) in &s.busy[1..] {
            assert_eq!(*b, 0.0);
        }
    }

    #[test]
    fn pipelined_beats_sequential() {
        let dev = profiles::meizu_16t();
        let g = zoo::mobilenet_v1();
        let choices = default_choices(&g, &Registry::full());
        let set = OpSet::build(&g, &choices, false);
        let pricer = Pricer::new(&dev, &g, &choices, false);
        let seq = evaluate(&set, &sequential_plan(&set, choices.clone(), dev.n_little), &pricer)
            .unwrap();
        // Round-robin prep bundles across little cores, execs on gang.
        let mut gang = Vec::new();
        let mut little: Vec<Vec<usize>> = vec![vec![]; dev.n_little];
        let mut rr = 0usize;
        for l in g.layers() {
            let bundle = set.prep_bundle(l.id);
            if !bundle.is_empty() {
                little[rr % dev.n_little].extend(bundle);
                rr += 1;
            }
            if let Some(e) = set.exec_of[l.id] {
                gang.push(e);
            }
        }
        let plan = Plan { choices: choices.clone(), gang, little, estimated_ms: 0.0 };
        let pipe = evaluate(&set, &plan, &pricer).unwrap();
        assert!(
            pipe.makespan < seq.makespan,
            "pipe {} vs seq {}",
            pipe.makespan,
            seq.makespan
        );
    }

    #[test]
    fn deadlock_detected() {
        let dev = profiles::meizu_16t();
        let g = zoo::tiny_net();
        let choices = default_choices(&g, &Registry::full());
        let set = OpSet::build(&g, &choices, false);
        let pricer = Pricer::new(&dev, &g, &choices, false);
        // Reverse the gang queue: exec ops before their reads on the same
        // unit ⇒ the first queued op depends on a later one ⇒ deadlock.
        let plan = Plan {
            choices: choices.clone(),
            gang: (0..set.len()).rev().collect(),
            little: vec![vec![]; dev.n_little],
            estimated_ms: 0.0,
        };
        assert!(evaluate(&set, &plan, &pricer).is_err());
    }

    #[test]
    fn makespan_never_below_critical_path() {
        let dev = profiles::meizu_16t();
        for name in ["tinynet", "mobilenet", "resnet18"] {
            let g = zoo::by_name(name).unwrap();
            let choices = default_choices(&g, &Registry::full());
            let set = OpSet::build(&g, &choices, false);
            let pricer = Pricer::new(&dev, &g, &choices, false);
            let plan = sequential_plan(&set, choices.clone(), dev.n_little);
            let s = evaluate(&set, &plan, &pricer).unwrap();
            let cp = critical_path_ms(&set, &pricer);
            assert!(
                s.makespan >= cp - 1e-9,
                "{name}: makespan {} < critical path {cp}",
                s.makespan
            );
        }
    }
}
