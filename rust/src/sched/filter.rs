//! Kernel-candidate filtering (Algorithm 1, line 1).
//!
//! "Filter out the kernel candidates that exhibit no faster operation" —
//! i.e. keep only the Pareto frontier over (preparation time, execution
//! time). Each surviving kernel additionally spawns a cached variant
//! (read post-transformed weights, skip transformation) when that is
//! cheaper preparation, so a *candidate* here is a full [`KernelChoice`].
//! The paper observes 1–2 candidates typically survive per operator.

use crate::cost::CostModel;
use crate::device::{CoreClass, DeviceProfile};
use crate::graph::Layer;
use crate::kernels::Registry;
use crate::sched::plan::KernelChoice;
use crate::Ms;

/// A candidate with its scheduling-relevant costs, priced once at filter
/// time on both unit classes — the per-candidate slice of the flat price
/// table the outer search consumes. The stage prices mirror
/// [`crate::sched::price::Pricer::price`] exactly (same [`CostModel`]
/// calls), so swapping a layer to this candidate is a pure table update
/// with no cost-model work.
#[derive(Debug, Clone)]
pub struct Candidate {
    pub choice: KernelChoice,
    /// Preparation (read + transform) on a little core, ms.
    pub prep_ms: Ms,
    /// Execution on the gang, ms.
    pub exec_ms: Ms,
    /// Read op price on the gang / a little core.
    pub read_g: Ms,
    pub read_l: Ms,
    /// Transform op price on the gang / a little core (0 when the choice
    /// bypasses transformation — cached weights or a transform-free family).
    pub tf_g: Ms,
    pub tf_l: Ms,
    /// Exec op price on the gang / a little core (single-threaded).
    pub exec_g: Ms,
    pub exec_l: Ms,
}

/// Enumerate and Pareto-filter the candidates of one layer. With
/// `allow_cache = false` (the "no C knob" ablation) only raw-read variants
/// are generated.
pub fn candidates(
    dev: &DeviceProfile,
    layer: &Layer,
    registry: &Registry,
    allow_cache: bool,
) -> Vec<Candidate> {
    let cm = CostModel::new(dev);
    let (exec_class, exec_threads) = cm.exec_class();
    let mut all: Vec<Candidate> = Vec::new();
    for kernel in registry.candidates(layer) {
        let exec_g = cm.exec_ms(&kernel, layer, exec_class, exec_threads);
        let exec_l = cm.exec_ms(&kernel, layer, CoreClass::Little, 1);
        // Uncached variant: read raw weights, pay the transform (if the
        // family has one — `transform_ms` is 0 otherwise).
        let read_g = cm.read_ms(layer.weight_bytes(), CoreClass::Big, 1);
        let read_l = cm.read_ms(layer.weight_bytes(), CoreClass::Little, 1);
        let tf_g = cm.transform_ms(&kernel, layer, CoreClass::Big, 1);
        let tf_l = cm.transform_ms(&kernel, layer, CoreClass::Little, 1);
        all.push(Candidate {
            choice: KernelChoice { kernel: kernel.clone(), cache: false },
            prep_ms: read_l + tf_l,
            exec_ms: exec_g,
            read_g,
            read_l,
            tf_g,
            tf_l,
            exec_g,
            exec_l,
        });
        // Cached variant (only meaningful if a transform exists to bypass):
        // read the (larger) post-transformed blob, skip the transform.
        if allow_cache && kernel.family.needs_transform() {
            let bytes = kernel.transformed_bytes(layer);
            let cread_g = cm.read_ms(bytes, CoreClass::Big, 1);
            let cread_l = cm.read_ms(bytes, CoreClass::Little, 1);
            all.push(Candidate {
                choice: KernelChoice { kernel, cache: true },
                prep_ms: cread_l,
                exec_ms: exec_g,
                read_g: cread_g,
                read_l: cread_l,
                tf_g: 0.0,
                tf_l: 0.0,
                exec_g,
                exec_l,
            });
        }
    }
    pareto(all)
}

/// Keep the Pareto frontier over (prep_ms, exec_ms), minimizing both.
fn pareto(mut cands: Vec<Candidate>) -> Vec<Candidate> {
    // Sort by prep, then exec: a candidate is dominated if an earlier one
    // has ≤ prep and ≤ exec.
    cands.sort_by(|a, b| {
        a.prep_ms
            .partial_cmp(&b.prep_ms)
            .unwrap()
            .then(a.exec_ms.partial_cmp(&b.exec_ms).unwrap())
    });
    let mut frontier: Vec<Candidate> = Vec::new();
    let mut best_exec = f64::INFINITY;
    for c in cands {
        if c.exec_ms < best_exec - 1e-12 {
            best_exec = c.exec_ms;
            frontier.push(c);
        }
    }
    frontier
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::profiles;
    use crate::graph::OpKind;
    use crate::kernels::KernelFamily;

    fn conv(in_ch: u32, out_ch: u32, hw: u32, k: u32, s: u32) -> Layer {
        Layer {
            id: 0,
            name: "c".into(),
            op: OpKind::Conv { kernel: k, stride: s, groups: 1 },
            in_ch,
            out_ch,
            in_hw: hw,
            out_hw: hw / s,
            deps: vec![],
        }
    }

    #[test]
    fn frontier_is_pareto() {
        let dev = profiles::meizu_16t();
        let l = conv(64, 192, 56, 3, 1);
        let cs = candidates(&dev, &l, &Registry::full(), true);
        assert!(!cs.is_empty());
        for a in &cs {
            for b in &cs {
                if std::ptr::eq(a, b) {
                    continue;
                }
                let dominates = a.prep_ms <= b.prep_ms + 1e-12
                    && a.exec_ms <= b.exec_ms + 1e-12;
                assert!(!dominates, "{:?} dominates {:?}", a.choice, b.choice);
            }
        }
    }

    #[test]
    fn few_candidates_survive() {
        // Paper: "there are only 1–2 candidate kernels left for each
        // operator as observed". Allow up to 4 for safety.
        let dev = profiles::meizu_16t();
        for (ic, oc, hw, k, s) in
            [(64, 192, 56, 3, 1), (64, 64, 56, 1, 1), (3, 32, 224, 3, 2), (256, 512, 14, 3, 2)]
        {
            let l = conv(ic, oc, hw, k, s);
            let cs = candidates(&dev, &l, &Registry::full(), true);
            assert!(
                (1..=4).contains(&cs.len()),
                "k{k}s{s} {ic}->{oc}: {} candidates",
                cs.len()
            );
        }
    }

    #[test]
    fn winograd_survives_as_cached_for_3x3s1() {
        // For the Table 2 conv, the fastest-exec candidate should be a
        // cached winograd (fast exec, cheap prep via cache) — exactly the
        // paper's "C" knob.
        let dev = profiles::meizu_16t();
        let l = conv(64, 192, 56, 3, 1);
        let cs = candidates(&dev, &l, &Registry::full(), true);
        let fastest = cs
            .iter()
            .min_by(|a, b| a.exec_ms.partial_cmp(&b.exec_ms).unwrap())
            .unwrap();
        assert_eq!(fastest.choice.kernel.family, KernelFamily::WinogradPack4);
        assert!(fastest.choice.cache, "fastest-exec candidate should be cached");
    }

    #[test]
    fn candidate_prices_match_pricer_exactly() {
        use crate::graph::zoo;
        use crate::sched::op::OpSet;
        use crate::sched::plan::{default_choices, UnitId};
        use crate::sched::price::Pricer;
        for (dev, gpu) in [(profiles::meizu_16t(), false), (profiles::jetson_tx2(), true)] {
            let g = zoo::resnet50();
            let reg = Registry::full();
            for &layer in g.weighted_layers().iter().take(8) {
                let l = g.layer(layer);
                for c in candidates(&dev, l, &reg, true) {
                    let mut choices = default_choices(&g, &reg);
                    choices[layer] = Some(c.choice.clone());
                    let set = OpSet::build(&g, &choices, gpu);
                    let p = Pricer::new(&dev, &g, &choices, true);
                    let r = set.read_of[layer].unwrap();
                    assert_eq!(p.price(&set.ops[r], UnitId::Gang).to_bits(), c.read_g.to_bits());
                    assert_eq!(p.price(&set.ops[r], UnitId::Little(0)).to_bits(), c.read_l.to_bits());
                    // Canonical sets always carry the transform op; for a
                    // bypassing candidate both sides must be exactly 0.
                    let w = set.transform_of[layer].expect("canonical transform op");
                    assert_eq!(p.price(&set.ops[w], UnitId::Gang).to_bits(), c.tf_g.to_bits());
                    assert_eq!(p.price(&set.ops[w], UnitId::Little(0)).to_bits(), c.tf_l.to_bits());
                    let e = set.exec_of[layer].unwrap();
                    assert_eq!(p.price(&set.ops[e], UnitId::Gang).to_bits(), c.exec_g.to_bits());
                    assert_eq!(p.price(&set.ops[e], UnitId::Little(0)).to_bits(), c.exec_l.to_bits());
                }
            }
        }
    }

    #[test]
    fn direct_kernel_survives_as_cheapest_prep() {
        let dev = profiles::meizu_16t();
        let l = conv(64, 192, 56, 3, 1);
        let cs = candidates(&dev, &l, &Registry::full(), true);
        let cheapest = cs
            .iter()
            .min_by(|a, b| a.prep_ms.partial_cmp(&b.prep_ms).unwrap())
            .unwrap();
        // Cheapest prep pays no transformation on the critical path: either
        // a no-transform family on raw weights, or a size-preserving cached
        // layout (sgemm-pack4's cache file is the same size as raw).
        assert!(
            !cheapest.choice.kernel.family.needs_transform() || cheapest.choice.cache,
            "{:?}",
            cheapest.choice
        );
        let cm = CostModel::new(&dev);
        let raw_read = cm.read_ms(l.weight_bytes(), CoreClass::Little, 1);
        assert!(cheapest.prep_ms <= raw_read * 1.05, "{} vs {}", cheapest.prep_ms, raw_read);
    }
}
