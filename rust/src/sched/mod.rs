//! The kernel scheduling problem (§3.2) and the heuristic scheduler (§3.3).
//!
//! A cold inference of an N-layer model decomposes into up to 4N
//! *operations*: per-layer weights **read**, weights **transform**, kernel
//! **execution**, and (GPU) **pipeline creation**. The scheduler jointly
//! decides (i) which kernel each layer uses, (ii) whether to bypass its
//! transformation by reading cached post-transformed weights, and (iii)
//! where/when each operation runs. The exact problem is nonlinear integer
//! programming (NP-hard); NNV12 uses the heuristics of §3.3:
//!
//! * execution operations always occupy **all big cores** (or the GPU) as
//!   one gang, in model order;
//! * each layer's read+transform are **bundled** into a preparation
//!   operation placed on a single little core;
//! * Algorithm 1 balances preparations across little cores and migrates
//!   early-layer preparations onto the big gang when the gang would
//!   otherwise idle.
//!
//! # The exact incremental plan-search engine
//!
//! The outer kernel-combination search is the planner's hot path: it
//! evaluates hundreds of single-layer kernel swaps per model. Four layers
//! make every step cheap *and structurally exact*:
//!
//! 1. **Canonical op sets** ([`op::OpSet::build`]). Every weighted layer
//!    materializes its full read → transform → exec chain; a choice that
//!    bypasses transformation (cached post-transformed weights, or a
//!    transform-free family) keeps a zero-priced transform op, which is
//!    timing-neutral right after its read. The op-set *structure* is
//!    therefore a function of the graph alone — a kernel swap never adds
//!    or removes ops — so screening and confirming are pure price-table
//!    updates with no approximation. (The historical fold of a
//!    candidate's transform cost into its read price, which could
//!    mis-rank candidates when read and transform were not
//!    contention-adjacent, is gone; the pre-canonical structure survives
//!    only as the [`op::OpSet::build_minimal`] test oracle.)
//! 2. **Flat price tables** ([`price::PriceTable`], plus the per-stage
//!    prices on [`filter::Candidate`]). Unit cost depends only on the unit
//!    *class* (gang vs little — all little cores are identical), so a
//!    table of two `Vec<f64>` lanes indexed by `OpId` replaces every
//!    cost-model call after setup. Candidates are priced once at
//!    Pareto-filter time; swapping a layer's kernel is an exact 3-entry
//!    table update ([`heuristic::swap_prices`]), never a `CostModel`
//!    re-derivation.
//! 3. **Delta re-evaluation** ([`makespan::IncrementalEval`]). The
//!    baseline evaluation records its dispatch order; a trial replays the
//!    unchanged schedule prefix (every dispatch before the first re-priced
//!    op) from the recording and list-schedules only the affected suffix,
//!    with a binary-heap ready-queue in place of the per-dispatch
//!    O(units·deps) rescan. Delta results are **bit-exact** against a
//!    from-scratch [`makespan::evaluate_with`] under the same prices
//!    (property-tested in `tests/incremental_eval.rs` against
//!    [`makespan::evaluate_reference`], the original evaluator kept as the
//!    executable specification).
//! 4. **Parallel coordinate descent with an incremental confirm**
//!    ([`heuristic::schedule`]). Each pass freezes the incumbent plan,
//!    screens every layer's best alternative kernel concurrently
//!    (`util::parallel::par_map`) against the frozen baseline, and
//!    applies surviving swaps to `pick` in place, rebasing the
//!    evaluator's table. The pass-end confirm
//!    ([`heuristic::confirm_from_table`]) re-runs only the Algorithm-1
//!    queue assembly — bundle promotion via precomputed round-robin
//!    suffix loads (O(layers × little cores), the last O(layers²) step
//!    removed) plus little-core balancing — and one full evaluation over
//!    the rebased table, which canonical sets keep bit-identical to a
//!    freshly priced one. The table then carries into the next pass, so
//!    the cost model runs exactly once per search. The confirm remains
//!    the only accept gate: the returned plan is always fully evaluated,
//!    never a delta estimate. [`heuristic::inner_schedule`] (the
//!    from-scratch rebuild) survives as the oracle
//!    `tests/canonical_confirm.rs` proves the confirm bit-exact against,
//!    across randomized descent traces.
//!
//! Price-table invariants relied on throughout: `table.gang[op]` /
//! `table.little[op]` equal `Pricer::price(op, Gang)` / `price(op,
//! Little(_))` for the choices the table was built from; bypassed
//! transforms price as 0 on both lanes; and a candidate's flat prices
//! equal what a `Pricer` over that candidate's choice would produce
//! (asserted by `candidate_prices_match_pricer_exactly` — this is what
//! makes the rebased table exact, and the incremental confirm sound).
//!
//! Repeat planning of an identical problem skips all of the above via the
//! fingerprint-keyed [`cache::PlanCache`]; a cache opened with
//! [`cache::PlanCache::persistent`] (or over a shared
//! [`crate::store::ArtifactStore`]) additionally survives the *process*
//! as content-addressed plan artifacts (Fig. 4's offline decision stage
//! on disk), so even a fresh engine skips the search. Calibrated plans —
//! which carry a re-profiled device view as part of the answer — live in
//! their own [`cache::CalibratedPlanCache`] and store namespace, so the
//! fig8/fig10 grids and repeated calibrated engines skip the (much more
//! expensive) calibration loop the same way.
//!
//! # Fleet planning: seeded search vs cold search
//!
//! [`heuristic::schedule_seeded`] is the cross-device entry point of the
//! [`crate::fleet`] subsystem. Instead of descending from the greedy
//! seed, it maps a *donor device's* kernel choices onto the target's
//! Pareto-filtered candidates, re-prices them by patching the greedy
//! rebuild's price table at only the disagreeing layers (canonical op
//! sets make each patch an exact 3-entry delta), and confirms the result
//! with the same [`heuristic::confirm_from_table`] used at every pass
//! end. The transferred seed is **accepted** only when that confirmed
//! makespan is no worse than the target's own greedy baseline; it then
//! runs a single descent pass restricted to the transferred layers. It
//! is **rejected** — and the search falls back to the full cold descent
//! — when the seed has the wrong layer count or re-prices worse than the
//! baseline. Both branches end at a confirmed, fully evaluated plan that
//! is never worse than the greedy baseline, so transfer affects search
//! *time*, never the quality floor ([`heuristic::TransferOutcome`]
//! documents the invariants).
//!
//! # Expected-makespan search (multi-exit models)
//!
//! The same exact machinery schedules BranchyNet-style multi-exit models
//! under *expected* cost: [`crate::exits::schedule_expected`] scales the
//! candidate prices and the confirmed price table by the graph's
//! per-layer survival weights ([`crate::graph::ModelGraph::survival_weights`])
//! and then runs the identical greedy → confirm → descent pipeline — the
//! weighting touches only the two table lanes, so every exactness
//! invariant above carries over verbatim, and an all-ones weight vector
//! (no exits, or all-zero exit probabilities) reproduces
//! [`heuristic::schedule`] bit-for-bit (IEEE `x * 1.0 == x`). That
//! module, not this one, also prices serving the conditional tail on a
//! remote ([`crate::exits::OffloadPolicy`]).
//!
//! Callers normally do not drive this module directly: the
//! [`crate::engine::Engine`] facade owns planning (cache, store,
//! calibration) and hands out sessions; `sched` is the planner it drives.
//!
//! Modules: [`op`] (operation set + dependencies), [`plan`] (the output,
//! JSON round-trippable), [`price`] (operation costing on units + the
//! flat price table), [`makespan`] (list-schedule evaluator: heap-based,
//! incremental, and reference), [`filter`] (kernel candidate Pareto
//! filtering + candidate pricing), [`heuristic`] (Algorithm 1 + the
//! incremental outer search), [`cache`] (fingerprint-keyed plan +
//! calibrated-plan caches over the artifact store), [`bruteforce`]
//! (exact oracle for tiny instances, test-only scale).

pub mod op;
pub mod plan;
pub mod price;
pub mod makespan;
pub mod filter;
pub mod heuristic;
pub mod cache;
pub mod bruteforce;

pub use cache::{CalibratedPlanCache, PlanCache};
pub use heuristic::{schedule, schedule_seeded, SchedulerConfig, TransferOutcome};
pub use makespan::IncrementalEval;
pub use op::{OpId, OpSet, OpStage, Operation};
pub use plan::{KernelChoice, Plan, UnitId};
pub use price::{PriceTable, Pricer};
