//! The kernel scheduling problem (§3.2) and the heuristic scheduler (§3.3).
//!
//! A cold inference of an N-layer model decomposes into up to 4N
//! *operations*: per-layer weights **read**, weights **transform**, kernel
//! **execution**, and (GPU) **pipeline creation**. The scheduler jointly
//! decides (i) which kernel each layer uses, (ii) whether to bypass its
//! transformation by reading cached post-transformed weights, and (iii)
//! where/when each operation runs. The exact problem is nonlinear integer
//! programming (NP-hard); NNV12 uses the heuristics of §3.3:
//!
//! * execution operations always occupy **all big cores** (or the GPU) as
//!   one gang, in model order;
//! * each layer's read+transform are **bundled** into a preparation
//!   operation placed on a single little core;
//! * Algorithm 1 balances preparations across little cores and migrates
//!   early-layer preparations onto the big gang when the gang would
//!   otherwise idle.
//!
//! Modules: [`op`] (operation set + dependencies), [`plan`] (the output),
//! [`price`] (operation costing on units), [`makespan`] (list-schedule
//! evaluator), [`filter`] (kernel candidate Pareto filtering),
//! [`heuristic`] (Algorithm 1 + outer kernel-combination search),
//! [`bruteforce`] (exact oracle for tiny instances, test-only scale).

pub mod op;
pub mod plan;
pub mod price;
pub mod makespan;
pub mod filter;
pub mod heuristic;
pub mod bruteforce;

pub use heuristic::{schedule, SchedulerConfig};
pub use op::{OpId, OpSet, OpStage, Operation};
pub use plan::{KernelChoice, Plan, UnitId};
pub use price::Pricer;
