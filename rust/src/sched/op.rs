//! The operation set: per-layer read/transform/exec (+ GPU pipeline
//! creation) with the dependency graph of §3.2.
//!
//! Op sets are **canonical**: [`OpSet::build`] materializes the full
//! read → transform → exec chain for *every* weighted layer, even when
//! the kernel choice bypasses transformation (cached post-transformed
//! weights, or a transform-free family) — the bypassed transform op
//! simply prices as 0 ([`crate::sched::price::Pricer`]). A zero-cost op
//! queued directly after its read on the same unit is timing-neutral
//! (`finish = read.finish + 0.0`), so canonical sets evaluate
//! bit-identically to the historical minimal sets
//! (`tests/canonical_confirm.rs`), while making the op-set *structure* a
//! function of the graph alone: swapping a layer's kernel never adds or
//! removes ops, so the outer search's screening
//! ([`crate::sched::heuristic::swap_prices`]) and pass-end confirm
//! ([`crate::sched::heuristic::confirm_from_table`]) are pure price-table
//! updates. The pre-canonical structure survives as
//! [`OpSet::build_minimal`], a test oracle only.

use crate::graph::{LayerId, ModelGraph};
use crate::sched::plan::KernelChoice;

/// Index into [`OpSet::ops`].
pub type OpId = usize;

/// Stage of a kernel (§3.2 uses r_i, w_i, e_i; §3.4 adds pipeline creation).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum OpStage {
    /// One-shot GPU driver/context initialization (GPU devices only).
    DriverInit,
    /// Read (raw or cached post-transformed) weights from disk.
    Read,
    /// Transform raw weights into the kernel's layout.
    Transform,
    /// Create the GPU pipeline (compile shader unless cached) for a kernel.
    Pipeline,
    /// Execute the kernel.
    Exec,
}

impl OpStage {
    pub fn name(&self) -> &'static str {
        match self {
            OpStage::DriverInit => "driver-init",
            OpStage::Read => "read",
            OpStage::Transform => "transform",
            OpStage::Pipeline => "pipeline",
            OpStage::Exec => "exec",
        }
    }
}

/// One schedulable operation.
#[derive(Debug, Clone)]
pub struct Operation {
    pub id: OpId,
    /// Owning layer (DriverInit uses layer 0 by convention).
    pub layer: LayerId,
    pub stage: OpStage,
    /// Precursor operations (Θ_i in the paper's formulation).
    pub deps: Vec<OpId>,
}

/// The full operation set for one model + kernel-choice combination.
#[derive(Debug, Clone)]
pub struct OpSet {
    pub ops: Vec<Operation>,
    /// Per-layer handle: read op (if any).
    pub read_of: Vec<Option<OpId>>,
    /// Per-layer handle: transform op (if any).
    pub transform_of: Vec<Option<OpId>>,
    /// Per-layer handle: pipeline-creation op (if any).
    pub pipeline_of: Vec<Option<OpId>>,
    /// Per-layer handle: exec op (if any; Input layers have none).
    pub exec_of: Vec<Option<OpId>>,
    /// The driver-init op (GPU devices).
    pub driver_init: Option<OpId>,
    /// Reverse dependency adjacency: `dependents[i]` = ops with `i` in
    /// their `deps`. Precomputed once so the evaluator's finish-event
    /// notification is O(edges) per evaluation instead of re-scanning
    /// `deps` of every queue head per dispatched op.
    pub dependents: Vec<Vec<OpId>>,
}

impl OpSet {
    /// Build the canonical operation set for `graph` under `choices` (one
    /// optional [`KernelChoice`] per layer; `None` for weightless layers).
    /// Every weighted layer gets read, transform, and exec ops — a choice
    /// that bypasses transformation keeps its transform op at zero price —
    /// so the returned structure (op ids, stages, dependencies) is
    /// identical for every choice vector over the same graph. With `gpu`,
    /// pipeline-creation ops and a driver-init op are added and every exec
    /// op depends on its pipeline op (§3.4).
    pub fn build(graph: &ModelGraph, choices: &[Option<KernelChoice>], gpu: bool) -> OpSet {
        OpSet::build_impl(graph, choices, gpu, true)
    }

    /// The pre-canonical structure: a transform op exists only when the
    /// choice actually transforms (`needs_transform() && !cache`), so
    /// exec ops of bypassing layers depend directly on their read. Kept
    /// solely as the test oracle that canonical sets are timing-neutral
    /// (`tests/canonical_confirm.rs`) and to fabricate pre-canonical plan
    /// artifacts in cache tests; production code always builds canonical
    /// sets.
    pub fn build_minimal(graph: &ModelGraph, choices: &[Option<KernelChoice>], gpu: bool) -> OpSet {
        OpSet::build_impl(graph, choices, gpu, false)
    }

    fn build_impl(
        graph: &ModelGraph,
        choices: &[Option<KernelChoice>],
        gpu: bool,
        canonical: bool,
    ) -> OpSet {
        assert_eq!(choices.len(), graph.len());
        let n = graph.len();
        let mut set = OpSet {
            ops: Vec::with_capacity(3 * n + 1),
            read_of: vec![None; n],
            transform_of: vec![None; n],
            pipeline_of: vec![None; n],
            exec_of: vec![None; n],
            driver_init: None,
            dependents: Vec::new(),
        };
        let push = |layer: LayerId, stage: OpStage, deps: Vec<OpId>, ops: &mut Vec<Operation>| -> OpId {
            let id = ops.len();
            ops.push(Operation { id, layer, stage, deps });
            id
        };

        if gpu {
            let id = push(0, OpStage::DriverInit, vec![], &mut set.ops);
            set.driver_init = Some(id);
        }

        for layer in graph.layers() {
            let i = layer.id;
            let choice = &choices[i];
            // Read raw or cached weights.
            if layer.op.has_weights() {
                let r = push(i, OpStage::Read, vec![], &mut set.ops);
                set.read_of[i] = Some(r);
                // Canonical: the transform op always exists; a bypassed
                // one (cache read, or a transform-free family) prices as
                // 0 and is timing-neutral right after its read. Minimal
                // (oracle only): transform only when actually needed.
                let transforms = matches!(
                    choice,
                    Some(c) if c.kernel.family.needs_transform() && !c.cache
                );
                if canonical || transforms {
                    let w = push(i, OpStage::Transform, vec![r], &mut set.ops);
                    set.transform_of[i] = Some(w);
                }
            }
            // Pipeline creation per executed kernel (GPU only).
            if gpu && !matches!(layer.op, crate::graph::OpKind::Input) {
                let p = push(
                    i,
                    OpStage::Pipeline,
                    vec![set.driver_init.unwrap()],
                    &mut set.ops,
                );
                set.pipeline_of[i] = Some(p);
            }
            // Execution.
            if !matches!(layer.op, crate::graph::OpKind::Input) {
                let mut deps = Vec::new();
                if let Some(w) = set.transform_of[i] {
                    deps.push(w);
                } else if let Some(r) = set.read_of[i] {
                    deps.push(r);
                }
                if let Some(p) = set.pipeline_of[i] {
                    deps.push(p);
                }
                for &d in &layer.deps {
                    if let Some(e) = set.exec_of[d] {
                        deps.push(e);
                    }
                }
                let e = push(i, OpStage::Exec, deps, &mut set.ops);
                set.exec_of[i] = Some(e);
            }
        }
        set.dependents = vec![Vec::new(); set.ops.len()];
        for op in &set.ops {
            for &d in &op.deps {
                set.dependents[d].push(op.id);
            }
        }
        set
    }

    pub fn len(&self) -> usize {
        self.ops.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ops.is_empty()
    }

    /// The last exec op (`e_N` — the objective minimizes its finish time).
    pub fn final_exec(&self) -> OpId {
        self.exec_of
            .iter()
            .rev()
            .flatten()
            .copied()
            .next()
            .expect("opset has no exec ops")
    }

    /// Preparation bundle for a layer: its read (+ transform) ops in order.
    pub fn prep_bundle(&self, layer: LayerId) -> Vec<OpId> {
        let mut v = Vec::new();
        if let Some(r) = self.read_of[layer] {
            v.push(r);
        }
        if let Some(w) = self.transform_of[layer] {
            v.push(w);
        }
        v
    }

    /// All ops owned by `layer`, in pipeline order (read, transform,
    /// pipeline, exec). These are exactly the ops whose price changes when
    /// the layer's kernel choice swaps — the delta evaluator's dirty set.
    pub fn ops_of_layer(&self, layer: LayerId) -> Vec<OpId> {
        let mut v = Vec::with_capacity(4);
        if let Some(r) = self.read_of[layer] {
            v.push(r);
        }
        if let Some(w) = self.transform_of[layer] {
            v.push(w);
        }
        if let Some(p) = self.pipeline_of[layer] {
            v.push(p);
        }
        if let Some(e) = self.exec_of[layer] {
            v.push(e);
        }
        v
    }

    /// Layers that have a preparation bundle.
    pub fn prep_layers(&self) -> Vec<LayerId> {
        self.read_of
            .iter()
            .enumerate()
            .filter_map(|(i, r)| r.map(|_| i))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::zoo;
    use crate::kernels::Registry;
    use crate::sched::plan::default_choices;

    #[test]
    fn cpu_opset_structure() {
        let g = zoo::tiny_net();
        let choices = default_choices(&g, &Registry::full());
        let set = OpSet::build(&g, &choices, false);
        // Each weighted layer: read + (transform?) + exec; weightless: exec.
        for l in g.layers() {
            if l.op.has_weights() {
                assert!(set.read_of[l.id].is_some(), "layer {} read", l.id);
            } else {
                assert!(set.read_of[l.id].is_none());
            }
        }
        assert!(set.driver_init.is_none());
        assert!(set.pipeline_of.iter().all(Option::is_none));
        // Exec deps include the predecessor exec.
        let e3 = set.exec_of[3].unwrap();
        let e2 = set.exec_of[2].unwrap();
        assert!(set.ops[e3].deps.contains(&e2));
    }

    #[test]
    fn transform_depends_on_read_exec_on_transform() {
        let g = zoo::tiny_net();
        let mut choices = default_choices(&g, &Registry::full());
        // Force a transforming kernel without cache on layer 1.
        if let Some(c) = &mut choices[1] {
            c.cache = false;
        }
        let set = OpSet::build(&g, &choices, false);
        if let Some(w) = set.transform_of[1] {
            let r = set.read_of[1].unwrap();
            assert_eq!(set.ops[w].deps, vec![r]);
            let e = set.exec_of[1].unwrap();
            assert!(set.ops[e].deps.contains(&w));
            assert!(!set.ops[e].deps.contains(&r));
        } else {
            panic!("expected a transform op for layer 1");
        }
    }

    #[test]
    fn canonical_set_keeps_transform_ops_for_bypassing_choices() {
        let g = zoo::tiny_net();
        let mut choices = default_choices(&g, &Registry::full());
        for c in choices.iter_mut().flatten() {
            if c.kernel.family.needs_transform() {
                c.cache = true;
            }
        }
        let set = OpSet::build(&g, &choices, false);
        // Canonical: every weighted layer has the full read→transform
        // chain even though every choice bypasses transformation; the
        // bypass shows up as a zero *price*, not a missing op.
        for l in g.layers() {
            assert_eq!(
                set.transform_of[l.id].is_some(),
                l.op.has_weights(),
                "layer {}",
                l.id
            );
            if let (Some(w), Some(e)) = (set.transform_of[l.id], set.exec_of[l.id]) {
                let r = set.read_of[l.id].unwrap();
                assert_eq!(set.ops[w].deps, vec![r]);
                assert!(set.ops[e].deps.contains(&w));
                assert!(!set.ops[e].deps.contains(&r));
            }
        }
    }

    #[test]
    fn canonical_structure_is_choice_independent() {
        let g = zoo::tiny_net();
        let defaults = default_choices(&g, &Registry::full());
        let mut cached = defaults.clone();
        for c in cached.iter_mut().flatten() {
            if c.kernel.family.needs_transform() {
                c.cache = true;
            }
        }
        for gpu in [false, true] {
            let a = OpSet::build(&g, &defaults, gpu);
            let b = OpSet::build(&g, &cached, gpu);
            assert_eq!(a.len(), b.len());
            for (x, y) in a.ops.iter().zip(&b.ops) {
                assert_eq!((x.id, x.layer, x.stage), (y.id, y.layer, y.stage));
                assert_eq!(x.deps, y.deps);
            }
        }
    }

    #[test]
    fn minimal_oracle_drops_bypassed_transforms() {
        // The pre-canonical structure, kept as a test oracle: caching
        // every transforming kernel removes every transform op and execs
        // depend directly on reads.
        let g = zoo::tiny_net();
        let mut choices = default_choices(&g, &Registry::full());
        for c in choices.iter_mut().flatten() {
            if c.kernel.family.needs_transform() {
                c.cache = true;
            }
        }
        let set = OpSet::build_minimal(&g, &choices, false);
        assert!(set.transform_of.iter().all(Option::is_none));
        for l in g.layers() {
            if let (Some(r), Some(e)) = (set.read_of[l.id], set.exec_of[l.id]) {
                assert!(set.ops[e].deps.contains(&r));
            }
        }
        assert!(set.len() < OpSet::build(&g, &choices, false).len());
    }

    #[test]
    fn gpu_opset_adds_pipelines() {
        let g = zoo::tiny_net();
        let choices = default_choices(&g, &Registry::full());
        let set = OpSet::build(&g, &choices, true);
        let di = set.driver_init.unwrap();
        for l in g.layers().iter().skip(1) {
            let p = set.pipeline_of[l.id].expect("pipeline op");
            assert!(set.ops[p].deps.contains(&di));
            let e = set.exec_of[l.id].unwrap();
            assert!(set.ops[e].deps.contains(&p));
        }
    }

    #[test]
    fn final_exec_is_last_layer() {
        let g = zoo::tiny_net();
        let choices = default_choices(&g, &Registry::full());
        let set = OpSet::build(&g, &choices, false);
        let f = set.final_exec();
        assert_eq!(set.ops[f].layer, g.len() - 1);
    }

    #[test]
    fn dependents_mirror_deps() {
        let g = zoo::resnet50();
        let choices = default_choices(&g, &Registry::full());
        for gpu in [false, true] {
            let set = OpSet::build(&g, &choices, gpu);
            assert_eq!(set.dependents.len(), set.len());
            let mut edges = 0;
            for op in &set.ops {
                for &d in &op.deps {
                    assert!(set.dependents[d].contains(&op.id));
                    edges += 1;
                }
            }
            let rev: usize = set.dependents.iter().map(Vec::len).sum();
            assert_eq!(edges, rev);
        }
    }

    #[test]
    fn ops_of_layer_covers_all_ops() {
        let g = zoo::tiny_net();
        let choices = default_choices(&g, &Registry::full());
        let set = OpSet::build(&g, &choices, true);
        let mut seen: Vec<OpId> = set.driver_init.into_iter().collect();
        for l in g.layers() {
            seen.extend(set.ops_of_layer(l.id));
        }
        seen.sort_unstable();
        assert_eq!(seen, (0..set.len()).collect::<Vec<_>>());
    }

    #[test]
    fn deps_are_acyclic_and_backward() {
        let g = zoo::resnet50();
        let choices = default_choices(&g, &Registry::full());
        let set = OpSet::build(&g, &choices, false);
        for op in &set.ops {
            for &d in &op.deps {
                assert!(d < op.id, "op {} depends on later op {}", op.id, d);
            }
        }
    }
}
