//! The scheduler's output: per-layer kernel choices + per-unit op queues.

use crate::graph::ModelGraph;
use crate::kernels::{Kernel, Registry};
use crate::sched::op::{OpId, OpSet};
use crate::util::json::Json;

/// Per-layer decision: which kernel, and whether to bypass its weight
/// transformation by reading cached post-transformed weights (§3.1.2).
#[derive(Debug, Clone, PartialEq)]
pub struct KernelChoice {
    pub kernel: Kernel,
    pub cache: bool,
}

/// Scheduling unit: the execution gang (all big cores, or the GPU) or one
/// little core.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum UnitId {
    /// Q0 — the big-core gang / GPU (§3.3: execution occupies all big
    /// cores; §3.4: the GPU plays the big-core role).
    Gang,
    /// Q_j — little core j (0-based).
    Little(usize),
}

impl UnitId {
    pub fn name(&self) -> String {
        match self {
            UnitId::Gang => "gang".to_string(),
            UnitId::Little(j) => format!("little{j}"),
        }
    }
}

/// A kernel scheduling plan.
#[derive(Debug, Clone)]
pub struct Plan {
    /// Per layer: the kernel choice (`None` for weightless layers, which
    /// use the builtin implementation).
    pub choices: Vec<Option<KernelChoice>>,
    /// Q0: op queue of the gang, in order.
    pub gang: Vec<OpId>,
    /// Q1..Q_Ml: op queues of the little cores, in order.
    pub little: Vec<Vec<OpId>>,
    /// Estimated cold-inference makespan (ms) under the pricer used at
    /// planning time.
    pub estimated_ms: f64,
}

impl Plan {
    /// All (unit, queue) pairs.
    pub fn queues(&self) -> Vec<(UnitId, &Vec<OpId>)> {
        let mut v = vec![(UnitId::Gang, &self.gang)];
        for (j, q) in self.little.iter().enumerate() {
            v.push((UnitId::Little(j), q));
        }
        v
    }

    /// Check that every op appears exactly once across all queues.
    pub fn validate(&self, set: &OpSet) -> Result<(), String> {
        let mut seen = vec![0usize; set.len()];
        for (_, q) in self.queues() {
            for &op in q {
                if op >= set.len() {
                    return Err(format!("queue references op {op} out of range"));
                }
                seen[op] += 1;
            }
        }
        for (op, &count) in seen.iter().enumerate() {
            if count == 0 {
                return Err(format!(
                    "op {op} ({}, layer {}) unscheduled",
                    set.ops[op].stage.name(),
                    set.ops[op].layer
                ));
            }
            if count > 1 {
                return Err(format!("op {op} scheduled {count} times"));
            }
        }
        Ok(())
    }

    /// Storage overhead (extra bytes on disk) of the cache decisions.
    pub fn cache_bytes(&self, graph: &ModelGraph) -> u64 {
        self.choices
            .iter()
            .enumerate()
            .filter_map(|(i, c)| c.as_ref().map(|c| (i, c)))
            .filter(|(_, c)| c.cache)
            .map(|(i, c)| c.kernel.transformed_bytes(graph.layer(i)))
            .sum()
    }

    /// Deserialize a plan previously written by [`Plan::to_json`].
    ///
    /// Kernel choices are resolved by name against `registry`'s candidates
    /// for each layer, so a plan only loads against a registry that still
    /// offers the kernels it chose — this is the *structural* half of the
    /// artifact store's revalidation (the store's header + checksum catch
    /// byte-level damage; this catches semantic drift, and the plan caches
    /// treat any failure here as a miss and replan). The round trip is
    /// exact: `Plan::from_json(&p.to_json(g), g, reg)` reproduces `p`
    /// including `estimated_ms` bit-for-bit.
    pub fn from_json(j: &Json, graph: &ModelGraph, registry: &Registry) -> Result<Plan, String> {
        if j.get("model").as_str() != Some(graph.name.as_str()) {
            return Err(format!(
                "plan is for model {:?}, not '{}'",
                j.get("model").as_str(),
                graph.name
            ));
        }
        let estimated_ms = j
            .get("estimated_ms")
            .as_f64()
            .ok_or("plan missing estimated_ms")?;
        let choices_j = j.get("choices").as_arr().ok_or("plan missing choices")?;
        if choices_j.len() != graph.len() {
            return Err(format!(
                "plan has {} choices for a {}-layer model",
                choices_j.len(),
                graph.len()
            ));
        }
        let mut choices: Vec<Option<KernelChoice>> = Vec::with_capacity(choices_j.len());
        for (i, c) in choices_j.iter().enumerate() {
            if matches!(*c, Json::Null) {
                choices.push(None);
                continue;
            }
            let name = c
                .get("kernel")
                .as_str()
                .ok_or_else(|| format!("choice {i} missing kernel name"))?;
            let kernel = registry
                .candidates(graph.layer(i))
                .into_iter()
                .find(|k| k.name == name)
                .ok_or_else(|| format!("layer {i}: kernel '{name}' not offered by registry"))?;
            let cache = c.get("cache").as_bool().unwrap_or(false);
            choices.push(Some(KernelChoice { kernel, cache }));
        }
        let queue = |v: &Json, what: &str| -> Result<Vec<OpId>, String> {
            v.as_arr()
                .ok_or_else(|| format!("plan {what} queue is not an array"))?
                .iter()
                .map(|x| {
                    x.as_usize()
                        .ok_or_else(|| format!("plan {what} queue holds a non-index entry"))
                })
                .collect()
        };
        let gang = queue(j.get("gang"), "gang")?;
        let little = j
            .get("little")
            .as_arr()
            .ok_or("plan missing little queues")?
            .iter()
            .map(|q| queue(q, "little"))
            .collect::<Result<Vec<_>, _>>()?;
        Ok(Plan { choices, gang, little, estimated_ms })
    }

    /// Serialize to JSON (the on-device representation NNV12 stores next to
    /// the model after offline plan generation — Fig. 4's decision stage).
    pub fn to_json(&self, graph: &ModelGraph) -> Json {
        let choices: Vec<Json> = self
            .choices
            .iter()
            .enumerate()
            .map(|(i, c)| match c {
                None => Json::Null,
                Some(c) => Json::obj(vec![
                    ("layer", Json::from(i)),
                    ("kernel", Json::from(c.kernel.name.as_str())),
                    ("family", Json::from(c.kernel.family.name())),
                    ("cache", Json::from(c.cache)),
                ]),
            })
            .collect();
        let q = |ops: &Vec<OpId>| Json::Arr(ops.iter().map(|&o| Json::from(o)).collect());
        Json::obj(vec![
            ("model", Json::from(graph.name.as_str())),
            ("estimated_ms", Json::from(self.estimated_ms)),
            ("choices", Json::Arr(choices)),
            ("gang", q(&self.gang)),
            (
                "little",
                Json::Arr(self.little.iter().map(q).collect()),
            ),
        ])
    }
}

/// Default (warm-optimal, no-cache) kernel choices — what vanilla ncnn
/// hard-codes. Baselines and tests start from here.
pub fn default_choices(graph: &ModelGraph, registry: &Registry) -> Vec<Option<KernelChoice>> {
    graph
        .layers()
        .iter()
        .map(|l| {
            if !l.op.has_weights() {
                return None;
            }
            // Warm-optimal = fastest exec_speed among candidates.
            let kernel = registry
                .candidates(l)
                .into_iter()
                .max_by(|a, b| {
                    a.family
                        .exec_speed()
                        .partial_cmp(&b.family.exec_speed())
                        .unwrap()
                })
                .unwrap();
            Some(KernelChoice { kernel, cache: false })
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::zoo;
    use crate::sched::op::OpSet;

    #[test]
    fn default_choices_cover_weighted_layers() {
        let g = zoo::tiny_net();
        let c = default_choices(&g, &Registry::full());
        for l in g.layers() {
            assert_eq!(c[l.id].is_some(), l.op.has_weights(), "layer {}", l.id);
        }
    }

    #[test]
    fn validate_catches_missing_and_duplicate_ops() {
        let g = zoo::tiny_net();
        let choices = default_choices(&g, &Registry::full());
        let set = OpSet::build(&g, &choices, false);
        let all: Vec<OpId> = (0..set.len()).collect();
        let ok = Plan {
            choices: choices.clone(),
            gang: all.clone(),
            little: vec![vec![]],
            estimated_ms: 0.0,
        };
        assert!(ok.validate(&set).is_ok());

        let missing = Plan {
            choices: choices.clone(),
            gang: all[1..].to_vec(),
            little: vec![vec![]],
            estimated_ms: 0.0,
        };
        assert!(missing.validate(&set).unwrap_err().contains("unscheduled"));

        let mut dup = all.clone();
        dup.push(0);
        let dupped = Plan {
            choices,
            gang: dup,
            little: vec![vec![]],
            estimated_ms: 0.0,
        };
        assert!(dupped.validate(&set).unwrap_err().contains("scheduled 2 times"));
    }

    #[test]
    fn cache_bytes_counts_only_cached_layers() {
        let g = zoo::tiny_net();
        let mut choices = default_choices(&g, &Registry::full());
        let plan_no_cache = Plan {
            choices: choices.clone(),
            gang: vec![],
            little: vec![],
            estimated_ms: 0.0,
        };
        assert_eq!(plan_no_cache.cache_bytes(&g), 0);
        let mut expected = 0u64;
        for (i, c) in choices.iter_mut().enumerate() {
            if let Some(c) = c {
                if c.kernel.family.needs_transform() {
                    c.cache = true;
                    expected += c.kernel.transformed_bytes(g.layer(i));
                }
            }
        }
        let plan = Plan { choices, gang: vec![], little: vec![], estimated_ms: 0.0 };
        assert_eq!(plan.cache_bytes(&g), expected);
        assert!(expected > 0);
    }

    #[test]
    fn json_roundtrip_exact() {
        let g = zoo::tiny_net();
        let reg = Registry::full();
        let mut choices = default_choices(&g, &reg);
        for c in choices.iter_mut().flatten() {
            if c.kernel.family.needs_transform() {
                c.cache = true;
            }
        }
        let set = OpSet::build(&g, &choices, false);
        let plan = Plan {
            choices,
            gang: (0..set.len()).collect(),
            little: vec![vec![], vec![]],
            estimated_ms: 17.25,
        };
        let text = plan.to_json(&g).to_pretty();
        let back = Plan::from_json(&Json::parse(&text).unwrap(), &g, &reg).unwrap();
        assert_eq!(back.choices, plan.choices);
        assert_eq!(back.gang, plan.gang);
        assert_eq!(back.little, plan.little);
        assert_eq!(back.estimated_ms.to_bits(), plan.estimated_ms.to_bits());
        // And the reserialization is byte-identical.
        assert_eq!(back.to_json(&g).to_pretty(), text);
        // Wrong model / mangled payloads are rejected.
        assert!(Plan::from_json(&Json::parse(&text).unwrap(), &zoo::squeezenet(), &reg).is_err());
        assert!(Plan::from_json(&Json::Null, &g, &reg).is_err());
    }

    #[test]
    fn json_roundtrip_shape() {
        let g = zoo::tiny_net();
        let choices = default_choices(&g, &Registry::full());
        let set = OpSet::build(&g, &choices, false);
        let plan = Plan {
            choices,
            gang: (0..set.len()).collect(),
            little: vec![vec![]],
            estimated_ms: 12.5,
        };
        let j = plan.to_json(&g);
        let parsed = Json::parse(&j.to_pretty()).unwrap();
        assert_eq!(parsed.get("model").as_str(), Some("tinynet"));
        assert_eq!(parsed.get("gang").as_arr().unwrap().len(), set.len());
    }
}
