//! Early-exit (conditional-execution) workloads: expected-makespan
//! scheduling and the local-vs-offload decision model.
//!
//! Multi-exit models ([`crate::graph::ExitPoint`]) make execution past an
//! exit *conditional*: a layer only runs for the fraction of requests
//! that survived every earlier exit. This module teaches the planner and
//! the serving layer about that structure:
//!
//! * **Expected-makespan scheduling** ([`schedule_expected`]). The cold
//!   plan is searched under *survival-weighted* prices: every op of layer
//!   `l` is priced at `weight[l] ×` its cold cost, where `weight[l] =
//!   Π (1 - p_e)` over the exits preceding `l`
//!   ([`crate::graph::ModelGraph::survival_weights`]). The search reuses
//!   the exact incremental machinery of [`crate::sched::heuristic`]
//!   unchanged — canonical op sets, flat price tables, 3-entry
//!   [`crate::sched::heuristic::swap_prices`] deltas, incremental confirm
//!   — only the numbers in the table (and on the Pareto candidates)
//!   carry the weights. Because a layer's weight scales *all* of its
//!   prices uniformly, the per-layer greedy ranking is unchanged; the
//!   win comes from Algorithm 1's bundle promotion/balancing and the
//!   coordinate descent optimizing the makespan that requests actually
//!   experience, instead of the worst-case all-layers one. With all exit
//!   probabilities zero the weights are all `1.0`, multiplication is
//!   bit-preserving in IEEE arithmetic, and [`schedule_expected`] is
//!   **bit-identical** to [`crate::sched::schedule`] (tested here and
//!   against the [`crate::sched::heuristic::inner_schedule`] oracle).
//! * **Expected-makespan scoring** ([`expected_price_table`],
//!   [`expected_makespan_of`]). Any plan — in particular a
//!   probability-blind one — can be evaluated under the same weighted
//!   metric, which is how [`compare_expected_vs_blind`] produces the
//!   apples-to-apples comparison the `exits` report and bench ratchet.
//! * **Offload estimation** ([`OffloadPolicy`], [`offload_estimate`]).
//!   The CSGO-style collaborative-serving formulation: serve the head up
//!   to the first exit locally; requests that do not exit there ship the
//!   cut-point activation to a simulated remote over an RTT + bandwidth
//!   link and run the tail there. The estimate is a deterministic
//!   expected latency the Router compares against the request's deadline
//!   ([`crate::serving::Router`] folds the resulting `offloaded` outcome
//!   into its conservation invariant).

use std::sync::Arc;

use crate::device::DeviceProfile;
use crate::graph::{LayerId, ModelGraph};
use crate::kernels::Registry;
use crate::sched::filter::Candidate;
use crate::sched::heuristic::{
    build_candidates, choices_of, confirm_from_table, descend, greedy_pick, prep_units,
    Scheduled, SchedulerConfig,
};
use crate::sched::makespan::evaluate_with;
use crate::sched::op::OpSet;
use crate::sched::plan::KernelChoice;
use crate::sched::price::{PriceTable, Pricer};
use crate::Ms;

/// Scale every lane of `table` by its op's layer survival weight. Weight
/// `1.0` is bit-preserving, so a graph without exits leaves the table
/// untouched bit-for-bit.
fn apply_weights(set: &OpSet, table: &mut PriceTable, weights: &[f64]) {
    for op in &set.ops {
        let w = weights[op.layer];
        table.gang[op.id] *= w;
        table.little[op.id] *= w;
    }
}

/// Scale the Pareto candidates' flat prices by their layer's survival
/// weight, so [`crate::sched::heuristic::swap_prices`] deltas stay exact
/// 3-entry patches *of the weighted table*.
fn weight_candidates(cands: &mut [Vec<Candidate>], weights: &[f64]) {
    for (layer, cs) in cands.iter_mut().enumerate() {
        let w = weights[layer];
        for c in cs.iter_mut() {
            c.prep_ms *= w;
            c.exec_ms *= w;
            c.read_g *= w;
            c.read_l *= w;
            c.tf_g *= w;
            c.tf_l *= w;
            c.exec_g *= w;
            c.exec_l *= w;
        }
    }
}

/// The survival-weighted price table for `choices` on `dev` — the
/// expected-makespan metric as a reusable object. Returns the canonical
/// op set, the weighted table, and the little-unit count the assembly
/// uses.
pub fn expected_price_table(
    dev: &DeviceProfile,
    graph: &ModelGraph,
    choices: &[Option<KernelChoice>],
    cfg: &SchedulerConfig,
) -> (Arc<OpSet>, PriceTable, usize) {
    let set = Arc::new(OpSet::build(graph, choices, dev.executes_on_gpu()));
    let pricer = Pricer::new(dev, graph, choices, cfg.shader_cache);
    let mut table = PriceTable::build(&set, &pricer);
    apply_weights(&set, &mut table, &graph.survival_weights());
    (set, table, pricer.n_little_units())
}

/// Expected (survival-weighted) makespan of an arbitrary plan — the
/// common metric [`compare_expected_vs_blind`] scores both arms under.
pub fn expected_makespan_of(
    dev: &DeviceProfile,
    graph: &ModelGraph,
    scheduled: &Scheduled,
    cfg: &SchedulerConfig,
) -> Ms {
    let (_, table, _) = expected_price_table(dev, graph, &scheduled.plan.choices, cfg);
    evaluate_with(&scheduled.set, &scheduled.plan, &table)
        .expect("plan valid under weighted prices")
        .makespan
}

/// The expected-makespan scheduler: [`crate::sched::schedule`] run under
/// survival-weighted prices. The returned [`Scheduled`]'s makespan is the
/// *expected* cold makespan over the exit distribution, and the plan's
/// queue assignment is optimized for it — early-exit heads land on fast
/// units, conditional tail work is discounted by how rarely it runs.
///
/// Exactness contract: with every exit probability `0` (or a graph with
/// no exits at all) this is bit-identical to [`crate::sched::schedule`] —
/// weights of `1.0` preserve every price bit, so greedy seeding,
/// Algorithm-1 assembly, and the incremental descent take exactly the
/// same branches.
pub fn schedule_expected(
    dev: &DeviceProfile,
    graph: &ModelGraph,
    registry: &Registry,
    cfg: &SchedulerConfig,
) -> Scheduled {
    let weights = graph.survival_weights();
    let mut cands = build_candidates(dev, graph, registry, cfg);
    weight_candidates(&mut cands, &weights);
    let n_prep_units = prep_units(dev);
    let mut pick = greedy_pick(&cands, cfg, n_prep_units);

    let choices = choices_of(&cands, &pick);
    let set = Arc::new(OpSet::build(graph, &choices, dev.executes_on_gpu()));
    let pricer = Pricer::new(dev, graph, &choices, cfg.shader_cache);
    let mut table = PriceTable::build(&set, &pricer);
    apply_weights(&set, &mut table, &weights);
    let n_little = pricer.n_little_units();
    let mut best = confirm_from_table(&set, choices, &table, cfg, n_little);

    if cfg.kernel_selection {
        let searchable: Vec<usize> =
            (0..cands.len()).filter(|&l| cands[l].len() >= 2).collect();
        descend(
            &cands,
            &mut pick,
            &mut best,
            table,
            cfg,
            n_prep_units,
            cfg.max_outer_passes,
            &searchable,
        );
    }
    best
}

/// Result of [`compare_expected_vs_blind`]: both plans, both scored under
/// the *same* survival-weighted metric.
#[derive(Debug, Clone)]
pub struct ExitComparison {
    /// The expected-makespan scheduler's plan.
    pub expected: Scheduled,
    /// The probability-blind plan ([`crate::sched::schedule`]).
    pub blind: Scheduled,
    /// Expected makespan of the expected plan.
    pub expected_ms: Ms,
    /// Expected makespan of the blind plan.
    pub blind_ms: Ms,
}

/// Schedule `graph` both ways — probability-blind and
/// expected-makespan-aware — and score both under the survival-weighted
/// metric. Guarantee: `expected_ms <= blind_ms`, because the expected
/// scheduler may always keep the blind plan when its own search does not
/// improve on it (the blind plan is a valid candidate answer under the
/// weighted metric); the measured gap on the branchy zoo is what the
/// `exits` bench ratchets.
pub fn compare_expected_vs_blind(
    dev: &DeviceProfile,
    graph: &ModelGraph,
    registry: &Registry,
    cfg: &SchedulerConfig,
) -> ExitComparison {
    let blind = crate::sched::schedule(dev, graph, registry, cfg);
    let blind_ms = expected_makespan_of(dev, graph, &blind, cfg);
    let expected = schedule_expected(dev, graph, registry, cfg);
    let expected_ms = expected.schedule.makespan;
    if expected_ms <= blind_ms {
        ExitComparison { expected, blind, expected_ms, blind_ms }
    } else {
        ExitComparison { expected: blind.clone(), blind, expected_ms: blind_ms, blind_ms }
    }
}

/// Policy for offloading the conditional tail of a multi-exit model to a
/// simulated remote (the CSGO collaborative-serving formulation). All
/// parameters are deterministic: the estimate is pure arithmetic, so
/// serving replays stay bit-reproducible.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OffloadPolicy {
    /// Round-trip time to the remote, ms (paid once per offloaded tail).
    pub rtt_ms: Ms,
    /// Uplink bandwidth for shipping the cut-point activation, megabits/s.
    pub bandwidth_mbps: f64,
    /// How much faster the remote executes the tail than the local cold
    /// estimate (a server-class accelerator vs the edge SoC).
    pub remote_speedup: f64,
    /// Remote-side cold-start penalty charged once per offloaded request
    /// (container wake + weights already resident remotely).
    pub remote_cold_ms: Ms,
}

impl Default for OffloadPolicy {
    fn default() -> OffloadPolicy {
        OffloadPolicy {
            rtt_ms: 20.0,
            bandwidth_mbps: 100.0,
            remote_speedup: 4.0,
            remote_cold_ms: 8.0,
        }
    }
}

/// One offload decision's arithmetic, all in the open for the report.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OffloadEstimate {
    /// The backbone layer whose activation ships to the remote.
    pub cut_layer: LayerId,
    /// Bytes of that activation (fp32).
    pub transfer_bytes: u64,
    /// Local head cost: the cold estimate scaled by the head's share of
    /// the model's FLOPs (everything up to and including the first exit).
    pub head_ms: Ms,
    /// RTT + activation transfer, ms.
    pub link_ms: Ms,
    /// Remote tail execution + remote cold penalty, ms.
    pub remote_tail_ms: Ms,
    /// Probability a request survives the first exit and needs the tail.
    pub survive: f64,
    /// Expected end-to-end latency:
    /// `head + survive × (link + remote_tail)`.
    pub expected_ms: Ms,
}

/// Deterministic expected latency of serving `graph` with its head local
/// and its tail offloaded per `policy`, given the local cold estimate.
/// `None` for single-exit graphs (nothing to cut at) or degenerate cost
/// models.
pub fn offload_estimate(
    graph: &ModelGraph,
    policy: &OffloadPolicy,
    local_cold_ms: Ms,
) -> Option<OffloadEstimate> {
    let exit = graph.exits().first()?;
    let total_flops = graph.flops() as f64;
    if total_flops <= 0.0 || !local_cold_ms.is_finite() || local_cold_ms <= 0.0 {
        return None;
    }
    let head_flops: f64 = graph
        .layers()
        .iter()
        .filter(|l| l.id <= exit.layer)
        .map(|l| l.flops() as f64)
        .sum();
    let head_frac = (head_flops / total_flops).clamp(0.0, 1.0);
    let head_ms = local_cold_ms * head_frac;

    // The tensor shipped remote is the backbone activation at the branch
    // point: the first tail layer's dependency inside the head region.
    let mut cut_layer = exit.layer;
    for l in graph.layers().iter().filter(|l| l.id > exit.layer) {
        if let Some(&d) = l.deps.iter().find(|&&d| d <= exit.layer) {
            cut_layer = d;
        }
        break;
    }
    let transfer_bytes = graph.layer(cut_layer).activation_bytes();
    let data_ms = transfer_bytes as f64 * 8.0 / (policy.bandwidth_mbps.max(1e-9) * 1e3);
    let link_ms = policy.rtt_ms + data_ms;
    let remote_tail_ms =
        (local_cold_ms - head_ms) / policy.remote_speedup.max(1e-9) + policy.remote_cold_ms;
    let survive = (1.0 - exit.probability).clamp(0.0, 1.0);
    Some(OffloadEstimate {
        cut_layer,
        transfer_bytes,
        head_ms,
        link_ms,
        remote_tail_ms,
        survive,
        expected_ms: head_ms + survive * (link_ms + remote_tail_ms),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::profiles;
    use crate::graph::{zoo, ExitPoint};
    use crate::sched::heuristic::inner_schedule;
    use crate::sched::schedule;

    fn with_probability(g: &ModelGraph, p: f64) -> ModelGraph {
        let exits: Vec<ExitPoint> = g
            .exits()
            .iter()
            .map(|e| ExitPoint { probability: p, ..*e })
            .collect();
        g.clone().with_exits(exits).unwrap()
    }

    #[test]
    fn no_exits_is_bit_exact_vs_blind_scheduler() {
        let dev = profiles::meizu_16t();
        let cfg = SchedulerConfig::kcp();
        let reg = Registry::full();
        for model in ["tinynet", "squeezenet"] {
            let g = zoo::by_name(model).unwrap();
            let a = schedule(&dev, &g, &reg, &cfg);
            let b = schedule_expected(&dev, &g, &reg, &cfg);
            assert_eq!(
                a.schedule.makespan.to_bits(),
                b.schedule.makespan.to_bits(),
                "{model}: expected scheduler drifted from the blind one"
            );
            assert_eq!(a.plan.gang, b.plan.gang);
            assert_eq!(a.plan.little, b.plan.little);
            assert_eq!(a.plan.choices, b.plan.choices);
        }
    }

    #[test]
    fn zero_probability_exits_are_bit_exact_vs_oracle() {
        // All-zero exit probabilities ⇒ all-ones weights ⇒ every price
        // multiplication is by 1.0 (bit-preserving) ⇒ the expected search
        // must reproduce the blind plan bit-for-bit, and re-deriving its
        // choices through the from-scratch `inner_schedule` oracle must
        // reproduce the reported makespan exactly.
        let dev = profiles::meizu_16t();
        let cfg = SchedulerConfig::kcp();
        let reg = Registry::full();
        for model in ["branchy-tinynet", "branchy-mobilenet"] {
            let g = with_probability(&zoo::by_name(model).unwrap(), 0.0);
            let blind = schedule(&dev, &g, &reg, &cfg);
            let exp = schedule_expected(&dev, &g, &reg, &cfg);
            assert_eq!(
                blind.schedule.makespan.to_bits(),
                exp.schedule.makespan.to_bits(),
                "{model}: zero-probability expected plan drifted"
            );
            assert_eq!(blind.plan.gang, exp.plan.gang, "{model}");
            assert_eq!(blind.plan.little, exp.plan.little, "{model}");
            let oracle = inner_schedule(&dev, &g, &exp.plan.choices, &cfg);
            assert_eq!(
                oracle.schedule.makespan.to_bits(),
                exp.schedule.makespan.to_bits(),
                "{model}: inner_schedule oracle disagrees"
            );
        }
    }

    #[test]
    fn probability_one_first_exit_schedules_only_the_head() {
        // p = 1 on the first exit zeroes the survival weight of every
        // layer past its head: the weighted table prices the whole tail
        // at exactly 0, and the expected makespan collapses to head-only
        // work — strictly below the blind (full-model) makespan.
        let dev = profiles::meizu_16t();
        let cfg = SchedulerConfig::kcp();
        let reg = Registry::full();
        let g = with_probability(&zoo::branchy_tinynet(), 1.0);
        let first_exit = g.exits()[0].layer;
        let w = g.survival_weights();
        for l in 0..g.len() {
            if l > first_exit {
                assert_eq!(w[l], 0.0, "layer {l} must be unreachable");
            }
        }
        let exp = schedule_expected(&dev, &g, &reg, &cfg);
        let (set, table, _) = expected_price_table(&dev, &g, &exp.plan.choices, &cfg);
        for op in &set.ops {
            if op.layer > first_exit {
                assert_eq!(table.gang[op.id], 0.0, "tail op {} priced", op.id);
                assert_eq!(table.little[op.id], 0.0, "tail op {} priced", op.id);
            }
        }
        let blind = schedule(&dev, &g, &reg, &cfg);
        assert!(
            exp.schedule.makespan < blind.schedule.makespan,
            "head-only expected {} must beat full blind {}",
            exp.schedule.makespan,
            blind.schedule.makespan
        );
    }

    #[test]
    fn expected_never_worse_than_blind_under_the_weighted_metric() {
        let dev = profiles::meizu_16t();
        let cfg = SchedulerConfig::kcp();
        let reg = Registry::full();
        for model in ["branchy-resnet18", "branchy-mobilenet", "branchy-tinynet"] {
            let g = zoo::by_name(model).unwrap();
            let cmp = compare_expected_vs_blind(&dev, &g, &reg, &cfg);
            assert!(
                cmp.expected_ms <= cmp.blind_ms + 1e-9,
                "{model}: expected {} vs blind {}",
                cmp.expected_ms,
                cmp.blind_ms
            );
            // And the weighted metric can only discount a plan, never
            // inflate it (weights ≤ 1, fixed queues are monotone in op
            // durations).
            assert!(cmp.blind_ms <= cmp.blind.schedule.makespan + 1e-9, "{model}");
        }
    }

    #[test]
    fn weighted_metric_matches_search_output() {
        // The makespan the expected search reports IS the weighted metric
        // of its plan — scoring the returned plan through
        // `expected_makespan_of` must agree bit-for-bit.
        let dev = profiles::meizu_16t();
        let cfg = SchedulerConfig::kcp();
        let g = zoo::branchy_mobilenet();
        let exp = schedule_expected(&dev, &g, &Registry::full(), &cfg);
        let scored = expected_makespan_of(&dev, &g, &exp, &cfg);
        assert_eq!(scored.to_bits(), exp.schedule.makespan.to_bits());
    }

    #[test]
    fn offload_estimate_arithmetic() {
        let g = zoo::branchy_resnet18();
        let policy = OffloadPolicy::default();
        let est = offload_estimate(&g, &policy, 1000.0).unwrap();
        assert!(est.cut_layer < g.exits()[0].layer);
        assert!(est.head_ms > 0.0 && est.head_ms < 1000.0);
        assert!(est.survive > 0.0 && est.survive < 1.0);
        assert!(est.expected_ms > est.head_ms);
        // Cheaper link ⇒ cheaper offload; slower remote ⇒ pricier.
        let fast_link =
            offload_estimate(&g, &OffloadPolicy { bandwidth_mbps: 1000.0, ..policy }, 1000.0)
                .unwrap();
        assert!(fast_link.expected_ms < est.expected_ms);
        let slow_remote =
            offload_estimate(&g, &OffloadPolicy { remote_speedup: 1.0, ..policy }, 1000.0)
                .unwrap();
        assert!(slow_remote.expected_ms > est.expected_ms);
        // Single-exit models have nothing to cut.
        assert!(offload_estimate(&zoo::tiny_net(), &policy, 1000.0).is_none());
    }
}
