//! The per-operation latency model `T(op, core, threads)`.
//!
//! Cold inference decomposes into per-layer *operations* (§3.2): weights
//! reading, weights transformation, kernel execution, and — on GPU —
//! pipeline creation (§3.4). This module prices each operation on each core
//! class, with the multithreading behaviour of Fig. 6 (execution scales
//! almost linearly; read/transform barely scale because they are disk- and
//! memory-bound).
//!
//! All rates come from the [`DeviceProfile`]; the kernel-family factors come
//! from [`KernelFamily`]. Both are calibrated against the paper's Tables
//! 1–2 and Fig. 6 (see `DESIGN.md §Calibration targets`).

use crate::device::{CoreClass, DeviceProfile};
use crate::graph::{Layer, ModelGraph, OpKind};
use crate::kernels::{Kernel, Registry};
use crate::{Bytes, Ms};

/// Fixed dispatch overhead per executed kernel on CPU, ms.
pub const CPU_OP_OVERHEAD_MS: f64 = 0.015;
/// Fixed dispatch overhead per executed kernel on GPU, ms (driver queue
/// submission + descriptor binding; dominant for tiny layers).
pub const GPU_DISPATCH_MS: f64 = 2.0;
/// Execution-unit utilization for depthwise conv (memory-bound: each weight
/// is used O(HW) times but arithmetic intensity per byte is ~9 MACs).
const DW_UTILIZATION: f64 = 0.25;
/// Utilization for FC (GEMV, memory-bound).
const FC_UTILIZATION: f64 = 0.55;

/// Latency model bound to a device.
#[derive(Debug, Clone)]
pub struct CostModel<'d> {
    pub dev: &'d DeviceProfile,
}

impl<'d> CostModel<'d> {
    pub fn new(dev: &'d DeviceProfile) -> CostModel<'d> {
        CostModel { dev }
    }

    /// Multithread speedup for a stage with scaling exponent `exp`.
    fn mt(&self, threads: usize, exp: f64) -> f64 {
        (threads.max(1) as f64).powf(exp)
    }

    /// Disk read of `bytes`, issued from `class` with `threads` reader
    /// threads. Reads from little cores are slower (Fig. 6, ≈2×) because
    /// the issuing core drives the I/O stack.
    pub fn read_ms(&self, bytes: Bytes, class: CoreClass, threads: usize) -> Ms {
        if bytes == 0 {
            return 0.0;
        }
        let base_rate = self.dev.disk_mbps * 1e6 / 1e3; // bytes per ms
        let class_factor = match class {
            CoreClass::Big | CoreClass::Gpu => 1.0,
            CoreClass::Little => 1.0 / self.dev.read_little_slowdown,
        };
        let rate = base_rate * class_factor * self.mt(threads, self.dev.mt_read_exp);
        // 4 KiB minimum granularity: tiny blobs still pay one I/O.
        (bytes.max(4096) as f64) / rate
    }

    /// Weight transformation raw→kernel layout for `kernel` on `layer`.
    /// Memory-bound: `transform_work` effective passes over the transformed
    /// bytes at the class's streaming bandwidth.
    pub fn transform_ms(&self, kernel: &Kernel, layer: &Layer, class: CoreClass, threads: usize) -> Ms {
        let work = kernel.family.transform_work();
        if work == 0.0 {
            return 0.0;
        }
        let bytes_moved = kernel.transformed_bytes(layer) as f64 * work;
        let base_rate = self.dev.mem_eff_gbps * 1e9 / 1e3; // bytes per ms
        let class_factor = match class {
            CoreClass::Big | CoreClass::Gpu => 1.0,
            CoreClass::Little => 1.0 / self.dev.transform_little_slowdown,
        };
        let rate = base_rate * class_factor * self.mt(threads, self.dev.mt_transform_exp);
        bytes_moved / rate
    }

    /// Kernel execution time on `class` with `threads` cores of that class.
    pub fn exec_ms(&self, kernel: &Kernel, layer: &Layer, class: CoreClass, threads: usize) -> Ms {
        let flops = layer.flops() as f64;
        if flops == 0.0 {
            return 0.0;
        }
        let gflops = self.dev.core_gflops(class);
        if gflops <= 0.0 {
            return f64::INFINITY;
        }
        let speed = kernel.family.exec_speed();
        let util = self.utilization(layer);
        let overhead = match class {
            CoreClass::Gpu => GPU_DISPATCH_MS,
            _ => CPU_OP_OVERHEAD_MS,
        };
        let mt = match class {
            CoreClass::Gpu => 1.0, // the GPU is modelled as one wide unit
            _ => self.mt(threads, self.dev.mt_exec_exp),
        };
        overhead + flops / (gflops * 1e9 / 1e3 * speed * util * mt)
    }

    /// Per-layer utilization factor of the execution units.
    fn utilization(&self, layer: &Layer) -> f64 {
        match layer.op {
            OpKind::Conv { .. } if layer.op.is_depthwise(layer.in_ch) => DW_UTILIZATION,
            OpKind::Fc => FC_UTILIZATION,
            OpKind::Conv { .. } => {
                // Small feature maps can't fill the SIMD/GPU lanes.
                if layer.out_hw >= 14 {
                    1.0
                } else {
                    0.6
                }
            }
            _ => 0.35, // weightless data-movement ops
        }
    }

    /// Host→GPU weight upload.
    pub fn upload_ms(&self, bytes: Bytes) -> Ms {
        match &self.dev.gpu {
            Some(g) => bytes as f64 / (g.upload_gbps * 1e9 / 1e3),
            None => 0.0,
        }
    }

    /// Vulkan pipeline creation for one kernel (§3.4). The shader-compile
    /// portion is bypassed when the shader cache holds this kernel.
    pub fn pipeline_create_ms(&self, shader_cached: bool) -> Ms {
        match &self.dev.gpu {
            Some(g) => {
                g.pipeline_create_ms + if shader_cached { 0.0 } else { g.shader_compile_ms }
            }
            None => 0.0,
        }
    }

    /// One-shot GPU driver/context initialization.
    pub fn gpu_driver_init_ms(&self) -> Ms {
        self.dev.gpu.as_ref().map(|g| g.driver_init_ms).unwrap_or(0.0)
    }

    /// Memory allocation for weights + activations (Table 1 shows this is
    /// small: ~1 ms). Modelled as one pass of page faults over the arena.
    pub fn alloc_ms(&self, graph: &ModelGraph) -> Ms {
        let bytes: u64 = graph.weight_bytes()
            + graph.layers().iter().map(Layer::activation_bytes).sum::<u64>();
        // First-touch page faulting ~ 25 GB/s equivalent.
        bytes as f64 / (25.0 * 1e9 / 1e3)
    }

    /// Warm-inference latency: every layer executes its warm-fastest kernel
    /// on all big cores (phones) or the GPU (Jetsons); weights are resident.
    /// This is the paper's lower bound for cold inference (§3.3).
    pub fn warm_ms(&self, graph: &ModelGraph, registry: &Registry) -> Ms {
        let (class, threads) = self.exec_class();
        graph
            .layers()
            .iter()
            .map(|l| {
                let k = self.warm_best_kernel(l, registry);
                self.exec_ms(&k, l, class, threads)
            })
            .sum()
    }

    /// The class + thread count execution runs on for this device.
    pub fn exec_class(&self) -> (CoreClass, usize) {
        if self.dev.executes_on_gpu() {
            (CoreClass::Gpu, 1)
        } else {
            (CoreClass::Big, self.dev.n_big.max(1))
        }
    }

    /// The kernel with the fastest execution (warm-optimal choice, i.e.
    /// what vanilla ncnn hard-codes).
    pub fn warm_best_kernel(&self, layer: &Layer, registry: &Registry) -> Kernel {
        let (class, threads) = self.exec_class();
        registry
            .candidates(layer)
            .into_iter()
            .min_by(|a, b| {
                self.exec_ms(a, layer, class, threads)
                    .partial_cmp(&self.exec_ms(b, layer, class, threads))
                    .unwrap()
            })
            .expect("layer has no kernel candidates")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::profiles;
    use crate::kernels::KernelFamily;

    fn table2_layer() -> Layer {
        // Table 2's conv: kernel 3, stride 1, 64→192 channels.
        Layer {
            id: 0,
            name: "conv".into(),
            op: OpKind::Conv { kernel: 3, stride: 1, groups: 1 },
            in_ch: 64,
            out_ch: 192,
            in_hw: 32,
            out_hw: 32,
            deps: vec![],
        }
    }

    #[test]
    fn table2_orderings_hold() {
        // The qualitative structure of Table 2 must reproduce:
        // transform: winograd > winograd-pack4 >> sgemm-pack4 > direct (=0)
        // exec (big): winograd-pack4 < winograd < direct ≈ sgemm-pack4 < pack4 << general
        // cache read: winograd variants ≫ raw read; sgemm cache read = raw read.
        let dev = profiles::meizu_16t();
        let cm = CostModel::new(&dev);
        let l = table2_layer();
        let k = |f: KernelFamily| Kernel::new(f.name(), f);

        let tw = |f| cm.transform_ms(&k(f), &l, CoreClass::Little, 1);
        assert!(tw(KernelFamily::Winograd) > tw(KernelFamily::WinogradPack4));
        assert!(tw(KernelFamily::WinogradPack4) > 5.0 * tw(KernelFamily::SgemmPack4));
        assert_eq!(tw(KernelFamily::Direct), 0.0);

        let ex = |f| cm.exec_ms(&k(f), &l, CoreClass::Big, 4);
        assert!(ex(KernelFamily::WinogradPack4) < ex(KernelFamily::Winograd));
        assert!(ex(KernelFamily::Winograd) < ex(KernelFamily::SgemmPack4));
        assert!(ex(KernelFamily::SgemmPack4) < ex(KernelFamily::Pack4));
        assert!(ex(KernelFamily::General) > 8.0 * ex(KernelFamily::SgemmPack4));

        let rd_raw = cm.read_ms(l.weight_bytes(), CoreClass::Little, 1);
        let rd_cache = cm.read_ms(
            k(KernelFamily::WinogradPack4).transformed_bytes(&l),
            CoreClass::Little,
            1,
        );
        let ratio = rd_cache / rd_raw;
        assert!((6.5..8.5).contains(&ratio), "cache/raw read ratio {ratio}");
        // And caching still beats transforming:
        assert!(rd_cache < tw(KernelFamily::WinogradPack4));
    }

    #[test]
    fn fig6_asymmetry_ratios() {
        let dev = profiles::meizu_16t();
        let cm = CostModel::new(&dev);
        let l = table2_layer();
        let k = Kernel::new("sgemm_pack4", KernelFamily::SgemmPack4);

        let exec_ratio = cm.exec_ms(&k, &l, CoreClass::Little, 1)
            / cm.exec_ms(&k, &l, CoreClass::Big, 1);
        assert!((4.0..8.0).contains(&exec_ratio), "exec big/little {exec_ratio}");

        let read_ratio = cm.read_ms(1 << 20, CoreClass::Little, 1)
            / cm.read_ms(1 << 20, CoreClass::Big, 1);
        assert!((1.8..2.2).contains(&read_ratio), "read {read_ratio}");

        let tr_ratio = cm.transform_ms(&k, &l, CoreClass::Little, 1)
            / cm.transform_ms(&k, &l, CoreClass::Big, 1);
        assert!((3.4..4.2).contains(&tr_ratio), "transform {tr_ratio}");
    }

    #[test]
    fn fig6_multithread_scaling() {
        let dev = profiles::meizu_16t();
        let cm = CostModel::new(&dev);
        let l = table2_layer();
        let k = Kernel::new("sgemm_pack4", KernelFamily::SgemmPack4);
        // Execution: 4 threads ≳ 3.3×.
        let e1 = cm.exec_ms(&k, &l, CoreClass::Big, 1) - CPU_OP_OVERHEAD_MS;
        let e4 = cm.exec_ms(&k, &l, CoreClass::Big, 4) - CPU_OP_OVERHEAD_MS;
        assert!(e1 / e4 > 3.2, "exec mt speedup {}", e1 / e4);
        // Read: 4 threads ≲ 1.2×.
        let r1 = cm.read_ms(1 << 24, CoreClass::Big, 1);
        let r4 = cm.read_ms(1 << 24, CoreClass::Big, 4);
        assert!(r1 / r4 < 1.3, "read mt speedup {}", r1 / r4);
        // Transform: 4 threads ≲ 1.6×.
        let t1 = cm.transform_ms(&k, &l, CoreClass::Big, 1);
        let t4 = cm.transform_ms(&k, &l, CoreClass::Big, 4);
        assert!(t1 / t4 < 1.7, "transform mt speedup {}", t1 / t4);
    }

    #[test]
    fn table1_resnet50_shape() {
        // Pixel 5 / ncnn-style defaults: transform must dominate cold
        // inference (paper: 1,135 ms transform vs 36.5 ms read vs 190 ms
        // exec), and warm ≈ exec.
        let dev = profiles::pixel_5();
        let cm = CostModel::new(&dev);
        let g = crate::graph::zoo::resnet50();
        let reg = Registry::full();

        let read: f64 = g
            .layers()
            .iter()
            .map(|l| cm.read_ms(l.weight_bytes(), CoreClass::Big, 1))
            .sum();
        let transform: f64 = g
            .layers()
            .iter()
            .map(|l| {
                let k = cm.warm_best_kernel(l, &reg);
                cm.transform_ms(&k, l, CoreClass::Big, 1)
            })
            .sum();
        let warm = cm.warm_ms(&g, &reg);
        assert!(
            (15.0..80.0).contains(&read),
            "read {read} ms (paper 36.5)"
        );
        assert!(
            (500.0..2500.0).contains(&transform),
            "transform {transform} ms (paper 1135)"
        );
        assert!((80.0..400.0).contains(&warm), "warm {warm} ms (paper 186)");
        assert!(transform > 5.0 * warm, "transform must dominate");
    }

    #[test]
    fn gpu_prep_matches_table1_scale() {
        // TX2 / ResNet-50: driver init + per-kernel pipeline creation
        // should land in the thousands of ms (paper: 3,004 ms).
        let dev = profiles::jetson_tx2();
        let cm = CostModel::new(&dev);
        let g = crate::graph::zoo::resnet50();
        let kernels = g
            .layers()
            .iter()
            .filter(|l| l.op.has_weights())
            .count();
        let prep = cm.gpu_driver_init_ms()
            + (kernels as f64) * cm.pipeline_create_ms(false);
        assert!((2000.0..4500.0).contains(&prep), "gpu prep {prep} ms");
        // Shader cache removes most of it.
        let cached = cm.gpu_driver_init_ms()
            + (kernels as f64) * cm.pipeline_create_ms(true);
        assert!(cached < prep * 0.5, "cached {cached} vs {prep}");
    }

    #[test]
    fn alloc_is_negligible() {
        let dev = profiles::pixel_5();
        let cm = CostModel::new(&dev);
        let g = crate::graph::zoo::resnet50();
        let a = cm.alloc_ms(&g);
        assert!(a < 20.0, "alloc {a} ms (paper: 1.34 ms)");
    }
}
