//! Kernel registry: one operator, many kernels (§3.1.1).
//!
//! ncnn implements 28 distinct kernels for convolution alone (Fig. 5);
//! which ones are *usable* depends on the conv configuration (kernel size,
//! stride, channel divisibility), and which one is *best* depends on
//! whether you optimize warm execution time or cold end-to-end time —
//! winograd executes fastest but pays a heavy weight transformation, plain
//! sgemm transforms cheaply but executes slower (Table 2).
//!
//! * [`family`] — the kernel implementation families and their cost-
//!   relevant properties (layout expansion, transform cost, exec speed).
//! * [`tree`] — the Fig. 5 applicability tree: conv config → usable kernels.
//! * [`registry`] — per-layer candidate enumeration for every op kind.

pub mod family;
pub mod tree;
pub mod registry;

pub use family::KernelFamily;
pub use registry::{registry_generation, Kernel, Registry};
