//! Per-layer kernel-candidate enumeration for every operator kind.

use super::family::{transformed_bytes, KernelFamily};
use super::tree::usable_conv_kernels;
use crate::graph::{Layer, OpKind};
use crate::Bytes;

/// A kernel candidate for a specific layer.
#[derive(Debug, Clone, PartialEq)]
pub struct Kernel {
    /// Human-readable name (ncnn-style for convs).
    pub name: String,
    pub family: KernelFamily,
}

impl Kernel {
    pub fn new(name: &str, family: KernelFamily) -> Kernel {
        Kernel { name: name.to_string(), family }
    }

    /// Transformed-weight bytes for this kernel on `layer`.
    pub fn transformed_bytes(&self, layer: &Layer) -> Bytes {
        transformed_bytes(self.family, layer)
    }
}

/// The kernel registry. Stateless; kept as a struct so alternative builds
/// (e.g. a trimmed registry for ablations) can be injected.
#[derive(Debug, Clone, Default)]
pub struct Registry {
    /// If true, only the warm-fastest kernel is offered per layer — used by
    /// the "no kernel selection" ablation arm (Fig. 13 baseline).
    pub warm_only: bool,
}

impl Registry {
    pub fn full() -> Registry {
        Registry { warm_only: false }
    }

    /// Registry that mimics the hard-coded warm-optimal selection of
    /// vanilla ncnn (ablation baseline).
    pub fn warm_default() -> Registry {
        Registry { warm_only: true }
    }

    /// Kernel candidates usable for `layer`. Weightless layers get the
    /// single builtin implementation. Depthwise convs get the dw kernels
    /// (Fig. 5 covers standard convs; ncnn has a parallel dw set).
    pub fn candidates(&self, layer: &Layer) -> Vec<Kernel> {
        let mut all = self.all_candidates(layer);
        if self.warm_only && all.len() > 1 {
            // ncnn's hard-coded choice: fastest warm execution.
            let best = all
                .iter()
                .enumerate()
                .max_by(|(_, a), (_, b)| {
                    a.family
                        .exec_speed()
                        .partial_cmp(&b.family.exec_speed())
                        .unwrap()
                })
                .map(|(i, _)| i)
                .unwrap();
            all = vec![all.swap_remove(best)];
        }
        all
    }

    fn all_candidates(&self, layer: &Layer) -> Vec<Kernel> {
        match layer.op {
            OpKind::Conv { .. } if layer.op.is_depthwise(layer.in_ch) => {
                let mut v = vec![Kernel::new("dw-direct", KernelFamily::DwDirect)];
                if layer.in_ch % 4 == 0 {
                    v.insert(0, Kernel::new("dw-pack4", KernelFamily::DwPack4));
                }
                v
            }
            OpKind::Conv { .. } => usable_conv_kernels(layer)
                .into_iter()
                .map(|ck| Kernel::new(ck.name, ck.family))
                .collect(),
            OpKind::Fc => {
                let mut v = vec![Kernel::new("fc-sgemm", KernelFamily::FcSgemm)];
                if layer.in_ch % 4 == 0 && layer.out_ch % 4 == 0 {
                    v.insert(0, Kernel::new("fc-sgemm-pack4", KernelFamily::FcSgemmPack4));
                }
                v
            }
            _ => vec![Kernel::new("builtin", KernelFamily::Builtin)],
        }
    }
}

/// A 64-bit fingerprint of this build's kernel registry: what every
/// persisted artifact is implicitly a function of but no per-artifact key
/// captures — which kernels exist, which layers they apply to, and the
/// cost-model properties the scheduler ranks them by. The
/// [`crate::store::ArtifactStore`] stamps it into every artifact header
/// (format v2) so plans and transformed weights searched under an older
/// registry are detected on first read after an engine upgrade and
/// invalidated (or migrated) exactly once, instead of silently serving
/// decisions a kernel change made wrong.
///
/// Computed by enumerating both registry variants (full and warm-default)
/// over a fixed set of probe layers spanning every op-kind branch —
/// standard conv (odd and pack-4 channel counts, several kernel/stride
/// shapes), depthwise conv, fc, and a weightless op — and FNV-hashing each
/// candidate's name, family name, applicability, and cost properties
/// (`expand`, `transform_work`, `exec_speed`, `transformed_bytes`). Any
/// change to the candidate set, the applicability tree, or a family
/// constant moves the hash; pure refactors that preserve all of those keep
/// it stable. The probe shapes are part of the format: changing them
/// changes the generation, which is safe (one extra invalidation round)
/// but not free.
pub fn registry_generation() -> u64 {
    let probe = |op: OpKind, in_ch: u32, out_ch: u32, hw: u32| Layer {
        id: 0,
        name: String::new(),
        op,
        in_ch,
        out_ch,
        in_hw: hw,
        out_hw: hw,
        deps: vec![],
    };
    let probes = [
        probe(OpKind::Conv { kernel: 3, stride: 1, groups: 1 }, 64, 192, 56),
        probe(OpKind::Conv { kernel: 3, stride: 2, groups: 1 }, 32, 64, 112),
        probe(OpKind::Conv { kernel: 1, stride: 1, groups: 1 }, 64, 256, 28),
        probe(OpKind::Conv { kernel: 5, stride: 1, groups: 1 }, 48, 96, 28),
        probe(OpKind::Conv { kernel: 7, stride: 2, groups: 1 }, 3, 64, 224),
        probe(OpKind::Conv { kernel: 3, stride: 1, groups: 64 }, 64, 64, 56),
        probe(OpKind::Conv { kernel: 3, stride: 1, groups: 30 }, 30, 30, 56),
        probe(OpKind::Fc, 2048, 1000, 1),
        probe(OpKind::Fc, 2048, 10, 1),
        probe(OpKind::Pool { kernel: 2, stride: 2, global: false }, 64, 64, 56),
    ];
    let mut doc = String::new();
    for (variant, registry) in [("full", Registry::full()), ("warm", Registry::warm_default())] {
        for (pi, layer) in probes.iter().enumerate() {
            for k in registry.candidates(layer) {
                use std::fmt::Write as _;
                let _ = writeln!(
                    doc,
                    "{variant}|{pi}|{}|{}|{}|{:.6}|{:.6}|{:.6}|{}",
                    k.name,
                    k.family.name(),
                    k.family.needs_transform(),
                    k.family.expand(),
                    k.family.transform_work(),
                    k.family.exec_speed(),
                    k.transformed_bytes(layer),
                );
            }
        }
    }
    crate::store::fnv1a(doc.as_bytes())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layer(op: OpKind, in_ch: u32, out_ch: u32) -> Layer {
        Layer {
            id: 0,
            name: "l".into(),
            op,
            in_ch,
            out_ch,
            in_hw: 28,
            out_hw: 28,
            deps: vec![],
        }
    }

    #[test]
    fn conv_gets_multiple_candidates() {
        let l = layer(OpKind::Conv { kernel: 3, stride: 1, groups: 1 }, 64, 128);
        let ks = Registry::full().candidates(&l);
        assert!(ks.len() >= 4, "{ks:?}");
    }

    #[test]
    fn warm_only_registry_picks_fastest_exec() {
        let l = layer(OpKind::Conv { kernel: 3, stride: 1, groups: 1 }, 64, 128);
        let ks = Registry::warm_default().candidates(&l);
        assert_eq!(ks.len(), 1);
        // warm-fastest 3x3s1 I4O4 kernel is winograd-pack4 (Table 2)
        assert_eq!(ks[0].family, KernelFamily::WinogradPack4);
    }

    #[test]
    fn depthwise_gets_dw_kernels() {
        let l = layer(OpKind::Conv { kernel: 3, stride: 1, groups: 64 }, 64, 64);
        let ks = Registry::full().candidates(&l);
        assert!(ks.iter().all(|k| matches!(
            k.family,
            KernelFamily::DwDirect | KernelFamily::DwPack4
        )));
    }

    #[test]
    fn weightless_gets_builtin() {
        let l = layer(OpKind::Pool { kernel: 2, stride: 2, global: false }, 64, 64);
        let ks = Registry::full().candidates(&l);
        assert_eq!(ks.len(), 1);
        assert_eq!(ks[0].family, KernelFamily::Builtin);
    }

    #[test]
    fn registry_generation_is_stable_and_nonzero() {
        let g = registry_generation();
        assert_ne!(g, 0);
        assert_eq!(g, registry_generation(), "must be a pure build constant");
    }

    #[test]
    fn fc_pack4_requires_divisibility() {
        let l = layer(OpKind::Fc, 2048, 1000);
        let ks = Registry::full().candidates(&l);
        assert_eq!(ks.len(), 2); // 1000 % 4 == 0
        let l = layer(OpKind::Fc, 2048, 10);
        let ks = Registry::full().candidates(&l);
        assert!(ks.len() >= 1);
    }
}
