//! The Fig. 5 kernel-applicability tree for convolution.
//!
//! ncnn picks among 28 convolution kernels based on kernel size K, stride S,
//! and whether the input/output channel counts are divisible by 4
//! ("I4O4" / "I1O4" / "I4O1" / "I1O1" in the figure). This module encodes
//! which kernels are *usable* for a configuration; which one is *chosen*
//! is the scheduler's job (warm-optimal choice ≠ cold-optimal choice).

use super::family::KernelFamily;
use crate::graph::{Layer, OpKind};

/// A concrete conv kernel: ncnn-style name + family it belongs to.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ConvKernel {
    pub name: &'static str,
    pub family: KernelFamily,
}

/// All 28 convolution kernels of Fig. 5 (top box), by ncnn name.
pub const ALL_CONV_KERNELS: [ConvKernel; 28] = [
    // SGEMM family (S1..S7)
    ConvKernel { name: "sgemm", family: KernelFamily::Sgemm },
    ConvKernel { name: "sgemm_pack4", family: KernelFamily::SgemmPack4 },
    ConvKernel { name: "1x1s1_sgemm", family: KernelFamily::Sgemm },
    ConvKernel { name: "1x1s1_sgemm_pack4", family: KernelFamily::SgemmPack4 },
    ConvKernel { name: "1x1s1_sgemm_pack4to1", family: KernelFamily::SgemmPack4 },
    ConvKernel { name: "1x1s2_sgemm_pack4", family: KernelFamily::SgemmPack4 },
    ConvKernel { name: "3x3s2_sgemm_pack4", family: KernelFamily::SgemmPack4 },
    // Winograd family (W1..W3)
    ConvKernel { name: "3x3s1_winograd", family: KernelFamily::Winograd },
    ConvKernel { name: "3x3s1_winograd_pack4", family: KernelFamily::WinogradPack4 },
    ConvKernel { name: "3x3s1_winograd_pack4to1", family: KernelFamily::WinogradPack4 },
    // Pack re-layout family (P1..P9)
    ConvKernel { name: "pack4", family: KernelFamily::Pack4 },
    ConvKernel { name: "pack4to1", family: KernelFamily::Pack4 },
    ConvKernel { name: "pack1to4", family: KernelFamily::Pack4 },
    ConvKernel { name: "1x1s1_pack4", family: KernelFamily::Pack4 },
    ConvKernel { name: "3x3s1_pack4", family: KernelFamily::Pack4 },
    ConvKernel { name: "3x3s2_pack1to4", family: KernelFamily::Pack4 },
    ConvKernel { name: "5x5s1_pack4", family: KernelFamily::Pack4 },
    ConvKernel { name: "5x5s2_pack4", family: KernelFamily::Pack4 },
    ConvKernel { name: "7x7s2_pack1to4", family: KernelFamily::Pack4 },
    // Direct specialized family (G2..G9) + vanilla (G1)
    ConvKernel { name: "vanilla", family: KernelFamily::General },
    ConvKernel { name: "1x1s1", family: KernelFamily::Direct },
    ConvKernel { name: "1x1s2", family: KernelFamily::Direct },
    ConvKernel { name: "3x3s1", family: KernelFamily::Direct },
    ConvKernel { name: "3x3s2", family: KernelFamily::Direct },
    ConvKernel { name: "4x4s4", family: KernelFamily::Direct },
    ConvKernel { name: "5x5s1", family: KernelFamily::Direct },
    ConvKernel { name: "5x5s2", family: KernelFamily::Direct },
    ConvKernel { name: "7x7s2", family: KernelFamily::Direct },
];

/// Channel-divisibility case of Fig. 5's column axis.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PackCase {
    I4O4,
    I1O4,
    I4O1,
    I1O1,
}

/// Classify a layer's channel divisibility.
pub fn pack_case(layer: &Layer) -> PackCase {
    let i4 = layer.in_ch % 4 == 0;
    let o4 = layer.out_ch % 4 == 0;
    match (i4, o4) {
        (true, true) => PackCase::I4O4,
        (false, true) => PackCase::I1O4,
        (true, false) => PackCase::I4O1,
        (false, false) => PackCase::I1O1,
    }
}

/// Usable conv kernels for a layer, walking Fig. 5's K/S/divisibility tree.
/// The vanilla kernel is always usable (last resort in every tree node).
pub fn usable_conv_kernels(layer: &Layer) -> Vec<ConvKernel> {
    let (k, s) = match layer.op {
        OpKind::Conv { kernel, stride, .. } => (kernel, stride),
        _ => return Vec::new(),
    };
    let case = pack_case(layer);
    let pick = |names: &[&str]| -> Vec<ConvKernel> {
        let mut out: Vec<ConvKernel> = names
            .iter()
            .map(|n| {
                ALL_CONV_KERNELS
                    .iter()
                    .find(|ck| ck.name == *n)
                    .unwrap_or_else(|| panic!("unknown kernel name {n}"))
                    .clone()
            })
            .collect();
        // vanilla fallback always present exactly once
        if !out.iter().any(|ck| ck.name == "vanilla") {
            out.push(ConvKernel { name: "vanilla", family: KernelFamily::General });
        }
        out
    };
    use PackCase::*;
    match (k, s) {
        (1, 1) => match case {
            I4O4 => pick(&["1x1s1_sgemm_pack4", "1x1s1_pack4", "1x1s1_sgemm", "sgemm", "1x1s1"]),
            I1O4 => pick(&["pack1to4", "1x1s1_sgemm", "sgemm", "1x1s1"]),
            I4O1 => pick(&["1x1s1_sgemm_pack4to1", "1x1s1_sgemm", "sgemm", "1x1s1"]),
            I1O1 => pick(&["1x1s1_sgemm", "sgemm", "1x1s1"]),
        },
        (1, 2) => match case {
            I4O4 => pick(&["1x1s2_sgemm_pack4", "sgemm", "1x1s2"]),
            _ => pick(&["sgemm", "1x1s2"]),
        },
        (1, _) => pick(&["sgemm", "vanilla"]),
        (3, 1) => match case {
            I4O4 => pick(&[
                "3x3s1_winograd_pack4",
                "sgemm_pack4",
                "3x3s1_pack4",
                "3x3s1_winograd",
                "sgemm",
                "3x3s1",
            ]),
            I1O4 => pick(&["pack1to4", "3x3s1_winograd", "sgemm", "3x3s1"]),
            I4O1 => pick(&["3x3s1_winograd_pack4to1", "3x3s1_winograd", "sgemm", "3x3s1"]),
            I1O1 => pick(&["3x3s1_winograd", "sgemm", "3x3s1"]),
        },
        (3, 2) => match case {
            I4O4 => pick(&["3x3s2_sgemm_pack4", "sgemm", "3x3s2"]),
            I1O4 => pick(&["3x3s2_pack1to4", "sgemm", "3x3s2"]),
            _ => pick(&["sgemm", "3x3s2"]),
        },
        (3, _) => pick(&["sgemm", "vanilla"]),
        (4, 4) => pick(&["4x4s4", "sgemm"]),
        (4, _) => pick(&["sgemm", "vanilla"]),
        (5, 1) => match case {
            I4O4 => pick(&["5x5s1_pack4", "sgemm_pack4", "sgemm", "5x5s1"]),
            _ => pick(&["sgemm", "5x5s1"]),
        },
        (5, 2) => match case {
            I4O4 => pick(&["5x5s2_pack4", "sgemm", "5x5s2"]),
            _ => pick(&["sgemm", "5x5s2"]),
        },
        (7, 2) => match case {
            I1O4 => pick(&["7x7s2_pack1to4", "sgemm", "7x7s2"]),
            I4O4 => pick(&["sgemm_pack4", "sgemm", "7x7s2"]),
            _ => pick(&["sgemm", "7x7s2"]),
        },
        _ => match case {
            I4O4 => pick(&["sgemm_pack4", "sgemm"]),
            _ => pick(&["sgemm"]),
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn conv(in_ch: u32, out_ch: u32, k: u32, s: u32) -> Layer {
        Layer {
            id: 0,
            name: "c".into(),
            op: OpKind::Conv { kernel: k, stride: s, groups: 1 },
            in_ch,
            out_ch,
            in_hw: 56,
            out_hw: 56 / s,
            deps: vec![],
        }
    }

    #[test]
    fn twenty_eight_kernels_total() {
        assert_eq!(ALL_CONV_KERNELS.len(), 28);
        // names unique
        let mut names: Vec<_> = ALL_CONV_KERNELS.iter().map(|k| k.name).collect();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), 28);
    }

    #[test]
    fn k3s1_i4o4_includes_winograd_and_sgemm() {
        let ks = usable_conv_kernels(&conv(64, 192, 3, 1));
        let names: Vec<_> = ks.iter().map(|k| k.name).collect();
        assert!(names.contains(&"3x3s1_winograd_pack4"));
        assert!(names.contains(&"sgemm_pack4"));
        assert!(names.contains(&"3x3s1"));
        assert!(names.contains(&"vanilla"));
    }

    #[test]
    fn odd_channels_disable_pack4() {
        let ks = usable_conv_kernels(&conv(3, 32, 3, 1)); // I1O4
        assert!(!ks.iter().any(|k| k.name == "3x3s1_winograd_pack4"));
        assert!(ks.iter().any(|k| k.name == "pack1to4"));
    }

    #[test]
    fn vanilla_always_available() {
        for (k, s) in [(1, 1), (1, 2), (3, 1), (3, 2), (5, 1), (5, 2), (7, 2), (11, 4), (2, 1)] {
            for (ic, oc) in [(64, 64), (3, 32), (64, 65), (3, 5)] {
                let ks = usable_conv_kernels(&conv(ic, oc, k, s));
                assert!(
                    ks.iter().any(|x| x.name == "vanilla"),
                    "no vanilla for k{k}s{s} {ic}->{oc}"
                );
                // no duplicates
                let mut names: Vec<_> = ks.iter().map(|x| x.name).collect();
                names.sort();
                let n = names.len();
                names.dedup();
                assert_eq!(names.len(), n, "duplicate kernels for k{k}s{s}");
            }
        }
    }

    #[test]
    fn pack_case_classification() {
        assert_eq!(pack_case(&conv(64, 192, 3, 1)), PackCase::I4O4);
        assert_eq!(pack_case(&conv(3, 192, 3, 1)), PackCase::I1O4);
        assert_eq!(pack_case(&conv(64, 3, 3, 1)), PackCase::I4O1);
        assert_eq!(pack_case(&conv(3, 5, 3, 1)), PackCase::I1O1);
    }

    #[test]
    fn alexnet_k11_uses_sgemm() {
        let ks = usable_conv_kernels(&conv(3, 96, 11, 4));
        assert!(ks.iter().any(|k| k.name == "sgemm"));
    }
}
