//! Kernel implementation families and their cost-relevant properties.
//!
//! The constants are calibrated against Table 2 of the paper (conv
//! 3×3 s1, 64→192 channels on the Meizu 16T):
//!
//! | kernel               | read raw | transform | read cache | exec |
//! |----------------------|---------:|----------:|-----------:|-----:|
//! | 3x3s1-winograd-pack4 |     0.70 |     38.23 |       5.23 | 2.98 |
//! | sgemm-pack4          |     0.70 |      2.21 |       0.70 | 8.14 |
//! | pack4                |     0.70 |      2.22 |       0.70 | 18.63|
//! | 3x3s1-winograd       |     0.70 |     65.67 |       4.12 | 3.37 |
//! | 3x3s1 (direct)       |     0.70 |      0.00 |       0.70 | 8.01 |
//! | general              |     0.70 |      0.00 |       0.70 | 87.12|
//!
//! Three family-level properties generate those columns for *any* layer:
//! `expand` (transformed bytes ÷ raw bytes — drives "read cache"),
//! `transform_work` (memory passes over the transformed weights — drives
//! "transform"), and `exec_speed` (execution throughput relative to plain
//! sgemm = 1.0 — drives "exec").

use crate::graph::Layer;
use crate::Bytes;

/// Implementation family of a kernel.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum KernelFamily {
    /// Winograd F(4,3) with pack-4 layout (`3x3s1_winograd_pack4`, W2/W3).
    WinogradPack4,
    /// Winograd F(4,3), planar layout (`3x3s1_winograd`, W1).
    Winograd,
    /// Im2col + SGEMM with pack-4 layout (S2/S4…S7 `sgemm_pack4` family).
    SgemmPack4,
    /// Im2col + SGEMM, planar (`sgemm`, S1/S3).
    Sgemm,
    /// Pack-4 direct convolution (`pack4`, P1…P9 re-layout kernels).
    Pack4,
    /// Shape-specialized direct kernel (G4…G9: `3x3s1`, `3x3s2`, `5x5s1`…).
    Direct,
    /// Generic fallback convolution (`G1: vanilla`). Always applicable.
    General,
    /// Depthwise direct (the dw counterparts of G/P kernels).
    DwDirect,
    /// Depthwise pack-4.
    DwPack4,
    /// Inner-product SGEMM (fc).
    FcSgemm,
    /// Inner-product SGEMM pack-4.
    FcSgemmPack4,
    /// Weightless builtin (pool/act/eltwise/…): single implementation.
    Builtin,
}

impl KernelFamily {
    /// Transformed-weight size ÷ raw-weight size.
    ///
    /// Winograd F(4,3) expands each 3×3 tap to an 8×8 tile (Fig. 3 of the
    /// paper: (H,3,3,C) → (8·8·H·4, C/4, 1, 1)): ×64/9 ≈ 7.1, plus pack-4
    /// padding ≈ 7.5. Planar winograd stores 6×6 tiles (×4 ≈ 36/9) with
    /// alignment ≈ 5.9 (the ratio implied by Table 2's 4.12 ms cache read).
    /// SGEMM/pack4 re-layouts are size-preserving (×1.0, modulo ≤4-lane
    /// padding handled in [`transformed_bytes`]).
    pub fn expand(&self) -> f64 {
        match self {
            KernelFamily::WinogradPack4 => 7.5,
            KernelFamily::Winograd => 5.9,
            KernelFamily::SgemmPack4
            | KernelFamily::Sgemm
            | KernelFamily::Pack4
            | KernelFamily::DwPack4
            | KernelFamily::FcSgemm
            | KernelFamily::FcSgemmPack4 => 1.0,
            KernelFamily::Direct | KernelFamily::General | KernelFamily::DwDirect => 1.0,
            KernelFamily::Builtin => 0.0,
        }
    }

    /// Whether the family needs a weight transformation at all. Families
    /// that execute directly on raw weights (direct/general) have none, so
    /// caching is pointless for them.
    pub fn needs_transform(&self) -> bool {
        match self {
            KernelFamily::Direct
            | KernelFamily::General
            | KernelFamily::DwDirect
            | KernelFamily::Builtin => false,
            _ => true,
        }
    }

    /// Transformation work factor: effective number of read+write passes
    /// over the *transformed* bytes during weight transformation, on the
    /// reference little core. Winograd's G·g·Gᵀ per-tile matmuls make it
    /// far more expensive than a pure re-layout; the planar variant is
    /// worse still (strided scatter, Table 2: 65.67 vs 38.23 ms).
    pub fn transform_work(&self) -> f64 {
        match self {
            KernelFamily::WinogradPack4 => 5.3,
            KernelFamily::Winograd => 11.6,
            KernelFamily::SgemmPack4 | KernelFamily::Pack4 | KernelFamily::DwPack4 => 2.3,
            KernelFamily::Sgemm => 1.6,
            KernelFamily::FcSgemm => 1.0,
            KernelFamily::FcSgemmPack4 => 2.3,
            KernelFamily::Direct
            | KernelFamily::General
            | KernelFamily::DwDirect
            | KernelFamily::Builtin => 0.0,
        }
    }

    /// Execution throughput relative to planar SGEMM (= 1.0) for the
    /// layer shapes the family targets. From Table 2 (big-core exec):
    /// general 87.12 ms ⇒ 0.094× sgemm-ish direct 8.01; winograd-pack4
    /// 2.98 ms ⇒ 2.73×.
    pub fn exec_speed(&self) -> f64 {
        match self {
            KernelFamily::WinogradPack4 => 2.73,
            KernelFamily::Winograd => 2.41,
            KernelFamily::SgemmPack4 => 1.0,
            KernelFamily::Sgemm => 0.72,
            KernelFamily::Pack4 => 0.44,
            KernelFamily::Direct => 0.98,
            KernelFamily::General => 0.094,
            KernelFamily::DwDirect => 0.85,
            KernelFamily::DwPack4 => 1.25,
            KernelFamily::FcSgemm => 0.9,
            KernelFamily::FcSgemmPack4 => 1.15,
            KernelFamily::Builtin => 0.6,
        }
    }

    /// Display name.
    pub fn name(&self) -> &'static str {
        match self {
            KernelFamily::WinogradPack4 => "winograd-pack4",
            KernelFamily::Winograd => "winograd",
            KernelFamily::SgemmPack4 => "sgemm-pack4",
            KernelFamily::Sgemm => "sgemm",
            KernelFamily::Pack4 => "pack4",
            KernelFamily::Direct => "direct",
            KernelFamily::General => "general",
            KernelFamily::DwDirect => "dw-direct",
            KernelFamily::DwPack4 => "dw-pack4",
            KernelFamily::FcSgemm => "fc-sgemm",
            KernelFamily::FcSgemmPack4 => "fc-sgemm-pack4",
            KernelFamily::Builtin => "builtin",
        }
    }
}

/// Transformed-weight bytes for a layer under a family (pack-4 pads channel
/// counts up to multiples of 4).
pub fn transformed_bytes(family: KernelFamily, layer: &Layer) -> Bytes {
    let raw = layer.weight_bytes();
    if !family.needs_transform() {
        return raw;
    }
    let pad = |c: u64| -> f64 {
        let padded = (c + 3) / 4 * 4;
        padded as f64 / c.max(1) as f64
    };
    let pad_factor = match family {
        KernelFamily::WinogradPack4
        | KernelFamily::SgemmPack4
        | KernelFamily::Pack4
        | KernelFamily::DwPack4
        | KernelFamily::FcSgemmPack4 => pad(layer.in_ch as u64) * pad(layer.out_ch as u64),
        _ => 1.0,
    };
    (raw as f64 * family.expand() * pad_factor).round() as Bytes
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::OpKind;

    fn conv_layer() -> Layer {
        Layer {
            id: 0,
            name: "c".into(),
            op: OpKind::Conv { kernel: 3, stride: 1, groups: 1 },
            in_ch: 64,
            out_ch: 192,
            in_hw: 56,
            out_hw: 56,
            deps: vec![],
        }
    }

    #[test]
    fn table2_cache_read_ratio() {
        // Table 2: winograd-pack4 cache read 5.23 ms vs raw read 0.70 ms
        // ⇒ expansion ≈ 7.5×. Channels already divisible by 4 ⇒ no padding.
        let l = conv_layer();
        let raw = l.weight_bytes();
        let t = transformed_bytes(KernelFamily::WinogradPack4, &l);
        let ratio = t as f64 / raw as f64;
        assert!((7.0..8.0).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn no_transform_families_keep_raw_size() {
        let l = conv_layer();
        assert_eq!(transformed_bytes(KernelFamily::Direct, &l), l.weight_bytes());
        assert_eq!(transformed_bytes(KernelFamily::General, &l), l.weight_bytes());
        assert!(!KernelFamily::Direct.needs_transform());
        assert_eq!(KernelFamily::Direct.transform_work(), 0.0);
    }

    #[test]
    fn pack4_pads_odd_channels() {
        let mut l = conv_layer();
        l.in_ch = 3; // pads to 4: factor 4/3
        let t = transformed_bytes(KernelFamily::SgemmPack4, &l);
        assert!(t > l.weight_bytes());
    }

    #[test]
    fn exec_speed_ordering_matches_table2() {
        // winograd fastest, general slowest.
        let fams = [
            KernelFamily::WinogradPack4,
            KernelFamily::Winograd,
            KernelFamily::SgemmPack4,
            KernelFamily::Direct,
            KernelFamily::Sgemm,
            KernelFamily::Pack4,
            KernelFamily::General,
        ];
        for w in fams.windows(2) {
            assert!(
                w[0].exec_speed() >= w[1].exec_speed(),
                "{} < {}",
                w[0].name(),
                w[1].name()
            );
        }
    }
}
