//! The content-addressed artifact store: one persistence layer for every
//! byte the system finds expensive to recompute.
//!
//! NNV12 produces four kinds of durable artifacts: scheduling **plans**
//! (the Fig. 4 offline decision stage), **calibrated plans** (a plan plus
//! the §3.3 re-profiled device view), post-transformed **weights**
//! (the §3.1.2 transformation-bypass cache), and **fleet plans** (a plan
//! published under a model scope and keyed by device fingerprint, so
//! other devices can enumerate candidates for cross-device transfer —
//! [`crate::fleet`]). Before this module each would have had its own
//! ad-hoc disk format with no shared integrity, versioning, or eviction
//! story; [`ArtifactStore`] gives them one.
//!
//! # Key scheme
//!
//! Artifacts are *content-addressed*: the key is a 64-bit structural
//! fingerprint of everything the artifact is a function of — device
//! profile fields, model architecture, scheduler config knobs, registry
//! tag for plans ([`crate::sched::cache::fingerprint`]); model name,
//! layer, kernel variant, and the raw blob's length + checksum for
//! weights ([`crate::weights::TransformCache`]). A changed input produces
//! a different key, so stale artifacts are never *returned* — they simply
//! stop being addressed and age out through LRU eviction. Keys are
//! namespaced ([`Namespace`]) so a plan and a weight blob can never
//! collide even at equal hashes.
//!
//! # On-disk layout (format version 2)
//!
//! One flat directory of `<namespace>-<key:016x>.art` files. Each file is
//! a fixed 48-byte header followed by the payload:
//!
//! ```text
//! offset  size  field
//!      0     8  magic  b"NNV12ART"
//!      8     4  format version (little-endian u32, currently 2)
//!     12     4  namespace id (u32: 0 plan, 1 calibrated-plan, 2 weights,
//!                             3 fleet-plan)
//!     16     8  key (u64; must match the filename)
//!     24     8  payload length (u64)
//!     32     8  FNV-1a 64 checksum of the payload
//!     40     8  registry stamp ([`crate::kernels::registry_generation`]
//!                              of the build that wrote the artifact)
//!     48     …  payload bytes
//! ```
//!
//! Reads validate every header field plus the checksum; a malformed file
//! (foreign, truncated, bit-rotted) is rejected, deleted, and reported as
//! a miss — corrupt artifacts can never poison a consumer, they only cost
//! a recompute. Typed views layer *structural* revalidation on top (a
//! plan JSON is re-validated against the live model graph and kernel
//! registry before it is trusted).
//!
//! ## Registry versioning
//!
//! The content-addressed key captures everything an artifact is a
//! function of *except the build itself*: an engine upgrade that changes
//! the kernel registry (new kernels, retuned cost constants) silently
//! invalidates every plan and transformed-weight blob while leaving their
//! keys unchanged. The v2 registry stamp closes that hole. A well-formed
//! artifact whose stamp differs from this build's
//! [`crate::kernels::registry_generation`] is **stale**: deleted on first
//! read and reported as a miss (counted in [`StoreStats::stale`]), so the
//! caller recomputes and re-stores under the current stamp — the upgrade
//! costs each live artifact exactly one recompute, after which every read
//! hits again.
//!
//! ## Migration from format version 1
//!
//! v1 files (40-byte header, no stamp) are still parsed. JSON-payload
//! namespaces (plans, calibrated plans, fleet plans) carry downstream
//! structural revalidation, so their payloads are bit-compatible across
//! the header change: a v1 read serves the payload as a hit and rewrites
//! the file in place with a v2 header under the current stamp (counted in
//! [`StoreStats::migrated`]) — the PR 8 heal-in-place idiom. Weight blobs
//! have no downstream check that could catch a registry change, so a v1
//! weights artifact is treated as stale: deleted, missed, re-transformed.
//!
//! # Writes, concurrency, and crash safety
//!
//! Writes go to a process- and writer-unique temp file, then rename into
//! place, so concurrent processes sharing a store directory only ever
//! observe complete documents; whichever complete document wins the
//! rename is kept (put is last-wins, which is safe because equal keys
//! address equal content). All counters are atomics; the store is `Sync`
//! and cheap to share as an `Arc` across caches, engines, and threads.
//!
//! ## Write intents (multi-artifact atomicity)
//!
//! One cold start writes *several* artifacts (plan + calibrated plan +
//! transformed weights + fleet seed); each rename is atomic, but a crash
//! between them leaves a group that is individually valid and mutually
//! inconsistent. [`ArtifactStore::begin_intent`] opens a journal file
//! (`intent-<pid>-<id>.intent`) for the current thread; every `put` on
//! that thread first records its final file name in the journal (atomic
//! rewrite) and only then writes the member, so the journal always lists
//! a superset of the group's landed members.
//! [`WriteIntent::commit`] removes the journal; a crash — or any abandon
//! without commit — leaves it behind, and the next
//! [`ArtifactStore::open`] discards the whole group. Discarding is always
//! safe (members are recomputable), and it is the conservative choice: a
//! reopened store never serves a partially-written group, even when the
//! surviving members would individually validate.
//!
//! ## Boot-time recovery
//!
//! [`ArtifactStore::open`] (and [`ArtifactStore::with_cap`]) runs a
//! recovery pass before serving anything: every leftover intent journal
//! has its member files and itself deleted (torn groups), and every
//! orphaned temp file (`*.tmp.*` — a write that died between temp-write
//! and rename, from *any* process id) is swept. The pass assumes it is
//! the only writer at open time — a store directory is opened once per
//! process, before serving starts — which is what lets it judge files
//! this same pid wrote before a simulated crash. [`ArtifactStore::at`]
//! defers directory creation and runs **no** recovery, so audits
//! (`repro store fsck`) can inspect the pre-recovery state.
//! [`ArtifactStore::recovery`] reports what the pass did.
//!
//! # Eviction
//!
//! A store opened with [`ArtifactStore::with_cap`] bounds its total
//! payload+header bytes. After every write the store scans its directory
//! and removes least-recently-used `.art` files (by modification time)
//! until it fits the cap; a validated read re-stamps the artifact's
//! (constant) magic bytes in place, refreshing its recency, so hot
//! artifacts survive both the LRU sweep and age-based gc.
//! The most recently written artifact is always kept, even when it alone
//! exceeds the cap — a store too small for its newest artifact would
//! otherwise evict everything and thrash. Evicting an artifact is always
//! safe: the next consumer takes a miss and recomputes (observable as a
//! cold re-plan or a re-transform), then re-stores.
//!
//! Uncapped stores have no size pressure, so unaddressed artifacts
//! (plans/weights of updated models, whose new content hashes to new
//! keys) would linger forever; [`ArtifactStore::gc`] — the `repro store
//! gc --days N` subcommand — sweeps them by age instead, never removing
//! a namespace's newest artifact.

use std::collections::HashMap;
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::thread::ThreadId;
use std::time::SystemTime;

use crate::faults::{crash_now, FaultKind, FaultPlan, FaultSite};

const MAGIC: [u8; 8] = *b"NNV12ART";
const FORMAT_VERSION: u32 = 2;
const HEADER_LEN: usize = 48;
/// The PR 3 .. PR 9 on-disk format: identical through offset 40, no
/// registry stamp. Still parsed on read — see the module docs' migration
/// section.
const LEGACY_V1_VERSION: u32 = 1;
const LEGACY_V1_HEADER_LEN: usize = 40;

/// Typed artifact namespaces. The namespace is part of the address (file
/// name prefix + header field), so artifacts of different kinds can never
/// collide or be misinterpreted for one another.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Namespace {
    /// Scheduling plans (JSON payload, see [`crate::sched::plan::Plan`]).
    Plan,
    /// Calibrated `(plan, device-view)` pairs (JSON payload).
    CalibratedPlan,
    /// Post-transformed weight blobs (little-endian f32 payload).
    Weights,
    /// Fleet-published plans (JSON payload): a plan plus the device
    /// fingerprint it was searched on, stored under a *model* scope and
    /// keyed by the fingerprint's identity so [`crate::fleet`] can
    /// enumerate every device's plan for a model and pick the
    /// nearest-profile one to seed a transfer.
    FleetPlan,
}

impl Namespace {
    /// Stable file-name prefix of this namespace.
    pub fn tag(self) -> &'static str {
        match self {
            Namespace::Plan => "plan",
            Namespace::CalibratedPlan => "calibrated-plan",
            Namespace::Weights => "weights",
            Namespace::FleetPlan => "fleet-plan",
        }
    }

    fn id(self) -> u32 {
        match self {
            Namespace::Plan => 0,
            Namespace::CalibratedPlan => 1,
            Namespace::Weights => 2,
            Namespace::FleetPlan => 3,
        }
    }
}

/// FNV-1a 64-bit over a byte slice — the store's payload checksum, also
/// usable by views to fingerprint source content (e.g. raw weight blobs).
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Snapshot of a store's counters ([`ArtifactStore::stats`]); surfaced
/// through [`crate::engine::Engine::store_stats`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StoreStats {
    /// Validated artifact reads served.
    pub hits: usize,
    /// Lookups of absent keys.
    pub misses: usize,
    /// Files removed by the LRU size-cap sweep.
    pub evictions: usize,
    /// Artifacts rejected (and deleted) by header/checksum validation.
    pub rejected: usize,
    /// Well-formed artifacts invalidated because they were written under
    /// a different kernel-registry generation (v2 stamp mismatch, or v1
    /// weights with no stamp at all). Each costs exactly one recompute.
    pub stale: usize,
    /// v1 artifacts served and rewritten in place with a v2 header (the
    /// bit-compatible JSON namespaces — see the module docs).
    pub migrated: usize,
    /// Current total bytes of artifact files in the directory.
    pub bytes_used: u64,
    /// Total artifact bytes written over this store handle's lifetime.
    pub bytes_written: u64,
}

/// The store. See the module docs for the key scheme, on-disk layout, and
/// eviction policy.
#[derive(Debug)]
pub struct ArtifactStore {
    dir: PathBuf,
    cap_bytes: Option<u64>,
    hits: AtomicUsize,
    misses: AtomicUsize,
    evictions: AtomicUsize,
    rejected: AtomicUsize,
    stale: AtomicUsize,
    migrated: AtomicUsize,
    bytes_written: AtomicU64,
    /// Running estimate of on-disk bytes, used only to decide *when* a
    /// capped store must run an eviction sweep (each sweep re-measures
    /// exactly and re-seeds this, so drift from other writers
    /// self-corrects). Keeps `put` O(1) instead of a directory walk.
    approx_used: AtomicU64,
    next_tmp: AtomicUsize,
    /// The registry generation stamped into every write and expected of
    /// every v2 read. Defaults to this build's
    /// [`crate::kernels::registry_generation`]; tests simulate an engine
    /// upgrade with [`ArtifactStore::pin_registry_stamp`].
    registry_stamp: AtomicU64,
    next_intent: AtomicU64,
    /// Active write intents, keyed by the thread that opened them (an
    /// intent groups the puts of *its* thread's cold start; concurrent
    /// threads' writes are unrelated and uncaptured). Innermost-last per
    /// thread, so intents nest.
    intents: Mutex<HashMap<ThreadId, Vec<IntentFrame>>>,
    /// What the boot-time recovery pass did, when this handle ran one
    /// ([`ArtifactStore::open`]; `at` handles never recover).
    recovery: Option<RecoveryReport>,
    /// Armed fault-injection plan ([`ArtifactStore::inject_faults`]).
    /// Empty in production: reads/writes pay one pointer check and behave
    /// bit-identically to an uninstrumented store.
    faults: OnceLock<Arc<FaultPlan>>,
}

impl ArtifactStore {
    /// Open (creating if absent) an unbounded store at `dir`, running the
    /// boot-time recovery pass (discard torn intent groups, sweep orphan
    /// temp files — see the module docs) before anything is served.
    pub fn open(dir: impl Into<PathBuf>) -> std::io::Result<ArtifactStore> {
        let mut store = ArtifactStore::at(dir);
        std::fs::create_dir_all(&store.dir)?;
        store.recovery = Some(store.recover());
        Ok(store)
    }

    /// [`ArtifactStore::open`] with a total size cap in bytes: after every
    /// write, least-recently-used artifacts are evicted until the store
    /// fits (the newest artifact is always kept).
    pub fn with_cap(dir: impl Into<PathBuf>, cap_bytes: u64) -> std::io::Result<ArtifactStore> {
        let mut store = ArtifactStore::open(dir)?;
        store.cap_bytes = Some(cap_bytes);
        store
            .approx_used
            .store(store.bytes_used(), Ordering::Relaxed);
        Ok(store)
    }

    /// A store handle that defers directory creation to the first write
    /// (infallible; reads against a missing directory are plain misses).
    /// Runs no recovery pass — `repro store fsck` uses this to audit the
    /// directory exactly as a crash left it.
    pub fn at(dir: impl Into<PathBuf>) -> ArtifactStore {
        ArtifactStore {
            dir: dir.into(),
            cap_bytes: None,
            hits: AtomicUsize::new(0),
            misses: AtomicUsize::new(0),
            evictions: AtomicUsize::new(0),
            rejected: AtomicUsize::new(0),
            stale: AtomicUsize::new(0),
            migrated: AtomicUsize::new(0),
            bytes_written: AtomicU64::new(0),
            approx_used: AtomicU64::new(0),
            next_tmp: AtomicUsize::new(0),
            registry_stamp: AtomicU64::new(crate::kernels::registry_generation()),
            next_intent: AtomicU64::new(0),
            intents: Mutex::new(HashMap::new()),
            recovery: None,
            faults: OnceLock::new(),
        }
    }

    /// Test hook: pretend this build's kernel registry hashes to `stamp`.
    /// Subsequent writes stamp it and subsequent reads expect it, so
    /// pinning a different stamp on a fresh handle simulates reopening
    /// the store after an engine upgrade.
    pub fn pin_registry_stamp(&self, stamp: u64) {
        self.registry_stamp.store(stamp, Ordering::Relaxed);
    }

    /// What the boot-time recovery pass found, for handles opened via
    /// [`ArtifactStore::open`] / [`ArtifactStore::with_cap`] (`None` for
    /// [`ArtifactStore::at`] handles, which never recover).
    pub fn recovery(&self) -> Option<RecoveryReport> {
        self.recovery
    }

    /// Arm deterministic fault injection on this handle (chaos tests and
    /// `repro serve --faults SEED`): subsequent reads consult `plan` for
    /// injected I/O errors and in-place corruption, writes for injected
    /// errors and torn writes. One-shot — a second call is ignored. A
    /// store that never calls this behaves bit-identically to before the
    /// hook existed.
    pub fn inject_faults(&self, plan: Arc<FaultPlan>) {
        let _ = self.faults.set(plan);
    }

    /// The backing directory.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// The size cap, if this store is bounded.
    pub fn cap_bytes(&self) -> Option<u64> {
        self.cap_bytes
    }

    /// Content-address helper: hash an ordered list of string parts into a
    /// key. Views with richer inputs (device profiles, graphs) hash those
    /// directly instead.
    pub fn key_of(parts: &[&str]) -> u64 {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for p in parts {
            h = fnv1a_continue(h, p.as_bytes());
            // Separator so ["ab","c"] != ["a","bc"].
            h = fnv1a_continue(h, &[0x1f]);
        }
        h
    }

    fn path_of(&self, ns: Namespace, key: u64) -> PathBuf {
        self.dir.join(format!("{}-{key:016x}.art", ns.tag()))
    }

    /// File name of a *scoped* artifact: `<ns>~<scope>-<key>.art`. The
    /// scope (e.g. a model name) groups artifacts for enumeration —
    /// [`ArtifactStore::clear_scope`] / [`ArtifactStore::bytes_in_scope`]
    /// — without affecting addressing (the key already covers the scope's
    /// content). Sanitized so the `~`/`-` separators stay unambiguous.
    fn scoped_path(&self, ns: Namespace, scope: &str, key: u64) -> PathBuf {
        self.dir
            .join(format!("{}~{}-{key:016x}.art", ns.tag(), sanitize_scope(scope)))
    }

    fn header(ns: Namespace, key: u64, payload: &[u8], stamp: u64) -> [u8; HEADER_LEN] {
        let mut h = [0u8; HEADER_LEN];
        h[0..8].copy_from_slice(&MAGIC);
        h[8..12].copy_from_slice(&FORMAT_VERSION.to_le_bytes());
        h[12..16].copy_from_slice(&ns.id().to_le_bytes());
        h[16..24].copy_from_slice(&key.to_le_bytes());
        h[24..32].copy_from_slice(&(payload.len() as u64).to_le_bytes());
        h[32..40].copy_from_slice(&fnv1a(payload).to_le_bytes());
        h[40..48].copy_from_slice(&stamp.to_le_bytes());
        h
    }

    /// Fetch and validate an artifact. `None` means absent, truncated,
    /// corrupt, foreign, or old-format — in every case the caller should
    /// recompute (invalid files are deleted so the recompute's `put`
    /// heals the store; a *transient read error* is reported as a plain
    /// miss and deletes nothing, since it is not evidence of corruption).
    /// A validated read refreshes the artifact's LRU recency.
    pub fn get(&self, ns: Namespace, key: u64) -> Option<Vec<u8>> {
        self.get_at(&self.path_of(ns, key), ns, key)
    }

    /// [`ArtifactStore::get`] for a scoped artifact (see
    /// [`ArtifactStore::put_scoped`]).
    pub fn get_scoped(&self, ns: Namespace, scope: &str, key: u64) -> Option<Vec<u8>> {
        self.get_at(&self.scoped_path(ns, scope, key), ns, key)
    }

    fn get_at(&self, path: &Path, ns: Namespace, key: u64) -> Option<Vec<u8>> {
        if let Some(f) = self.faults.get() {
            let (call, kind) = f.draw_at(FaultSite::StoreRead);
            match kind {
                // Injected transient read error: by contract a miss, never
                // a deletion — the bytes on disk may be perfectly valid.
                Some(FaultKind::IoError) => {
                    self.misses.fetch_add(1, Ordering::Relaxed);
                    return None;
                }
                // Injected bit rot: flip one byte of the on-disk artifact
                // and fall through — validation below must reject + heal.
                Some(FaultKind::CorruptBytes) => corrupt_in_place(path),
                // Simulated process death between operations: nothing was
                // touched yet, the disk is exactly as the last op left it.
                Some(FaultKind::Crash) => crash_now(FaultSite::StoreRead, call),
                _ => {}
            }
        }
        let mut file = match std::fs::File::open(path) {
            Ok(f) => f,
            Err(_) => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                return None;
            }
        };
        let mut bytes = Vec::new();
        if file.read_to_end(&mut bytes).is_err() {
            // Transient I/O failure (EIO, flaky network fs): the bytes on
            // disk may be perfectly valid, so don't delete — miss and let
            // the caller recompute.
            drop(file);
            self.misses.fetch_add(1, Ordering::Relaxed);
            return None;
        }
        drop(file);
        let expected = self.registry_stamp.load(Ordering::Relaxed);
        match classify_bytes(&bytes, ns, key, expected) {
            Image::Current(payload) => {
                let payload = payload.to_vec();
                self.hits.fetch_add(1, Ordering::Relaxed);
                // Refresh recency on every validated read: LRU eviction
                // (capped stores) and age-based gc (uncapped stores) both
                // define "in use" through the file's mtime, so a daily-hit
                // artifact must never look stale to either sweep.
                self.touch(path);
                Some(payload)
            }
            // Written under another kernel registry: the decisions inside
            // may be wrong for this build, and the key cannot tell.
            // Invalidate exactly once — the recompute re-stores under the
            // current stamp and every later read hits again.
            Image::Stale => {
                self.stale.fetch_add(1, Ordering::Relaxed);
                self.misses.fetch_add(1, Ordering::Relaxed);
                let _ = std::fs::remove_file(path);
                None
            }
            Image::Legacy(payload) => match ns {
                // A transformed blob with no stamp could come from any
                // registry generation; nothing downstream would catch a
                // wrong one, so treat it as stale.
                Namespace::Weights => {
                    self.stale.fetch_add(1, Ordering::Relaxed);
                    self.misses.fetch_add(1, Ordering::Relaxed);
                    let _ = std::fs::remove_file(path);
                    None
                }
                // JSON namespaces are structurally revalidated downstream
                // against the live registry, so the payload is
                // bit-compatible: serve it and heal the header in place.
                _ => {
                    let payload = payload.to_vec();
                    self.migrated.fetch_add(1, Ordering::Relaxed);
                    self.hits.fetch_add(1, Ordering::Relaxed);
                    self.rewrite_image(path, ns, key, &payload);
                    Some(payload)
                }
            },
            Image::Bad => self.reject(path),
        }
    }

    /// Rewrite one artifact file in place under the current format and
    /// stamp (v1 → v2 migration). Not an artifact write: draws no faults,
    /// joins no intent, moves no byte counters — and best-effort, because
    /// the payload has already been validated and is being served either
    /// way (a failed migration just retries on the next read).
    fn rewrite_image(&self, path: &Path, ns: Namespace, key: u64, payload: &[u8]) {
        let tmp = self.dir.join(format!(
            "{}-migrate.tmp.{}.{}",
            ns.tag(),
            std::process::id(),
            self.next_tmp.fetch_add(1, Ordering::Relaxed)
        ));
        let stamp = self.registry_stamp.load(Ordering::Relaxed);
        let header = ArtifactStore::header(ns, key, payload, stamp);
        let write = || -> std::io::Result<()> {
            let mut f = std::fs::File::create(&tmp)?;
            f.write_all(&header)?;
            f.write_all(payload)?;
            Ok(())
        };
        if write().and_then(|_| std::fs::rename(&tmp, path)).is_err() {
            let _ = std::fs::remove_file(&tmp);
        }
    }

    fn reject(&self, path: &Path) -> Option<Vec<u8>> {
        self.rejected.fetch_add(1, Ordering::Relaxed);
        let _ = std::fs::remove_file(path);
        None
    }

    /// Refresh recency: rewrite the 8 magic bytes in place, which bumps
    /// the file's modification time portably (a write updates mtime even
    /// when the bytes are identical). Only the magic is touched — never
    /// the key/len/checksum fields — because every valid artifact starts
    /// with the same magic: if a concurrent writer just renamed a
    /// *different* payload into place under this key (e.g. healing a
    /// stale entry), stamping the constant prefix cannot corrupt it,
    /// whereas re-writing the full header validated from the old payload
    /// would. Best-effort — a read-only store still serves hits, it just
    /// loses recency tracking.
    fn touch(&self, path: &Path) {
        if let Ok(mut f) = std::fs::OpenOptions::new().write(true).open(path) {
            let _ = f
                .seek(SeekFrom::Start(0))
                .and_then(|_| f.write_all(&MAGIC));
        }
    }

    /// Store an artifact atomically (temp file + rename), then enforce the
    /// size cap. Equal keys address equal content, so concurrent writers
    /// racing on one key are benign: whichever complete document wins the
    /// rename is kept.
    pub fn put(&self, ns: Namespace, key: u64, payload: &[u8]) -> std::io::Result<()> {
        self.put_at(self.path_of(ns, key), ns, key, payload)
    }

    /// [`ArtifactStore::put`] under a scope (e.g. a model name): the
    /// artifact is addressed exactly like an unscoped one, but its file
    /// name carries the scope so a whole scope can be enumerated, sized
    /// ([`ArtifactStore::bytes_in_scope`]), or dropped
    /// ([`ArtifactStore::clear_scope`]) without knowing its keys.
    pub fn put_scoped(
        &self,
        ns: Namespace,
        scope: &str,
        key: u64,
        payload: &[u8],
    ) -> std::io::Result<()> {
        self.put_at(self.scoped_path(ns, scope, key), ns, key, payload)
    }

    fn put_at(
        &self,
        path: PathBuf,
        ns: Namespace,
        key: u64,
        payload: &[u8],
    ) -> std::io::Result<()> {
        std::fs::create_dir_all(&self.dir)?;
        // Journal first, member second: if this thread is inside a write
        // intent, the journal on disk must already list this member by the
        // time its rename can land, so a crash at any later point leaves a
        // journal that covers every landed member of the group.
        if let Some(name) = path.file_name().and_then(|n| n.to_str()) {
            self.note_intent_member(name);
        }
        let tmp = self.dir.join(format!(
            "{}-{key:016x}.tmp.{}.{}",
            ns.tag(),
            std::process::id(),
            self.next_tmp.fetch_add(1, Ordering::Relaxed)
        ));
        let stamp = self.registry_stamp.load(Ordering::Relaxed);
        let header = ArtifactStore::header(ns, key, payload, stamp);
        let mut torn: Option<&[u8]> = None;
        if let Some(f) = self.faults.get() {
            let (call, kind) = f.draw_at(FaultSite::StoreWrite);
            match kind {
                // Injected mid-write failure: the EIO arrived after the
                // temp file was created, so — like a real one — it leaves
                // the half-written temp orphaned on disk (the boot-time
                // recovery sweep's job) and surfaces an error; callers
                // already treat a failed put as "artifact not cached".
                Some(FaultKind::IoError) => {
                    let _ = std::fs::File::create(&tmp).and_then(|mut f| {
                        f.write_all(&header)?;
                        f.write_all(&payload[..payload.len() / 2])
                    });
                    return Err(std::io::Error::new(
                        std::io::ErrorKind::Other,
                        "injected store write failure",
                    ));
                }
                // Injected torn write: the header (already built) claims
                // the full payload, but only the first half lands — the
                // file renames into place looking complete and must be
                // caught by the next read's checksum validation.
                Some(FaultKind::TornWrite) => torn = Some(&payload[..payload.len() / 2]),
                // Simulated process death in the worst window: the temp
                // file is fully written but the rename never happens. The
                // orphan (and, under an intent, the whole group) is the
                // recovery pass's problem.
                Some(FaultKind::Crash) => {
                    let _ = std::fs::File::create(&tmp).and_then(|mut f| {
                        f.write_all(&header)?;
                        f.write_all(payload)
                    });
                    crash_now(FaultSite::StoreWrite, call);
                }
                _ => {}
            }
        }
        let body: &[u8] = torn.unwrap_or(payload);
        let write = || -> std::io::Result<()> {
            let mut f = std::fs::File::create(&tmp)?;
            f.write_all(&header)?;
            f.write_all(body)?;
            Ok(())
        };
        if let Err(e) = write().and_then(|_| std::fs::rename(&tmp, &path)) {
            // Don't leave orphaned temp files accumulating in a long-lived
            // store directory (a *detected* failure can clean up after
            // itself; only crashes and injected mid-write deaths can't).
            let _ = std::fs::remove_file(&tmp);
            return Err(e);
        }
        let entry_bytes = (HEADER_LEN + body.len()) as u64;
        self.bytes_written.fetch_add(entry_bytes, Ordering::Relaxed);
        let estimated = self.approx_used.fetch_add(entry_bytes, Ordering::Relaxed) + entry_bytes;
        if self.cap_bytes.is_some_and(|cap| estimated > cap) {
            self.evict_to_cap();
        }
        Ok(())
    }

    /// Begin a write intent on the *current thread*: until the returned
    /// guard is [committed](WriteIntent::commit), every `put` this thread
    /// performs is recorded in an on-disk journal, and a crash (or any
    /// abandon without commit) makes the next [`ArtifactStore::open`]
    /// discard the whole group — partially-written multi-artifact cold
    /// starts are never served. Intents nest (the innermost captures the
    /// puts) and are thread-ambient, so the engine can group a cold
    /// start's plan + calibration + weights writes without threading a
    /// handle through every layer. Best-effort like all store
    /// persistence: if the journal itself cannot be written, the puts
    /// proceed ungrouped.
    ///
    /// Keep the guard on the thread that opened it — moving it elsewhere
    /// leaves the opening thread's puts captured and the new thread's
    /// not, which is never what you want.
    pub fn begin_intent(&self, label: &str) -> WriteIntent<'_> {
        let id = self.next_intent.fetch_add(1, Ordering::Relaxed);
        let label: String = label.replace(['\n', '\r'], " ");
        let _ = std::fs::create_dir_all(&self.dir);
        self.write_journal(id, &label, &[]);
        let thread = std::thread::current().id();
        self.intents_table()
            .entry(thread)
            .or_default()
            .push(IntentFrame { id, label, members: Vec::new() });
        WriteIntent { store: self, thread, id, committed: false }
    }

    fn intents_table(&self) -> std::sync::MutexGuard<'_, HashMap<ThreadId, Vec<IntentFrame>>> {
        // A poisoned table only means some thread panicked mid-access; the
        // map itself is always consistent (every mutation is a single
        // push/pop/remove), so keep going — intents must keep working
        // through the very crashes they exist to survive.
        self.intents
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner)
    }

    /// Record `member` (a final artifact file name) in the current
    /// thread's innermost intent journal, if one is active. The journal is
    /// rewritten atomically *before* the caller writes the member, so the
    /// on-disk journal always lists a superset of the group's landed
    /// members.
    fn note_intent_member(&self, member: &str) {
        let mut table = self.intents_table();
        let thread = std::thread::current().id();
        let Some(frame) = table.get_mut(&thread).and_then(|stack| stack.last_mut()) else {
            return;
        };
        frame.members.push(member.to_string());
        let (id, label, members) = (frame.id, frame.label.clone(), frame.members.clone());
        drop(table);
        self.write_journal(id, &label, &members);
    }

    fn journal_path(&self, id: u64) -> PathBuf {
        self.dir
            .join(format!("intent-{}-{id}.intent", std::process::id()))
    }

    fn write_journal(&self, id: u64, label: &str, members: &[String]) {
        let tmp = self.dir.join(format!(
            "intent-{}-{id}.intent.tmp.{}",
            std::process::id(),
            self.next_tmp.fetch_add(1, Ordering::Relaxed)
        ));
        let mut doc = format!("# {label}\n");
        for m in members {
            doc.push_str(m);
            doc.push('\n');
        }
        if std::fs::write(&tmp, doc)
            .and_then(|_| std::fs::rename(&tmp, self.journal_path(id)))
            .is_err()
        {
            let _ = std::fs::remove_file(&tmp);
        }
    }

    /// Deregister intent `id` from `thread`'s stack; remove its journal
    /// only on commit (an uncommitted journal is exactly what recovery
    /// keys on, so the abandon path must leave the disk untouched).
    fn finish_intent(&self, thread: ThreadId, id: u64, committed: bool) {
        let mut table = self.intents_table();
        if let Some(stack) = table.get_mut(&thread) {
            stack.retain(|f| f.id != id);
            if stack.is_empty() {
                table.remove(&thread);
            }
        }
        drop(table);
        if committed {
            let _ = std::fs::remove_file(self.journal_path(id));
        }
    }

    /// The boot-time recovery pass ([`ArtifactStore::open`]): discard
    /// every torn intent group (journal present = never committed — delete
    /// the listed members, then the journal) and sweep every orphaned temp
    /// file, regardless of the process id baked into the names — recovery
    /// assumes it is the only writer at open time (see the module docs),
    /// which is also what lets a test reopen a store this same process
    /// "crashed".
    fn recover(&self) -> RecoveryReport {
        let mut report = RecoveryReport::default();
        let Ok(rd) = std::fs::read_dir(&self.dir) else {
            return report;
        };
        let mut journals: Vec<PathBuf> = Vec::new();
        let mut orphans: Vec<PathBuf> = Vec::new();
        for entry in rd.flatten() {
            let path = entry.path();
            let Some(name) = path.file_name().and_then(|n| n.to_str()) else {
                continue;
            };
            if name.starts_with("intent-") && name.ends_with(".intent") {
                journals.push(path);
            } else if name.contains(".tmp.") {
                orphans.push(path);
            }
        }
        for journal in journals {
            if let Ok(doc) = std::fs::read_to_string(&journal) {
                for line in doc.lines() {
                    // Member lines are bare file names this store wrote;
                    // refuse anything path-like so a corrupted journal
                    // can never direct deletion outside the directory.
                    if line.is_empty()
                        || line.starts_with('#')
                        || line.contains('/')
                        || line.contains('\\')
                    {
                        continue;
                    }
                    if std::fs::remove_file(self.dir.join(line)).is_ok() {
                        report.members_discarded += 1;
                    }
                }
            }
            if std::fs::remove_file(&journal).is_ok() {
                report.groups_discarded += 1;
            }
        }
        for orphan in orphans {
            if std::fs::remove_file(&orphan).is_ok() {
                report.orphans_swept += 1;
            }
        }
        report
    }

    /// Whether a file for this artifact exists (without validating it).
    pub fn contains(&self, ns: Namespace, key: u64) -> bool {
        self.path_of(ns, key).exists()
    }

    /// [`ArtifactStore::contains`] for a scoped artifact.
    pub fn contains_scoped(&self, ns: Namespace, scope: &str, key: u64) -> bool {
        self.scoped_path(ns, scope, key).exists()
    }

    /// Remove one artifact. Returns whether a file was deleted.
    pub fn remove(&self, ns: Namespace, key: u64) -> bool {
        std::fs::remove_file(self.path_of(ns, key)).is_ok()
    }

    /// [`ArtifactStore::remove`] for a scoped artifact. Returns whether a
    /// file was deleted.
    pub fn remove_scoped(&self, ns: Namespace, scope: &str, key: u64) -> bool {
        std::fs::remove_file(self.scoped_path(ns, scope, key)).is_ok()
    }

    /// Remove every artifact in one namespace (scoped and unscoped).
    pub fn clear_namespace(&self, ns: Namespace) {
        let unscoped = format!("{}-", ns.tag());
        let scoped = format!("{}~", ns.tag());
        for (path, _, _) in self.scan() {
            let name = path.file_name().and_then(|n| n.to_str()).unwrap_or("");
            if name.starts_with(&unscoped) || name.starts_with(&scoped) {
                let _ = std::fs::remove_file(&path);
            }
        }
    }

    /// Remove every artifact of one scope within a namespace.
    pub fn clear_scope(&self, ns: Namespace, scope: &str) {
        let prefix = format!("{}~{}-", ns.tag(), sanitize_scope(scope));
        for (path, _, _) in self.scan() {
            let name = path.file_name().and_then(|n| n.to_str()).unwrap_or("");
            if name.starts_with(&prefix) {
                let _ = std::fs::remove_file(&path);
            }
        }
    }

    /// Total bytes of one scope's artifacts within a namespace.
    pub fn bytes_in_scope(&self, ns: Namespace, scope: &str) -> u64 {
        let prefix = format!("{}~{}-", ns.tag(), sanitize_scope(scope));
        self.scan()
            .iter()
            .filter(|(path, _, _)| {
                path.file_name()
                    .and_then(|n| n.to_str())
                    .is_some_and(|n| n.starts_with(&prefix))
            })
            .map(|(_, b, _)| *b)
            .sum()
    }

    /// Enumerate the keys of every artifact in one scope of a namespace,
    /// parsed from the file names (no payloads are read or validated —
    /// callers [`ArtifactStore::get_scoped`] the keys they care about,
    /// which is where validation lives). Sorted ascending so enumeration
    /// order is deterministic across platforms and directory layouts.
    /// This is what makes the scoped file-name scheme a poor man's index:
    /// the fleet's nearest-profile lookup lists every device's plan for a
    /// model without maintaining a separate manifest.
    pub fn keys_in_scope(&self, ns: Namespace, scope: &str) -> Vec<u64> {
        let prefix = format!("{}~{}-", ns.tag(), sanitize_scope(scope));
        let mut keys: Vec<u64> = self
            .scan()
            .iter()
            .filter_map(|(path, _, _)| {
                let name = path.file_name().and_then(|n| n.to_str())?;
                if !name.starts_with(&prefix) {
                    return None;
                }
                name.strip_suffix(".art")
                    .and_then(|stem| stem.rsplit('-').next())
                    .and_then(|hex| u64::from_str_radix(hex, 16).ok())
            })
            .collect();
        keys.sort_unstable();
        keys.dedup();
        keys
    }

    /// All `.art` files: (path, bytes, mtime).
    fn scan(&self) -> Vec<(PathBuf, u64, SystemTime)> {
        let Ok(rd) = std::fs::read_dir(&self.dir) else {
            return Vec::new();
        };
        let mut out = Vec::new();
        for entry in rd.flatten() {
            let path = entry.path();
            if path.extension().and_then(|e| e.to_str()) != Some("art") {
                continue;
            }
            if let Ok(meta) = entry.metadata() {
                let mtime = meta.modified().unwrap_or(SystemTime::UNIX_EPOCH);
                out.push((path, meta.len(), mtime));
            }
        }
        out
    }

    /// Total bytes of artifact files currently in the directory.
    pub fn bytes_used(&self) -> u64 {
        self.scan().iter().map(|(_, b, _)| *b).sum()
    }

    /// Number of artifact files currently in the directory.
    pub fn len(&self) -> usize {
        self.scan().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// One eviction sweep: measure the directory exactly, evict LRU files
    /// until the cap fits, and re-seed the running estimate with the exact
    /// result (correcting any drift from concurrent writers).
    fn evict_to_cap(&self) {
        let Some(cap) = self.cap_bytes else { return };
        let mut files = self.scan();
        let mut total: u64 = files.iter().map(|(_, b, _)| *b).sum();
        if total > cap {
            // Oldest modification time first = least recently used first
            // (validated reads re-stamp the magic, refreshing mtime).
            files.sort_by_key(|(_, _, mtime)| *mtime);
            let n = files.len();
            for (i, (path, bytes, _)) in files.into_iter().enumerate() {
                if total <= cap || i + 1 == n {
                    // Always keep the newest artifact, even over cap.
                    break;
                }
                if std::fs::remove_file(&path).is_ok() {
                    // Simulated process death in the evictor's window: the
                    // file is already unlinked, but no byte accounting has
                    // been updated and the sweep never finishes. Safe by
                    // construction — every counter a reopen consults is
                    // re-measured from the directory, which is why the
                    // draw sits exactly here (the crash test pins that).
                    if let Some(f) = self.faults.get() {
                        let (call, kind) = f.draw_at(FaultSite::StoreEvict);
                        if kind == Some(FaultKind::Crash) {
                            crash_now(FaultSite::StoreEvict, call);
                        }
                    }
                    total = total.saturating_sub(bytes);
                    self.evictions.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
        self.approx_used.store(total, Ordering::Relaxed);
    }

    /// Age-based garbage collection of unaddressed artifacts: remove
    /// every artifact whose last touch (write *or* validated read — both
    /// refresh the file's mtime) is older than `max_age`. Content-addressed keys mean
    /// artifacts for updated models are never overwritten — they simply
    /// stop being addressed — so *uncapped* stores accumulate them until
    /// something sweeps; this is that sweep (the `repro store gc` path).
    /// The newest artifact of each namespace is always kept, even when
    /// stale — mirroring the size cap's newest-file guarantee, a gc that
    /// could empty a live namespace would only force pointless
    /// recomputes. Foreign `.art` files whose name matches no known
    /// namespace are never touched.
    pub fn gc(&self, max_age: std::time::Duration) -> GcResult {
        let mut out = GcResult::default();
        let Some(cutoff) = SystemTime::now().checked_sub(max_age) else {
            out.kept = self.scan().len();
            return out;
        };
        let files: Vec<(PathBuf, u64, SystemTime, Option<Namespace>)> = self
            .scan()
            .into_iter()
            .map(|(path, bytes, mtime)| {
                let ns = path
                    .file_name()
                    .and_then(|n| n.to_str())
                    .and_then(namespace_of_file);
                (path, bytes, mtime, ns)
            })
            .collect();
        // Newest mtime per namespace; ties all count as newest (kept).
        let mut newest: [Option<SystemTime>; 4] = [None; 4];
        for (_, _, mtime, ns) in &files {
            if let Some(ns) = ns {
                let slot = &mut newest[ns.id() as usize];
                match slot {
                    Some(t) if *t >= *mtime => {}
                    _ => *slot = Some(*mtime),
                }
            }
        }
        for (path, bytes, mtime, ns) in files {
            let stale = mtime <= cutoff;
            let is_newest = match ns {
                Some(ns) => newest[ns.id() as usize] == Some(mtime),
                None => true, // foreign file: never ours to delete
            };
            if stale && !is_newest && std::fs::remove_file(&path).is_ok() {
                out.removed += 1;
                out.bytes_freed += bytes;
            } else {
                out.kept += 1;
            }
        }
        if out.removed > 0 {
            // Keep a capped store's next-sweep trigger honest.
            self.approx_used
                .store(self.bytes_used(), Ordering::Relaxed);
        }
        out
    }

    /// Read-only integrity audit of every artifact file in the directory:
    /// parse each file name, re-run the full header + checksum validation,
    /// and report the tally. Unlike [`ArtifactStore::get`], `fsck` never
    /// deletes, never touches mtimes, never moves counters, and bypasses
    /// any armed fault injection — it is the chaos suite's ground truth
    /// that no injected corruption survived a run (`corrupt == 0` after
    /// healing). Files whose name matches no known namespace are counted
    /// `foreign` and otherwise ignored, like everywhere else in the store.
    pub fn fsck(&self) -> FsckReport {
        let expected = self.registry_stamp.load(Ordering::Relaxed);
        let mut out = FsckReport::default();
        for (path, _, _) in self.scan() {
            out.scanned += 1;
            let name = path.file_name().and_then(|n| n.to_str()).unwrap_or("");
            let parsed = namespace_of_file(name).zip(
                name.strip_suffix(".art")
                    .and_then(|stem| stem.rsplit('-').next())
                    .and_then(|hex| u64::from_str_radix(hex, 16).ok()),
            );
            let Some((ns, key)) = parsed else {
                out.foreign += 1;
                continue;
            };
            let bytes = std::fs::read(&path).unwrap_or_default();
            match classify_bytes(&bytes, ns, key, expected) {
                Image::Current(_) => out.valid += 1,
                Image::Stale => out.stale += 1,
                Image::Legacy(_) => out.legacy += 1,
                Image::Bad => out.corrupt += 1,
            }
        }
        // Non-artifact debris an un-recovered directory can hold: orphan
        // temp files and uncommitted intent journals. Counted separately
        // from `scanned` (which has always meant `.art` files) so the
        // pre-existing tallies keep their meaning.
        if let Ok(rd) = std::fs::read_dir(&self.dir) {
            for entry in rd.flatten() {
                let path = entry.path();
                let Some(name) = path.file_name().and_then(|n| n.to_str()) else {
                    continue;
                };
                if name.starts_with("intent-") && name.ends_with(".intent") {
                    out.intents += 1;
                } else if name.contains(".tmp.") {
                    out.orphans += 1;
                }
            }
        }
        out
    }

    /// Counter snapshot (`bytes_used` is measured live from the
    /// directory, so it reflects other processes' writes and evictions).
    pub fn stats(&self) -> StoreStats {
        StoreStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            stale: self.stale.load(Ordering::Relaxed),
            migrated: self.migrated.load(Ordering::Relaxed),
            bytes_used: self.bytes_used(),
            bytes_written: self.bytes_written.load(Ordering::Relaxed),
        }
    }
}

/// One frame of a thread's intent stack: the journal id, its label, and
/// the member file names recorded so far (mirrors the on-disk journal).
#[derive(Debug)]
struct IntentFrame {
    id: u64,
    label: String,
    members: Vec<String>,
}

/// Guard for one open write intent ([`ArtifactStore::begin_intent`]).
/// [`WriteIntent::commit`] seals the group (removes the journal; the
/// members are now individually owned by the store). Dropping without
/// commit *abandons* the group: the in-memory registration is popped so
/// later puts on this thread are no longer captured, but the journal
/// stays on disk — deliberately, because an abandoned group is exactly as
/// suspect as a crashed one, and because a simulated crash unwinds
/// through this `Drop` and must not let it repair the disk.
#[derive(Debug)]
pub struct WriteIntent<'a> {
    store: &'a ArtifactStore,
    thread: ThreadId,
    id: u64,
    committed: bool,
}

impl WriteIntent<'_> {
    /// Seal the group: every member is fully written and mutually
    /// consistent, so the journal is removed and a crash from here on
    /// cannot discard them.
    pub fn commit(mut self) {
        self.committed = true;
        self.store.finish_intent(self.thread, self.id, true);
    }

    /// The journal id, exposed for tests that assert on journal files.
    pub fn id(&self) -> u64 {
        self.id
    }
}

impl Drop for WriteIntent<'_> {
    fn drop(&mut self) {
        if !self.committed {
            self.store.finish_intent(self.thread, self.id, false);
        }
    }
}

/// What one boot-time recovery pass did ([`ArtifactStore::recovery`]).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RecoveryReport {
    /// Uncommitted intent journals found (each = one discarded group).
    pub groups_discarded: usize,
    /// Member artifact files deleted while discarding those groups.
    pub members_discarded: usize,
    /// Orphaned temp files (`*.tmp.*`) swept.
    pub orphans_swept: usize,
}

impl RecoveryReport {
    /// Whether the pass found nothing to repair (a clean shutdown).
    pub fn is_clean(&self) -> bool {
        *self == RecoveryReport::default()
    }
}

/// Result of one [`ArtifactStore::fsck`] audit.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FsckReport {
    /// `.art` files examined.
    pub scanned: usize,
    /// Files that passed full header + checksum validation under the
    /// current format and registry stamp.
    pub valid: usize,
    /// Files that failed validation (torn, bit-rotted, truncated).
    pub corrupt: usize,
    /// Files whose name matches no known namespace (never ours to judge).
    pub foreign: usize,
    /// Well-formed v2 artifacts stamped by a *different* kernel-registry
    /// generation — valid bytes, untrustworthy decisions; the read path
    /// invalidates them on first touch.
    pub stale: usize,
    /// Well-formed format-v1 artifacts awaiting read-path migration (or
    /// invalidation, for weights).
    pub legacy: usize,
    /// Orphaned temp files (`*.tmp.*`): a write died between temp-write
    /// and rename. Swept by the next [`ArtifactStore::open`].
    pub orphans: usize,
    /// Uncommitted intent journals: each marks a torn multi-artifact
    /// group the next [`ArtifactStore::open`] will discard.
    pub intents: usize,
}

/// Classification of one artifact image (header + payload) against its
/// expected namespace, key, and registry stamp. Shared by the read path
/// (which enacts the verdict: serve / migrate / invalidate / delete) and
/// [`ArtifactStore::fsck`] (which only tallies).
#[derive(Debug)]
enum Image<'a> {
    /// Well-formed v2 under the expected registry stamp: serve it.
    Current(&'a [u8]),
    /// Well-formed v2 under a different registry stamp.
    Stale,
    /// Well-formed under the 40-byte v1 header (no stamp).
    Legacy(&'a [u8]),
    /// Malformed: foreign, truncated, torn, or bit-rotted.
    Bad,
}

fn classify_bytes(bytes: &[u8], ns: Namespace, key: u64, expected_stamp: u64) -> Image<'_> {
    let field = |header: &[u8], a: usize, b: usize| -> u64 {
        let mut buf = [0u8; 8];
        buf[..b - a].copy_from_slice(&header[a..b]);
        u64::from_le_bytes(buf)
    };
    // The version field keeps the two layouts mutually exclusive: the
    // same bytes can never parse as both v1 and v2.
    if bytes.len() >= HEADER_LEN {
        let (header, payload) = bytes.split_at(HEADER_LEN);
        if header[0..8] == MAGIC
            && field(header, 8, 12) as u32 == FORMAT_VERSION
            && field(header, 12, 16) as u32 == ns.id()
            && field(header, 16, 24) == key
            && field(header, 24, 32) == payload.len() as u64
            && field(header, 32, 40) == fnv1a(payload)
        {
            return if field(header, 40, 48) == expected_stamp {
                Image::Current(payload)
            } else {
                Image::Stale
            };
        }
    }
    if bytes.len() >= LEGACY_V1_HEADER_LEN {
        let (header, payload) = bytes.split_at(LEGACY_V1_HEADER_LEN);
        if header[0..8] == MAGIC
            && field(header, 8, 12) as u32 == LEGACY_V1_VERSION
            && field(header, 12, 16) as u32 == ns.id()
            && field(header, 16, 24) == key
            && field(header, 24, 32) == payload.len() as u64
            && field(header, 32, 40) == fnv1a(payload)
        {
            return Image::Legacy(payload);
        }
    }
    Image::Bad
}

/// Injected bit rot: flip the last byte of the file in place (payload
/// when one exists, else the checksum field) so the next validation must
/// reject it. Best-effort — a missing file corrupts nothing.
fn corrupt_in_place(path: &Path) {
    let Ok(mut f) = std::fs::OpenOptions::new().read(true).write(true).open(path) else {
        return;
    };
    let Ok(len) = f.metadata().map(|m| m.len()) else {
        return;
    };
    if len == 0 {
        return;
    }
    let pos = len - 1;
    let mut b = [0u8; 1];
    if f.seek(SeekFrom::Start(pos)).is_ok() && f.read_exact(&mut b).is_ok() {
        b[0] ^= 0x01;
        let _ = f
            .seek(SeekFrom::Start(pos))
            .and_then(|_| f.write_all(&b));
    }
}

/// Result of one [`ArtifactStore::gc`] sweep.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct GcResult {
    /// Artifacts removed.
    pub removed: usize,
    /// Total bytes of the removed artifacts.
    pub bytes_freed: u64,
    /// Artifacts kept (fresh, newest of their namespace, or foreign).
    pub kept: usize,
}

/// Parse the namespace a store file belongs to from its name
/// (`<ns>-<key>.art` or `<ns>~<scope>-<key>.art`). `None` for foreign
/// files.
fn namespace_of_file(name: &str) -> Option<Namespace> {
    for ns in [
        Namespace::Plan,
        Namespace::CalibratedPlan,
        Namespace::Weights,
        Namespace::FleetPlan,
    ] {
        let tag = ns.tag();
        if name.len() > tag.len()
            && name.starts_with(tag)
            && matches!(name.as_bytes()[tag.len()], b'-' | b'~')
        {
            return Some(ns);
        }
    }
    None
}

fn fnv1a_continue(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Encode a scope for use between the `~` and `-` file-name separators.
/// ASCII alphanumerics pass through; every other byte becomes `_xx`
/// (two hex digits). The encoding is injective — a literal alphanumeric
/// never starts with `_` and every escape is exactly three characters —
/// so distinct scopes (e.g. `net-a` vs `net_a`) can never share a file
/// prefix, which the per-scope clear/size guarantees rely on.
fn sanitize_scope(scope: &str) -> String {
    let mut out = String::with_capacity(scope.len());
    for b in scope.bytes() {
        if b.is_ascii_alphanumeric() {
            out.push(b as char);
        } else {
            out.push_str(&format!("_{b:02x}"));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn temp_store(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!(
            "nnv12-artifact-{tag}-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ))
    }

    #[test]
    fn put_get_roundtrip_and_counters() {
        let dir = temp_store("roundtrip");
        let _ = std::fs::remove_dir_all(&dir);
        let s = ArtifactStore::open(&dir).unwrap();
        let payload = b"plan payload".to_vec();
        assert!(s.get(Namespace::Plan, 7).is_none());
        s.put(Namespace::Plan, 7, &payload).unwrap();
        assert!(s.contains(Namespace::Plan, 7));
        assert_eq!(s.get(Namespace::Plan, 7).unwrap(), payload);
        // Namespaces are part of the address.
        assert!(s.get(Namespace::Weights, 7).is_none());
        let st = s.stats();
        assert_eq!((st.hits, st.misses), (1, 2));
        assert_eq!(st.bytes_used, (HEADER_LEN + payload.len()) as u64);
        assert_eq!(st.bytes_written, st.bytes_used);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_truncated_and_foreign_files_rejected_and_healed() {
        let dir = temp_store("corrupt");
        let _ = std::fs::remove_dir_all(&dir);
        let s = ArtifactStore::open(&dir).unwrap();
        let payload: Vec<u8> = (0u8..=255).collect();

        // Bit flip in the payload.
        s.put(Namespace::Weights, 1, &payload).unwrap();
        let path = s.path_of(Namespace::Weights, 1);
        let mut bytes = std::fs::read(&path).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 0x01;
        std::fs::write(&path, &bytes).unwrap();
        assert!(s.get(Namespace::Weights, 1).is_none());
        assert!(!path.exists(), "rejected artifact must be deleted");

        // Truncation inside the header.
        s.put(Namespace::Weights, 2, &payload).unwrap();
        let path2 = s.path_of(Namespace::Weights, 2);
        let bytes = std::fs::read(&path2).unwrap();
        std::fs::write(&path2, &bytes[..HEADER_LEN / 2]).unwrap();
        assert!(s.get(Namespace::Weights, 2).is_none());

        // Foreign file under the right name.
        std::fs::write(s.path_of(Namespace::Weights, 3), b"not an artifact").unwrap();
        assert!(s.get(Namespace::Weights, 3).is_none());

        assert_eq!(s.stats().rejected, 3);
        // A rewrite heals: the store serves the new artifact.
        s.put(Namespace::Weights, 1, &payload).unwrap();
        assert_eq!(s.get(Namespace::Weights, 1).unwrap(), payload);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn size_cap_evicts_lru_and_keeps_touched_entries() {
        let dir = temp_store("evict");
        let _ = std::fs::remove_dir_all(&dir);
        let entry_bytes = (HEADER_LEN + 100) as u64;
        // Cap fits two entries.
        let s = ArtifactStore::with_cap(&dir, 2 * entry_bytes).unwrap();
        let payload = vec![0xabu8; 100];
        s.put(Namespace::Plan, 1, &payload).unwrap();
        std::thread::sleep(std::time::Duration::from_millis(20));
        s.put(Namespace::Plan, 2, &payload).unwrap();
        std::thread::sleep(std::time::Duration::from_millis(20));
        // Touch 1: it becomes most recently used.
        assert!(s.get(Namespace::Plan, 1).is_some());
        std::thread::sleep(std::time::Duration::from_millis(20));
        // Third entry exceeds the cap: the LRU entry (2, untouched) goes.
        s.put(Namespace::Plan, 3, &payload).unwrap();
        assert!(s.contains(Namespace::Plan, 1), "touched entry must survive");
        assert!(!s.contains(Namespace::Plan, 2), "LRU entry must be evicted");
        assert!(s.contains(Namespace::Plan, 3), "newest entry must survive");
        assert_eq!(s.stats().evictions, 1);
        assert!(s.bytes_used() <= 2 * entry_bytes);

        // A single artifact larger than the whole cap is still kept.
        let big = vec![0u8; 3 * entry_bytes as usize];
        s.put(Namespace::Plan, 4, &big).unwrap();
        assert!(s.contains(Namespace::Plan, 4));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn scope_encoding_is_injective() {
        // `net-a` vs `net_a` vs `net a` must not share a file prefix.
        let dir = temp_store("scope-enc");
        let _ = std::fs::remove_dir_all(&dir);
        let s = ArtifactStore::open(&dir).unwrap();
        let payload = vec![1u8; 8];
        for scope in ["net-a", "net_a", "net a", "net-a-"] {
            s.put_scoped(Namespace::Weights, scope, 5, &payload).unwrap();
        }
        assert_eq!(s.len(), 4, "distinct scopes must produce distinct files");
        s.clear_scope(Namespace::Weights, "net-a");
        assert!(!s.contains_scoped(Namespace::Weights, "net-a", 5));
        assert!(s.contains_scoped(Namespace::Weights, "net_a", 5));
        assert!(s.contains_scoped(Namespace::Weights, "net a", 5));
        assert!(s.contains_scoped(Namespace::Weights, "net-a-", 5));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn gc_drops_stale_artifacts_but_keeps_newest_per_namespace() {
        let dir = temp_store("gc");
        let _ = std::fs::remove_dir_all(&dir);
        let s = ArtifactStore::open(&dir).unwrap();
        let payload = vec![9u8; 48];
        s.put(Namespace::Plan, 1, &payload).unwrap();
        std::thread::sleep(std::time::Duration::from_millis(25));
        s.put(Namespace::Plan, 2, &payload).unwrap();
        std::thread::sleep(std::time::Duration::from_millis(25));
        s.put_scoped(Namespace::Weights, "m", 1, &payload).unwrap();
        std::thread::sleep(std::time::Duration::from_millis(25));
        s.put(Namespace::Plan, 3, &payload).unwrap();
        std::thread::sleep(std::time::Duration::from_millis(25));

        // A generous age: nothing qualifies, even in an uncapped store.
        let r = s.gc(std::time::Duration::from_secs(24 * 3600));
        assert_eq!((r.removed, r.bytes_freed, r.kept), (0, 0, 4), "{r:?}");

        // Age zero: everything is "stale", but the newest artifact of
        // each namespace survives — plan 3, and the sole weights entry
        // (scoped files are namespace members too).
        let r = s.gc(std::time::Duration::ZERO);
        assert_eq!(r.removed, 2, "{r:?}");
        assert_eq!(r.kept, 2, "{r:?}");
        assert_eq!(r.bytes_freed, 2 * (HEADER_LEN + payload.len()) as u64);
        assert!(!s.contains(Namespace::Plan, 1));
        assert!(!s.contains(Namespace::Plan, 2));
        assert!(s.contains(Namespace::Plan, 3), "newest plan must survive");
        assert!(
            s.contains_scoped(Namespace::Weights, "m", 1),
            "the only weights artifact is its namespace's newest"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn gc_never_touches_foreign_files() {
        let dir = temp_store("gc-foreign");
        let _ = std::fs::remove_dir_all(&dir);
        let s = ArtifactStore::open(&dir).unwrap();
        std::fs::write(dir.join("unrelated-0000000000000001.art"), b"not ours").unwrap();
        s.put(Namespace::Plan, 1, b"p").unwrap();
        std::thread::sleep(std::time::Duration::from_millis(25));
        let r = s.gc(std::time::Duration::ZERO);
        assert_eq!(r.removed, 0, "{r:?}");
        assert!(dir.join("unrelated-0000000000000001.art").exists());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn keys_in_scope_enumerates_only_that_scope() {
        let dir = temp_store("keys-scope");
        let _ = std::fs::remove_dir_all(&dir);
        let s = ArtifactStore::open(&dir).unwrap();
        let payload = b"fleet plan".to_vec();
        s.put_scoped(Namespace::FleetPlan, "resnet50", 0xb, &payload).unwrap();
        s.put_scoped(Namespace::FleetPlan, "resnet50", 0xa, &payload).unwrap();
        // Same key twice is one file (content-addressed last-wins).
        s.put_scoped(Namespace::FleetPlan, "resnet50", 0xa, &payload).unwrap();
        // Other scopes / namespaces / unscoped files never leak in.
        s.put_scoped(Namespace::FleetPlan, "squeezenet", 0xc, &payload).unwrap();
        s.put_scoped(Namespace::Weights, "resnet50", 0xd, &payload).unwrap();
        s.put(Namespace::FleetPlan, 0xe, &payload).unwrap();
        assert_eq!(s.keys_in_scope(Namespace::FleetPlan, "resnet50"), vec![0xa, 0xb]);
        assert_eq!(s.keys_in_scope(Namespace::FleetPlan, "squeezenet"), vec![0xc]);
        assert!(s.keys_in_scope(Namespace::FleetPlan, "absent").is_empty());
        // Every enumerated key round-trips through the validated read.
        for key in s.keys_in_scope(Namespace::FleetPlan, "resnet50") {
            assert_eq!(s.get_scoped(Namespace::FleetPlan, "resnet50", key).unwrap(), payload);
        }
        // The new namespace plays by the store's rules: fsck sees no
        // foreign files, and clear_namespace drops scoped + unscoped.
        let audit = s.fsck();
        assert_eq!((audit.corrupt, audit.foreign), (0, 0), "{audit:?}");
        s.clear_namespace(Namespace::FleetPlan);
        assert!(s.keys_in_scope(Namespace::FleetPlan, "resnet50").is_empty());
        assert!(!s.contains(Namespace::FleetPlan, 0xe));
        assert!(s.contains_scoped(Namespace::Weights, "resnet50", 0xd));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn key_of_separates_parts() {
        assert_ne!(
            ArtifactStore::key_of(&["ab", "c"]),
            ArtifactStore::key_of(&["a", "bc"])
        );
        assert_eq!(
            ArtifactStore::key_of(&["model", "L3", "winograd"]),
            ArtifactStore::key_of(&["model", "L3", "winograd"])
        );
    }

    #[test]
    fn scoped_artifacts_enumerate_and_clear_per_scope() {
        let dir = temp_store("scoped");
        let _ = std::fs::remove_dir_all(&dir);
        let s = ArtifactStore::open(&dir).unwrap();
        let payload = vec![7u8; 64];
        s.put_scoped(Namespace::Weights, "model-a", 1, &payload).unwrap();
        s.put_scoped(Namespace::Weights, "model-a", 2, &payload).unwrap();
        s.put_scoped(Namespace::Weights, "model-b", 1, &payload).unwrap();
        s.put(Namespace::Plan, 9, &payload).unwrap();

        assert_eq!(s.get_scoped(Namespace::Weights, "model-a", 1).unwrap(), payload);
        assert!(s.contains_scoped(Namespace::Weights, "model-b", 1));
        // Same key under different scopes addresses different files.
        assert!(!s.contains(Namespace::Weights, 1));
        let entry = (HEADER_LEN + payload.len()) as u64;
        assert_eq!(s.bytes_in_scope(Namespace::Weights, "model-a"), 2 * entry);
        assert_eq!(s.bytes_in_scope(Namespace::Weights, "model-b"), entry);

        // Clearing one scope leaves the other scope and other namespaces.
        s.clear_scope(Namespace::Weights, "model-a");
        assert!(!s.contains_scoped(Namespace::Weights, "model-a", 1));
        assert!(s.contains_scoped(Namespace::Weights, "model-b", 1));
        assert!(s.contains(Namespace::Plan, 9));
        // clear_namespace takes scoped files too.
        s.clear_namespace(Namespace::Weights);
        assert!(!s.contains_scoped(Namespace::Weights, "model-b", 1));
        assert!(s.contains(Namespace::Plan, 9));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn fresh_handle_sees_prior_process_artifacts() {
        let dir = temp_store("crossproc");
        let _ = std::fs::remove_dir_all(&dir);
        let payload = b"persisted".to_vec();
        ArtifactStore::open(&dir)
            .unwrap()
            .put(Namespace::CalibratedPlan, 42, &payload)
            .unwrap();
        // A fresh handle (≈ a fresh process) serves the artifact.
        let b = ArtifactStore::open(&dir).unwrap();
        assert_eq!(b.get(Namespace::CalibratedPlan, 42).unwrap(), payload);
        assert_eq!(b.stats().hits, 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn fsck_tallies_valid_corrupt_and_foreign_without_touching_them() {
        let dir = temp_store("fsck");
        let _ = std::fs::remove_dir_all(&dir);
        let s = ArtifactStore::open(&dir).unwrap();
        let payload = vec![3u8; 64];
        s.put(Namespace::Plan, 1, &payload).unwrap();
        s.put_scoped(Namespace::Weights, "m", 2, &payload).unwrap();
        // Hand-corrupt one artifact and drop one foreign file.
        s.put(Namespace::Plan, 9, &payload).unwrap();
        let bad = s.path_of(Namespace::Plan, 9);
        let mut bytes = std::fs::read(&bad).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 0xFF;
        std::fs::write(&bad, &bytes).unwrap();
        std::fs::write(dir.join("unrelated-0000000000000001.art"), b"not ours").unwrap();

        let r = s.fsck();
        assert_eq!(
            (r.scanned, r.valid, r.corrupt, r.foreign),
            (4, 2, 1, 1),
            "{r:?}"
        );
        // fsck is read-only: the corrupt file survives, counters are
        // untouched, and a real read still rejects + heals it.
        assert!(bad.exists(), "fsck must never delete");
        assert_eq!(s.stats().rejected, 0);
        assert!(s.get(Namespace::Plan, 9).is_none());
        assert_eq!(s.stats().rejected, 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn injected_torn_write_is_rejected_then_healed() {
        use crate::faults::{FaultKind, FaultPlan, FaultSite, Trigger};
        let dir = temp_store("torn");
        let _ = std::fs::remove_dir_all(&dir);
        let s = ArtifactStore::open(&dir).unwrap();
        s.inject_faults(std::sync::Arc::new(FaultPlan::new(2).with_rule(
            FaultSite::StoreWrite,
            FaultKind::TornWrite,
            Trigger::At(0),
        )));
        let payload: Vec<u8> = (0u8..=255).collect();
        // The torn write "succeeds" and renames into place...
        s.put(Namespace::Plan, 5, &payload).unwrap();
        assert!(s.contains(Namespace::Plan, 5));
        assert_eq!(s.fsck().corrupt, 1, "torn file must fail validation");
        // ...but the next read catches it, deletes it, and the re-put
        // (fault schedule exhausted) heals the store.
        assert!(s.get(Namespace::Plan, 5).is_none());
        assert_eq!(s.stats().rejected, 1);
        s.put(Namespace::Plan, 5, &payload).unwrap();
        assert_eq!(s.get(Namespace::Plan, 5).unwrap(), payload);
        assert_eq!(s.fsck().corrupt, 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn injected_read_io_error_is_a_miss_not_a_rejection() {
        // The PR-3 contract, now directly testable: a *transient* read
        // failure (EIO, a vanished mount) is a cache miss — the caller
        // recomputes — and must not delete the artifact, which is intact
        // and serves once the transient clears.
        use crate::faults::{FaultKind, FaultPlan, FaultSite, Trigger};
        let dir = temp_store("eio");
        let _ = std::fs::remove_dir_all(&dir);
        let s = ArtifactStore::open(&dir).unwrap();
        let payload = vec![5u8; 80];
        s.put(Namespace::Plan, 4, &payload).unwrap();
        s.inject_faults(std::sync::Arc::new(FaultPlan::new(1).with_rule(
            FaultSite::StoreRead,
            FaultKind::IoError,
            Trigger::At(0),
        )));
        assert!(s.get(Namespace::Plan, 4).is_none(), "transient error is a miss");
        assert_eq!(s.stats().rejected, 0, "a transient error is not corruption");
        assert!(s.contains(Namespace::Plan, 4), "the artifact must survive");
        // The transient cleared (fault schedule exhausted): same handle,
        // same key, served intact.
        assert_eq!(s.get(Namespace::Plan, 4).unwrap(), payload);
        assert_eq!(s.stats().hits, 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// A format-v1 artifact image, byte-for-byte what PR 3..9 builds
    /// wrote: 40-byte header, no registry stamp.
    fn v1_image(ns: Namespace, key: u64, payload: &[u8]) -> Vec<u8> {
        let mut h = vec![0u8; LEGACY_V1_HEADER_LEN];
        h[0..8].copy_from_slice(&MAGIC);
        h[8..12].copy_from_slice(&LEGACY_V1_VERSION.to_le_bytes());
        h[12..16].copy_from_slice(&ns.id().to_le_bytes());
        h[16..24].copy_from_slice(&key.to_le_bytes());
        h[24..32].copy_from_slice(&(payload.len() as u64).to_le_bytes());
        h[32..40].copy_from_slice(&fnv1a(payload).to_le_bytes());
        h.extend_from_slice(payload);
        h
    }

    #[test]
    fn v1_plan_artifacts_migrate_in_place_and_serve() {
        let dir = temp_store("v1-migrate");
        let _ = std::fs::remove_dir_all(&dir);
        let s = ArtifactStore::open(&dir).unwrap();
        let payload = br#"{"plan":"doc"}"#.to_vec();
        std::fs::write(s.path_of(Namespace::Plan, 3), v1_image(Namespace::Plan, 3, &payload))
            .unwrap();
        assert_eq!(s.fsck().legacy, 1);
        // First read: served as a hit AND healed to v2 in place.
        assert_eq!(s.get(Namespace::Plan, 3).unwrap(), payload);
        let st = s.stats();
        assert_eq!((st.hits, st.misses, st.migrated, st.stale), (1, 0, 1, 0));
        let audit = s.fsck();
        assert_eq!((audit.valid, audit.legacy), (1, 0), "{audit:?}");
        // Second read: an ordinary v2 hit, no second migration.
        assert_eq!(s.get(Namespace::Plan, 3).unwrap(), payload);
        assert_eq!(s.stats().migrated, 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn v1_weights_are_invalidated_not_migrated() {
        let dir = temp_store("v1-weights");
        let _ = std::fs::remove_dir_all(&dir);
        let s = ArtifactStore::open(&dir).unwrap();
        let payload = vec![0x42u8; 64];
        std::fs::write(
            s.path_of(Namespace::Weights, 6),
            v1_image(Namespace::Weights, 6, &payload),
        )
        .unwrap();
        assert!(s.get(Namespace::Weights, 6).is_none(), "no stamp, no trust");
        let st = s.stats();
        assert_eq!((st.stale, st.misses, st.rejected), (1, 1, 0));
        assert!(!s.contains(Namespace::Weights, 6), "invalidated on first read");
        // The recompute re-stores under the current format; reads hit.
        s.put(Namespace::Weights, 6, &payload).unwrap();
        assert_eq!(s.get(Namespace::Weights, 6).unwrap(), payload);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn registry_bump_invalidates_exactly_once() {
        let dir = temp_store("registry-bump");
        let _ = std::fs::remove_dir_all(&dir);
        let payload = b"searched under the old registry".to_vec();
        {
            let s = ArtifactStore::open(&dir).unwrap();
            s.pin_registry_stamp(0xAAAA);
            s.put(Namespace::Plan, 1, &payload).unwrap();
        }
        // "Engine upgrade": a fresh handle under a different generation.
        let s = ArtifactStore::open(&dir).unwrap();
        s.pin_registry_stamp(0xBBBB);
        assert_eq!(s.fsck().stale, 1);
        assert!(s.get(Namespace::Plan, 1).is_none(), "stale must not serve");
        let st = s.stats();
        assert_eq!((st.stale, st.misses, st.hits), (1, 1, 0));
        assert!(!s.contains(Namespace::Plan, 1), "invalidated, not retried");
        // The caller recomputes and re-puts; from then on, all hits.
        s.put(Namespace::Plan, 1, &payload).unwrap();
        assert_eq!(s.get(Namespace::Plan, 1).unwrap(), payload);
        assert_eq!(s.stats().stale, 1, "exactly once");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn committed_intent_group_survives_reopen() {
        let dir = temp_store("intent-commit");
        let _ = std::fs::remove_dir_all(&dir);
        let s = ArtifactStore::open(&dir).unwrap();
        let intent = s.begin_intent("cold-start resnet50");
        s.put(Namespace::Plan, 1, b"plan").unwrap();
        s.put_scoped(Namespace::Weights, "m", 2, b"weights").unwrap();
        assert_eq!(s.fsck().intents, 1, "journal lives until commit");
        intent.commit();
        assert_eq!(s.fsck().intents, 0);
        let reopened = ArtifactStore::open(&dir).unwrap();
        assert!(reopened.recovery().unwrap().is_clean());
        assert!(reopened.contains(Namespace::Plan, 1));
        assert!(reopened.contains_scoped(Namespace::Weights, "m", 2));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn abandoned_intent_group_is_discarded_whole_on_reopen() {
        let dir = temp_store("intent-abandon");
        let _ = std::fs::remove_dir_all(&dir);
        let s = ArtifactStore::open(&dir).unwrap();
        // An artifact from *before* the group must survive the discard.
        s.put(Namespace::Plan, 9, b"old and committed").unwrap();
        {
            let _intent = s.begin_intent("cold-start that died");
            s.put(Namespace::Plan, 1, b"plan").unwrap();
            s.put(Namespace::CalibratedPlan, 2, b"calibrated").unwrap();
            // Guard dropped without commit: the in-memory frame pops, the
            // journal stays — exactly the disk state a crash leaves.
        }
        assert_eq!(s.fsck().intents, 1);
        // Puts after the abandon are NOT captured by the dead intent.
        s.put(Namespace::Plan, 8, b"later, unrelated").unwrap();
        let reopened = ArtifactStore::open(&dir).unwrap();
        let r = reopened.recovery().unwrap();
        assert_eq!((r.groups_discarded, r.members_discarded), (1, 2), "{r:?}");
        assert!(!reopened.contains(Namespace::Plan, 1), "group member discarded");
        assert!(!reopened.contains(Namespace::CalibratedPlan, 2));
        assert!(reopened.contains(Namespace::Plan, 9), "pre-group artifact kept");
        assert!(reopened.contains(Namespace::Plan, 8), "post-abandon artifact kept");
        assert_eq!(reopened.fsck().intents, 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn injected_write_error_leaves_an_orphan_and_recovery_sweeps_it() {
        use crate::faults::{FaultKind, FaultPlan, FaultSite, Trigger};
        let dir = temp_store("orphan");
        let _ = std::fs::remove_dir_all(&dir);
        let s = ArtifactStore::open(&dir).unwrap();
        s.inject_faults(std::sync::Arc::new(FaultPlan::new(5).with_rule(
            FaultSite::StoreWrite,
            FaultKind::IoError,
            Trigger::At(0),
        )));
        assert!(s.put(Namespace::Plan, 7, &vec![1u8; 64]).is_err());
        assert!(!s.contains(Namespace::Plan, 7));
        let audit = s.fsck();
        assert_eq!(audit.orphans, 1, "a mid-write EIO strands its temp file");
        assert_eq!(audit.corrupt, 0, "the orphan is not an .art file");
        let reopened = ArtifactStore::open(&dir).unwrap();
        assert_eq!(reopened.recovery().unwrap().orphans_swept, 1);
        assert_eq!(reopened.fsck().orphans, 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn crash_during_put_leaves_temp_then_recovery_cleans() {
        use crate::faults::{quiet_crash_panics, with_crash_boundary, CrashPlan, FaultSite};
        quiet_crash_panics();
        let dir = temp_store("crash-put");
        let _ = std::fs::remove_dir_all(&dir);
        let s = ArtifactStore::open(&dir).unwrap();
        let plan = CrashPlan { site: FaultSite::StoreWrite, call: 0 }.arm(11);
        s.inject_faults(std::sync::Arc::new(plan));
        let died = with_crash_boundary(|| {
            let intent = s.begin_intent("crashing cold start");
            s.put(Namespace::Plan, 4, &vec![7u8; 32]).unwrap();
            intent.commit();
        });
        assert!(died.is_err(), "the scheduled crash must fire");
        assert!(!s.contains(Namespace::Plan, 4), "rename never happened");
        let audit = ArtifactStore::at(&dir).fsck();
        assert_eq!((audit.orphans, audit.intents), (1, 1), "{audit:?}");
        let reopened = ArtifactStore::open(&dir).unwrap();
        let r = reopened.recovery().unwrap();
        assert_eq!(r.orphans_swept, 1, "{r:?}");
        assert_eq!(r.groups_discarded, 1, "{r:?}");
        let audit = reopened.fsck();
        assert_eq!((audit.corrupt, audit.orphans, audit.intents), (0, 0, 0));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn at_handles_never_recover_and_open_reports_clean() {
        let dir = temp_store("recovery-report");
        let _ = std::fs::remove_dir_all(&dir);
        assert!(ArtifactStore::at(&dir).recovery().is_none());
        let s = ArtifactStore::open(&dir).unwrap();
        assert!(s.recovery().unwrap().is_clean());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn injected_corruption_on_read_rejects_and_heals() {
        use crate::faults::{FaultKind, FaultPlan, FaultSite, Trigger};
        let dir = temp_store("bitrot");
        let _ = std::fs::remove_dir_all(&dir);
        let s = ArtifactStore::open(&dir).unwrap();
        let payload = vec![7u8; 100];
        s.put(Namespace::Weights, 8, &payload).unwrap();
        s.inject_faults(std::sync::Arc::new(FaultPlan::new(3).with_rule(
            FaultSite::StoreRead,
            FaultKind::CorruptBytes,
            Trigger::At(0),
        )));
        // Read 0: the injector flips a payload byte on disk; validation
        // must reject + delete rather than serve rotten bytes.
        assert!(s.get(Namespace::Weights, 8).is_none());
        assert_eq!(s.stats().rejected, 1);
        assert!(!s.contains(Namespace::Weights, 8));
        // Recompute-and-put heals; the next (clean) read serves.
        s.put(Namespace::Weights, 8, &payload).unwrap();
        assert_eq!(s.get(Namespace::Weights, 8).unwrap(), payload);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
