//! The simulation engine: processor-sharing contention + work stealing.

use crate::device::DeviceProfile;
use crate::sched::makespan::OpTiming;
use crate::sched::op::{OpSet, OpStage};
use crate::sched::plan::{Plan, UnitId};
use crate::sched::price::{PriceTable, Pricer};
use crate::Ms;

/// Background load on one unit (Fig. 11's 0%/25%/50% occupancy): ops on the
/// unit run at rate `1 - utilization`.
#[derive(Debug, Clone, Copy)]
pub struct BgLoad {
    pub unit: UnitId,
    pub utilization: f64,
}

/// Simulator configuration.
#[derive(Debug, Clone, Default)]
pub struct SimConfig {
    /// Enable the §3.3 workload-stealing technique ("WS" in Fig. 11).
    pub stealing: bool,
    /// Model disk/memory bandwidth interference between concurrent ops.
    /// Disabled ⇒ the simulator agrees exactly with the list-schedule
    /// evaluator (asserted in `tests/sim_vs_makespan.rs`).
    pub contention: bool,
    /// Background loads on specific units.
    pub background: Vec<BgLoad>,
}

impl SimConfig {
    /// NNV12's runtime defaults: stealing on, contention on.
    pub fn nnv12() -> SimConfig {
        SimConfig { stealing: true, contention: true, background: Vec::new() }
    }

    pub fn with_background(mut self, bg: Vec<BgLoad>) -> SimConfig {
        self.background = bg;
        self
    }
}

/// Simulation output.
#[derive(Debug, Clone)]
pub struct SimResult {
    /// Finish time of the final exec op.
    pub makespan: Ms,
    /// Per-op timings, indexed by OpId. `unit` is where it actually ran
    /// (work stealing may move ops off their planned unit).
    pub timings: Vec<OpTiming>,
    /// Number of ops executed on a different unit than planned.
    pub steals: usize,
    /// Busy ms per unit in plan order (gang first).
    pub busy: Vec<Ms>,
    /// Energy consumed, millijoules (active + idle power over makespan).
    pub energy_mj: f64,
}

/// Resource class for contention.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Resource {
    Disk,
    Memory,
    Compute,
}

fn resource_of(stage: OpStage) -> Resource {
    match stage {
        OpStage::Read => Resource::Disk,
        OpStage::Transform => Resource::Memory,
        _ => Resource::Compute,
    }
}

#[derive(Debug, Clone)]
struct Running {
    op: usize,
    unit_idx: usize,
    /// Remaining work, in ms at nominal (rate 1.0) speed.
    remaining: Ms,
    started: Ms,
}

/// Simulate `plan` over `set`, pricing ops with `pricer`.
pub fn simulate(
    dev: &DeviceProfile,
    set: &OpSet,
    plan: &Plan,
    pricer: &Pricer,
    cfg: &SimConfig,
) -> SimResult {
    let queues: Vec<(UnitId, Vec<usize>)> = plan
        .queues()
        .into_iter()
        .map(|(u, q)| (u, q.clone()))
        .collect();
    // Flat price table shared with the scheduler's evaluator: the cost
    // model runs once per op up front; the event loop is pure lookups.
    let table = PriceTable::build(set, pricer);
    let n_units = queues.len();
    let mut bg = vec![0.0f64; n_units];
    for load in &cfg.background {
        if let Some(idx) = queues.iter().position(|(u, _)| *u == load.unit) {
            bg[idx] = load.utilization.clamp(0.0, 0.99);
        }
    }

    let mut cursor = vec![0usize; n_units];
    let mut done = vec![false; set.len()];
    let mut claimed = vec![false; set.len()]; // started or stolen
    let mut finish_time = vec![0.0f64; set.len()];
    let mut timings = vec![
        OpTiming { start: 0.0, finish: 0.0, unit: UnitId::Gang };
        set.len()
    ];
    let mut running: Vec<Running> = Vec::new();
    let mut busy = vec![0.0f64; n_units];
    let mut steals = 0usize;
    let mut now: Ms = 0.0;

    // §Perf: the evaluator's notification discipline, ported to the
    // simulator's start phase. Dependency readiness is a per-op pending
    // count decremented through `set.dependents` at completion (never a
    // deps rescan), unit occupancy is a flag maintained at start/finish
    // (never a scan over `running`), and only *woken* units — the unit a
    // completion freed, plus units whose queued op just became ready — are
    // re-examined for normal dispatch.
    let mut pending: Vec<u32> = set.ops.iter().map(|o| o.deps.len() as u32).collect();
    let mut busy_unit = vec![false; n_units];
    let mut wake: Vec<usize> = (0..n_units).collect();
    let mut in_wake = vec![true; n_units];

    // Advance each queue's cursor past claimed ops; return next unclaimed.
    let next_in_queue = |u: usize, cursor: &mut [usize], claimed: &[bool],
                         queues: &[(UnitId, Vec<usize>)]| -> Option<usize> {
        let q = &queues[u].1;
        while cursor[u] < q.len() && claimed[q[cursor[u]]] {
            cursor[u] += 1;
        }
        q.get(cursor[u]).copied()
    };

    let total_ops: usize = queues.iter().map(|(_, q)| q.len()).sum();
    let mut completed = 0usize;
    let mut guard = 0usize;

    // Per-queue remaining nominal work + op→queue map, maintained
    // incrementally as ops are claimed (used by the stealing policy).
    let mut queue_of = vec![usize::MAX; set.len()];
    let mut q_remaining = vec![0.0f64; n_units];
    for (v, (unit, q)) in queues.iter().enumerate() {
        for &op in q {
            queue_of[op] = v;
            q_remaining[v] += table.get(op, *unit);
        }
    }
    let table_ref = &table;
    let claim = move |op: usize,
                      claimed: &mut [bool],
                      q_remaining: &mut [f64],
                      queue_of: &[usize],
                      queues: &[(UnitId, Vec<usize>)]| {
        claimed[op] = true;
        let v = queue_of[op];
        q_remaining[v] -= table_ref.get(op, queues[v].0);
    };

    while completed < total_ops {
        guard += 1;
        assert!(
            guard < 20 * total_ops + 100,
            "simulator failed to make progress (deadlocked plan?)"
        );
        // --- Start phase: put ready ops on woken idle units. A unit only
        // lands on the wake list through an event that could unblock it
        // (its own op finished, or a dependency of a queued op resolved),
        // so nothing is rescanned. ---
        while let Some(u) = wake.pop() {
            in_wake[u] = false;
            if busy_unit[u] {
                continue;
            }
            if let Some(op) = next_in_queue(u, &mut cursor, &claimed, &queues) {
                if pending[op] == 0 {
                    claim(op, &mut claimed, &mut q_remaining, &queue_of, &queues);
                    busy_unit[u] = true;
                    let dur = table.get(op, queues[u].0);
                    running.push(Running { op, unit_idx: u, remaining: dur, started: now });
                }
            }
        }
        // --- Work stealing (§3.3): a still-idle unit (empty queue or
        // blocked head) steals the first ready, unclaimed, non-exec op
        // from the most-loaded busy queue. Only little cores steal: the
        // gang's idle slots belong to execution (and to §3.5's warm-kernel
        // preparation), and a gang steal would add disk contention right
        // where execution needs the bandwidth.
        //
        // Note a deliberate timing refinement vs the pre-notification
        // code: all normal dispatches for the event complete before the
        // steal pass, so a unit that just started is visible as a busy
        // steal source within the same event (previously only units
        // earlier in index order were). Stealing is therefore slightly
        // more eager; steal counts/makespans under stealing-enabled
        // configs can differ marginally from older snapshots. ---
        if cfg.stealing {
            for u in 0..n_units {
                if busy_unit[u] || !matches!(queues[u].0, UnitId::Little(_)) {
                    continue;
                }
                let mut best: Option<(usize, usize, f64)> = None; // (queue, op, load)
                for v in 0..n_units {
                    // Only steal from a currently busy source (an idle one
                    // would start the op itself now).
                    if v == u || !busy_unit[v] {
                        continue;
                    }
                    // Remaining nominal work in v's queue (incrementally
                    // maintained — §Perf: the per-event rescan was the
                    // simulator's hottest loop).
                    let load = q_remaining[v];
                    if load <= 1e-12 {
                        continue;
                    }
                    // Head = first unclaimed ready op in v's queue with
                    // real work. Canonical op sets carry zero-priced
                    // transform ops for bypassing kernels; "stealing" one
                    // relieves no load and would only burn the idle
                    // unit's slot for this event, so skip them.
                    let head = queues[v]
                        .1
                        .iter()
                        .copied()
                        .find(|&o| !claimed[o] && pending[o] == 0
                            && set.ops[o].stage != OpStage::Exec
                            && set.ops[o].stage != OpStage::DriverInit
                            && table.get(o, queues[v].0) > 0.0);
                    if let Some(op) = head {
                        match best {
                            Some((_, _, l)) if l >= load => {}
                            _ => best = Some((v, op, load)),
                        }
                    }
                }
                if let Some((_, op, _)) = best {
                    claim(op, &mut claimed, &mut q_remaining, &queue_of, &queues);
                    steals += 1;
                    busy_unit[u] = true;
                    let dur = table.get(op, queues[u].0);
                    running.push(Running { op, unit_idx: u, remaining: dur, started: now });
                }
            }
        }

        if running.is_empty() {
            // Nothing runnable: all remaining ops blocked — deadlock.
            let left: Vec<_> = (0..set.len()).filter(|&o| !done[o]).take(5).collect();
            panic!("simulation deadlock at t={now}: blocked ops {left:?}");
        }

        // --- Rate computation (bandwidth sharing + background load). ---
        // Concurrent reads share the *device's* disk bandwidth; concurrent
        // transforms share DRAM bandwidth. Each op's nominal duration
        // already encodes its issuing core's class rate, so we express
        // demand in class-rate units: a big-core read demands 1.0 of the
        // disk, a little-core read 1/read_little_slowdown. When total
        // demand exceeds the device aggregate, everyone scales down
        // proportionally (the §3.2 interference challenge) — but running
        // more readers never *reduces* aggregate throughput.
        let demand_of = |r: &Running, res: Resource| -> f64 {
            let little = matches!(queues[r.unit_idx].0, UnitId::Little(_));
            match res {
                Resource::Disk => {
                    if little {
                        1.0 / dev.read_little_slowdown
                    } else {
                        1.0
                    }
                }
                Resource::Memory => {
                    if little {
                        1.0 / dev.transform_little_slowdown
                    } else {
                        1.0
                    }
                }
                Resource::Compute => 0.0,
            }
        };
        // Device aggregates in class-rate units: the disk saturates at the
        // big-core rate; DRAM has ~60% headroom over one big core's
        // streaming rate (shared LLC + controller parallelism).
        const DISK_AGG: f64 = 1.0;
        const MEM_AGG: f64 = 1.6;
        let mut scale = [1.0f64; 2]; // [disk, memory]
        if cfg.contention {
            for (i, (res, cap)) in
                [(Resource::Disk, DISK_AGG), (Resource::Memory, MEM_AGG)].iter().enumerate()
            {
                let total: f64 = running
                    .iter()
                    .filter(|r| resource_of(set.ops[r.op].stage) == *res)
                    .map(|r| demand_of(r, *res))
                    .sum();
                if total > *cap {
                    scale[i] = cap / total;
                }
            }
        }
        let rates: Vec<f64> = running
            .iter()
            .map(|r| {
                let mut rate = 1.0 - bg[r.unit_idx];
                match resource_of(set.ops[r.op].stage) {
                    Resource::Disk => rate *= scale[0],
                    Resource::Memory => rate *= scale[1],
                    Resource::Compute => {}
                }
                rate.max(1e-6)
            })
            .collect();

        // --- Advance to the earliest finish. ---
        let (idx, dt) = running
            .iter()
            .zip(&rates)
            .enumerate()
            .map(|(i, (r, &rate))| (i, r.remaining / rate))
            .min_by(|(_, a), (_, b)| a.partial_cmp(b).unwrap())
            .unwrap();
        now += dt;
        for (r, &rate) in running.iter_mut().zip(&rates) {
            r.remaining -= rate * dt;
        }
        // Track busy time: every running op occupies its unit for dt.
        for r in &running {
            busy[r.unit_idx] += dt;
        }
        let fin = running.swap_remove(idx);
        done[fin.op] = true;
        finish_time[fin.op] = now;
        timings[fin.op] = OpTiming { start: fin.started, finish: now, unit: queues[fin.unit_idx].0 };
        completed += 1;

        // --- Notify: the freed unit re-examines its queue; dependents
        // whose last dependency this was wake their (idle) planned unit.
        busy_unit[fin.unit_idx] = false;
        if !in_wake[fin.unit_idx] {
            wake.push(fin.unit_idx);
            in_wake[fin.unit_idx] = true;
        }
        for &d in &set.dependents[fin.op] {
            pending[d] -= 1;
            if pending[d] == 0 {
                let v = queue_of[d];
                if v != usize::MAX && !busy_unit[v] && !in_wake[v] {
                    wake.push(v);
                    in_wake[v] = true;
                }
            }
        }
    }

    let makespan = finish_time[set.final_exec()];
    let energy_mj = energy(dev, &queues, &busy, makespan);
    SimResult { makespan, timings, steals, busy, energy_mj }
}

/// Energy model (Fig. 12): Σ unit busy-time × unit power + idle power ×
/// makespan. Units map to core classes via the plan layout: the gang is
/// all big cores (or the GPU), each little queue is one little core.
fn energy(dev: &DeviceProfile, queues: &[(UnitId, Vec<usize>)], busy: &[Ms], makespan: Ms) -> f64 {
    let mut mj = dev.idle_power_w * makespan; // mW·ms == μJ… keep mJ: W×ms = mJ
    for ((unit, _), &b) in queues.iter().zip(busy) {
        let power = match unit {
            UnitId::Gang => {
                if let Some(g) = &dev.gpu {
                    g.power_w
                } else {
                    dev.big_power_w * dev.n_big as f64
                }
            }
            UnitId::Little(_) => dev.little_power_w,
        };
        mj += power * b;
    }
    mj
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::profiles;
    use crate::graph::zoo;
    use crate::kernels::Registry;
    use crate::sched::heuristic::{schedule, SchedulerConfig};
    use crate::sched::makespan::evaluate;

    fn setup(model: &str) -> (DeviceProfile, crate::graph::ModelGraph) {
        (profiles::meizu_16t(), zoo::by_name(model).unwrap())
    }

    #[test]
    fn matches_evaluator_without_contention() {
        let (dev, g) = setup("googlenet");
        let s = schedule(&dev, &g, &Registry::full(), &SchedulerConfig::kcp());
        let pricer = Pricer::new(&dev, &g, &s.plan.choices, true);
        let eval = evaluate(&s.set, &s.plan, &pricer).unwrap();
        let sim = simulate(
            &dev,
            &s.set,
            &s.plan,
            &pricer,
            &SimConfig { stealing: false, contention: false, background: vec![] },
        );
        assert!(
            (sim.makespan - eval.makespan).abs() < 1e-6,
            "sim {} vs eval {}",
            sim.makespan,
            eval.makespan
        );
    }

    #[test]
    fn contention_slows_things_down() {
        let (dev, g) = setup("resnet50");
        let s = schedule(&dev, &g, &Registry::full(), &SchedulerConfig::kcp());
        let pricer = Pricer::new(&dev, &g, &s.plan.choices, true);
        let no_c = simulate(
            &dev, &s.set, &s.plan, &pricer,
            &SimConfig { stealing: false, contention: false, background: vec![] },
        );
        let with_c = simulate(
            &dev, &s.set, &s.plan, &pricer,
            &SimConfig { stealing: false, contention: true, background: vec![] },
        );
        assert!(with_c.makespan >= no_c.makespan - 1e-9);
    }

    #[test]
    fn fig11_background_load_hurts_and_stealing_recovers() {
        let (dev, g) = setup("googlenet");
        let s = schedule(&dev, &g, &Registry::full(), &SchedulerConfig::kcp());
        let pricer = Pricer::new(&dev, &g, &s.plan.choices, true);
        let bg = vec![
            BgLoad { unit: UnitId::Little(0), utilization: 0.5 },
            BgLoad { unit: UnitId::Little(1), utilization: 0.5 },
        ];
        let clean = simulate(&dev, &s.set, &s.plan, &pricer, &SimConfig::nnv12());
        let loaded_no_ws = simulate(
            &dev, &s.set, &s.plan, &pricer,
            &SimConfig { stealing: false, contention: true, background: bg.clone() },
        );
        let loaded_ws = simulate(
            &dev, &s.set, &s.plan, &pricer,
            &SimConfig { stealing: true, contention: true, background: bg },
        );
        assert!(
            loaded_no_ws.makespan > clean.makespan * 1.05,
            "background load should hurt: {} vs {}",
            loaded_no_ws.makespan,
            clean.makespan
        );
        assert!(
            loaded_ws.makespan < loaded_no_ws.makespan,
            "stealing should recover: ws {} vs no-ws {}",
            loaded_ws.makespan,
            loaded_no_ws.makespan
        );
        assert!(loaded_ws.steals > 0);
    }

    #[test]
    fn energy_accounting_positive_and_scales() {
        let (dev, g) = setup("mobilenet");
        let s = schedule(&dev, &g, &Registry::full(), &SchedulerConfig::kcp());
        let pricer = Pricer::new(&dev, &g, &s.plan.choices, true);
        let r = simulate(&dev, &s.set, &s.plan, &pricer, &SimConfig::nnv12());
        assert!(r.energy_mj > 0.0);
        // Energy at least idle × makespan.
        assert!(r.energy_mj >= dev.idle_power_w * r.makespan);
    }

    #[test]
    fn timings_respect_dependencies() {
        let (dev, g) = setup("squeezenet");
        let s = schedule(&dev, &g, &Registry::full(), &SchedulerConfig::kcp());
        let pricer = Pricer::new(&dev, &g, &s.plan.choices, true);
        let r = simulate(&dev, &s.set, &s.plan, &pricer, &SimConfig::nnv12());
        for op in &s.set.ops {
            for &d in &op.deps {
                assert!(
                    r.timings[op.id].start >= r.timings[d].finish - 1e-9,
                    "op {} started before dep {} finished",
                    op.id,
                    d
                );
            }
        }
    }
}
