//! Gantt-style trace rendering of a schedule (the paper's Fig. 7 view).

use std::collections::BTreeMap;

use crate::sched::makespan::OpTiming;
use crate::sched::op::OpSet;
use crate::sched::plan::UnitId;

/// Render an ASCII Gantt chart: one row per unit, `width` columns across
/// the makespan. Cells show the op stage initial (r/w/e/p/d).
pub fn gantt(set: &OpSet, timings: &[OpTiming], width: usize) -> String {
    let makespan = timings
        .iter()
        .map(|t| t.finish)
        .fold(0.0f64, f64::max)
        .max(1e-9);
    let mut rows: BTreeMap<String, Vec<char>> = BTreeMap::new();
    let mut order: Vec<String> = Vec::new();
    for (op, t) in set.ops.iter().zip(timings) {
        let key = match t.unit {
            UnitId::Gang => "gang   ".to_string(),
            UnitId::Little(j) => format!("little{j}"),
        };
        if !rows.contains_key(&key) {
            order.push(key.clone());
        }
        let row = rows.entry(key).or_insert_with(|| vec!['.'; width]);
        let c = match op.stage {
            crate::sched::op::OpStage::Read => 'r',
            crate::sched::op::OpStage::Transform => 'w',
            crate::sched::op::OpStage::Exec => 'e',
            crate::sched::op::OpStage::Pipeline => 'p',
            crate::sched::op::OpStage::DriverInit => 'd',
        };
        let lo = ((t.start / makespan) * width as f64).floor() as usize;
        let hi = ((t.finish / makespan) * width as f64).ceil() as usize;
        for cell in row.iter_mut().take(hi.min(width)).skip(lo.min(width.saturating_sub(1))) {
            *cell = c;
        }
    }
    order.sort();
    let mut out = String::new();
    out.push_str(&format!("makespan: {makespan:.2} ms\n"));
    for key in order {
        let row: String = rows[&key].iter().collect();
        out.push_str(&format!("{key} |{row}|\n"));
    }
    out
}

/// Per-stage time totals (for breakdown reporting).
pub fn stage_totals(set: &OpSet, timings: &[OpTiming]) -> BTreeMap<&'static str, f64> {
    let mut m = BTreeMap::new();
    for (op, t) in set.ops.iter().zip(timings) {
        *m.entry(op.stage.name()).or_insert(0.0) += t.finish - t.start;
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::profiles;
    use crate::graph::zoo;
    use crate::kernels::Registry;
    use crate::sched::heuristic::{schedule, SchedulerConfig};

    #[test]
    fn renders_all_units() {
        let dev = profiles::meizu_16t();
        let g = zoo::tiny_net();
        let s = schedule(&dev, &g, &Registry::full(), &SchedulerConfig::kcp());
        let txt = gantt(&s.set, &s.schedule.timings, 60);
        assert!(txt.contains("gang"));
        assert!(txt.contains("makespan"));
        // Execution must appear on the gang row.
        let gang_row = txt.lines().find(|l| l.starts_with("gang")).unwrap();
        assert!(gang_row.contains('e'));
    }

    #[test]
    fn stage_totals_sum_positive() {
        let dev = profiles::meizu_16t();
        let g = zoo::tiny_net();
        let s = schedule(&dev, &g, &Registry::full(), &SchedulerConfig::kcp());
        let totals = stage_totals(&s.set, &s.schedule.timings);
        assert!(totals["exec"] > 0.0);
        assert!(totals.get("read").copied().unwrap_or(0.0) > 0.0);
    }
}
