//! Discrete-event simulator of an edge device executing a kernel
//! scheduling plan.
//!
//! Where the scheduler's internal evaluator ([`crate::sched::makespan`])
//! assumes operations never interfere, the simulator models what the paper
//! identifies as the second challenge of §3.2: *"the execution time can be
//! interfered with … because the co-running operations reach the limit of
//! disk and/or memory I/O speed"*. Concurrent reads share disk bandwidth
//! (processor sharing); concurrent transformations share memory bandwidth;
//! background workloads steal cycles from individual cores (Fig. 11); and
//! the workload-stealing technique of §3.3 reassigns queued preparations
//! from busy cores to idle ones at runtime.
//!
//! The simulator also integrates the energy model (Fig. 12): per-core-class
//! active power × busy time + device idle power × makespan.

pub mod engine;
pub mod trace;

pub use engine::{simulate, BgLoad, SimConfig, SimResult};
