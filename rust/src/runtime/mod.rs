//! PJRT runtime: load AOT HLO-text artifacts and execute them.
//!
//! This is the only module that touches the `xla` crate. Artifacts are the
//! HLO *text* files produced by `python/compile/aot.py` (text, not
//! serialized `HloModuleProto` — jax ≥ 0.5 emits 64-bit instruction ids the
//! crate's XLA 0.5.1 rejects; the text parser reassigns ids).
//!
//! Compilation happens lazily per artifact and the compiled executable is
//! cached — the in-process analogue of §3.4's shader cache: first load of a
//! model pays "pipeline creation" (XLA compilation), subsequent loads hit
//! the cache. Compile times are recorded so the real-mode experiments can
//! report them as the GPU-preparation stage.
//!
//! PJRT types are not `Send`; the runtime is owned by the executor thread
//! (the "gang"), which is also the only place kernels execute — matching
//! the paper's design where execution owns the big cores.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use anyhow::{Context, Result};

use crate::metrics::Timer;

/// A compiled, loaded computation.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    /// Compile (pipeline-creation) time paid to produce this executable.
    pub compile_ms: f64,
}

impl Executable {
    /// Execute with f32 inputs (data, dims) and return the flat f32 output.
    /// Artifacts are lowered with `return_tuple=True`, so the single output
    /// is unwrapped with `to_tuple1`.
    pub fn run_f32(&self, inputs: &[(&[f32], &[i64])]) -> Result<Vec<f32>> {
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|(data, dims)| {
                let lit = xla::Literal::vec1(data);
                lit.reshape(dims).context("reshaping input literal")
            })
            .collect::<Result<_>>()?;
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0]
            .to_literal_sync()?;
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }
}

/// The PJRT CPU runtime with an executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    cache: RefCell<HashMap<PathBuf, Rc<Executable>>>,
    /// (artifact, compile ms) log in load order.
    pub compile_log: RefCell<Vec<(String, f64)>>,
}

impl Runtime {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Runtime> {
        Ok(Runtime {
            client: xla::PjRtClient::cpu()?,
            cache: RefCell::new(HashMap::new()),
            compile_log: RefCell::new(Vec::new()),
        })
    }

    /// Load + compile an HLO-text artifact, hitting the cache when warm.
    pub fn load(&self, path: &Path) -> Result<Rc<Executable>> {
        if let Some(e) = self.cache.borrow().get(path) {
            return Ok(e.clone());
        }
        let t = Timer::start();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        let compile_ms = t.elapsed_ms();
        self.compile_log
            .borrow_mut()
            .push((path.display().to_string(), compile_ms));
        let e = Rc::new(Executable { exe, compile_ms });
        self.cache.borrow_mut().insert(path.to_path_buf(), e.clone());
        Ok(e)
    }

    /// Whether an artifact is already compiled (shader-cache hit).
    pub fn is_cached(&self, path: &Path) -> bool {
        self.cache.borrow().contains_key(path)
    }

    /// Number of compiled artifacts resident.
    pub fn cached_count(&self) -> usize {
        self.cache.borrow().len()
    }

    /// Drop compiled executables (simulates a cold process start).
    pub fn evict_all(&self) {
        self.cache.borrow_mut().clear();
    }
}

// NOTE: runtime tests live in `tests/real_mode.rs` (integration), because
// they need the artifacts built by `make artifacts`.
