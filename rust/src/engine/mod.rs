//! The unified engine facade — the crate's primary public API.
//!
//! NNV12's pipeline (§3) is one lifecycle: plan kernels offline, read and
//! transform (or cache-read) weights, execute the cold inference, then
//! switch kernels toward steady-state warm speed. [`Engine`] owns the
//! shared substrate of that lifecycle — device profile, kernel registry,
//! scheduler configuration, the fingerprint-keyed (optionally
//! disk-persistent) [`PlanCache`], and a pluggable [`ExecBackend`] — and
//! hands out per-model [`Session`]s with an explicit state machine:
//!
//! ```
//! use nnv12::device::profiles;
//! use nnv12::engine::{Engine, Phase};
//! use nnv12::graph::zoo;
//!
//! let engine = Engine::builder().device(profiles::meizu_16t()).build();
//! let session = engine.load(zoo::tiny_net());
//! let first = session.infer();
//! assert_eq!(first.phase, Phase::Cold);
//! let second = session.infer();
//! assert!(second.latency_ms <= first.latency_ms);
//! ```
//!
//! [`Engine::load`] plans the model (a [`PlanCache`] hit skips the
//! search; with [`EngineBuilder::artifact_store`] the hit survives the
//! process — Fig. 4's offline decision stage as an on-disk artifact in
//! the content-addressed [`crate::store::ArtifactStore`], which also
//! persists calibrated plans and transformed weights under one size cap
//! and integrity story; counters surface via [`Engine::store_stats`]) and
//! computes the §3.5 warm-up ladder. [`Session::infer`] then drives the
//! cold → warming → warm lifecycle against the engine's memory budget:
//! loading more models than fit evicts least-recently-used sessions,
//! whose next inference is cold again — the multi-tenant environment of
//! §1–2 that motivates the whole system.
//!
//! # Residency and tenancy
//!
//! The residency manager is built for fleet scale (thousands of resident
//! models): an intrusive `HashMap<session id, slot>` plus per-lane
//! doubly-linked LRU lists (the [`crate::store::ArtifactStore`]'s
//! recency trick, in memory), so the warm-path charge, `is_resident`,
//! and `release` are all O(1) map + list operations and eviction pops
//! the list head — oldest first, the exact order of the original
//! scan-based implementation (pinned by the
//! `lru_matches_vec_reference_model` property test below).
//!
//! Tenancy is a first-class axis on top of the same structure:
//! [`EngineBuilder::tenant_budget`] declares a named residency *lane*
//! with its own byte budget, and [`Engine::load_for_tenant`] /
//! [`Engine::load_all_for`] open sessions charged against that lane.
//! Each session lives in exactly one lane's LRU list and eviction only
//! ever walks the charging session's own lane, so isolation holds by
//! construction: one tenant's eviction storm can never cold-start
//! another tenant's models while that tenant stays under its quota.
//! Sessions loaded without a tenant share lane 0, whose budget is
//! [`EngineBuilder::memory_budget`].
//!
//! Execution is a backend choice, not a code path: [`SimBackend`] runs
//! plans on the contention-aware device simulator (default),
//! [`BaselineBackend`] charges a vanilla engine's latencies for
//! comparison arms, and `RealBackend` (behind the `real-runtime` cargo
//! feature) executes AOT artifacts through PJRT.
//!
//! # Threading model
//!
//! The engine substrate is fully thread-safe: `Engine: Send + Sync` and
//! `Session: Send + Sync` (compile-time asserted in
//! `tests/concurrent_serving.rs`), so one engine can answer inference
//! requests from any number of threads — the concurrent serving path the
//! sharded [`crate::serving::Router`] builds on. The locking is
//! fine-grained and never held across expensive work:
//!
//! * **Residency/LRU state** lives behind one short-critical-section
//!   `Mutex` (the charge path does an O(1) map lookup + list splice and
//!   nothing else under it, so the critical section stays flat as the
//!   model population grows); session ids come from an atomic counter.
//! * **Per-session state** (the lazily computed §3.5 warm-up ladder) is
//!   owned by the session itself in a `OnceLock`, so concurrent first
//!   inferences of *different* models never contend.
//! * **Plan caches and the artifact store** were already `Sync`
//!   ([`Engine::load_all`]'s planning fan-out relies on it); planning on
//!   a cache miss happens outside every map lock.
//! * **Backends** are required to be `Send + Sync`
//!   ([`ExecBackend`]'s supertraits). [`SimBackend`] and
//!   [`BaselineBackend`] are stateless value types; `RealBackend` is
//!   `Sync` by *thread confinement* — its PJRT client lives on a
//!   dedicated executor thread fed by a channel, because the underlying
//!   runtime handle is deliberately single-threaded (see
//!   `engine::backend`).

mod backend;
mod session;

pub use backend::{BackendCtx, BaselineBackend, ColdOutcome, ExecBackend, SimBackend};
#[cfg(feature = "real-runtime")]
pub use backend::RealBackend;
pub use session::{InferenceReport, Phase, Session};

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::device::DeviceProfile;
use crate::fleet::PlanTransfer;
use crate::graph::ModelGraph;
use crate::kernels::Registry;
use crate::sched::cache::{CalibratedPlanCache, PlanCache};
use crate::sched::heuristic::{schedule, Scheduled, SchedulerConfig};
use crate::store::{ArtifactStore, StoreStats};
use crate::util::parallel::par_map;
use crate::Ms;

/// Sentinel "no slot" link for the intrusive LRU lists.
const NIL: usize = usize::MAX;

/// One resident session in the intrusive LRU: identity, charged bytes,
/// inferences since the last cold start, the owning lane, and the
/// recency-list links within that lane.
struct Slot {
    id: u64,
    bytes: u64,
    count: usize,
    lane: usize,
    prev: usize,
    next: usize,
}

/// One residency lane: a byte budget, current usage, and a doubly-linked
/// recency list threaded through [`Residency::slots`] (`head` = least
/// recently used, `tail` = most recently used). Lane 0 is the shared
/// engine-wide budget; lanes 1.. are tenant sub-budgets in
/// [`EngineBuilder::tenant_budget`] declaration order.
struct Lane {
    budget: u64,
    used: u64,
    head: usize,
    tail: usize,
}

/// LRU residency state shared by an engine's sessions: an intrusive
/// `HashMap` + per-lane doubly-linked lists, so charge / warm-hit /
/// `is_resident` / `release` are O(1) and eviction pops the owning
/// lane's head. Observable behavior (reports, memory accounting,
/// eviction order) is bit-identical to the original front-evicting Vec
/// — the `lru_matches_vec_reference_model` property test keeps that Vec
/// around as the executable specification.
struct Residency {
    /// Slot arena; freed slots are recycled through `free`.
    slots: Vec<Slot>,
    free: Vec<usize>,
    /// session id → slot index: the O(1) replacement for the Vec scan.
    map: HashMap<u64, usize>,
    lanes: Vec<Lane>,
}

impl Residency {
    fn new(budget: u64, tenant_budgets: &[u64]) -> Residency {
        let mut lanes = Vec::with_capacity(1 + tenant_budgets.len());
        lanes.push(Lane { budget, used: 0, head: NIL, tail: NIL });
        for &b in tenant_budgets {
            lanes.push(Lane { budget: b, used: 0, head: NIL, tail: NIL });
        }
        Residency {
            slots: Vec::new(),
            free: Vec::new(),
            map: HashMap::new(),
            lanes,
        }
    }

    /// Detach slot `i` from its lane's recency list.
    fn unlink(&mut self, i: usize) {
        let (lane, prev, next) = {
            let s = &self.slots[i];
            (s.lane, s.prev, s.next)
        };
        match prev {
            NIL => self.lanes[lane].head = next,
            p => self.slots[p].next = next,
        }
        match next {
            NIL => self.lanes[lane].tail = prev,
            n => self.slots[n].prev = prev,
        }
        self.slots[i].prev = NIL;
        self.slots[i].next = NIL;
    }

    /// Append slot `i` at its lane's most-recently-used end.
    fn push_tail(&mut self, i: usize) {
        let lane = self.slots[i].lane;
        let tail = self.lanes[lane].tail;
        self.slots[i].prev = tail;
        self.slots[i].next = NIL;
        match tail {
            NIL => self.lanes[lane].head = i,
            t => self.slots[t].next = i,
        }
        self.lanes[lane].tail = i;
    }

    /// Evict `lane`'s least-recently-used resident; false when empty.
    fn evict_head(&mut self, lane: usize) -> bool {
        let h = self.lanes[lane].head;
        if h == NIL {
            return false;
        }
        self.unlink(h);
        let (id, bytes) = (self.slots[h].id, self.slots[h].bytes);
        self.map.remove(&id);
        self.lanes[lane].used -= bytes;
        self.free.push(h);
        true
    }

    /// The warm half of a charge: if `id` is resident, bump it to its
    /// lane's MRU end and price the next warm-ladder rung. Rung
    /// `count + 1` of the ladder; past the end the session is at steady
    /// state (so a depth-1 ladder never re-bills its cold rung to warm
    /// inferences).
    fn warm_hit(&mut self, id: u64, ladder: &[Ms], warm_ms: Ms) -> Option<InferenceReport> {
        let &i = self.map.get(&id)?;
        self.unlink(i);
        self.slots[i].count += 1;
        self.push_tail(i);
        let idx = self.slots[i].count;
        let latency = ladder.get(idx).copied().unwrap_or(warm_ms);
        let phase = if latency.to_bits() == warm_ms.to_bits() {
            Phase::Warm
        } else {
            Phase::Warming { n: idx }
        };
        Some(InferenceReport { latency_ms: latency, phase, evictions: 0 })
    }

    /// Full charge: warm when resident, otherwise evict the charging
    /// lane's LRU residents until `bytes` fits and charge cold. A model
    /// larger than the whole lane budget still runs, transiently
    /// overcommitting like a real OS would.
    fn charge(
        &mut self,
        id: u64,
        bytes: u64,
        lane: usize,
        ladder: &[Ms],
        warm_ms: Ms,
    ) -> InferenceReport {
        if let Some(report) = self.warm_hit(id, ladder, warm_ms) {
            return report;
        }
        let mut evictions = 0;
        while self.lanes[lane].used + bytes > self.lanes[lane].budget && self.evict_head(lane) {
            evictions += 1;
        }
        self.lanes[lane].used += bytes;
        let slot = Slot { id, bytes, count: 0, lane, prev: NIL, next: NIL };
        let i = match self.free.pop() {
            Some(i) => {
                self.slots[i] = slot;
                i
            }
            None => {
                self.slots.push(slot);
                self.slots.len() - 1
            }
        };
        self.map.insert(id, i);
        self.push_tail(i);
        // A well-formed ladder always has a cold rung; a custom backend
        // returning an empty one degrades to warm pricing rather than
        // panicking inside the residency manager.
        let latency = ladder.first().copied().unwrap_or(warm_ms);
        InferenceReport { latency_ms: latency, phase: Phase::Cold, evictions }
    }

    fn release(&mut self, id: u64) {
        if let Some(i) = self.map.remove(&id) {
            self.unlink(i);
            let (lane, bytes) = (self.slots[i].lane, self.slots[i].bytes);
            self.lanes[lane].used -= bytes;
            self.free.push(i);
        }
    }

    fn is_resident(&self, id: u64) -> bool {
        self.map.contains_key(&id)
    }

    fn mem_used(&self) -> u64 {
        self.lanes.iter().map(|l| l.used).sum()
    }

    fn evict_all(&mut self) {
        self.slots.clear();
        self.free.clear();
        self.map.clear();
        for l in &mut self.lanes {
            l.used = 0;
            l.head = NIL;
            l.tail = NIL;
        }
    }
}

/// Shared engine internals. [`Engine`] and every [`Session`] hold an
/// `Arc` of this; everything here is `Sync`, so engines and sessions can
/// be driven from any number of threads. The one piece of cross-session
/// mutable state — the intrusive LRU [`Residency`] — sits behind its own
/// `Mutex` with O(1) critical sections; the backend is a shared
/// `Send + Sync` trait object and is never called under that lock.
pub(crate) struct Inner {
    pub(crate) dev: DeviceProfile,
    pub(crate) registry: Registry,
    pub(crate) registry_tag: &'static str,
    pub(crate) sched: SchedulerConfig,
    pub(crate) warmup_depth: usize,
    pub(crate) calibrated: bool,
    pub(crate) plan_cache: Arc<PlanCache>,
    pub(crate) calibrated_cache: Arc<CalibratedPlanCache>,
    pub(crate) store: Option<Arc<ArtifactStore>>,
    /// Cross-device plan transfer over the store's fleet namespace
    /// ([`EngineBuilder::fleet_transfer`]); substitutes a nearest-profile
    /// seeded search for the cold search on full plan-cache misses.
    pub(crate) fleet: Option<Arc<PlanTransfer>>,
    pub(crate) backend: Box<dyn ExecBackend>,
    /// Declared tenant names; residency lane `k + 1` belongs to
    /// `tenant_names[k]` (lane 0 is the shared engine-wide budget).
    pub(crate) tenant_names: Vec<String>,
    residency: Mutex<Residency>,
    next_session: AtomicU64,
}

impl Inner {
    /// Charge one inference for session `id`: warm-ladder latency when
    /// resident, otherwise evict-until-fit within `lane` and charge cold.
    /// The whole decision happens under the residency lock, so concurrent
    /// requests observe a consistent LRU order (two racing requests for
    /// the same evicted model produce exactly one cold charge).
    pub(crate) fn charge(
        &self,
        id: u64,
        bytes: u64,
        lane: usize,
        ladder: &[Ms],
        warm_ms: Ms,
    ) -> InferenceReport {
        self.residency
            .lock()
            .unwrap()
            .charge(id, bytes, lane, ladder, warm_ms)
    }

    /// Charge a warm inference *only if* the session is resident; `None`
    /// means a cold start is due and the caller should run the cold path
    /// (retries, degradation policy, …) before calling [`Inner::charge`],
    /// which stays the single atomic residency decision. Two requests
    /// racing an eviction both see `None` here; `charge` then resolves
    /// them to exactly one cold + one warm, preserving the
    /// cold-exactly-once parity contract.
    pub(crate) fn charge_warm(
        &self,
        id: u64,
        ladder: &[Ms],
        warm_ms: Ms,
    ) -> Option<InferenceReport> {
        self.residency.lock().unwrap().warm_hit(id, ladder, warm_ms)
    }

    pub(crate) fn is_resident(&self, id: u64) -> bool {
        self.residency.lock().unwrap().is_resident(id)
    }

    /// Drop a session's residency (called on [`Session`] drop).
    pub(crate) fn release(&self, id: u64) {
        self.residency.lock().unwrap().release(id)
    }

    /// Residency lane for a tenant name: lane 0 (the shared budget) for
    /// `None`. Panics on an undeclared tenant — a configuration error,
    /// not a runtime condition.
    pub(crate) fn lane_of(&self, tenant: Option<&str>) -> usize {
        match tenant {
            None => 0,
            Some(t) => self
                .tenant_names
                .iter()
                .position(|n| n == t)
                .map(|k| k + 1)
                .unwrap_or_else(|| {
                    panic!("unknown tenant {t:?}: declare it with EngineBuilder::tenant_budget")
                }),
        }
    }
}

/// The engine: shared planning/execution substrate + session factory.
/// Cheap to clone (all state is behind an `Arc`); clones and their
/// sessions share the plan cache and the residency budget. `Engine` is
/// `Send + Sync`: clone it into threads, or share one behind a
/// reference — both work.
#[derive(Clone)]
pub struct Engine {
    inner: Arc<Inner>,
}

impl Engine {
    /// Start configuring an engine. [`EngineBuilder::device`] is the only
    /// required call.
    pub fn builder() -> EngineBuilder {
        EngineBuilder::default()
    }

    /// Plan `graph` and open a session: resolves the plan (cache →
    /// disk store → scheduler) and registers the session with the
    /// residency manager (not yet resident — the first
    /// [`Session::infer`] is cold). The §3.5 warm-up ladder is computed
    /// through the backend lazily, on first use.
    pub fn load(&self, graph: ModelGraph) -> Session {
        let (scheduled, dev) = self.plan_with_dev(&graph);
        self.open_session(graph, scheduled, dev, 0)
    }

    /// [`Engine::load`], charging the session against `tenant`'s
    /// residency sub-budget ([`EngineBuilder::tenant_budget`]) instead of
    /// the shared engine-wide one. Panics on an undeclared tenant.
    pub fn load_for_tenant(&self, graph: ModelGraph, tenant: &str) -> Session {
        let lane = self.inner.lane_of(Some(tenant));
        let (scheduled, dev) = self.plan_with_dev(&graph);
        self.open_session(graph, scheduled, dev, lane)
    }

    /// [`Engine::load`] for a fleet of models, planning them in parallel
    /// (multi-model startup planning is embarrassingly parallel; the
    /// shared [`PlanCache`] makes repeats free).
    pub fn load_all(&self, graphs: Vec<ModelGraph>) -> Vec<Session> {
        self.load_all_for(graphs.into_iter().map(|g| (g, None)).collect())
    }

    /// [`Engine::load_all`] with a per-model tenant assignment (`None`
    /// charges the shared budget) — how the serving router partitions a
    /// model fleet across tenant sub-budgets in one parallel planning
    /// pass. Panics on an undeclared tenant.
    pub fn load_all_for(&self, models: Vec<(ModelGraph, Option<String>)>) -> Vec<Session> {
        let inner = &self.inner;
        let lanes: Vec<usize> = models
            .iter()
            .map(|(_, t)| inner.lane_of(t.as_deref()))
            .collect();
        let sched_cfg = self.effective_sched();
        // Only planning fans out across cores here; warm-up ladders stay
        // lazy per session, so the (Sync) backend is not touched.
        let planned: Vec<(Arc<Scheduled>, DeviceProfile)> =
            if inner.calibrated && inner.backend.needs_plan() {
                let (dev, registry, tag, cache) = (
                    &inner.dev,
                    &inner.registry,
                    inner.registry_tag,
                    &inner.calibrated_cache,
                );
                let sched = &sched_cfg;
                par_map(&models, move |_, (g, _)| {
                    cache.get_or_plan(dev, g, registry, sched, tag)
                })
            } else {
                let (dev, registry, tag, cache) = (
                    &inner.dev,
                    &inner.registry,
                    inner.registry_tag,
                    &inner.plan_cache,
                );
                let sched = &sched_cfg;
                let fleet = inner.fleet.as_deref();
                par_map(&models, move |_, (g, _)| {
                    let s = match fleet {
                        Some(f) => cache.get_or_plan_with(dev, g, registry, sched, tag, || {
                            f.plan(dev, g, registry, sched, tag).outcome.scheduled
                        }),
                        None => cache.get_or_plan(dev, g, registry, sched, tag),
                    };
                    (s, dev.clone())
                })
            };
        models
            .into_iter()
            .zip(planned)
            .zip(lanes)
            .map(|(((g, _), (s, d)), lane)| self.open_session(g, s, d, lane))
            .collect()
    }

    /// The plan for `graph` under this engine's configuration, via the
    /// plan cache (and disk store, if configured).
    pub fn plan(&self, graph: &ModelGraph) -> Arc<Scheduled> {
        self.plan_with_dev(graph).0
    }

    /// Run the scheduler from scratch, bypassing the cache and store —
    /// offline plan generation and planner benchmarks.
    pub fn plan_fresh(&self, graph: &ModelGraph) -> Scheduled {
        let inner = &self.inner;
        schedule(&inner.dev, graph, &inner.registry, &inner.sched)
    }

    /// Scheduler config actually used at load time: the configured one,
    /// or — for backends that never execute the plan
    /// ([`ExecBackend::needs_plan`] is false) — a search-free warm-default
    /// sequential config, so baseline arms don't pay the
    /// kernel-combination search for a plan nothing reads.
    fn effective_sched(&self) -> SchedulerConfig {
        let inner = &self.inner;
        if inner.backend.needs_plan() {
            inner.sched.clone()
        } else {
            SchedulerConfig {
                kernel_selection: false,
                weight_cache: false,
                pipeline: false,
                max_outer_passes: 0,
                ..inner.sched.clone()
            }
        }
    }

    fn plan_with_dev(&self, graph: &ModelGraph) -> (Arc<Scheduled>, DeviceProfile) {
        let inner = &self.inner;
        if inner.calibrated && inner.backend.needs_plan() {
            inner.calibrated_cache.get_or_plan(
                &inner.dev,
                graph,
                &inner.registry,
                &inner.sched,
                inner.registry_tag,
            )
        } else {
            let cfg = self.effective_sched();
            let s = match &inner.fleet {
                // Full misses (memory and disk) go through the fleet
                // transfer path: seed from the nearest profile's plan
                // when one is published, cold search otherwise. Either
                // way the result is confirmed on this device and cached
                // under the ordinary plan key.
                Some(fleet) => inner.plan_cache.get_or_plan_with(
                    &inner.dev,
                    graph,
                    &inner.registry,
                    &cfg,
                    inner.registry_tag,
                    || {
                        fleet
                            .plan(&inner.dev, graph, &inner.registry, &cfg, inner.registry_tag)
                            .outcome
                            .scheduled
                    },
                ),
                None => inner.plan_cache.get_or_plan(
                    &inner.dev,
                    graph,
                    &inner.registry,
                    &cfg,
                    inner.registry_tag,
                ),
            };
            (s, inner.dev.clone())
        }
    }

    fn open_session(
        &self,
        graph: ModelGraph,
        scheduled: Arc<Scheduled>,
        dev: DeviceProfile,
        lane: usize,
    ) -> Session {
        let inner = &self.inner;
        // Resident-set size: weights + transformed layouts + workspace.
        let resident_bytes = graph.weight_bytes() + graph.weight_bytes() / 4;
        let id = inner.next_session.fetch_add(1, Ordering::Relaxed);
        Session {
            engine: inner.clone(),
            id,
            graph,
            dev,
            scheduled,
            ladder: std::sync::OnceLock::new(),
            degraded: std::sync::OnceLock::new(),
            resident_bytes,
            lane,
        }
    }

    /// The shared plan cache (hit/miss/disk-hit counters live here).
    pub fn plan_cache(&self) -> &Arc<PlanCache> {
        &self.inner.plan_cache
    }

    /// The shared calibrated-plan cache (only consulted by engines built
    /// with [`EngineBuilder::calibrated`]).
    pub fn calibrated_cache(&self) -> &Arc<CalibratedPlanCache> {
        &self.inner.calibrated_cache
    }

    /// The backing artifact store, when this engine persists artifacts
    /// ([`EngineBuilder::artifact_store`]).
    pub fn artifact_store(&self) -> Option<&Arc<ArtifactStore>> {
        self.inner.store.as_ref()
    }

    /// The cross-device plan-transfer handle, when this engine was built
    /// with [`EngineBuilder::fleet_transfer`] over an artifact store
    /// (counters: transfer hits / rejected seeds / donor misses).
    pub fn fleet(&self) -> Option<&Arc<PlanTransfer>> {
        self.inner.fleet.as_ref()
    }

    /// Counter snapshot of the artifact store (hits, misses, evictions,
    /// corrupt-rejections, registry-stale invalidations, bytes), or
    /// `None` for a purely in-memory engine.
    pub fn store_stats(&self) -> Option<StoreStats> {
        self.inner.store.as_ref().map(|s| s.stats())
    }

    /// What the store's boot-time recovery pass repaired when this engine
    /// opened it (torn intent groups discarded, orphan temp files swept).
    /// `None` for in-memory engines and for stores shared via
    /// [`EngineBuilder::artifact_store_shared`] whose handle predates
    /// this engine (recovery ran — or didn't — when *that* handle was
    /// opened).
    pub fn store_recovery(&self) -> Option<crate::store::RecoveryReport> {
        self.inner.store.as_ref().and_then(|s| s.recovery())
    }

    /// The device this engine targets.
    pub fn device(&self) -> &DeviceProfile {
        &self.inner.dev
    }

    /// The kernel registry sessions plan against.
    pub fn registry(&self) -> &Registry {
        &self.inner.registry
    }

    /// The backend executing this engine's sessions.
    pub fn backend_name(&self) -> &'static str {
        self.inner.backend.name()
    }

    /// Bytes of the residency budget currently in use, across every lane.
    pub fn mem_used(&self) -> u64 {
        self.inner.residency.lock().unwrap().mem_used()
    }

    /// Declared tenant names, in [`EngineBuilder::tenant_budget`]
    /// declaration order (empty for an untenanted engine).
    pub fn tenants(&self) -> &[String] {
        &self.inner.tenant_names
    }

    /// Bytes currently resident under `tenant`'s sub-budget, or `None`
    /// for an undeclared tenant.
    pub fn tenant_mem_used(&self, tenant: &str) -> Option<u64> {
        let k = self.inner.tenant_names.iter().position(|n| n == tenant)?;
        Some(self.inner.residency.lock().unwrap().lanes[k + 1].used)
    }

    /// Evict every resident session in every lane (their next inference
    /// is cold).
    pub fn evict_all(&self) {
        self.inner.residency.lock().unwrap().evict_all()
    }
}

/// Builder for [`Engine`]. Defaults: full kernel registry, `kcp`
/// scheduler config, simulated execution ([`SimBackend::nnv12`]),
/// unbounded residency budget, warm-up ladder depth 4, in-memory plan
/// cache, no calibration.
pub struct EngineBuilder {
    dev: Option<DeviceProfile>,
    registry: Registry,
    sched: SchedulerConfig,
    warmup_depth: usize,
    memory_budget: u64,
    calibrated: bool,
    backend: Option<Box<dyn ExecBackend>>,
    plan_cache: Option<Arc<PlanCache>>,
    shared_calibrated: Option<Arc<CalibratedPlanCache>>,
    store_dir: Option<PathBuf>,
    store_cap: Option<u64>,
    shared_store: Option<Arc<ArtifactStore>>,
    fleet_transfer: bool,
    tenant_budgets: Vec<(String, u64)>,
}

impl Default for EngineBuilder {
    fn default() -> EngineBuilder {
        EngineBuilder {
            dev: None,
            registry: Registry::full(),
            sched: SchedulerConfig::kcp(),
            warmup_depth: 4,
            memory_budget: u64::MAX,
            calibrated: false,
            backend: None,
            plan_cache: None,
            shared_calibrated: None,
            store_dir: None,
            store_cap: None,
            shared_store: None,
            fleet_transfer: false,
            tenant_budgets: Vec::new(),
        }
    }
}

impl EngineBuilder {
    /// Target device (required).
    pub fn device(mut self, dev: DeviceProfile) -> EngineBuilder {
        self.dev = Some(dev);
        self
    }

    /// Kernel registry (default: [`Registry::full`]).
    pub fn registry(mut self, registry: Registry) -> EngineBuilder {
        self.registry = registry;
        self
    }

    /// Scheduler configuration (default: [`SchedulerConfig::kcp`]).
    pub fn sched(mut self, cfg: SchedulerConfig) -> EngineBuilder {
        self.sched = cfg;
        self
    }

    /// Length of the warm-up latency ladder computed per session
    /// (default 4: cold, 2nd, 3rd, steady).
    pub fn warmup_depth(mut self, depth: usize) -> EngineBuilder {
        self.warmup_depth = depth.max(1);
        self
    }

    /// Memory budget for resident sessions, bytes (default unbounded).
    pub fn memory_budget(mut self, bytes: u64) -> EngineBuilder {
        self.memory_budget = bytes;
        self
    }

    /// Declare a tenant with its own residency sub-budget, in bytes.
    /// Sessions opened for the tenant ([`Engine::load_for_tenant`],
    /// [`Engine::load_all_for`]) charge and evict only within that
    /// tenant's LRU lane, so one tenant's eviction storm never
    /// cold-starts another tenant's resident models — isolation by
    /// construction, not by policy. Lanes are enforced independently of
    /// the shared [`EngineBuilder::memory_budget`] (which governs only
    /// untenanted sessions); declare sub-budgets that sum to the physical
    /// budget when full partitioning is intended. Re-declaring a tenant
    /// updates its budget.
    pub fn tenant_budget(mut self, tenant: impl Into<String>, bytes: u64) -> EngineBuilder {
        let tenant = tenant.into();
        match self.tenant_budgets.iter_mut().find(|(t, _)| *t == tenant) {
            Some((_, b)) => *b = bytes,
            None => self.tenant_budgets.push((tenant, bytes)),
        }
        self
    }

    /// Re-profile prep-parallelism degrees under the contention-aware
    /// simulator at plan time (§3.3 calibration; used by the paper's
    /// end-to-end figures). Calibrated plans carry their chosen device
    /// view as part of the answer, so they live in their own
    /// [`CalibratedPlanCache`] (and, with an artifact store, the
    /// `calibrated-plan` namespace) rather than the plain plan cache.
    pub fn calibrated(mut self, on: bool) -> EngineBuilder {
        self.calibrated = on;
        self
    }

    /// Execution backend (default: [`SimBackend::nnv12`]). Backends are
    /// `Send + Sync` by trait bound; see the module docs for what that
    /// means per backend.
    pub fn backend(self, backend: impl ExecBackend + 'static) -> EngineBuilder {
        self.backend_box(Box::new(backend))
    }

    /// [`EngineBuilder::backend`] for an already-boxed backend.
    pub fn backend_box(mut self, backend: Box<dyn ExecBackend>) -> EngineBuilder {
        self.backend = Some(backend);
        self
    }

    /// Share a plan cache with other engines (ablation arms, engine
    /// comparisons, restarts).
    pub fn plan_cache(mut self, cache: Arc<PlanCache>) -> EngineBuilder {
        self.plan_cache = Some(cache);
        self
    }

    /// Share a calibrated-plan cache with other engines — e.g. the report
    /// grids, which rebuild a calibrated engine per cell; sharing one
    /// cache makes revisited (device, model) cells free. Ignored when an
    /// artifact store is configured (the store-backed cache persists and
    /// already deduplicates).
    pub fn calibrated_cache(mut self, cache: Arc<CalibratedPlanCache>) -> EngineBuilder {
        self.shared_calibrated = Some(cache);
        self
    }

    /// Persist every expensive artifact — plans, calibrated plans,
    /// transformed weights — to a content-addressed
    /// [`ArtifactStore`] at `dir`: a later engine — including one in a
    /// fresh process — pointed at the same directory skips planning (and
    /// calibration) entirely, observable via [`Engine::store_stats`].
    /// Overrides [`EngineBuilder::plan_cache`].
    pub fn artifact_store(mut self, dir: impl Into<PathBuf>) -> EngineBuilder {
        self.store_dir = Some(dir.into());
        self
    }

    /// Share an already-open artifact store with other engines (ablation
    /// arms, serving routers, sibling processes' handles). Takes
    /// precedence over [`EngineBuilder::artifact_store`].
    pub fn artifact_store_shared(mut self, store: Arc<ArtifactStore>) -> EngineBuilder {
        self.shared_store = Some(store);
        self
    }

    /// Plan cold starts through cross-device transfer
    /// ([`crate::fleet::PlanTransfer`]): on a full plan-cache miss
    /// (memory and disk), look up the nearest-profile plan published in
    /// the store's fleet namespace and run the seeded search instead of
    /// the cold one; the engine's own results are published back for
    /// other devices. Requires an artifact store
    /// ([`EngineBuilder::artifact_store`] or
    /// [`EngineBuilder::artifact_store_shared`]) — without one there is
    /// nowhere to publish to or draw from, and the flag is a no-op.
    pub fn fleet_transfer(mut self, on: bool) -> EngineBuilder {
        self.fleet_transfer = on;
        self
    }

    /// Bound the artifact store opened by
    /// [`EngineBuilder::artifact_store`] to `bytes` total, evicting
    /// least-recently-used artifacts past the cap (ignored for shared or
    /// absent stores).
    pub fn store_cap_bytes(mut self, bytes: u64) -> EngineBuilder {
        self.store_cap = Some(bytes);
        self
    }

    /// Deprecated spelling of [`EngineBuilder::artifact_store`].
    #[deprecated(
        note = "use `artifact_store(dir)`: plans now persist through the unified \
                content-addressed ArtifactStore alongside calibrated plans and weights"
    )]
    pub fn plan_store(self, dir: impl Into<PathBuf>) -> EngineBuilder {
        self.artifact_store(dir)
    }

    /// Build the engine.
    ///
    /// Panics if no device was set or the artifact-store directory cannot
    /// be created; use [`EngineBuilder::try_build`] to handle a bad store
    /// path gracefully.
    pub fn build(self) -> Engine {
        self.try_build()
            .unwrap_or_else(|e| panic!("Engine::builder(): artifact store: {e}"))
    }

    /// [`EngineBuilder::build`], surfacing artifact-store I/O errors
    /// instead of panicking. Still panics if no device was set (a
    /// programming error, not an environment one).
    pub fn try_build(self) -> std::io::Result<Engine> {
        let dev = self
            .dev
            .expect("Engine::builder(): .device(..) is required");
        let store: Option<Arc<ArtifactStore>> = match (self.shared_store, self.store_dir) {
            (Some(s), _) => Some(s),
            (None, Some(dir)) => Some(Arc::new(match self.store_cap {
                Some(cap) => ArtifactStore::with_cap(dir, cap)?,
                None => ArtifactStore::open(dir)?,
            })),
            (None, None) => None,
        };
        let plan_cache = match &store {
            Some(s) => Arc::new(PlanCache::with_store(s.clone())),
            None => self.plan_cache.unwrap_or_default(),
        };
        let calibrated_cache = match (&store, self.shared_calibrated) {
            (Some(s), _) => Arc::new(CalibratedPlanCache::with_store(Some(s.clone()))),
            (None, Some(c)) => c,
            (None, None) => Arc::new(CalibratedPlanCache::new()),
        };
        let registry_tag = if self.registry.warm_only {
            "warm-default"
        } else {
            "full"
        };
        let fleet = match (&store, self.fleet_transfer) {
            (Some(s), true) => Some(Arc::new(PlanTransfer::new(s.clone()))),
            _ => None,
        };
        let (tenant_names, tenant_budgets): (Vec<String>, Vec<u64>) =
            self.tenant_budgets.into_iter().unzip();
        Ok(Engine {
            inner: Arc::new(Inner {
                dev,
                registry: self.registry,
                registry_tag,
                sched: self.sched,
                warmup_depth: self.warmup_depth,
                calibrated: self.calibrated,
                plan_cache,
                calibrated_cache,
                store,
                fleet,
                backend: self.backend.unwrap_or_else(|| Box::new(SimBackend::nnv12())),
                tenant_names,
                residency: Mutex::new(Residency::new(self.memory_budget, &tenant_budgets)),
                next_session: AtomicU64::new(0),
            }),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::profiles;
    use crate::graph::zoo;

    #[test]
    fn builder_defaults_and_load() {
        let engine = Engine::builder().device(profiles::meizu_16t()).build();
        assert_eq!(engine.backend_name(), "sim");
        let s = engine.load(zoo::tiny_net());
        assert_eq!(s.name(), "tinynet");
        assert!(s.cold_ms() > s.warm_ms());
        assert!(!s.is_resident());
        assert_eq!(engine.plan_cache().misses(), 1);
    }

    #[test]
    fn cloned_engines_share_state() {
        let a = Engine::builder().device(profiles::meizu_16t()).build();
        let b = a.clone();
        let s = a.load(zoo::tiny_net());
        assert_eq!(b.plan_cache().misses(), 1);
        s.infer();
        assert_eq!(b.mem_used(), s.resident_bytes());
        b.evict_all();
        assert!(!s.is_resident());
    }

    #[test]
    fn dropping_a_session_releases_residency() {
        let engine = Engine::builder().device(profiles::meizu_16t()).build();
        let s = engine.load(zoo::tiny_net());
        s.infer();
        assert!(engine.mem_used() > 0);
        drop(s);
        assert_eq!(engine.mem_used(), 0);
    }

    #[test]
    fn fleet_transfer_crosses_devices_through_the_store() {
        let dir = std::env::temp_dir().join(format!(
            "nnv12-engine-fleet-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        // First device: nothing to draw from — the cold search runs and
        // its plan is published into the fleet namespace.
        let a = Engine::builder()
            .device(profiles::meizu_16t())
            .artifact_store(&dir)
            .fleet_transfer(true)
            .build();
        a.load(zoo::tiny_net());
        let fa = a.fleet().expect("fleet handle when flag is set");
        assert_eq!((fa.hits(), fa.rejected(), fa.misses()), (0, 0, 1));

        // A different device over the same store is a full plan-cache
        // miss (different plan key), so the cold start goes through the
        // transfer path and finds the first device's published plan as a
        // donor — accepted or rejected, but never a donor miss.
        let b = Engine::builder()
            .device(profiles::pixel_5())
            .artifact_store(&dir)
            .fleet_transfer(true)
            .build();
        b.load(zoo::tiny_net());
        let fb = b.fleet().unwrap();
        assert_eq!(fb.misses(), 0, "the donor published by engine A must be found");
        assert_eq!(fb.hits() + fb.rejected(), 1);

        // Without the flag (or without a store) there is no fleet handle.
        assert!(Engine::builder()
            .device(profiles::meizu_16t())
            .fleet_transfer(true)
            .build()
            .fleet()
            .is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn sessions_infer_concurrently_from_many_threads() {
        // The substrate contract the serving layer builds on: one engine,
        // sessions driven from different threads, a consistent LRU
        // outcome. With an unbounded budget each session is cold exactly
        // once no matter the interleaving.
        let engine = Engine::builder().device(profiles::meizu_16t()).build();
        let sessions = engine.load_all(vec![zoo::tiny_net(), zoo::micro_mobilenet()]);
        let colds = std::sync::atomic::AtomicUsize::new(0);
        std::thread::scope(|scope| {
            for s in &sessions {
                for _ in 0..2 {
                    let colds = &colds;
                    scope.spawn(move || {
                        if s.infer().phase == Phase::Cold {
                            colds.fetch_add(1, Ordering::Relaxed);
                        }
                    });
                }
            }
        });
        assert_eq!(colds.load(Ordering::Relaxed), sessions.len());
        assert_eq!(engine.mem_used(), sessions.iter().map(|s| s.resident_bytes()).sum::<u64>());
    }

    /// The original Vec-based residency, retained verbatim as the
    /// executable specification for the O(1) map+list rewrite: same
    /// front-evicting LRU, same ladder-rung pricing, same transient
    /// overcommit for oversized models.
    struct VecResidency {
        budget: u64,
        mem_used: u64,
        resident: Vec<(u64, u64, usize)>,
    }

    impl VecResidency {
        fn warm_hit(&mut self, id: u64, ladder: &[Ms], warm_ms: Ms) -> Option<InferenceReport> {
            let pos = self.resident.iter().position(|(i, _, _)| *i == id)?;
            let (i, b, count) = self.resident.remove(pos);
            let idx = count + 1;
            let latency = ladder.get(idx).copied().unwrap_or(warm_ms);
            self.resident.push((i, b, count + 1));
            let phase = if latency.to_bits() == warm_ms.to_bits() {
                Phase::Warm
            } else {
                Phase::Warming { n: idx }
            };
            Some(InferenceReport { latency_ms: latency, phase, evictions: 0 })
        }

        fn charge(&mut self, id: u64, bytes: u64, ladder: &[Ms], warm_ms: Ms) -> InferenceReport {
            if let Some(report) = self.warm_hit(id, ladder, warm_ms) {
                return report;
            }
            let mut evictions = 0;
            while self.mem_used + bytes > self.budget && !self.resident.is_empty() {
                let (_, b, _) = self.resident.remove(0);
                self.mem_used -= b;
                evictions += 1;
            }
            self.mem_used += bytes;
            self.resident.push((id, bytes, 0));
            let latency = ladder.first().copied().unwrap_or(warm_ms);
            InferenceReport { latency_ms: latency, phase: Phase::Cold, evictions }
        }

        fn release(&mut self, id: u64) {
            if let Some(pos) = self.resident.iter().position(|(i, _, _)| *i == id) {
                let (_, b, _) = self.resident.remove(pos);
                self.mem_used -= b;
            }
        }
    }

    #[test]
    fn lru_matches_vec_reference_model() {
        // Randomized charge / warm / release traces: every report, the
        // memory accounting, and the full membership set must stay
        // bit-identical to the Vec specification — this is the parity
        // proof that lets tests/engine_facade.rs and
        // tests/concurrent_serving.rs gate the rewrite unchanged.
        crate::util::prop::check(0x1095_1de2, 200, |rng| {
            let budget = rng.range(1, 64) * 1024;
            let n = rng.index(10) as u64 + 2;
            let bytes: Vec<u64> = (0..n).map(|_| rng.range(1, 40) * 1024).collect();
            let ladder = [100.0, 50.0, 25.0, 10.0];
            let warm = 10.0;
            let mut new = Residency::new(budget, &[]);
            let mut old = VecResidency { budget, mem_used: 0, resident: Vec::new() };
            let steps = rng.range(1, 120);
            for step in 0..steps {
                let id = rng.index(n as usize) as u64;
                match rng.index(4) {
                    0 | 1 => {
                        let a = new.charge(id, bytes[id as usize], 0, &ladder, warm);
                        let b = old.charge(id, bytes[id as usize], &ladder, warm);
                        if a != b {
                            return Err(format!(
                                "step {step}: charge({id}) diverged: {a:?} vs {b:?}"
                            ));
                        }
                    }
                    2 => {
                        let a = new.warm_hit(id, &ladder, warm);
                        let b = old.warm_hit(id, &ladder, warm);
                        if a != b {
                            return Err(format!(
                                "step {step}: warm_hit({id}) diverged: {a:?} vs {b:?}"
                            ));
                        }
                    }
                    _ => {
                        new.release(id);
                        old.release(id);
                    }
                }
                if new.mem_used() != old.mem_used {
                    return Err(format!(
                        "step {step}: mem_used diverged: {} vs {}",
                        new.mem_used(),
                        old.mem_used
                    ));
                }
                for cand in 0..n {
                    let in_new = new.is_resident(cand);
                    let in_old = old.resident.iter().any(|(i, _, _)| *i == cand);
                    if in_new != in_old {
                        return Err(format!(
                            "step {step}: membership of {cand} diverged: {in_new} vs {in_old}"
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn tenant_lanes_isolate_eviction_storms() {
        // Under quota, tenant B's residents must survive ANY sequence of
        // tenant-A charges and releases — including oversized models that
        // repeatedly wipe A's own lane.
        crate::util::prop::check(0x7e41_a27b, 100, |rng| {
            let quota = rng.range(8, 64) * 1024;
            let mut r = Residency::new(u64::MAX, &[quota, quota]);
            let ladder = [100.0, 10.0];
            let nb = rng.index(5) + 1;
            let b_bytes = quota / nb as u64;
            let b_ids: Vec<u64> = (0..nb as u64).map(|i| 1000 + i).collect();
            for &id in &b_ids {
                let report = r.charge(id, b_bytes, 2, &ladder, 10.0);
                if report.evictions != 0 {
                    return Err("tenant B under quota must not self-evict".into());
                }
            }
            let b_used = r.lanes[2].used;
            let storm = rng.range(1, 200);
            for _ in 0..storm {
                let id = rng.index(16) as u64;
                if rng.chance(0.7) {
                    let bytes = rng.range(1, 4) * quota / 2;
                    r.charge(id, bytes, 1, &ladder, 10.0);
                } else {
                    r.release(id);
                }
            }
            for &id in &b_ids {
                if !r.is_resident(id) {
                    return Err(format!(
                        "tenant A's eviction storm cold-started tenant B's session {id}"
                    ));
                }
            }
            if r.lanes[2].used != b_used {
                return Err("tenant B's lane usage changed during tenant A's storm".into());
            }
            Ok(())
        });
    }

    #[test]
    fn engine_tenant_budgets_isolate() {
        // Tenant "a" gets a 1-byte quota so every inference is an
        // eviction storm in its own lane; tenant "b" must never notice.
        let engine = Engine::builder()
            .device(profiles::meizu_16t())
            .tenant_budget("a", 1)
            .tenant_budget("b", u64::MAX)
            .build();
        let a1 = engine.load_for_tenant(zoo::tiny_net(), "a");
        let a2 = engine.load_for_tenant(zoo::micro_mobilenet(), "a");
        let b = engine.load_for_tenant(zoo::tiny_net(), "b");
        assert_eq!(b.infer().phase, Phase::Cold);
        for _ in 0..4 {
            assert_eq!(a1.infer().phase, Phase::Cold, "1-byte quota must thrash a1");
            assert_eq!(a2.infer().phase, Phase::Cold, "1-byte quota must thrash a2");
        }
        assert!(b.is_resident());
        assert_ne!(b.infer().phase, Phase::Cold);
        assert_eq!(engine.tenant_mem_used("b"), Some(b.resident_bytes()));
        assert_eq!(engine.tenant_mem_used("nope"), None);
        assert_eq!(engine.tenants().len(), 2);
        assert_eq!(engine.tenants()[0], "a");
        assert_eq!(b.tenant(), Some("b"));
        assert_eq!(engine.load(zoo::tiny_net()).tenant(), None);
    }
}
