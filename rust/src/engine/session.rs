//! Per-model sessions: the explicit cold → warming → warm lifecycle.
//!
//! Sessions are `Send + Sync`: all shared mutable state (the engine's
//! intrusive LRU residency) is behind the engine's lock, and the
//! session's own
//! lazily computed warm-up ladder sits in a `OnceLock`, so one session
//! can serve `infer()` calls from many threads at once.

use std::sync::{Arc, OnceLock};

use crate::device::DeviceProfile;
use crate::engine::backend::{BackendCtx, ColdOutcome};
use crate::engine::Inner;
use crate::graph::ModelGraph;
use crate::sched::heuristic::{schedule, Scheduled, SchedulerConfig};
use crate::sched::plan::Plan;
use crate::warm::ContinuousReport;
use crate::Ms;

/// Where a session is in its warm-up lifecycle.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// The model was not resident: this inference paid the full cold path
    /// (reads, transforms/cache reads, pipelined execution).
    Cold,
    /// The `n`-th inference after a cold start, still above steady state
    /// while §3.5 kernel switching completes (`n` starts at 1).
    Warming { n: usize },
    /// Steady-state warm inference.
    Warm,
}

/// Outcome of one [`Session::infer`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct InferenceReport {
    pub latency_ms: Ms,
    pub phase: Phase,
    /// Sessions evicted from residency to make room for this one.
    pub evictions: usize,
}

/// A loaded model with its plan, warm-up ladder, and residency identity.
///
/// Created by [`crate::engine::Engine::load`]; holds a handle to its
/// engine, so sessions of one engine share the residency budget — an
/// inference on one session can evict another (the next inference on the
/// evicted session is [`Phase::Cold`] again). Dropping a session releases
/// its residency. Sessions are `Send + Sync` (wrap one in an `Arc` to
/// serve it from several threads, as the sharded
/// [`crate::serving::Router`] does).
pub struct Session {
    pub(crate) engine: Arc<Inner>,
    pub(crate) id: u64,
    pub(crate) graph: ModelGraph,
    /// The device view this session was planned against (differs from the
    /// engine's device only when calibration is on).
    pub(crate) dev: DeviceProfile,
    pub(crate) scheduled: Arc<Scheduled>,
    /// §3.5 warm-up ladder, computed through the backend on first use
    /// (plan-only consumers — `run_cold`, plan inspection — never pay for
    /// it). Per-session state owned by the session: concurrent first
    /// inferences of different models never contend on a shared lock.
    pub(crate) ladder: OnceLock<ContinuousReport>,
    /// Search-free fallback plan + its cold-makespan estimate, for the
    /// serving layer's degraded path (deadline misses, open breakers).
    /// Lazy: sessions that never degrade never pay for it.
    pub(crate) degraded: OnceLock<(Arc<Scheduled>, Ms)>,
    pub(crate) resident_bytes: u64,
    /// Residency lane this session charges: 0 for the shared engine-wide
    /// budget, `k + 1` for the engine's `k`-th declared tenant.
    pub(crate) lane: usize,
}

impl Session {
    /// The continuous-inference model for this session (lazy).
    fn ladder_report(&self) -> &ContinuousReport {
        self.ladder.get_or_init(|| {
            let ctx = BackendCtx {
                dev: &self.dev,
                graph: &self.graph,
                registry: &self.engine.registry,
                sched: &self.engine.sched,
                store: self.engine.store.as_ref(),
            };
            self.engine
                .backend
                .warm_ladder(&ctx, &self.scheduled, self.engine.warmup_depth)
        })
    }

    /// One inference request against this session: makes the model
    /// resident (evicting LRU sessions as needed), charges cold or
    /// warm-ladder latency, and reports the lifecycle phase.
    pub fn infer(&self) -> InferenceReport {
        let ladder = self.ladder_report();
        self.engine.charge(
            self.id,
            self.resident_bytes,
            self.lane,
            &ladder.latencies,
            ladder.warm_ms,
        )
    }

    /// Warm-only fast path: charge a warm-ladder inference if the model
    /// is currently resident, or return `None` without touching residency
    /// (a cold start is due). Serving uses this to run its cold-path
    /// policy — deadline check, admission, retries — *before* committing
    /// the residency charge via [`Session::infer`], which remains the
    /// single atomic cold/warm decision under races.
    pub fn infer_warm(&self) -> Option<InferenceReport> {
        let ladder = self.ladder_report();
        self.engine
            .charge_warm(self.id, &ladder.latencies, ladder.warm_ms)
    }

    /// Execute one full cold inference through the engine's backend
    /// (simulated with contention/stealing, or real execution), without
    /// touching residency state.
    pub fn run_cold(&self) -> Result<ColdOutcome, String> {
        let ctx = BackendCtx {
            dev: &self.dev,
            graph: &self.graph,
            registry: &self.engine.registry,
            sched: &self.engine.sched,
            store: self.engine.store.as_ref(),
        };
        self.engine.backend.run(&ctx, &self.scheduled)
    }

    /// The model graph this session serves.
    pub fn graph(&self) -> &ModelGraph {
        &self.graph
    }

    /// Model name (the residency/report key).
    pub fn name(&self) -> &str {
        &self.graph.name
    }

    /// The planned schedule (plan + op set + evaluated timings).
    pub fn scheduled(&self) -> &Arc<Scheduled> {
        &self.scheduled
    }

    /// The kernel scheduling plan.
    pub fn plan(&self) -> &Plan {
        &self.scheduled.plan
    }

    /// Device view the plan targets (recalibrated when the engine was
    /// built with calibration).
    pub fn device(&self) -> &DeviceProfile {
        &self.dev
    }

    /// Latency ladder `[cold, 2nd, …, steady warm]` of the §3.5
    /// continuous-inference model.
    pub fn ladder(&self) -> &[Ms] {
        &self.ladder_report().latencies
    }

    /// Planner's cold-latency estimate (first rung of the ladder; falls
    /// back to the warm latency if a custom backend returned no rungs).
    pub fn cold_ms(&self) -> Ms {
        let r = self.ladder_report();
        r.latencies.first().copied().unwrap_or(r.warm_ms)
    }

    /// Steady-state warm latency.
    pub fn warm_ms(&self) -> Ms {
        self.ladder_report().warm_ms
    }

    /// The degraded fallback: a search-free warm-default plan (the same
    /// shape baseline arms get) and its cold-makespan estimate under this
    /// session's backend. Computed once, on first degradation — skipping
    /// the kernel-combination search is the whole point of the path.
    fn degraded_plan(&self) -> &(Arc<Scheduled>, Ms) {
        self.degraded.get_or_init(|| {
            let cfg = SchedulerConfig {
                kernel_selection: false,
                weight_cache: false,
                pipeline: false,
                max_outer_passes: 0,
                ..self.engine.sched.clone()
            };
            let s = Arc::new(schedule(
                &self.dev,
                &self.graph,
                &self.engine.registry,
                &cfg,
            ));
            let ctx = BackendCtx {
                dev: &self.dev,
                graph: &self.graph,
                registry: &self.engine.registry,
                sched: &self.engine.sched,
                store: self.engine.store.as_ref(),
            };
            let ms = self.engine.backend.plan_makespan(&ctx, &s);
            (s, ms)
        })
    }

    /// Cold-latency estimate of the degraded (search-free) plan —
    /// what a request pays when served off the fallback path.
    pub fn degraded_cold_ms(&self) -> Ms {
        self.degraded_plan().1
    }

    /// Layers whose kernel is switched after cold inference (§3.5).
    pub fn switched_layers(&self) -> usize {
        self.ladder_report().switched_layers
    }

    /// Resident-set size charged against the engine's memory budget.
    pub fn resident_bytes(&self) -> u64 {
        self.resident_bytes
    }

    /// Whether the session currently occupies residency (a cold start is
    /// due when false).
    pub fn is_resident(&self) -> bool {
        self.engine.is_resident(self.id)
    }

    /// The tenant whose residency sub-budget this session charges, or
    /// `None` for a session on the shared engine-wide budget (see
    /// [`crate::engine::EngineBuilder::tenant_budget`]).
    pub fn tenant(&self) -> Option<&str> {
        self.lane
            .checked_sub(1)
            .map(|k| self.engine.tenant_names[k].as_str())
    }
}

impl Drop for Session {
    fn drop(&mut self) {
        self.engine.release(self.id);
    }
}
