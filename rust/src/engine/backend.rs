//! Pluggable execution backends for the [`crate::engine::Engine`].
//!
//! The engine drives one lifecycle — plan, cold-execute, warm up — but
//! *how* a planned model actually executes differs by deployment:
//! simulated on a modelled device (the evaluation path), charged from a
//! baseline engine's cost model (comparison arms), or executed for real
//! through PJRT (the `real-runtime` feature). [`ExecBackend`] is that
//! seam: callers pick a backend once at
//! [`crate::engine::EngineBuilder::backend`] and never change code.
//!
//! Backends must be `Send + Sync` (supertraits of [`ExecBackend`]): the
//! engine is shared across serving threads and calls `run`/`warm_ladder`
//! without any engine-level lock. [`SimBackend`] and [`BaselineBackend`]
//! are stateless value types, trivially `Sync`. `RealBackend` owns a
//! PJRT client that is *not* thread-safe (`Rc`/`RefCell` internals), so
//! it is `Sync` by **thread confinement**: the client lives on a
//! dedicated executor thread, lazily spawned, and `run` calls from any
//! thread post a job over a channel and block on the reply — requests
//! from N serving threads serialize at the one real device exactly like
//! they would on real hardware.

use std::sync::Arc;

use crate::baselines;
use crate::device::DeviceProfile;
use crate::faults::FaultPlan;
use crate::graph::ModelGraph;
use crate::kernels::Registry;
use crate::sched::heuristic::{Scheduled, SchedulerConfig};
use crate::sched::makespan::OpTiming;
use crate::sched::price::Pricer;
use crate::sim::{simulate, SimConfig};
use crate::warm::{continuous_from, ContinuousReport};
use crate::Ms;

/// Everything a backend may need about the model it is running: the
/// session's device view (recalibrated when the engine is calibrated),
/// the graph, the kernel registry, the scheduler knobs in force, and the
/// engine's shared artifact store (if one is configured) so real
/// execution can route its transformed-weights cache through the same
/// capped, counted store as plans.
pub struct BackendCtx<'a> {
    pub dev: &'a DeviceProfile,
    pub graph: &'a ModelGraph,
    pub registry: &'a Registry,
    pub sched: &'a SchedulerConfig,
    pub store: Option<&'a std::sync::Arc<crate::store::ArtifactStore>>,
}

/// Result of one cold inference executed by a backend.
#[derive(Debug, Clone)]
pub struct ColdOutcome {
    /// End-to-end cold latency.
    pub latency_ms: Ms,
    /// Energy over the cold inference (0 when the backend does not model
    /// energy).
    pub energy_mj: f64,
    /// Ops moved off their planned unit by workload stealing.
    pub steals: usize,
    /// Per-op timings indexed by `OpId` (empty when the backend does not
    /// produce an op-level trace).
    pub timings: Vec<OpTiming>,
}

/// How a planned model executes. Implementations must be deterministic in
/// their inputs where they model latency (the plan store and the parity
/// tests rely on it); a real backend reports measured wall time instead.
///
/// `Send + Sync` is part of the contract: the engine invokes backends
/// from arbitrary serving threads with no lock of its own. Backends with
/// thread-bound resources must confine them internally (see
/// `RealBackend`'s executor thread) rather than leak `!Sync` state.
pub trait ExecBackend: Send + Sync {
    /// Backend name for logs and reports.
    fn name(&self) -> &'static str;

    /// Whether this backend consumes the NNV12 plan. When `false`
    /// (baseline engines, which charge their own cost model), the engine
    /// skips the kernel-combination search at load time and attaches a
    /// cheap warm-default sequential plan to the session instead.
    fn needs_plan(&self) -> bool {
        true
    }

    /// Cold-makespan estimate of a planned model under this backend,
    /// without executing it (the planner's objective view).
    fn plan_makespan(&self, ctx: &BackendCtx, s: &Scheduled) -> Ms;

    /// Execute one cold inference of the planned model.
    fn run(&self, ctx: &BackendCtx, s: &Scheduled) -> Result<ColdOutcome, String>;

    /// Latency ladder of `depth` consecutive inferences starting cold
    /// (§3.5 kernel switching). The default derives it from the plan via
    /// the continuous-inference model; backends with their own warm story
    /// (baseline engines) override. Implementations should return at
    /// least one rung (the cold latency); an empty ladder makes the
    /// residency manager fall back to `warm_ms` for every inference.
    fn warm_ladder(&self, ctx: &BackendCtx, s: &Scheduled, depth: usize) -> ContinuousReport {
        continuous_from(ctx.dev, ctx.graph, ctx.registry, depth, s)
    }
}

/// The simulated-device backend: executes plans on the discrete-event
/// simulator with bandwidth contention and workload stealing
/// ([`crate::sim`]). This is the default backend and the one every paper
/// figure uses.
#[derive(Debug, Clone)]
pub struct SimBackend {
    pub cfg: SimConfig,
    faults: Option<Arc<FaultPlan>>,
}

impl SimBackend {
    /// NNV12 runtime defaults: stealing on, contention on.
    pub fn nnv12() -> SimBackend {
        SimBackend { cfg: SimConfig::nnv12(), faults: None }
    }

    /// A simulator backend with explicit knobs (ablations, background
    /// load experiments).
    pub fn with(cfg: SimConfig) -> SimBackend {
        SimBackend { cfg, faults: None }
    }

    /// Inject a deterministic fault plan: every [`ExecBackend::run`]
    /// consults it and may fail or panic on cue (chaos tests). Zero cost
    /// when unset.
    pub fn with_faults(mut self, plan: Arc<FaultPlan>) -> SimBackend {
        self.faults = Some(plan);
        self
    }
}

impl Default for SimBackend {
    fn default() -> SimBackend {
        SimBackend::nnv12()
    }
}

impl ExecBackend for SimBackend {
    fn name(&self) -> &'static str {
        "sim"
    }

    fn plan_makespan(&self, _ctx: &BackendCtx, s: &Scheduled) -> Ms {
        s.schedule.makespan
    }

    fn run(&self, ctx: &BackendCtx, s: &Scheduled) -> Result<ColdOutcome, String> {
        if let Some(f) = &self.faults {
            f.exec_check()?;
        }
        let pricer = Pricer::new(ctx.dev, ctx.graph, &s.plan.choices, ctx.sched.shader_cache);
        let r = simulate(ctx.dev, &s.set, &s.plan, &pricer, &self.cfg);
        Ok(ColdOutcome {
            latency_ms: r.makespan,
            energy_mj: r.energy_mj,
            steals: r.steals,
            timings: r.timings,
        })
    }
}

/// A comparison backend that charges the latencies of a vanilla engine
/// (ncnn, TFLite, …) from [`crate::baselines`]. It ignores the NNV12
/// plan: the point is serving the same workload through a baseline for
/// side-by-side numbers (Fig. 8/10, the serving comparisons).
#[derive(Debug, Clone)]
pub struct BaselineBackend {
    pub engine: baselines::Engine,
    faults: Option<Arc<FaultPlan>>,
}

impl BaselineBackend {
    pub fn new(engine: baselines::Engine) -> BaselineBackend {
        BaselineBackend { engine, faults: None }
    }

    pub fn ncnn() -> BaselineBackend {
        BaselineBackend::new(baselines::Engine::Ncnn)
    }

    /// Inject a deterministic fault plan (see [`SimBackend::with_faults`]).
    pub fn with_faults(mut self, plan: Arc<FaultPlan>) -> BaselineBackend {
        self.faults = Some(plan);
        self
    }
}

impl ExecBackend for BaselineBackend {
    fn name(&self) -> &'static str {
        "baseline"
    }

    fn needs_plan(&self) -> bool {
        false
    }

    fn plan_makespan(&self, ctx: &BackendCtx, _s: &Scheduled) -> Ms {
        baselines::cold_ms(self.engine, ctx.dev, ctx.graph)
    }

    fn run(&self, ctx: &BackendCtx, s: &Scheduled) -> Result<ColdOutcome, String> {
        if let Some(f) = &self.faults {
            f.exec_check()?;
        }
        Ok(ColdOutcome {
            latency_ms: self.plan_makespan(ctx, s),
            energy_mj: 0.0,
            steals: 0,
            timings: Vec::new(),
        })
    }

    fn warm_ladder(&self, ctx: &BackendCtx, _s: &Scheduled, _depth: usize) -> ContinuousReport {
        let cold = baselines::cold_ms(self.engine, ctx.dev, ctx.graph);
        let warm = baselines::warm_ms(self.engine, ctx.dev, ctx.graph);
        ContinuousReport {
            latencies: vec![cold, warm],
            warm_ms: warm,
            switched_layers: 0,
        }
    }
}

/// One unit of real execution posted to the executor thread: everything
/// it needs, owned (the thread outlives any one `run` call's borrows).
#[cfg(feature = "real-runtime")]
struct RealJob {
    dir: std::path::PathBuf,
    opts: crate::pipeline::RealRunOpts,
    faults: Option<Arc<FaultPlan>>,
    reply: std::sync::mpsc::Sender<Result<ColdOutcome, String>>,
}

/// The real-execution backend: cold inference over AOT HLO artifacts
/// through the PJRT runtime and the pipelined executor
/// ([`crate::runtime`] + [`crate::pipeline`]). Artifacts for a model
/// named `m` are expected under `<artifacts_root>/m` (as produced by
/// `make artifacts`). `plan_makespan` still reports the modelled
/// estimate; [`ExecBackend::run`] reports measured wall time.
///
/// # Thread confinement
///
/// The PJRT [`crate::runtime::Runtime`] is deliberately single-threaded
/// (`Rc`-cached executables, one device stream), so `RealBackend` never
/// touches it from the caller's thread. Instead it lazily spawns one
/// **executor thread** that owns the runtime for the backend's lifetime;
/// [`ExecBackend::run`] posts a job over a channel and blocks on
/// the reply. That makes the backend itself `Send + Sync` (asserted at
/// compile time in `tests/real_mode.rs`) while keeping every PJRT call
/// on one thread — concurrent serving threads queue at the single real
/// device, as they would on hardware. Dropping the backend closes the
/// channel and the executor thread exits.
#[cfg(feature = "real-runtime")]
pub struct RealBackend {
    pub artifacts_root: std::path::PathBuf,
    pub opts: crate::pipeline::RealRunOpts,
    faults: Option<Arc<FaultPlan>>,
    executor: std::sync::Mutex<Option<std::sync::mpsc::Sender<RealJob>>>,
}

#[cfg(feature = "real-runtime")]
impl RealBackend {
    pub fn new(
        artifacts_root: impl Into<std::path::PathBuf>,
        opts: crate::pipeline::RealRunOpts,
    ) -> RealBackend {
        RealBackend {
            artifacts_root: artifacts_root.into(),
            opts,
            faults: None,
            executor: std::sync::Mutex::new(None),
        }
    }

    /// Inject a deterministic fault plan. The check runs *on the executor
    /// thread*, so an injected [`crate::faults::FaultKind::ExecPanic`]
    /// kills that thread exactly like a PJRT panic would — the respawn
    /// test drives the PR 5 healing path through this hook.
    pub fn with_faults(mut self, plan: Arc<FaultPlan>) -> RealBackend {
        self.faults = Some(plan);
        self
    }

    /// The executor-thread body: owns the (lazily created) PJRT runtime
    /// and serves jobs until the backend drops its channel sender.
    fn executor_loop(rx: std::sync::mpsc::Receiver<RealJob>) {
        use crate::runtime::Runtime;
        let mut runtime: Option<Runtime> = None;
        while let Ok(job) = rx.recv() {
            let result = (|| -> Result<ColdOutcome, String> {
                if let Some(f) = &job.faults {
                    // May return Err (transient) or panic — a panic
                    // unwinds this thread and drops `rx`, exercising the
                    // caller-side respawn path.
                    f.exec_check()?;
                }
                if runtime.is_none() {
                    runtime = Some(Runtime::cpu().map_err(|e| format!("{e:#}"))?);
                }
                Self::execute(&job, runtime.as_ref().unwrap())
            })();
            // A dropped reply receiver means the caller gave up; the
            // executor just moves on to the next job.
            let _ = job.reply.send(result);
        }
    }

    /// One real cold inference, on the executor thread.
    fn execute(
        job: &RealJob,
        runtime: &crate::runtime::Runtime,
    ) -> Result<ColdOutcome, String> {
        use crate::graph::manifest::Manifest;
        use crate::pipeline::run_cold;
        use crate::weights::read_f32;

        let dir = &job.dir;
        let manifest = Manifest::load(dir).map_err(|e| format!("{e:#}"))?;
        // Prefer the build-time fixture input; fall back to zeros shaped
        // like the first real layer's input (artifact 0 is the input
        // layer when present).
        let input: Vec<f32> = match &manifest.fixture_input {
            Some(p) => read_f32(&manifest.resolve(p)).map_err(|e| format!("{e:#}"))?,
            None => {
                let arts = &manifest.artifacts;
                let first = arts
                    .get(1)
                    .or_else(|| arts.first())
                    .ok_or_else(|| format!("{dir:?}: manifest has no layer artifacts"))?;
                let n: i64 = first.in_dims.iter().product();
                vec![0.0; n as usize]
            }
        };
        let r = run_cold(&manifest, runtime, &input, &job.opts).map_err(|e| format!("{e:#}"))?;
        Ok(ColdOutcome {
            latency_ms: r.wall_ms,
            energy_mj: 0.0,
            steals: 0,
            timings: Vec::new(),
        })
    }
}

#[cfg(feature = "real-runtime")]
impl ExecBackend for RealBackend {
    fn name(&self) -> &'static str {
        "real"
    }

    fn plan_makespan(&self, _ctx: &BackendCtx, s: &Scheduled) -> Ms {
        s.schedule.makespan
    }

    fn run(&self, ctx: &BackendCtx, _s: &Scheduled) -> Result<ColdOutcome, String> {
        // Route the weights cache through the engine's shared artifact
        // store (size cap + counters) unless the caller pinned one;
        // `cache_dir` remains the store-less fallback.
        let mut opts = self.opts.clone();
        if opts.store.is_none() {
            opts.store = ctx.store.cloned();
        }
        let (reply_tx, reply_rx) = std::sync::mpsc::channel();
        let job = RealJob {
            dir: self.artifacts_root.join(&ctx.graph.name),
            opts,
            faults: self.faults.clone(),
            reply: reply_tx,
        };
        {
            let mut slot = self.executor.lock().unwrap();
            let mut job = job;
            loop {
                let fresh = slot.is_none();
                let tx = slot.get_or_insert_with(|| {
                    let (tx, rx) = std::sync::mpsc::channel();
                    std::thread::Builder::new()
                        .name("nnv12-real-executor".into())
                        .spawn(move || RealBackend::executor_loop(rx))
                        .expect("spawn real-backend executor thread");
                    tx
                });
                match tx.send(job) {
                    Ok(()) => break,
                    // The cached executor died (a panic on an earlier job
                    // dropped its receiver). Clear the stale sender so the
                    // backend heals: retry once on a freshly spawned
                    // executor instead of failing every future run.
                    Err(std::sync::mpsc::SendError(returned)) => {
                        *slot = None;
                        if fresh {
                            return Err(
                                "real-backend executor thread died on spawn".to_string()
                            );
                        }
                        job = returned;
                    }
                }
            }
        }
        reply_rx
            .recv()
            .map_err(|_| "real-backend executor dropped the reply".to_string())?
    }
}
