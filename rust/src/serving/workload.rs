//! Multi-tenant workload generation: open-loop Poisson arrivals, Zipf
//! popularity, optional per-request deadlines.
//!
//! Models the paper's motivating environment — many DNN-backed app features
//! invoked at different rates (voice assistant, OCR, camera filters…) on
//! one device. Popularity skew is what makes cold inference frequent: the
//! long tail gets evicted between invocations.
//!
//! Arrivals are **open-loop**: [`Request::at_ms`] is when the request
//! fires regardless of whether earlier ones finished.
//! [`crate::serving::Router::replay`] ignores arrival times (throughput
//! mode); [`crate::serving::Router::replay_open_loop`] honors them, which
//! is what makes latency percentiles under load meaningful. A request's
//! [`Request::deadline_ms`] feeds the router's degradation policy: a cold
//! start whose §3.5 estimate exceeds the deadline is served degraded.

use crate::util::rng::Rng;
use crate::Ms;

/// One inference request.
#[derive(Debug, Clone, PartialEq)]
pub struct Request {
    /// Arrival time, ms since session start (open-loop).
    pub at_ms: f64,
    pub model: String,
    /// Latency budget for this request, if any: the router degrades a
    /// cold start that cannot meet it. `None` = no deadline.
    pub deadline_ms: Option<Ms>,
    /// Requesting tenant, if any: the router attributes the outcome to
    /// this tenant's per-tenant counters ([`crate::serving::TenantStats`]).
    /// `None` attributes to the serving model's owning tenant, if it has
    /// one.
    pub tenant: Option<String>,
}

/// Workload parameters.
#[derive(Debug, Clone)]
pub struct WorkloadSpec {
    /// Mean inter-arrival time across all models, ms.
    pub mean_interarrival_ms: f64,
    /// Zipf skew (0 = uniform; ~1 = strong skew).
    pub zipf_s: f64,
    pub n_requests: usize,
    pub seed: u64,
    /// Deadline stamped on every generated request (`None` = no
    /// deadlines, the default).
    pub deadline_ms: Option<Ms>,
    /// Number of tenants to stamp requests with (0 = untenanted, the
    /// default). Model index `i` requests as `tenant-{i % tenants}` —
    /// the same round-robin assignment
    /// [`crate::serving::RouterConfig::tenants`] uses to partition the
    /// fleet, so generated traffic matches model ownership.
    pub tenants: usize,
}

impl Default for WorkloadSpec {
    fn default() -> WorkloadSpec {
        WorkloadSpec {
            mean_interarrival_ms: 500.0,
            zipf_s: 0.9,
            n_requests: 200,
            seed: 42,
            deadline_ms: None,
            tenants: 0,
        }
    }
}

/// Generate a request trace over `models` (popularity follows their order:
/// first = most popular).
pub fn generate(models: &[String], spec: &WorkloadSpec) -> Vec<Request> {
    assert!(!models.is_empty());
    let mut rng = Rng::new(spec.seed);
    // Zipf CDF.
    let weights: Vec<f64> = (1..=models.len())
        .map(|r| 1.0 / (r as f64).powf(spec.zipf_s))
        .collect();
    let total: f64 = weights.iter().sum();
    let mut cdf = Vec::with_capacity(weights.len());
    let mut acc = 0.0;
    for w in &weights {
        acc += w / total;
        cdf.push(acc);
    }
    let mut t = 0.0;
    let mut out = Vec::with_capacity(spec.n_requests);
    for _ in 0..spec.n_requests {
        t += rng.exponential(spec.mean_interarrival_ms);
        let u = rng.f64();
        let idx = cdf.iter().position(|&c| u <= c).unwrap_or(models.len() - 1);
        out.push(Request {
            at_ms: t,
            model: models[idx].clone(),
            deadline_ms: spec.deadline_ms,
            tenant: (spec.tenants > 0).then(|| format!("tenant-{}", idx % spec.tenants)),
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn names() -> Vec<String> {
        vec!["a".into(), "b".into(), "c".into(), "d".into()]
    }

    #[test]
    fn deterministic_and_sized() {
        let spec = WorkloadSpec::default();
        let w1 = generate(&names(), &spec);
        let w2 = generate(&names(), &spec);
        assert_eq!(w1, w2);
        assert_eq!(w1.len(), spec.n_requests);
        // Arrival times strictly increasing.
        for pair in w1.windows(2) {
            assert!(pair[1].at_ms > pair[0].at_ms);
        }
    }

    #[test]
    fn zipf_skews_toward_head() {
        let spec = WorkloadSpec { n_requests: 2000, zipf_s: 1.0, ..Default::default() };
        let w = generate(&names(), &spec);
        let count = |m: &str| w.iter().filter(|r| r.model == m).count();
        assert!(count("a") > count("d") * 2, "a={} d={}", count("a"), count("d"));
    }

    #[test]
    fn deadlines_stamp_every_request() {
        let spec = WorkloadSpec { deadline_ms: Some(12.5), ..Default::default() };
        assert!(generate(&names(), &spec)
            .iter()
            .all(|r| r.deadline_ms == Some(12.5)));
        assert!(generate(&names(), &WorkloadSpec::default())
            .iter()
            .all(|r| r.deadline_ms.is_none()));
    }

    #[test]
    fn tenants_stamp_by_model_index() {
        let spec = WorkloadSpec { tenants: 2, n_requests: 500, ..Default::default() };
        let w = generate(&names(), &spec);
        // names() is [a, b, c, d]: even indices -> tenant-0, odd -> tenant-1.
        for r in &w {
            let expect = match r.model.as_str() {
                "a" | "c" => "tenant-0",
                _ => "tenant-1",
            };
            assert_eq!(r.tenant.as_deref(), Some(expect), "model {}", r.model);
        }
        assert!(generate(&names(), &WorkloadSpec::default())
            .iter()
            .all(|r| r.tenant.is_none()));
    }

    #[test]
    fn uniform_when_s_zero() {
        let spec = WorkloadSpec { n_requests: 4000, zipf_s: 0.0, ..Default::default() };
        let w = generate(&names(), &spec);
        let count = |m: &str| w.iter().filter(|r| r.model == m).count() as f64;
        let ratio = count("a") / count("d");
        assert!((0.8..1.25).contains(&ratio), "ratio {ratio}");
    }
}
