//! Multi-tenant serving front: request router + model residency manager.
//!
//! The paper's motivation (§1–2): edge devices host many DNNs; memory
//! pressure means models cannot all stay resident, so inferences are cold
//! whenever the OS or the app evicted the model. This module builds that
//! environment: a router dispatches per-model requests; an LRU residency
//! manager holds models within a memory budget; a request against a
//! non-resident model pays the cold-inference latency of whichever engine
//! is configured (NNV12's scheduled plan or a baseline), while resident
//! models serve at warm latency — including NNV12's §3.5 kernel-switching
//! warm-up sequence for the first post-cold inferences.

pub mod router;
pub mod workload;

pub use router::{Router, RouterConfig, ServedModel};
pub use workload::{generate, Request, WorkloadSpec};
