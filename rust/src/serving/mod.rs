//! Multi-tenant serving front over the engine facade — concurrent,
//! deadline-aware, and survivable.
//!
//! The paper's motivation (§1–2): edge devices host many DNNs; memory
//! pressure means models cannot all stay resident, so inferences are cold
//! whenever the OS or the app evicted the model. This module builds that
//! environment on top of [`crate::engine`]: a [`Router`] names one
//! [`crate::engine::Session`] per model and dispatches requests to it,
//! while the engine's residency manager holds sessions within the memory
//! budget — a request against a non-resident model pays the cold latency
//! of whichever backend is configured (NNV12's scheduled plan via
//! [`crate::engine::SimBackend`], or [`crate::engine::BaselineBackend`]
//! for a vanilla engine), and resident models serve down the §3.5
//! kernel-switching warm-up ladder. [`workload`] generates the
//! Zipf-skewed, open-loop Poisson request streams the serving
//! experiments replay, with optional per-request deadlines and tenant
//! stamps.
//!
//! # Tenancy
//!
//! "Multi-tenant" is structural, not just a label: with
//! [`RouterConfig::tenants`]` = K`, the model fleet is partitioned
//! round-robin across tenants `tenant-0 … tenant-{K-1}`, each holding an
//! equal share of the residency budget as its own LRU lane in the engine
//! ([`crate::engine::EngineBuilder::tenant_budget`]). Quota enforcement
//! happens at eviction time inside the engine — one tenant thrashing its
//! quota can never cold-start another tenant's resident models — while
//! the router adds per-tenant *attribution*: every request lands in a
//! [`TenantStats`] row of [`RouterStats::per_tenant`] (explicit
//! [`Request::tenant`] first, else the serving model's owner), so a
//! fleet operator can read per-tenant cold/warm/shed columns off one
//! summary. `repro serve --models N --tenants K` prints that table.
//!
//! # The failure model: offload → degrade → queue → shed → fail
//!
//! Cold starts are where serving failures concentrate, so the cold path
//! is policy-gated (ISSUE 6, extended by ISSUE 8). Every request
//! resolves to exactly one [`Outcome`], and the counters in
//! [`RouterStats`] conserve:
//! `cold + warm + degraded + offloaded + shed + failed == issued`.
//!
//! * **Served / [`ServeClass::Warm`]** — resident model, ladder rung.
//!   Never gated.
//! * **Served / [`ServeClass::Cold`]** — a cold start that passed every
//!   gate; executed with bounded, seeded-backoff retries when
//!   [`RouterConfig::execute_cold`] is on.
//! * **Served / [`ServeClass::Offloaded`]** — the deadline was tighter
//!   than the cold estimate, the model has early exits, and
//!   [`RouterConfig::offload`] (an [`OffloadPolicy`]) priced running the
//!   head locally and shipping the conditional tail to a remote inside
//!   the deadline: serve at that expected latency, residency untouched.
//!   An injected send fault (`FaultKind::OffloadDrop`) falls back to the
//!   degraded path, counted in `degraded_offload`.
//! * **Served / [`ServeClass::Degraded`]** — the request's deadline was
//!   tighter than the §3.5 cold estimate (and offload was off or
//!   infeasible), or the model's circuit breaker is open: serve the
//!   search-free baseline-shaped plan instead, without touching
//!   residency. `degraded == degraded_deadline + degraded_breaker +
//!   degraded_offload` in the stats.
//! * **[`Outcome::Shed`]** — the per-shard budget of in-flight cold
//!   starts ([`RouterConfig::admission`]) was exhausted: explicit
//!   backpressure instead of unbounded queueing. With
//!   [`RouterConfig::queue_depth`] set, up to that many requests per
//!   shard first *wait* for a slot instead of shedding immediately —
//!   counted by the non-terminal `queued` stat — and only an overfull
//!   waiting room sheds.
//! * **[`Outcome::Failed`]** — every retry failed (backend panics are
//!   caught at the router boundary and counted in `exec_panics`).
//!
//! The per-model **circuit breaker** walks Closed → Open (after
//! [`BreakerPolicy::threshold`] consecutive attempt failures) → HalfOpen
//! (after a [`BreakerPolicy::cooldown`]-request count-based cooldown) and
//! back: a successful half-open probe closes it, a failed probe reopens
//! it. Open means requests short-circuit to the degraded path — the
//! router keeps serving while the backend is sick.
//!
//! The router is **concurrent**: it is `Send + Sync`, entries live in a
//! sharded map, [`Router::request`] takes `&self`, [`Router::replay`]
//! fans a trace across N serving threads, and
//! [`Router::replay_open_loop`] fires requests at their trace arrival
//! times to measure sojourn percentiles under load. The hot path is
//! O(1) end to end at fleet scale: session lookup is a sharded hash map,
//! the engine's residency charge is a hash lookup plus an intrusive-list
//! splice, and latency recording hits a per-shard
//! [`crate::metrics::Recorder`] with indexed labels (merged on read) —
//! no linear scans over the model population anywhere. Chaos coverage
//! lives in `tests/chaos_serving.rs`, driven by
//! [`crate::faults::FaultPlan`]; the happy path is benchmarked by
//! `benches/serving_throughput.rs` and the thousand-model fleet by
//! `benches/serve_1000.rs`, both ratcheted in CI (4-thread throughput
//! must beat 1-thread in the same run, with zero shed on the fault-free
//! traces). See [`router`]'s module docs for the locking design and the
//! full taxonomy.

pub mod router;
pub mod workload;

// Re-exported so serving callers configure offload next to the router
// knobs it gates; the policy itself (and the estimate arithmetic) lives
// with the rest of the early-exit machinery in [`crate::exits`].
pub use crate::exits::OffloadPolicy;
pub use router::{
    BreakerPolicy, Outcome, RetryPolicy, Router, RouterConfig, RouterStats, ServeClass,
    ServeEngine, Served, TenantStats,
};
pub use workload::{generate, Request, WorkloadSpec};
