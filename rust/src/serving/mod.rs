//! Multi-tenant serving front over the engine facade.
//!
//! The paper's motivation (§1–2): edge devices host many DNNs; memory
//! pressure means models cannot all stay resident, so inferences are cold
//! whenever the OS or the app evicted the model. This module builds that
//! environment on top of [`crate::engine`]: a [`Router`] names one
//! [`crate::engine::Session`] per model and dispatches requests to it,
//! while the engine's residency manager holds sessions within the memory
//! budget — a request against a non-resident model pays the cold latency
//! of whichever backend is configured (NNV12's scheduled plan via
//! [`crate::engine::SimBackend`], or [`crate::engine::BaselineBackend`]
//! for a vanilla engine), and resident models serve down the §3.5
//! kernel-switching warm-up ladder. [`workload`] generates the
//! Zipf-skewed request streams the serving experiments replay.
//!
//! The router is **concurrent**: it is `Send + Sync`, sessions live in a
//! sharded map, [`Router::request`] takes `&self`, and
//! [`Router::replay`] fans a request trace across N serving threads —
//! the many-requests-at-once environment the ROADMAP's north star
//! demands, measured by `benches/serving_throughput.rs` and ratcheted in
//! CI (4-thread throughput must beat 1-thread in the same run). See
//! [`router`]'s module docs for the locking design.

pub mod router;
pub mod workload;

pub use router::{Outcome, Router, RouterConfig, ServeEngine};
pub use workload::{generate, Request, WorkloadSpec};
