//! The request router: a sharded, concurrent, *survivable* serving front
//! over the engine facade.
//!
//! All planning, warm-up-ladder computation, and LRU residency live in
//! [`crate::engine`]; the router contributes the per-model request
//! surface, the failure-handling policy, request statistics, and the
//! engine-choice knob (NNV12 vs a vanilla baseline) used by the serving
//! comparisons.
//!
//! # Failure model (ISSUE 6, extended by ISSUE 8)
//!
//! Cold starts are where serving failures concentrate — slow or corrupt
//! artifact reads, transient backend errors, overload from eviction
//! storms — so the cold path is policy-gated. Every request resolves to
//! exactly one of six outcomes, and the **conservation invariant**
//!
//! ```text
//! cold + warm + degraded + offloaded + shed + failed == issued
//! ```
//!
//! holds at all times ([`RouterStats::conserves`], asserted under
//! injected faults by `tests/chaos_serving.rs`):
//!
//! * **Warm** — the model was resident; charge the next §3.5 warm-up
//!   ladder rung. Never gated: warm service stays cheap and infallible.
//! * **Cold** — a cold start that passed every gate. With
//!   [`RouterConfig::execute_cold`] the backend executes it, with bounded
//!   exponential-backoff retries on transient failure (deterministic,
//!   seeded jitter; backoff is charged to the reported latency, never
//!   slept).
//! * **Degraded** — served off the session's search-free baseline plan,
//!   without touching residency, because (a) the request's deadline is
//!   tighter than the ladder's cold estimate, or (b) the model's circuit
//!   breaker is open. Deliberately cheap: no plan search, no backend
//!   execution, no retries.
//! * **Offloaded** — a *multi-exit* model whose local cold estimate
//!   missed the deadline, but whose head-local / tail-remote split
//!   ([`crate::exits::offload_estimate`] under
//!   [`RouterConfig::offload`]) fits it: the head serves locally, the
//!   conditional tail ships to the simulated remote, and the request is
//!   charged the deterministic expected offload latency. Residency is
//!   untouched, like the degraded path. An injected
//!   [`crate::faults::FaultKind::OffloadDrop`] on the send falls back to
//!   the degraded path (counted under `degraded_offload`).
//! * **Shed** — the per-shard admission budget of in-flight cold starts
//!   ([`RouterConfig::admission`]) was exhausted *and* the bounded wait
//!   queue ([`RouterConfig::queue_depth`], default off) was full or
//!   disabled; refuse explicitly rather than queueing unboundedly. A
//!   request that does wait for a slot is counted by the non-terminal
//!   `queued` statistic and then resolves normally.
//! * **Failed** — every retry of a cold execution failed. The error
//!   string of the last attempt is reported; a backend *panic* is caught
//!   at the router boundary and counted like a failure (no panic ever
//!   escapes [`Router::request`]).
//!
//! Per-model **circuit breaker**: after
//! [`BreakerPolicy::threshold`] consecutive cold-execution failures the
//! breaker opens and requests short-circuit to the degraded path for
//! [`BreakerPolicy::cooldown`] requests (a count-based cooldown keeps
//! replays deterministic); the next request after cooldown runs as a
//! half-open probe — success closes the breaker, failure reopens it.
//!
//! # Threading model
//!
//! [`Router`] is `Send + Sync` and [`Router::request`] takes `&self`:
//! share one router across N serving threads (an `Arc`, a scoped
//! borrow — either works) and hammer it. Internally:
//!
//! * The model → entry map is a **hand-rolled sharded hash map**
//!   (`SHARDS` `Mutex<HashMap<..>>` buckets keyed by a hash of the model
//!   name — the vendored crate set has no `DashMap`, and doesn't need
//!   one). A request locks exactly one shard just long enough to clone
//!   the entry's `Arc`, then serves **outside** the lock. Shards exist
//!   because the map is mutable at runtime ([`Router::register`] /
//!   [`Router::remove`] add and retire models while requests are in
//!   flight), and the admission budget is tracked per shard.
//! * Request counters are atomics; latency observations go to
//!   **per-shard [`Recorder`]s** (one small `Mutex` each, keyed by the
//!   same model-name hash as the entry map, merged on read), so
//!   recording scales with the shard count instead of serializing every
//!   request on one global recorder lock — and the per-model composite
//!   label is matched allocation-free on the hot path
//!   ([`Recorder::record_scoped`]). The locks are never held across
//!   inference work and never exposed as guards:
//!   [`Router::latency_summary`] and [`Router::recorded`] merge the
//!   shards into snapshots. Breaker state is a tiny per-model `Mutex`.
//! * Per-tenant outcome counters ([`RouterStats::per_tenant`]) are
//!   atomics indexed by tenant slot; with [`RouterConfig::tenants`] the
//!   model fleet is partitioned round-robin across `tenant-{k}` engine
//!   lanes ([`crate::engine::EngineBuilder::tenant_budget`]), whose
//!   per-lane LRU lists make tenant isolation structural: one tenant's
//!   eviction storm cannot cold-start another tenant's resident models.
//! * The cold/warm decision is race-free: the warm fast path
//!   ([`crate::engine::Session::infer_warm`]) only *charges* an
//!   already-resident model, and the residency commit after the policy
//!   gates ([`crate::engine::Session::infer`]) is the engine's single
//!   atomic decision — two requests racing an eviction resolve to
//!   exactly one cold and one warm, exactly as before this layer existed.
//!
//! With no deadline, no admission bound, and no injected faults, every
//! gate is pass-through and the request path is *bit-identical* to the
//! pre-robustness router (`tests/concurrent_serving.rs` proves the
//! parity; the serving bench asserts shed == 0 and degraded == 0).

use std::collections::hash_map::DefaultHasher;
use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::engine::{BaselineBackend, Engine, ExecBackend, Phase, Session, SimBackend};
use crate::device::DeviceProfile;
use crate::exits::{offload_estimate, OffloadPolicy};
use crate::faults::{mix64, unit_f64, FaultKind, FaultPlan, FaultSite};
use crate::graph::ModelGraph;
use crate::metrics::Recorder;
use crate::sched::cache::PlanCache;
use crate::serving::workload::Request;
use crate::store::ArtifactStore;
use crate::Ms;

/// Number of session-map shards (power of two; max concurrent
/// registrations/lookups that never contend, assuming a decent hash).
const SHARDS: usize = 16;

/// One serving entry: the session plus its circuit-breaker state.
struct ModelEntry {
    session: Arc<Session>,
    breaker: Breaker,
}

/// One bucket of the sharded entry map.
type Shard = Mutex<HashMap<String, Arc<ModelEntry>>>;

/// Serving engine the router charges latencies from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServeEngine {
    Nnv12,
    Ncnn,
}

/// Retry policy for transient cold-execution failures. Backoff is
/// deterministic — jitter comes from `(seed, model, attempt)` — and is
/// *charged to the request's reported latency*, never slept, so replays
/// stay reproducible and fast.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RetryPolicy {
    /// Retries after the first attempt (0 disables retrying).
    pub max_retries: usize,
    /// Backoff before retry `k` is `min(cap, base·2^(k-1))`, scaled by a
    /// seeded jitter factor in `[0.5, 1.0)`.
    pub backoff_base_ms: Ms,
    pub backoff_cap_ms: Ms,
    /// Jitter seed (same seed ⇒ same charged backoff, bit for bit).
    pub seed: u64,
}

impl Default for RetryPolicy {
    fn default() -> RetryPolicy {
        RetryPolicy {
            max_retries: 2,
            backoff_base_ms: 5.0,
            backoff_cap_ms: 80.0,
            seed: 0x5EED,
        }
    }
}

/// Circuit-breaker policy, per model.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BreakerPolicy {
    /// Consecutive cold-execution failures (counted per attempt) that
    /// open the breaker.
    pub threshold: usize,
    /// Requests short-circuited to the degraded path while open before
    /// the next one runs as a half-open probe. Counted in requests, not
    /// wall time, so chaos replays are deterministic.
    pub cooldown: usize,
}

impl Default for BreakerPolicy {
    fn default() -> BreakerPolicy {
        BreakerPolicy { threshold: 5, cooldown: 16 }
    }
}

/// Router configuration.
#[derive(Debug, Clone)]
pub struct RouterConfig {
    /// Device memory available for resident models, bytes.
    pub memory_budget: u64,
    pub engine: ServeEngine,
    /// Length of the warm-up latency ladder computed per model.
    pub warmup_depth: usize,
    /// Execute cold requests through the engine's backend (the
    /// contention-aware simulator for [`ServeEngine::Nnv12`]) instead of
    /// charging the planner's precomputed cold estimate. Costs real
    /// (deterministic) compute per cold request — which is the point of
    /// the throughput benchmark: cold work parallelizes across serving
    /// threads. Default off, preserving the cheap charge-only semantics.
    pub execute_cold: bool,
    /// Max in-flight cold starts per shard; excess cold-due requests are
    /// shed ([`Outcome::Shed`]). `None` (default) admits everything.
    pub admission: Option<usize>,
    /// Bounded per-shard wait queue for cold-start admission: a request
    /// that finds the shard's in-flight budget exhausted waits for a slot
    /// if fewer than `queue_depth` requests are already waiting there,
    /// instead of shedding immediately. `None` (default) preserves the
    /// historical shed-immediately behavior exactly. Only meaningful
    /// together with [`RouterConfig::admission`] (> 0).
    pub queue_depth: Option<usize>,
    /// Offload policy for multi-exit models: when a local cold start
    /// would miss a request's deadline, serve the head locally and the
    /// conditional tail on the simulated remote if the expected offload
    /// latency fits the deadline. `None` (default) never offloads.
    pub offload: Option<OffloadPolicy>,
    pub retry: RetryPolicy,
    pub breaker: BreakerPolicy,
    /// Deterministic fault plan threaded into the execution backend
    /// (chaos testing). `None` (default) is zero-cost. Store faults are
    /// injected separately via
    /// [`crate::store::ArtifactStore::inject_faults`] on a shared store.
    pub faults: Option<Arc<FaultPlan>>,
    /// Number of tenants to partition the fleet across (0 = untenanted,
    /// the default — one shared residency budget, exactly the historical
    /// behavior). With `K > 0`, the router declares tenants
    /// `tenant-0 … tenant-{K-1}`, each with an equal share
    /// (`memory_budget / K`) of the residency budget as its own LRU lane
    /// ([`crate::engine::EngineBuilder::tenant_budget`]), and assigns
    /// model `i` (in construction order) to `tenant-{i % K}`. Models
    /// added later via [`Router::register`] stay on the shared lane.
    pub tenants: usize,
}

impl Default for RouterConfig {
    fn default() -> RouterConfig {
        RouterConfig {
            memory_budget: 64 << 20,
            engine: ServeEngine::Nnv12,
            warmup_depth: 4,
            execute_cold: false,
            admission: None,
            queue_depth: None,
            offload: None,
            retry: RetryPolicy::default(),
            breaker: BreakerPolicy::default(),
            faults: None,
            tenants: 0,
        }
    }
}

/// How a served (non-shed, non-failed) request was priced.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServeClass {
    /// Full cold start (planned path; executed when
    /// [`RouterConfig::execute_cold`]).
    Cold,
    /// Resident model, warm-up ladder rung.
    Warm,
    /// Served off the search-free baseline plan (deadline miss, open
    /// breaker, or a dropped offload); residency untouched.
    Degraded,
    /// Multi-exit model: head served locally, conditional tail offloaded
    /// to the simulated remote; charged the deterministic expected
    /// offload latency. Residency untouched.
    Offloaded,
}

/// A successfully served request.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Served {
    /// Reported latency: executed/charged latency plus any retry backoff.
    pub latency_ms: Ms,
    pub class: ServeClass,
    /// Sessions evicted from residency to make room for this one.
    pub evictions: usize,
    /// Transient-failure retries this request absorbed.
    pub retries: usize,
}

/// Outcome of one routed request — exactly one of served / shed / failed
/// (see the module docs for the taxonomy and conservation invariant).
#[derive(Debug, Clone, PartialEq)]
pub enum Outcome {
    Served(Served),
    /// Refused at admission: the shard's in-flight cold-start budget was
    /// exhausted.
    Shed,
    /// Cold execution failed every attempt; `error` is the last failure
    /// (a caught backend panic is reported here too).
    Failed { attempts: usize, error: String },
}

impl Outcome {
    /// The served payload, if this request was served at all.
    pub fn served(&self) -> Option<&Served> {
        match self {
            Outcome::Served(s) => Some(s),
            _ => None,
        }
    }

    pub fn is_cold(&self) -> bool {
        matches!(self.served(), Some(s) if s.class == ServeClass::Cold)
    }

    pub fn is_warm(&self) -> bool {
        matches!(self.served(), Some(s) if s.class == ServeClass::Warm)
    }

    pub fn is_degraded(&self) -> bool {
        matches!(self.served(), Some(s) if s.class == ServeClass::Degraded)
    }

    pub fn is_offloaded(&self) -> bool {
        matches!(self.served(), Some(s) if s.class == ServeClass::Offloaded)
    }

    pub fn is_shed(&self) -> bool {
        matches!(self, Outcome::Shed)
    }

    pub fn is_failed(&self) -> bool {
        matches!(self, Outcome::Failed { .. })
    }
}

/// Per-tenant slice of [`RouterStats::per_tenant`]: the outcomes that
/// residency and admission decide — the ones tenant quotas exist to
/// isolate. Degraded/offloaded/failed outcomes stay global-only.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct TenantStats {
    pub tenant: String,
    pub cold: usize,
    pub warm: usize,
    pub shed: usize,
}

/// Snapshot of the router's full failure-taxonomy counter set
/// ([`Router::summary`]). All counters are monotonic.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct RouterStats {
    /// Requests issued against known models.
    pub issued: usize,
    pub cold: usize,
    pub warm: usize,
    /// Requests served off the degraded path
    /// (`== degraded_deadline + degraded_breaker + degraded_offload`).
    pub degraded: usize,
    /// Requests served by offloading the multi-exit tail to the remote.
    pub offloaded: usize,
    pub shed: usize,
    pub failed: usize,
    /// Requests that waited in the bounded admission queue for a cold
    /// slot. **Not** a terminal outcome (a queued request still resolves
    /// to cold/warm/failed), so it does not enter the conservation sum.
    pub queued: usize,
    /// Degradations caused by a deadline tighter than the cold estimate.
    pub degraded_deadline: usize,
    /// Degradations caused by an open circuit breaker.
    pub degraded_breaker: usize,
    /// Degradations caused by a dropped offload send (injected
    /// [`crate::faults::FaultKind::OffloadDrop`]).
    pub degraded_offload: usize,
    /// Individual cold-execution attempt failures (includes panics).
    pub exec_failures: usize,
    /// Backend panics caught at the router boundary.
    pub exec_panics: usize,
    /// Retry attempts issued (each also charged seeded backoff).
    pub retries: usize,
    /// Breaker open transitions (threshold trips and failed probes).
    pub breaker_opens: usize,
    /// Half-open probes admitted.
    pub breaker_probes: usize,
    /// Per-tenant cold/warm/shed attribution, in tenant declaration
    /// order; empty for an untenanted router. A request is attributed to
    /// its explicit [`Request::tenant`] when it names a declared tenant,
    /// else to the serving model's owning tenant (if any). When every
    /// model is tenant-owned, the per-tenant columns sum to the global
    /// `cold`/`warm`/`shed` counters.
    pub per_tenant: Vec<TenantStats>,
}

impl RouterStats {
    /// The conservation invariant: every issued request resolved to
    /// exactly one outcome.
    pub fn conserves(&self) -> bool {
        self.cold + self.warm + self.degraded + self.offloaded + self.shed + self.failed
            == self.issued
    }
}

/// Monotonic request counters (atomics; snapshot via
/// [`Router::summary`]).
#[derive(Default)]
struct Counters {
    issued: AtomicUsize,
    cold: AtomicUsize,
    warm: AtomicUsize,
    degraded: AtomicUsize,
    offloaded: AtomicUsize,
    shed: AtomicUsize,
    failed: AtomicUsize,
    queued: AtomicUsize,
    degraded_deadline: AtomicUsize,
    degraded_breaker: AtomicUsize,
    degraded_offload: AtomicUsize,
    exec_failures: AtomicUsize,
    exec_panics: AtomicUsize,
    retries: AtomicUsize,
    breaker_opens: AtomicUsize,
    breaker_probes: AtomicUsize,
}

/// Per-tenant outcome counters (one slot per declared tenant; see
/// [`TenantStats`] for what is and is not attributed).
#[derive(Default)]
struct TenantCounters {
    cold: AtomicUsize,
    warm: AtomicUsize,
    shed: AtomicUsize,
}

/// Circuit-breaker state machine: Closed → Open{countdown} →
/// HalfOpen{probe} → Closed/Open. Count-based cooldown keeps replays
/// deterministic (no wall clock anywhere in the serving path).
struct Breaker {
    policy: BreakerPolicy,
    state: Mutex<BreakerState>,
}

struct BreakerState {
    consecutive: usize,
    mode: BreakerMode,
}

enum BreakerMode {
    Closed,
    Open { remaining: usize },
    HalfOpen { probing: bool },
}

/// What the breaker says about admitting one cold start.
enum Admit {
    Through,
    Probe,
    ShortCircuit,
}

impl Breaker {
    fn new(policy: BreakerPolicy) -> Breaker {
        Breaker {
            policy,
            state: Mutex::new(BreakerState {
                consecutive: 0,
                mode: BreakerMode::Closed,
            }),
        }
    }

    fn admit(&self) -> Admit {
        let mut s = self.state.lock().unwrap();
        match s.mode {
            BreakerMode::Closed => Admit::Through,
            BreakerMode::Open { remaining } if remaining > 0 => {
                s.mode = BreakerMode::Open { remaining: remaining - 1 };
                Admit::ShortCircuit
            }
            BreakerMode::Open { .. } => {
                s.mode = BreakerMode::HalfOpen { probing: true };
                Admit::Probe
            }
            BreakerMode::HalfOpen { probing: false } => {
                s.mode = BreakerMode::HalfOpen { probing: true };
                Admit::Probe
            }
            // Another request already holds the probe slot.
            BreakerMode::HalfOpen { probing: true } => Admit::ShortCircuit,
        }
    }

    /// One failed (non-probe) attempt. Returns true when this failure
    /// just opened the breaker.
    fn on_failure(&self) -> bool {
        let mut s = self.state.lock().unwrap();
        s.consecutive += 1;
        if s.consecutive >= self.policy.threshold {
            s.consecutive = 0;
            s.mode = BreakerMode::Open { remaining: self.policy.cooldown };
            return true;
        }
        false
    }

    fn on_success(&self) {
        self.state.lock().unwrap().consecutive = 0;
    }

    fn probe_succeeded(&self) {
        let mut s = self.state.lock().unwrap();
        s.consecutive = 0;
        s.mode = BreakerMode::Closed;
    }

    fn probe_failed(&self) {
        let mut s = self.state.lock().unwrap();
        s.consecutive = 0;
        s.mode = BreakerMode::Open { remaining: self.policy.cooldown };
    }

    /// The probe never ran (its cold start was shed): release the probe
    /// slot for the next request.
    fn probe_aborted(&self) {
        let mut s = self.state.lock().unwrap();
        if let BreakerMode::HalfOpen { probing } = &mut s.mode {
            *probing = false;
        }
    }
}

/// RAII decrement of a shard's in-flight cold-start gauge.
struct ColdGuard<'a> {
    slot: &'a AtomicUsize,
}

impl Drop for ColdGuard<'_> {
    fn drop(&mut self) {
        self.slot.fetch_sub(1, Ordering::Relaxed);
    }
}

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

fn panic_message(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "backend panicked".to_string()
    }
}

/// The router: named [`Session`]s over one shared [`Engine`], behind a
/// sharded concurrent map, gated by the failure policy described in the
/// module docs. `Send + Sync`; [`Router::request`] is `&self`.
pub struct Router {
    engine: Engine,
    shards: Vec<Shard>,
    /// In-flight cold starts, per shard (the admission gauge).
    cold_inflight: Vec<AtomicUsize>,
    /// Requests waiting for an admission slot, per shard (the bounded
    /// queue gauge; only moves when `queue_depth` is set).
    queue_waiting: Vec<AtomicUsize>,
    /// Latency recorders, one per shard (indexed by [`Router::shard_of`]
    /// of the request's model), merged on read by [`Router::recorded`].
    recorders: Vec<Mutex<Recorder>>,
    counters: Counters,
    /// Declared tenants (engine order: slot `k` ⇔ engine lane `k + 1`).
    tenants: Vec<String>,
    /// Tenant name → slot in `tenants`/`tenant_counts`.
    tenant_index: HashMap<String, usize>,
    tenant_counts: Vec<TenantCounters>,
    execute_cold: bool,
    admission: Option<usize>,
    queue_depth: Option<usize>,
    offload: Option<OffloadPolicy>,
    retry: RetryPolicy,
    breaker_policy: BreakerPolicy,
    /// The fault plan, for sites the *router itself* instruments
    /// (offload sends); store/backend sites hold their own `Arc`.
    faults: Option<Arc<FaultPlan>>,
}

impl Router {
    /// Build a router: plans every model on `dev` up front (the paper's
    /// offline decision stage, parallel across models); each model's
    /// warm-up ladder is computed lazily on its first request. Plans come
    /// from a fresh private [`PlanCache`]; use
    /// [`Router::with_plan_cache`] to share one across routers (ablation
    /// arms, engine comparisons, router restarts) so repeated
    /// cold-planning of the same model × device × config is free.
    pub fn new(dev: &DeviceProfile, models: Vec<ModelGraph>, cfg: RouterConfig) -> Router {
        Router::with_plan_cache(dev, models, cfg, Arc::new(PlanCache::new()))
    }

    /// [`Router::new`] planning through a shared plan cache.
    pub fn with_plan_cache(
        dev: &DeviceProfile,
        models: Vec<ModelGraph>,
        cfg: RouterConfig,
        plan_cache: Arc<PlanCache>,
    ) -> Router {
        let builder = Router::builder_for(dev, &cfg).plan_cache(plan_cache);
        Router::finish(builder.build(), models, &cfg)
    }

    /// [`Router::new`] persisting plans through a shared content-addressed
    /// [`ArtifactStore`]: a restarted router — including one in a fresh
    /// process — pointed at the same store directory skips every plan
    /// search (observable via [`Engine::store_stats`]).
    pub fn with_artifact_store(
        dev: &DeviceProfile,
        models: Vec<ModelGraph>,
        cfg: RouterConfig,
        store: Arc<ArtifactStore>,
    ) -> Router {
        let builder = Router::builder_for(dev, &cfg).artifact_store_shared(store);
        Router::finish(builder.build(), models, &cfg)
    }

    fn builder_for(dev: &DeviceProfile, cfg: &RouterConfig) -> crate::engine::EngineBuilder {
        let backend: Box<dyn ExecBackend> = match (cfg.engine, &cfg.faults) {
            (ServeEngine::Nnv12, None) => Box::new(SimBackend::nnv12()),
            (ServeEngine::Nnv12, Some(f)) => {
                Box::new(SimBackend::nnv12().with_faults(f.clone()))
            }
            (ServeEngine::Ncnn, None) => Box::new(BaselineBackend::ncnn()),
            (ServeEngine::Ncnn, Some(f)) => {
                Box::new(BaselineBackend::ncnn().with_faults(f.clone()))
            }
        };
        let mut builder = Engine::builder()
            .device(dev.clone())
            .memory_budget(cfg.memory_budget)
            .warmup_depth(cfg.warmup_depth)
            .backend_box(backend);
        // Equal residency shares: each tenant gets its own LRU lane, so
        // one tenant's eviction storm cannot evict another's models.
        let share = (cfg.memory_budget / cfg.tenants.max(1) as u64).max(1);
        for k in 0..cfg.tenants {
            builder = builder.tenant_budget(format!("tenant-{k}"), share);
        }
        builder
    }

    fn finish(engine: Engine, models: Vec<ModelGraph>, cfg: &RouterConfig) -> Router {
        let tenants: Vec<String> = engine.tenants().to_vec();
        let tenant_index: HashMap<String, usize> = tenants
            .iter()
            .enumerate()
            .map(|(k, t)| (t.clone(), k))
            .collect();
        let tenant_counts = tenants.iter().map(|_| TenantCounters::default()).collect();
        let router = Router {
            engine,
            shards: (0..SHARDS).map(|_| Mutex::new(HashMap::new())).collect(),
            cold_inflight: (0..SHARDS).map(|_| AtomicUsize::new(0)).collect(),
            queue_waiting: (0..SHARDS).map(|_| AtomicUsize::new(0)).collect(),
            recorders: (0..SHARDS).map(|_| Mutex::new(Recorder::new())).collect(),
            counters: Counters::default(),
            tenants,
            tenant_index,
            tenant_counts,
            execute_cold: cfg.execute_cold,
            admission: cfg.admission,
            queue_depth: cfg.queue_depth,
            offload: cfg.offload,
            retry: cfg.retry,
            breaker_policy: cfg.breaker,
            faults: cfg.faults.clone(),
        };
        // Round-robin model → tenant ownership, matching the workload
        // generator's stamping ([`crate::serving::WorkloadSpec::tenants`]).
        let k = cfg.tenants;
        let assigned: Vec<(ModelGraph, Option<String>)> = models
            .into_iter()
            .enumerate()
            .map(|(i, g)| {
                let t = (k > 0).then(|| format!("tenant-{}", i % k));
                (g, t)
            })
            .collect();
        for s in router.engine.load_all_for(assigned) {
            router.insert(s);
        }
        router
    }

    /// The shard index serving `model`.
    fn shard_of(&self, model: &str) -> usize {
        let mut h = DefaultHasher::new();
        model.hash(&mut h);
        (h.finish() as usize) & (SHARDS - 1)
    }

    fn insert(&self, session: Session) {
        let name = session.name().to_string();
        let shard = self.shard_of(&name);
        let entry = ModelEntry {
            session: Arc::new(session),
            breaker: Breaker::new(self.breaker_policy),
        };
        self.shards[shard]
            .lock()
            .unwrap()
            .insert(name, Arc::new(entry));
    }

    /// Plan and add a model at runtime (`&self`: callable while other
    /// threads serve requests — they contend only on this model's
    /// shard). Replaces any existing session of the same name (with a
    /// fresh, closed breaker); its residency is released when the last
    /// in-flight request drops the old `Arc`.
    pub fn register(&self, model: ModelGraph) {
        self.insert(self.engine.load(model));
    }

    /// Retire a model. In-flight requests holding the entry's `Arc`
    /// finish normally; residency is released once they drop it.
    pub fn remove(&self, model: &str) -> bool {
        let shard = self.shard_of(model);
        self.shards[shard].lock().unwrap().remove(model).is_some()
    }

    pub fn model_names(&self) -> Vec<String> {
        let mut v: Vec<String> = self
            .shards
            .iter()
            .flat_map(|s| s.lock().unwrap().keys().cloned().collect::<Vec<_>>())
            .collect();
        v.sort();
        v
    }

    pub fn is_resident(&self, name: &str) -> bool {
        self.session(name).is_some_and(|s| s.is_resident())
    }

    /// Handle a request for `model` with no deadline. `None` for unknown
    /// models; see [`Router::request_with`] for the full policy.
    pub fn request(&self, model: &str) -> Option<Outcome> {
        self.request_with(model, None)
    }

    /// Handle a request for `model`, from any thread. `None` for unknown
    /// models; every known-model request resolves to exactly one
    /// [`Outcome`] (the conservation invariant).
    ///
    /// The policy pipeline, in order (see the module docs): warm fast
    /// path → deadline check against the cold estimate → circuit breaker
    /// → per-shard admission → (optionally executed) cold start with
    /// retries → residency commit. The shard lock covers only the entry
    /// `Arc` clone; everything else runs outside it. No panic escapes:
    /// backend panics are caught, counted, and reported as failures.
    pub fn request_with(&self, model: &str, deadline_ms: Option<Ms>) -> Option<Outcome> {
        self.request_for(model, deadline_ms, None)
    }

    /// [`Router::request_with`], attributing the outcome to a tenant's
    /// [`TenantStats`] counters: the named `tenant` when it is one the
    /// router declared ([`RouterConfig::tenants`]), else the serving
    /// model's owning tenant, else nobody. Attribution is bookkeeping
    /// only — quota enforcement lives in the engine's per-lane residency,
    /// keyed by the model's *owner*, regardless of who asked.
    pub fn request_for(
        &self,
        model: &str,
        deadline_ms: Option<Ms>,
        tenant: Option<&str>,
    ) -> Option<Outcome> {
        let entry = {
            let shard = self.shard_of(model);
            self.shards[shard].lock().unwrap().get(model).cloned()?
        };
        let tslot = tenant
            .and_then(|t| self.tenant_index.get(t).copied())
            .or_else(|| entry.session.lane.checked_sub(1));
        self.counters.issued.fetch_add(1, Ordering::Relaxed);

        // Warm fast path: a resident model serves its ladder rung with no
        // gating at all (warm service cannot fail and must stay cheap).
        if let Some(r) = entry.session.infer_warm() {
            self.counters.warm.fetch_add(1, Ordering::Relaxed);
            if let Some(k) = tslot {
                self.tenant_counts[k].warm.fetch_add(1, Ordering::Relaxed);
            }
            self.record(model, "warm", r.latency_ms);
            return Some(Outcome::Served(Served {
                latency_ms: r.latency_ms,
                class: ServeClass::Warm,
                evictions: 0,
                retries: 0,
            }));
        }

        // A cold start is due. Gate 1: can it meet the deadline? The
        // §3.5 ladder's first rung is the planner's cold estimate. A
        // multi-exit model that cannot may still fit by offloading its
        // conditional tail (Gate 1b) before falling back to degradation.
        if let Some(d) = deadline_ms {
            if entry.session.cold_ms() > d {
                if let Some(o) = self.try_offload(&entry, model, d) {
                    return Some(o);
                }
                self.counters.degraded_deadline.fetch_add(1, Ordering::Relaxed);
                return Some(self.serve_degraded(&entry, model));
            }
        }

        // Gate 2: circuit breaker.
        let probing = match entry.breaker.admit() {
            Admit::ShortCircuit => {
                self.counters.degraded_breaker.fetch_add(1, Ordering::Relaxed);
                return Some(self.serve_degraded(&entry, model));
            }
            Admit::Probe => {
                self.counters.breaker_probes.fetch_add(1, Ordering::Relaxed);
                true
            }
            Admit::Through => false,
        };

        // Gate 3: bounded admission of in-flight cold starts, per shard.
        // On a full budget, Gate 3b lets the request wait in the bounded
        // queue for a slot (holding one on success); otherwise shed.
        let shard = self.shard_of(model);
        let slot = &self.cold_inflight[shard];
        let prev = slot.fetch_add(1, Ordering::Relaxed);
        if self.admission.is_some_and(|limit| prev >= limit) {
            slot.fetch_sub(1, Ordering::Relaxed);
            if !self.wait_for_cold_slot(shard) {
                if probing {
                    entry.breaker.probe_aborted();
                }
                self.counters.shed.fetch_add(1, Ordering::Relaxed);
                if let Some(k) = tslot {
                    self.tenant_counts[k].shed.fetch_add(1, Ordering::Relaxed);
                }
                return Some(Outcome::Shed);
            }
        }
        let _guard = ColdGuard { slot };

        // The cold start proper, with retries. Backoff is charged to the
        // reported latency (deterministic seeded jitter), never slept.
        let mut exec_latency: Option<Ms> = None;
        let mut penalty_ms: Ms = 0.0;
        let mut attempts = 0usize;
        let mut retries = 0usize;
        let mut last_err = String::new();
        if self.execute_cold {
            // A half-open probe gets exactly one attempt: its job is to
            // answer "has the backend recovered?", not to mask the answer
            // behind retries.
            let max_attempts = if probing { 1 } else { self.retry.max_retries + 1 };
            while attempts < max_attempts {
                attempts += 1;
                if attempts > 1 {
                    retries += 1;
                    self.counters.retries.fetch_add(1, Ordering::Relaxed);
                    penalty_ms += self.backoff_ms(model, attempts - 1);
                }
                let run = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    entry.session.run_cold()
                }));
                match run {
                    Ok(Ok(out)) => {
                        exec_latency = Some(out.latency_ms);
                        break;
                    }
                    Ok(Err(e)) => {
                        self.counters.exec_failures.fetch_add(1, Ordering::Relaxed);
                        last_err = e;
                    }
                    Err(p) => {
                        // A simulated process death is not an executor
                        // panic to contain — the "process" is gone, so the
                        // token must keep unwinding to the test's crash
                        // boundary (containing it here would let dead code
                        // keep serving).
                        if p.downcast_ref::<crate::faults::CrashToken>().is_some() {
                            std::panic::resume_unwind(p);
                        }
                        self.counters.exec_failures.fetch_add(1, Ordering::Relaxed);
                        self.counters.exec_panics.fetch_add(1, Ordering::Relaxed);
                        last_err = panic_message(p.as_ref());
                    }
                }
                if probing {
                    entry.breaker.probe_failed();
                    self.counters.breaker_opens.fetch_add(1, Ordering::Relaxed);
                    break;
                }
                if entry.breaker.on_failure() {
                    // This failure tripped the breaker: this request (and
                    // the cooldown's worth behind it) rides the degraded
                    // path rather than burning more attempts.
                    self.counters.breaker_opens.fetch_add(1, Ordering::Relaxed);
                    self.counters.degraded_breaker.fetch_add(1, Ordering::Relaxed);
                    return Some(self.serve_degraded(&entry, model));
                }
            }
            match exec_latency {
                None => {
                    self.counters.failed.fetch_add(1, Ordering::Relaxed);
                    return Some(Outcome::Failed { attempts, error: last_err });
                }
                Some(_) => {
                    if probing {
                        entry.breaker.probe_succeeded();
                    } else {
                        entry.breaker.on_success();
                    }
                }
            }
        } else if probing {
            // Charge-only serving never executes, so nothing can fail:
            // the probe trivially succeeds and the breaker closes.
            entry.breaker.probe_succeeded();
        }

        // Commit the residency charge — the engine's single atomic
        // cold/warm decision (another thread may have won the race while
        // we executed; then we're the raced-warm request).
        let r = entry.session.infer();
        if r.phase == Phase::Cold {
            let latency = exec_latency.unwrap_or(r.latency_ms) + penalty_ms;
            self.counters.cold.fetch_add(1, Ordering::Relaxed);
            if let Some(k) = tslot {
                self.tenant_counts[k].cold.fetch_add(1, Ordering::Relaxed);
            }
            self.record(model, "cold", latency);
            Some(Outcome::Served(Served {
                latency_ms: latency,
                class: ServeClass::Cold,
                evictions: r.evictions,
                retries,
            }))
        } else {
            self.counters.warm.fetch_add(1, Ordering::Relaxed);
            if let Some(k) = tslot {
                self.tenant_counts[k].warm.fetch_add(1, Ordering::Relaxed);
            }
            self.record(model, "warm", r.latency_ms);
            Some(Outcome::Served(Served {
                latency_ms: r.latency_ms,
                class: ServeClass::Warm,
                evictions: r.evictions,
                retries,
            }))
        }
    }

    /// Gate 1b: try to serve a deadline-missing request by offloading the
    /// multi-exit tail (CSGO-style head-local / tail-remote split).
    /// `None` when offload is not configured, the model has no exits, or
    /// the expected offload latency still misses the deadline — the
    /// caller then degrades as before. The send is an instrumented fault
    /// site: an injected drop falls back to the degraded path, counted
    /// under `degraded_offload`.
    fn try_offload(&self, entry: &ModelEntry, model: &str, deadline_ms: Ms) -> Option<Outcome> {
        let policy = self.offload.as_ref()?;
        let graph = entry.session.graph();
        if !graph.has_exits() {
            return None;
        }
        let est = offload_estimate(graph, policy, entry.session.cold_ms())?;
        if est.expected_ms > deadline_ms {
            return None;
        }
        if let Some(f) = &self.faults {
            if f.draw(FaultSite::OffloadSend) == Some(FaultKind::OffloadDrop) {
                self.counters.degraded_offload.fetch_add(1, Ordering::Relaxed);
                return Some(self.serve_degraded(entry, model));
            }
        }
        self.counters.offloaded.fetch_add(1, Ordering::Relaxed);
        self.record(model, "offloaded", est.expected_ms);
        Some(Outcome::Served(Served {
            latency_ms: est.expected_ms,
            class: ServeClass::Offloaded,
            evictions: 0,
            retries: 0,
        }))
    }

    /// Gate 3b: wait in the bounded per-shard queue for a cold-start
    /// admission slot. Returns `true` holding a slot (the caller's
    /// `ColdGuard` releases it), `false` when queueing is disabled,
    /// futile (`admission == Some(0)` can never free a slot), or the
    /// queue itself is full. The wait spins on the admission gauge with
    /// `yield_now` — slots are held only for the duration of one cold
    /// start, and queue depths are small by construction.
    fn wait_for_cold_slot(&self, shard: usize) -> bool {
        let Some(depth) = self.queue_depth else { return false };
        let limit = match self.admission {
            Some(l) if l > 0 => l,
            _ => return false,
        };
        let gauge = &self.queue_waiting[shard];
        if gauge.fetch_add(1, Ordering::Relaxed) >= depth {
            gauge.fetch_sub(1, Ordering::Relaxed);
            return false;
        }
        self.counters.queued.fetch_add(1, Ordering::Relaxed);
        let slot = &self.cold_inflight[shard];
        loop {
            if slot.fetch_add(1, Ordering::Relaxed) < limit {
                break;
            }
            slot.fetch_sub(1, Ordering::Relaxed);
            std::thread::yield_now();
        }
        gauge.fetch_sub(1, Ordering::Relaxed);
        true
    }

    /// Serve off the degraded path: the session's search-free baseline
    /// plan estimate, residency untouched (the next undegraded request
    /// still pays its cold start — degradation trades latency *now* for
    /// no residency/planning work).
    fn serve_degraded(&self, entry: &ModelEntry, model: &str) -> Outcome {
        let latency = entry.session.degraded_cold_ms();
        self.counters.degraded.fetch_add(1, Ordering::Relaxed);
        self.record(model, "degraded", latency);
        Outcome::Served(Served {
            latency_ms: latency,
            class: ServeClass::Degraded,
            evictions: 0,
            retries: 0,
        })
    }

    /// Charged retry backoff before retry `k` (1-based):
    /// `min(cap, base·2^(k-1))` scaled by seeded jitter in `[0.5, 1.0)`.
    fn backoff_ms(&self, model: &str, k: usize) -> Ms {
        let exp = (k - 1).min(20) as u32;
        let raw = self.retry.backoff_base_ms * (1u64 << exp) as f64;
        let capped = raw.min(self.retry.backoff_cap_ms);
        let h = mix64(
            self.retry.seed
                ^ fnv1a(model.as_bytes())
                ^ (k as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
        );
        capped * (0.5 + 0.5 * unit_f64(h))
    }

    fn record(&self, model: &str, label: &str, latency: Ms) {
        // One recorder per shard, keyed like the entry map: requests for
        // models on different shards never contend on a recorder lock.
        // The critical section is two O(1) index lookups + pushes;
        // `record_scoped` keeps the per-model composite label
        // allocation-free after a (model, label) pair's first
        // observation.
        let mut rec = self.recorders[self.shard_of(model)].lock().unwrap();
        rec.record(label, latency);
        rec.record_scoped(model, label, latency);
    }

    /// Replay a request trace across `threads` serving threads (request
    /// `i` goes to thread `i % threads`, each thread serving its share
    /// in trace order, honoring per-request deadlines). Returns the
    /// number of requests processed (requests for unknown models are
    /// skipped; shed and failed requests count as processed — they *are*
    /// outcomes). `threads <= 1` replays inline — the single-threaded
    /// baseline the throughput ratchet compares against.
    pub fn replay(&self, reqs: &[Request], threads: usize) -> usize {
        if threads <= 1 {
            return reqs
                .iter()
                .filter(|r| {
                    self.request_for(&r.model, r.deadline_ms, r.tenant.as_deref())
                        .is_some()
                })
                .count();
        }
        let served = AtomicUsize::new(0);
        std::thread::scope(|scope| {
            for t in 0..threads {
                let served = &served;
                scope.spawn(move || {
                    let n = reqs
                        .iter()
                        .skip(t)
                        .step_by(threads)
                        .filter(|r| {
                            self.request_for(&r.model, r.deadline_ms, r.tenant.as_deref())
                                .is_some()
                        })
                        .count();
                    served.fetch_add(n, Ordering::Relaxed);
                });
            }
        });
        served.into_inner()
    }

    /// Open-loop replay: requests fire at their trace arrival times
    /// (`Request::at_ms`, divided by `accel`), regardless of whether
    /// earlier requests finished — the load model that makes latency
    /// *percentiles under load* meaningful. `threads` workers pull from a
    /// shared cursor; each sleeps until its request's arrival, serves it,
    /// and records the wall-clock **sojourn** (completion − scheduled
    /// arrival, ms) under the `"sojourn"` recorder label
    /// ([`Router::latency_summary`]`("sojourn")` for percentiles).
    /// Returns the number of requests processed.
    pub fn replay_open_loop(&self, reqs: &[Request], threads: usize, accel: f64) -> usize {
        let accel = if accel > 0.0 { accel } else { 1.0 };
        let served = AtomicUsize::new(0);
        let cursor = AtomicUsize::new(0);
        let start = std::time::Instant::now();
        std::thread::scope(|scope| {
            for _ in 0..threads.max(1) {
                let (served, cursor) = (&served, &cursor);
                scope.spawn(move || loop {
                    let i = cursor.fetch_add(1, Ordering::Relaxed);
                    let Some(req) = reqs.get(i) else { break };
                    let due =
                        std::time::Duration::from_secs_f64((req.at_ms / accel / 1e3).max(0.0));
                    loop {
                        let elapsed = start.elapsed();
                        if elapsed >= due {
                            break;
                        }
                        std::thread::sleep(due - elapsed);
                    }
                    if self
                        .request_for(&req.model, req.deadline_ms, req.tenant.as_deref())
                        .is_some()
                    {
                        let sojourn =
                            start.elapsed().saturating_sub(due).as_secs_f64() * 1e3;
                        self.recorders[self.shard_of(&req.model)]
                            .lock()
                            .unwrap()
                            .record("sojourn", sojourn);
                        served.fetch_add(1, Ordering::Relaxed);
                    }
                });
            }
        });
        served.into_inner()
    }

    /// Snapshot of the full failure-taxonomy counter set. Counters are
    /// read individually (`Relaxed`); quiesce serving threads before
    /// asserting exact cross-counter identities.
    pub fn summary(&self) -> RouterStats {
        let c = &self.counters;
        let load = |a: &AtomicUsize| a.load(Ordering::Relaxed);
        RouterStats {
            issued: load(&c.issued),
            cold: load(&c.cold),
            warm: load(&c.warm),
            degraded: load(&c.degraded),
            offloaded: load(&c.offloaded),
            shed: load(&c.shed),
            failed: load(&c.failed),
            queued: load(&c.queued),
            degraded_deadline: load(&c.degraded_deadline),
            degraded_breaker: load(&c.degraded_breaker),
            degraded_offload: load(&c.degraded_offload),
            exec_failures: load(&c.exec_failures),
            exec_panics: load(&c.exec_panics),
            retries: load(&c.retries),
            breaker_opens: load(&c.breaker_opens),
            breaker_probes: load(&c.breaker_probes),
            per_tenant: self
                .tenants
                .iter()
                .zip(&self.tenant_counts)
                .map(|(t, c)| TenantStats {
                    tenant: t.clone(),
                    cold: load(&c.cold),
                    warm: load(&c.warm),
                    shed: load(&c.shed),
                })
                .collect(),
        }
    }

    /// Requests that hit the cold path so far.
    pub fn stats_cold(&self) -> usize {
        self.counters.cold.load(Ordering::Relaxed)
    }

    /// Requests served warm (resident) so far.
    pub fn stats_warm(&self) -> usize {
        self.counters.warm.load(Ordering::Relaxed)
    }

    /// Individual cold-execution attempt failures (always 0 when
    /// [`RouterConfig::execute_cold`] is off or no faults are injected —
    /// the sim and baseline backends are infallible by construction).
    /// Superseded by [`Router::summary`]`.exec_failures`; kept as the
    /// stable spelling benches and older tests assert on.
    pub fn stats_exec_failed(&self) -> usize {
        self.counters.exec_failures.load(Ordering::Relaxed)
    }

    /// Latency summary for a recorder label (`"cold"`, `"warm"`,
    /// `"degraded"`, `"sojourn"`, or a per-model
    /// `"model:cold"`/`"model:warm"`/`"model:degraded"` key), merged
    /// across the per-shard recorders. Snapshot API on purpose: each
    /// recorder lock is taken and released inside the call, so callers
    /// can never hold one across another router call (a guard held while
    /// calling [`Router::request`] on the same thread would
    /// self-deadlock on the non-reentrant lock).
    pub fn latency_summary(&self, label: &str) -> crate::util::stats::Summary {
        crate::util::stats::Summary::of(&self.recorded(label))
    }

    /// Snapshot of the raw latency observations recorded under `label`
    /// (empty for unknown labels), merged across the per-shard recorders
    /// in shard order — aggregate labels (`"cold"`, …) are therefore not
    /// globally time-ordered; treat them as a multiset. Cloned out from
    /// under the locks, one shard at a time — see
    /// [`Router::latency_summary`] for why no guard is exposed.
    pub fn recorded(&self, label: &str) -> Vec<f64> {
        let mut out = Vec::new();
        for rec in &self.recorders {
            out.extend_from_slice(rec.lock().unwrap().values(label));
        }
        out
    }

    /// The underlying engine (residency, plan cache, device).
    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    /// The session serving `model` (an `Arc` clone — callers can infer
    /// on it directly, concurrently with the router).
    pub fn session(&self, model: &str) -> Option<Arc<Session>> {
        let shard = self.shard_of(model);
        self.shards[shard]
            .lock()
            .unwrap()
            .get(model)
            .map(|e| e.session.clone())
    }

    /// The shared plan cache.
    pub fn plan_cache(&self) -> &Arc<PlanCache> {
        self.engine.plan_cache()
    }

    pub fn mem_used(&self) -> u64 {
        self.engine.mem_used()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::profiles;
    use crate::faults::{FaultKind, FaultSite, Trigger};
    use crate::graph::zoo;

    fn router(budget: u64) -> Router {
        let dev = profiles::meizu_16t();
        let models = vec![zoo::tiny_net(), zoo::micro_mobilenet(), zoo::squeezenet()];
        Router::new(&dev, models, RouterConfig { memory_budget: budget, ..Default::default() })
    }

    fn latency(o: &Outcome) -> Ms {
        o.served().expect("request was served").latency_ms
    }

    #[test]
    fn first_request_cold_second_warm() {
        let r = router(1 << 30);
        let a = r.request("tinynet").unwrap();
        assert!(a.is_cold());
        let b = r.request("tinynet").unwrap();
        assert!(b.is_warm());
        assert!(latency(&b) <= latency(&a));
        assert_eq!(r.stats_cold(), 1);
        assert_eq!(r.stats_warm(), 1);
        assert!(r.summary().conserves());
    }

    #[test]
    fn warm_ladder_descends_to_steady_state() {
        let r = router(1 << 30);
        let l1 = latency(&r.request("squeezenet").unwrap());
        let l2 = latency(&r.request("squeezenet").unwrap());
        let l3 = latency(&r.request("squeezenet").unwrap());
        let l4 = latency(&r.request("squeezenet").unwrap());
        assert!(l1 > l2, "cold {l1} > 2nd {l2}");
        assert!(l2 >= l3, "2nd {l2} >= 3rd {l3}");
        assert_eq!(l3, l4, "steady state from 3rd inference");
    }

    #[test]
    fn tight_budget_causes_evictions_and_recold() {
        // Budget fits roughly one model: alternating requests thrash.
        let r = router(6 << 20);
        r.request("squeezenet").unwrap();
        let out = r.request("micro-mobilenet").unwrap();
        // squeezenet (~5MB resident +25%) + micro must exceed 6MB ⇒ evict.
        assert!(out.is_cold());
        assert!(out.served().unwrap().evictions > 0 || r.mem_used() <= 6 << 20);
        let back = r.request("squeezenet").unwrap();
        assert!(back.is_cold(), "evicted model must cold-start again");
    }

    #[test]
    fn shared_plan_cache_skips_replanning() {
        let dev = profiles::meizu_16t();
        let models = || vec![zoo::tiny_net(), zoo::squeezenet()];
        let cache = Arc::new(PlanCache::new());
        let a = Router::with_plan_cache(&dev, models(), RouterConfig::default(), cache.clone());
        assert_eq!(cache.misses(), 2, "first router plans each model once");
        assert_eq!(cache.hits(), 0);
        // A restarted / sibling router re-uses every plan.
        let b = Router::with_plan_cache(&dev, models(), RouterConfig::default(), cache.clone());
        assert_eq!(cache.misses(), 2);
        assert_eq!(cache.hits(), 2);
        // And identical plans ⇒ identical cold latencies.
        assert_eq!(
            latency(&a.request("squeezenet").unwrap()).to_bits(),
            latency(&b.request("squeezenet").unwrap()).to_bits()
        );
    }

    #[test]
    fn restarted_router_on_shared_store_skips_planning() {
        let dir = std::env::temp_dir().join(format!(
            "nnv12-router-store-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let dev = profiles::meizu_16t();
        let models = || vec![zoo::tiny_net(), zoo::squeezenet()];

        let store = Arc::new(ArtifactStore::open(&dir).unwrap());
        let a = Router::with_artifact_store(&dev, models(), RouterConfig::default(), store);
        assert_eq!(a.plan_cache().misses(), 2, "first router plans each model");

        // A "restarted" router: fresh store handle over the same directory
        // (≈ a fresh process). Every plan comes from disk.
        let store2 = Arc::new(ArtifactStore::open(&dir).unwrap());
        let b =
            Router::with_artifact_store(&dev, models(), RouterConfig::default(), store2);
        assert_eq!(b.plan_cache().misses(), 0, "restart must not re-plan");
        assert_eq!(b.plan_cache().disk_hits(), 2);
        let stats = b.engine().store_stats().unwrap();
        assert_eq!(stats.hits, 2);
        assert_eq!(
            latency(&a.request("squeezenet").unwrap()).to_bits(),
            latency(&b.request("squeezenet").unwrap()).to_bits(),
            "stored plans must reproduce identical serving latencies"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn unknown_model_is_none() {
        let r = router(1 << 30);
        assert!(r.request("nope").is_none());
    }

    #[test]
    fn register_and_remove_at_runtime() {
        let r = router(1 << 30);
        assert!(r.request("mobilenetv2").is_none());
        r.register(zoo::mobilenet_v2());
        let out = r.request("mobilenetv2").expect("registered model serves");
        assert!(out.is_cold());
        assert!(r.model_names().contains(&"mobilenetv2".to_string()));
        assert!(r.remove("mobilenetv2"));
        assert!(r.request("mobilenetv2").is_none());
        assert!(!r.remove("mobilenetv2"), "second remove is a no-op");
    }

    #[test]
    fn nnv12_colder_starts_beat_ncnn() {
        let dev = profiles::meizu_16t();
        let models = vec![zoo::squeezenet()];
        let nnv12 = Router::new(
            &dev,
            models.clone(),
            RouterConfig { engine: ServeEngine::Nnv12, ..Default::default() },
        );
        let ncnn = Router::new(
            &dev,
            models,
            RouterConfig { engine: ServeEngine::Ncnn, ..Default::default() },
        );
        let a = latency(&nnv12.request("squeezenet").unwrap());
        let b = latency(&ncnn.request("squeezenet").unwrap());
        assert!(a < b, "nnv12 cold {a} vs ncnn cold {b}");
    }

    #[test]
    fn executed_cold_requests_match_the_simulator() {
        // With `execute_cold`, a cold request's latency is the
        // deterministic contention-aware simulation of the plan, not the
        // planner's ladder estimate.
        let dev = profiles::meizu_16t();
        let r = Router::new(
            &dev,
            vec![zoo::squeezenet()],
            RouterConfig { execute_cold: true, ..Default::default() },
        );
        let out = r.request("squeezenet").unwrap();
        assert!(out.is_cold());
        let direct = r.session("squeezenet").unwrap().run_cold().unwrap();
        assert_eq!(latency(&out).to_bits(), direct.latency_ms.to_bits());
        // Warm requests still charge the ladder.
        let warm = r.request("squeezenet").unwrap();
        assert!(warm.is_warm());
        assert!(latency(&warm) < latency(&out));
    }

    #[test]
    fn impossible_deadline_degrades_every_request() {
        let r = router(1 << 30);
        for _ in 0..10 {
            let o = r.request_with("tinynet", Some(0.0)).unwrap();
            assert!(o.is_degraded());
            assert!(latency(&o) > 0.0);
        }
        let s = r.summary();
        assert_eq!(s.degraded, 10);
        assert_eq!(s.degraded_deadline, 10);
        // Degradation never touches residency: the model stayed cold-due.
        assert_eq!((s.cold, s.warm), (0, 0));
        assert!(s.conserves());
        assert_eq!(r.recorded("degraded").len(), 10);
    }

    #[test]
    fn generous_deadline_serves_normally() {
        let r = router(1 << 30);
        let o = r.request_with("tinynet", Some(1e12)).unwrap();
        assert!(o.is_cold());
        assert_eq!(r.summary().degraded, 0);
    }

    #[test]
    fn degraded_latency_is_the_searchfree_estimate() {
        // The degraded path charges the baseline-shaped (search-free)
        // plan: pricier than the NNV12 cold start it replaces would have
        // been — degradation trades latency for skipping planned work,
        // not a free lunch.
        let r = router(1 << 30);
        let degraded = latency(&r.request_with("squeezenet", Some(0.0)).unwrap());
        let cold = latency(&r.request("squeezenet").unwrap());
        assert!(
            degraded >= cold,
            "degraded {degraded} must not beat the planned cold start {cold}"
        );
    }

    #[test]
    fn zero_admission_sheds_every_cold_start() {
        let dev = profiles::meizu_16t();
        let r = Router::new(
            &dev,
            vec![zoo::tiny_net()],
            RouterConfig { admission: Some(0), ..Default::default() },
        );
        for _ in 0..5 {
            assert!(r.request("tinynet").unwrap().is_shed());
        }
        let s = r.summary();
        assert_eq!((s.issued, s.shed, s.cold, s.warm), (5, 5, 0, 0));
        assert!(s.conserves());
    }

    #[test]
    fn breaker_opens_after_consecutive_failures_and_probes_closed() {
        // Deterministic end-to-end breaker walk: 5 injected transient
        // exec failures (call counts 0..4), threshold 5, cooldown 16,
        // 2 retries per request.
        //
        //   req 1: 3 attempts, all fail              → Failed
        //   req 2: 2 attempts fail, 5th trips breaker → Degraded (opens)
        //   req 3–18: short-circuit through cooldown  → 16 × Degraded
        //   req 19: half-open probe, exec succeeds    → Cold (closes)
        //   req 20: resident                          → Warm
        let plan = Arc::new(
            FaultPlan::new(1)
                .with_rule(FaultSite::ExecRun, FaultKind::ExecFail, Trigger::At(0))
                .with_rule(FaultSite::ExecRun, FaultKind::ExecFail, Trigger::At(1))
                .with_rule(FaultSite::ExecRun, FaultKind::ExecFail, Trigger::At(2))
                .with_rule(FaultSite::ExecRun, FaultKind::ExecFail, Trigger::At(3))
                .with_rule(FaultSite::ExecRun, FaultKind::ExecFail, Trigger::At(4)),
        );
        let dev = profiles::meizu_16t();
        let r = Router::new(
            &dev,
            vec![zoo::tiny_net()],
            RouterConfig {
                execute_cold: true,
                faults: Some(plan),
                breaker: BreakerPolicy { threshold: 5, cooldown: 16 },
                retry: RetryPolicy { max_retries: 2, ..Default::default() },
                ..Default::default()
            },
        );
        let outcomes: Vec<Outcome> =
            (0..20).map(|_| r.request("tinynet").unwrap()).collect();
        assert!(outcomes[0].is_failed());
        if let Outcome::Failed { attempts, error } = &outcomes[0] {
            assert_eq!(*attempts, 3);
            assert!(error.contains("injected"), "{error}");
        }
        for (i, o) in outcomes.iter().enumerate().take(18).skip(1) {
            assert!(o.is_degraded(), "request {i} should short-circuit: {o:?}");
        }
        assert!(outcomes[18].is_cold(), "probe request serves cold: {:?}", outcomes[18]);
        assert!(outcomes[19].is_warm());

        let s = r.summary();
        assert_eq!(s.issued, 20);
        assert_eq!((s.cold, s.warm), (1, 1));
        assert_eq!(s.degraded, 17);
        assert_eq!(s.degraded_breaker, 17);
        assert_eq!(s.failed, 1);
        assert_eq!(s.shed, 0);
        assert_eq!(s.exec_failures, 5);
        assert_eq!(s.exec_panics, 0);
        assert_eq!(s.retries, 3);
        assert_eq!(s.breaker_opens, 1);
        assert_eq!(s.breaker_probes, 1);
        assert!(s.conserves());
    }

    #[test]
    fn retried_cold_start_charges_backoff() {
        // One transient failure then success: the request serves cold
        // with exactly the executed latency plus one seeded backoff.
        let plan = Arc::new(FaultPlan::new(7).with_rule(
            FaultSite::ExecRun,
            FaultKind::ExecFail,
            Trigger::At(0),
        ));
        let dev = profiles::meizu_16t();
        let mk = |faults| {
            Router::new(
                &dev,
                vec![zoo::tiny_net()],
                RouterConfig { execute_cold: true, faults, ..Default::default() },
            )
        };
        let faulty = mk(Some(plan));
        let clean = mk(None);
        let o = faulty.request("tinynet").unwrap();
        assert!(o.is_cold());
        assert_eq!(o.served().unwrap().retries, 1);
        let baseline = clean.request("tinynet").unwrap();
        let penalty = latency(&o) - latency(&baseline);
        assert!(
            penalty > 2.4 && penalty < 5.1,
            "one base-5ms backoff with jitter in [0.5,1.0): {penalty}"
        );
        let s = faulty.summary();
        assert_eq!((s.exec_failures, s.retries, s.failed), (1, 1, 0));
        // And the same seed reproduces the same charged backoff.
        let again = mk(Some(Arc::new(FaultPlan::new(7).with_rule(
            FaultSite::ExecRun,
            FaultKind::ExecFail,
            Trigger::At(0),
        ))));
        assert_eq!(
            latency(&again.request("tinynet").unwrap()).to_bits(),
            latency(&o).to_bits()
        );
    }

    #[test]
    fn injected_panic_is_caught_and_counted() {
        let plan = Arc::new(FaultPlan::new(9).with_rule(
            FaultSite::ExecRun,
            FaultKind::ExecPanic,
            Trigger::At(0),
        ));
        let dev = profiles::meizu_16t();
        let r = Router::new(
            &dev,
            vec![zoo::tiny_net()],
            RouterConfig { execute_cold: true, faults: Some(plan), ..Default::default() },
        );
        // The panic is absorbed by the retry loop; the retry succeeds.
        let o = r.request("tinynet").unwrap();
        assert!(o.is_cold());
        let s = r.summary();
        assert_eq!((s.exec_panics, s.exec_failures, s.retries), (1, 1, 1));
        assert!(s.conserves());
    }

    /// A remote generous enough that offloading a branchy model's tail
    /// clearly beats its local cold start.
    fn fast_remote() -> OffloadPolicy {
        OffloadPolicy {
            rtt_ms: 5.0,
            bandwidth_mbps: 1000.0,
            remote_speedup: 10.0,
            remote_cold_ms: 2.0,
        }
    }

    #[test]
    fn tight_deadline_offloads_the_multi_exit_tail() {
        let dev = profiles::meizu_16t();
        let policy = fast_remote();
        let r = Router::new(
            &dev,
            vec![zoo::branchy_resnet18(), zoo::resnet18()],
            RouterConfig { offload: Some(policy), ..Default::default() },
        );
        let session = r.session("branchy-resnet18").unwrap();
        let cold = session.cold_ms();
        let est = offload_estimate(session.graph(), &policy, cold).unwrap();
        assert!(est.expected_ms < cold, "offload must beat local cold here");
        // A deadline between the offload estimate and the local cold
        // estimate: local misses, offload fits.
        let d = (est.expected_ms + cold) / 2.0;
        let o = r.request_with("branchy-resnet18", Some(d)).unwrap();
        assert!(o.is_offloaded(), "{o:?}");
        assert_eq!(latency(&o).to_bits(), est.expected_ms.to_bits());
        // A single-exit model with the same policy still degrades.
        let o2 = r.request_with("resnet18", Some(0.0)).unwrap();
        assert!(o2.is_degraded());
        let s = r.summary();
        assert_eq!((s.offloaded, s.degraded, s.degraded_deadline), (1, 1, 1));
        // Offload leaves residency untouched, like degradation.
        assert_eq!((s.cold, s.warm), (0, 0));
        assert!(s.conserves());
        assert_eq!(r.recorded("offloaded").len(), 1);
    }

    #[test]
    fn injected_offload_drop_falls_back_to_degraded() {
        use crate::faults::Trigger;
        let plan = Arc::new(FaultPlan::new(11).with_rule(
            FaultSite::OffloadSend,
            FaultKind::OffloadDrop,
            Trigger::At(0),
        ));
        let dev = profiles::meizu_16t();
        let policy = fast_remote();
        let r = Router::new(
            &dev,
            vec![zoo::branchy_resnet18()],
            RouterConfig {
                offload: Some(policy),
                faults: Some(plan.clone()),
                ..Default::default()
            },
        );
        let session = r.session("branchy-resnet18").unwrap();
        let est = offload_estimate(session.graph(), &policy, session.cold_ms()).unwrap();
        let d = (est.expected_ms + session.cold_ms()) / 2.0;
        // First send is dropped → degraded; the retry-free fallback never
        // hangs. Second request's send is clean → offloaded.
        let first = r.request_with("branchy-resnet18", Some(d)).unwrap();
        assert!(first.is_degraded(), "{first:?}");
        let second = r.request_with("branchy-resnet18", Some(d)).unwrap();
        assert!(second.is_offloaded(), "{second:?}");
        let s = r.summary();
        assert_eq!((s.offloaded, s.degraded, s.degraded_offload), (1, 1, 1));
        assert_eq!(s.degraded_deadline, 0);
        assert_eq!(plan.injected(FaultKind::OffloadDrop), 1);
        assert_eq!(plan.calls(FaultSite::OffloadSend), 2);
        assert!(s.conserves());
    }

    #[test]
    fn queued_request_waits_for_a_slot_instead_of_shedding() {
        let dev = profiles::meizu_16t();
        let r = Router::new(
            &dev,
            vec![zoo::tiny_net()],
            RouterConfig {
                admission: Some(1),
                queue_depth: Some(4),
                ..Default::default()
            },
        );
        let shard = r.shard_of("tinynet");
        // Occupy the only admission slot by hand, issue the request from
        // another thread — it must queue rather than shed — then release
        // the slot and watch the queued request complete normally.
        r.cold_inflight[shard].fetch_add(1, Ordering::Relaxed);
        let out = std::thread::scope(|s| {
            let h = s.spawn(|| r.request("tinynet").unwrap());
            while r.summary().queued == 0 {
                std::thread::yield_now();
            }
            r.cold_inflight[shard].fetch_sub(1, Ordering::Relaxed);
            h.join().unwrap()
        });
        assert!(out.is_cold(), "{out:?}");
        let s = r.summary();
        assert_eq!((s.queued, s.shed), (1, 0));
        assert_eq!(r.cold_inflight[shard].load(Ordering::Relaxed), 0);
        assert_eq!(r.queue_waiting[shard].load(Ordering::Relaxed), 0);
        assert!(s.conserves());
    }

    #[test]
    fn futile_queue_with_zero_admission_still_sheds() {
        // admission == Some(0) can never free a slot, so queueing would
        // hang forever; the router must recognize futility and shed.
        let dev = profiles::meizu_16t();
        let r = Router::new(
            &dev,
            vec![zoo::tiny_net()],
            RouterConfig {
                admission: Some(0),
                queue_depth: Some(8),
                ..Default::default()
            },
        );
        assert!(r.request("tinynet").unwrap().is_shed());
        let s = r.summary();
        assert_eq!((s.shed, s.queued), (1, 0));
        assert!(s.conserves());
    }

    #[test]
    fn per_model_latency_series_merge_across_shards() {
        let r = router(1 << 30);
        r.request("tinynet").unwrap();
        r.request("tinynet").unwrap();
        r.request("squeezenet").unwrap();
        assert_eq!(r.recorded("cold").len(), 2);
        assert_eq!(r.recorded("warm").len(), 1);
        assert_eq!(r.recorded("tinynet:cold").len(), 1);
        assert_eq!(r.recorded("tinynet:warm").len(), 1);
        assert_eq!(r.recorded("squeezenet:cold").len(), 1);
        assert_eq!(r.latency_summary("cold").n, 2);
        // The merged aggregate is exactly the union of the per-model
        // series, wherever each model's shard recorder lives.
        let mut merged = r.recorded("tinynet:cold");
        merged.extend(r.recorded("squeezenet:cold"));
        merged.sort_by(f64::total_cmp);
        let mut agg = r.recorded("cold");
        agg.sort_by(f64::total_cmp);
        assert_eq!(agg, merged);
    }

    #[test]
    fn tenanted_router_partitions_and_counts() {
        let dev = profiles::meizu_16t();
        let models = vec![zoo::tiny_net(), zoo::micro_mobilenet(), zoo::squeezenet()];
        let names: Vec<String> = models.iter().map(|g| g.name.clone()).collect();
        let r = Router::new(
            &dev,
            models,
            RouterConfig { memory_budget: 1 << 30, tenants: 2, ..Default::default() },
        );
        // Round-robin ownership over construction order.
        assert_eq!(r.session(&names[0]).unwrap().tenant(), Some("tenant-0"));
        assert_eq!(r.session(&names[1]).unwrap().tenant(), Some("tenant-1"));
        assert_eq!(r.session(&names[2]).unwrap().tenant(), Some("tenant-0"));
        // Requests without an explicit tenant attribute to the owner…
        r.request(&names[0]).unwrap();
        r.request(&names[0]).unwrap();
        // …and an explicit requesting tenant wins over ownership.
        r.request_for(&names[1], None, Some("tenant-0")).unwrap();
        let s = r.summary();
        assert!(s.conserves());
        assert_eq!(s.per_tenant.len(), 2);
        assert_eq!(s.per_tenant[0].tenant, "tenant-0");
        assert_eq!(s.per_tenant[1].tenant, "tenant-1");
        assert_eq!((s.per_tenant[0].cold, s.per_tenant[0].warm), (2, 1));
        assert_eq!((s.per_tenant[1].cold, s.per_tenant[1].warm), (0, 0));
        // With every model tenant-owned, per-tenant sums match globals.
        let cold: usize = s.per_tenant.iter().map(|t| t.cold).sum();
        let warm: usize = s.per_tenant.iter().map(|t| t.warm).sum();
        assert_eq!((cold, warm), (s.cold, s.warm));
        // An untenanted router reports no per-tenant rows.
        assert!(router(1 << 30).summary().per_tenant.is_empty());
    }

    #[test]
    fn taxonomy_is_all_zero_without_faults() {
        let r = router(24 << 20);
        for m in ["tinynet", "micro-mobilenet", "squeezenet", "tinynet"] {
            assert!(r.request(m).unwrap().served().is_some());
        }
        let s = r.summary();
        assert_eq!(s.degraded + s.shed + s.failed, 0);
        assert_eq!(
            (s.exec_failures, s.retries, s.breaker_opens, s.breaker_probes),
            (0, 0, 0, 0)
        );
        assert!(s.conserves());
    }
}
