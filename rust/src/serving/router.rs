//! The request router: a sharded, concurrent serving front over the
//! engine facade.
//!
//! All planning, warm-up-ladder computation, and LRU residency live in
//! [`crate::engine`]; the router contributes the per-model request
//! surface, request statistics, and the engine-choice knob (NNV12 vs a
//! vanilla baseline) used by the serving comparisons.
//!
//! # Threading model
//!
//! [`Router`] is `Send + Sync` and [`Router::request`] takes `&self`:
//! share one router across N serving threads (an `Arc`, a scoped
//! borrow — either works) and hammer it. Internally:
//!
//! * The model → session map is a **hand-rolled sharded hash map**
//!   (`SHARDS` `Mutex<HashMap<String, Arc<Session>>>` buckets keyed by a
//!   hash of the model name — the vendored crate set has no `DashMap`,
//!   and doesn't need one). A request locks exactly one shard just long
//!   enough to clone the session's `Arc`, then serves **outside** the
//!   lock, so requests for different models never serialize on the map
//!   and requests for the same model only serialize at the engine's
//!   residency lock. Shards exist because the map is mutable at runtime
//!   ([`Router::register`] / [`Router::remove`] add and retire models
//!   while requests are in flight).
//! * Request counters are atomics; the latency [`Recorder`] sits behind
//!   its own small `Mutex` (label scan + push — never held across
//!   inference work, and never exposed as a guard: [`Router::summary`]
//!   and [`Router::recorded`] hand out snapshots).
//! * Everything else (residency/LRU, plan caches, the artifact store,
//!   backends) is the engine's thread-safe substrate.
//!
//! The multi-threaded request path is *deterministic in aggregate*:
//! replaying the same trace with 1 or N threads produces the same
//! cold/warm totals and bit-identical plans whenever residency outcomes
//! don't depend on interleaving (proven in
//! `tests/concurrent_serving.rs`; under an eviction-thrashing budget the
//! totals still add up, but which request goes cold legitimately depends
//! on arrival order, exactly as on a real device).

use std::collections::hash_map::DefaultHasher;
use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::engine::{BaselineBackend, Engine, ExecBackend, Phase, Session, SimBackend};
use crate::device::DeviceProfile;
use crate::graph::ModelGraph;
use crate::metrics::Recorder;
use crate::sched::cache::PlanCache;
use crate::serving::workload::Request;
use crate::store::ArtifactStore;
use crate::Ms;

/// Number of session-map shards (power of two; max concurrent
/// registrations/lookups that never contend, assuming a decent hash).
const SHARDS: usize = 16;

/// One bucket of the sharded session map.
type Shard = Mutex<HashMap<String, Arc<Session>>>;

/// Serving engine the router charges latencies from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServeEngine {
    Nnv12,
    Ncnn,
}

/// Router configuration.
#[derive(Debug, Clone)]
pub struct RouterConfig {
    /// Device memory available for resident models, bytes.
    pub memory_budget: u64,
    pub engine: ServeEngine,
    /// Length of the warm-up latency ladder computed per model.
    pub warmup_depth: usize,
    /// Execute cold requests through the engine's backend (the
    /// contention-aware simulator for [`ServeEngine::Nnv12`]) instead of
    /// charging the planner's precomputed cold estimate. Costs real
    /// (deterministic) compute per cold request — which is the point of
    /// the throughput benchmark: cold work parallelizes across serving
    /// threads. Default off, preserving the cheap charge-only semantics.
    pub execute_cold: bool,
}

impl Default for RouterConfig {
    fn default() -> RouterConfig {
        RouterConfig {
            memory_budget: 64 << 20,
            engine: ServeEngine::Nnv12,
            warmup_depth: 4,
            execute_cold: false,
        }
    }
}

/// Outcome of one routed request.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Outcome {
    pub latency_ms: Ms,
    pub cold: bool,
    pub evictions: usize,
}

/// The router: named [`Session`]s over one shared [`Engine`], behind a
/// sharded concurrent map. `Send + Sync`; [`Router::request`] is `&self`.
pub struct Router {
    engine: Engine,
    shards: Vec<Shard>,
    recorder: Mutex<Recorder>,
    stats_cold: AtomicUsize,
    stats_warm: AtomicUsize,
    stats_exec_failed: AtomicUsize,
    execute_cold: bool,
}

impl Router {
    /// Build a router: plans every model on `dev` up front (the paper's
    /// offline decision stage, parallel across models); each model's
    /// warm-up ladder is computed lazily on its first request. Plans come
    /// from a fresh private [`PlanCache`]; use
    /// [`Router::with_plan_cache`] to share one across routers (ablation
    /// arms, engine comparisons, router restarts) so repeated
    /// cold-planning of the same model × device × config is free.
    pub fn new(dev: &DeviceProfile, models: Vec<ModelGraph>, cfg: RouterConfig) -> Router {
        Router::with_plan_cache(dev, models, cfg, Arc::new(PlanCache::new()))
    }

    /// [`Router::new`] planning through a shared plan cache.
    pub fn with_plan_cache(
        dev: &DeviceProfile,
        models: Vec<ModelGraph>,
        cfg: RouterConfig,
        plan_cache: Arc<PlanCache>,
    ) -> Router {
        let builder = Router::builder_for(dev, &cfg).plan_cache(plan_cache);
        Router::finish(builder.build(), models, &cfg)
    }

    /// [`Router::new`] persisting plans through a shared content-addressed
    /// [`ArtifactStore`]: a restarted router — including one in a fresh
    /// process — pointed at the same store directory skips every plan
    /// search (observable via [`Engine::store_stats`]).
    pub fn with_artifact_store(
        dev: &DeviceProfile,
        models: Vec<ModelGraph>,
        cfg: RouterConfig,
        store: Arc<ArtifactStore>,
    ) -> Router {
        let builder = Router::builder_for(dev, &cfg).artifact_store_shared(store);
        Router::finish(builder.build(), models, &cfg)
    }

    fn builder_for(dev: &DeviceProfile, cfg: &RouterConfig) -> crate::engine::EngineBuilder {
        let backend: Box<dyn ExecBackend> = match cfg.engine {
            ServeEngine::Nnv12 => Box::new(SimBackend::nnv12()),
            ServeEngine::Ncnn => Box::new(BaselineBackend::ncnn()),
        };
        Engine::builder()
            .device(dev.clone())
            .memory_budget(cfg.memory_budget)
            .warmup_depth(cfg.warmup_depth)
            .backend_box(backend)
    }

    fn finish(engine: Engine, models: Vec<ModelGraph>, cfg: &RouterConfig) -> Router {
        let router = Router {
            engine,
            shards: (0..SHARDS).map(|_| Mutex::new(HashMap::new())).collect(),
            recorder: Mutex::new(Recorder::new()),
            stats_cold: AtomicUsize::new(0),
            stats_warm: AtomicUsize::new(0),
            stats_exec_failed: AtomicUsize::new(0),
            execute_cold: cfg.execute_cold,
        };
        for s in router.engine.load_all(models) {
            router.insert(s);
        }
        router
    }

    /// The shard index serving `model`.
    fn shard_of(&self, model: &str) -> usize {
        let mut h = DefaultHasher::new();
        model.hash(&mut h);
        (h.finish() as usize) & (SHARDS - 1)
    }

    fn insert(&self, session: Session) {
        let name = session.name().to_string();
        let shard = self.shard_of(&name);
        self.shards[shard]
            .lock()
            .unwrap()
            .insert(name, Arc::new(session));
    }

    /// Plan and add a model at runtime (`&self`: callable while other
    /// threads serve requests — they contend only on this model's
    /// shard). Replaces any existing session of the same name; its
    /// residency is released when the last in-flight request drops the
    /// old `Arc`.
    pub fn register(&self, model: ModelGraph) {
        self.insert(self.engine.load(model));
    }

    /// Retire a model. In-flight requests holding the session's `Arc`
    /// finish normally; residency is released once they drop it.
    pub fn remove(&self, model: &str) -> bool {
        let shard = self.shard_of(model);
        self.shards[shard].lock().unwrap().remove(model).is_some()
    }

    pub fn model_names(&self) -> Vec<String> {
        let mut v: Vec<String> = self
            .shards
            .iter()
            .flat_map(|s| s.lock().unwrap().keys().cloned().collect::<Vec<_>>())
            .collect();
        v.sort();
        v
    }

    pub fn is_resident(&self, name: &str) -> bool {
        self.session(name).is_some_and(|s| s.is_resident())
    }

    /// Handle a request for `model`: one [`Session::infer`] plus request
    /// accounting, from any thread. `None` for unknown models.
    ///
    /// The shard lock covers only the `Arc` clone; inference (residency
    /// charge, lazy ladder, and — with [`RouterConfig::execute_cold`] —
    /// backend execution) runs outside it.
    pub fn request(&self, model: &str) -> Option<Outcome> {
        let session = self.session(model)?;
        let r = session.infer();
        let cold = r.phase == Phase::Cold;
        let mut latency = r.latency_ms;
        if cold && self.execute_cold {
            // Execute the cold inference through the backend (the
            // deterministic contention-aware simulation, or a real run);
            // fall back to the charged estimate if the backend cannot —
            // counted, so a silently broken backend is observable via
            // [`Router::stats_exec_failed`].
            match session.run_cold() {
                Ok(out) => latency = out.latency_ms,
                Err(_) => {
                    self.stats_exec_failed.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
        let label = if cold { "cold" } else { "warm" };
        if cold {
            self.stats_cold.fetch_add(1, Ordering::Relaxed);
        } else {
            self.stats_warm.fetch_add(1, Ordering::Relaxed);
        }
        // The per-model label is formatted before taking the recorder
        // lock: the critical section is two label-scan + push appends,
        // never an allocation.
        let model_label = format!("{model}:{label}");
        {
            let mut rec = self.recorder.lock().unwrap();
            rec.record(label, latency);
            rec.record(&model_label, latency);
        }
        Some(Outcome { latency_ms: latency, cold, evictions: r.evictions })
    }

    /// Replay a request trace across `threads` serving threads (request
    /// `i` goes to thread `i % threads`, each thread serving its share
    /// in trace order). Returns the number of requests served (requests
    /// for unknown models are skipped). `threads <= 1` replays inline —
    /// the single-threaded baseline the throughput ratchet compares
    /// against.
    pub fn replay(&self, reqs: &[Request], threads: usize) -> usize {
        if threads <= 1 {
            return reqs
                .iter()
                .filter(|r| self.request(&r.model).is_some())
                .count();
        }
        let served = AtomicUsize::new(0);
        std::thread::scope(|scope| {
            for t in 0..threads {
                let served = &served;
                scope.spawn(move || {
                    let n = reqs
                        .iter()
                        .skip(t)
                        .step_by(threads)
                        .filter(|r| self.request(&r.model).is_some())
                        .count();
                    served.fetch_add(n, Ordering::Relaxed);
                });
            }
        });
        served.into_inner()
    }

    /// Requests that hit the cold path so far.
    pub fn stats_cold(&self) -> usize {
        self.stats_cold.load(Ordering::Relaxed)
    }

    /// Requests served warm (resident) so far.
    pub fn stats_warm(&self) -> usize {
        self.stats_warm.load(Ordering::Relaxed)
    }

    /// Cold requests whose [`RouterConfig::execute_cold`] backend
    /// execution failed and fell back to the charged estimate (always 0
    /// when `execute_cold` is off). A nonzero value means reported cold
    /// latencies are planner estimates, not executed ones.
    pub fn stats_exec_failed(&self) -> usize {
        self.stats_exec_failed.load(Ordering::Relaxed)
    }

    /// Latency summary for a recorder label (`"cold"`, `"warm"`, or a
    /// per-model `"model:cold"`/`"model:warm"` key). Snapshot API on
    /// purpose: the recorder lock is taken and released inside the call,
    /// so callers can never hold it across another router call (a guard
    /// held while calling [`Router::request`] on the same thread would
    /// self-deadlock on the non-reentrant lock).
    pub fn summary(&self, label: &str) -> crate::util::stats::Summary {
        self.recorder.lock().unwrap().summary(label)
    }

    /// Snapshot of the raw latency observations recorded under `label`
    /// (empty for unknown labels). Cloned out from under the recorder
    /// lock — see [`Router::summary`] for why no guard is exposed.
    pub fn recorded(&self, label: &str) -> Vec<f64> {
        self.recorder.lock().unwrap().values(label).to_vec()
    }

    /// The underlying engine (residency, plan cache, device).
    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    /// The session serving `model` (an `Arc` clone — callers can infer
    /// on it directly, concurrently with the router).
    pub fn session(&self, model: &str) -> Option<Arc<Session>> {
        let shard = self.shard_of(model);
        self.shards[shard].lock().unwrap().get(model).cloned()
    }

    /// The shared plan cache.
    pub fn plan_cache(&self) -> &Arc<PlanCache> {
        self.engine.plan_cache()
    }

    pub fn mem_used(&self) -> u64 {
        self.engine.mem_used()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::profiles;
    use crate::graph::zoo;

    fn router(budget: u64) -> Router {
        let dev = profiles::meizu_16t();
        let models = vec![zoo::tiny_net(), zoo::micro_mobilenet(), zoo::squeezenet()];
        Router::new(&dev, models, RouterConfig { memory_budget: budget, ..Default::default() })
    }

    #[test]
    fn first_request_cold_second_warm() {
        let r = router(1 << 30);
        let a = r.request("tinynet").unwrap();
        assert!(a.cold);
        let b = r.request("tinynet").unwrap();
        assert!(!b.cold);
        assert!(b.latency_ms <= a.latency_ms);
        assert_eq!(r.stats_cold(), 1);
        assert_eq!(r.stats_warm(), 1);
    }

    #[test]
    fn warm_ladder_descends_to_steady_state() {
        let r = router(1 << 30);
        let l1 = r.request("squeezenet").unwrap().latency_ms;
        let l2 = r.request("squeezenet").unwrap().latency_ms;
        let l3 = r.request("squeezenet").unwrap().latency_ms;
        let l4 = r.request("squeezenet").unwrap().latency_ms;
        assert!(l1 > l2, "cold {l1} > 2nd {l2}");
        assert!(l2 >= l3, "2nd {l2} >= 3rd {l3}");
        assert_eq!(l3, l4, "steady state from 3rd inference");
    }

    #[test]
    fn tight_budget_causes_evictions_and_recold() {
        // Budget fits roughly one model: alternating requests thrash.
        let r = router(6 << 20);
        r.request("squeezenet").unwrap();
        let out = r.request("micro-mobilenet");
        // squeezenet (~5MB resident +25%) + micro must exceed 6MB ⇒ evict.
        let out = out.unwrap();
        assert!(out.cold);
        assert!(out.evictions > 0 || r.mem_used() <= 6 << 20);
        let back = r.request("squeezenet").unwrap();
        assert!(back.cold, "evicted model must cold-start again");
    }

    #[test]
    fn shared_plan_cache_skips_replanning() {
        let dev = profiles::meizu_16t();
        let models = || vec![zoo::tiny_net(), zoo::squeezenet()];
        let cache = Arc::new(PlanCache::new());
        let a = Router::with_plan_cache(&dev, models(), RouterConfig::default(), cache.clone());
        assert_eq!(cache.misses(), 2, "first router plans each model once");
        assert_eq!(cache.hits(), 0);
        // A restarted / sibling router re-uses every plan.
        let b = Router::with_plan_cache(&dev, models(), RouterConfig::default(), cache.clone());
        assert_eq!(cache.misses(), 2);
        assert_eq!(cache.hits(), 2);
        // And identical plans ⇒ identical cold latencies.
        assert_eq!(
            a.request("squeezenet").unwrap().latency_ms.to_bits(),
            b.request("squeezenet").unwrap().latency_ms.to_bits()
        );
    }

    #[test]
    fn restarted_router_on_shared_store_skips_planning() {
        let dir = std::env::temp_dir().join(format!(
            "nnv12-router-store-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let dev = profiles::meizu_16t();
        let models = || vec![zoo::tiny_net(), zoo::squeezenet()];

        let store = Arc::new(ArtifactStore::open(&dir).unwrap());
        let a = Router::with_artifact_store(&dev, models(), RouterConfig::default(), store);
        assert_eq!(a.plan_cache().misses(), 2, "first router plans each model");

        // A "restarted" router: fresh store handle over the same directory
        // (≈ a fresh process). Every plan comes from disk.
        let store2 = Arc::new(ArtifactStore::open(&dir).unwrap());
        let b =
            Router::with_artifact_store(&dev, models(), RouterConfig::default(), store2);
        assert_eq!(b.plan_cache().misses(), 0, "restart must not re-plan");
        assert_eq!(b.plan_cache().disk_hits(), 2);
        let stats = b.engine().store_stats().unwrap();
        assert_eq!(stats.hits, 2);
        assert_eq!(
            a.request("squeezenet").unwrap().latency_ms.to_bits(),
            b.request("squeezenet").unwrap().latency_ms.to_bits(),
            "stored plans must reproduce identical serving latencies"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn unknown_model_is_none() {
        let r = router(1 << 30);
        assert!(r.request("nope").is_none());
    }

    #[test]
    fn register_and_remove_at_runtime() {
        let r = router(1 << 30);
        assert!(r.request("mobilenetv2").is_none());
        r.register(zoo::mobilenet_v2());
        let out = r.request("mobilenetv2").expect("registered model serves");
        assert!(out.cold);
        assert!(r.model_names().contains(&"mobilenetv2".to_string()));
        assert!(r.remove("mobilenetv2"));
        assert!(r.request("mobilenetv2").is_none());
        assert!(!r.remove("mobilenetv2"), "second remove is a no-op");
    }

    #[test]
    fn nnv12_colder_starts_beat_ncnn() {
        let dev = profiles::meizu_16t();
        let models = vec![zoo::squeezenet()];
        let nnv12 = Router::new(
            &dev,
            models.clone(),
            RouterConfig { engine: ServeEngine::Nnv12, ..Default::default() },
        );
        let ncnn = Router::new(
            &dev,
            models,
            RouterConfig { engine: ServeEngine::Ncnn, ..Default::default() },
        );
        let a = nnv12.request("squeezenet").unwrap().latency_ms;
        let b = ncnn.request("squeezenet").unwrap().latency_ms;
        assert!(a < b, "nnv12 cold {a} vs ncnn cold {b}");
    }

    #[test]
    fn executed_cold_requests_match_the_simulator() {
        // With `execute_cold`, a cold request's latency is the
        // deterministic contention-aware simulation of the plan, not the
        // planner's ladder estimate.
        let dev = profiles::meizu_16t();
        let r = Router::new(
            &dev,
            vec![zoo::squeezenet()],
            RouterConfig { execute_cold: true, ..Default::default() },
        );
        let out = r.request("squeezenet").unwrap();
        assert!(out.cold);
        let direct = r.session("squeezenet").unwrap().run_cold().unwrap();
        assert_eq!(out.latency_ms.to_bits(), direct.latency_ms.to_bits());
        // Warm requests still charge the ladder.
        let warm = r.request("squeezenet").unwrap();
        assert!(!warm.cold);
        assert!(warm.latency_ms < out.latency_ms);
    }
}
