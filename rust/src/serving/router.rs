//! The request router: a thin serving front over the engine facade.
//!
//! All planning, warm-up-ladder computation, and LRU residency live in
//! [`crate::engine`]; the router contributes the per-model request
//! surface, request statistics, and the engine-choice knob (NNV12 vs a
//! vanilla baseline) used by the serving comparisons.

use std::collections::HashMap;
use std::sync::Arc;

use crate::engine::{BaselineBackend, Engine, ExecBackend, Phase, Session, SimBackend};
use crate::device::DeviceProfile;
use crate::graph::ModelGraph;
use crate::metrics::Recorder;
use crate::sched::cache::PlanCache;
use crate::store::ArtifactStore;
use crate::Ms;

/// Serving engine the router charges latencies from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServeEngine {
    Nnv12,
    Ncnn,
}

/// Router configuration.
#[derive(Debug, Clone)]
pub struct RouterConfig {
    /// Device memory available for resident models, bytes.
    pub memory_budget: u64,
    pub engine: ServeEngine,
    /// Length of the warm-up latency ladder computed per model.
    pub warmup_depth: usize,
}

impl Default for RouterConfig {
    fn default() -> RouterConfig {
        RouterConfig {
            memory_budget: 64 << 20,
            engine: ServeEngine::Nnv12,
            warmup_depth: 4,
        }
    }
}

/// Outcome of one routed request.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Outcome {
    pub latency_ms: Ms,
    pub cold: bool,
    pub evictions: usize,
}

/// The router: named [`Session`]s over one shared [`Engine`].
pub struct Router {
    engine: Engine,
    sessions: HashMap<String, Session>,
    pub recorder: Recorder,
    pub stats_cold: usize,
    pub stats_warm: usize,
}

impl Router {
    /// Build a router: plans every model on `dev` up front (the paper's
    /// offline decision stage, parallel across models); each model's
    /// warm-up ladder is computed lazily on its first request. Plans come
    /// from a fresh private [`PlanCache`]; use
    /// [`Router::with_plan_cache`] to share one across routers (ablation
    /// arms, engine comparisons, router restarts) so repeated
    /// cold-planning of the same model × device × config is free.
    pub fn new(dev: &DeviceProfile, models: Vec<ModelGraph>, cfg: RouterConfig) -> Router {
        Router::with_plan_cache(dev, models, cfg, Arc::new(PlanCache::new()))
    }

    /// [`Router::new`] planning through a shared plan cache.
    pub fn with_plan_cache(
        dev: &DeviceProfile,
        models: Vec<ModelGraph>,
        cfg: RouterConfig,
        plan_cache: Arc<PlanCache>,
    ) -> Router {
        let builder = Router::builder_for(dev, &cfg).plan_cache(plan_cache);
        Router::finish(builder.build(), models)
    }

    /// [`Router::new`] persisting plans through a shared content-addressed
    /// [`ArtifactStore`]: a restarted router — including one in a fresh
    /// process — pointed at the same store directory skips every plan
    /// search (observable via [`Engine::store_stats`]).
    pub fn with_artifact_store(
        dev: &DeviceProfile,
        models: Vec<ModelGraph>,
        cfg: RouterConfig,
        store: Arc<ArtifactStore>,
    ) -> Router {
        let builder = Router::builder_for(dev, &cfg).artifact_store_shared(store);
        Router::finish(builder.build(), models)
    }

    fn builder_for(dev: &DeviceProfile, cfg: &RouterConfig) -> crate::engine::EngineBuilder {
        let backend: Box<dyn ExecBackend> = match cfg.engine {
            ServeEngine::Nnv12 => Box::new(SimBackend::nnv12()),
            ServeEngine::Ncnn => Box::new(BaselineBackend::ncnn()),
        };
        Engine::builder()
            .device(dev.clone())
            .memory_budget(cfg.memory_budget)
            .warmup_depth(cfg.warmup_depth)
            .backend_box(backend)
    }

    fn finish(engine: Engine, models: Vec<ModelGraph>) -> Router {
        let sessions = engine
            .load_all(models)
            .into_iter()
            .map(|s| (s.name().to_string(), s))
            .collect();
        Router {
            engine,
            sessions,
            recorder: Recorder::new(),
            stats_cold: 0,
            stats_warm: 0,
        }
    }

    pub fn model_names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.sessions.keys().cloned().collect();
        v.sort();
        v
    }

    pub fn is_resident(&self, name: &str) -> bool {
        self.sessions.get(name).map_or(false, |s| s.is_resident())
    }

    /// Handle a request for `model`: one [`Session::infer`] plus request
    /// accounting. `None` for unknown models.
    pub fn handle(&mut self, model: &str) -> Option<Outcome> {
        let session = self.sessions.get(model)?;
        let r = session.infer();
        let cold = r.phase == Phase::Cold;
        let label = if cold { "cold" } else { "warm" };
        if cold {
            self.stats_cold += 1;
        } else {
            self.stats_warm += 1;
        }
        self.recorder.record(label, r.latency_ms);
        self.recorder.record(&format!("{model}:{label}"), r.latency_ms);
        Some(Outcome { latency_ms: r.latency_ms, cold, evictions: r.evictions })
    }

    /// The underlying engine (residency, plan cache, device).
    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    /// The session serving `model`.
    pub fn session(&self, model: &str) -> Option<&Session> {
        self.sessions.get(model)
    }

    /// The shared plan cache.
    pub fn plan_cache(&self) -> &Arc<PlanCache> {
        self.engine.plan_cache()
    }

    pub fn mem_used(&self) -> u64 {
        self.engine.mem_used()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::profiles;
    use crate::graph::zoo;

    fn router(budget: u64) -> Router {
        let dev = profiles::meizu_16t();
        let models = vec![zoo::tiny_net(), zoo::micro_mobilenet(), zoo::squeezenet()];
        Router::new(&dev, models, RouterConfig { memory_budget: budget, ..Default::default() })
    }

    #[test]
    fn first_request_cold_second_warm() {
        let mut r = router(1 << 30);
        let a = r.handle("tinynet").unwrap();
        assert!(a.cold);
        let b = r.handle("tinynet").unwrap();
        assert!(!b.cold);
        assert!(b.latency_ms <= a.latency_ms);
        assert_eq!(r.stats_cold, 1);
        assert_eq!(r.stats_warm, 1);
    }

    #[test]
    fn warm_ladder_descends_to_steady_state() {
        let mut r = router(1 << 30);
        let l1 = r.handle("squeezenet").unwrap().latency_ms;
        let l2 = r.handle("squeezenet").unwrap().latency_ms;
        let l3 = r.handle("squeezenet").unwrap().latency_ms;
        let l4 = r.handle("squeezenet").unwrap().latency_ms;
        assert!(l1 > l2, "cold {l1} > 2nd {l2}");
        assert!(l2 >= l3, "2nd {l2} >= 3rd {l3}");
        assert_eq!(l3, l4, "steady state from 3rd inference");
    }

    #[test]
    fn tight_budget_causes_evictions_and_recold() {
        // Budget fits roughly one model: alternating requests thrash.
        let mut r = router(6 << 20);
        r.handle("squeezenet").unwrap();
        let out = r.handle("micro-mobilenet");
        // squeezenet (~5MB resident +25%) + micro must exceed 6MB ⇒ evict.
        let out = out.unwrap();
        assert!(out.cold);
        assert!(out.evictions > 0 || r.mem_used() <= 6 << 20);
        let back = r.handle("squeezenet").unwrap();
        assert!(back.cold, "evicted model must cold-start again");
    }

    #[test]
    fn shared_plan_cache_skips_replanning() {
        let dev = profiles::meizu_16t();
        let models = || vec![zoo::tiny_net(), zoo::squeezenet()];
        let cache = Arc::new(PlanCache::new());
        let a = Router::with_plan_cache(&dev, models(), RouterConfig::default(), cache.clone());
        assert_eq!(cache.misses(), 2, "first router plans each model once");
        assert_eq!(cache.hits(), 0);
        // A restarted / sibling router re-uses every plan.
        let b = Router::with_plan_cache(&dev, models(), RouterConfig::default(), cache.clone());
        assert_eq!(cache.misses(), 2);
        assert_eq!(cache.hits(), 2);
        // And identical plans ⇒ identical cold latencies.
        let mut a = a;
        let mut b = b;
        assert_eq!(
            a.handle("squeezenet").unwrap().latency_ms.to_bits(),
            b.handle("squeezenet").unwrap().latency_ms.to_bits()
        );
    }

    #[test]
    fn restarted_router_on_shared_store_skips_planning() {
        let dir = std::env::temp_dir().join(format!(
            "nnv12-router-store-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let dev = profiles::meizu_16t();
        let models = || vec![zoo::tiny_net(), zoo::squeezenet()];

        let store = Arc::new(ArtifactStore::open(&dir).unwrap());
        let a = Router::with_artifact_store(&dev, models(), RouterConfig::default(), store);
        assert_eq!(a.plan_cache().misses(), 2, "first router plans each model");

        // A "restarted" router: fresh store handle over the same directory
        // (≈ a fresh process). Every plan comes from disk.
        let store2 = Arc::new(ArtifactStore::open(&dir).unwrap());
        let mut b =
            Router::with_artifact_store(&dev, models(), RouterConfig::default(), store2);
        assert_eq!(b.plan_cache().misses(), 0, "restart must not re-plan");
        assert_eq!(b.plan_cache().disk_hits(), 2);
        let stats = b.engine().store_stats().unwrap();
        assert_eq!(stats.hits, 2);
        let mut a = a;
        assert_eq!(
            a.handle("squeezenet").unwrap().latency_ms.to_bits(),
            b.handle("squeezenet").unwrap().latency_ms.to_bits(),
            "stored plans must reproduce identical serving latencies"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn unknown_model_is_none() {
        let mut r = router(1 << 30);
        assert!(r.handle("nope").is_none());
    }

    #[test]
    fn nnv12_colder_starts_beat_ncnn() {
        let dev = profiles::meizu_16t();
        let models = vec![zoo::squeezenet()];
        let mut nnv12 = Router::new(
            &dev,
            models.clone(),
            RouterConfig { engine: ServeEngine::Nnv12, ..Default::default() },
        );
        let mut ncnn = Router::new(
            &dev,
            models,
            RouterConfig { engine: ServeEngine::Ncnn, ..Default::default() },
        );
        let a = nnv12.handle("squeezenet").unwrap().latency_ms;
        let b = ncnn.handle("squeezenet").unwrap().latency_ms;
        assert!(a < b, "nnv12 cold {a} vs ncnn cold {b}");
    }
}
