//! The request router and LRU model-residency manager.

use std::collections::HashMap;
use std::sync::Arc;

use crate::device::DeviceProfile;
use crate::graph::ModelGraph;
use crate::kernels::Registry;
use crate::metrics::Recorder;
use crate::sched::cache::PlanCache;
use crate::sched::heuristic::SchedulerConfig;
use crate::warm::continuous_from;
use crate::Ms;

/// Serving engine the router charges latencies from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServeEngine {
    Nnv12,
    Ncnn,
}

/// Router configuration.
#[derive(Debug, Clone)]
pub struct RouterConfig {
    /// Device memory available for resident models, bytes.
    pub memory_budget: u64,
    pub engine: ServeEngine,
    /// Length of the warm-up latency ladder computed per model.
    pub warmup_depth: usize,
}

impl Default for RouterConfig {
    fn default() -> RouterConfig {
        RouterConfig {
            memory_budget: 64 << 20,
            engine: ServeEngine::Nnv12,
            warmup_depth: 4,
        }
    }
}

/// A model registered with the router.
pub struct ServedModel {
    pub graph: ModelGraph,
    /// Latency ladder: [cold, 2nd, 3rd, …, steady warm].
    pub ladder: Vec<Ms>,
    pub warm_ms: Ms,
    /// Resident-set size (weights + transformed layouts), bytes.
    pub resident_bytes: u64,
}

/// Outcome of one routed request.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Outcome {
    pub latency_ms: Ms,
    pub cold: bool,
    pub evictions: usize,
}

/// The router.
pub struct Router {
    cfg: RouterConfig,
    models: HashMap<String, ServedModel>,
    /// Resident models, most-recently-used last, with per-model inference
    /// count since last cold start (drives the warm-up ladder).
    resident: Vec<(String, usize)>,
    mem_used: u64,
    /// Shared fingerprint-keyed plan cache (hits when the same
    /// model × device × config was already planned, by this router or a
    /// sibling sharing the cache).
    pub plan_cache: Arc<PlanCache>,
    pub recorder: Recorder,
    pub stats_cold: usize,
    pub stats_warm: usize,
}

impl Router {
    /// Build a router: plans every model on `dev` up front (the paper's
    /// offline decision stage) and computes its latency ladder. Plans come
    /// from a fresh private [`PlanCache`]; use [`Router::with_plan_cache`]
    /// to share one across routers (ablation arms, engine comparisons,
    /// router restarts) so repeated cold-planning of the same
    /// model × device × config is free.
    pub fn new(dev: &DeviceProfile, models: Vec<ModelGraph>, cfg: RouterConfig) -> Router {
        Router::with_plan_cache(dev, models, cfg, Arc::new(PlanCache::new()))
    }

    /// [`Router::new`] planning through a shared plan cache.
    pub fn with_plan_cache(
        dev: &DeviceProfile,
        models: Vec<ModelGraph>,
        cfg: RouterConfig,
        plan_cache: Arc<PlanCache>,
    ) -> Router {
        let registry = Registry::full();
        let mut map = HashMap::new();
        for g in models {
            let (ladder, warm_ms) = match cfg.engine {
                ServeEngine::Nnv12 => {
                    let sched_cfg = SchedulerConfig::kcp();
                    let s = plan_cache.get_or_plan(dev, &g, &registry, &sched_cfg, "full");
                    let r = continuous_from(dev, &g, &registry, cfg.warmup_depth, &s);
                    (r.latencies, r.warm_ms)
                }
                ServeEngine::Ncnn => {
                    let cold = crate::baselines::cold_ms(crate::baselines::Engine::Ncnn, dev, &g);
                    let warm = crate::baselines::warm_ms(crate::baselines::Engine::Ncnn, dev, &g);
                    (vec![cold, warm], warm)
                }
            };
            let resident_bytes = g.weight_bytes() + g.weight_bytes() / 4; // + workspace
            map.insert(
                g.name.clone(),
                ServedModel { graph: g, ladder, warm_ms, resident_bytes },
            );
        }
        Router {
            cfg,
            models: map,
            resident: Vec::new(),
            mem_used: 0,
            plan_cache,
            recorder: Recorder::new(),
            stats_cold: 0,
            stats_warm: 0,
        }
    }

    pub fn model_names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.models.keys().cloned().collect();
        v.sort();
        v
    }

    pub fn is_resident(&self, name: &str) -> bool {
        self.resident.iter().any(|(n, _)| n == name)
    }

    /// Handle a request for `model`. Evicts LRU models as needed to make
    /// the target resident; charges cold or warm-ladder latency.
    pub fn handle(&mut self, model: &str) -> Option<Outcome> {
        let m = self.models.get(model)?;
        let bytes = m.resident_bytes;
        let mut evictions = 0;

        if let Some(pos) = self.resident.iter().position(|(n, _)| n == model) {
            // Warm path: bump LRU position, advance the ladder.
            let (name, count) = self.resident.remove(pos);
            let ladder = &self.models[&name].ladder;
            let latency = *ladder
                .get((count + 1).min(ladder.len() - 1))
                .unwrap_or(&self.models[&name].warm_ms);
            self.resident.push((name, count + 1));
            self.stats_warm += 1;
            self.recorder.record("warm", latency);
            self.recorder.record(&format!("{model}:warm"), latency);
            return Some(Outcome { latency_ms: latency, cold: false, evictions: 0 });
        }

        // Cold path: evict until it fits (a model larger than the budget
        // still runs, transiently overcommitting like a real OS would).
        while self.mem_used + bytes > self.cfg.memory_budget && !self.resident.is_empty() {
            let (victim, _) = self.resident.remove(0);
            self.mem_used -= self.models[&victim].resident_bytes;
            evictions += 1;
        }
        let latency = self.models[model].ladder[0];
        self.mem_used += bytes;
        self.resident.push((model.to_string(), 0));
        self.stats_cold += 1;
        self.recorder.record("cold", latency);
        self.recorder.record(&format!("{model}:cold"), latency);
        Some(Outcome { latency_ms: latency, cold: true, evictions })
    }

    pub fn mem_used(&self) -> u64 {
        self.mem_used
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::profiles;
    use crate::graph::zoo;

    fn router(budget: u64) -> Router {
        let dev = profiles::meizu_16t();
        let models = vec![zoo::tiny_net(), zoo::micro_mobilenet(), zoo::squeezenet()];
        Router::new(&dev, models, RouterConfig { memory_budget: budget, ..Default::default() })
    }

    #[test]
    fn first_request_cold_second_warm() {
        let mut r = router(1 << 30);
        let a = r.handle("tinynet").unwrap();
        assert!(a.cold);
        let b = r.handle("tinynet").unwrap();
        assert!(!b.cold);
        assert!(b.latency_ms <= a.latency_ms);
        assert_eq!(r.stats_cold, 1);
        assert_eq!(r.stats_warm, 1);
    }

    #[test]
    fn warm_ladder_descends_to_steady_state() {
        let mut r = router(1 << 30);
        let l1 = r.handle("squeezenet").unwrap().latency_ms;
        let l2 = r.handle("squeezenet").unwrap().latency_ms;
        let l3 = r.handle("squeezenet").unwrap().latency_ms;
        let l4 = r.handle("squeezenet").unwrap().latency_ms;
        assert!(l1 > l2, "cold {l1} > 2nd {l2}");
        assert!(l2 >= l3, "2nd {l2} >= 3rd {l3}");
        assert_eq!(l3, l4, "steady state from 3rd inference");
    }

    #[test]
    fn tight_budget_causes_evictions_and_recold() {
        // Budget fits roughly one model: alternating requests thrash.
        let mut r = router(6 << 20);
        r.handle("squeezenet").unwrap();
        let out = r.handle("micro-mobilenet");
        // squeezenet (~5MB resident +25%) + micro must exceed 6MB ⇒ evict.
        let out = out.unwrap();
        assert!(out.cold);
        assert!(out.evictions > 0 || r.mem_used() <= 6 << 20);
        let back = r.handle("squeezenet").unwrap();
        assert!(back.cold, "evicted model must cold-start again");
    }

    #[test]
    fn shared_plan_cache_skips_replanning() {
        let dev = profiles::meizu_16t();
        let models = || vec![zoo::tiny_net(), zoo::squeezenet()];
        let cache = Arc::new(PlanCache::new());
        let a = Router::with_plan_cache(&dev, models(), RouterConfig::default(), cache.clone());
        assert_eq!(cache.misses(), 2, "first router plans each model once");
        assert_eq!(cache.hits(), 0);
        // A restarted / sibling router re-uses every plan.
        let b = Router::with_plan_cache(&dev, models(), RouterConfig::default(), cache.clone());
        assert_eq!(cache.misses(), 2);
        assert_eq!(cache.hits(), 2);
        // And identical plans ⇒ identical cold latencies.
        let mut a = a;
        let mut b = b;
        assert_eq!(
            a.handle("squeezenet").unwrap().latency_ms.to_bits(),
            b.handle("squeezenet").unwrap().latency_ms.to_bits()
        );
    }

    #[test]
    fn unknown_model_is_none() {
        let mut r = router(1 << 30);
        assert!(r.handle("nope").is_none());
    }

    #[test]
    fn nnv12_colder_starts_beat_ncnn() {
        let dev = profiles::meizu_16t();
        let models = vec![zoo::squeezenet()];
        let mut nnv12 = Router::new(
            &dev,
            models.clone(),
            RouterConfig { engine: ServeEngine::Nnv12, ..Default::default() },
        );
        let mut ncnn = Router::new(
            &dev,
            models,
            RouterConfig { engine: ServeEngine::Ncnn, ..Default::default() },
        );
        let a = nnv12.handle("squeezenet").unwrap().latency_ms;
        let b = ncnn.handle("squeezenet").unwrap().latency_ms;
        assert!(a < b, "nnv12 cold {a} vs ncnn cold {b}");
    }
}
