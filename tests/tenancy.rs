//! Multi-tenant serving: structural isolation and attribution, through
//! the public API only.
//!
//! The engine gives each tenant its own residency lane (an intrusive LRU
//! chain with its own budget), so isolation is by construction: evicting
//! in one lane never touches another lane's sessions. These tests pin
//! that contract where users actually hold it — [`EngineBuilder::
//! tenant_budget`] + [`Engine::load_for_tenant`] at the engine layer,
//! `RouterConfig::tenants` at the serving layer — and check that the
//! router's per-tenant outcome counters conserve against the globals.

use nnv12::device::profiles;
use nnv12::engine::{Engine, Phase};
use nnv12::graph::zoo;
use nnv12::serving::{generate, Router, RouterConfig, WorkloadSpec};
use nnv12::util::prop;

/// Residency footprint the engine charges for a model: weights + 25%
/// activation slack (mirrors `Session::resident_bytes`).
fn footprint(g: &nnv12::graph::ModelGraph) -> u64 {
    g.weight_bytes() + g.weight_bytes() / 4
}

#[test]
fn tenant_quota_isolates_eviction_storms_public_api() {
    // Property: a victim tenant serving comfortably under its own quota
    // must be completely unaffected by ANY storm of loads/inferences from
    // a noisy neighbour with a too-small quota — no evictions, no lane
    // usage drift, warm stays warm.
    prop::check(0x7e9a_11c3, 10, |rng| {
        let engine = Engine::builder()
            .device(profiles::meizu_16t())
            .tenant_budget("noisy", rng.range(1, 1024))
            .tenant_budget("victim", u64::MAX)
            .build();

        let nv = rng.index(3) + 1;
        let victims: Vec<_> = (0..nv)
            .map(|i| engine.load_for_tenant(zoo::synthetic_model(0xBEEF, i), "victim"))
            .collect();
        for v in &victims {
            if v.infer().phase != Phase::Cold {
                return Err("first inference must be cold".into());
            }
        }
        let used = engine.tenant_mem_used("victim");

        let storm = rng.range(1, 30);
        for i in 0..storm {
            let s = engine.load_for_tenant(zoo::synthetic_model(0xD00D, (i % 5) as usize), "noisy");
            s.infer();
            if s.is_resident() {
                return Err("noisy tenant's quota is too small to ever hold a model".into());
            }
        }

        for v in &victims {
            if !v.is_resident() {
                return Err(format!(
                    "noisy tenant's storm cold-started victim session {}",
                    v.name()
                ));
            }
            if v.infer().phase == Phase::Cold {
                return Err("victim must still be warm after the storm".into());
            }
            if v.tenant() != Some("victim") {
                return Err("session must report its owning tenant".into());
            }
        }
        if engine.tenant_mem_used("victim") != used {
            return Err("victim lane usage changed during the noisy storm".into());
        }
        Ok(())
    });
}

#[test]
fn router_partitions_fleet_round_robin_and_isolates() {
    let dev = profiles::meizu_16t();
    // Construction order fixes ownership (model i → tenant-{i % K}).
    // Interleave big and small models so tenant-0 owns the two big ones:
    // its equal share is sized below either big model (every tenant-0
    // request stays cold) while tenant-1's small models fit theirs.
    let models: Vec<_> = ["googlenet", "squeezenet", "resnet18", "shufflenetv2"]
        .iter()
        .map(|m| zoo::by_name(m).unwrap())
        .collect();
    let fp: Vec<u64> = models.iter().map(footprint).collect();
    let share = fp[0].min(fp[2]) - 1;
    assert!(
        share >= fp[1].max(fp[3]),
        "test premise: small models must fit the share that starves the big ones ({fp:?})"
    );
    let router = Router::new(&dev, models, RouterConfig {
        memory_budget: 2 * share,
        tenants: 2,
        ..Default::default()
    });

    for (i, name) in ["googlenet", "squeezenet", "resnet18", "shufflenetv2"]
        .iter()
        .enumerate()
    {
        let sess = router.session(name).unwrap();
        assert_eq!(sess.tenant(), Some(format!("tenant-{}", i % 2).as_str()));
    }

    // Park a tenant-1 model, then storm tenant-0's lane.
    assert!(router.request("squeezenet").unwrap().is_cold());
    let used = router.engine().tenant_mem_used("tenant-1").unwrap();
    for _ in 0..20 {
        assert!(router.request("googlenet").unwrap().is_cold());
        assert!(router.request("resnet18").unwrap().is_cold());
    }
    assert!(router.is_resident("squeezenet"), "tenant-0's storm evicted tenant-1");
    assert_eq!(router.engine().tenant_mem_used("tenant-1"), Some(used));
    assert!(router.request("squeezenet").unwrap().is_warm());

    let s = router.summary();
    assert!(s.conserves(), "{s:?}");
    assert_eq!(s.per_tenant.len(), 2);
    assert_eq!(
        (s.per_tenant[0].cold, s.per_tenant[0].warm),
        (40, 0),
        "starved tenant-0 must be all-cold: {:?}",
        s.per_tenant
    );
    assert_eq!((s.per_tenant[1].cold, s.per_tenant[1].warm), (1, 1));

    // Explicit stamps override model ownership: a request carrying
    // tenant-1's identity for a tenant-0 model bills tenant-1.
    router.request_for("googlenet", None, Some("tenant-1")).unwrap();
    let s = router.summary();
    assert_eq!(s.per_tenant[1].cold, 2);
    assert_eq!(s.per_tenant[0].cold, 40);
}

#[test]
fn per_tenant_counters_conserve_over_a_stamped_trace() {
    let dev = profiles::meizu_16t();
    let models = zoo::synthetic(0xFEED, 12);
    let names: Vec<String> = models.iter().map(|g| g.name.clone()).collect();
    let budget: u64 = models.iter().map(footprint).sum::<u64>() / 3;
    let router = Router::new(&dev, models, RouterConfig {
        memory_budget: budget,
        tenants: 4,
        ..Default::default()
    });

    let reqs = generate(&names, &WorkloadSpec {
        n_requests: 400,
        zipf_s: 0.8,
        tenants: 4,
        ..Default::default()
    });
    assert!(reqs.iter().all(|r| r.tenant.is_some()), "every request stamped");
    assert_eq!(router.replay(&reqs, 2), reqs.len());

    let s = router.summary();
    assert!(s.conserves(), "{s:?}");
    assert_eq!(s.per_tenant.len(), 4);
    for (k, t) in s.per_tenant.iter().enumerate() {
        assert_eq!(t.tenant, format!("tenant-{k}"));
    }
    // Fully-stamped trace, fully-owned fleet: per-tenant rows sum exactly
    // to the global cold/warm/shed counters.
    let (c, w, sh) = s
        .per_tenant
        .iter()
        .fold((0, 0, 0), |(c, w, sh), t| (c + t.cold, w + t.warm, sh + t.shed));
    assert_eq!((c, w, sh), (s.cold, s.warm, s.shed), "{:?}", s.per_tenant);
    assert!(s.cold > 12, "a third of the footprint must thrash: {s:?}");

    // An untenanted router reports no per-tenant rows at all.
    let plain = Router::new(&dev, zoo::synthetic(0xFEED, 2), RouterConfig::default());
    plain.request(&names[0]).unwrap();
    assert!(plain.summary().per_tenant.is_empty());
}
