//! Rust-vs-Python transform parity: the Rust weight transforms
//! (rust/src/transform) must produce bit-compatible layouts with the
//! Python build-time transforms (python/compile/kernels/ref.py), because
//! the AOT'd exec HLO consumes whichever one ran.
//!
//! Goldens are emitted by `make artifacts` (aot.py::export_goldens);
//! the tests skip when artifacts are absent.

use std::path::{Path, PathBuf};

use nnv12::graph::{Layer, OpKind};
use nnv12::transform::{transform_by_name, winograd23_weights};
use nnv12::util::json::Json;
use nnv12::weights::read_f32;

fn goldens_dir() -> Option<PathBuf> {
    let d = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/goldens");
    d.join("meta.json").exists().then_some(d)
}

struct Golden {
    c_out: usize,
    c_in: usize,
    k: usize,
    raw: Vec<f32>,
    winograd: Vec<f32>,
    im2col: Vec<f32>,
}

fn load() -> Option<Golden> {
    let dir = goldens_dir()?;
    let meta = Json::parse(&std::fs::read_to_string(dir.join("meta.json")).ok()?).ok()?;
    Some(Golden {
        c_out: meta.get("c_out").as_usize()?,
        c_in: meta.get("c_in").as_usize()?,
        k: meta.get("k").as_usize()?,
        raw: read_f32(&dir.join("conv.raw.bin")).ok()?,
        winograd: read_f32(&dir.join("conv.winograd.bin")).ok()?,
        im2col: read_f32(&dir.join("conv.im2col.bin")).ok()?,
    })
}

fn close(a: &[f32], b: &[f32], tol: f32) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| (x - y).abs() <= tol)
}

#[test]
fn winograd_transform_matches_python() {
    let Some(g) = load() else {
        eprintln!("skipping: artifacts/goldens not built");
        return;
    };
    let wlen = g.c_out * g.c_in * g.k * g.k;
    let (w, bias) = g.raw.split_at(wlen);
    let mut ours = winograd23_weights(w, g.c_out, g.c_in);
    ours.extend_from_slice(bias);
    assert!(
        close(&ours, &g.winograd, 1e-5),
        "rust winograd transform diverges from python golden"
    );
}

#[test]
fn im2col_transform_matches_python() {
    let Some(g) = load() else {
        eprintln!("skipping: artifacts/goldens not built");
        return;
    };
    // im2col is a reshape: identical numbers.
    assert!(close(&g.raw, &g.im2col, 0.0));
}

#[test]
fn dispatch_matches_python_golden() {
    let Some(g) = load() else {
        eprintln!("skipping: artifacts/goldens not built");
        return;
    };
    let layer = Layer {
        id: 0,
        name: "golden".into(),
        op: OpKind::Conv { kernel: g.k as u32, stride: 1, groups: 1 },
        in_ch: g.c_in as u32,
        out_ch: g.c_out as u32,
        in_hw: 8,
        out_hw: 8,
        deps: vec![],
    };
    let wino = transform_by_name("winograd", &g.raw, &layer).unwrap();
    assert!(close(&wino, &g.winograd, 1e-5));
    let im2col = transform_by_name("im2col", &g.raw, &layer).unwrap();
    assert!(close(&im2col, &g.im2col, 0.0));
}
