//! Cross-module integration: scheduler ⇄ simulator agreement, the Fig. 7
//! walk-through, paper-headline invariants over the full model zoo, and
//! property tests on random graphs (plan validity, dependency safety,
//! makespan bounds).

use nnv12::baselines::{cold_ms, Engine};
use nnv12::cost::CostModel;
use nnv12::device::profiles;
use nnv12::graph::builder::GraphBuilder;
use nnv12::graph::zoo;
use nnv12::kernels::Registry;
use nnv12::sched::heuristic::{schedule, SchedulerConfig};
use nnv12::sched::makespan::{critical_path_ms, evaluate};
use nnv12::sched::op::OpStage;
use nnv12::sched::plan::UnitId;
use nnv12::sched::price::Pricer;
use nnv12::sim::{simulate, SimConfig};
use nnv12::util::prop;
use nnv12::util::rng::Rng;

/// The Fig. 7 illustrative example: a 4-layer model on a 4+4 device. The
/// first layer's preparation lands on the gang, the remaining
/// preparations spread over little cores, and all executions run on the
/// gang in model order.
#[test]
fn sched_example_fig7() {
    let dev = profiles::meizu_16t();
    let mut b = GraphBuilder::new("fig7");
    b.input(4, 32);
    b.conv("l1", 16, 3, 1);
    b.conv("l2", 16, 3, 1);
    b.conv("l3", 32, 3, 1);
    b.conv("l4", 32, 3, 1);
    let g = b.build().unwrap();
    let s = schedule(&dev, &g, &Registry::full(), &SchedulerConfig::kcp());
    s.plan.validate(&s.set).unwrap();
    // All execs on the gang, in layer order.
    let exec_layers: Vec<usize> = s
        .plan
        .gang
        .iter()
        .filter(|&&op| s.set.ops[op].stage == OpStage::Exec)
        .map(|&op| s.set.ops[op].layer)
        .collect();
    let mut sorted = exec_layers.clone();
    sorted.sort_unstable();
    assert_eq!(exec_layers, sorted, "execs must stay in model order");
    assert_eq!(exec_layers.len(), 4);
    // Layer 1's preparation was promoted to the gang (fast boot).
    let gang_reads: Vec<usize> = s
        .plan
        .gang
        .iter()
        .filter(|&&op| s.set.ops[op].stage == OpStage::Read)
        .map(|&op| s.set.ops[op].layer)
        .collect();
    assert!(gang_reads.contains(&1), "first prep should boot on the gang");
    // Remaining preparations live on little cores.
    let little_ops: usize = s.plan.little.iter().map(Vec::len).sum();
    assert!(little_ops > 0, "pipelining must use the little cores");
}

/// Paper headline: NNV12 beats ncnn on every model/device, with meaningful
/// average speedup (paper: 2.8–3.9× on phones).
#[test]
fn nnv12_beats_ncnn_across_zoo() {
    let reg = Registry::full();
    for dev in [profiles::meizu_16t(), profiles::pixel_5()] {
        let mut speedups = Vec::new();
        for model in zoo::PAPER_MODELS {
            let g = zoo::by_name(model).unwrap();
            let s = schedule(&dev, &g, &reg, &SchedulerConfig::kcp());
            let pricer = Pricer::new(&dev, &g, &s.plan.choices, true);
            let ours = simulate(&dev, &s.set, &s.plan, &pricer, &SimConfig::nnv12()).makespan;
            let ncnn = cold_ms(Engine::Ncnn, &dev, &g);
            assert!(
                ours < ncnn,
                "{model} on {}: nnv12 {ours:.1} >= ncnn {ncnn:.1}",
                dev.name
            );
            speedups.push(ncnn / ours);
        }
        let avg = nnv12::util::stats::geomean(&speedups);
        assert!(
            avg > 1.8,
            "{}: average speedup {avg:.2} too small (paper ~2.8-3.9x)",
            dev.name
        );
    }
}

/// GPU headline: larger speedups on Jetsons (paper: 28-30x average vs
/// ncnn-Vulkan) thanks to pipeline-creation overlap + shader cache.
#[test]
fn gpu_speedups_exceed_cpu_speedups() {
    let reg = Registry::full();
    let cpu = profiles::meizu_16t();
    let gpu = profiles::jetson_tx2();
    let mut cpu_sp = Vec::new();
    let mut gpu_sp = Vec::new();
    for model in ["googlenet", "resnet50", "mobilenetv2", "squeezenet"] {
        let g = zoo::by_name(model).unwrap();
        for (dev, out) in [(&cpu, &mut cpu_sp), (&gpu, &mut gpu_sp)] {
            let s = schedule(dev, &g, &reg, &SchedulerConfig::kcp());
            let pricer = Pricer::new(dev, &g, &s.plan.choices, true);
            let ours = simulate(dev, &s.set, &s.plan, &pricer, &SimConfig::nnv12()).makespan;
            out.push(cold_ms(Engine::Ncnn, dev, &g) / ours);
        }
    }
    let cpu_avg = nnv12::util::stats::geomean(&cpu_sp);
    let gpu_avg = nnv12::util::stats::geomean(&gpu_sp);
    assert!(
        gpu_avg > 2.0 * cpu_avg,
        "gpu avg {gpu_avg:.1}x should far exceed cpu avg {cpu_avg:.1}x"
    );
}

/// Simulator == evaluator when contention and stealing are off, across the
/// whole zoo and several devices.
#[test]
fn sim_matches_evaluator_without_contention() {
    let reg = Registry::full();
    for dev in [profiles::meizu_16t(), profiles::redmi_9(), profiles::jetson_tx2()] {
        for model in ["mobilenet", "squeezenet", "resnet18"] {
            let g = zoo::by_name(model).unwrap();
            let s = schedule(&dev, &g, &reg, &SchedulerConfig::kcp());
            let pricer = Pricer::new(&dev, &g, &s.plan.choices, true);
            let eval = evaluate(&s.set, &s.plan, &pricer).unwrap();
            let sim = simulate(
                &dev,
                &s.set,
                &s.plan,
                &pricer,
                &SimConfig { stealing: false, contention: false, background: vec![] },
            );
            assert!(
                (sim.makespan - eval.makespan).abs() < 1e-6,
                "{model}@{}: sim {} vs eval {}",
                dev.name,
                sim.makespan,
                eval.makespan
            );
        }
    }
}

/// Property: on random layer graphs, the scheduler always produces a valid
/// plan whose makespan is ≥ the critical path and ≤ the fully sequential
/// cold time (+ small numerical slack).
#[test]
fn prop_random_graphs_schedule_validly() {
    let dev = profiles::meizu_16t();
    let reg = Registry::full();
    prop::check(0xC01D, 40, |rng: &mut Rng| {
        let g = random_graph(rng);
        let s = schedule(&dev, &g, &reg, &SchedulerConfig::kcp());
        s.plan.validate(&s.set).map_err(|e| format!("{}: {e}", g.name))?;
        let pricer = Pricer::new(&dev, &g, &s.plan.choices, true);
        let cp = critical_path_ms(&s.set, &pricer);
        if s.schedule.makespan < cp - 1e-6 {
            return Err(format!(
                "makespan {} below critical path {cp}",
                s.schedule.makespan
            ));
        }
        // Sequential upper bound with the same kernel choices.
        let seq_cfg = SchedulerConfig { pipeline: false, ..SchedulerConfig::kcp() };
        let seq = schedule(&dev, &g, &reg, &seq_cfg);
        if s.schedule.makespan > seq.schedule.makespan * 1.05 {
            return Err(format!(
                "pipelined {} far above sequential {}",
                s.schedule.makespan, seq.schedule.makespan
            ));
        }
        // Dependencies hold in the simulated execution too.
        let sim = simulate(&dev, &s.set, &s.plan, &pricer, &SimConfig::nnv12());
        for op in &s.set.ops {
            for &d in &op.deps {
                if sim.timings[op.id].start < sim.timings[d].finish - 1e-9 {
                    return Err(format!("op {} started before dep {d}", op.id));
                }
            }
        }
        Ok(())
    });
}

/// Property: the heuristic's kernel choices never pick a kernel that is
/// inapplicable to its layer (every choice came from the registry).
#[test]
fn prop_choices_are_applicable() {
    let dev = profiles::pixel_5();
    let reg = Registry::full();
    prop::check(0xBEEF, 25, |rng: &mut Rng| {
        let g = random_graph(rng);
        let s = schedule(&dev, &g, &reg, &SchedulerConfig::kcp());
        for (i, c) in s.plan.choices.iter().enumerate() {
            let layer = g.layer(i);
            match c {
                Some(c) => {
                    let names: Vec<String> = reg
                        .candidates(layer)
                        .into_iter()
                        .map(|k| k.name)
                        .collect();
                    if !names.contains(&c.kernel.name) {
                        return Err(format!(
                            "layer {i} chose inapplicable kernel {}",
                            c.kernel.name
                        ));
                    }
                    if c.cache && !c.kernel.family.needs_transform() {
                        return Err(format!("layer {i} caches a no-transform kernel"));
                    }
                }
                None => {
                    if layer.op.has_weights() {
                        return Err(format!("weighted layer {i} has no choice"));
                    }
                }
            }
        }
        Ok(())
    });
}

/// Property: warm inference is a lower bound for cold inference.
#[test]
fn prop_cold_at_least_warm() {
    let dev = profiles::meizu_16t();
    let reg = Registry::full();
    let cm = CostModel::new(&dev);
    prop::check(0x3A3A, 25, |rng: &mut Rng| {
        let g = random_graph(rng);
        let s = schedule(&dev, &g, &reg, &SchedulerConfig::kcp());
        let warm = cm.warm_ms(&g, &reg);
        // The heuristic's exec kernels may differ from warm-optimal, so
        // allow a hair of slack for fp noise only.
        if s.schedule.makespan < warm * 0.999 {
            return Err(format!(
                "cold {} below warm bound {warm}",
                s.schedule.makespan
            ));
        }
        Ok(())
    });
}

/// Random chain-with-branches CNN generator for property tests.
fn random_graph(rng: &mut Rng) -> nnv12::graph::ModelGraph {
    let mut b = GraphBuilder::new("prop");
    let mut hw = *rng.choose(&[16u32, 28, 32, 56]);
    b.input(*rng.choose(&[3u32, 4, 8]), hw);
    let n_layers = rng.range(2, 12) as usize;
    let mut branch: Option<nnv12::graph::builder::Tap> = None;
    for i in 0..n_layers {
        let roll = rng.f64();
        if roll < 0.55 {
            let k = *rng.choose(&[1u32, 3, 3, 5]);
            let s = if hw >= 8 && rng.chance(0.3) { 2 } else { 1 };
            let out = *rng.choose(&[8u32, 16, 24, 32, 64]);
            let t = b.conv(&format!("c{i}"), out, k, s);
            hw = t.hw;
            if branch.is_none() && rng.chance(0.3) {
                branch = Some(t);
            }
        } else if roll < 0.7 {
            if b.tap().ch % 4 == 0 && rng.chance(0.5) {
                b.dwconv(&format!("dw{i}"), 3, 1);
            } else {
                b.pwconv(&format!("pw{i}"), *rng.choose(&[16u32, 32, 48]));
            }
        } else if roll < 0.85 && hw >= 4 {
            b.pool(&format!("p{i}"), 2, 2);
            hw = b.tap().hw;
            branch = None; // shapes diverge: drop pending branch
        } else {
            // Branch merge when shapes still line up.
            if let Some(t) = branch.take() {
                if t.hw == b.tap().hw {
                    let cur = b.tap();
                    if cur.ch == t.ch && cur.id != t.id {
                        b.add(&format!("add{i}"), t);
                        continue;
                    }
                }
            }
            b.pwconv(&format!("x{i}"), 16);
        }
    }
    b.global_pool("gap");
    b.fc("fc", 10);
    b.build().unwrap()
}
