//! Engine/Session facade integration: the lifecycle state machine
//! (cold → warming → warm, eviction re-colds), backend parity with the
//! underlying simulator, the disk-persistent plan store round trip, and
//! parallel multi-model startup planning.

use std::path::PathBuf;

use nnv12::device::profiles;
use nnv12::engine::{BaselineBackend, Engine, Phase, SimBackend};
use nnv12::graph::zoo;
use nnv12::sched::price::Pricer;
use nnv12::sim::{simulate, SimConfig};

#[test]
fn session_lifecycle_cold_then_monotone_to_warm() {
    let engine = Engine::builder().device(profiles::meizu_16t()).build();
    let session = engine.load(zoo::googlenet());
    assert!(!session.is_resident());

    let mut phases = Vec::new();
    let mut latencies = Vec::new();
    for _ in 0..8 {
        let r = session.infer();
        phases.push(r.phase);
        latencies.push(r.latency_ms);
    }
    // First inference is cold; the lifecycle never regresses (warming
    // cannot follow warm without an eviction) and ends warm.
    assert_eq!(phases[0], Phase::Cold);
    assert!(session.is_resident());
    let first_warm = phases
        .iter()
        .position(|p| *p == Phase::Warm)
        .expect("must reach steady state");
    for (i, p) in phases.iter().enumerate() {
        match p {
            Phase::Cold => assert_eq!(i, 0, "cold only at the start"),
            Phase::Warming { n } => {
                assert!(i < first_warm, "warming after warm at step {i}");
                assert_eq!(*n, i, "ladder rung mismatch at step {i}");
            }
            Phase::Warm => assert_eq!(latencies[i].to_bits(), session.warm_ms().to_bits()),
        }
    }
    // Latencies walk down the session's ladder.
    assert!(latencies[0] > *latencies.last().unwrap());
    for w in latencies.windows(2) {
        assert!(w[1] <= w[0] + 1e-9, "ladder must be non-increasing: {w:?}");
    }
    assert_eq!(latencies[0].to_bits(), session.cold_ms().to_bits());
}

#[test]
fn eviction_under_budget_pressure_recolds() {
    // Budget fits roughly one model: alternating inference thrashes.
    let engine = Engine::builder()
        .device(profiles::meizu_16t())
        .memory_budget(6 << 20)
        .build();
    let squeeze = engine.load(zoo::squeezenet());
    let micro = engine.load(zoo::micro_mobilenet());

    assert_eq!(squeeze.infer().phase, Phase::Cold);
    let b = micro.infer();
    assert_eq!(b.phase, Phase::Cold);
    assert!(b.evictions > 0 || engine.mem_used() <= 6 << 20);
    assert!(!squeeze.is_resident(), "squeezenet must have been evicted");
    // The evicted session cold-starts again — and again reports Cold.
    let again = squeeze.infer();
    assert_eq!(again.phase, Phase::Cold);
    assert_eq!(again.latency_ms.to_bits(), squeeze.cold_ms().to_bits());
}

#[test]
fn simbackend_matches_direct_simulator_call() {
    let dev = profiles::meizu_16t();
    let engine = Engine::builder()
        .device(dev.clone())
        .backend(SimBackend::with(SimConfig::nnv12()))
        .build();
    let session = engine.load(zoo::googlenet());
    let via_facade = session.run_cold().expect("sim backend");

    let s = session.scheduled();
    let pricer = Pricer::new(&dev, session.graph(), &s.plan.choices, true);
    let direct = simulate(&dev, &s.set, &s.plan, &pricer, &SimConfig::nnv12());
    assert_eq!(
        via_facade.latency_ms.to_bits(),
        direct.makespan.to_bits(),
        "facade and direct simulator must agree bit-for-bit"
    );
    assert_eq!(via_facade.steals, direct.steals);
    assert_eq!(via_facade.energy_mj.to_bits(), direct.energy_mj.to_bits());
    assert_eq!(via_facade.timings.len(), direct.timings.len());
}

#[test]
fn baseline_backend_charges_ncnn_latencies() {
    let dev = profiles::meizu_16t();
    let g = zoo::squeezenet();
    let engine = Engine::builder()
        .device(dev.clone())
        .backend(BaselineBackend::ncnn())
        .build();
    let session = engine.load(g.clone());
    let cold = nnv12::baselines::cold_ms(nnv12::baselines::Engine::Ncnn, &dev, &g);
    let warm = nnv12::baselines::warm_ms(nnv12::baselines::Engine::Ncnn, &dev, &g);
    assert_eq!(session.cold_ms().to_bits(), cold.to_bits());
    assert_eq!(session.warm_ms().to_bits(), warm.to_bits());
    // Baseline ladders have no warming phase: 2nd inference is warm.
    assert_eq!(session.infer().phase, Phase::Cold);
    let second = session.infer();
    assert_eq!(second.phase, Phase::Warm);
    assert_eq!(second.latency_ms.to_bits(), warm.to_bits());
}

fn store_dir(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("nnv12-facade-{tag}-{}", std::process::id()))
}

#[test]
fn artifact_store_round_trip_skips_planning_in_fresh_engine() {
    let dir = store_dir("roundtrip");
    let _ = std::fs::remove_dir_all(&dir);

    // First engine: plans, persists.
    let a = Engine::builder()
        .device(profiles::meizu_16t())
        .artifact_store(&dir)
        .build();
    let s1 = a.load(zoo::squeezenet());
    assert_eq!(a.plan_cache().misses(), 1);
    assert_eq!(a.plan_cache().disk_hits(), 0);
    let stats = a.store_stats().expect("store-backed engine has stats");
    assert_eq!(stats.hits, 0);
    assert!(stats.bytes_used > 0, "plan artifact must be on disk");

    // Second engine on the same directory (≈ a process restart): the
    // plan comes from disk — planning is skipped entirely.
    let b = Engine::builder()
        .device(profiles::meizu_16t())
        .artifact_store(&dir)
        .build();
    let s2 = b.load(zoo::squeezenet());
    assert_eq!(b.plan_cache().misses(), 0, "fresh engine must not re-plan");
    assert_eq!(b.plan_cache().disk_hits(), 1, "plan must come from the store");
    assert_eq!(b.store_stats().unwrap().hits, 1);

    // The reloaded plan is bit-identical: same JSON artifact, same
    // makespan, same cold/warm ladder.
    assert_eq!(
        s1.plan().to_json(s1.graph()).to_compact(),
        s2.plan().to_json(s2.graph()).to_compact()
    );
    assert_eq!(
        s1.scheduled().schedule.makespan.to_bits(),
        s2.scheduled().schedule.makespan.to_bits()
    );
    assert_eq!(s1.cold_ms().to_bits(), s2.cold_ms().to_bits());
    assert_eq!(s1.warm_ms().to_bits(), s2.warm_ms().to_bits());

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
#[allow(deprecated)] // exercises the `plan_store` compatibility shim
fn deprecated_plan_store_shim_still_persists() {
    let dir = store_dir("shim");
    let _ = std::fs::remove_dir_all(&dir);
    let a = Engine::builder()
        .device(profiles::meizu_16t())
        .plan_store(&dir)
        .build();
    a.load(zoo::tiny_net());
    assert_eq!(a.plan_cache().misses(), 1);

    let b = Engine::builder()
        .device(profiles::meizu_16t())
        .artifact_store(&dir)
        .build();
    b.load(zoo::tiny_net());
    assert_eq!(b.plan_cache().misses(), 0, "shim and store must share artifacts");
    assert_eq!(b.plan_cache().disk_hits(), 1);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn load_all_plans_in_parallel_and_matches_sequential() {
    let dev = profiles::meizu_16t();
    let models = || {
        vec![
            zoo::squeezenet(),
            zoo::mobilenet_v1(),
            zoo::micro_mobilenet(),
            zoo::tiny_net(),
        ]
    };
    let par = Engine::builder().device(dev.clone()).build();
    let sessions = par.load_all(models());
    assert_eq!(sessions.len(), 4);
    assert_eq!(par.plan_cache().misses(), 4, "each model planned exactly once");

    let seq = Engine::builder().device(dev).build();
    for (i, g) in models().into_iter().enumerate() {
        let s = seq.load(g);
        assert_eq!(
            s.scheduled().schedule.makespan.to_bits(),
            sessions[i].scheduled().schedule.makespan.to_bits(),
            "parallel and sequential planning disagree for {}",
            s.name()
        );
        assert_eq!(s.cold_ms().to_bits(), sessions[i].cold_ms().to_bits());
    }

    // Shared cache: a second fleet load is all hits.
    let again = par.load_all(models());
    assert_eq!(par.plan_cache().misses(), 4);
    assert_eq!(par.plan_cache().hits(), 4);
    assert_eq!(again.len(), 4);
}
