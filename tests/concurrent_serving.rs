//! Concurrent serving integration: compile-time `Send`/`Sync` contracts
//! for the engine substrate, multi- vs single-threaded replay parity over
//! the sharded router, and eviction-thrash stress under concurrency.
//!
//! The parity contract (ISSUE 5): N threads hammering the sharded router
//! must produce the same aggregate cold/warm counts — and bit-identical
//! plans — as the same request trace replayed single-threaded. With an
//! unbounded residency budget the outcome is interleaving-independent
//! (each model is cold exactly once, then walks its deterministic
//! warm-up ladder), so even the per-model latency *multisets* must
//! match bit-for-bit.

use std::sync::Arc;

use nnv12::device::profiles;
use nnv12::engine::{BaselineBackend, Engine, ExecBackend, Session, SimBackend};
use nnv12::graph::zoo;
use nnv12::serving::{generate, Router, RouterConfig, WorkloadSpec};

fn assert_send<T: Send>() {}
fn assert_sync<T: Sync>() {}

#[test]
fn engine_and_serving_types_are_send_and_sync() {
    // Compile-time assertions: a regression back to `Rc`/`RefCell`
    // internals (non-Send sessions, non-Sync engines or routers) fails
    // `cargo test` at this very line instead of surfacing as a distant
    // "cannot be sent between threads" error in some consumer.
    assert_send::<Engine>();
    assert_sync::<Engine>();
    assert_send::<Session>();
    assert_sync::<Session>();
    assert_send::<Router>();
    assert_sync::<Router>();
    assert_send::<SimBackend>();
    assert_sync::<SimBackend>();
    assert_send::<BaselineBackend>();
    assert_sync::<BaselineBackend>();
    // The backend seam itself guarantees thread-safety by trait bound.
    assert_send::<Box<dyn ExecBackend>>();
    assert_sync::<Box<dyn ExecBackend>>();
    assert_send::<Arc<nnv12::sched::cache::PlanCache>>();
    assert_sync::<Arc<nnv12::sched::cache::PlanCache>>();
    assert_send::<Arc<nnv12::store::ArtifactStore>>();
    assert_sync::<Arc<nnv12::store::ArtifactStore>>();
}

fn models() -> Vec<nnv12::graph::ModelGraph> {
    ["squeezenet", "shufflenetv2", "mobilenetv2", "googlenet"]
        .iter()
        .map(|m| zoo::by_name(m).unwrap())
        .collect()
}

#[test]
fn threaded_replay_matches_single_threaded_aggregates_and_plan_bits() {
    let dev = profiles::meizu_16t();
    let cfg = RouterConfig {
        memory_budget: u64::MAX,
        execute_cold: true,
        ..Default::default()
    };
    let single = Router::new(&dev, models(), cfg.clone());
    let threaded = Router::new(&dev, models(), cfg);
    let names = single.model_names();
    let reqs = generate(&names, &WorkloadSpec { n_requests: 120, ..Default::default() });

    assert_eq!(single.replay(&reqs, 1), reqs.len());
    assert_eq!(threaded.replay(&reqs, 4), reqs.len());

    // Aggregate stats agree: each requested model cold exactly once,
    // ever.
    let requested: std::collections::HashSet<&str> =
        reqs.iter().map(|r| r.model.as_str()).collect();
    assert_eq!(single.stats_cold(), requested.len());
    assert_eq!(threaded.stats_cold(), single.stats_cold());
    assert_eq!(threaded.stats_warm(), single.stats_warm());

    // Bit-identical plans: threading never touches planning.
    for m in &names {
        let a = single.session(m).unwrap();
        let b = threaded.session(m).unwrap();
        assert_eq!(
            a.plan().to_json(a.graph()).to_compact(),
            b.plan().to_json(b.graph()).to_compact(),
            "{m}: plan bits differ across thread counts"
        );
        assert_eq!(a.cold_ms().to_bits(), b.cold_ms().to_bits());
    }

    // With an unbounded budget, each model's rung sequence is a function
    // of its request count alone — so the per-model latency multisets
    // (cold simulation + warm-up ladder) match bit-for-bit across
    // interleavings.
    assert_eq!(single.stats_exec_failed(), 0);
    assert_eq!(threaded.stats_exec_failed(), 0);
    for m in &names {
        for label in ["cold", "warm"] {
            let key = format!("{m}:{label}");
            let mut a: Vec<u64> = single.recorded(&key).iter().map(|v| v.to_bits()).collect();
            let mut b: Vec<u64> =
                threaded.recorded(&key).iter().map(|v| v.to_bits()).collect();
            a.sort_unstable();
            b.sort_unstable();
            assert_eq!(a, b, "{key}: latency multiset differs across thread counts");
        }
    }
}

#[test]
fn eviction_thrash_under_concurrency_stays_consistent() {
    // Under a thrashing budget, *which* request goes cold legitimately
    // depends on arrival interleaving — but the accounting must stay
    // exact: every request is either cold or warm, recorder and atomic
    // counters agree, and the LRU invariant (within budget unless a
    // single oversized model overcommits) holds at the end.
    let dev = profiles::meizu_16t();
    let fleet = models();
    let budget: u64 = fleet
        .iter()
        .map(|g| g.weight_bytes() + g.weight_bytes() / 4)
        .sum::<u64>()
        / 3;
    let r = Router::new(
        &dev,
        fleet,
        RouterConfig { memory_budget: budget, ..Default::default() },
    );
    let names = r.model_names();
    let reqs = generate(
        &names,
        &WorkloadSpec { n_requests: 400, zipf_s: 0.7, ..Default::default() },
    );
    assert_eq!(r.replay(&reqs, 8), reqs.len());
    assert_eq!(r.stats_cold() + r.stats_warm(), reqs.len());
    assert!(
        r.stats_cold() > names.len(),
        "budget must thrash: only {} colds over {} models",
        r.stats_cold(),
        names.len()
    );
    assert_eq!(r.recorded("cold").len(), r.stats_cold());
    assert_eq!(r.recorded("warm").len(), r.stats_warm());
    let residents = names.iter().filter(|n| r.is_resident(n)).count();
    assert!(
        r.mem_used() <= budget || residents == 1,
        "mem {} over budget {budget} with {residents} residents",
        r.mem_used()
    );
    r.engine().evict_all();
    assert_eq!(r.mem_used(), 0);
}

#[test]
fn register_and_serve_concurrently() {
    // The sharded map is mutable while requests are in flight: one
    // thread registers a new model and serves it while another hammers
    // an existing one.
    let dev = profiles::meizu_16t();
    let r = Router::new(&dev, vec![zoo::tiny_net()], RouterConfig::default());
    std::thread::scope(|s| {
        s.spawn(|| {
            for _ in 0..50 {
                r.request("tinynet").unwrap();
            }
        });
        s.spawn(|| {
            r.register(zoo::micro_mobilenet());
            for _ in 0..50 {
                r.request("micro-mobilenet").unwrap();
            }
        });
    });
    assert_eq!(r.stats_cold() + r.stats_warm(), 100);
    assert_eq!(r.stats_cold(), 2, "each model cold-starts exactly once");
    assert_eq!(r.model_names().len(), 2);
}
